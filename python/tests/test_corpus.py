"""Corpus / RNG tests: the Python generator must match the Rust one
bit-for-bit (rust/src/util/rng.rs::xlang_tests pins the same vector)."""

from compile import corpus


def test_pcg64_cross_language_vector():
    r = corpus.Pcg64(42)
    got = [r.next_u64() for _ in range(4)]
    assert got == [
        5707447046872229490,
        7522330712029359324,
        16568102611872412033,
        560887338126967608,
    ]


def test_range_unbiased_bounds():
    r = corpus.Pcg64(7)
    vals = [r.range(3, 9) for _ in range(2000)]
    assert min(vals) == 3 and max(vals) == 8


def test_grammar_examples():
    r = corpus.Pcg64(1)
    p, a = corpus.gen_example(r, "copy")
    assert p.startswith("C:") and p.endswith("=") and a.endswith(";")
    assert p[2:-1] == a[:-1]
    p, a = corpus.gen_example(r, "sort")
    assert sorted(p[2:-1]) == list(a[:-1])
    p, a = corpus.gen_example(r, "add")
    x, y = p[2:-1].split("+")
    assert int(x) + int(y) == int(a[:-1])


def test_corpus_bytes_deterministic():
    a = corpus.gen_corpus_bytes(5, 1000)
    b = corpus.gen_corpus_bytes(5, 1000)
    assert a == b and len(a) == 1000
    assert corpus.gen_corpus_bytes(6, 1000) != a


def test_eval_prompts_disjoint_streams():
    c = corpus.eval_prompts(100, "copy", 5)
    s = corpus.eval_prompts(100, "sort", 5)
    assert len(c) == 5 and len(s) == 5
    assert c[0][0].startswith("C:") and s[0][0].startswith("S:")

"""Format-level tests: decompose/reconstruct vs the bit-level spec.

Mirrors rust/tests/format_exhaustive.rs — the same exhaustive sweeps over
the full 2^16 FP16 space, pinning the Python/JAX implementation to the
Rust one.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from compile.kernels import ref


def all_f16_bits():
    return jnp.arange(0, 1 << 16, dtype=jnp.uint32).astype(jnp.uint16)


@pytest.fixture(scope="module")
def eligible_bits():
    bits = all_f16_bits()
    mask = ref.is_eligible_u16(bits)
    return bits[np.asarray(mask)]


def test_eligibility_rule_matches_value_rule():
    bits = all_f16_bits()
    mask = np.asarray(ref.is_eligible_u16(bits))
    vals = np.asarray(bits.view(jnp.float16)).astype(np.float64)
    expected = np.isfinite(vals) & (np.abs(vals) <= 1.75)
    np.testing.assert_array_equal(mask, expected)


def test_eligible_count():
    bits = all_f16_bits()
    assert int(ref.is_eligible_u16(bits).sum()) == 32_258


def test_exhaustive_lossless_roundtrip(eligible_bits):
    up, lo = ref.decompose_u16(eligible_bits)
    back = ref.reconstruct_u16(up, lo)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(eligible_bits))


def test_upper_never_nan_pattern(eligible_bits):
    up, _ = ref.decompose_u16(eligible_bits)
    assert not np.any((np.asarray(up) & 0x7F) == 0x7F)


def test_exhaustive_upper_matches_e4m3_times_256(eligible_bits):
    """decode(upper) must equal RNE-E4M3(value * 2^8) for every value."""
    up, _ = ref.decompose_u16(eligible_bits)
    decoded = np.asarray(ref.e4m3_decode_u8(up)).astype(np.float64)
    vals = np.asarray(eligible_bits.view(jnp.float16)).astype(np.float64)
    direct = np.asarray(ref.e4m3_fake_quant(jnp.asarray(vals * 256.0, jnp.float32)))
    np.testing.assert_array_equal(decoded, direct.astype(np.float64))


def test_fp8_weight_error_bound(eligible_bits):
    up, _ = ref.decompose_u16(eligible_bits)
    w8 = np.asarray(ref.upper_to_weight_f32(up)).astype(np.float64)
    w16 = np.asarray(eligible_bits.view(jnp.float16)).astype(np.float64)
    nz = w16 != 0
    rel = np.abs((w8[nz] - w16[nz]) / w16[nz])
    absd = np.abs(w8 - w16)
    ok = np.zeros_like(w16, dtype=bool)
    ok[nz] = rel <= 1 / 16 + 1e-9
    ok |= absd <= 2.0 ** -17
    assert ok.all(), f"worst rel {rel.max()}"


def test_checksum_rule(eligible_bits):
    """upper LSB != lower MSB exactly when RNE rounded up."""
    bits = np.asarray(eligible_bits).astype(np.uint32)
    up, lo = ref.decompose_u16(eligible_bits)
    m3 = (np.asarray(lo) >> 7) & 1
    m3p = np.asarray(up) & 1
    base = (bits >> 7) & 0x7F
    rem = bits & 0x7F
    rounded_up = (rem > 64) | ((rem == 64) & ((base & 1) == 1))
    np.testing.assert_array_equal(m3 != m3p, rounded_up)


def test_e4m3_decode_known_values():
    codes = jnp.array([0x00, 0x38, 0x3E, 0x7E, 0x01, 0x08, 0xBE], jnp.uint8)
    vals = np.asarray(ref.e4m3_decode_u8(codes))
    np.testing.assert_allclose(
        vals, [0.0, 1.0, 1.75, 448.0, 2.0**-9, 2.0**-6, -1.75], rtol=0
    )


def test_e4m3_fake_quant_fixed_points():
    """Every exact E4M3 value must be a fixed point of the quantizer."""
    codes = jnp.arange(256, dtype=jnp.uint8)
    vals = ref.e4m3_decode_u8(codes)
    finite = np.isfinite(np.asarray(vals))
    v = np.asarray(vals)[finite]
    q = np.asarray(ref.e4m3_fake_quant(jnp.asarray(v)))
    np.testing.assert_array_equal(q, v)


def test_e4m3_fake_quant_saturates():
    q = np.asarray(ref.e4m3_fake_quant(jnp.asarray([1e9, -1e9, 460.0], jnp.float32)))
    np.testing.assert_array_equal(q, [448.0, -448.0, 448.0])

"""Pallas kernel vs pure-jnp oracle: hypothesis sweeps over shapes/dtypes.

The CORE correctness signal for Layer 1: the tiled, reconstructing GEMM
must match the reference on every shape/block combination.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import nested as knl
from compile.kernels import ref


def rand_weights(rng, n, k, scale=0.25):
    w = (rng.standard_normal((n, k)) * scale).clip(-1.75, 1.75).astype(np.float16)
    return jnp.asarray(w)


def rand_x(rng, m, k, scale=1.0, dtype=np.float16):
    return jnp.asarray((rng.standard_normal((m, k)) * scale).astype(dtype))


# -- fixed-shape sanity ------------------------------------------------------


def test_fp16_kernel_matches_plain_small():
    rng = np.random.default_rng(0)
    w = rand_weights(rng, 64, 64)
    x = rand_x(rng, 8, 64)
    up, lo = ref.decompose_f16(w)
    out = knl.nested_fp16_gemm(x, up, lo, block_m=8, block_n=64, block_k=64)
    expect = ref.gemm_fp16_plain(x, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), rtol=1e-5, atol=1e-5)


def test_fp16_kernel_reconstruction_is_lossless():
    """The kernel's in-tile reconstruction must be bit-exact: feed an
    identity activation so the GEMM output *is* the reconstructed weight."""
    rng = np.random.default_rng(1)
    w = rand_weights(rng, 64, 64)
    up, lo = ref.decompose_f16(w)
    eye = jnp.eye(64, dtype=jnp.float16)
    out = knl.nested_fp16_gemm(eye, up, lo, block_m=32, block_n=64, block_k=64)
    np.testing.assert_array_equal(
        np.asarray(out.T), np.asarray(w).astype(np.float32)
    )


def test_fp8_kernel_matches_ref():
    rng = np.random.default_rng(2)
    w = rand_weights(rng, 128, 64)
    x = rand_x(rng, 16, 64, dtype=np.float32)
    up, _ = ref.decompose_f16(w)
    s = ref.act_scale_per_tensor(x)
    xq = ref.e4m3_fake_quant(x * s) / s
    out = knl.nested_fp8_gemm(xq, up, block_m=16, block_n=64, block_k=64)
    expect = ref.gemm_fp8_nested(x, up, s)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), rtol=1e-5, atol=1e-6)


def test_fp8_close_to_fp16_output():
    """FP8-path output should track the FP16 output within E4M3 noise."""
    rng = np.random.default_rng(3)
    w = rand_weights(rng, 64, 128, scale=0.05)
    x = rand_x(rng, 32, 128, dtype=np.float32)
    up, lo = ref.decompose_f16(w)
    s = ref.act_scale_per_tensor(x)
    xq = ref.e4m3_fake_quant(x * s) / s
    out8 = knl.nested_fp8_gemm(xq, up)
    out16 = ref.gemm_fp16_nested(x.astype(jnp.float16), up, lo)
    denom = float(jnp.linalg.norm(out16))
    rel = float(jnp.linalg.norm(out8 - out16)) / denom
    assert rel < 0.1, f"fp8 vs fp16 rel err {rel}"


# -- hypothesis sweeps -------------------------------------------------------

block_dims = st.sampled_from([8, 16, 32])
shape_mult = st.integers(min_value=1, max_value=3)


@settings(max_examples=20, deadline=None)
@given(
    bm=block_dims,
    mi=shape_mult,
    nj=shape_mult,
    kk=shape_mult,
    seed=st.integers(min_value=0, max_value=2**31),
    scale=st.sampled_from([0.01, 0.25, 1.0]),
)
def test_fp16_kernel_shape_sweep(bm, mi, nj, kk, seed, scale):
    m, n, k = bm * mi, 64 * nj, 64 * kk
    rng = np.random.default_rng(seed)
    w = rand_weights(rng, n, k, scale)
    x = rand_x(rng, m, k)
    up, lo = ref.decompose_f16(w)
    out = knl.nested_fp16_gemm(x, up, lo, block_m=bm, block_n=64, block_k=64)
    expect = ref.gemm_fp16_plain(x, w)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(expect), rtol=2e-4, atol=2e-4
    )


@settings(max_examples=20, deadline=None)
@given(
    bm=block_dims,
    mi=shape_mult,
    nj=shape_mult,
    kk=shape_mult,
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_fp8_kernel_shape_sweep(bm, mi, nj, kk, seed):
    m, n, k = bm * mi, 64 * nj, 64 * kk
    rng = np.random.default_rng(seed)
    w = rand_weights(rng, n, k)
    x = rand_x(rng, m, k, dtype=np.float32)
    up, _ = ref.decompose_f16(w)
    s = ref.act_scale_per_tensor(x)
    xq = ref.e4m3_fake_quant(x * s) / s
    out = knl.nested_fp8_gemm(xq, up, block_m=bm, block_n=64, block_k=64)
    expect = ref.gemm_fp8_nested(x, up, s)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(expect), rtol=1e-4, atol=1e-5
    )


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31))
def test_kernel_lossless_on_random_tiles(seed):
    """Identity-activation probe on random weights across block configs."""
    rng = np.random.default_rng(seed)
    w = rand_weights(rng, 64, 64, scale=0.5)
    up, lo = ref.decompose_f16(w)
    eye = jnp.eye(64, dtype=jnp.float16)
    out = knl.nested_fp16_gemm(eye, up, lo, block_m=16, block_n=64, block_k=64)
    np.testing.assert_array_equal(np.asarray(out.T), np.asarray(w).astype(np.float32))


def test_vmem_estimator():
    fp16 = knl.kernel_vmem_bytes(32, 64, 64, "fp16")
    fp8 = knl.kernel_vmem_bytes(32, 64, 64, "fp8")
    assert fp8 < fp16  # fp8 path reads half the weight bytes
    assert fp16 <= 16 * 1024 * 1024  # fits VMEM budget
    with pytest.raises(ValueError):
        knl.kernel_vmem_bytes(32, 64, 64, "fp4")

"""Model-level tests: the three execution modes, KV-cache step functions,
and the end-to-end losslessness claim (nested16 == fp16, bitwise)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model
from compile.kernels import ref


@pytest.fixture(scope="module")
def setup():
    cfg = model.ModelConfig(n_layers=2, max_seq=64)
    params = model.init_params(cfg, jax.random.PRNGKey(42))
    serving = model.to_serving_weights(params)
    scales = {
        f"layers.{i}.{n}": 30.0
        for i in range(cfg.n_layers)
        for n in model.LINEAR_NAMES
    }
    return cfg, params, serving, scales


def empty_cache(cfg, batch=None):
    shape = (cfg.n_layers, cfg.n_heads, cfg.max_seq, cfg.head_dim)
    if batch is not None:
        shape = (batch,) + shape
    return jnp.zeros(shape, jnp.float32), jnp.zeros(shape, jnp.float32)


def test_serving_weights_structure(setup):
    cfg, params, serving, _ = setup
    assert serving["embed"].dtype == jnp.float16
    up = serving["layers.0.wq.upper"]
    lo = serving["layers.0.wq.lower"]
    assert up.dtype == jnp.uint8 and lo.dtype == jnp.uint8
    assert up.shape == (cfg.d_model, cfg.d_model)
    # scaled init keeps everything within +-1.75 -> no exception layers
    for i in range(cfg.n_layers):
        for n in model.LINEAR_NAMES:
            assert serving[f"layers.{i}.{n}.exception"] is False


def test_nested_planes_reconstruct_weights(setup):
    cfg, params, serving, _ = setup
    w16 = serving["layers.0.w_gate.f16"]
    up = serving["layers.0.w_gate.upper"]
    lo = serving["layers.0.w_gate.lower"]
    rec = ref.reconstruct_f16(up, lo)
    np.testing.assert_array_equal(
        np.asarray(rec.view(jnp.uint16)), np.asarray(w16.view(jnp.uint16))
    )


def test_decode_nested16_bitwise_equals_fp16(setup):
    """The paper's losslessness claim, end-to-end through the model."""
    cfg, _, serving, _ = setup
    ck, cv = empty_cache(cfg, batch=2)
    tokens = jnp.array([10, 200], jnp.int32)
    pos = jnp.array([0, 3], jnp.int32)
    lg_a, ka, va = model.decode_step(cfg, serving, tokens, pos, ck, cv, "fp16")
    lg_b, kb, vb = model.decode_step(
        cfg, serving, tokens, pos, ck, cv, "nested16", use_pallas=False
    )
    np.testing.assert_array_equal(np.asarray(lg_a), np.asarray(lg_b))
    np.testing.assert_array_equal(np.asarray(ka), np.asarray(kb))


def test_decode_pallas_close_to_ref(setup):
    cfg, _, serving, _ = setup
    ck, cv = empty_cache(cfg, batch=4)
    tokens = jnp.array([1, 2, 3, 4], jnp.int32)
    pos = jnp.zeros(4, jnp.int32)
    lg_a, _, _ = model.decode_step(cfg, serving, tokens, pos, ck, cv, "fp16")
    lg_p, _, _ = model.decode_step(
        cfg, serving, tokens, pos, ck, cv, "nested16", use_pallas=True
    )
    np.testing.assert_allclose(np.asarray(lg_a), np.asarray(lg_p), atol=2e-3)


def test_decode_nested8_reasonable(setup):
    cfg, _, serving, scales = setup
    ck, cv = empty_cache(cfg, batch=2)
    tokens = jnp.array([7, 9], jnp.int32)
    pos = jnp.zeros(2, jnp.int32)
    lg16, _, _ = model.decode_step(cfg, serving, tokens, pos, ck, cv, "fp16")
    lg8, _, _ = model.decode_step(
        cfg, serving, tokens, pos, ck, cv, "nested8", scales, use_pallas=False
    )
    # quantization noise, but the same model: top-logit sets overlap heavily
    denom = float(jnp.linalg.norm(lg16))
    rel = float(jnp.linalg.norm(lg8 - lg16)) / denom
    assert rel < 0.25, rel


def test_prefill_then_decode_consistency(setup):
    """Prefilling T tokens then decoding token T must equal prefilling
    T+1 tokens: the KV hand-off works."""
    cfg, _, serving, _ = setup
    prompt = jnp.arange(9, dtype=jnp.int32) + 60

    # full prefill of 9 tokens
    ck, cv = empty_cache(cfg)
    lg_full, nk, nv = model.prefill_step(
        cfg, serving, prompt, jnp.int32(0), ck, cv, "fp16"
    )

    # prefill 8, scatter kv, then decode token 8
    ck8, cv8 = empty_cache(cfg)
    _, nk8, nv8 = model.prefill_step(
        cfg, serving, prompt[:8], jnp.int32(0), ck8, cv8, "fp16"
    )
    # scatter new kv into per-slot cache: nk8 [L,T,H,Dh] -> cache [L,H,S,Dh]
    ck8 = ck8.at[:, :, :8, :].set(jnp.swapaxes(nk8, 1, 2))
    cv8 = cv8.at[:, :, :8, :].set(jnp.swapaxes(nv8, 1, 2))

    lg_dec, _, _ = model.decode_step(
        cfg,
        serving,
        prompt[8:9],
        jnp.array([8], jnp.int32),
        ck8[None],
        cv8[None],
        "fp16",
    )
    # different contraction orders (batched prefill vs single-token decode)
    # accumulate ~1e-3 relative f32 noise through the layers
    np.testing.assert_allclose(
        np.asarray(lg_full), np.asarray(lg_dec[0]), rtol=5e-3, atol=5e-3
    )


def test_decode_batch_independence(setup):
    """Each sequence in a decode batch must be computed independently:
    running [a, b] together equals running them alone."""
    cfg, _, serving, _ = setup
    ck, cv = empty_cache(cfg, batch=2)
    tokens = jnp.array([11, 33], jnp.int32)
    pos = jnp.array([0, 0], jnp.int32)
    both, _, _ = model.decode_step(cfg, serving, tokens, pos, ck, cv, "fp16")
    ck1, cv1 = empty_cache(cfg, batch=1)
    alone0, _, _ = model.decode_step(
        cfg, serving, tokens[:1], pos[:1], ck1, cv1, "fp16"
    )
    alone1, _, _ = model.decode_step(
        cfg, serving, tokens[1:], pos[1:], ck1, cv1, "fp16"
    )
    # different batch sizes tile the XLA matmuls differently -> ~1e-4 f32
    # reassociation noise; independence holds to that tolerance
    np.testing.assert_allclose(np.asarray(both[0]), np.asarray(alone0[0]), rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(both[1]), np.asarray(alone1[0]), rtol=1e-3, atol=1e-3)


def test_exception_layer_forced_fp16(setup):
    """A layer with |w| > 1.75 must be flagged and executed via the f16
    plane in every mode."""
    cfg, params, _, scales = setup
    import copy

    p2 = jax.tree.map(lambda x: x, params)
    # blow up one weight beyond the threshold
    p2["layers"][0]["wq"] = p2["layers"][0]["wq"].at[0, 0].set(3.5)
    serving2 = model.to_serving_weights(p2)
    assert serving2["layers.0.wq.exception"] is True
    ck, cv = empty_cache(cfg, batch=1)
    tokens = jnp.array([5], jnp.int32)
    pos = jnp.zeros(1, jnp.int32)
    # nested16 must still work (exception layer takes the f16 path) and be
    # bitwise equal to fp16 mode
    lg16, _, _ = model.decode_step(cfg, serving2, tokens, pos, ck, cv, "fp16")
    lgN, _, _ = model.decode_step(
        cfg, serving2, tokens, pos, ck, cv, "nested16", use_pallas=False
    )
    np.testing.assert_array_equal(np.asarray(lg16), np.asarray(lgN))
    # nested8 also runs (exception layer in fp16) without NaNs
    lg8, _, _ = model.decode_step(
        cfg, serving2, tokens, pos, ck, cv, "nested8", scales, use_pallas=False
    )
    assert np.isfinite(np.asarray(lg8)).all()


def test_train_forward_loss_decreases_sanity(setup):
    cfg, params, _, _ = setup
    tokens = jnp.asarray(
        np.random.default_rng(0).integers(0, cfg.vocab, size=(2, 16), dtype=np.int32)
    )
    loss = model.lm_loss(cfg, params, tokens)
    # random init: loss near ln(vocab)
    assert abs(float(loss) - np.log(cfg.vocab)) < 1.0

"""Layer-2: the JAX transformer whose linear layers consume NestedFP.

A small Llama-style decoder (RMSNorm, RoPE, SwiGLU MLP) sized so the whole
serving stack runs comfortably on the CPU PJRT client while still being a
*real* autoregressive LM (it is trained in-repo by ``train.py``).

Three linear-layer execution modes, matching the paper's comparison:

* ``fp16``    — plain FP16 weights (the torch.matmul/cuBLAS baseline).
* ``nested16``— weights stored as NestedFP (upper, lower) uint8 planes;
                reconstructed on the fly by the Pallas kernel. Bitwise
                identical outputs to ``fp16`` (the losslessness claim).
* ``nested8`` — FP8 path: only the upper plane is read; activations are
                quantized per-tensor with *static* scales calibrated
                offline (the paper's activation-scaling configuration).
* ``fp8base`` — the paper's FP8 *baseline* (Tables 1-2): per-channel
                absmax E4M3 weight fake-quant (baked offline into an fp16
                plane) + the same per-tensor activation quantization.

The step functions (``prefill_step``, ``decode_step``) are pure, take
weights as explicit inputs (the Rust side owns the single weight store),
and are AOT-lowered per (mode, batch bucket) by ``aot.py``.

Exception layers: a layer whose weights exceed |1.75| cannot be nested and
stays in plain FP16 in *every* mode (paper section 4.2 "Handling Exception
Layers"). The trained tiny model has no such layers, but the machinery is
exercised by tests and by the model-zoo analysis on the Rust side.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from .kernels import nested as knl
from .kernels import ref


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Tiny-Llama configuration (defaults are the in-repo trained model)."""

    vocab: int = 256
    d_model: int = 256
    n_layers: int = 4
    n_heads: int = 8
    d_ff: int = 704
    max_seq: int = 256
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    @property
    def linear_shapes(self) -> dict[str, tuple[int, int]]:
        """[N, K] shapes of every linear-layer kind (GEMM1..4 analog)."""
        d, f = self.d_model, self.d_ff
        return {
            "wq": (d, d),
            "wk": (d, d),
            "wv": (d, d),
            "wo": (d, d),
            "w_gate": (f, d),
            "w_up": (f, d),
            "w_down": (d, f),
        }


LINEAR_NAMES = ("wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down")


# ---------------------------------------------------------------------------
# Parameter initialization (fp32 master; train.py optimizes these)
# ---------------------------------------------------------------------------


def init_params(cfg: ModelConfig, key: jax.Array) -> dict[str, Any]:
    """Scaled-init fp32 parameters."""
    keys = jax.random.split(key, cfg.n_layers * len(LINEAR_NAMES) + 2)
    ki = iter(range(len(keys)))
    d = cfg.d_model

    def dense(k, n, kk, scale):
        return jax.random.normal(keys[k], (n, kk), jnp.float32) * scale

    params: dict[str, Any] = {
        "embed": jax.random.normal(keys[next(ki)], (cfg.vocab, d), jnp.float32) * 0.02,
        "final_norm": jnp.ones((d,), jnp.float32),
        "layers": [],
    }
    out_scale = 0.02 / (2.0 * cfg.n_layers) ** 0.5
    for _ in range(cfg.n_layers):
        layer = {
            "attn_norm": jnp.ones((d,), jnp.float32),
            "mlp_norm": jnp.ones((d,), jnp.float32),
            "wq": dense(next(ki), d, d, 0.02),
            "wk": dense(next(ki), d, d, 0.02),
            "wv": dense(next(ki), d, d, 0.02),
            "wo": dense(next(ki), d, d, out_scale),
            "w_gate": dense(next(ki), cfg.d_ff, d, 0.02),
            "w_up": dense(next(ki), cfg.d_ff, d, 0.02),
            "w_down": dense(next(ki), d, cfg.d_ff, out_scale),
        }
        params["layers"].append(layer)
    params["lm_head"] = jax.random.normal(keys[next(ki)], (cfg.vocab, d), jnp.float32) * 0.02
    return params


# ---------------------------------------------------------------------------
# Serving-format weights
# ---------------------------------------------------------------------------


def to_serving_weights(params: dict[str, Any]) -> dict[str, Any]:
    """Convert fp32 training params into the serving store:

    linear layers -> fp16 master + NestedFP (upper, lower) planes,
    everything else -> fp16/fp32 as appropriate.

    Returns a dict with, per layer i and linear name L:
      ``layers.i.L.f16``   uint16 view  (plain fp16 weights)
      ``layers.i.L.upper`` uint8        (NestedFP upper plane)
      ``layers.i.L.lower`` uint8        (NestedFP lower plane)
      ``layers.i.L.exception`` bool     (True -> not nestable, FP16 only)
    plus embed / norms / lm_head.
    """
    out: dict[str, Any] = {}
    out["embed"] = params["embed"].astype(jnp.float16)
    out["final_norm"] = params["final_norm"].astype(jnp.float32)
    out["lm_head"] = params["lm_head"].astype(jnp.float16)
    for i, layer in enumerate(params["layers"]):
        out[f"layers.{i}.attn_norm"] = layer["attn_norm"].astype(jnp.float32)
        out[f"layers.{i}.mlp_norm"] = layer["mlp_norm"].astype(jnp.float32)
        for name in LINEAR_NAMES:
            w16 = layer[name].astype(jnp.float16)
            eligible = bool(jnp.all(ref.is_eligible_u16(w16.view(jnp.uint16))))
            out[f"layers.{i}.{name}.f16"] = w16
            out[f"layers.{i}.{name}.exception"] = not eligible
            # FP8-baseline plane: per-channel absmax E4M3 fake-quant of the
            # fp16 weights, stored as fp16 (the numerics the baseline GEMM
            # sees on FP8 tensor cores)
            wf = w16.astype(jnp.float32)
            absmax = jnp.max(jnp.abs(wf), axis=1, keepdims=True)
            scale = jnp.where(absmax > 0, 448.0 / absmax, 1.0)
            fq = ref.e4m3_fake_quant(wf * scale) / scale
            out[f"layers.{i}.{name}.fq16"] = fq.astype(jnp.float16)
            if eligible:
                up, lo = ref.decompose_f16(w16)
            else:
                # exception layer: planes still emitted (unused) to keep a
                # uniform artifact layout; flagged so no mode reads them.
                up = jnp.zeros(w16.shape, jnp.uint8)
                lo = jnp.zeros(w16.shape, jnp.uint8)
            out[f"layers.{i}.{name}.upper"] = up
            out[f"layers.{i}.{name}.lower"] = lo
    return out


# ---------------------------------------------------------------------------
# Linear layer dispatch
# ---------------------------------------------------------------------------


def _pad_rows(x: jnp.ndarray, multiple: int) -> tuple[jnp.ndarray, int]:
    m = x.shape[0]
    pad = (-m) % multiple
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    return x, m


def linear(
    x: jnp.ndarray,
    wrec: dict[str, jnp.ndarray],
    mode: str,
    act_scale: float | None = None,
    use_pallas: bool = True,
) -> jnp.ndarray:
    """Apply one linear layer in the given execution mode.

    ``x`` is [M, K] (f16 storage, f32 accumulate); returns [M, N] f32.
    ``wrec`` holds the planes for one weight (f16 / upper / lower /
    exception flag resolved at trace time — it is a python bool).
    """
    exception = bool(wrec["exception"])
    if mode == "fp16" or exception:
        return ref.gemm_fp16_plain(x, wrec["f16"])

    if mode == "nested16":
        if use_pallas:
            xp, m = _pad_rows(x, 8)
            bm = min(xp.shape[0], 32)
            out = knl.nested_fp16_gemm(
                xp.astype(jnp.float16),
                wrec["upper"],
                wrec["lower"],
                block_m=bm,
                block_n=64,
                block_k=64,
            )
            return out[:m]
        return ref.gemm_fp16_nested(x, wrec["upper"], wrec["lower"])

    if mode == "fp8base":
        assert act_scale is not None, "fp8base needs a calibrated act scale"
        s = jnp.float32(act_scale)
        xq = ref.e4m3_fake_quant(x.astype(jnp.float32) * s) / s
        return jnp.dot(
            xq,
            wrec["fq16"].astype(jnp.float32).T,
            preferred_element_type=jnp.float32,
        )

    if mode == "nested8":
        assert act_scale is not None, "nested8 needs a calibrated act scale"
        s = jnp.float32(act_scale)
        xq = ref.e4m3_fake_quant(x.astype(jnp.float32) * s) / s
        if use_pallas:
            xp, m = _pad_rows(xq, 8)
            bm = min(xp.shape[0], 32)
            out = knl.nested_fp8_gemm(
                xp, wrec["upper"], block_m=bm, block_n=64, block_k=64
            )
            return out[:m]
        w8 = ref.upper_to_weight_f32(wrec["upper"])
        return jnp.dot(xq, w8.T, preferred_element_type=jnp.float32)

    raise ValueError(f"unknown mode {mode!r}")


# ---------------------------------------------------------------------------
# Transformer blocks
# ---------------------------------------------------------------------------


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float) -> jnp.ndarray:
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * scale


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Rotary embedding. x: [T, H, Dh]; positions: [T]."""
    t, h, dh = x.shape
    half = dh // 2
    freqs = jnp.exp(
        -jnp.arange(0, half, dtype=jnp.float32) * (jnp.log(theta) / half)
    )  # [half]
    ang = positions[:, None].astype(jnp.float32) * freqs[None, :]  # [T, half]
    cos = jnp.cos(ang)[:, None, :]
    sin = jnp.sin(ang)[:, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _layer_weights(weights: dict[str, Any], i: int, name: str) -> dict[str, Any]:
    return {
        "f16": weights[f"layers.{i}.{name}.f16"],
        "fq16": weights.get(f"layers.{i}.{name}.fq16"),
        "upper": weights[f"layers.{i}.{name}.upper"],
        "lower": weights[f"layers.{i}.{name}.lower"],
        "exception": weights[f"layers.{i}.{name}.exception"],
    }


def _block(
    cfg: ModelConfig,
    weights: dict[str, Any],
    i: int,
    x: jnp.ndarray,
    positions: jnp.ndarray,
    kv_k: jnp.ndarray,
    kv_v: jnp.ndarray,
    kv_len_mask: jnp.ndarray,
    mode: str,
    act_scales: dict[str, float] | None,
    use_pallas: bool,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One decoder block over T new tokens with an external KV cache.

    x: [T, D]; kv_k/kv_v: [H, S, Dh] *including* the slots where the new
    tokens will be written (the caller pre-scattered them or we write here).
    kv_len_mask: [S] float mask, 1 for valid positions.
    Returns (x_out, new_k [T,H,Dh], new_v [T,H,Dh]).
    """
    t = x.shape[0]
    h, dh = cfg.n_heads, cfg.head_dim

    def scale_of(name: str) -> float | None:
        if act_scales is None:
            return None
        return act_scales.get(f"layers.{i}.{name}", 1.0)

    attn_in = rms_norm(x, weights[f"layers.{i}.attn_norm"], cfg.norm_eps)
    attn_in = attn_in.astype(jnp.float16)

    q = linear(attn_in, _layer_weights(weights, i, "wq"), mode, scale_of("wq"), use_pallas)
    k = linear(attn_in, _layer_weights(weights, i, "wk"), mode, scale_of("wk"), use_pallas)
    v = linear(attn_in, _layer_weights(weights, i, "wv"), mode, scale_of("wv"), use_pallas)

    q = rope(q.reshape(t, h, dh), positions, cfg.rope_theta)
    new_k = rope(k.reshape(t, h, dh), positions, cfg.rope_theta)
    new_v = v.reshape(t, h, dh)

    # merge the new tokens into the cache view for attention
    s = kv_k.shape[1]
    # scatter new tokens at their positions
    kk = kv_k.at[:, positions, :].set(jnp.swapaxes(new_k, 0, 1))
    vv = kv_v.at[:, positions, :].set(jnp.swapaxes(new_v, 0, 1))

    # attention: q [T,H,Dh] x kk [H,S,Dh] -> scores [H,T,S]
    qh = jnp.swapaxes(q, 0, 1)  # [H,T,Dh]
    scores = jnp.einsum("htd,hsd->hts", qh, kk) / jnp.sqrt(float(dh))
    # causal + validity mask: position j visible to query at position p iff
    # j <= p and j < current length (mask covers both: kv_len_mask already
    # marks filled slots plus the new tokens)
    pos_ids = jnp.arange(s)[None, None, :]
    causal = pos_ids <= positions[None, :, None]
    valid = kv_len_mask[None, None, :] > 0
    scores = jnp.where(causal & valid, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("hts,hsd->htd", probs, vv)  # [H,T,Dh]
    ctx = jnp.swapaxes(ctx, 0, 1).reshape(t, cfg.d_model).astype(jnp.float16)

    attn_out = linear(ctx, _layer_weights(weights, i, "wo"), mode, scale_of("wo"), use_pallas)
    x = x + attn_out

    mlp_in = rms_norm(x, weights[f"layers.{i}.mlp_norm"], cfg.norm_eps).astype(jnp.float16)
    g = linear(mlp_in, _layer_weights(weights, i, "w_gate"), mode, scale_of("w_gate"), use_pallas)
    u = linear(mlp_in, _layer_weights(weights, i, "w_up"), mode, scale_of("w_up"), use_pallas)
    act = (jax.nn.silu(g) * u).astype(jnp.float16)
    d = linear(act, _layer_weights(weights, i, "w_down"), mode, scale_of("w_down"), use_pallas)
    x = x + d
    return x, new_k, new_v


# ---------------------------------------------------------------------------
# Step functions (AOT entry points)
# ---------------------------------------------------------------------------


def prefill_step(
    cfg: ModelConfig,
    weights: dict[str, Any],
    tokens: jnp.ndarray,  # [T] int32 (one request chunk)
    start_pos: jnp.ndarray,  # scalar int32
    cache_k: jnp.ndarray,  # [L, H, S, Dh] f32 — past context
    cache_v: jnp.ndarray,
    mode: str,
    act_scales: dict[str, float] | None = None,
    use_pallas: bool = True,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Process a chunk of T prompt tokens for one sequence.

    Returns (logits_last [V], new_k [L,T,H,Dh], new_v [L,T,H,Dh]).
    The Rust KV manager scatters new_k/new_v into the slot's cache.
    """
    t = tokens.shape[0]
    s = cache_k.shape[2]
    positions = start_pos + jnp.arange(t, dtype=jnp.int32)
    # valid slots: everything before start_pos (past) plus the new tokens
    slot_ids = jnp.arange(s, dtype=jnp.int32)
    len_mask = (slot_ids < start_pos + t).astype(jnp.float32)

    x = weights["embed"].astype(jnp.float32)[tokens]
    new_ks, new_vs = [], []
    for i in range(cfg.n_layers):
        x, nk, nv = _block(
            cfg, weights, i, x, positions, cache_k[i], cache_v[i], len_mask,
            mode, act_scales, use_pallas,
        )
        new_ks.append(nk)
        new_vs.append(nv)
    x = rms_norm(x, weights["final_norm"], cfg.norm_eps)
    logits = ref.gemm_fp16_plain(x[-1:].astype(jnp.float16), weights["lm_head"])[0]
    return logits, jnp.stack(new_ks), jnp.stack(new_vs)


def decode_step(
    cfg: ModelConfig,
    weights: dict[str, Any],
    tokens: jnp.ndarray,  # [B] int32 — one new token per sequence
    positions: jnp.ndarray,  # [B] int32 — its position (= current length)
    cache_k: jnp.ndarray,  # [B, L, H, S, Dh] f32 — gathered per-slot caches
    cache_v: jnp.ndarray,
    mode: str,
    act_scales: dict[str, float] | None = None,
    use_pallas: bool = True,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One decode iteration over a batch of B sequences.

    Linear layers run over the flattened [B, D] batch (ORCA-style batching:
    every sequence contributes one token). Attention runs per sequence over
    its own cache. Returns (logits [B,V], new_k [B,L,H,Dh], new_v).
    """
    b = tokens.shape[0]
    s = cache_k.shape[3]
    x = weights["embed"].astype(jnp.float32)[tokens]  # [B, D]

    # Attention is per-sequence; linear layers are batched. We interleave:
    # for each block, run the linears on [B, D], then do B independent
    # single-token attentions via vmap.
    new_ks, new_vs = [], []
    h, dh = cfg.n_heads, cfg.head_dim

    def scale_of(i: int, name: str) -> float | None:
        if act_scales is None:
            return None
        return act_scales.get(f"layers.{i}.{name}", 1.0)

    for i in range(cfg.n_layers):
        attn_in = rms_norm(x, weights[f"layers.{i}.attn_norm"], cfg.norm_eps).astype(jnp.float16)
        q = linear(attn_in, _layer_weights(weights, i, "wq"), mode, scale_of(i, "wq"), use_pallas)
        k = linear(attn_in, _layer_weights(weights, i, "wk"), mode, scale_of(i, "wk"), use_pallas)
        v = linear(attn_in, _layer_weights(weights, i, "wv"), mode, scale_of(i, "wv"), use_pallas)

        q = q.reshape(b, h, dh)
        k = k.reshape(b, h, dh)
        v = v.reshape(b, h, dh)

        # RoPE at each sequence's own position
        def rope1(vec, pos):
            return rope(vec[None, :, :], pos[None], cfg.rope_theta)[0]

        q = jax.vmap(rope1)(q, positions)
        nk = jax.vmap(rope1)(k, positions)
        nv = v
        new_ks.append(nk)
        new_vs.append(nv)

        def attend(qi, ki_cache, vi_cache, nki, nvi, pos):
            # qi [H,Dh]; caches [H,S,Dh]; write the new token then attend
            kk = ki_cache.at[:, pos, :].set(nki)
            vv = vi_cache.at[:, pos, :].set(nvi)
            scores = jnp.einsum("hd,hsd->hs", qi, kk) / jnp.sqrt(float(dh))
            slot = jnp.arange(s)
            scores = jnp.where(slot[None, :] <= pos, scores, -1e30)
            probs = jax.nn.softmax(scores, axis=-1)
            return jnp.einsum("hs,hsd->hd", probs, vv)

        ctx = jax.vmap(attend)(
            q, cache_k[:, i], cache_v[:, i], nk, nv, positions
        )  # [B,H,Dh]
        ctx = ctx.reshape(b, cfg.d_model).astype(jnp.float16)
        attn_out = linear(ctx, _layer_weights(weights, i, "wo"), mode, scale_of(i, "wo"), use_pallas)
        x = x + attn_out

        mlp_in = rms_norm(x, weights[f"layers.{i}.mlp_norm"], cfg.norm_eps).astype(jnp.float16)
        g = linear(mlp_in, _layer_weights(weights, i, "w_gate"), mode, scale_of(i, "w_gate"), use_pallas)
        u = linear(mlp_in, _layer_weights(weights, i, "w_up"), mode, scale_of(i, "w_up"), use_pallas)
        act = (jax.nn.silu(g) * u).astype(jnp.float16)
        dwn = linear(act, _layer_weights(weights, i, "w_down"), mode, scale_of(i, "w_down"), use_pallas)
        x = x + dwn

    x = rms_norm(x, weights["final_norm"], cfg.norm_eps)
    logits = ref.gemm_fp16_plain(x.astype(jnp.float16), weights["lm_head"])
    return logits, jnp.stack(new_ks, axis=1), jnp.stack(new_vs, axis=1)


# ---------------------------------------------------------------------------
# Training-time forward (fp32, plain) — used by train.py and calibration
# ---------------------------------------------------------------------------


def train_forward(cfg: ModelConfig, params: dict[str, Any], tokens: jnp.ndarray) -> jnp.ndarray:
    """Causal LM forward over [B, T] token batches -> logits [B, T, V]."""
    b, t = tokens.shape
    x = params["embed"][tokens]  # [B,T,D]
    h, dh = cfg.n_heads, cfg.head_dim
    positions = jnp.arange(t)
    half = dh // 2
    freqs = jnp.exp(-jnp.arange(0, half, dtype=jnp.float32) * (jnp.log(cfg.rope_theta) / half))
    ang = positions[:, None].astype(jnp.float32) * freqs[None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)

    def rope_t(v):  # [B,T,H,Dh]
        v1, v2 = v[..., :half], v[..., half:]
        c = cos[None, :, None, :]
        s_ = sin[None, :, None, :]
        return jnp.concatenate([v1 * c - v2 * s_, v1 * s_ + v2 * c], axis=-1)

    mask = jnp.tril(jnp.ones((t, t), bool))
    for layer in params["layers"]:
        y = rms_norm(x, layer["attn_norm"], cfg.norm_eps)
        q = (y @ layer["wq"].T).reshape(b, t, h, dh)
        k = (y @ layer["wk"].T).reshape(b, t, h, dh)
        v = (y @ layer["wv"].T).reshape(b, t, h, dh)
        q, k = rope_t(q), rope_t(k)
        scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) / jnp.sqrt(float(dh))
        scores = jnp.where(mask[None, None], scores, -1e30)
        ctx = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(scores, -1), v)
        x = x + ctx.reshape(b, t, cfg.d_model) @ layer["wo"].T
        y = rms_norm(x, layer["mlp_norm"], cfg.norm_eps)
        g = y @ layer["w_gate"].T
        u = y @ layer["w_up"].T
        x = x + (jax.nn.silu(g) * u) @ layer["w_down"].T
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x @ params["lm_head"].T


def lm_loss(cfg: ModelConfig, params: dict[str, Any], tokens: jnp.ndarray) -> jnp.ndarray:
    """Next-token cross-entropy over [B, T]."""
    logits = train_forward(cfg, params, tokens[:, :-1])
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)

"""AOT compile path: checkpoint -> NestedFP weight store + HLO artifacts.

This is the *only* place Python runs; its outputs are everything the Rust
serving binary needs:

  artifacts/weights.bin     — the single NestedFP weight store (upper /
                              lower uint8 planes + fp16 masters + norms)
                              in a simple length-prefixed binary format
                              (see rust/src/runtime/weights.rs).
  artifacts/manifest.json   — executable index: for every (kind, mode,
                              bucket) the HLO file, input signature and
                              shapes; plus model config and act scales.
  artifacts/<name>.hlo.txt  — HLO text per step function, lowered from
                              jax.jit(...).lower(...) via stablehlo ->
                              XlaComputation (text interchange because
                              xla_extension 0.5.1 rejects jax>=0.5's
                              64-bit-id protos; see /opt/xla-example).

Step executables take (weight arrays..., dynamic inputs...) in manifest
order. Weights are passed at call time — the Rust side owns the single
16-bit store and feeds whichever executable the precision controller
picked; that is the paper's zero-extra-memory dual-precision story.

Usage: python -m compile.aot [--out-dir ../artifacts] [--fast]
"""

from __future__ import annotations

import argparse
import json
import os
import struct
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import corpus, model
from .kernels import ref

SEED = 20250710

# batch buckets per step kind (fixed AOT shapes; the batcher pads to these)
DECODE_BUCKETS = (1, 2, 4, 8)
PREFILL_CHUNKS = (32, 64)
MODES = ("fp16", "nested16", "nested8", "fp8base")

# standalone GEMM artifacts for the runtime micro-bench (examples/kernel_tour)
GEMM_SHAPES = ((32, 256, 256), (32, 704, 256))


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


# ---------------------------------------------------------------------------
# weights.bin
# ---------------------------------------------------------------------------

_DTYPE_CODES = {"u8": 0, "u16": 1, "f32": 2, "i32": 3}


def write_weights_bin(path: str, tensors: dict[str, np.ndarray]) -> None:
    """Format: magic 'NFPW', u32 version, u32 count, then per tensor:
    u16 name_len, name bytes, u8 dtype code, u8 ndim, u32 dims...,
    u64 byte_len, raw little-endian data."""
    with open(path, "wb") as f:
        f.write(b"NFPW")
        f.write(struct.pack("<II", 1, len(tensors)))
        for name, arr in sorted(tensors.items()):
            if arr.dtype == np.uint8:
                code, payload = 0, arr.tobytes()
            elif arr.dtype == np.uint16 or arr.dtype == np.float16:
                code, payload = 1, arr.view(np.uint16).tobytes()
            elif arr.dtype == np.float32:
                code, payload = 2, arr.tobytes()
            elif arr.dtype == np.int32:
                code, payload = 3, arr.tobytes()
            else:
                raise ValueError(f"{name}: unsupported dtype {arr.dtype}")
            nb = name.encode()
            f.write(struct.pack("<H", len(nb)))
            f.write(nb)
            f.write(struct.pack("<BB", code, arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<I", d))
            f.write(struct.pack("<Q", len(payload)))
            f.write(payload)


# ---------------------------------------------------------------------------
# Activation-scale calibration (static per-tensor, paper section 5.1)
# ---------------------------------------------------------------------------


def calibrate_act_scales(cfg, params, n_batches=4, batch=8, seqlen=48) -> dict[str, float]:
    """Run the fp32 training forward on corpus batches, record per-linear
    input absmax, return scale = 448 / absmax (with 10% headroom)."""
    data = np.frombuffer(corpus.gen_corpus_bytes(SEED + 7, 200_000), np.uint8).astype(np.int32)
    n_seq = len(data) // seqlen
    data = data[: n_seq * seqlen].reshape(n_seq, seqlen)

    maxes: dict[str, float] = {}

    # re-implement the forward, capturing linear inputs (cheap: few batches)
    def record(name, x):
        m = float(jnp.max(jnp.abs(x)))
        maxes[name] = max(maxes.get(name, 0.0), m)

    for b in range(n_batches):
        tokens = jnp.asarray(data[b * batch : (b + 1) * batch])
        bsz, t = tokens.shape
        x = params["embed"][tokens]
        h, dh = cfg.n_heads, cfg.head_dim
        positions = jnp.arange(t)
        half = dh // 2
        freqs = jnp.exp(-jnp.arange(0, half, dtype=jnp.float32) * (jnp.log(cfg.rope_theta) / half))
        ang = positions[:, None].astype(jnp.float32) * freqs[None, :]
        cos, sin = jnp.cos(ang), jnp.sin(ang)

        def rope_t(v):
            v1, v2 = v[..., :half], v[..., half:]
            return jnp.concatenate(
                [v1 * cos[None, :, None, :] - v2 * sin[None, :, None, :],
                 v1 * sin[None, :, None, :] + v2 * cos[None, :, None, :]], axis=-1)

        mask = jnp.tril(jnp.ones((t, t), bool))
        for i, layer in enumerate(params["layers"]):
            y = model.rms_norm(x, layer["attn_norm"], cfg.norm_eps)
            for nm in ("wq", "wk", "wv"):
                record(f"layers.{i}.{nm}", y)
            q = (y @ layer["wq"].T).reshape(bsz, t, h, dh)
            k = (y @ layer["wk"].T).reshape(bsz, t, h, dh)
            v = (y @ layer["wv"].T).reshape(bsz, t, h, dh)
            q, k = rope_t(q), rope_t(k)
            scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) / jnp.sqrt(float(dh))
            scores = jnp.where(mask[None, None], scores, -1e30)
            ctx = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(scores, -1), v).reshape(bsz, t, cfg.d_model)
            record(f"layers.{i}.wo", ctx)
            x = x + ctx @ layer["wo"].T
            y = model.rms_norm(x, layer["mlp_norm"], cfg.norm_eps)
            record(f"layers.{i}.w_gate", y)
            record(f"layers.{i}.w_up", y)
            g = y @ layer["w_gate"].T
            u = y @ layer["w_up"].T
            act = jax.nn.silu(g) * u
            record(f"layers.{i}.w_down", act)
            x = x + act @ layer["w_down"].T

    return {
        name: 448.0 / (m * 1.1) if m > 0 else 1.0
        for name, m in maxes.items()
    }


# ---------------------------------------------------------------------------
# Lowering
# ---------------------------------------------------------------------------


def weight_input_order(cfg) -> list[tuple[str, str]]:
    """Deterministic (tensor_name, role) order of weight inputs shared by
    all executables of a given mode. role in {f16, upper, lower, norm}."""
    order: list[tuple[str, str]] = [("embed", "f16")]
    for i in range(cfg.n_layers):
        order.append((f"layers.{i}.attn_norm", "norm"))
        order.append((f"layers.{i}.mlp_norm", "norm"))
        for nm in model.LINEAR_NAMES:
            order.append((f"layers.{i}.{nm}", "linear"))
    order.append(("final_norm", "norm"))
    order.append(("lm_head", "f16"))
    return order


def mode_weight_inputs(cfg, serving: dict, mode: str) -> list[tuple[str, np.ndarray]]:
    """Flat list of (input_name, example_array) for a mode, in order."""
    out: list[tuple[str, np.ndarray]] = []
    for name, role in weight_input_order(cfg):
        if role in ("f16",):
            out.append((name, np.asarray(serving[name]).view(np.uint16)))
        elif role == "norm":
            out.append((name, np.asarray(serving[name])))
        else:  # linear
            exception = bool(serving[f"{name}.exception"])
            if mode == "fp16" or exception:
                out.append((f"{name}.f16", np.asarray(serving[f"{name}.f16"]).view(np.uint16)))
            elif mode == "nested16":
                out.append((f"{name}.upper", np.asarray(serving[f"{name}.upper"])))
                out.append((f"{name}.lower", np.asarray(serving[f"{name}.lower"])))
            elif mode == "nested8":
                out.append((f"{name}.upper", np.asarray(serving[f"{name}.upper"])))
            elif mode == "fp8base":
                out.append((f"{name}.fq16", np.asarray(serving[f"{name}.fq16"]).view(np.uint16)))
            else:
                raise ValueError(mode)
    return out


def rebuild_weights(cfg, serving: dict, mode: str, arrays: list[jnp.ndarray]) -> dict:
    """Inverse of mode_weight_inputs: reassemble the weights dict the model
    expects from the flat traced arrays (f16 views arrive as u16)."""
    w: dict = {}
    it = iter(arrays)
    for name, role in weight_input_order(cfg):
        if role == "f16":
            w[name] = next(it).view(jnp.float16)
        elif role == "norm":
            w[name] = next(it)
        else:
            exception = bool(serving[f"{name}.exception"])
            shape = serving[f"{name}.f16"].shape
            zeros8 = jnp.zeros(shape, jnp.uint8)
            zeros16 = jnp.zeros(shape, jnp.float16)
            if mode == "fp16" or exception:
                w[f"{name}.f16"] = next(it).view(jnp.float16)
                w[f"{name}.fq16"] = zeros16
                w[f"{name}.upper"] = zeros8
                w[f"{name}.lower"] = zeros8
            elif mode == "nested16":
                w[f"{name}.f16"] = zeros16
                w[f"{name}.fq16"] = zeros16
                w[f"{name}.upper"] = next(it)
                w[f"{name}.lower"] = next(it)
            elif mode == "nested8":
                w[f"{name}.f16"] = zeros16
                w[f"{name}.fq16"] = zeros16
                w[f"{name}.upper"] = next(it)
                w[f"{name}.lower"] = zeros8
            else:  # fp8base
                w[f"{name}.f16"] = zeros16
                w[f"{name}.fq16"] = next(it).view(jnp.float16)
                w[f"{name}.upper"] = zeros8
                w[f"{name}.lower"] = zeros8
            w[f"{name}.exception"] = exception
    # rename flat keys to the names model.py expects
    out = {}
    for key, val in w.items():
        out[key] = val
    return out


def _spec(arr) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(np.asarray(arr).shape, np.asarray(arr).dtype)


def lower_step(cfg, serving, act_scales, mode: str, kind: str, size: int,
               use_pallas: bool) -> tuple[str, dict]:
    """Lower one step function; returns (hlo_text, signature dict)."""
    l, h, s, dh = cfg.n_layers, cfg.n_heads, cfg.max_seq, cfg.head_dim
    winputs = mode_weight_inputs(cfg, serving, mode)
    wspecs = [_spec(a) for _, a in winputs]
    scales = act_scales if mode in ("nested8", "fp8base") else None

    if kind == "decode":
        b = size
        dyn_specs = [
            jax.ShapeDtypeStruct((b,), jnp.int32),            # tokens
            jax.ShapeDtypeStruct((b,), jnp.int32),            # positions
            jax.ShapeDtypeStruct((b, l, h, s, dh), jnp.float32),  # cache_k
            jax.ShapeDtypeStruct((b, l, h, s, dh), jnp.float32),  # cache_v
        ]

        def fn(*args):
            warrs = list(args[: len(wspecs)])
            tokens, positions, ck, cv = args[len(wspecs):]
            weights = rebuild_weights(cfg, serving, mode, warrs)
            logits, nk, nv = model.decode_step(
                cfg, weights, tokens, positions, ck, cv, mode, scales, use_pallas
            )
            return (logits, nk, nv)

    elif kind == "prefill":
        t = size
        dyn_specs = [
            jax.ShapeDtypeStruct((t,), jnp.int32),            # tokens
            jax.ShapeDtypeStruct((), jnp.int32),              # start_pos
            jax.ShapeDtypeStruct((l, h, s, dh), jnp.float32),  # cache_k
            jax.ShapeDtypeStruct((l, h, s, dh), jnp.float32),  # cache_v
        ]

        def fn(*args):
            warrs = list(args[: len(wspecs)])
            tokens, start, ck, cv = args[len(wspecs):]
            weights = rebuild_weights(cfg, serving, mode, warrs)
            logits, nk, nv = model.prefill_step(
                cfg, weights, tokens, start, ck, cv, mode, scales, use_pallas
            )
            return (logits, nk, nv)

    else:
        raise ValueError(kind)

    lowered = jax.jit(fn).lower(*wspecs, *dyn_specs)
    sig = {
        "kind": kind,
        "mode": mode,
        "size": size,
        "weight_inputs": [
            {"name": n, "shape": list(np.asarray(a).shape),
             "dtype": str(np.asarray(a).dtype)}
            for n, a in winputs
        ],
        "dynamic_inputs": [
            {"shape": list(sp.shape), "dtype": str(np.dtype(sp.dtype))}
            for sp in dyn_specs
        ],
        "outputs": ["logits", "new_k", "new_v"],
    }
    return to_hlo_text(lowered), sig


def lower_gemm(cfg, serving, mode: str, m: int, n: int, k: int, use_pallas: bool):
    """Standalone GEMM artifact over layer-0 wq-shaped planes (runtime
    micro-bench / kernel_tour example)."""
    name = "layers.0.wq" if (n, k) == (cfg.d_model, cfg.d_model) else "layers.0.w_gate"
    up = np.asarray(serving[f"{name}.upper"])
    lo = np.asarray(serving[f"{name}.lower"])
    w16 = np.asarray(serving[f"{name}.f16"]).view(np.uint16)
    assert up.shape == (n, k), (up.shape, (n, k))

    if mode == "nested16":
        def fn(x, u, lw):
            if use_pallas:
                from .kernels import nested as knl
                return (knl.nested_fp16_gemm(x, u, lw, block_m=min(m, 32)),)
            return (ref.gemm_fp16_nested(x, u, lw),)
        specs = [
            jax.ShapeDtypeStruct((m, k), jnp.float16),
            jax.ShapeDtypeStruct((n, k), jnp.uint8),
            jax.ShapeDtypeStruct((n, k), jnp.uint8),
        ]
    elif mode == "nested8":
        def fn(x, u):
            if use_pallas:
                from .kernels import nested as knl
                return (knl.nested_fp8_gemm(x, u, block_m=min(m, 32)),)
            w8 = ref.upper_to_weight_f32(u)
            return (jnp.dot(x, w8.T, preferred_element_type=jnp.float32),)
        specs = [
            jax.ShapeDtypeStruct((m, k), jnp.float32),
            jax.ShapeDtypeStruct((n, k), jnp.uint8),
        ]
    else:  # fp16
        def fn(x, w_u16):
            return (ref.gemm_fp16_plain(x, w_u16.view(jnp.float16)),)
        specs = [
            jax.ShapeDtypeStruct((m, k), jnp.float16),
            jax.ShapeDtypeStruct((n, k), jnp.uint16),
        ]
    lowered = jax.jit(fn).lower(*specs)
    sig = {
        "kind": "gemm", "mode": mode, "m": m, "n": n, "k": k,
        # distinguish gemm shapes via size (= N); all inputs are dynamic
        "size": n,
        "weight_name": name,
        "weight_inputs": [],
        "dynamic_inputs": [
            {"shape": list(sp.shape), "dtype": str(np.dtype(sp.dtype))}
            for sp in specs
        ],
    }
    return to_hlo_text(lowered), sig


# ---------------------------------------------------------------------------
# main
# ---------------------------------------------------------------------------


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--fast", action="store_true",
                    help="skip pallas kernels in step functions (ref path; "
                         "identical numerics, quicker lowering)")
    ap.add_argument("--train-steps", type=int, default=2000)
    args = ap.parse_args()
    out = args.out_dir
    os.makedirs(out, exist_ok=True)

    cfg = model.ModelConfig()

    ckpt_path = args.checkpoint or os.path.join(out, "checkpoint.npz")
    if not os.path.exists(ckpt_path):
        print(f"checkpoint {ckpt_path} missing; training {args.train_steps} steps...",
              flush=True)
        from . import train as train_mod
        params, losses = train_mod.train(cfg, args.train_steps)
        flat = train_mod.flatten_params(params)
        flat["__losses__"] = np.asarray(losses, np.float32)
        np.savez(ckpt_path, **flat)
    flat = dict(np.load(ckpt_path))
    losses = flat.pop("__losses__", None)
    from .train import unflatten_params
    params = unflatten_params(flat, cfg)

    print("calibrating activation scales...", flush=True)
    act_scales = calibrate_act_scales(cfg, params)

    print("building serving weight store...", flush=True)
    serving = model.to_serving_weights(params)

    # ---- weights.bin -----------------------------------------------------
    tensors: dict[str, np.ndarray] = {}
    exceptions: dict[str, bool] = {}
    for key, val in serving.items():
        if key.endswith(".exception"):
            exceptions[key[: -len(".exception")]] = bool(val)
            continue
        arr = np.asarray(val)
        tensors[key] = arr
    write_weights_bin(os.path.join(out, "weights.bin"), tensors)

    # ---- executables ------------------------------------------------------
    manifest: dict = {
        "model": {
            "vocab": cfg.vocab, "d_model": cfg.d_model, "n_layers": cfg.n_layers,
            "n_heads": cfg.n_heads, "d_ff": cfg.d_ff, "max_seq": cfg.max_seq,
            "head_dim": cfg.head_dim,
        },
        "seed": SEED,
        "act_scales": act_scales,
        "exception_layers": {k: v for k, v in exceptions.items() if v},
        "decode_buckets": list(DECODE_BUCKETS),
        "prefill_chunks": list(PREFILL_CHUNKS),
        "modes": list(MODES),
        "executables": [],
        "final_train_loss": float(losses[-1]) if losses is not None else None,
    }

    use_pallas = not args.fast
    jobs = []
    for mode in MODES:
        for b in DECODE_BUCKETS:
            jobs.append(("decode", mode, b))
        for t in PREFILL_CHUNKS:
            jobs.append(("prefill", mode, t))

    for kind, mode, size in jobs:
        name = f"{kind}_{mode}_b{size}"
        print(f"lowering {name} ...", flush=True)
        hlo, sig = lower_step(cfg, serving, act_scales, mode, kind, size, use_pallas)
        path = f"{name}.hlo.txt"
        with open(os.path.join(out, path), "w") as f:
            f.write(hlo)
        sig["path"] = path
        manifest["executables"].append(sig)

    for mode in MODES:
        for (m, n, k) in GEMM_SHAPES:
            name = f"gemm_{mode}_m{m}n{n}k{k}"
            print(f"lowering {name} ...", flush=True)
            hlo, sig = lower_gemm(cfg, serving, mode, m, n, k, use_pallas)
            path = f"{name}.hlo.txt"
            with open(os.path.join(out, path), "w") as f:
                f.write(hlo)
            sig["path"] = path
            manifest["executables"].append(sig)

    with open(os.path.join(out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"wrote {len(manifest['executables'])} executables + weights.bin + manifest.json")


if __name__ == "__main__":
    main()

"""Synthetic training/eval corpus for the in-repo tiny model.

Three byte-level "downstream tasks" stand in for the paper's Minerva Math
/ MMLU-Pro / BBH (see DESIGN.md section 2 for the substitution argument):

  copy  —  "C:abcd=abcd;"            (sequence fidelity)
  sort  —  "S:dcba=abcd;"            (symbol manipulation)
  add   —  "A:12+34=46;"             (2-digit arithmetic)

The grammar is deliberately tiny and *shared verbatim* with the Rust eval
harness (rust/src/eval/tasks.rs): both sides generate the same prompts
from the same PCG64 stream so accuracy numbers are comparable.
"""

from __future__ import annotations

# ---------------------------------------------------------------------------
# PCG64 (XSL-RR 128/64) — mirror of rust/src/util/rng.rs so prompt streams
# match bit-for-bit across the language boundary.
# ---------------------------------------------------------------------------

_MASK128 = (1 << 128) - 1
_PCG_MULT = 0x2360ED051FC65DA44385DF649FCCF645
_DEFAULT_STREAM = 0xDA3E39CB94B95BDB


class Pcg64:
    def __init__(self, seed: int, stream: int = _DEFAULT_STREAM):
        self.inc = ((stream << 1) | 1) & _MASK128
        self.state = 0
        self.state = (self.state * _PCG_MULT + self.inc) & _MASK128
        self.state = (self.state + seed) & _MASK128
        self.state = (self.state * _PCG_MULT + self.inc) & _MASK128

    def next_u64(self) -> int:
        self.state = (self.state * _PCG_MULT + self.inc) & _MASK128
        rot = self.state >> 122
        xored = ((self.state >> 64) ^ self.state) & ((1 << 64) - 1)
        if rot == 0:
            return xored
        return ((xored >> rot) | (xored << (64 - rot))) & ((1 << 64) - 1)

    def range(self, lo: int, hi: int) -> int:
        span = hi - lo
        zone = ((1 << 64) - 1) - (((1 << 64) - 1) % span)
        while True:
            v = self.next_u64()
            if v < zone:
                return lo + v % span


LETTERS = "abcdefghijklmnopqrstuvwxyz"
TASKS = ("copy", "sort", "add")


def gen_example(rng: Pcg64, task: str) -> tuple[str, str]:
    """Returns (prompt, answer); full training line is prompt+answer."""
    if task == "copy":
        n = rng.range(3, 7)
        s = "".join(LETTERS[rng.range(0, 26)] for _ in range(n))
        return f"C:{s}=", f"{s};"
    if task == "sort":
        n = rng.range(3, 7)
        s = "".join(LETTERS[rng.range(0, 26)] for _ in range(n))
        return f"S:{s}=", "".join(sorted(s)) + ";"
    if task == "add":
        a = rng.range(0, 100)
        b = rng.range(0, 100)
        return f"A:{a}+{b}=", f"{a + b};"
    raise ValueError(task)


def gen_line(rng: Pcg64) -> str:
    task = TASKS[rng.range(0, 3)]
    p, a = gen_example(rng, task)
    return p + a


def gen_corpus_bytes(seed: int, n_bytes: int) -> bytes:
    """Concatenated task lines, exactly n_bytes long."""
    rng = Pcg64(seed)
    parts: list[str] = []
    total = 0
    while total < n_bytes:
        line = gen_line(rng)
        parts.append(line)
        total += len(line)
    return "".join(parts).encode("ascii")[:n_bytes]


def eval_prompts(seed: int, task: str, n: int) -> list[tuple[str, str]]:
    """Held-out eval set (seed disjoint from training by convention:
    training uses seed, eval uses seed+1000+task index)."""
    rng = Pcg64(seed + 1000 + TASKS.index(task))
    return [gen_example(rng, task) for _ in range(n)]

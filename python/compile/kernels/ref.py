"""Pure-jnp reference oracles for the NestedFP format and GEMMs.

Everything here is the *specification*: the Pallas kernels in
``nested.py`` and the Rust implementation (``rust/src/format``) are both
tested against these functions (and against each other through the
exhaustive bit sweeps in ``python/tests``).

Bit layout recap (paper section 4.2):

  FP16 (E5M10):   S EEEEE MMMMMMMMMM
  upper (E4M3):   S E[2:5] M'[1:3]     -- RNE-rounded 3-bit mantissa,
                                          value == fp16 * 2^8 as E4M3
  lower:          M[3:10]              -- MSB is the pre-rounding M3
                                          (the checksum bit)
"""

from __future__ import annotations

import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Decomposition / reconstruction on uint16 bit patterns
# ---------------------------------------------------------------------------


def is_eligible_u16(bits: jnp.ndarray) -> jnp.ndarray:
    """Eligibility mask (|v| <= 1.75) on raw fp16 bit patterns (uint16)."""
    bits = bits.astype(jnp.uint32)
    e = (bits >> 10) & 0x1F
    m = bits & 0x3FF
    return (e < 15) | ((e == 15) & (m <= 0x300))


def decompose_u16(bits: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Split eligible fp16 bit patterns into (upper, lower) uint8 planes.

    Round-to-nearest-even on the dropped 7 mantissa bits, applied to the
    7-bit integer E[2:5]||M[1:3] so carries propagate into the exponent.
    """
    b = bits.astype(jnp.uint32)
    s = (b >> 15) & 1
    base = (b >> 7) & 0x7F
    rem = b & 0x7F
    round_up = (rem > 64) | ((rem == 64) & ((base & 1) == 1))
    upper7 = base + round_up.astype(jnp.uint32)
    upper = (s << 7) | upper7
    lower = b & 0xFF
    return upper.astype(jnp.uint8), lower.astype(jnp.uint8)


def reconstruct_u16(upper: jnp.ndarray, lower: jnp.ndarray) -> jnp.ndarray:
    """Branch-free lossless reconstruction (paper Fig. 6) -> uint16 bits."""
    u = upper.astype(jnp.uint32)
    low = lower.astype(jnp.uint32)
    s = (u >> 7) & 1
    m3 = (low >> 7) & 1
    corrected = (u & 0x7F) - m3  # cannot underflow for valid encodings
    top6 = (corrected >> 1) & 0x3F
    bits = (s << 15) | (top6 << 8) | low
    return bits.astype(jnp.uint16)


def decompose_f16(w: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Decompose an fp16 array into (upper, lower) uint8 planes."""
    assert w.dtype == jnp.float16, w.dtype
    return decompose_u16(w.view(jnp.uint16))


def reconstruct_f16(upper: jnp.ndarray, lower: jnp.ndarray) -> jnp.ndarray:
    """Reconstruct fp16 values from the two planes."""
    return reconstruct_u16(upper, lower).view(jnp.float16)


# ---------------------------------------------------------------------------
# E4M3 semantics of the upper plane (FP8 path)
# ---------------------------------------------------------------------------


def e4m3_decode_u8(codes: jnp.ndarray) -> jnp.ndarray:
    """Decode OCP E4M3 bytes to f32 (S.1111.111 -> NaN)."""
    c = codes.astype(jnp.uint32)
    s = jnp.where(((c >> 7) & 1) == 1, -1.0, 1.0).astype(jnp.float32)
    e = ((c >> 3) & 0xF).astype(jnp.int32)
    m = (c & 0x7).astype(jnp.float32)
    normal = (1.0 + m / 8.0) * jnp.exp2((e - 7).astype(jnp.float32))
    subnormal = (m / 8.0) * jnp.exp2(jnp.float32(-6))
    v = jnp.where(e == 0, subnormal, normal)
    v = jnp.where((e == 0xF) & (c & 0x7 == 7), jnp.nan, v)
    return s * v


def upper_to_weight_f32(upper: jnp.ndarray) -> jnp.ndarray:
    """FP8-path weight values: E4M3(upper) * 2^-8."""
    return e4m3_decode_u8(upper) * jnp.float32(2.0**-8)


def e4m3_fake_quant(x: jnp.ndarray) -> jnp.ndarray:
    """RNE quantize-dequantize of f32 values onto the E4M3 grid with
    saturation to +-448 (per-element; scaling handled by the caller)."""
    x = x.astype(jnp.float32)
    sat = jnp.clip(x, -448.0, 448.0)
    a = jnp.abs(sat)
    # exponent of the E4M3 bucket; subnormal floor at 2^-6
    e = jnp.floor(jnp.log2(jnp.maximum(a, jnp.float32(1e-30))))
    e = jnp.clip(e, -6.0, 8.0)
    q = jnp.exp2(e - 3.0)  # ulp = 2^(e-3) for a 3-bit mantissa
    # round-to-nearest-even in units of the ulp (jnp.round is RNE)
    k = a / q
    kr = jnp.round(k)
    # a value exactly at a bucket's top edge (k == 16) carries into the next
    # exponent; kr*q still represents it exactly, no special case needed.
    out = jnp.sign(sat) * kr * q
    return jnp.where(a == 0.0, 0.0 * sat, out).astype(jnp.float32)


# ---------------------------------------------------------------------------
# Reference GEMMs
#
# Activations are [M, K]; weights are stored output-major [N, K] as in the
# paper (and in every LLM serving stack), so GEMM computes x @ w.T.
# ---------------------------------------------------------------------------


def gemm_fp16_plain(x: jnp.ndarray, w_f16: jnp.ndarray) -> jnp.ndarray:
    """Baseline FP16 GEMM: x [M,K] times w [N,K] -> [M,N] f32 accumulate."""
    return jnp.dot(
        x.astype(jnp.float32),
        w_f16.astype(jnp.float32).T,
        preferred_element_type=jnp.float32,
    )


def gemm_fp16_nested(x: jnp.ndarray, upper: jnp.ndarray, lower: jnp.ndarray) -> jnp.ndarray:
    """NestedFP16 GEMM reference: reconstruct then matmul. Must be
    *bitwise identical* to gemm_fp16_plain on the original weights."""
    w = reconstruct_f16(upper, lower)
    return gemm_fp16_plain(x, w)


def act_scale_per_tensor(x: jnp.ndarray) -> jnp.ndarray:
    """Per-tensor activation scale: 448 / absmax (paper section 5.1)."""
    m = jnp.max(jnp.abs(x.astype(jnp.float32)))
    return jnp.where(m > 0, 448.0 / m, 1.0).astype(jnp.float32)


def gemm_fp8_nested(
    x: jnp.ndarray, upper: jnp.ndarray, act_scale: jnp.ndarray | float = 1.0
) -> jnp.ndarray:
    """NestedFP8 GEMM reference: absmax-quantized activations (per-tensor
    scale, computed offline as the paper does) times the upper-plane
    weights at the global 2^-8 scale."""
    scale = jnp.asarray(act_scale, dtype=jnp.float32)
    xs = e4m3_fake_quant(x.astype(jnp.float32) * scale) / scale
    w8 = upper_to_weight_f32(upper)
    return jnp.dot(xs, w8.T, preferred_element_type=jnp.float32)

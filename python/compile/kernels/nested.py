"""Layer-1 Pallas kernels: the NestedFP GEMM hot paths.

Two kernels mirror the paper's CUTLASS designs (section 4.3), rethought for
a TPU-shaped machine (see DESIGN.md "Hardware adaptation"):

* ``nested_fp16_gemm`` — FP16 GEMM over the two 8-bit component planes.
  The on-the-fly reconstruction (the paper's SIMT bitwise stage) runs as
  vectorized integer ops on the uint8 tiles resident in VMEM before the
  tile matmul hits the MXU. The grid's K-loop plays the role of the
  CUTLASS mainloop; Pallas double-buffers the HBM->VMEM tile copies that
  the H100 kernel drives with TMA.

* ``nested_fp8_gemm`` — FP8 GEMM over the upper plane only (half the
  weight traffic, the paper's memory-bandwidth argument). Upper bytes are
  decoded as OCP E4M3 at the fixed 2^-8 global scale; activations arrive
  pre-quantized to the E4M3 grid with a per-tensor absmax scale.

Kernels run with ``interpret=True`` (the CPU PJRT plugin cannot execute
Mosaic custom-calls), so their numerics — not their wall-clock — are the
deliverable; H100-side performance is modelled by ``rust/src/gpusim``.

Weight layout is output-major ``[N, K]`` and activations are ``[M, K]``;
the GEMM computes ``x @ w.T`` exactly like the serving stack's linear
layers.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


# ---------------------------------------------------------------------------
# In-kernel bit manipulation (the SIMT stage)
# ---------------------------------------------------------------------------


def _reconstruct_tile(upper: jnp.ndarray, lower: jnp.ndarray) -> jnp.ndarray:
    """Reconstruct an fp16 tile from uint8 component tiles.

    Branch-free (paper Fig. 6): subtract the checksum bit m3 from the
    upper byte; its top 6 bits are then the original E[2:5]||M[1:2].
    All ops are lane-parallel integer arithmetic (VPU-friendly).
    """
    u = upper.astype(jnp.uint16)
    low = lower.astype(jnp.uint16)
    s = (u >> 7) & 1
    m3 = (low >> 7) & 1
    corrected = (u & 0x7F) - m3
    top6 = (corrected >> 1) & 0x3F
    bits = (s << 15) | (top6 << 8) | low
    return bits.view(jnp.float16)


def _e4m3_decode_tile(upper: jnp.ndarray) -> jnp.ndarray:
    """Decode a uint8 E4M3 tile to f32 (NaN pattern never occurs for
    NestedFP uppers — guaranteed by the eligibility rule)."""
    c = upper.astype(jnp.int32)
    s = jnp.where((c >> 7) & 1 == 1, -1.0, 1.0).astype(jnp.float32)
    e = (c >> 3) & 0xF
    m = (c & 0x7).astype(jnp.float32)
    normal = (1.0 + m / 8.0) * jnp.exp2((e - 7).astype(jnp.float32))
    subnormal = (m / 8.0) * jnp.exp2(jnp.float32(-6))
    return s * jnp.where(e == 0, subnormal, normal)


# ---------------------------------------------------------------------------
# NestedFP16 GEMM kernel
# ---------------------------------------------------------------------------


def _nested_fp16_kernel(x_ref, up_ref, lo_ref, o_ref, *, n_k: int):
    """One (bm, bn, bk) grid step of the FP16-mode GEMM.

    Grid order is (m, n, k) with k innermost: the accumulator tile lives in
    VMEM scratch across the K loop (the CUTLASS register accumulator).
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero_acc():
        o_ref[...] = jnp.zeros_like(o_ref)

    # --- the "SIMT" stage: reconstruct the fp16 weight tile from bytes ---
    w_tile = _reconstruct_tile(up_ref[...], lo_ref[...])  # [bn, bk] f16

    # --- the MXU stage: tile matmul with f32 accumulation ---
    # (o_ref acts as the accumulator: its block index is constant along k,
    # playing the role of the CUTLASS register accumulator tile)
    x_tile = x_ref[...].astype(jnp.float32)  # [bm, bk]
    o_ref[...] += jax.lax.dot_general(
        x_tile,
        w_tile.astype(jnp.float32),
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k"))
def nested_fp16_gemm(
    x: jnp.ndarray,
    upper: jnp.ndarray,
    lower: jnp.ndarray,
    *,
    block_m: int = 32,
    block_n: int = 64,
    block_k: int = 64,
) -> jnp.ndarray:
    """FP16-mode GEMM: ``x [M,K] @ reconstruct(upper, lower).T -> [M,N]``.

    Bitwise-identical to running the plain FP16 GEMM on the original
    weights (the losslessness claim); verified in python/tests.
    """
    m, k = x.shape
    n, k2 = upper.shape
    assert k == k2 and upper.shape == lower.shape
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0, (
        f"shape ({m},{n},{k}) not divisible by blocks "
        f"({block_m},{block_n},{block_k}); pad upstream"
    )
    n_k = k // block_k
    grid = (m // block_m, n // block_n, n_k)
    return pl.pallas_call(
        functools.partial(_nested_fp16_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_n, block_k), lambda i, j, kk: (j, kk)),
            pl.BlockSpec((block_n, block_k), lambda i, j, kk: (j, kk)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, upper, lower)


# ---------------------------------------------------------------------------
# NestedFP8 GEMM kernel
# ---------------------------------------------------------------------------


def _nested_fp8_kernel(x_ref, up_ref, o_ref, *, n_k: int):
    """One grid step of the FP8-mode GEMM: only the upper plane is read."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero_acc():
        o_ref[...] = jnp.zeros_like(o_ref)

    w_tile = _e4m3_decode_tile(up_ref[...])  # [bn, bk] f32, value*2^8
    x_tile = x_ref[...].astype(jnp.float32)
    o_ref[...] += jax.lax.dot_general(
        x_tile,
        w_tile,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == n_k - 1)
    def _scale():
        # fold out the fixed 2^8 weight scale once per output tile
        o_ref[...] *= jnp.float32(2.0**-8)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k"))
def nested_fp8_gemm(
    x_quant: jnp.ndarray,
    upper: jnp.ndarray,
    *,
    block_m: int = 32,
    block_n: int = 64,
    block_k: int = 64,
) -> jnp.ndarray:
    """FP8-mode GEMM: pre-quantized activations times the upper plane.

    ``x_quant`` must already sit on the E4M3 grid after per-tensor scaling
    (use ``ref.e4m3_fake_quant`` / the model's activation quant step);
    the kernel itself only touches 8-bit weight traffic, mirroring the
    memory-bandwidth advantage on real hardware.
    """
    m, k = x_quant.shape
    n, k2 = upper.shape
    assert k == k2
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0
    n_k = k // block_k
    grid = (m // block_m, n // block_n, n_k)
    return pl.pallas_call(
        functools.partial(_nested_fp8_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_n, block_k), lambda i, j, kk: (j, kk)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x_quant, upper)


# ---------------------------------------------------------------------------
# VMEM footprint / MXU utilization estimator (the L1 "profiler")
# ---------------------------------------------------------------------------


def kernel_vmem_bytes(block_m: int, block_n: int, block_k: int, mode: str) -> int:
    """Estimated VMEM working set for one grid step (double-buffered
    inputs + accumulator), used by the L1 performance pass."""
    x_tile = block_m * block_k * 2  # f16 activations
    if mode == "fp16":
        w_tiles = 2 * block_n * block_k  # upper + lower bytes
    elif mode == "fp8":
        w_tiles = block_n * block_k
    else:
        raise ValueError(mode)
    acc = block_m * block_n * 4
    # double buffering on the streamed inputs
    return 2 * (x_tile + w_tiles) + acc


def mxu_utilization_estimate(block_m: int, block_n: int, block_k: int) -> float:
    """Fraction of MXU lanes used by a tile shape (128x128 systolic array,
    8-deep pipeline assumed)."""
    eff_m = min(block_m, 128) / 128.0 if block_m < 128 else 1.0
    eff_n = min(block_n, 128) / 128.0 if block_n < 128 else 1.0
    eff_k = min(block_k, 128) / 128.0 if block_k < 128 else 1.0
    return eff_m * eff_n * eff_k

"""Build-time training of the in-repo tiny model on the synthetic corpus.

Stands in for the paper's production checkpoints (DESIGN.md section 2):
the format-level claims only need a *real* autoregressive LM with realistic
weight distributions, which a few hundred Adam steps on the task corpus
provides. Runs once at `make artifacts`; the checkpoint is cached in
artifacts/checkpoint.npz.

Usage: python -m compile.train [--steps N] [--out PATH]
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import corpus, model

SEED = 20250710


def make_batches(cfg: model.ModelConfig, n_bytes: int, batch: int, seqlen: int):
    data = np.frombuffer(corpus.gen_corpus_bytes(SEED, n_bytes), dtype=np.uint8)
    data = data.astype(np.int32)
    n_seq = len(data) // seqlen
    data = data[: n_seq * seqlen].reshape(n_seq, seqlen)
    rng = np.random.default_rng(SEED)

    def batches():
        while True:
            idx = rng.integers(0, n_seq, size=batch)
            yield jnp.asarray(data[idx])

    return batches()


def adam_init(params):
    z = jax.tree.map(jnp.zeros_like, params)
    return {"m": z, "v": jax.tree.map(jnp.zeros_like, params), "t": jnp.zeros((), jnp.int32)}


def adam_step(params, grads, state, lr, b1=0.9, b2=0.98, eps=1e-9):
    t = state["t"] + 1
    m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
    v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads)
    mhat_scale = 1.0 / (1 - b1 ** t.astype(jnp.float32))
    vhat_scale = 1.0 / (1 - b2 ** t.astype(jnp.float32))
    new = jax.tree.map(
        lambda p, m_, v_: p - lr * (m_ * mhat_scale) / (jnp.sqrt(v_ * vhat_scale) + eps),
        params,
        m,
        v,
    )
    return new, {"m": m, "v": v, "t": t}


def train(cfg: model.ModelConfig, steps: int, batch: int = 32, seqlen: int = 48,
          init_params_from=None, base_lr: float = 3e-3):
    if init_params_from is not None:
        params = init_params_from
    else:
        params = model.init_params(cfg, jax.random.PRNGKey(SEED))
    opt = adam_init(params)
    data = make_batches(cfg, n_bytes=2_000_000, batch=batch, seqlen=seqlen)

    warmup = max(1, steps // 20)

    @jax.jit
    def step_fn(params, opt, tokens, lr):
        loss, grads = jax.value_and_grad(lambda p: model.lm_loss(cfg, p, tokens))(params)
        params, opt = adam_step(params, grads, opt, lr)
        return params, opt, loss

    t0 = time.time()
    losses = []
    for i in range(steps):
        if i < warmup:
            lr = base_lr * (i + 1) / warmup
        else:
            frac = (i - warmup) / max(1, steps - warmup)
            lr = base_lr * 0.5 * (1 + np.cos(np.pi * frac))
        tokens = next(data)
        params, opt, loss = step_fn(params, opt, tokens, jnp.float32(lr))
        losses.append(float(loss))
        if i % 25 == 0 or i == steps - 1:
            print(
                f"step {i:4d}  loss {float(loss):.4f}  lr {lr:.2e}  "
                f"({time.time() - t0:.1f}s)",
                flush=True,
            )
    return params, losses


def flatten_params(params) -> dict[str, np.ndarray]:
    out = {
        "embed": np.asarray(params["embed"]),
        "final_norm": np.asarray(params["final_norm"]),
        "lm_head": np.asarray(params["lm_head"]),
    }
    for i, layer in enumerate(params["layers"]):
        for k, v in layer.items():
            out[f"layers.{i}.{k}"] = np.asarray(v)
    return out


def unflatten_params(flat: dict[str, np.ndarray], cfg: model.ModelConfig):
    params = {
        "embed": jnp.asarray(flat["embed"]),
        "final_norm": jnp.asarray(flat["final_norm"]),
        "lm_head": jnp.asarray(flat["lm_head"]),
        "layers": [],
    }
    for i in range(cfg.n_layers):
        layer = {}
        for k in ("attn_norm", "mlp_norm", *model.LINEAR_NAMES):
            layer[k] = jnp.asarray(flat[f"layers.{i}.{k}"])
        params["layers"].append(layer)
    return params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--out", default="../artifacts/checkpoint.npz")
    ap.add_argument("--resume", default=None, help="continue from a checkpoint")
    ap.add_argument("--lr", type=float, default=3e-3)
    args = ap.parse_args()
    cfg = model.ModelConfig()
    init = None
    if args.resume:
        flat = dict(np.load(args.resume))
        flat.pop("__losses__", None)
        init = unflatten_params(flat, cfg)
    params, losses = train(cfg, args.steps, init_params_from=init, base_lr=args.lr)
    flat = flatten_params(params)
    flat["__losses__"] = np.asarray(losses, np.float32)
    np.savez(args.out, **flat)
    print(f"saved checkpoint to {args.out} (final loss {losses[-1]:.4f})")


if __name__ == "__main__":
    main()

//! Kernel tour: the NestedFP format and the GEMM paths, bottom-up.
//!
//! 1. bit-level: decompose / losslessly reconstruct FP16 weights in Rust;
//! 2. runtime: execute the standalone AOT GEMM artifacts (the Pallas
//!    kernels lowered to HLO) on the PJRT CPU client and check each mode
//!    against its host twin on the real compute engine
//!    (`RealBackend::native_gemm` — the fused `gemm::GemmEngine` over the
//!    same weight store, replacing the old reconstruct + naive-matmul
//!    reference);
//! 3. cost model: what the same GEMMs cost on the simulated H100 under
//!    the paper's kernel config search;
//! 4. engine vs model: run one paper shape on the *real* engine next to
//!    the gpusim prediction and compare the format ratios.
//!
//! Run: `cargo run --release --offline --example kernel_tour`

use std::path::Path;
use std::time::Duration;

use nestedfp::coordinator::backend::{ModeMap, RealBackend};
use nestedfp::format::fp16::F16;
use nestedfp::format::nested;
use nestedfp::format::tensor::Tensor2;
use nestedfp::gemm::{GemmEngine, GemmFormat, GemmWeights};
use nestedfp::gpusim::{self, GemmQuery, OptLevel, WeightFormat};
use nestedfp::runtime::{HostTensor, ModelRuntime};
use nestedfp::util::rng::Pcg64;
use nestedfp::util::timer;

fn main() -> anyhow::Result<()> {
    println!("== 1. the format, bit level ==");
    let mut rng = Pcg64::seeded(99);
    let vals: Vec<f32> = (0..8).map(|_| rng.normal() as f32 * 0.4).collect();
    for &v in &vals[..4] {
        let h = F16::from_f32(v);
        let (u, l) = nested::decompose(h);
        let back = nested::reconstruct(u, l);
        let w8 = nested::upper_as_weight(u);
        println!(
            "  {v:+.5} -> upper 0x{u:02x} lower 0x{l:02x} -> fp16 {:+.5} (lossless: {}), fp8-path {w8:+.5}",
            back.to_f32(),
            back.to_bits() == h.to_bits()
        );
    }

    println!("\n== 2. the AOT GEMM artifacts vs their host twin ==");
    let dir = Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("  (skipped: run `make artifacts` first)");
    } else {
        let rt = ModelRuntime::load(dir, &["fp16", "nested16", "nested8"], &["gemm"])?;
        let backend = RealBackend::new(rt, ModeMap::default(), 64);
        // layer-0 wq's planes for a (32, 256, 256) GEMM
        let (m, n, k) = (32usize, 256usize, 256usize);
        let x: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32 * 0.5).collect();
        let x16: Vec<u16> = x.iter().map(|&v| F16::from_f32(v).to_bits()).collect();
        let xr = Tensor2::from_vec(
            m,
            k,
            x16.iter().map(|&b| F16::from_bits(b).to_f32()).collect(),
        );

        for mode in ["fp16", "nested16", "nested8"] {
            // host twin: the fused engine straight from the weight store
            let expect = backend.native_gemm(mode, "layers.0.wq", &xr)?;
            let step = backend.rt.step("gemm", mode, n)?;
            let dyn_in: Vec<HostTensor> = match mode {
                "fp16" => vec![
                    HostTensor::from_u16(vec![m, k], &x16),
                    HostTensor::from_u16(
                        vec![n, k],
                        &backend.rt.weights.get("layers.0.wq.f16")?.as_u16()?,
                    ),
                ],
                "nested16" => vec![
                    HostTensor::from_u16(vec![m, k], &x16),
                    HostTensor::from_u8(
                        vec![n, k],
                        backend.rt.weights.get("layers.0.wq.upper")?.bytes.clone(),
                    ),
                    HostTensor::from_u8(
                        vec![n, k],
                        backend.rt.weights.get("layers.0.wq.lower")?.bytes.clone(),
                    ),
                ],
                _ => vec![
                    HostTensor::from_f32(vec![m, k], &xr.data),
                    HostTensor::from_u8(
                        vec![n, k],
                        backend.rt.weights.get("layers.0.wq.upper")?.bytes.clone(),
                    ),
                ],
            };
            let out = backend.rt.run(step, &dyn_in)?;
            let got = Tensor2::from_vec(m, n, out.tensors[0].as_f32()?);
            println!(
                "  {mode:<9} exec {:>6} us   rel err vs host engine: {:.2e}",
                out.exec_micros,
                got.rel_err(&expect)
            );
        }
    }

    println!("\n== 3. the same GEMM on the simulated H100 ==");
    for (m, n, k) in [(32usize, 4096usize, 4096usize), (512, 14336, 4096)] {
        print!("  ({m:>4} x {n} x {k}):");
        for fmt in [
            WeightFormat::Fp16,
            WeightFormat::Nested16,
            WeightFormat::Nested8,
            WeightFormat::Fp8,
        ] {
            let (cfg, t) = gpusim::best_config(&GemmQuery {
                m,
                n,
                k,
                format: fmt,
                opt: OptLevel::Level3,
            })
            .unwrap();
            print!("  {fmt:?} {:.0}us ({})", t * 1e6, cfg.name());
        }
        println!();
    }

    println!("\n== 4. the real engine vs the analytical model ==");
    // one paper shape: llama31-8b's MLP down projection (N=4096, K=14336)
    // at 1/4 scale so the CPU sweep stays interactive
    let (m, n, k) = (128usize, 1024usize, 3584usize);
    println!("  shape ({m} x {n} x {k}) — llama31-8b down-proj / 4, single thread");
    let x = Tensor2::from_vec(
        m,
        k,
        (0..m * k).map(|_| rng.normal() as f32 * 0.5).collect(),
    );
    let w = Tensor2::from_vec(
        n,
        k,
        (0..n * k)
            .map(|_| (rng.normal() as f32 * 0.3).clamp(-1.7, 1.7))
            .collect(),
    );
    let engine = GemmEngine::with_threads(1);
    let flops = 2.0 * (m * n * k) as f64;
    let mut secs = Vec::new();
    for fmt in GemmFormat::ALL {
        let g = GemmWeights::prepare(&w, fmt)?;
        let stats = timer::bench(0, 2, Duration::from_millis(400), || {
            std::hint::black_box(engine.matmul(&x, &g, fmt));
        });
        let t_meas = stats.min_ns * 1e-9;
        let t_pred = gpusim::best_latency(&GemmQuery {
            m,
            n,
            k,
            format: fmt.to_gpusim(),
            opt: OptLevel::Level3,
        });
        println!(
            "  {:<9} measured {:>7.1} ms ({:>5.2} GFLOP/s)   predicted H100 {:>6.0} us",
            fmt.label(),
            t_meas * 1e3,
            flops / t_meas / 1e9,
            t_pred * 1e6
        );
        secs.push((fmt, t_meas, t_pred));
    }
    let t = |f: GemmFormat| secs.iter().find(|(g, _, _)| *g == f).unwrap();
    let (_, m16, p16) = t(GemmFormat::Fp16);
    let (_, mn16, pn16) = t(GemmFormat::Nested16);
    let (_, mn8, pn8) = t(GemmFormat::Nested8);
    println!(
        "  nested16 overhead vs fp16:   predicted {:+.1}%   measured {:+.1}%",
        (pn16 / p16 - 1.0) * 100.0,
        (mn16 / m16 - 1.0) * 100.0
    );
    println!(
        "  nested8 speedup vs nested16: predicted {:.2}x   measured {:.2}x",
        pn16 / pn8,
        mn16 / mn8
    );
    println!("  (predictions are HBM-roofline H100 latencies; the CPU engine agrees in ordering, not magnitude)");

    // and the losslessness claim at the product level: nested16 output is
    // bit-identical to the fp16 output, because the fused pack stage
    // reconstructs the exact master bits
    let g16 = GemmWeights::prepare(&w, GemmFormat::Fp16)?;
    let gn = GemmWeights::prepare(&w, GemmFormat::Nested16)?;
    let c16 = engine.matmul(&x, &g16, GemmFormat::Fp16);
    let cn = engine.matmul(&x, &gn, GemmFormat::Nested16);
    let identical = c16
        .data
        .iter()
        .zip(&cn.data)
        .all(|(a, b)| a.to_bits() == b.to_bits());
    println!("  nested16 product bit-identical to fp16 product: {identical}");
    Ok(())
}

//! Kernel tour: the NestedFP format and the GEMM paths, bottom-up.
//!
//! 1. bit-level: decompose / losslessly reconstruct FP16 weights in Rust;
//! 2. runtime: execute the standalone AOT GEMM artifacts (the Pallas
//!    kernels lowered to HLO) on the PJRT CPU client and check them
//!    against the Rust reference matmul;
//! 3. cost model: show what the same GEMMs cost on the simulated H100
//!    under the paper's kernel config search.
//!
//! Run: `cargo run --release --offline --example kernel_tour`

use std::path::Path;

use nestedfp::format::nested;
use nestedfp::format::fp16::F16;
use nestedfp::format::tensor::Tensor2;
use nestedfp::gpusim::{self, GemmQuery, OptLevel, WeightFormat};
use nestedfp::runtime::{HostTensor, ModelRuntime};
use nestedfp::util::rng::Pcg64;

fn main() -> anyhow::Result<()> {
    println!("== 1. the format, bit level ==");
    let mut rng = Pcg64::seeded(99);
    let vals: Vec<f32> = (0..8).map(|_| rng.normal() as f32 * 0.4).collect();
    for &v in &vals[..4] {
        let h = F16::from_f32(v);
        let (u, l) = nested::decompose(h);
        let back = nested::reconstruct(u, l);
        let w8 = nested::upper_as_weight(u);
        println!(
            "  {v:+.5} -> upper 0x{u:02x} lower 0x{l:02x} -> fp16 {:+.5} (lossless: {}), fp8-path {w8:+.5}",
            back.to_f32(),
            back.to_bits() == h.to_bits()
        );
    }

    println!("\n== 2. the AOT GEMM artifacts on PJRT ==");
    let dir = Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("  (skipped: run `make artifacts` first)");
    } else {
        let rt = ModelRuntime::load(dir, &["fp16", "nested16", "nested8"], &["gemm"])?;
        // use layer-0 wq's planes for a (32, 256, 256) GEMM
        let (m, n, k) = (32usize, 256usize, 256usize);
        let x: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32 * 0.5).collect();
        let x16: Vec<u16> = x.iter().map(|&v| F16::from_f32(v).to_bits()).collect();

        // rust-side reference from the weight store
        let wstore = rt.weights.get("layers.0.wq.f16")?.as_u16()?;
        let w = Tensor2::from_vec(
            n,
            k,
            wstore.iter().map(|&b| F16::from_bits(b).to_f32()).collect(),
        );
        let xr = Tensor2::from_vec(
            m,
            k,
            x16.iter().map(|&b| F16::from_bits(b).to_f32()).collect(),
        );
        // reference: x @ w.T via transpose trick
        let mut wt = Tensor2::zeros(k, n);
        for r in 0..n {
            for c in 0..k {
                wt.set(c, r, w.get(r, c));
            }
        }
        let expect = xr.matmul(&wt);

        for mode in ["fp16", "nested16", "nested8"] {
            let step = rt.step("gemm", mode, n)?;
            let dyn_in: Vec<HostTensor> = match mode {
                "fp16" => vec![
                    HostTensor::from_u16(vec![m, k], &x16),
                    HostTensor::from_u16(
                        vec![n, k],
                        &rt.weights.get("layers.0.wq.f16")?.as_u16()?,
                    ),
                ],
                "nested16" => vec![
                    HostTensor::from_u16(vec![m, k], &x16),
                    HostTensor::from_u8(
                        vec![n, k],
                        rt.weights.get("layers.0.wq.upper")?.bytes.clone(),
                    ),
                    HostTensor::from_u8(
                        vec![n, k],
                        rt.weights.get("layers.0.wq.lower")?.bytes.clone(),
                    ),
                ],
                _ => vec![
                    HostTensor::from_f32(vec![m, k], &xr.data),
                    HostTensor::from_u8(
                        vec![n, k],
                        rt.weights.get("layers.0.wq.upper")?.bytes.clone(),
                    ),
                ],
            };
            let out = rt.run(step, &dyn_in)?;
            let got = Tensor2::from_vec(m, n, out.tensors[0].as_f32()?);
            println!(
                "  {mode:<9} exec {:>6} us   rel err vs rust reference: {:.2e}",
                out.exec_micros,
                got.rel_err(&expect)
            );
        }
    }

    println!("\n== 3. the same GEMM on the simulated H100 ==");
    for (m, n, k) in [(32usize, 4096usize, 4096usize), (512, 14336, 4096)] {
        print!("  ({m:>4} x {n} x {k}):");
        for fmt in [
            WeightFormat::Fp16,
            WeightFormat::Nested16,
            WeightFormat::Nested8,
            WeightFormat::Fp8,
        ] {
            let (cfg, t) = gpusim::best_config(&GemmQuery {
                m,
                n,
                k,
                format: fmt,
                opt: OptLevel::Level3,
            })
            .unwrap();
            print!("  {fmt:?} {:.0}us ({})", t * 1e6, cfg.name());
        }
        println!();
    }
    Ok(())
}

//! Cluster surge absorption — the multi-replica SLO study.
//!
//! Replays one synthetic traffic surge (flat base rate with a 5x plateau)
//! against 1, 2, and 4 simulated-H100 engine replicas behind the
//! SLO-headroom router, and prints, per cluster size:
//!
//! * aggregate TTFT / TPOT percentiles, SLO violations, and goodput,
//! * the staged-escalation timeline (how many replicas were demoted to
//!   FP8, and when), and
//! * each replica's own precision timeline — so you can watch the surge
//!   being absorbed by *selective* FP8 demotion: the tail replicas go
//!   FP8 first and come back first, replica 0 keeps FP16 the longest.
//!
//! Run: `cargo run --release --offline --example cluster_surge
//!       [-- --seconds 60 --base 3.0 --policy slo|rr|kv|rand]`

use nestedfp::bench::cluster::{run_cluster, surge_workload};
use nestedfp::coordinator::precision::SloConfig;
use nestedfp::coordinator::router::RoutingPolicy;
use nestedfp::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let seconds = args.get_usize("seconds", 60);
    let base = args.get_f64("base", 3.0);
    let policy = match args.get_or("policy", "slo") {
        "rr" => RoutingPolicy::RoundRobin,
        "kv" => RoutingPolicy::LeastLoadedKv,
        "rand" => RoutingPolicy::Random { seed: 17 },
        _ => RoutingPolicy::SloHeadroom,
    };
    let slo = SloConfig::default();

    let n_requests = surge_workload(seconds, base).len();
    println!(
        "== cluster_surge: {seconds}s at {base} req/s with a 5x surge ({n_requests} requests, {policy:?} routing) =="
    );

    for n in [1usize, 2, 4] {
        let mut report = run_cluster(n, policy, seconds, base)?;
        let ttft = report.aggregate.ttft_summary();
        let tpot = report.aggregate.tpot_summary();
        println!("\n-- {n} replica(s) --");
        println!(
            "aggregate  TTFT p50 {:6.1} ms  p90 {:6.1} ms | TPOT p50 {:5.1} ms  p90 {:5.1} ms | viol {:>3}s | goodput {:5.2} req/s | fp16-time {:>3.0}%",
            ttft.p50 * 1e3,
            ttft.p90 * 1e3,
            tpot.p50 * 1e3,
            tpot.p90 * 1e3,
            report.aggregate.slo_violation_seconds(&slo),
            report.aggregate.goodput_req_s(&slo),
            report.fp16_fraction() * 100.0,
        );
        if report.demotion_timeline.is_empty() {
            println!("escalation: never engaged (surge absorbed at FP16)");
        } else {
            let line: Vec<String> = report
                .demotion_timeline
                .iter()
                .take(12)
                .map(|&(t, k)| format!("{t:.1}s->{k}fp8"))
                .collect();
            println!("escalation: {}", line.join("  "));
        }
        for (i, r) in report.replicas.iter().enumerate() {
            let modes: Vec<String> = r
                .mode_timeline
                .iter()
                .take(10)
                .map(|&(t, fp8)| format!("{:.1}s->{}", t, if fp8 { "fp8" } else { "fp16" }))
                .collect();
            println!(
                "replica {i}: {:>3} reqs  {:>5} iters  fp16-time {:>3.0}%  modes: {}",
                r.routed,
                r.iterations,
                r.controller.fp16_fraction() * 100.0,
                if modes.is_empty() {
                    "(idle)".to_string()
                } else {
                    modes.join("  ")
                },
            );
        }
    }
    println!(
        "\nReading the output: with 1 replica the whole fleet is the surge's victim — \
         escalation (and the Dual controller itself) push it to FP8 for much of the \
         surge window. With 4 replicas the router spreads the load and only the \
         tail replicas (3, then 2) are demoted, briefly; replica 0 serves FP16 \
         throughout. Aggregate violations shrink as replicas are added while \
         goodput holds — the surge is absorbed by selective FP8 demotion."
    );
    Ok(())
}

//! End-to-end serving driver — the repository's headline validation run.
//!
//! Loads the trained tiny model's AOT artifacts and serves a bursty
//! workload of *real* task prompts through the full stack: router →
//! continuous-batching scheduler → chunked prefill → batched decode on
//! the PJRT CPU runtime, with the dual-precision controller switching
//! between the FP16 and FP8 executables of the single NestedFP weight
//! store. Reports real TTFT/TPOT/throughput plus answer accuracy.
//!
//! Run: `cargo run --release --offline --example serve_trace [-- --n 24 --rate 6]`
//! Recorded in EXPERIMENTS.md §End-to-end.

use std::path::Path;

use nestedfp::coordinator::backend::{ModeMap, RealBackend};
use nestedfp::coordinator::engine::{Engine, EngineConfig};
use nestedfp::coordinator::precision::{PrecisionPolicy, SloConfig};
use nestedfp::coordinator::request::Request;
use nestedfp::eval::tasks::{self, Task};
use nestedfp::runtime::ModelRuntime;
use nestedfp::util::cli::Args;
use nestedfp::util::rng::Pcg64;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let n_req = args.get_usize("n", 24);
    let rate = args.get_f64("rate", 6.0); // arrivals per simulated second
    let dir = Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("artifacts/ missing — run `make artifacts` first");
        return Ok(());
    }

    println!("== serve_trace: dual-precision serving on the real PJRT backend ==");
    let t_load = std::time::Instant::now();
    let rt = ModelRuntime::load(dir, &["nested16", "nested8"], &["decode", "prefill"])?;
    println!(
        "loaded + compiled {} executables in {:.1}s",
        rt.loaded_keys().len(),
        t_load.elapsed().as_secs_f64()
    );
    let align = rt.manifest.prefill_chunks.iter().copied().min().unwrap_or(32);
    let max_seq = rt.manifest.model.max_seq;
    let max_batch = rt.manifest.decode_buckets.iter().copied().max().unwrap_or(4);

    // build a bursty workload of real task prompts
    let mut rng = Pcg64::seeded(4242);
    let mut requests = Vec::new();
    let mut answers = Vec::new();
    let mut t = 0.0f64;
    for i in 0..n_req {
        let task = Task::ALL[rng.index(3)];
        let (prompt, answer) = tasks::gen_example(&mut rng, task);
        let toks = tasks::chunk_aligned_prompt(&prompt, align, 1000 + i as u64);
        // bursty arrivals: clustered exponential gaps
        t += if rng.f64() < 0.3 { 0.001 } else { rng.exp(rate) };
        requests.push(
            Request::new(i as u64, toks, answer.len() + 4, t).with_stop(b';' as i32),
        );
        answers.push((task, prompt, answer));
    }

    let backend = RealBackend::new(
        rt,
        ModeMap::default(),
        max_batch * (max_seq / 16 + 1) + 32,
    );
    let mut engine = Engine::new(
        backend,
        EngineConfig {
            policy: PrecisionPolicy::Dual,
            // CPU-scale SLO: the PJRT-CPU decode step is ~25 ms, so the
            // "interactive" target scales to 120 ms per token
            slo: SloConfig {
                tpot_target: 0.120,
                ttft_target: 1.0,
            },
            physical_kv: true,
            ..Default::default()
        },
    );

    let t0 = std::time::Instant::now();
    let mut report = engine.run(requests)?;
    let wall = t0.elapsed().as_secs_f64();

    // accuracy
    let mut correct = 0;
    for c in &report.completions {
        let (task, prompt, answer) = &answers[c.id as usize];
        let text: String = c.tokens.iter().map(|&t| (t as u8) as char).collect();
        let ok = text == *answer;
        if ok {
            correct += 1;
        }
        if c.id < 8 {
            println!(
                "  [{:>4}] {:<5} {prompt:<12} -> {text:<10} ({})",
                c.id,
                task.name(),
                if ok { "ok" } else { "wrong" }
            );
        }
    }

    println!("--------------------------------------------------");
    println!(
        "requests: {}   correct: {}/{} ({:.0}%)",
        report.metrics.completed,
        correct,
        n_req,
        correct as f64 / n_req as f64 * 100.0
    );
    println!("engine-clock span: {:.2}s (wall {wall:.2}s)", engine.now());
    println!("TTFT  {}", report.metrics.ttft_summary());
    println!("TPOT  {}", report.metrics.tpot_summary());
    println!(
        "throughput: {:.1} output tok/s",
        report.metrics.throughput_tok_s()
    );
    println!(
        "precision: {} switches, {:.0}% of iterations in FP16 mode",
        report.controller.switches,
        report.controller.fp16_fraction() * 100.0
    );
    Ok(())
}

//! Dual-precision SLO study on the simulated H100 — an interactive
//! version of Figure 1b with tunable load.
//!
//! Replays an Azure-like bursty trace slice against llama-3.1-8b (cost
//! model) under the three policies and prints the TPOT distribution, SLO
//! violations, and the controller's mode timeline.
//!
//! Run: `cargo run --release --offline --example dual_precision_slo
//!       [-- --scale 0.16 --seconds 120 --model mistral-small-24b]`

use nestedfp::coordinator::backend::SimBackend;
use nestedfp::coordinator::engine::{Engine, EngineConfig};
use nestedfp::coordinator::precision::{PrecisionPolicy, SloConfig};
use nestedfp::gpusim::WeightFormat;
use nestedfp::model::zoo;
use nestedfp::trace::azure::{self, AzureTraceConfig};
use nestedfp::trace::workload::{build_requests, poisson_arrivals, WorkloadConfig};
use nestedfp::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let scale = args.get_f64("scale", 0.16);
    let seconds = args.get_usize("seconds", 120);
    let model = args.get_or("model", "llama31-8b").to_string();
    let spec = zoo::find(&model)
        .ok_or_else(|| anyhow::anyhow!("unknown model '{model}' (see model::zoo)"))?;

    println!("== dual_precision_slo: {model}, {seconds}s slice at {scale}x scale ==");
    let cfg = AzureTraceConfig::default();
    let rates = azure::generate_rate_series(&cfg);
    let start = cfg.busy_minute_start - seconds / 2;
    let slice = azure::downscale(&rates[start..start + seconds], scale);
    let arrivals = poisson_arrivals(&slice, 33);
    println!(
        "workload: {} requests over {seconds}s (avg {:.1} req/s)",
        arrivals.len(),
        arrivals.len() as f64 / seconds as f64
    );

    let slo = SloConfig::default();
    for (name, policy) in [
        ("fp16-only      ", PrecisionPolicy::Fp16Only),
        ("fp8-only       ", PrecisionPolicy::Fp8Only),
        ("dual (NestedFP)", PrecisionPolicy::Dual),
    ] {
        let max_seq = 2048;
        let wl = WorkloadConfig {
            seed: 5,
            input_len: 0,
            output_len: 0,
            chunk_align: 64,
        };
        let mut requests = build_requests(&arrivals, &wl, max_seq);
        for r in &mut requests {
            r.max_new_tokens = r.max_new_tokens.min(256);
        }
        let backend = SimBackend::new(
            spec,
            WeightFormat::Nested16,
            WeightFormat::Nested8,
            64,
            max_seq,
            64 * (max_seq / 16 + 1) * 2,
        );
        let mut engine = Engine::new(
            backend,
            EngineConfig {
                policy,
                slo,
                physical_kv: false,
                ..Default::default()
            },
        );
        let mut report = engine.run(requests)?;
        let tp = report.metrics.tpot_summary();
        println!(
            "{name}  p50 {:6.1} ms  p90 {:6.1} ms  p99 {:6.1} ms  viol {:>3}s  fp16-time {:>3.0}%  switches {}",
            tp.p50 * 1e3,
            tp.p90 * 1e3,
            tp.p99 * 1e3,
            report.metrics.slo_violation_seconds(&slo),
            report.controller.fp16_fraction() * 100.0,
            report.controller.switches,
        );
        if policy == PrecisionPolicy::Dual && !report.mode_timeline.is_empty() {
            let line: Vec<String> = report
                .mode_timeline
                .iter()
                .take(14)
                .map(|&(t, fp8)| format!("{:.1}s->{}", t, if fp8 { "fp8" } else { "fp16" }))
                .collect();
            println!("    mode timeline: {}", line.join("  "));
        }
    }
    Ok(())
}

//! Quickstart: load the NestedFP artifacts, run one decode step in every
//! mode, and show the dual-precision property in action — the SAME weight
//! store serves both FP16 (lossless) and FP8 execution.
//!
//! Run: `cargo run --release --offline --example quickstart`
//! (requires `make artifacts` first)

use std::path::Path;

use nestedfp::runtime::{HostTensor, ModelRuntime};

fn main() -> anyhow::Result<()> {
    let dir = Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("artifacts/ missing — run `make artifacts` first");
        return Ok(());
    }

    println!("== NestedFP quickstart ==");
    let rt = ModelRuntime::load(dir, &["fp16", "nested16", "nested8"], &["decode"])?;
    let m = &rt.manifest.model;
    println!(
        "model: d_model={} layers={} heads={} vocab={} (train loss {:.3})",
        m.d_model,
        m.n_layers,
        m.n_heads,
        m.vocab,
        rt.manifest.final_train_loss.unwrap_or(f64::NAN)
    );
    println!(
        "weight store: {:.2} MiB nested planes (== one fp16 copy) vs {:.2} MiB to co-deploy fp16+fp8 separately",
        rt.weights.nested_plane_bytes() as f64 / (1 << 20) as f64,
        (rt.weights.f16_linear_bytes() + rt.weights.f16_linear_bytes() / 2) as f64
            / (1 << 20) as f64,
    );

    // one decode step, batch 2, empty KV cache
    let b = 2usize;
    let (l, h, s, dh) = (m.n_layers, m.n_heads, m.max_seq, m.head_dim);
    let tokens = HostTensor::from_i32(vec![b], &[b'C' as i32, b'A' as i32]);
    let positions = HostTensor::from_i32(vec![b], &[0, 0]);
    let kv = vec![0f32; b * l * h * s * dh];
    let cache_k = HostTensor::from_f32(vec![b, l, h, s, dh], &kv);
    let cache_v = HostTensor::from_f32(vec![b, l, h, s, dh], &kv);

    let mut logits: Vec<(String, Vec<f32>)> = Vec::new();
    for mode in ["fp16", "nested16", "nested8"] {
        let step = rt.step("decode", mode, b)?;
        let out = rt.run(
            step,
            &[
                tokens.clone(),
                positions.clone(),
                cache_k.clone(),
                cache_v.clone(),
            ],
        )?;
        let lg = out.tensors[0].as_f32()?;
        let argmax = lg[..m.vocab]
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap();
        println!(
            "mode {mode:<9} exec {:>6} us   logits[0][..4] = {:?}   argmax = {argmax} ({:?})",
            out.exec_micros,
            &lg[..4],
            argmax as u8 as char
        );
        logits.push((mode.to_string(), lg));
    }

    // the losslessness claim: fp16 and nested16 agree to f32 round-off
    let a = &logits[0].1;
    let nb = &logits[1].1;
    let max_diff = a
        .iter()
        .zip(nb)
        .map(|(x, y)| (x - y).abs())
        .fold(0f32, f32::max);
    println!("fp16 vs nested16 max |Δlogit| = {max_diff:.2e} (reconstruction is lossless)");
    let c = &logits[2].1;
    let rel = {
        let num: f32 = a.iter().zip(c).map(|(x, y)| (x - y) * (x - y)).sum();
        let den: f32 = a.iter().map(|x| x * x).sum();
        (num / den).sqrt()
    };
    println!("fp16 vs nested8  rel Δ = {rel:.3} (E4M3 quantization noise)");
    Ok(())
}

//! The closed-loop SLO autopilot under an Azure-shaped surge — the
//! multi-replica control study.
//!
//! Replays the window around the day trace's busiest minute (calm
//! lead-in, the 31 → 98 req/s spike downscaled to a two-replica budget,
//! drain) against four arms — static-FP16, static-FP8, local-dual
//! (per-engine reactive control only), and the cluster autopilot — and
//! prints, per arm, goodput / SLO violations / tail latencies, plus for
//! the autopilot arm:
//!
//! * the cluster ladder timeline (severity 0..2N and FP8 pins over time),
//! * each replica's directive timeline (FP16 → Mixed → FP8 and back) and
//!   per-mode dwell, and
//! * how many escalations the surge predictor fired ahead of measured
//!   pressure (the "pre-escalations" that keep the queue from backing up).
//!
//! Run: `cargo run --release --offline --example autopilot_surge
//!       [-- --quick]`

use nestedfp::bench::autopilot::{run_arm, summarize, surge_workload, Arm, SurgeScenario};
use nestedfp::coordinator::precision::{PrecisionDirective, SloConfig};
use nestedfp::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let sc = if args.flag("quick") {
        SurgeScenario::quick()
    } else {
        SurgeScenario::full()
    };
    let slo = SloConfig::default();
    let n_requests = surge_workload(&sc).len();
    println!(
        "== autopilot_surge: {} requests over {}s ({} replicas, lead {}s, the 18:12 spike, drain) ==",
        n_requests, sc.len_s, sc.replicas, sc.lead_s
    );

    for arm in [Arm::StaticFp16, Arm::StaticFp8, Arm::LocalDual, Arm::Autopilot] {
        let mut report = run_arm(arm, &sc)?;
        let s = summarize(&mut report, &slo);
        println!(
            "\n-- {:<11} goodput {:5.2} req/s | viol {:>3}s | TTFT p99 {:6.1} ms | TPOT p99 {:5.1} ms | fp16-time {:>3.0}%",
            arm.name(),
            s.goodput_req_s,
            s.slo_violation_s,
            s.ttft_p99_s * 1e3,
            s.tpot_p99_s * 1e3,
            s.fp16_time_frac * 100.0,
        );
        if arm != Arm::Autopilot {
            continue;
        }
        println!(
            "   ladder: {}",
            if report.ladder_timeline.is_empty() {
                "never engaged".to_string()
            } else {
                report
                    .ladder_timeline
                    .iter()
                    .take(16)
                    .map(|&(t, sev)| format!("{t:.1}s->{sev}"))
                    .collect::<Vec<_>>()
                    .join("  ")
            }
        );
        println!(
            "   {} directive switches, {} predictor pre-escalations",
            s.mode_switches, s.pre_escalations
        );
        for (i, r) in report.replicas.iter().enumerate() {
            let dirs: Vec<String> = r
                .directive_timeline
                .iter()
                .take(10)
                .map(|&(t, d)| {
                    let name = match d {
                        PrecisionDirective::Fp16 => "fp16",
                        PrecisionDirective::Mixed => "mix",
                        PrecisionDirective::Fp8 => "fp8",
                    };
                    format!("{t:.1}s->{name}")
                })
                .collect();
            println!(
                "   replica {i}: {:>3} reqs  dwell fp16/mix/fp8 = {:>4.0}/{:>4.0}/{:>4.0}s  ladder: {}",
                r.routed,
                r.mode_stats.dwell_s[0],
                r.mode_stats.dwell_s[1],
                r.mode_stats.dwell_s[2],
                if dirs.is_empty() {
                    "(pinned fp16 throughout)".to_string()
                } else {
                    dirs.join("  ")
                },
            );
        }
    }
    println!(
        "\nReading the output: static-FP16 pays the surge in SLO violations, \
         static-FP8 pays it in quality all day long. The autopilot pays neither: \
         the predictor pre-arms the fleet to Mixed as the ramp builds, measured \
         pressure pins the least-headroom replica(s) to FP8 for the spike itself, \
         and the hysteresis ladders walk everyone back to the FP16 quality lock \
         as the surge drains — goodput at least FP16's, violations at FP8's level, \
         most replica-time still at full quality."
    );
    Ok(())
}

//! KV pressure under surge — the paged dual-precision cache at work.
//!
//! Part 1 walks the block-level state machine on a tiny cache you can
//! read by hand: allocate → demote (LRU, FP8, half the units) → offload
//! (host tier, latency billed) → fetch → release.
//!
//! Part 2 replays one traffic surge against a single simulated H100 with
//! a deliberately tight device block budget, three times under the same
//! budget:
//!
//! * `dense-f32`   — the seed behavior: full-context reservation, stall
//!                   when blocks run out.
//! * `fp8-demote`  — LRU-cold blocks re-encode to FP8 as utilization
//!                   rises and the precision controller escalates.
//! * `paged+offload` — true paged admission + host tier: preempt-by-
//!                   offload instead of stalling the queue.
//!
//! Watch `admitted_peak`: the same budget holds measurably more
//! concurrent requests once cold KV stores at half the bytes.
//!
//! Run: `cargo run --release --offline --example kv_pressure
//!       [-- --seconds 48 --base 2.0 --blocks 384]`

use nestedfp::bench::kvcache::{run_pressure, variants};
use nestedfp::coordinator::precision::SloConfig;
use nestedfp::kvcache::{KvGeometry, KvPressureConfig, PagedKvCache};
use nestedfp::util::cli::Args;

fn main() -> anyhow::Result<()> {
    // ---- part 1: the state machine, by hand --------------------------
    println!("== part 1: block lifecycle on a 16-block cache ==");
    let geo = KvGeometry {
        n_layers: 2,
        n_heads: 2,
        max_seq: 128,
        head_dim: 4,
        block_size: 8,
        total_blocks: 16,
    };
    let mut kv = PagedKvCache::accounting_only(geo, KvPressureConfig::default());
    let a = kv.allocate(32)?; // 4 prompt blocks + 1 headroom
    kv.grow(a, 32)?;
    let b = kv.allocate(32)?;
    kv.grow(b, 32)?;
    println!(
        "allocated 2 seqs x 5 blocks : free {:>2} blocks, util {:.0}%",
        kv.free_blocks(),
        kv.block_utilization() * 100.0
    );
    kv.set_precision_pressure(true); // the controller escalated to FP8
    let demoted = kv.maintain();
    println!(
        "fp8 pressure -> maintain()  : demoted {demoted} LRU blocks, free {:>2} blocks, util {:.0}%",
        kv.free_blocks(),
        kv.block_utilization() * 100.0
    );
    let dt = kv.offload_sequence(a)?;
    println!(
        "offload seq A to host tier  : {:.0} us billed to the clock, free {:>2} blocks, host {} blocks",
        dt * 1e6,
        kv.free_blocks(),
        kv.host_blocks()
    );
    let dt = kv.fetch_sequence(a)?;
    println!(
        "fetch seq A back            : {:.0} us billed, free {:>2} blocks",
        dt * 1e6,
        kv.free_blocks()
    );
    kv.release(a);
    kv.release(b);
    println!("release both                : free {:>2} blocks\n", kv.free_blocks());

    // ---- part 2: the surge, three policies ---------------------------
    let args = Args::parse(std::env::args().skip(1));
    let seconds = args.get_usize("seconds", 48);
    let base = args.get_f64("base", 2.0);
    let blocks = args.get_usize("blocks", 384);
    let slo = SloConfig::default();
    println!(
        "== part 2: {seconds}s surge at {base} req/s (6x plateau), {blocks}-block budget, llama31-8b sim =="
    );

    for (name, cfg) in variants() {
        let (mut report, st) = run_pressure(cfg, seconds, base, blocks)?;
        let ttft = report.metrics.ttft_summary();
        let tpot = report.metrics.tpot_summary();
        println!(
            "{name:>13}: peak {:>3} resident | {:>3} done | TTFT p90 {:>7.1} ms | TPOT p90 {:>5.1} ms | viol {:>3}s | demoted {:>4} | offloads {:>3} | transfer {:>6.2} ms",
            st.peak_live_seqs,
            report.metrics.completed,
            ttft.p90 * 1e3,
            tpot.p90 * 1e3,
            report.metrics.slo_violation_seconds(&slo),
            st.demoted_blocks,
            st.offload_events,
            st.transfer_seconds * 1e3,
        );
    }

    println!(
        "\nReading the output: all three rows replay the identical workload on the \
         identical block budget. dense-f32 hits the budget wall and queues — its \
         TTFT tail is the stall. fp8-demote stores cold blocks at half the bytes, \
         so the same device admits more concurrent requests (higher peak) and the \
         queue drains sooner. paged+offload additionally swaps whole victims to \
         the host tier instead of stalling admission — capacity beyond the device, \
         paid for in the transfer column, on the virtual clock, not in queueing \
         delay."
    );
    Ok(())
}

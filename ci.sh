#!/usr/bin/env sh
# Tier-1 CI gate: build, test, and doc-lint the crate.
#
# Usage: ./ci.sh
# Runs offline (all dependencies are vendored in rust/vendor/).

set -eu

if ! command -v cargo >/dev/null 2>&1; then
    echo "ci.sh: cargo not found in PATH — install a Rust toolchain first" >&2
    exit 1
fi

echo "== cargo build --release =="
cargo build --release --offline

echo "== cargo test -q =="
cargo test -q --offline

echo "== cargo doc --no-deps (warnings denied) =="
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --offline

echo "== ci.sh: all green =="

#!/usr/bin/env sh
# Tier-1 CI gate: format, lint, build (lib + bin + examples), test, and
# doc-lint the crate.
#
# Usage: ./ci.sh
# Runs offline (all dependencies are vendored in rust/vendor/).
# rustfmt/clippy steps are skipped with a loud warning when the toolchain
# components are not installed, so a bare cargo still gets a full gate.

set -eu

if ! command -v cargo >/dev/null 2>&1; then
    echo "ci.sh: cargo not found in PATH — install a Rust toolchain first" >&2
    exit 1
fi

if cargo fmt --version >/dev/null 2>&1; then
    echo "== cargo fmt --check =="
    cargo fmt --all -- --check
else
    echo "ci.sh: WARNING: rustfmt not installed — skipping cargo fmt --check" >&2
fi

if cargo clippy --version >/dev/null 2>&1; then
    echo "== cargo clippy (warnings denied) =="
    cargo clippy --offline --all-targets -- -D warnings
else
    echo "ci.sh: WARNING: clippy not installed — skipping cargo clippy" >&2
fi

echo "== cargo build --release =="
cargo build --release --offline

echo "== cargo build --release --examples =="
cargo build --release --offline --examples

# The control-layer suites run first, by name, so a behavioral drift
# (golden trace) or stability regression (autopilot props) fails the
# gate with clear attribution; the full `cargo test -q` below includes
# them again at negligible cost (binaries are already built).
echo "== cargo test (control-layer suites: golden trace + autopilot props) =="
cargo test -q --offline --test golden_trace --test autopilot_props

# The attention path's central invariant (block-native == dense-gather
# oracle, bit for bit, across precision mixes / threads / offload
# cycles) runs by name so a divergence fails with clear attribution.
echo "== cargo test (attention suite: block-native vs dense oracle) =="
cargo test -q --offline --test attn_props

# The event core's central invariant (heap driver == lockstep oracle,
# bit for bit, across routing policies and autopilot on/off) runs by
# name so a scheduler divergence fails with clear attribution.
echo "== cargo test (event core: heap driver vs lockstep oracle) =="
cargo test -q --offline --test event_core_props

# The shard layer's invariants (in-flight conservation across reshard
# windows, two-ladder dwell discipline, resharder state-machine safety)
# run by name so a reshard regression fails with clear attribution.
echo "== cargo test (shard layer: reshard + two-ladder invariants) =="
cargo test -q --offline --test shard_props

# The telemetry layer's invariants (byte-identical trace exports across
# reruns and drivers, tracing-on == tracing-off bit identity, balanced
# exports under cap pressure, order-independent registry merge) run by
# name so an observability regression fails with clear attribution.
echo "== cargo test (telemetry: trace determinism + registry merge) =="
cargo test -q --offline --test telemetry_props

# The per-layer morph's invariants (demotion order == sensitivity
# ranking, endpoint bit-identity with the legacy single-mode paths,
# elastic KV watermark monotonicity, fine-ladder dwell discipline) run
# by name so a morph regression fails with clear attribution.
echo "== cargo test (morph: schedule + endpoint bit-identity) =="
cargo test -q --offline --test morph_props

# The host-attention piggybacking invariants (HostTier ledger
# conservation, the resume-headroom anti-thrash margin, host/device
# attention cost laws, piggybacked-pipeline determinism) run by name so
# a tier-placement regression fails with clear attribution.
echo "== cargo test (host tier: ledger + anti-thrash + piggyback) =="
cargo test -q --offline --test host_attn_props

echo "== cargo test -q =="
cargo test -q --offline

echo "== smoke: repro reproduce gemm --quick =="
./target/release/repro reproduce gemm --quick --json /tmp/nestedfp_gemm_ci.json

echo "== smoke: repro reproduce autopilot --quick =="
./target/release/repro reproduce autopilot --quick --json /tmp/nestedfp_autopilot_ci.json

echo "== smoke: repro reproduce parallelism --quick =="
./target/release/repro reproduce parallelism --quick --json /tmp/nestedfp_parallelism_ci.json

echo "== smoke: repro reproduce morph --quick =="
./target/release/repro reproduce morph --quick --json /tmp/nestedfp_morph_ci.json

echo "== smoke: repro reproduce attention --quick =="
./target/release/repro reproduce attention --quick --json /tmp/nestedfp_attention_ci.json

echo "== smoke: repro reproduce kvcache --quick (incl. host-piggyback arm) =="
./target/release/repro reproduce kvcache --quick --json /tmp/nestedfp_kvcache_ci.json

echo "== smoke: repro reproduce cluster --scale --quick =="
./target/release/repro reproduce cluster --scale --quick --json /tmp/nestedfp_cluster_scale_ci.json

echo "== smoke: repro reproduce cluster --quick --trace (Perfetto export) =="
./target/release/repro reproduce cluster --quick --trace /tmp/nestedfp_trace_ci.json

echo "== smoke: repro analyze trace (exported trace validates) =="
./target/release/repro analyze trace /tmp/nestedfp_trace_ci.json

echo "== smoke: example kernel_tour (real engine vs gpusim) =="
cargo run --release --offline --example kernel_tour

echo "== smoke: example autopilot_surge (closed-loop SLO control) =="
cargo run --release --offline --example autopilot_surge -- --quick

echo "== cargo doc --no-deps (warnings denied) =="
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --offline

echo "== ci.sh: all green =="

//! Exhaustive verification of the NestedFP format over the *entire* FP16
//! space — all 65,536 bit patterns. This is stronger than any sampled
//! property test and pins the Rust implementation as the ground truth the
//! Pallas kernel is compared against (python/tests does the same sweep).

use nestedfp::format::{e4m3, fp16::F16, nested};

/// Every eligible FP16 value must decompose and reconstruct to the exact
/// same bit pattern (the paper's losslessness claim).
#[test]
fn exhaustive_lossless_roundtrip() {
    let mut eligible = 0u32;
    for bits in 0..=u16::MAX {
        let h = F16::from_bits(bits);
        if !nested::is_eligible(h) {
            continue;
        }
        eligible += 1;
        let (u, l) = nested::decompose(h);
        let back = nested::reconstruct(u, l);
        assert_eq!(
            back.to_bits(),
            bits,
            "0x{bits:04x} ({}) -> upper=0x{u:02x} lower=0x{l:02x} -> 0x{:04x}",
            h.to_f32(),
            back.to_bits()
        );
    }
    // eligibility covers E<15 fully plus part of E=15, both signs:
    // 2 * (15*1024 + 769) = 32258
    assert_eq!(eligible, 32_258);
}

/// The upper byte must be *exactly* the RNE E4M3 encoding of value*2^8 for
/// every eligible value (the paper's claim that the upper tensor is a
/// high-quality E4M3 representation with a global scale of 2^8).
#[test]
fn exhaustive_upper_matches_direct_e4m3() {
    for bits in 0..=u16::MAX {
        let h = F16::from_bits(bits);
        if !nested::is_eligible(h) {
            continue;
        }
        let (u, _) = nested::decompose(h);
        let direct = e4m3::encode_sat(h.to_f32() * 256.0);
        assert_eq!(
            u, direct,
            "0x{bits:04x} ({}): upper=0x{u:02x} direct=0x{direct:02x}",
            h.to_f32()
        );
    }
}

/// The upper byte must never be the E4M3 NaN pattern (S.1111.111) — this
/// is exactly what the 1.75 eligibility threshold guarantees.
#[test]
fn exhaustive_upper_never_nan() {
    for bits in 0..=u16::MAX {
        let h = F16::from_bits(bits);
        if !nested::is_eligible(h) {
            continue;
        }
        let (u, _) = nested::decompose(h);
        assert_ne!(u & 0x7F, 0x7F, "0x{bits:04x} produced NaN upper");
    }
}

/// FP8-path semantics: decoding the upper byte with the 2^-8 scale must
/// land within half an E4M3 ulp of the original value.
#[test]
fn exhaustive_fp8_weight_error_bound() {
    for bits in 0..=u16::MAX {
        let h = F16::from_bits(bits);
        if !nested::is_eligible(h) {
            continue;
        }
        let (u, _) = nested::decompose(h);
        let w8 = nested::upper_as_weight(u);
        let w16 = h.to_f32();
        if w16 == 0.0 {
            assert_eq!(w8, 0.0, "0x{bits:04x}");
            continue;
        }
        // E4M3 has a 3-bit mantissa: relative error <= 2^-4 for values in
        // the normal range of the scaled representation; subnormal tail is
        // bounded by the absolute quantum 2^-9 * 2^-8 = 2^-17.
        let rel = ((w8 - w16) / w16).abs();
        let abs = (w8 - w16).abs();
        assert!(
            rel <= 1.0 / 16.0 + 1e-6 || abs <= f32::powi(2.0, -17),
            "0x{bits:04x}: w16={w16} w8={w8} rel={rel} abs={abs}"
        );
    }
}

/// Ineligible values must be exactly the complement: |v| > 1.75, NaN, Inf.
#[test]
fn exhaustive_eligibility_rule() {
    for bits in 0..=u16::MAX {
        let h = F16::from_bits(bits);
        let v = h.to_f32();
        let expected = v.is_finite() && v.abs() <= 1.75;
        assert_eq!(
            nested::is_eligible(h),
            expected,
            "0x{bits:04x} ({v}): eligibility mismatch"
        );
    }
}

/// Checksum semantics: upper LSB == lower MSB exactly when rounding did
/// not add one (Fig 6's detection rule).
#[test]
fn exhaustive_checksum_rule() {
    for bits in 0..=u16::MAX {
        let h = F16::from_bits(bits);
        if !nested::is_eligible(h) {
            continue;
        }
        let (u, l) = nested::decompose(h);
        let m3 = (l >> 7) & 1;
        let m3p = u & 1;
        let rem = (bits & 0x7F) as u8;
        let base = ((bits >> 7) & 0x7F) as u8;
        let rounded_up = rem > 64 || (rem == 64 && base & 1 == 1);
        assert_eq!(
            m3 != m3p,
            rounded_up,
            "0x{bits:04x}: checksum vs rounding disagree"
        );
    }
}

//! Integration tests over the real AOT artifacts + PJRT runtime.
//!
//! These need `make artifacts` to have run; they skip (with a notice)
//! when artifacts/ is absent so `cargo test` stays green pre-build.

use std::path::{Path, PathBuf};

use nestedfp::coordinator::backend::{ModeMap, RealBackend};
use nestedfp::coordinator::engine::{Engine, EngineConfig};
use nestedfp::coordinator::precision::PrecisionPolicy;
use nestedfp::coordinator::request::Request;
use nestedfp::eval::tasks;
use nestedfp::format::nested;
use nestedfp::runtime::{HostTensor, ModelRuntime, WeightStore};

fn artifacts() -> Option<PathBuf> {
    let dir = Path::new("artifacts");
    if dir.join("manifest.json").exists() && dir.join("weights.bin").exists() {
        Some(dir.to_path_buf())
    } else {
        eprintln!("[skip] artifacts/ missing — run `make artifacts`");
        None
    }
}

#[test]
fn weight_store_planes_reconstruct_masters() {
    let Some(dir) = artifacts() else { return };
    let ws = WeightStore::load(&dir.join("weights.bin")).unwrap();
    let mut checked = 0;
    for (name, t) in &ws.tensors {
        let Some(base) = name.strip_suffix(".upper") else {
            continue;
        };
        let lower = &ws.tensors[&format!("{base}.lower")];
        let master = ws.tensors[&format!("{base}.f16")].as_u16().unwrap();
        for ((&u, &l), &m) in t.bytes.iter().zip(&lower.bytes).zip(&master) {
            assert_eq!(
                nested::reconstruct(u, l).to_bits(),
                m,
                "{base}: plane reconstruction mismatch"
            );
        }
        checked += 1;
    }
    assert!(checked >= 28, "only {checked} nested tensors checked");
}

#[test]
fn memory_footprint_matches_paper_claim() {
    let Some(dir) = artifacts() else { return };
    let ws = WeightStore::load(&dir.join("weights.bin")).unwrap();
    // the nested planes must cost exactly the same bytes as the fp16
    // masters of the same layers (the zero-overhead claim)
    let nested_bytes = ws.nested_plane_bytes();
    let f16_bytes = ws.f16_linear_bytes();
    assert_eq!(nested_bytes, f16_bytes);
}

#[test]
fn decode_modes_agree_like_the_paper_says() {
    let Some(dir) = artifacts() else { return };
    let rt = ModelRuntime::load(&dir, &["fp16", "nested16", "nested8", "fp8base"], &["decode"])
        .unwrap();
    let m = &rt.manifest.model;
    let b = 1usize;
    let dims = vec![b, m.n_layers, m.n_heads, m.max_seq, m.head_dim];
    let kv = vec![0f32; dims.iter().product()];
    let inputs = [
        HostTensor::from_i32(vec![b], &[b'A' as i32]),
        HostTensor::from_i32(vec![b], &[0]),
        HostTensor::from_f32(dims.clone(), &kv),
        HostTensor::from_f32(dims, &kv),
    ];
    let logits_of = |mode: &str| -> Vec<f32> {
        let step = rt.step("decode", mode, b).unwrap();
        rt.run(step, &inputs).unwrap().tensors[0].as_f32().unwrap()
    };
    let fp16 = logits_of("fp16");
    let n16 = logits_of("nested16");
    let n8 = logits_of("nested8");
    let b8 = logits_of("fp8base");

    let rel = |a: &[f32], b: &[f32]| -> f64 {
        let num: f64 = a.iter().zip(b).map(|(x, y)| ((x - y) as f64).powi(2)).sum();
        let den: f64 = b.iter().map(|y| (*y as f64).powi(2)).sum();
        (num / den).sqrt()
    };
    // losslessness: nested16 == fp16 up to f32 reassociation noise
    assert!(rel(&n16, &fp16) < 1e-5, "nested16 vs fp16: {}", rel(&n16, &fp16));
    // fp8 variants: close to fp16, close to each other (Tables 1-2)
    assert!(rel(&n8, &fp16) < 0.05, "nested8 vs fp16: {}", rel(&n8, &fp16));
    assert!(rel(&b8, &fp16) < 0.05, "fp8base vs fp16: {}", rel(&b8, &fp16));
    assert!(rel(&n8, &b8) < 0.05, "nested8 vs fp8base: {}", rel(&n8, &b8));
}

#[test]
fn engine_end_to_end_generates_correct_answers() {
    let Some(dir) = artifacts() else { return };
    let rt = ModelRuntime::load(&dir, &["nested16", "nested8"], &["decode", "prefill"]).unwrap();
    let align = rt.manifest.prefill_chunks.iter().copied().min().unwrap();
    let max_seq = rt.manifest.model.max_seq;
    let max_batch = rt.manifest.decode_buckets.iter().copied().max().unwrap();
    let backend = RealBackend::new(rt, ModeMap::default(), max_batch * (max_seq / 16 + 1) + 32);
    let mut engine = Engine::new(
        backend,
        EngineConfig {
            policy: PrecisionPolicy::Fp16Only,
            physical_kv: true,
            ..Default::default()
        },
    );

    // four concurrent copy-task requests: the trained model should ace
    // copy; correctness here proves prefill->decode KV handoff + batching
    let mut requests = Vec::new();
    let mut answers = Vec::new();
    let mut rng = nestedfp::util::rng::Pcg64::seeded(777);
    for i in 0..4u64 {
        let (p, a) = tasks::gen_example(&mut rng, tasks::Task::Copy);
        let toks = tasks::chunk_aligned_prompt(&p, align, 50 + i);
        requests.push(Request::new(i, toks, a.len() + 2, 0.0).with_stop(b';' as i32));
        answers.push(a);
    }
    let report = engine.run(requests).unwrap();
    assert_eq!(report.metrics.completed, 4);
    let mut correct = 0;
    for c in &report.completions {
        let text: String = c.tokens.iter().map(|&t| (t as u8) as char).collect();
        // every token must be a plausible byte and the request must have
        // produced output; exact-match accuracy depends on how long the
        // checkpoint trained and is *reported*, not asserted
        assert!(!c.tokens.is_empty(), "request {} produced nothing", c.id);
        assert!(c.tokens.iter().all(|&t| (0..256).contains(&t)));
        if text == answers[c.id as usize] {
            correct += 1;
        }
    }
    eprintln!("[info] copy-task exact-match: {correct}/4 (checkpoint-dependent)");
}

#[test]
fn gemm_artifacts_match_native_engine() {
    let Some(dir) = artifacts() else { return };
    use nestedfp::format::fp16::F16;
    use nestedfp::format::tensor::Tensor2;
    let rt = ModelRuntime::load(&dir, &["nested16", "nested8"], &["gemm"]).unwrap();
    let backend = RealBackend::new(rt, ModeMap::default(), 64);
    let (m, n, k) = (32usize, 256usize, 256usize);
    let x16: Vec<u16> = (0..m * k)
        .map(|i| F16::from_f32(((i % 17) as f32 - 8.0) * 0.1).to_bits())
        .collect();
    let xr = Tensor2::from_vec(
        m,
        k,
        x16.iter().map(|&b| F16::from_bits(b).to_f32()).collect(),
    );
    let upper = backend.rt.weights.get("layers.0.wq.upper").unwrap().bytes.clone();
    let lower = backend.rt.weights.get("layers.0.wq.lower").unwrap().bytes.clone();
    let step = backend.rt.step("gemm", "nested16", n).unwrap();
    let out = backend
        .rt
        .run(
            step,
            &[
                HostTensor::from_u16(vec![m, k], &x16),
                HostTensor::from_u8(vec![n, k], upper),
                HostTensor::from_u8(vec![n, k], lower),
            ],
        )
        .unwrap();
    let got = out.tensors[0].as_f32().unwrap();

    // rust reference: the host compute engine over the same store (the
    // fused-pack path, bit-identical to reconstruct + naive matmul)
    let expect = backend.native_gemm("nested16", "layers.0.wq", &xr).unwrap();
    for i in (0..m).step_by(7) {
        for j in (0..n).step_by(31) {
            let (acc, g) = (expect.get(i, j), got[i * n + j]);
            assert!(
                (acc - g).abs() <= 1e-3 * acc.abs().max(1.0),
                "({i},{j}): engine {acc} vs artifact {g}"
            );
        }
    }

    // the nested16 host path must equal the fp16 host path bit-for-bit
    // (losslessness at the product level)
    let native16 = backend.native_gemm("fp16", "layers.0.wq", &xr).unwrap();
    assert!(
        expect
            .data
            .iter()
            .zip(&native16.data)
            .all(|(a, b)| a.to_bits() == b.to_bits()),
        "nested16 and fp16 native products must be bit-identical"
    );
}

//! Property sweeps over the H100 cost model: orderings and bounds that
//! must hold for every shape, format, and config.

use nestedfp::gpusim::gemm::{gemm_latency, GemmQuery, WeightFormat};
use nestedfp::gpusim::kernel::OptLevel;
use nestedfp::gpusim::{best_config, best_latency, config_space};
use nestedfp::model::zoo;
use nestedfp::util::prop;
use nestedfp::util::rng::Pcg64;

fn rand_query(rng: &mut Pcg64) -> GemmQuery {
    let fmts = [
        WeightFormat::Fp16,
        WeightFormat::Nested16,
        WeightFormat::Nested8,
        WeightFormat::Fp8,
    ];
    GemmQuery {
        m: rng.range_u64(1, 65) as usize * 32,
        n: rng.range_u64(8, 257) as usize * 16,
        k: rng.range_u64(8, 257) as usize * 16,
        format: fmts[rng.index(4)],
        opt: OptLevel::Level3,
    }
}

#[test]
fn prop_latency_positive_and_roofline_bounded() {
    prop::check_res(
        "roofline-bound",
        300,
        rand_query,
        |q| {
            let t = best_latency(q);
            if !(t > 0.0) {
                return Err(format!("nonpositive latency {t}"));
            }
            // no configuration may beat the ideal roofline
            let flops = 2.0 * (q.m * q.n * q.k) as f64;
            let t_ideal_compute = flops / q.format.flops();
            let bytes =
                (q.n * q.k) as f64 * q.format.weight_bytes() + (q.m * q.k) as f64 * 2.0;
            let t_ideal_mem = bytes / 3.35e12;
            let floor = t_ideal_compute.max(t_ideal_mem);
            if t < floor {
                return Err(format!("latency {t} beats roofline {floor} for {q:?}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_format_ordering_holds_everywhere() {
    prop::check_res(
        "format-ordering",
        150,
        |rng: &mut Pcg64| {
            (
                rng.range_u64(1, 65) as usize * 32,
                rng.range_u64(16, 129) as usize * 32,
                rng.range_u64(16, 129) as usize * 32,
            )
        },
        |&(m, n, k)| {
            let t = |format| {
                best_latency(&GemmQuery {
                    m,
                    n,
                    k,
                    format,
                    opt: OptLevel::Level3,
                })
            };
            let fp16 = t(WeightFormat::Fp16);
            let n16 = t(WeightFormat::Nested16);
            let n8 = t(WeightFormat::Nested8);
            let fp8 = t(WeightFormat::Fp8);
            if n16 < fp16 - 1e-12 {
                return Err(format!("nested16 {n16} beats fp16 {fp16} at ({m},{n},{k})"));
            }
            if n8 < fp8 - 1e-12 {
                return Err(format!("nested8 {n8} beats fp8 {fp8}"));
            }
            if fp8 > fp16 + 1e-12 {
                return Err(format!("fp8 {fp8} slower than fp16 {fp16}"));
            }
            // nested16 overhead must stay within a sane band after tuning
            if n16 / fp16 > 1.25 {
                return Err(format!(
                    "tuned nested16 overhead {:.1}% at ({m},{n},{k})",
                    (n16 / fp16 - 1.0) * 100.0
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_opt_levels_monotone_everywhere() {
    prop::check_res(
        "opt-monotone",
        150,
        |rng: &mut Pcg64| {
            (
                rng.range_u64(1, 65) as usize * 32,
                rng.range_u64(16, 129) as usize * 32,
                rng.range_u64(16, 129) as usize * 32,
            )
        },
        |&(m, n, k)| {
            let t = |opt| {
                best_latency(&GemmQuery {
                    m,
                    n,
                    k,
                    format: WeightFormat::Nested16,
                    opt,
                })
            };
            let l1 = t(OptLevel::Level1);
            let l2 = t(OptLevel::Level2);
            let l3 = t(OptLevel::Level3);
            if !(l1 >= l2 && l2 >= l3) {
                return Err(format!("levels not monotone: {l1} {l2} {l3} at ({m},{n},{k})"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_best_config_is_argmin_of_space() {
    prop::check_res(
        "search-argmin",
        40,
        rand_query,
        |q| {
            let (best, t_best) = best_config(q).ok_or("no feasible config")?;
            for cfg in config_space() {
                if let Some(t) = gemm_latency(q, &cfg) {
                    if t < t_best - 1e-15 {
                        return Err(format!(
                            "search missed {}: {t} < {t_best} (picked {})",
                            cfg.name(),
                            best.name()
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn zoo_step_latency_scales_with_model_size() {
    use nestedfp::gpusim::{step_latency, StepKind, StepQuery};
    let q = StepQuery {
        kind: StepKind::Decode,
        m: 64,
        ctx: 512,
        seqs: 64,
        format: WeightFormat::Fp16,
        opt: OptLevel::Level3,
    };
    let mut prev = 0.0;
    for name in ["llama31-8b", "mistral-nemo-12b", "mistral-small-24b"] {
        let spec = zoo::find(name).unwrap();
        let t = step_latency(spec, &q);
        assert!(t > prev, "{name}: {t} !> {prev}");
        prev = t;
    }
}

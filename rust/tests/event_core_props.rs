//! Event-core equivalence and property suite.
//!
//! The cluster's discrete-event driver (`event_core::drive`, a binary
//! min-heap) replaced the lockstep iteration loop, which survives only
//! as the test oracle (`event_core::drive_lockstep`, a naive O(n) scan
//! per event with the identical dispatch law). This suite pins the two
//! against each other **bit-for-bit** on full cluster scenarios — every
//! routing policy, autopilot on and off — and checks the event queue's
//! own laws (clock monotonicity, deterministic tie-breaking, idle
//! components cost nothing) plus the control-tick cadence fix and
//! `Metrics::merge` pooling at fleet scale.

use anyhow::Result;

use nestedfp::bench::autopilot::{arm_cluster, run_arm, surge_workload, Arm, SurgeScenario};
use nestedfp::bench::cluster::{run_scale, ScaleScenario};
use nestedfp::coordinator::autopilot::AutopilotConfig;
use nestedfp::coordinator::backend::SimBackend;
use nestedfp::coordinator::cluster::{ClusterConfig, ClusterReport, ClusterRouter, SurgeConfig};
use nestedfp::coordinator::engine::EngineConfig;
use nestedfp::coordinator::event_core::{drive, drive_lockstep, Component, ComponentId, Waker};
use nestedfp::coordinator::metrics::Metrics;
use nestedfp::coordinator::precision::{PrecisionPolicy, SloConfig};
use nestedfp::coordinator::request::{FinishReason, Request, RequestState};
use nestedfp::coordinator::router::RoutingPolicy;
use nestedfp::gpusim::WeightFormat;
use nestedfp::kvcache::KvPressureConfig;
use nestedfp::model::zoo;
use nestedfp::util::rng::Pcg64;

// ---------------------------------------------------------------------
// Fingerprinting: every observable of a cluster run, with f64s encoded
// as raw bits so "equal" means bit-for-bit, not approximately.
// ---------------------------------------------------------------------

fn fingerprint(r: &ClusterReport) -> String {
    use std::fmt::Write as _;
    let mut s = String::new();
    for c in &r.completions {
        writeln!(
            s,
            "c {} {} {:016x} {:016x}",
            c.id,
            c.tokens.len(),
            c.ttft_s.to_bits(),
            c.mean_tpot_s.to_bits()
        )
        .unwrap();
    }
    for (i, rep) in r.replicas.iter().enumerate() {
        writeln!(
            s,
            "r{i} routed={} iters={} fp16={} fp8={} free={} host={} total={} tp={}",
            rep.routed,
            rep.iterations,
            rep.controller.iters_fp16,
            rep.controller.iters_fp8,
            rep.final_free_kv_blocks,
            rep.final_host_kv_blocks,
            rep.total_kv_blocks,
            rep.final_tp_degree
        )
        .unwrap();
        for &(t, fp8) in &rep.mode_timeline {
            writeln!(s, "  m {:016x} {fp8}", t.to_bits()).unwrap();
        }
        for &(t, d) in &rep.directive_timeline {
            writeln!(s, "  d {:016x} {d:?}", t.to_bits()).unwrap();
        }
    }
    for &(t, k) in &r.demotion_timeline {
        writeln!(s, "dem {:016x} {k}", t.to_bits()).unwrap();
    }
    for &(t, k) in &r.ladder_timeline {
        writeln!(s, "lad {:016x} {k}", t.to_bits()).unwrap();
    }
    writeln!(s, "pre {}", r.pre_escalations).unwrap();
    for &t in &r.control_ticks {
        writeln!(s, "ct {:016x}", t.to_bits()).unwrap();
    }
    for &(t, i, tp) in &r.reshard_timeline {
        writeln!(s, "rs {:016x} {i} {tp}", t.to_bits()).unwrap();
    }
    // queue.stale is intentionally excluded: the heap counts lazily
    // deleted entries, the scan oracle has none. popped and scheduled
    // must agree.
    let e = &r.events;
    writeln!(
        s,
        "ev a={} c={} p={} s={} w={} i={} rs={} popped={} scheduled={}",
        e.arrival_events,
        e.control_events,
        e.predictor_events,
        e.replica_step_events,
        e.replica_blocked_wakes,
        e.idle_replica_events,
        e.reshard_events,
        e.queue.popped,
        e.queue.scheduled
    )
    .unwrap();
    writeln!(
        s,
        "agg completed={} out={} ttft_n={} tpot_n={} reshards={} repart={:016x} t0={:016x} t1={:016x}",
        r.aggregate.completed,
        r.aggregate.total_output_tokens,
        r.aggregate.ttft.len(),
        r.aggregate.tpot.len(),
        r.aggregate.reshards,
        r.aggregate.reshard_repartition_s.to_bits(),
        r.aggregate.t_start.to_bits(),
        r.aggregate.t_end.to_bits()
    )
    .unwrap();
    s
}

// ---------------------------------------------------------------------
// Cluster construction mirroring bench::autopilot::arm_cluster, but
// parameterized over the routing policy and autopilot switch so the
// equivalence matrix covers all four policies both with the autopilot's
// ladder and with the reactive staged-escalation path.
// ---------------------------------------------------------------------

fn policy_cluster(
    policy: RoutingPolicy,
    autopilot: bool,
    sc: &SurgeScenario,
) -> ClusterRouter<SimBackend> {
    let spec = zoo::find("llama31-8b").expect("llama31-8b in the zoo");
    let max_seq = 1024;
    let backends: Vec<SimBackend> = (0..sc.replicas)
        .map(|_| {
            SimBackend::new(
                spec,
                WeightFormat::Nested16,
                WeightFormat::Nested8,
                64,
                max_seq,
                64 * (max_seq / 16 + 1) * 2,
            )
        })
        .collect();
    let cfg = ClusterConfig {
        policy,
        engine: EngineConfig {
            policy: PrecisionPolicy::Dual,
            slo: SloConfig::default(),
            physical_kv: false,
            max_iterations: 0,
            kv: KvPressureConfig::default(),
            devices: 1,
        },
        // autopilot off exercises the reactive staged-escalation control
        // path instead (finite queue_per_stage keeps the loop armed)
        surge: if autopilot {
            SurgeConfig::disabled()
        } else {
            SurgeConfig::default()
        },
        autopilot: autopilot.then(AutopilotConfig::default),
        ..ClusterConfig::default()
    };
    ClusterRouter::new(backends, cfg)
}

/// Small-but-busy scenario for the 4-policy × autopilot-on/off matrix
/// (16 full cluster runs — kept below the golden scenario's budget).
fn matrix_scenario() -> SurgeScenario {
    SurgeScenario {
        lead_s: 10,
        len_s: 30,
        scale: 0.12,
        ..SurgeScenario::golden()
    }
}

#[test]
fn event_driver_matches_lockstep_oracle_across_policies() -> Result<()> {
    let sc = matrix_scenario();
    let policies = [
        RoutingPolicy::RoundRobin,
        RoutingPolicy::Random { seed: 7 },
        RoutingPolicy::LeastLoadedKv,
        RoutingPolicy::SloHeadroom,
    ];
    for policy in policies {
        for autopilot in [false, true] {
            let heap = policy_cluster(policy, autopilot, &sc).run(surge_workload(&sc))?;
            let scan =
                policy_cluster(policy, autopilot, &sc).run_lockstep(surge_workload(&sc))?;
            assert!(
                heap.aggregate.completed > 0,
                "{policy:?}/autopilot={autopilot}: scenario produced no completions"
            );
            assert_eq!(
                fingerprint(&heap),
                fingerprint(&scan),
                "{policy:?}/autopilot={autopilot}: heap driver diverged from lockstep oracle"
            );
        }
    }
    Ok(())
}

/// The bench entry point (`run_arm`) rides the heap driver; pin it to
/// the oracle on the exact golden-trace scenario the snapshot suite
/// replays.
#[test]
fn golden_autopilot_arm_matches_lockstep_oracle() -> Result<()> {
    let sc = SurgeScenario::golden();
    let heap = run_arm(Arm::Autopilot, &sc)?;
    let scan = arm_cluster(Arm::Autopilot, &sc).run_lockstep(surge_workload(&sc))?;
    assert!(heap.aggregate.completed > 0);
    assert_eq!(fingerprint(&heap), fingerprint(&scan));
    Ok(())
}

// ---------------------------------------------------------------------
// Event-queue property tests on toy components: monotone clock,
// deterministic tie-break under shuffled insertion, idle no-op, and
// heap-vs-scan parity under seeded fuzz.
// ---------------------------------------------------------------------

/// Fires at a fixed list of (sorted, possibly duplicated) times,
/// appending `(time, id)` to the shared log.
struct Ticker {
    id: ComponentId,
    times: Vec<f64>,
    next: usize,
}

type Log = Vec<(f64, ComponentId)>;

impl Component<Log> for Ticker {
    fn next_tick(&self, _sys: &Log) -> Option<f64> {
        self.times.first().copied()
    }
    fn tick(&mut self, now: f64, sys: &mut Log, _wake: &mut Waker) -> Result<Option<f64>> {
        sys.push((now, self.id));
        self.next += 1;
        Ok(self.times.get(self.next).copied())
    }
}

fn tickers(spec: &[Vec<f64>]) -> Vec<Box<dyn Component<Log>>> {
    spec.iter()
        .enumerate()
        .map(|(id, times)| {
            Box::new(Ticker {
                id,
                times: times.clone(),
                next: 0,
            }) as Box<dyn Component<Log>>
        })
        .collect()
}

#[test]
fn pops_are_monotone_and_ties_break_by_id_under_shuffled_insertion() {
    use nestedfp::coordinator::event_core::EventQueue;
    // ids 0..6 all competing, with a 4-way tie at t=2.0; insertion order
    // must not matter, so shuffle it under several seeds.
    let events: Vec<(ComponentId, f64)> =
        vec![(0, 2.0), (1, 2.0), (2, 9.0), (3, 2.0), (4, 0.5), (5, 2.0)];
    let mut reference: Option<Vec<(f64, ComponentId)>> = None;
    for seed in 0..16u64 {
        let mut order = events.clone();
        Pcg64::seeded(seed).shuffle(&mut order);
        let mut q = EventQueue::new(events.len());
        for &(id, at) in &order {
            q.schedule(id, at);
        }
        let mut popped = Vec::new();
        let mut last = f64::NEG_INFINITY;
        while let Some((at, id)) = q.pop_next() {
            assert!(at >= last, "clock went backwards: {at} after {last}");
            if at == last {
                let prev = popped.last().map(|&(_, p)| p).unwrap();
                assert!(id > prev, "tie at t={at} not broken by ascending id");
            }
            last = at;
            popped.push((at, id));
        }
        assert_eq!(
            popped,
            vec![(0.5, 4), (2.0, 0), (2.0, 1), (2.0, 3), (2.0, 5), (9.0, 2)]
        );
        match &reference {
            None => reference = Some(popped),
            Some(r) => assert_eq!(&popped, r, "seed {seed} changed the pop order"),
        }
    }
}

#[test]
fn scheduling_before_the_popped_clock_panics_with_time_travel() {
    use nestedfp::coordinator::event_core::EventQueue;
    let err = std::panic::catch_unwind(|| {
        let mut q = EventQueue::new(2);
        q.schedule(0, 5.0);
        q.pop_next();
        q.schedule(1, 1.0); // the clock already reached 5.0
    })
    .expect_err("scheduling the past must panic");
    let msg = err
        .downcast_ref::<String>()
        .cloned()
        .or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()))
        .unwrap_or_default();
    assert!(msg.contains("time travel"), "unexpected panic: {msg}");
}

#[test]
fn idle_components_receive_no_ticks_in_either_driver() {
    // components 1 and 3 have empty schedules: they must never appear in
    // the log, and the busy components' histories must be unaffected.
    let spec: Vec<Vec<f64>> = vec![
        vec![0.0, 1.0, 2.0],
        vec![],
        vec![0.5, 1.0],
        vec![],
        vec![3.0],
    ];
    let mut log_heap = Log::new();
    let heap = drive(&mut tickers(&spec), &mut log_heap).unwrap();
    let mut log_scan = Log::new();
    let scan = drive_lockstep(&mut tickers(&spec), &mut log_scan).unwrap();
    assert_eq!(log_heap, log_scan);
    assert!(
        !log_heap.iter().any(|&(_, id)| id == 1 || id == 3),
        "idle components were ticked: {log_heap:?}"
    );
    assert_eq!(log_heap.len(), 6);
    assert_eq!(heap.popped, 6);
    assert_eq!(heap.popped, scan.popped);
    assert_eq!(heap.scheduled, scan.scheduled);
}

#[test]
fn drivers_agree_on_seeded_random_schedules() {
    for seed in 0..12u64 {
        let mut rng = Pcg64::new(seed, 4242);
        // 2..=9 components, each with 0..8 tick times on a coarse grid so
        // cross-component ties are common.
        let n = 2 + rng.index(8);
        let spec: Vec<Vec<f64>> = (0..n)
            .map(|_| {
                let k = rng.index(8);
                let mut times: Vec<f64> = (0..k).map(|_| rng.index(20) as f64 * 0.5).collect();
                times.sort_by(f64::total_cmp);
                times
            })
            .collect();
        let mut log_heap = Log::new();
        let heap = drive(&mut tickers(&spec), &mut log_heap).unwrap();
        let mut log_scan = Log::new();
        let scan = drive_lockstep(&mut tickers(&spec), &mut log_scan).unwrap();
        let total: usize = spec.iter().map(Vec::len).sum();
        assert_eq!(log_heap.len(), total, "seed {seed}: ticks lost");
        assert_eq!(log_heap, log_scan, "seed {seed}: drivers diverged");
        assert_eq!(heap.popped, scan.popped, "seed {seed}");
        assert_eq!(heap.scheduled, scan.scheduled, "seed {seed}");
    }
}

// ---------------------------------------------------------------------
// Control-tick cadence: the skew fix. Ticks land exactly on the 0.25 s
// grid anchored at the first arrival, even across arrival droughts where
// no replica event falls on the tick instant.
// ---------------------------------------------------------------------

#[test]
fn control_ticks_keep_exact_cadence_across_sparse_arrivals() -> Result<()> {
    let spec = zoo::find("llama31-8b").expect("llama31-8b in the zoo");
    let backends: Vec<SimBackend> = (0..2)
        .map(|_| {
            SimBackend::new(
                spec,
                WeightFormat::Nested16,
                WeightFormat::Nested8,
                8,
                64,
                80,
            )
        })
        .collect();
    let cfg = ClusterConfig {
        policy: RoutingPolicy::SloHeadroom,
        engine: EngineConfig {
            policy: PrecisionPolicy::Dual,
            slo: SloConfig::default(),
            physical_kv: false,
            max_iterations: 0,
            kv: KvPressureConfig::default(),
            devices: 1,
        },
        surge: SurgeConfig::disabled(),
        autopilot: Some(AutopilotConfig::default()),
        ..ClusterConfig::default()
    };
    let mut cluster = ClusterRouter::new(backends, cfg);
    // two tiny requests separated by a 6 s drought: the first drains in
    // well under a second, so the old skewed loop (control piggybacked on
    // replica events) had nothing to tick on until t=6.
    let workload = vec![
        Request::new(0, vec![65; 16], 8, 0.0),
        Request::new(1, vec![65; 16], 8, 6.0),
    ];
    let report = cluster.run(workload)?;
    assert_eq!(report.aggregate.completed, 2);
    let ticks = &report.control_ticks;
    assert!(!ticks.is_empty());
    assert_eq!(
        ticks[0].to_bits(),
        0.0f64.to_bits(),
        "first control tick must land on the first arrival"
    );
    for w in ticks.windows(2) {
        // 0.25 = 2^-2: every tick k*0.25 is exact in f64, so the cadence
        // check is bit-exact, not approximate.
        assert_eq!(
            (w[1] - w[0]).to_bits(),
            0.25f64.to_bits(),
            "control cadence skewed between {} and {}",
            w[0],
            w[1]
        );
    }
    assert!(
        *ticks.last().unwrap() >= 6.0,
        "control stopped before the late arrival: last tick {}",
        ticks.last().unwrap()
    );
    assert!(
        ticks.len() >= 25,
        "control slept through the drought: only {} ticks",
        ticks.len()
    );
    assert_eq!(report.events.control_events, ticks.len());
    Ok(())
}

// ---------------------------------------------------------------------
// Metrics::merge at fleet scale: pooled samples, not averaged summaries.
// ---------------------------------------------------------------------

fn finished_request(arrival: f64, first: f64, done: f64, n_out: usize) -> Request {
    let mut r = Request::new(1, vec![1, 2], 64, arrival);
    r.state = RequestState::Finished;
    r.prefilled = 2;
    r.generated = vec![0; n_out];
    r.first_token_at = Some(first);
    r.finished_at = Some(done);
    r.finish_reason = Some(FinishReason::Length);
    r
}

#[test]
fn merge_pools_percentiles_across_100_replicas() {
    // 99 healthy replicas (10 ms TTFT) and one straggler (400 ms). The
    // pooled p99 must sit in the straggler's tail; averaging per-replica
    // p99s would report ~14 ms and hide it.
    let mut merged = Metrics::new();
    for i in 0..100 {
        let ttft = if i == 99 { 0.400 } else { 0.010 };
        let mut m = Metrics::new();
        m.record_request(&finished_request(0.0, ttft, ttft + 0.5, 8));
        merged.merge(&m);
    }
    assert_eq!(merged.completed, 100);
    assert_eq!(merged.ttft.len(), 100, "digests must pool samples");
    let p99 = merged.ttft.percentile(99.0);
    assert!(
        p99 > 0.2,
        "pooled p99 must reach the straggler's tail, got {p99}"
    );
    assert!(merged.ttft.percentile(50.0) < 0.02);
}

// ---------------------------------------------------------------------
// The ≥100-replica scale path: full drain, zero idle-replica events,
// KV conservation on every replica, pooled aggregate digests.
// ---------------------------------------------------------------------

#[test]
fn scale_run_drains_100_replicas_without_leaks_or_idle_events() -> Result<()> {
    let sc = ScaleScenario {
        replicas: 100,
        len_s: 180,
        scale: 0.3,
        ..ScaleScenario::full()
    };
    let (report, n_requests) = run_scale(&sc)?;
    assert!(n_requests > 1_000, "scenario too thin: {n_requests}");
    assert_eq!(report.replicas.len(), 100);
    assert_eq!(report.aggregate.completed, n_requests, "requests lost");
    assert_eq!(
        report.events.idle_replica_events, 0,
        "idle replicas must cost zero events"
    );
    let mut pooled_ttft = 0usize;
    for (i, rep) in report.replicas.iter().enumerate() {
        assert_eq!(
            rep.final_free_kv_blocks, rep.total_kv_blocks,
            "replica {i} leaked KV blocks"
        );
        assert_eq!(rep.final_host_kv_blocks, 0, "replica {i} left host KV");
        pooled_ttft += rep.metrics.ttft.len();
    }
    assert_eq!(
        report.aggregate.ttft.len(),
        pooled_ttft,
        "aggregate digest must pool every replica's samples"
    );
    let e = &report.events;
    assert_eq!(
        e.queue.popped as usize,
        e.arrival_events
            + e.control_events
            + e.predictor_events
            + e.replica_step_events
            + e.idle_replica_events
            + e.reshard_events,
        "event accounting identity broken"
    );
    Ok(())
}

//! Property tests for the block-native attention path (PR 5).
//!
//! Part 1 — the central invariant: for ANY cache state (ragged lengths,
//! f32 / FP8-demoted / mixed block tables, offloaded-then-resumed
//! sequences) and ANY worker count, the block-native engine is
//! bit-identical to the dense-gather oracle.
//!
//! Part 2 (`host_backend`, non-`pjrt` builds) — the rewired
//! `RealBackend` end to end over a synthesized tiny artifact store:
//! the empty-decode-batch regression, pad-lane-free batching, and a
//! full `Engine::run` on the host-native step path.

use nestedfp::attn::{attend_dense, AttnEngine, AttnLane};
use nestedfp::kvcache::{KvGeometry, KvPressureConfig, PagedKvCache};
use nestedfp::util::prop::check_res;
use nestedfp::util::rng::Pcg64;

/// One generated scenario.
#[derive(Debug)]
struct Scenario {
    geo: KvGeometry,
    lens: Vec<usize>,
    /// Queries per lane this step (1 = decode, >1 = prefill tail).
    t: usize,
    /// Demote LRU-cold blocks before attending.
    demote: bool,
    /// Offload the first sequence to the host tier and fetch it back
    /// before attending (payloads must survive the round trip).
    offload_cycle: bool,
    seed: u64,
}

fn gen_scenario(rng: &mut Pcg64) -> Scenario {
    let bs = [4usize, 8][(rng.next_u32() % 2) as usize];
    let max_seq = bs * (3 + (rng.next_u32() % 4) as usize);
    let geo = KvGeometry {
        n_layers: 1 + (rng.next_u32() % 3) as usize,
        n_heads: 1 + (rng.next_u32() % 2) as usize,
        max_seq,
        head_dim: [2usize, 4][(rng.next_u32() % 2) as usize],
        block_size: bs,
        total_blocks: 3 * (max_seq / bs + 2),
    };
    let n_seqs = 1 + (rng.next_u32() % 3) as usize;
    let t = 1 + (rng.next_u32() % 3) as usize;
    let lens = (0..n_seqs)
        .map(|_| t + (rng.next_u64() as usize % (max_seq - t + 1)))
        .collect();
    Scenario {
        geo,
        lens,
        t,
        demote: rng.next_u32() % 2 == 0,
        offload_cycle: rng.next_u32() % 3 == 0,
        seed: rng.next_u64(),
    }
}

fn build(sc: &Scenario) -> (PagedKvCache, Vec<usize>) {
    let policy = if sc.demote {
        KvPressureConfig {
            demote_watermark_fp8: 0.0,
            ..KvPressureConfig::default()
        }
    } else {
        KvPressureConfig::default()
    };
    let mut kv = PagedKvCache::new(sc.geo, policy);
    let mut rng = Pcg64::seeded(sc.seed);
    let g = sc.geo;
    let mut seqs = Vec::new();
    for &len in &sc.lens {
        let s = kv.allocate(len).expect("scenario block budget");
        let n = g.n_layers * len * g.n_heads * g.head_dim;
        let nk: Vec<f32> = (0..n).map(|_| rng.normal() as f32 * 0.6).collect();
        let nv: Vec<f32> = (0..n).map(|_| rng.normal() as f32 * 0.6).collect();
        kv.scatter_prefill(s, 0, len, &nk, &nv);
        kv.grow(s, len).unwrap();
        seqs.push(s);
    }
    if sc.demote {
        kv.set_precision_pressure(true);
        kv.maintain();
    }
    if sc.offload_cycle {
        kv.offload_sequence(seqs[0]).expect("offload enabled");
        kv.fetch_sequence(seqs[0]).expect("fetch fits: nothing new allocated");
    }
    (kv, seqs)
}

#[test]
fn block_native_is_bit_identical_to_the_dense_oracle() {
    check_res(
        "attn-block-native-vs-oracle",
        40,
        gen_scenario,
        |sc| {
            let (mut kv, seqs) = build(sc);
            let g = sc.geo;
            let (h, dh) = (g.n_heads, g.head_dim);
            let mut rng = Pcg64::seeded(sc.seed ^ 0xabcd);
            let qs: Vec<Vec<f32>> = seqs
                .iter()
                .map(|_| (0..sc.t * h * dh).map(|_| rng.normal() as f32 * 0.4).collect())
                .collect();
            // queries are the tail of each context (prefill-style for
            // t > 1, plain decode for t == 1)
            let positions: Vec<Vec<i32>> = sc
                .lens
                .iter()
                .map(|&len| ((len - sc.t)..len).map(|p| p as i32).collect())
                .collect();
            let lanes: Vec<AttnLane> = seqs
                .iter()
                .zip(&qs)
                .zip(&positions)
                .map(|((&seq, q), p)| AttnLane {
                    seq,
                    q,
                    positions: p,
                })
                .collect();
            let n_out = lanes.len() * h * sc.t * dh;
            for layer in 0..g.n_layers {
                let mut dns = vec![0.0f32; n_out];
                attend_dense(&mut kv, layer, &lanes, &mut dns);
                for threads in [1usize, 2, 5] {
                    let mut blk = vec![0.0f32; n_out];
                    AttnEngine::new(threads).attend(&kv, layer, &lanes, &mut blk);
                    for (i, (a, b)) in blk.iter().zip(&dns).enumerate() {
                        if a.to_bits() != b.to_bits() {
                            return Err(format!(
                                "layer {layer} threads {threads} elem {i}: block {a} vs dense {b}"
                            ));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn mixed_precision_tables_really_occur_in_the_generator() {
    // guard the property above against silently testing only-f32 caches
    let mut rng = Pcg64::seeded(0x5eed_0001);
    let mut saw_fp8 = false;
    let mut saw_offload = false;
    for _ in 0..40 {
        let sc = gen_scenario(&mut rng);
        let (kv, seqs) = build(&sc);
        saw_fp8 |= kv.fp8_blocks() > 0;
        saw_offload |= sc.offload_cycle;
        let _ = seqs;
    }
    assert!(saw_fp8, "no scenario produced FP8-demoted blocks");
    assert!(saw_offload, "no scenario exercised the offload round trip");
}

// ---------------------------------------------------------------------------
// Part 2: the rewired RealBackend over synthesized tiny artifacts.
// The stub runtime loads manifest + weights without PJRT, so these run
// in the default build; the pjrt build's real client would try to
// compile the (nonexistent) HLO files, so they are gated out there.
// ---------------------------------------------------------------------------

#[cfg(not(feature = "pjrt"))]
mod host_backend {
    use std::io::Write as _;
    use std::path::PathBuf;
    use std::sync::OnceLock;

    use nestedfp::coordinator::backend::{Backend, ModeMap, RealBackend};
    use nestedfp::coordinator::engine::{Engine, EngineConfig};
    use nestedfp::coordinator::kv::KvCacheManager;
    use nestedfp::coordinator::precision::{Precision, PrecisionPolicy};
    use nestedfp::coordinator::request::Request;
    use nestedfp::format::fp16::F16;
    use nestedfp::format::nested::{self, DecomposeResult};
    use nestedfp::kvcache::KvPressureConfig;
    use nestedfp::runtime::ModelRuntime;
    use nestedfp::util::rng::Pcg64;

    const VOCAB: usize = 16;
    const D: usize = 8;
    const L: usize = 2;
    const DFF: usize = 12;

    struct StoreWriter {
        tensors: Vec<(String, u8, Vec<usize>, Vec<u8>)>,
    }

    impl StoreWriter {
        fn new() -> StoreWriter {
            StoreWriter {
                tensors: Vec::new(),
            }
        }

        fn u16s(&mut self, name: &str, dims: &[usize], bits: &[u16]) {
            let mut bytes = Vec::with_capacity(bits.len() * 2);
            for b in bits {
                bytes.extend_from_slice(&b.to_le_bytes());
            }
            self.tensors.push((name.into(), 1, dims.to_vec(), bytes));
        }

        fn f32s(&mut self, name: &str, dims: &[usize], vals: &[f32]) {
            let mut bytes = Vec::with_capacity(vals.len() * 4);
            for v in vals {
                bytes.extend_from_slice(&v.to_le_bytes());
            }
            self.tensors.push((name.into(), 2, dims.to_vec(), bytes));
        }

        fn u8s(&mut self, name: &str, dims: &[usize], vals: &[u8]) {
            self.tensors.push((name.into(), 0, dims.to_vec(), vals.to_vec()));
        }

        fn write(&self, path: &std::path::Path) {
            let mut f = std::fs::File::create(path).unwrap();
            f.write_all(b"NFPW").unwrap();
            f.write_all(&1u32.to_le_bytes()).unwrap();
            f.write_all(&(self.tensors.len() as u32).to_le_bytes()).unwrap();
            for (name, code, dims, bytes) in &self.tensors {
                f.write_all(&(name.len() as u16).to_le_bytes()).unwrap();
                f.write_all(name.as_bytes()).unwrap();
                f.write_all(&[*code, dims.len() as u8]).unwrap();
                for &d in dims {
                    f.write_all(&(d as u32).to_le_bytes()).unwrap();
                }
                f.write_all(&(bytes.len() as u64).to_le_bytes()).unwrap();
                f.write_all(bytes).unwrap();
            }
        }
    }

    /// Random eligible (|w| ≤ 1.7) f16 bit matrix.
    fn gauss_bits(rng: &mut Pcg64, n: usize) -> Vec<u16> {
        (0..n)
            .map(|_| F16::from_f32((rng.normal() as f32 * 0.3).clamp(-1.7, 1.7)).to_bits())
            .collect()
    }

    fn add_linear(w: &mut StoreWriter, rng: &mut Pcg64, key: &str, rows: usize, cols: usize) {
        let bits = gauss_bits(rng, rows * cols);
        let DecomposeResult::Nested(t) = nested::decompose_tensor(rows, cols, &bits) else {
            panic!("{key}: clamped weights must be nestable");
        };
        w.u16s(&format!("{key}.f16"), &[rows, cols], &bits);
        w.u8s(&format!("{key}.upper"), &[rows, cols], &t.upper);
        w.u8s(&format!("{key}.lower"), &[rows, cols], &t.lower);
    }

    /// Build the tiny artifact dir once per process.
    fn artifacts() -> &'static PathBuf {
        static DIR: OnceLock<PathBuf> = OnceLock::new();
        DIR.get_or_init(|| {
            let dir = std::env::temp_dir().join(format!("nestedfp_attnprops_{}", std::process::id()));
            std::fs::create_dir_all(&dir).unwrap();
            let manifest = format!(
                r#"{{
  "model": {{"vocab": {VOCAB}, "d_model": {D}, "n_layers": {L}, "n_heads": 2,
            "d_ff": {DFF}, "max_seq": 64, "head_dim": 4}},
  "decode_buckets": [1, 2, 4],
  "prefill_chunks": [4, 8],
  "modes": ["nested16", "nested8"],
  "act_scales": {{}},
  "executables": [
    {{"kind": "decode", "mode": "nested16", "size": 1, "path": "host_native.hlo.txt"}},
    {{"kind": "prefill", "mode": "nested16", "size": 8, "path": "host_native.hlo.txt"}}
  ]
}}
"#
            );
            std::fs::write(dir.join("manifest.json"), manifest).unwrap();
            let mut w = StoreWriter::new();
            let mut rng = Pcg64::seeded(0xfeed);
            w.u16s("embed", &[VOCAB, D], &gauss_bits(&mut rng, VOCAB * D));
            w.f32s("final_norm", &[D], &vec![1.0f32; D]);
            w.u16s("lm_head", &[VOCAB, D], &gauss_bits(&mut rng, VOCAB * D));
            for i in 0..L {
                w.f32s(&format!("layers.{i}.attn_norm"), &[D], &vec![1.0f32; D]);
                w.f32s(&format!("layers.{i}.mlp_norm"), &[D], &vec![1.0f32; D]);
                for name in ["wq", "wk", "wv", "wo"] {
                    add_linear(&mut w, &mut rng, &format!("layers.{i}.{name}"), D, D);
                }
                add_linear(&mut w, &mut rng, &format!("layers.{i}.w_gate"), DFF, D);
                add_linear(&mut w, &mut rng, &format!("layers.{i}.w_up"), DFF, D);
                add_linear(&mut w, &mut rng, &format!("layers.{i}.w_down"), D, DFF);
            }
            w.write(&dir.join("weights.bin"));
            dir
        })
    }

    fn backend() -> RealBackend {
        let rt = ModelRuntime::load(artifacts(), &["nested16", "nested8"], &["decode", "prefill"])
            .expect("stub runtime must load synthesized artifacts");
        RealBackend::new(rt, ModeMap::default(), 48)
    }

    fn fresh_kv(b: &RealBackend) -> KvCacheManager {
        KvCacheManager::new(b.geometry(), KvPressureConfig::dense_baseline())
    }

    /// Regression (ISSUE 5 satellite 1): the old pad loop indexed
    /// `slots[0]` unconditionally, so an empty batch panicked.
    #[test]
    fn empty_decode_batch_returns_an_empty_step() {
        let mut b = backend();
        let mut kv = fresh_kv(&b);
        let run = b
            .decode(&mut kv, &[], &[], &[], Precision::Fp16)
            .expect("empty batch must not error");
        assert_eq!(run.logits.map(|v| v.len()), Some(0));
        assert_eq!(run.latency, 0.0);
        assert_eq!(run.attn_dense_bytes, 0);
        assert_eq!(run.attn_touched_bytes, 0);
    }

    /// Prefill then one decode over the host-native path: logits have
    /// the right shapes and the attention counters show the block walk
    /// touching less than a dense gather would have copied.
    fn prefill_and_decode(
        b: &mut RealBackend,
        kv: &mut KvCacheManager,
        prompt: &[i32],
        precision: Precision,
    ) -> (usize, Vec<f32>) {
        let slot = kv.allocate(prompt.len()).unwrap();
        let run = b.prefill(kv, slot, 0, prompt, precision).unwrap();
        let logits = run.logits.unwrap();
        assert_eq!(logits.len(), VOCAB, "prefill returns last-token logits");
        assert!(logits.iter().all(|v| v.is_finite()));
        assert!(
            run.attn_touched_bytes < run.attn_dense_bytes,
            "short context must beat the max_seq-sized gather: {} !< {}",
            run.attn_touched_bytes,
            run.attn_dense_bytes
        );
        kv.grow(slot, prompt.len()).unwrap();
        (slot, logits)
    }

    #[test]
    fn host_native_prefill_and_decode_produce_logits() {
        for precision in [Precision::Fp16, Precision::Fp8] {
            let mut b = backend();
            let mut kv = fresh_kv(&b);
            let prompt: Vec<i32> = (0..8).map(|i| (i % VOCAB) as i32).collect();
            let (slot, _) = prefill_and_decode(&mut b, &mut kv, &prompt, precision);
            let run = b
                .decode(&mut kv, &[slot], &[3], &[8], precision)
                .unwrap();
            let logits = run.logits.unwrap();
            assert_eq!(logits.len(), VOCAB, "decode returns [B, vocab]");
            assert!(logits.iter().all(|v| v.is_finite()), "{precision:?}");
        }
    }

    /// Satellite 2: no padding lanes means no cross-talk — a lane's
    /// decode logits are bit-identical whether it runs alone or batched
    /// with another sequence.
    #[test]
    fn batched_decode_lanes_do_not_cross_talk() {
        let run_scenario = |batch_both: bool| -> Vec<f32> {
            let mut b = backend();
            let mut kv = fresh_kv(&b);
            let p0: Vec<i32> = (0..8).map(|i| (i % VOCAB) as i32).collect();
            let p1: Vec<i32> = (0..8).map(|i| ((i + 5) % VOCAB) as i32).collect();
            let (s0, _) = prefill_and_decode(&mut b, &mut kv, &p0, Precision::Fp16);
            let (s1, _) = prefill_and_decode(&mut b, &mut kv, &p1, Precision::Fp16);
            let (slots, toks, pos): (Vec<usize>, Vec<i32>, Vec<i32>) = if batch_both {
                (vec![s0, s1], vec![3, 7], vec![8, 8])
            } else {
                (vec![s0], vec![3], vec![8])
            };
            let run = b.decode(&mut kv, &slots, &toks, &pos, Precision::Fp16).unwrap();
            run.logits.unwrap()[..VOCAB].to_vec()
        };
        let solo = run_scenario(false);
        let batched = run_scenario(true);
        assert_eq!(solo.len(), batched.len());
        for (i, (a, b)) in solo.iter().zip(&batched).enumerate() {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "logit {i} differs between solo and batched decode: {a} vs {b}"
            );
        }
    }

    /// The whole serving loop on the host twin: requests admit, prefill,
    /// batch-decode, and finish — with the gather-vs-touched counters
    /// flowing into `Metrics`.
    #[test]
    fn engine_runs_end_to_end_on_the_host_twin() {
        let b = backend();
        let mut engine = Engine::new(
            b,
            EngineConfig {
                policy: PrecisionPolicy::Fp16Only,
                physical_kv: true,
                ..Default::default()
            },
        );
        let requests: Vec<Request> = (0..3)
            .map(|i| Request::new(i, vec![(1 + i as i32) % VOCAB as i32; 8], 4, 0.0))
            .collect();
        let report = engine.run(requests).unwrap();
        assert_eq!(report.metrics.completed, 3);
        assert_eq!(report.metrics.total_output_tokens, 12);
        for c in &report.completions {
            assert!(!c.tokens.is_empty());
            assert!(c.tokens.iter().all(|&t| (0..VOCAB as i32).contains(&t)));
        }
        assert!(
            report.metrics.attn_dense_bytes > 0,
            "attention counters must reach Metrics"
        );
        assert!(
            report.metrics.attn_gather_savings() > 0.5,
            "short contexts vs max_seq 64 must show large gather savings, got {}",
            report.metrics.attn_gather_savings()
        );
    }
}

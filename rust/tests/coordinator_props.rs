//! Property tests on coordinator invariants (routing, batching, KV
//! accounting, precision control) using the in-crate property driver.

use nestedfp::coordinator::backend::{Backend, StepRun};
use nestedfp::coordinator::engine::{Engine, EngineConfig};
use nestedfp::coordinator::kv::{KvCacheManager, KvGeometry, KvPressureConfig};
use nestedfp::coordinator::precision::{Precision, PrecisionController, PrecisionPolicy, SloConfig};
use nestedfp::coordinator::request::Request;
use nestedfp::util::prop;
use nestedfp::util::rng::Pcg64;

// ---------------------------------------------------------------------------
// KV manager invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_kv_blocks_conserved_under_random_ops() {
    prop::check_res(
        "kv-conservation",
        200,
        |rng: &mut Pcg64| {
            // a random op sequence: (alloc len | grow | release)
            let ops: Vec<(u8, usize)> = (0..40)
                .map(|_| (rng.range_u64(0, 3) as u8, rng.range_u64(1, 64) as usize))
                .collect();
            ops
        },
        |ops| {
            let geo = KvGeometry {
                n_layers: 1,
                n_heads: 1,
                max_seq: 64,
                head_dim: 1,
                block_size: 8,
                total_blocks: 64,
            };
            let mut kv = KvCacheManager::accounting_only(geo, KvPressureConfig::default());
            let mut live: Vec<usize> = Vec::new();
            for &(op, val) in ops {
                match op {
                    0 => {
                        if kv.can_admit(val) {
                            let slot = kv.allocate(val).map_err(|e| e.to_string())?;
                            live.push(slot);
                        }
                    }
                    1 => {
                        if let Some(&slot) = live.last() {
                            let _ = kv.grow(slot, val.min(64));
                        }
                    }
                    _ => {
                        if let Some(slot) = live.pop() {
                            kv.release(slot);
                        }
                    }
                }
                if kv.free_blocks() > geo.total_blocks {
                    return Err(format!(
                        "free blocks {} exceed total {}",
                        kv.free_blocks(),
                        geo.total_blocks
                    ));
                }
            }
            // releasing everything must restore the full budget
            for slot in live.drain(..) {
                kv.release(slot);
            }
            if kv.free_blocks() != geo.total_blocks {
                return Err(format!(
                    "leak: {} free of {}",
                    kv.free_blocks(),
                    geo.total_blocks
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_admit_len_never_overadmits_across_seeded_steps() {
    // `admit_len` is the scheduler's admission contract. In Paged mode it
    // must reserve exactly the prompt (never the full expected context —
    // that is Reserve mode's job), a successful `can_admit` must make the
    // subsequent `allocate` infallible, and across any 100-step seeded
    // op sequence the unit accounting must balance exactly:
    // free + Σ live-sequence device units == total budget.
    prop::check_res(
        "kv-admit-len",
        100,
        |rng: &mut Pcg64| {
            (0..100)
                .map(|_| {
                    (
                        rng.range_u64(0, 3) as u8,        // op: admit/admit/release
                        rng.range_u64(1, 200) as usize,   // prompt len
                        rng.range_u64(1, 200) as usize,   // max_new
                    )
                })
                .collect::<Vec<_>>()
        },
        |steps| {
            for reserve_mode in [false, true] {
                let geo = KvGeometry {
                    n_layers: 1,
                    n_heads: 1,
                    max_seq: 96,
                    head_dim: 1,
                    block_size: 8,
                    total_blocks: 24,
                };
                let policy = if reserve_mode {
                    KvPressureConfig::dense_baseline()
                } else {
                    KvPressureConfig::default()
                };
                let mut kv = KvCacheManager::accounting_only(geo, policy);
                let total_units = kv.free_units();
                let mut live: Vec<usize> = Vec::new();
                for &(op, plen, max_new) in steps {
                    if op < 2 {
                        let len = kv.admit_len(plen, max_new);
                        let want = if reserve_mode {
                            (plen + max_new).min(geo.max_seq)
                        } else {
                            plen.min(geo.max_seq)
                        };
                        if len != want {
                            return Err(format!(
                                "admit_len({plen}, {max_new}) = {len}, want {want} \
                                 (reserve_mode={reserve_mode})"
                            ));
                        }
                        if kv.can_admit(len) {
                            let slot = kv.allocate(len).map_err(|e| {
                                format!("can_admit said yes but allocate failed: {e}")
                            })?;
                            live.push(slot);
                        }
                    } else if let Some(slot) = live.pop() {
                        kv.release(slot);
                    }
                    let used: usize =
                        live.iter().map(|&s| kv.seq_device_units(s)).sum();
                    if kv.free_units() + used != total_units {
                        return Err(format!(
                            "unit accounting broke: free {} + used {used} != {total_units}",
                            kv.free_units()
                        ));
                    }
                }
                for slot in live.drain(..) {
                    kv.release(slot);
                }
                if kv.free_units() != total_units {
                    return Err("blocks leaked after full release".into());
                }
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// Engine invariants with a scripted backend
// ---------------------------------------------------------------------------

struct ScriptBackend {
    geo: KvGeometry,
    latency: f64,
    vocab: usize,
}

impl Backend for ScriptBackend {
    fn geometry(&self) -> KvGeometry {
        self.geo
    }
    fn prefill_chunks(&self) -> Vec<usize> {
        vec![8, 16]
    }
    fn max_decode_batch(&self) -> usize {
        4
    }
    fn prefill(
        &mut self,
        _kv: &mut KvCacheManager,
        _slot: usize,
        _start: usize,
        _tokens: &[i32],
        _p: Precision,
    ) -> anyhow::Result<StepRun> {
        Ok(StepRun {
            logits: Some(vec![0.0; self.vocab]),
            latency: self.latency,
            ..StepRun::default()
        })
    }
    fn decode(
        &mut self,
        _kv: &mut KvCacheManager,
        slots: &[usize],
        _tokens: &[i32],
        _pos: &[i32],
        _p: Precision,
    ) -> anyhow::Result<StepRun> {
        Ok(StepRun {
            logits: Some(vec![0.0; self.vocab * slots.len()]),
            latency: self.latency,
            ..StepRun::default()
        })
    }
}

fn script_engine() -> Engine<ScriptBackend> {
    Engine::new(
        ScriptBackend {
            geo: KvGeometry {
                n_layers: 1,
                n_heads: 1,
                max_seq: 64,
                head_dim: 1,
                block_size: 8,
                total_blocks: 256,
            },
            latency: 0.002,
            vocab: 32,
        },
        EngineConfig {
            policy: PrecisionPolicy::Dual,
            physical_kv: false,
            ..Default::default()
        },
    )
}

#[test]
fn prop_every_request_completes_with_exact_token_count() {
    prop::check_res(
        "engine-completion",
        30,
        |rng: &mut Pcg64| {
            let n = rng.range_u64(1, 12) as usize;
            (0..n)
                .map(|i| {
                    (
                        i as u64,
                        rng.range_u64(1, 5) as usize * 8, // prompt len (chunk aligned)
                        rng.range_u64(1, 20) as usize,    // max_new
                        rng.f64() * 0.5,                  // arrival
                    )
                })
                .collect::<Vec<_>>()
        },
        |specs| {
            let mut engine = script_engine();
            let requests: Vec<Request> = specs
                .iter()
                .map(|&(id, plen, max_new, arr)| Request::new(id, vec![1; plen], max_new, arr))
                .collect();
            let report = engine.run(requests).map_err(|e| e.to_string())?;
            if report.metrics.completed != specs.len() {
                return Err(format!(
                    "completed {} of {}",
                    report.metrics.completed,
                    specs.len()
                ));
            }
            // scripted logits never emit a stop token -> every request
            // produces exactly max_new tokens
            for c in &report.completions {
                let (_, _, max_new, _) = specs[c.id as usize];
                if c.tokens.len() != max_new {
                    return Err(format!(
                        "request {} produced {} tokens, wanted {max_new}",
                        c.id,
                        c.tokens.len()
                    ));
                }
            }
            // all KV released at the end
            if engine.kv.free_blocks() != engine.kv.geo.total_blocks {
                return Err("kv blocks leaked".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_ttft_nondecreasing_in_arrival_for_fifo_bursts() {
    // within a single burst (same arrival), earlier-id requests are
    // admitted first (FCFS): their TTFT must be no larger than later ones
    prop::check_res(
        "engine-fcfs",
        20,
        |rng: &mut Pcg64| (rng.range_u64(2, 6) as usize, rng.range_u64(1, 3) as usize * 8),
        |&(n, plen)| {
            let mut engine = script_engine();
            let requests: Vec<Request> = (0..n)
                .map(|i| Request::new(i as u64, vec![1; plen], 4, 0.0))
                .collect();
            let report = engine.run(requests).map_err(|e| e.to_string())?;
            let mut ttfts: Vec<(u64, f64)> = report
                .completions
                .iter()
                .map(|c| (c.id, c.ttft_s))
                .collect();
            ttfts.sort_by_key(|&(id, _)| id);
            for w in ttfts.windows(2) {
                if w[0].1 > w[1].1 + 1e-9 {
                    return Err(format!(
                        "FCFS violated: id {} ttft {} > id {} ttft {}",
                        w[0].0, w[0].1, w[1].0, w[1].1
                    ));
                }
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// Precision controller invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_controller_fixed_policies_invariant_under_any_signal() {
    prop::check(
        "fixed-policy",
        100,
        |rng: &mut Pcg64| {
            (
                rng.f64() * 0.2,               // tpot
                rng.range_u64(0, 50) as usize, // queue
                rng.f64(),                     // kv util
            )
        },
        |&(tpot, queue, util)| {
            let mut c16 = PrecisionController::new(PrecisionPolicy::Fp16Only, SloConfig::default());
            let mut c8 = PrecisionController::new(PrecisionPolicy::Fp8Only, SloConfig::default());
            c16.observe_tpot(tpot);
            c8.observe_tpot(tpot);
            c16.decide(queue, util) == Precision::Fp16
                && c8.decide(queue, util) == Precision::Fp8
        },
    );
}

#[test]
fn prop_controller_switch_rate_bounded_by_dwell() {
    // adversarial signal cannot make the dual controller switch more than
    // once per dwell window
    prop::check(
        "dwell-bound",
        50,
        |rng: &mut Pcg64| {
            (0..200)
                .map(|_| (rng.f64() * 0.08, rng.range_u64(0, 8) as usize))
                .collect::<Vec<_>>()
        },
        |signals| {
            let mut c = PrecisionController::new(PrecisionPolicy::Dual, SloConfig::default());
            for &(tpot, q) in signals {
                c.observe_tpot(tpot);
                c.decide(q, 0.3);
            }
            // dwell = 8 iterations -> at most ceil(200/8)+1 switches
            c.switches <= 200 / 8 + 1
        },
    );
}

//! Golden-trace regression suite: one small, fully seeded cluster
//! scenario (the autopilot arm of `bench::autopilot`'s `golden`
//! scenario) replayed end to end, with its headline metrics compared
//! against a committed snapshot **exactly**. Any behavioral drift in the
//! scheduler, autopilot, router, or KV cache changes some number here
//! and fails with a line-by-line diff.
//!
//! Snapshot lifecycle:
//! * the committed file starts as an `UNINITIALIZED` sentinel (this repo
//!   is grown in a container without a Rust toolchain); the first test
//!   run on a real toolchain seeds it with the actual snapshot and asks
//!   you to commit it;
//! * afterwards the comparison is exact. Intentional behavior changes
//!   re-seed with `UPDATE_GOLDEN=1 cargo test --test golden_trace` and
//!   commit the diff — the point is that drift is *loud and reviewed*,
//!   never silent.

use nestedfp::bench::autopilot::{run_arm, summarize, surge_workload, Arm, SurgeScenario};
use nestedfp::coordinator::precision::SloConfig;

const GOLDEN_PATH: &str = concat!(
    env!("CARGO_MANIFEST_DIR"),
    "/rust/tests/golden/cluster_scenario.snapshot.txt"
);
const SENTINEL: &str = "UNINITIALIZED";

/// Render the scenario's outcome canonically: one `key: value` per line,
/// fixed float precision, replicas in index order. Diff-friendly by
/// construction.
fn render_snapshot() -> String {
    let sc = SurgeScenario::golden();
    let slo = SloConfig::default();
    let n_requests = surge_workload(&sc).len();
    let mut report = run_arm(Arm::Autopilot, &sc).expect("golden scenario must drain");
    let s = summarize(&mut report, &slo);
    let mut out = String::new();
    out.push_str("schema: nestedfp/golden-trace@1\n");
    out.push_str(&format!(
        "scenario: autopilot lead={} len={} scale={:.2} replicas={} seeds={}/{}\n",
        sc.lead_s, sc.len_s, sc.scale, sc.replicas, sc.arrival_seed, sc.shape_seed
    ));
    out.push_str(&format!("requests: {n_requests}\n"));
    out.push_str(&format!("completed: {}\n", s.completed));
    out.push_str(&format!(
        "total_output_tokens: {}\n",
        report.aggregate.total_output_tokens
    ));
    out.push_str(&format!("goodput_req_s: {:.6}\n", s.goodput_req_s));
    out.push_str(&format!("slo_attained: {}\n", report.aggregate.slo_attained(&slo)));
    out.push_str(&format!("slo_violation_s: {}\n", s.slo_violation_s));
    out.push_str(&format!("mode_switches: {}\n", s.mode_switches));
    out.push_str(&format!("ladder_changes: {}\n", report.ladder_timeline.len()));
    out.push_str(&format!("pre_escalations: {}\n", s.pre_escalations));
    out.push_str(&format!(
        "dwell_s: {:.3}/{:.3}/{:.3}\n",
        s.dwell_s[0], s.dwell_s[1], s.dwell_s[2]
    ));
    for (i, r) in report.replicas.iter().enumerate() {
        out.push_str(&format!(
            "replica{i}: routed={} iterations={} switches={} \
             final_free_blocks={} final_host_blocks={} total_blocks={}\n",
            r.routed,
            r.iterations,
            r.mode_stats.switches,
            r.final_free_kv_blocks,
            r.final_host_kv_blocks,
            r.total_kv_blocks
        ));
    }
    out
}

#[test]
fn golden_cluster_scenario_matches_committed_snapshot() {
    let actual = render_snapshot();
    let committed = std::fs::read_to_string(GOLDEN_PATH).unwrap_or_default();
    let reseed = std::env::var("UPDATE_GOLDEN").is_ok()
        || committed.trim().is_empty()
        || committed.trim_start().starts_with(SENTINEL);
    if reseed {
        std::fs::write(GOLDEN_PATH, &actual).expect("write golden snapshot");
        eprintln!(
            "golden_trace: seeded {GOLDEN_PATH} from this run — commit it to \
             lock the behavior (this message appears only on first run or \
             under UPDATE_GOLDEN=1)"
        );
        return;
    }
    if committed != actual {
        let mut diff = String::new();
        let c: Vec<&str> = committed.lines().collect();
        let a: Vec<&str> = actual.lines().collect();
        for i in 0..c.len().max(a.len()) {
            let want = c.get(i).copied().unwrap_or("<missing>");
            let got = a.get(i).copied().unwrap_or("<missing>");
            if want != got {
                diff.push_str(&format!("  line {:>2}: - {want}\n           + {got}\n", i + 1));
            }
        }
        panic!(
            "behavioral drift vs the committed golden trace:\n{diff}\
             If this change is intentional, regenerate with\n  \
             UPDATE_GOLDEN=1 cargo test -q --test golden_trace\n\
             and commit the snapshot diff alongside the code change."
        );
    }
}

/// KV-cache invariant, checked after **every** bench arm: with the
/// workload fully drained, free + used + host must equal the budget on
/// every replica — i.e. used == 0, host == 0, free == total. A single
/// leaked or stranded block anywhere in the admission / demotion /
/// offload / release paths fails here by name.
#[test]
fn kv_blocks_conserve_after_every_bench_arm() {
    let sc = SurgeScenario::golden();
    let n = surge_workload(&sc).len();
    for arm in [Arm::StaticFp16, Arm::StaticFp8, Arm::LocalDual, Arm::Autopilot] {
        let report = run_arm(arm, &sc).expect("arm must drain");
        assert_eq!(
            report.aggregate.completed, n,
            "{}: workload did not drain",
            arm.name()
        );
        for (i, r) in report.replicas.iter().enumerate() {
            assert_eq!(
                r.final_free_kv_blocks, r.total_kv_blocks,
                "{} replica {i}: leaked {} device blocks",
                arm.name(),
                r.total_kv_blocks - r.final_free_kv_blocks
            );
            assert_eq!(
                r.final_host_kv_blocks, 0,
                "{} replica {i}: {} blocks stranded on the host tier",
                arm.name(),
                r.final_host_kv_blocks
            );
        }
    }
}

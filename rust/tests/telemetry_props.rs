//! Telemetry-layer property suite.
//!
//! Pins the three guarantees the observability layer is built on:
//!
//! 1. **Determinism** — the exported trace of a cluster run is a pure
//!    function of (seed, config): rerunning produces a byte-identical
//!    Chrome-trace JSON, and the lockstep oracle emits the identical
//!    trace as the heap driver (same dispatch law ⇒ same event order).
//! 2. **Zero interference** — installing the tracer never perturbs the
//!    simulation: every observable is bit-for-bit identical with
//!    tracing on and off, in both drivers. Exports are balanced by
//!    construction, even when the ring-buffer cap drops events.
//! 3. **Deterministic merge** — the counter registry's fold is
//!    order-independent at fleet scale (100 replicas), and
//!    `Metrics::merge` agrees with folding the registries directly,
//!    since it is now implemented on top of them.

use anyhow::Result;

use nestedfp::bench::autopilot::{surge_workload, SurgeScenario};
use nestedfp::coordinator::autopilot::AutopilotConfig;
use nestedfp::coordinator::backend::SimBackend;
use nestedfp::coordinator::cluster::{ClusterConfig, ClusterReport, ClusterRouter, SurgeConfig};
use nestedfp::coordinator::engine::EngineConfig;
use nestedfp::coordinator::metrics::Metrics;
use nestedfp::coordinator::precision::{PrecisionPolicy, SloConfig};
use nestedfp::coordinator::request::{FinishReason, Request, RequestState};
use nestedfp::coordinator::router::RoutingPolicy;
use nestedfp::gpusim::WeightFormat;
use nestedfp::kvcache::KvPressureConfig;
use nestedfp::model::zoo;
use nestedfp::telemetry::export::{check_trace, trace_to_json};
use nestedfp::telemetry::registry::{MergeRule, Registry};
use nestedfp::telemetry::trace;
use nestedfp::util::rng::Pcg64;

// ---------------------------------------------------------------------
// Scenario + cluster construction (mirrors event_core_props.rs, scaled
// down: this suite runs several full cluster simulations per test).
// ---------------------------------------------------------------------

fn scenario() -> SurgeScenario {
    SurgeScenario {
        lead_s: 8,
        len_s: 24,
        scale: 0.12,
        ..SurgeScenario::golden()
    }
}

fn cluster(sc: &SurgeScenario) -> ClusterRouter<SimBackend> {
    let spec = zoo::find("llama31-8b").expect("llama31-8b in the zoo");
    let max_seq = 1024;
    let backends: Vec<SimBackend> = (0..sc.replicas)
        .map(|_| {
            SimBackend::new(
                spec,
                WeightFormat::Nested16,
                WeightFormat::Nested8,
                64,
                max_seq,
                64 * (max_seq / 16 + 1) * 2,
            )
        })
        .collect();
    let cfg = ClusterConfig {
        policy: RoutingPolicy::SloHeadroom,
        engine: EngineConfig {
            policy: PrecisionPolicy::Dual,
            slo: SloConfig::default(),
            physical_kv: false,
            max_iterations: 0,
            kv: KvPressureConfig::default(),
            devices: 1,
        },
        surge: SurgeConfig::disabled(),
        autopilot: Some(AutopilotConfig::default()),
        ..ClusterConfig::default()
    };
    ClusterRouter::new(backends, cfg)
}

/// Every observable of a run with f64s as raw bits, so "equal" means
/// bit-for-bit (trimmed copy of the event_core_props fingerprint).
fn fingerprint(r: &ClusterReport) -> String {
    use std::fmt::Write as _;
    let mut s = String::new();
    for c in &r.completions {
        writeln!(
            s,
            "c {} {} {:016x} {:016x}",
            c.id,
            c.tokens.len(),
            c.ttft_s.to_bits(),
            c.mean_tpot_s.to_bits()
        )
        .unwrap();
    }
    for (i, rep) in r.replicas.iter().enumerate() {
        writeln!(
            s,
            "r{i} routed={} iters={} fp16={} fp8={} free={} host={} tp={}",
            rep.routed,
            rep.iterations,
            rep.controller.iters_fp16,
            rep.controller.iters_fp8,
            rep.final_free_kv_blocks,
            rep.final_host_kv_blocks,
            rep.final_tp_degree
        )
        .unwrap();
        for &(t, fp8) in &rep.mode_timeline {
            writeln!(s, "  m {:016x} {fp8}", t.to_bits()).unwrap();
        }
    }
    for &(t, k) in &r.demotion_timeline {
        writeln!(s, "dem {:016x} {k}", t.to_bits()).unwrap();
    }
    for &(t, i, tp) in &r.reshard_timeline {
        writeln!(s, "rs {:016x} {i} {tp}", t.to_bits()).unwrap();
    }
    writeln!(
        s,
        "agg completed={} out={} pre={} t0={:016x} t1={:016x}",
        r.aggregate.completed,
        r.aggregate.total_output_tokens,
        r.pre_escalations,
        r.aggregate.t_start.to_bits(),
        r.aggregate.t_end.to_bits()
    )
    .unwrap();
    s
}

fn run_traced(
    sc: &SurgeScenario,
    cap: usize,
    lockstep: bool,
) -> Result<(ClusterReport, trace::Trace)> {
    trace::install(cap);
    let mut c = cluster(sc);
    let report = if lockstep {
        c.run_lockstep(surge_workload(sc))?
    } else {
        c.run(surge_workload(sc))?
    };
    let tr = trace::take().expect("tracer was installed");
    Ok((report, tr))
}

// ---------------------------------------------------------------------
// 1. Determinism: byte-identical exports across reruns and drivers.
// ---------------------------------------------------------------------

#[test]
fn trace_export_is_byte_identical_across_reruns_and_drivers() -> Result<()> {
    let sc = scenario();
    let (ra, ta) = run_traced(&sc, trace::DEFAULT_CAP, false)?;
    assert!(ra.aggregate.completed > 0, "scenario produced no completions");
    assert!(!ta.events.is_empty(), "cluster run recorded no events");
    assert_eq!(ta.dropped, 0, "default cap must hold the whole scenario");
    let a = trace_to_json(&ta).to_string();

    let (_, tb) = run_traced(&sc, trace::DEFAULT_CAP, false)?;
    let b = trace_to_json(&tb).to_string();
    assert_eq!(a, b, "same seed+config must export a byte-identical trace");

    let (_, tc) = run_traced(&sc, trace::DEFAULT_CAP, true)?;
    let c = trace_to_json(&tc).to_string();
    assert_eq!(a, c, "lockstep oracle must emit the identical trace");
    Ok(())
}

// ---------------------------------------------------------------------
// 2a. Zero interference: tracing on vs off is bit-identical.
// ---------------------------------------------------------------------

#[test]
fn tracing_never_changes_the_simulation_in_either_driver() -> Result<()> {
    let sc = scenario();
    for lockstep in [false, true] {
        let mut plain_cluster = cluster(&sc);
        let plain = if lockstep {
            plain_cluster.run_lockstep(surge_workload(&sc))?
        } else {
            plain_cluster.run(surge_workload(&sc))?
        };
        let (traced, tr) = run_traced(&sc, trace::DEFAULT_CAP, lockstep)?;
        assert!(!tr.events.is_empty());
        assert_eq!(
            fingerprint(&plain),
            fingerprint(&traced),
            "lockstep={lockstep}: tracing perturbed the simulation"
        );
    }
    Ok(())
}

// ---------------------------------------------------------------------
// 2b. Balance: exports validate, with and without cap pressure.
// ---------------------------------------------------------------------

#[test]
fn exported_cluster_trace_is_balanced_and_validates() -> Result<()> {
    let sc = scenario();
    let (_, tr) = run_traced(&sc, trace::DEFAULT_CAP, false)?;
    let chk = check_trace(&trace_to_json(&tr).to_string())?;
    assert_eq!(chk.events, tr.events.len());
    assert!(chk.spans > 0, "a cluster run must record spans");
    assert!(chk.instants > 0, "a cluster run must record instants");
    assert_eq!(chk.dropped, 0);
    Ok(())
}

#[test]
fn no_lifecycle_span_crosses_its_requests_completion() -> Result<()> {
    use nestedfp::telemetry::trace::{Kind, Phase};
    let sc = scenario();
    let (_, tr) = run_traced(&sc, trace::DEFAULT_CAP, false)?;
    let mut completion: std::collections::HashMap<u64, f64> = Default::default();
    for e in &tr.events {
        if e.kind == Kind::Completion {
            completion.insert(e.id, e.t);
        }
    }
    assert!(!completion.is_empty(), "run recorded no completion instants");
    // every queue/prefill/decode/offload span of a completed request must
    // close at or before that request's completion instant (requests
    // still in flight at the horizon have no instant and are skipped —
    // finish_run closes their spans at the horizon by design)
    let mut checked = 0usize;
    for e in &tr.events {
        let lifecycle = matches!(
            e.kind,
            Kind::Queue | Kind::Prefill | Kind::Decode | Kind::Offload
        );
        if lifecycle && e.phase == Phase::End {
            if let Some(&done) = completion.get(&e.id) {
                assert!(
                    e.t <= done,
                    "{:?} span of request {} ends at {} after its completion at {}",
                    e.kind,
                    e.id,
                    e.t,
                    done
                );
                checked += 1;
            }
        }
    }
    assert!(checked > 0, "no lifecycle span ends were checked");
    Ok(())
}

#[test]
fn trace_stays_balanced_when_the_cap_drops_events() -> Result<()> {
    let sc = scenario();
    // a cap far below the scenario's event count: most events drop, yet
    // check_trace must still validate (it errors on any unmatched B/E)
    // and must surface the truncation through the dropped counter.
    let (_, tr) = run_traced(&sc, 64, false)?;
    assert!(tr.dropped > 0, "tiny cap must drop events");
    let chk = check_trace(&trace_to_json(&tr).to_string())?;
    assert_eq!(chk.dropped, tr.dropped as u64);
    assert!(chk.events >= chk.spans * 2, "span accounting broken");
    Ok(())
}

// ---------------------------------------------------------------------
// 3. Registry merge at fleet scale: order-independent, and the
//    Metrics struct's merge agrees with folding registries directly.
// ---------------------------------------------------------------------

fn finished_request(id: u64, arrival: f64, first: f64, done: f64, n_out: usize) -> Request {
    let mut r = Request::new(id, vec![1, 2, 3], 64, arrival);
    r.state = RequestState::Finished;
    r.prefilled = 3;
    r.generated = vec![0; n_out];
    r.first_token_at = Some(first);
    r.finished_at = Some(done);
    r.finish_reason = Some(FinishReason::Length);
    r
}

/// One replica's metrics: staggered arrivals so `run.t_start_s` (Min)
/// and `run.t_end_s` (Max) have unique fleet-wide extremes.
fn replica_metrics(i: usize) -> Metrics {
    let mut m = Metrics::new();
    let arrival = 1.0 + i as f64 * 0.25;
    let ttft = 0.010 + i as f64 * 0.001;
    m.record_request(&finished_request(
        i as u64,
        arrival,
        arrival + ttft,
        arrival + ttft + 0.5,
        4 + i % 7,
    ));
    m
}

#[test]
fn registry_merge_is_order_independent_across_100_replicas() {
    let regs: Vec<Registry> = (0..100)
        .map(|i| replica_metrics(i).scalar_registry())
        .collect();
    let fold = |order: &[usize]| {
        let mut acc = Registry::new();
        for &i in order {
            acc.merge(&regs[i]);
        }
        acc
    };
    let fwd: Vec<usize> = (0..100).collect();
    let reference = fold(&fwd);
    for seed in 0..8u64 {
        let mut order = fwd.clone();
        Pcg64::seeded(seed).shuffle(&mut order);
        assert_eq!(
            fold(&order),
            reference,
            "seed {seed}: merge order changed the folded registry"
        );
    }

    // each rule lands on its documented fleet-wide aggregate
    assert_eq!(reference.int("requests.completed"), 100);
    assert_eq!(reference.get("requests.completed").unwrap().rule, MergeRule::Sum);
    let out: u64 = (0..100).map(|i| (4 + i % 7) as u64).sum();
    assert_eq!(reference.int("tokens.output"), out);
    assert_eq!(reference.float("run.t_start_s").to_bits(), 1.0f64.to_bits());
    assert_eq!(reference.get("run.t_start_s").unwrap().rule, MergeRule::Min);
    let last = 1.0 + 99.0 * 0.25;
    let t_end = last + (0.010 + 99.0 * 0.001) + 0.5;
    assert_eq!(reference.float("run.t_end_s").to_bits(), t_end.to_bits());
    assert_eq!(reference.get("run.t_end_s").unwrap().rule, MergeRule::Max);

    // Metrics::merge is registry-backed: folding through the struct
    // must land on the same scalars as folding the registries directly.
    let mut merged = Metrics::new();
    for i in 0..100 {
        merged.merge(&replica_metrics(i));
    }
    assert_eq!(merged.completed, 100);
    assert_eq!(merged.ttft.len(), 100, "digests must pool samples");
    assert_eq!(merged.scalar_registry(), reference);
}

//! Property and regression tests for the host-attention piggybacking PR:
//! the `HostTier` ledger, the resume-headroom anti-thrash margin, the
//! host/device attention cost laws, and determinism of the piggybacked
//! engine pipeline.

use nestedfp::bench::kvcache::run_pressure;
use nestedfp::gpusim::{
    device_attention_seconds, host_attention_seconds, HOST_ATTN_LAUNCH_S,
};
use nestedfp::kvcache::{HostTier, KvGeometry, KvPressureConfig, PagedKvCache};
use nestedfp::model::zoo;
use nestedfp::util::prop::check_res;
use nestedfp::util::rng::Pcg64;

// ---------------------------------------------------------------------------
// HostTier ledger
// ---------------------------------------------------------------------------

/// One random op against the tier. Withdraw/discard amounts are bounded
/// by what a shadow ledger says is resident, the way the paged cache
/// only ever moves blocks it actually deposited.
#[derive(Debug, Clone, Copy)]
enum Op {
    Deposit(usize, usize),
    Withdraw(usize, usize),
    Discard(usize, usize),
}

#[derive(Debug)]
struct LedgerCase {
    ops: Vec<Op>,
}

fn gen_ledger(rng: &mut Pcg64) -> LedgerCase {
    let n = 4 + (rng.next_u32() % 60) as usize;
    // shadow state used only to keep generated ops legal
    let (mut blocks, mut bytes) = (0usize, 0usize);
    let mut ops = Vec::with_capacity(n);
    for _ in 0..n {
        let kind = rng.next_u32() % 3;
        let op = if kind == 0 || blocks == 0 {
            let b = (rng.next_u32() % 8) as usize;
            let by = b * 1024 + (rng.next_u32() % 512) as usize;
            blocks += b;
            bytes += by;
            Op::Deposit(b, by)
        } else {
            let b = (rng.next_u64() % (blocks as u64 + 1)) as usize;
            let by = (rng.next_u64() % (bytes as u64 + 1)) as usize;
            blocks -= b;
            bytes -= by;
            if kind == 1 {
                Op::Withdraw(b, by)
            } else {
                Op::Discard(b, by)
            }
        };
        ops.push(op);
    }
    LedgerCase { ops }
}

#[test]
fn host_tier_ledger_never_goes_inconsistent() {
    check_res(
        "host-tier-ledger",
        200,
        gen_ledger,
        |case: &LedgerCase| {
            let mut t = HostTier::new(24.0, 50e-6);
            let (mut blocks, mut bytes) = (0usize, 0usize);
            for (i, op) in case.ops.iter().enumerate() {
                match *op {
                    Op::Deposit(b, by) => {
                        let dt = t.deposit(b, by);
                        if dt < t.transfer_seconds(0) {
                            return Err(format!("op {i}: deposit cheaper than the base latency"));
                        }
                        blocks += b;
                        bytes += by;
                    }
                    Op::Withdraw(b, by) => {
                        if b > blocks || by > bytes {
                            continue; // generator shadow drifted: skip illegal op
                        }
                        let dt = t.withdraw(b, by);
                        if dt < t.transfer_seconds(0) {
                            return Err(format!("op {i}: withdraw cheaper than the base latency"));
                        }
                        blocks -= b;
                        bytes -= by;
                    }
                    Op::Discard(b, by) => {
                        if b > blocks || by > bytes {
                            continue;
                        }
                        t.discard(b, by);
                        blocks -= b;
                        bytes -= by;
                    }
                }
                if t.resident_blocks() != blocks || t.resident_bytes() != bytes {
                    return Err(format!(
                        "op {i}: ledger ({}, {}) != shadow ({blocks}, {bytes})",
                        t.resident_blocks(),
                        t.resident_bytes()
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn transfer_seconds_is_monotone_in_bytes() {
    check_res(
        "transfer-monotone",
        300,
        |rng: &mut Pcg64| {
            let a = (rng.next_u64() % (1 << 30)) as usize;
            let b = a + (rng.next_u64() % (1 << 30)) as usize;
            let bw = 1.0 + (rng.next_u32() % 64) as f64;
            let base = (rng.next_u32() % 1000) as f64 * 1e-6;
            (a, b, bw, base)
        },
        |&(a, b, bw, base)| {
            let t = HostTier::new(bw, base);
            let (sa, sb) = (t.transfer_seconds(a), t.transfer_seconds(b));
            if sa > sb {
                return Err(format!("bytes {a} <= {b} but seconds {sa} > {sb}"));
            }
            if sa < base {
                return Err(format!("transfer below the base latency: {sa} < {base}"));
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// Resume-thrash regression (the headroom margin)
// ---------------------------------------------------------------------------

fn thrash_geo() -> KvGeometry {
    KvGeometry {
        n_layers: 1,
        n_heads: 1,
        max_seq: 256,
        head_dim: 8,
        block_size: 16,
        total_blocks: 8,
    }
}

/// Drive the cache to the exact state the margin exists for: a resumed
/// sequence whose very next grow fails because the fetch consumed the
/// last free blocks. With `resume_headroom_mult = 0` (the legacy rule)
/// the sequence ping-pongs straight back to the host.
#[test]
fn exact_fit_resume_ping_pongs_without_margin() {
    let p0 = KvPressureConfig {
        resume_headroom_mult: 0.0,
        demote_enabled: false,
        ..Default::default()
    };
    let mut kv = PagedKvCache::accounting_only(thrash_geo(), p0);
    let a = kv.allocate(32).unwrap(); // 3 blocks
    kv.grow(a, 32).unwrap();
    let b = kv.allocate(32).unwrap(); // 3 blocks, 2 free
    kv.grow(b, 32).unwrap();
    kv.offload_sequence(b).unwrap(); // 5 free
    kv.grow(a, 64).unwrap(); // 4 free
    // legacy rule: the fetch fits (stored 3 blocks + 1 headroom == free),
    // so the sequence resumes into a device with zero growth room left
    assert!(kv.can_fetch(b), "margin 0 must reproduce the legacy resume");
    kv.fetch_sequence(b).unwrap(); // 1 free
    kv.grow(a, 80).unwrap(); // 0 free
    // ... and its next grow strands it: straight back to the host
    assert!(kv.grow(b, 49).is_err(), "no growth room after an exact-fit resume");
    kv.offload_sequence(b).unwrap();
    assert_eq!(kv.stats().offload_events, 2, "the ping-pong the margin prevents");
}

#[test]
fn resume_headroom_margin_breaks_the_ping_pong() {
    // identical pressure, default margin: the fetch is refused until the
    // device has real growth room, and the resumed sequence then grows
    // without a second offload
    let mut kv = PagedKvCache::accounting_only(
        thrash_geo(),
        KvPressureConfig {
            demote_enabled: false,
            ..Default::default()
        },
    );
    let a = kv.allocate(32).unwrap();
    kv.grow(a, 32).unwrap();
    let b = kv.allocate(32).unwrap();
    kv.grow(b, 32).unwrap();
    kv.offload_sequence(b).unwrap();
    kv.grow(a, 64).unwrap();
    assert!(
        !kv.can_fetch(b),
        "margin must hold the fetch while growth room is thin"
    );
    kv.grow(a, 80).unwrap();
    assert!(!kv.can_fetch(b));
    kv.release(a);
    assert!(kv.can_fetch(b), "margin satisfied once real room frees");
    kv.fetch_sequence(b).unwrap();
    kv.grow(b, 49).unwrap(); // the grow that thrashed at margin 0
    assert_eq!(kv.stats().offload_events, 1, "no ping-pong with the margin");
}

// ---------------------------------------------------------------------------
// Cost laws
// ---------------------------------------------------------------------------

#[test]
fn host_attention_law_is_monotone_and_zero_at_zero() {
    assert_eq!(host_attention_seconds(32, 0), 0.0);
    check_res(
        "host-attn-monotone",
        300,
        |rng: &mut Pcg64| {
            let l = 1 + (rng.next_u32() % 80) as usize;
            let a = 1 + (rng.next_u64() % (1 << 32)) as usize;
            let b = a + (rng.next_u64() % (1 << 32)) as usize;
            (l, a, b)
        },
        |&(l, a, b)| {
            let (sa, sb) = (host_attention_seconds(l, a), host_attention_seconds(l, b));
            if sa > sb {
                return Err(format!("bytes {a} <= {b} but seconds {sa} > {sb}"));
            }
            // the launch term scales with layer count
            if host_attention_seconds(l + 1, a) <= sa {
                return Err("extra layer must add launch latency".into());
            }
            if sa < l as f64 * HOST_ATTN_LAUNCH_S {
                return Err(format!("below the launch floor: {sa}"));
            }
            Ok(())
        },
    );
}

#[test]
fn device_attention_law_matches_the_host_law_shape() {
    let spec = zoo::find("llama31-8b").expect("llama31-8b in the zoo");
    assert_eq!(device_attention_seconds(spec, 0, 512), 0.0);
    // both laws are linear-plus-launch; the device one must be far
    // cheaper per byte (HBM vs host DRAM) — the gap piggybacking trades
    // against the resume transfer
    let dev = device_attention_seconds(spec, 4, 512);
    let kv_bytes = spec.n_layers * 4 * 512 * 2 * spec.kv_dim() * 2;
    let host = host_attention_seconds(spec.n_layers, kv_bytes);
    assert!(dev > 0.0 && host > dev, "host serve must cost more than device: {host} !> {dev}");
    // monotone in batch
    assert!(device_attention_seconds(spec, 8, 512) > dev);
}

// ---------------------------------------------------------------------------
// Pipeline determinism
// ---------------------------------------------------------------------------

#[test]
fn piggybacked_pipeline_is_deterministic() {
    // the tier-agnostic decode pipeline with host lanes enabled must be
    // exactly reproducible on the virtual clock — same workload, same
    // bits, twice
    let run = || run_pressure(KvPressureConfig::piggyback(), 16, 2.0, 384).unwrap();
    let (r1, s1) = run();
    let (r2, s2) = run();
    assert_eq!(r1.metrics.completed, r2.metrics.completed);
    assert_eq!(r1.metrics.total_output_tokens, r2.metrics.total_output_tokens);
    assert_eq!(
        r1.metrics.host_piggybacked_steps,
        r2.metrics.host_piggybacked_steps
    );
    assert_eq!(
        r1.metrics.host_attn_seconds.to_bits(),
        r2.metrics.host_attn_seconds.to_bits()
    );
    assert_eq!(
        r1.metrics.host_transfer_seconds_avoided.to_bits(),
        r2.metrics.host_transfer_seconds_avoided.to_bits()
    );
    assert_eq!(s1.offload_events, s2.offload_events);
    assert_eq!(s1.transfer_seconds.to_bits(), s2.transfer_seconds.to_bits());
}

//! Property tests for the per-layer precision morph (ISSUE 9): the
//! [`LayerSchedule`] demotion order against its sensitivity ranking, the
//! elastic KV watermark's monotonicity in the demoted-layer fraction,
//! the fine-ladder FSM's dwell discipline under adversarial pressure
//! flapping, and — over synthesized tiny artifacts — bit-identity of the
//! morph path's endpoints with the legacy single-mode forward (which
//! also pins the exception-set precompute to the old per-linear scan's
//! semantics).

use nestedfp::coordinator::autopilot::{Autopilot, AutopilotConfig};
use nestedfp::coordinator::precision::LayerSchedule;
use nestedfp::kvcache::KvPressureConfig;

// ---------------------------------------------------------------------------
// Part 1: the schedule itself — ranking, rung mapping, quality proxy.
// ---------------------------------------------------------------------------

/// The demotion order is exactly the ascending sensitivity argsort
/// (least sensitive first, ties toward the lower layer index), `rank`
/// is its inverse, and demotion always takes a prefix of the order.
#[test]
fn demotion_order_matches_the_sensitivity_ranking() {
    let sens = nestedfp::bench::morph::layer_sensitivity(12);
    let mut sched = LayerSchedule::from_sensitivity(&sens);
    let order = sched.order().to_vec();

    let mut seen = vec![false; sens.len()];
    for &l in &order {
        assert!(!seen[l], "layer {l} repeated in the demotion order");
        seen[l] = true;
    }
    assert!(seen.iter().all(|&s| s), "order must be a permutation");

    for w in order.windows(2) {
        let (a, b) = (w[0], w[1]);
        assert!(
            sens[a] < sens[b] || (sens[a] == sens[b] && a < b),
            "order not an ascending-sensitivity argsort at ({a}, {b}): \
             {} vs {}",
            sens[a],
            sens[b]
        );
    }

    for k in 0..=sens.len() {
        sched.set_demoted(k);
        assert_eq!(sched.demoted_layers(), k);
        let mask = sched.cold_mask();
        for (pos, &l) in order.iter().enumerate() {
            assert_eq!(
                sched.is_demoted(l),
                pos < k,
                "k = {k}: layer {l} at rank {pos}"
            );
            assert_eq!(mask[l], pos < k);
        }
    }

    // endpoint fractions are exact so the elastic KV watermark
    // reproduces the legacy binary pressure flag bit for bit there
    sched.set_demoted(0);
    assert_eq!(sched.demoted_fraction().to_bits(), 0.0f64.to_bits());
    sched.set_demoted(sens.len());
    assert_eq!(sched.demoted_fraction().to_bits(), 1.0f64.to_bits());
}

/// Rung → demoted-prefix mapping: endpoints exact, monotone in the
/// rung, and every non-zero rung demotes at least one layer.
#[test]
fn rung_to_prefix_mapping_covers_the_ladder() {
    for (max_rung, n_layers) in [(2usize, 32usize), (4, 32), (8, 32), (8, 2), (8, 100)] {
        let mut prev = 0;
        for rung in 0..=max_rung {
            let k = LayerSchedule::demoted_for_rung(rung, max_rung, n_layers);
            assert!(k <= n_layers);
            assert!(k >= prev, "non-monotone at rung {rung}/{max_rung}");
            if rung == 0 {
                assert_eq!(k, 0, "rung 0 must demote nothing");
            } else {
                assert!(k >= 1, "non-zero rung {rung}/{max_rung} demotes nothing");
            }
            if rung == max_rung {
                assert_eq!(k, n_layers, "top rung must demote every layer");
            }
            prev = k;
        }
    }
}

/// The quality proxy is pinned at the endpoints (0 = all-FP16, 1 =
/// the all-FP8 error) and monotone in the demoted prefix.
#[test]
fn demotion_error_is_monotone_and_normalized() {
    let sens = nestedfp::bench::morph::layer_sensitivity(10);
    let sched = LayerSchedule::from_sensitivity(&sens);
    assert_eq!(sched.demotion_error(0).to_bits(), 0.0f64.to_bits());
    assert!((sched.demotion_error(10) - 1.0).abs() < 1e-12);
    let mut prev = 0.0;
    for k in 0..=10 {
        let e = sched.demotion_error(k);
        assert!(e >= prev, "err not monotone at k = {k}: {e} < {prev}");
        prev = e;
    }
    // the degenerate all-zero ranking falls back to a uniform proxy
    let flat = LayerSchedule::from_sensitivity(&[0.0; 4]);
    assert!((flat.demotion_error(2) - 0.5).abs() < 1e-12);
}

// ---------------------------------------------------------------------------
// Part 2: the elastic KV watermark.
// ---------------------------------------------------------------------------

/// `watermark_at` is monotone non-increasing in the demoted-layer
/// fraction, exactly reproduces the legacy binary watermarks at the
/// endpoints, and clamps out-of-range fractions.
#[test]
fn kv_watermark_is_monotone_in_the_demoted_fraction() {
    for cfg in [
        KvPressureConfig::default(),
        KvPressureConfig::dense_baseline(),
        KvPressureConfig::demote_only(),
    ] {
        assert_eq!(
            cfg.watermark_at(0.0).to_bits(),
            cfg.watermark(false).to_bits(),
            "frac 0 must equal the legacy calm watermark"
        );
        assert_eq!(
            cfg.watermark_at(1.0).to_bits(),
            cfg.watermark(true).to_bits(),
            "frac 1 must equal the legacy pressure watermark"
        );
        let mut prev = f64::INFINITY;
        for i in 0..=32 {
            let w = cfg.watermark_at(i as f64 / 32.0);
            assert!(w.is_finite() && w >= 0.0);
            assert!(
                w <= prev + 1e-12,
                "watermark rose with demotion at step {i}: {w} > {prev}"
            );
            prev = w;
        }
        assert_eq!(cfg.watermark_at(-3.0).to_bits(), cfg.watermark_at(0.0).to_bits());
        assert_eq!(cfg.watermark_at(7.0).to_bits(), cfg.watermark_at(1.0).to_bits());
    }
}

// ---------------------------------------------------------------------------
// Part 3: the fine ladder's dwell discipline.
// ---------------------------------------------------------------------------

/// Drive a single-replica autopilot on an 8-rung morph ladder with
/// adversarially flapping pressure (3 ticks hot, 3 ticks calm, forever).
/// The assignment flaps every 0.75 s; the replica may not: every
/// escalation waits out the escalate dwell (and the post-promotion
/// cooldown), every promotion walks one rung under the scaled promote
/// dwell.
#[test]
fn fine_ladder_respects_dwell_bounds_under_adversarial_pressure() {
    let cfg = AutopilotConfig {
        morph_rungs: 8,
        ..AutopilotConfig::default()
    };
    let esc_dwell = cfg.escalate_dwell_s;
    let promote_dwell = cfg.promote_dwell_s * 2.0 / 8.0;
    let cooldown = cfg.cooldown_s;
    let tick = cfg.control_interval_s;
    let mut ap = Autopilot::new(1, cfg);
    assert_eq!(
        ap.fine_rungs().map(|(s, m)| (s.len(), m)),
        Some((1, 8)),
        "morph_rungs = 8 must expose the fine ladder"
    );

    let ticks = 600usize;
    for k in 0..ticks {
        let t = k as f64 * tick;
        let p = if (k / 3) % 2 == 0 { 2.5 } else { 0.0 };
        ap.control_at(t, &[p], 0.0, &[1.0]);
    }

    let tl = ap.rung_timeline(0);
    assert!(!tl.is_empty(), "the ladder never moved under pressure");
    assert!(
        tl.iter().any(|&(_, s)| s > 0) && tl.windows(2).any(|w| w[1].1 < w[0].1),
        "need both an escalation and a promotion to exercise the law"
    );

    let mut last_promote_at = f64::NEG_INFINITY;
    for w in tl.windows(2) {
        let ((t0, s0), (t1, s1)) = (w[0], w[1]);
        assert!(t1 > t0, "timeline must advance: {t0} -> {t1}");
        assert!(s0 <= 8 && s1 <= 8, "rung beyond the ladder top");
        if s1 > s0 {
            assert!(
                t1 - t0 >= esc_dwell - 1e-9,
                "escalation at {t1} only {} s after the move at {t0}",
                t1 - t0
            );
            assert!(
                t1 - last_promote_at >= cooldown - 1e-9,
                "escalation at {t1} inside the cooldown of the promotion at \
                 {last_promote_at}"
            );
            assert!(s1 - s0 <= 4, "escalation jumped {} rungs", s1 - s0);
        } else {
            assert_eq!(s0 - s1, 1, "promotion must walk one rung at a time");
            assert!(
                t1 - t0 >= promote_dwell - 1e-9,
                "promotion at {t1} only {} s after the move at {t0}",
                t1 - t0
            );
            last_promote_at = t1;
        }
    }
    if let Some(&(t, s)) = tl.first() {
        assert!(s > 0 && t >= 0.0, "the first move must be an escalation");
    }
}

/// `morph_rungs == 0` keeps the legacy coarse controller: no fine
/// ladder is exposed, so the cluster driver stays on `apply_directive`.
#[test]
fn zero_morph_rungs_keeps_the_coarse_ladder() {
    let ap = Autopilot::new(2, AutopilotConfig::default());
    assert!(ap.fine_rungs().is_none(), "fine ladder must be opt-in");
}

// ---------------------------------------------------------------------------
// Part 4: morph endpoints over the rewired RealBackend / HostForward,
// on synthesized tiny artifacts (same fixture shape as attn_props; the
// pjrt build would try to compile the nonexistent HLO files).
// ---------------------------------------------------------------------------

#[cfg(not(feature = "pjrt"))]
mod host_morph {
    use std::io::Write as _;
    use std::path::PathBuf;
    use std::sync::OnceLock;

    use nestedfp::coordinator::backend::{Backend, ModeMap, RealBackend};
    use nestedfp::coordinator::hostforward::{HostForward, StepLane};
    use nestedfp::coordinator::kv::KvCacheManager;
    use nestedfp::coordinator::precision::{LayerSchedule, Precision};
    use nestedfp::format::fp16::F16;
    use nestedfp::format::nested::{self, DecomposeResult};
    use nestedfp::kvcache::KvPressureConfig;
    use nestedfp::runtime::ModelRuntime;
    use nestedfp::util::rng::Pcg64;

    const VOCAB: usize = 16;
    const D: usize = 8;
    const L: usize = 2;
    const DFF: usize = 12;

    struct StoreWriter {
        tensors: Vec<(String, u8, Vec<usize>, Vec<u8>)>,
    }

    impl StoreWriter {
        fn new() -> StoreWriter {
            StoreWriter {
                tensors: Vec::new(),
            }
        }

        fn u16s(&mut self, name: &str, dims: &[usize], bits: &[u16]) {
            let mut bytes = Vec::with_capacity(bits.len() * 2);
            for b in bits {
                bytes.extend_from_slice(&b.to_le_bytes());
            }
            self.tensors.push((name.into(), 1, dims.to_vec(), bytes));
        }

        fn f32s(&mut self, name: &str, dims: &[usize], vals: &[f32]) {
            let mut bytes = Vec::with_capacity(vals.len() * 4);
            for v in vals {
                bytes.extend_from_slice(&v.to_le_bytes());
            }
            self.tensors.push((name.into(), 2, dims.to_vec(), bytes));
        }

        fn u8s(&mut self, name: &str, dims: &[usize], vals: &[u8]) {
            self.tensors.push((name.into(), 0, dims.to_vec(), vals.to_vec()));
        }

        fn write(&self, path: &std::path::Path) {
            let mut f = std::fs::File::create(path).unwrap();
            f.write_all(b"NFPW").unwrap();
            f.write_all(&1u32.to_le_bytes()).unwrap();
            f.write_all(&(self.tensors.len() as u32).to_le_bytes()).unwrap();
            for (name, code, dims, bytes) in &self.tensors {
                f.write_all(&(name.len() as u16).to_le_bytes()).unwrap();
                f.write_all(name.as_bytes()).unwrap();
                f.write_all(&[*code, dims.len() as u8]).unwrap();
                for &d in dims {
                    f.write_all(&(d as u32).to_le_bytes()).unwrap();
                }
                f.write_all(&(bytes.len() as u64).to_le_bytes()).unwrap();
                f.write_all(bytes).unwrap();
            }
        }
    }

    fn gauss_bits(rng: &mut Pcg64, n: usize) -> Vec<u16> {
        (0..n)
            .map(|_| F16::from_f32((rng.normal() as f32 * 0.3).clamp(-1.7, 1.7)).to_bits())
            .collect()
    }

    fn add_linear(w: &mut StoreWriter, rng: &mut Pcg64, key: &str, rows: usize, cols: usize) {
        let bits = gauss_bits(rng, rows * cols);
        let DecomposeResult::Nested(t) = nested::decompose_tensor(rows, cols, &bits) else {
            panic!("{key}: clamped weights must be nestable");
        };
        w.u16s(&format!("{key}.f16"), &[rows, cols], &bits);
        w.u8s(&format!("{key}.upper"), &[rows, cols], &t.upper);
        w.u8s(&format!("{key}.lower"), &[rows, cols], &t.lower);
    }

    /// Build the tiny artifact dir once per process. Unlike the
    /// attn_props fixture, the manifest carries an `exception_layers`
    /// entry so the morph path exercises the precomputed exception set
    /// (layers.1.wo stays on its FP16 plane in nested8 mode).
    fn artifacts() -> &'static PathBuf {
        static DIR: OnceLock<PathBuf> = OnceLock::new();
        DIR.get_or_init(|| {
            let dir =
                std::env::temp_dir().join(format!("nestedfp_morphprops_{}", std::process::id()));
            std::fs::create_dir_all(&dir).unwrap();
            let manifest = format!(
                r#"{{
  "model": {{"vocab": {VOCAB}, "d_model": {D}, "n_layers": {L}, "n_heads": 2,
            "d_ff": {DFF}, "max_seq": 64, "head_dim": 4}},
  "decode_buckets": [1, 2, 4],
  "prefill_chunks": [4, 8],
  "modes": ["nested16", "nested8"],
  "act_scales": {{}},
  "exception_layers": {{"layers.1.wo": true}},
  "executables": [
    {{"kind": "decode", "mode": "nested16", "size": 1, "path": "host_native.hlo.txt"}},
    {{"kind": "prefill", "mode": "nested16", "size": 8, "path": "host_native.hlo.txt"}}
  ]
}}
"#
            );
            std::fs::write(dir.join("manifest.json"), manifest).unwrap();
            let mut w = StoreWriter::new();
            let mut rng = Pcg64::seeded(0x6d0f);
            w.u16s("embed", &[VOCAB, D], &gauss_bits(&mut rng, VOCAB * D));
            w.f32s("final_norm", &[D], &vec![1.0f32; D]);
            w.u16s("lm_head", &[VOCAB, D], &gauss_bits(&mut rng, VOCAB * D));
            for i in 0..L {
                w.f32s(&format!("layers.{i}.attn_norm"), &[D], &vec![1.0f32; D]);
                w.f32s(&format!("layers.{i}.mlp_norm"), &[D], &vec![1.0f32; D]);
                for name in ["wq", "wk", "wv", "wo"] {
                    add_linear(&mut w, &mut rng, &format!("layers.{i}.{name}"), D, D);
                }
                add_linear(&mut w, &mut rng, &format!("layers.{i}.w_gate"), DFF, D);
                add_linear(&mut w, &mut rng, &format!("layers.{i}.w_up"), DFF, D);
                add_linear(&mut w, &mut rng, &format!("layers.{i}.w_down"), D, DFF);
            }
            w.write(&dir.join("weights.bin"));
            dir
        })
    }

    fn runtime() -> ModelRuntime {
        ModelRuntime::load(artifacts(), &["nested16", "nested8"], &["decode", "prefill"])
            .expect("stub runtime must load synthesized artifacts")
    }

    fn backend() -> RealBackend {
        RealBackend::new(runtime(), ModeMap::default(), 48)
    }

    fn fresh_kv(b: &RealBackend) -> KvCacheManager {
        KvCacheManager::new(b.geometry(), KvPressureConfig::dense_baseline())
    }

    /// One 8-token prefill-shaped host step; `cold` selects the morph
    /// path (`forward_morph` over nested16/nested8) vs the legacy
    /// single-mode `forward`.
    fn host_logits(cold: Option<&[bool]>, mode: &str) -> Vec<f32> {
        let rt = runtime();
        let mut host = HostForward::new(&rt).unwrap();
        let mut kv = KvCacheManager::new(backend().geometry(), KvPressureConfig::dense_baseline());
        let slot = kv.allocate(8).unwrap();
        let tokens: Vec<i32> = (0..8).map(|i| (i % VOCAB) as i32).collect();
        let positions: Vec<i32> = (0..8).collect();
        let lanes = [StepLane {
            seq: slot,
            tokens: &tokens,
            positions: &positions,
        }];
        let out = match cold {
            Some(mask) => host
                .forward_morph(&rt, &mut kv, "nested16", "nested8", mask, &lanes)
                .unwrap(),
            None => host.forward(&rt, &mut kv, mode, &lanes).unwrap(),
        };
        assert_eq!(out.logits.len(), VOCAB);
        out.logits
    }

    fn bits(xs: &[f32]) -> Vec<u32> {
        xs.iter().map(|v| v.to_bits()).collect()
    }

    /// The tentpole's fidelity claim, at the hostforward layer: an
    /// all-hot (all-cold) morph mask is **bit-identical** to the legacy
    /// single-mode forward under the hot (cold) mode — so a schedule
    /// parked at either endpoint costs nothing, and the precomputed
    /// exception set reproduces the old per-linear manifest scan.
    #[test]
    fn morph_endpoints_are_bit_identical_to_the_single_mode_paths() {
        let hot = host_logits(None, "nested16");
        let cold = host_logits(None, "nested8");
        assert_ne!(
            bits(&hot),
            bits(&cold),
            "the two modes must genuinely differ or the endpoint claim is vacuous"
        );
        assert_eq!(
            bits(&host_logits(Some(&[false; L]), "unused")),
            bits(&hot),
            "all-hot morph mask != forward(nested16)"
        );
        assert_eq!(
            bits(&host_logits(Some(&[true; L]), "unused")),
            bits(&cold),
            "all-cold morph mask != forward(nested8)"
        );
    }

    /// An interior mask genuinely blends the planes: finite logits that
    /// match neither endpoint.
    #[test]
    fn interior_morph_mask_blends_the_planes() {
        let hot = host_logits(None, "nested16");
        let cold = host_logits(None, "nested8");
        let mixed = host_logits(Some(&[true, false]), "unused");
        assert!(mixed.iter().all(|v| v.is_finite()));
        assert_ne!(bits(&mixed), bits(&hot), "interior mask ran all-hot");
        assert_ne!(bits(&mixed), bits(&cold), "interior mask ran all-cold");
    }

    /// A cold mask that doesn't cover every layer is a hard error, not
    /// a silent truncation.
    #[test]
    fn morph_mask_must_cover_every_layer() {
        let rt = runtime();
        let mut host = HostForward::new(&rt).unwrap();
        let mut kv = KvCacheManager::new(backend().geometry(), KvPressureConfig::dense_baseline());
        let slot = kv.allocate(8).unwrap();
        let tokens: Vec<i32> = (0..8).collect();
        let positions: Vec<i32> = (0..8).collect();
        let lanes = [StepLane {
            seq: slot,
            tokens: &tokens,
            positions: &positions,
        }];
        let err = host
            .forward_morph(&rt, &mut kv, "nested16", "nested8", &[true], &lanes)
            .expect_err("short mask must bail");
        assert!(err.to_string().contains("cold mask"), "{err}");
    }

    /// One prefill + one decode through the RealBackend; `schedule`
    /// (if any) is installed via the Backend trait hook before any step.
    fn backend_decode_logits(schedule: Option<LayerSchedule>, precision: Precision) -> Vec<f32> {
        let mut b = backend();
        if let Some(s) = &schedule {
            b.set_layer_schedule(Some(s));
        }
        let mut kv = fresh_kv(&b);
        let prompt: Vec<i32> = (0..8).map(|i| (i % VOCAB) as i32).collect();
        let slot = kv.allocate(prompt.len()).unwrap();
        b.prefill(&mut kv, slot, 0, &prompt, precision).unwrap();
        kv.grow(slot, prompt.len()).unwrap();
        let run = b.decode(&mut kv, &[slot], &[3], &[8], precision).unwrap();
        run.logits.unwrap()
    }

    /// The same claim one layer up, through the engine-facing backend:
    /// a schedule parked at either endpoint leaves prefill + decode
    /// bit-identical to running with no schedule at all, and an
    /// interior schedule actually engages the morph path.
    #[test]
    fn schedule_endpoints_through_the_backend_match_the_legacy_modes() {
        let base16 = backend_decode_logits(None, Precision::Fp16);
        let base8 = backend_decode_logits(None, Precision::Fp8);
        assert_ne!(bits(&base16), bits(&base8));

        let mut s = LayerSchedule::identity(L);
        s.set_demoted(0);
        assert_eq!(
            bits(&backend_decode_logits(Some(s.clone()), Precision::Fp16)),
            bits(&base16),
            "schedule endpoint 0 != legacy Fp16 run"
        );
        s.set_demoted(L);
        assert_eq!(
            bits(&backend_decode_logits(Some(s.clone()), Precision::Fp8)),
            bits(&base8),
            "schedule endpoint n != legacy Fp8 run"
        );

        s.set_demoted(1);
        let mixed = backend_decode_logits(Some(s), Precision::Fp16);
        assert!(mixed.iter().all(|v| v.is_finite()));
        assert_ne!(
            bits(&mixed),
            bits(&base16),
            "interior schedule did not engage the morph path"
        );
    }
}

//! Property tests on the shard layer's invariants:
//!
//! (a) **in-flight conservation** — across any drain → repartition →
//!     resume window, no request is dropped and none is double-counted:
//!     every submitted request completes exactly once, and the KV ledger
//!     balances on every replica, for any workload seed;
//! (b) **two-ladder dwell discipline** — under adversarial pressure
//!     series, the precision ladder keeps its dwell bounds, the
//!     parallelism ladder keeps its own (longer) ones, TP targets walk
//!     one power-of-two rung at a time, and the arbiter never moves both
//!     knobs of one replica in the same 0.25 s control tick;
//! (c) **resharder state-machine safety** — under random operation
//!     sequences the per-replica lifecycle never skips a state, window
//!     deadlines and counters stay consistent, and double-begins are
//!     refused.

use std::collections::HashSet;

use nestedfp::bench::autopilot::surge_workload;
use nestedfp::bench::parallelism::{arm_cluster, mini_scenario, Arm};
use nestedfp::coordinator::autopilot::{Autopilot, AutopilotConfig};
use nestedfp::shard::{ReshardCost, ReshardState, Resharder, ShardPlan};
use nestedfp::util::prop;
use nestedfp::util::rng::Pcg64;

// ---------------------------------------------------------------------------
// (a) in-flight conservation across reshard windows
// ---------------------------------------------------------------------------

#[test]
fn prop_no_request_dropped_or_double_counted_across_reshard_windows() {
    // count reshards across all cases: each seed individually may or may
    // not cross the escalation threshold, but the suite as a whole must
    // actually exercise windows or it pins nothing
    let mut total_reshards = 0usize;
    prop::check_res(
        "reshard-conservation",
        6,
        |rng: &mut Pcg64| (rng.range_u64(1, 1 << 20), rng.range_u64(1, 1 << 20)),
        |&(arrival_seed, shape_seed)| {
            let sc = nestedfp::bench::autopilot::SurgeScenario {
                arrival_seed,
                shape_seed,
                ..mini_scenario()
            };
            let wl = surge_workload(&sc);
            let n = wl.len();
            let report = arm_cluster(Arm::Combined, &sc)
                .run(wl)
                .map_err(|e| format!("combined arm failed to drain: {e:#}"))?;
            if report.aggregate.completed != n {
                return Err(format!(
                    "dropped requests: {} of {n} completed",
                    report.aggregate.completed
                ));
            }
            let ids: HashSet<u64> = report.completions.iter().map(|c| c.id).collect();
            if ids.len() != n {
                return Err(format!(
                    "double-counted requests: {} unique ids for {n} completions",
                    ids.len()
                ));
            }
            // the KV ledger balances on every replica after the drain —
            // a request lost inside a freeze would strand its blocks
            for (i, r) in report.replicas.iter().enumerate() {
                if r.final_free_kv_blocks != r.total_kv_blocks || r.final_host_kv_blocks != 0 {
                    return Err(format!(
                        "replica {i} KV imbalance after reshard: free {}/{} host {}",
                        r.final_free_kv_blocks, r.total_kv_blocks, r.final_host_kv_blocks
                    ));
                }
            }
            if report.aggregate.reshards != report.reshard_timeline.len() {
                return Err(format!(
                    "reshard counter {} disagrees with timeline {}",
                    report.aggregate.reshards,
                    report.reshard_timeline.len()
                ));
            }
            // completion times of windows are non-decreasing, and the
            // one-at-a-time rule means no two windows close out of order
            for w in report.reshard_timeline.windows(2) {
                if w[1].0 < w[0].0 {
                    return Err(format!(
                        "reshard windows closed out of order: {w:?}"
                    ));
                }
            }
            total_reshards += report.aggregate.reshards;
            Ok(())
        },
    );
    assert!(
        total_reshards >= 1,
        "no seed ever resharded — the scenario tests nothing"
    );
}

// ---------------------------------------------------------------------------
// (b) two-ladder dwell discipline under adversarial pressure
// ---------------------------------------------------------------------------

#[test]
fn prop_two_ladder_autopilot_obeys_both_dwell_bounds() {
    prop::check_res(
        "two-ladder-no-thrash",
        30,
        |rng: &mut Pcg64| {
            // adversarial series: per-tick random per-replica pressures
            // straddling both thresholds, long enough for several full
            // escalate/release round trips of either ladder
            (0..240)
                .map(|_| [rng.f64() * 2.0, rng.f64() * 2.0])
                .collect::<Vec<_>>()
        },
        |series| {
            let cfg = AutopilotConfig {
                max_tp: 4,
                ..AutopilotConfig::default()
            };
            let mut ap = Autopilot::new(2, cfg);
            let hr = [0.0; 2];
            let mut t = 0.0;
            for p in series {
                ap.control_at(t, p, 0.0, &hr);
                t += cfg.control_interval_s;
            }
            let min_precision_dwell = cfg.escalate_dwell_s.min(cfg.promote_dwell_s);
            let min_tp_dwell = cfg.tp_escalate_dwell_s.min(cfg.tp_promote_dwell_s);
            for i in 0..2 {
                let ptl = ap.directive_timeline(i);
                for w in ptl.windows(2) {
                    let gap = w[1].0 - w[0].0;
                    if gap + 1e-9 < min_precision_dwell {
                        return Err(format!(
                            "replica {i}: precision switches {gap:.3}s apart \
                             (< dwell {min_precision_dwell})"
                        ));
                    }
                }
                let ttl = ap.tp_timeline(i);
                for w in ttl.windows(2) {
                    let gap = w[1].0 - w[0].0;
                    if gap + 1e-9 < min_tp_dwell {
                        return Err(format!(
                            "replica {i}: tp switches {gap:.3}s apart (< dwell {min_tp_dwell})"
                        ));
                    }
                }
                // the parallelism ladder walks one power-of-two rung at
                // a time and never leaves [1, max_tp]
                let mut prev = 1usize;
                for &(_, tp) in ttl {
                    if !tp.is_power_of_two() || tp < 1 || tp > cfg.max_tp {
                        return Err(format!("replica {i}: illegal tp target {tp}"));
                    }
                    if tp != prev * 2 && prev != tp * 2 {
                        return Err(format!(
                            "replica {i}: tp jumped {prev} -> {tp} (must move one rung)"
                        ));
                    }
                    prev = tp;
                }
                // arbitration: never both knobs of one replica in one tick
                let ptimes: HashSet<u64> = ptl.iter().map(|&(t, _)| t.to_bits()).collect();
                for &(tt, tp) in ttl {
                    if ptimes.contains(&tt.to_bits()) {
                        return Err(format!(
                            "replica {i}: precision and tp (-> {tp}) both moved at t={tt:.2}"
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// (c) resharder state-machine safety under random operation sequences
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, Copy)]
enum Op {
    Begin { replica: usize, tp: usize },
    Drain { replica: usize },
    Advance { dt_ms: u64 },
}

#[test]
fn prop_resharder_state_machine_is_safe_under_random_ops() {
    prop::check_res(
        "resharder-fuzz",
        40,
        |rng: &mut Pcg64| {
            (0..60)
                .map(|_| match rng.index(3) {
                    0 => Op::Begin {
                        replica: rng.index(3),
                        tp: 1 << rng.index(3),
                    },
                    1 => Op::Drain {
                        replica: rng.index(3),
                    },
                    _ => Op::Advance {
                        dt_ms: rng.range_u64(1, 120),
                    },
                })
                .collect::<Vec<Op>>()
        },
        |ops| {
            let mut rs = Resharder::new(3, ReshardCost::default());
            let mut now = 0.0f64;
            let mut open_windows = 0usize;
            for &op in ops {
                match op {
                    Op::Begin { replica, tp } => {
                        let was_serving = rs.state(replica) == ReshardState::Serving;
                        let began = rs.begin(replica, tp);
                        if began != was_serving {
                            return Err(format!(
                                "begin({replica}, {tp}) = {began} from state {:?}",
                                rs.state(replica)
                            ));
                        }
                    }
                    Op::Drain { replica } => {
                        if let ReshardState::Draining { target_tp } = rs.state(replica) {
                            let until = rs.drained(replica, now, None, ShardPlan::single(4));
                            if until <= now {
                                return Err(format!(
                                    "window closed before it opened: {until} <= {now}"
                                ));
                            }
                            match rs.state(replica) {
                                ReshardState::Repartitioning { target_tp: t2, .. }
                                    if t2 == target_tp => {}
                                s => {
                                    return Err(format!(
                                        "drained({replica}) landed in {s:?}, wanted \
                                         Repartitioning to tp {target_tp}"
                                    ))
                                }
                            }
                            open_windows += 1;
                        }
                    }
                    Op::Advance { dt_ms } => {
                        now += dt_ms as f64 * 1e-3;
                        let before = rs.reshards;
                        let done = rs.complete_due(now);
                        if rs.reshards != before + done.len() {
                            return Err("reshard counter skipped".into());
                        }
                        open_windows -= done.len();
                        // anything still open must be due strictly later
                        if let Some(d) = rs.next_deadline() {
                            if d <= now {
                                return Err(format!(
                                    "deadline {d} still pending at now {now}"
                                ));
                            }
                        } else if open_windows != 0 {
                            return Err(format!(
                                "{open_windows} windows open but no deadline"
                            ));
                        }
                    }
                }
            }
            if rs.reshards != rs.timeline.len() {
                return Err(format!(
                    "counter {} != timeline {}",
                    rs.reshards,
                    rs.timeline.len()
                ));
            }
            // repartition time is the sum of billed windows: positive iff
            // any window ever opened
            if (rs.repartition_s > 0.0) != (rs.reshards > 0 || open_windows > 0) {
                return Err("repartition_s inconsistent with window history".into());
            }
            Ok(())
        },
    );
}

//! Property tests for the fused-NestedFP GEMM engine: the engine must be
//! bit-identical to the naive reference oracle for every format (in
//! particular, fused `Nested16` == reconstruct-then-matmul exactly), the
//! `Nested8` path must sit within its documented tolerance of the FP16
//! product, and the thread pool must never change a single bit.

use nestedfp::format::tensor::Tensor2;
use nestedfp::gemm::{GemmConfig, GemmEngine, GemmFormat, GemmWeights};
use nestedfp::util::prop;
use nestedfp::util::rng::Pcg64;

fn gauss(rows: usize, cols: usize, rng: &mut Pcg64) -> Tensor2 {
    Tensor2::from_vec(
        rows,
        cols,
        (0..rows * cols)
            .map(|_| (rng.normal() as f32 * 0.3).clamp(-1.7, 1.7))
            .collect(),
    )
}

/// Deliberately awkward tiles + 2 workers, to exercise every edge path.
fn edge_engine() -> GemmEngine {
    GemmEngine::new(GemmConfig {
        mc: 6,
        kc: 10,
        nc: 20,
        threads: 2,
    })
}

fn oracle(x: &Tensor2, w: &GemmWeights, fmt: GemmFormat) -> Tensor2 {
    x.matmul(&w.dense_f32(fmt).transposed())
}

fn bits_equal(a: &Tensor2, b: &Tensor2) -> Result<(), String> {
    for (i, (x, y)) in a.data.iter().zip(&b.data).enumerate() {
        if x.to_bits() != y.to_bits() {
            return Err(format!("element {i}: {x} ({:#010x}) vs {y} ({:#010x})", x.to_bits(), y.to_bits()));
        }
    }
    Ok(())
}

#[test]
fn nested16_bit_identical_to_reconstruct_then_matmul() {
    // the acceptance criterion: fused reconstruction inside the pack
    // stage == reconstruct the whole tensor, then the reference matmul
    let engine = edge_engine();
    prop::check_res(
        "gemm-nested16-bitexact",
        40,
        |rng| {
            let (m, n, k) = (
                1 + rng.index(24),
                1 + rng.index(24),
                1 + rng.index(32),
            );
            let x = gauss(m, k, rng);
            let w = gauss(n, k, rng);
            (x, w)
        },
        |(x, w)| {
            let g = GemmWeights::prepare(w, GemmFormat::Nested16).map_err(|e| e.to_string())?;
            bits_equal(
                &engine.matmul(x, &g, GemmFormat::Nested16),
                &oracle(x, &g, GemmFormat::Nested16),
            )
        },
    );
}

#[test]
fn every_format_bit_identical_to_its_oracle() {
    let engine = edge_engine();
    prop::check_res(
        "gemm-all-formats-bitexact",
        24,
        |rng| {
            let fmt = GemmFormat::ALL[rng.index(4)];
            let (m, n, k) = (1 + rng.index(16), 1 + rng.index(20), 1 + rng.index(24));
            let x = gauss(m, k, rng);
            let w = gauss(n, k, rng);
            (fmt, x, w)
        },
        |(fmt, x, w)| {
            let g = GemmWeights::prepare(w, *fmt).map_err(|e| e.to_string())?;
            bits_equal(&engine.matmul(x, &g, *fmt), &oracle(x, &g, *fmt))
        },
    );
}

#[test]
fn nested16_bit_identical_on_a_larger_tensor() {
    // one shape big enough to cross several (mc, kc, nc) tile boundaries
    // and both worker bands
    let mut rng = Pcg64::seeded(4242);
    let x = gauss(33, 65, &mut rng);
    let w = gauss(47, 65, &mut rng);
    let g = GemmWeights::prepare(&w, GemmFormat::Nested16).unwrap();
    bits_equal(
        &edge_engine().matmul(&x, &g, GemmFormat::Nested16),
        &oracle(&x, &g, GemmFormat::Nested16),
    )
    .unwrap();
}

#[test]
fn nested8_within_documented_tolerance_of_fp16() {
    // documented tolerance: the Nested8 weight differs from the FP16
    // weight by at most max(|w|/16, 2^-18) per element (3-bit mantissa
    // RNE, plus the E4M3-subnormal floor at the 2^-8 scale), so the
    // product drift is bounded by sum_p |x|·|w8-w16|, plus a small
    // allowance for f32 accumulation-order rounding.
    let engine = edge_engine();
    prop::check_res(
        "gemm-nested8-tolerance",
        24,
        |rng| {
            let (m, n, k) = (1 + rng.index(12), 1 + rng.index(16), 1 + rng.index(48));
            let x = gauss(m, k, rng);
            let w = gauss(n, k, rng);
            (x, w)
        },
        |(x, w)| {
            let g = GemmWeights::prepare(w, GemmFormat::Nested16).map_err(|e| e.to_string())?;
            let w16 = g.dense_f32(GemmFormat::Nested16);
            let w8 = g.dense_f32(GemmFormat::Nested8);
            // per-element weight error obeys the documented bound
            for (a, b) in w8.data.iter().zip(&w16.data) {
                let lim = (b.abs() as f64 / 16.0).max(f64::powi(2.0, -18)) * (1.0 + 1e-6);
                if ((a - b).abs() as f64) > lim {
                    return Err(format!("weight tolerance broken: {a} vs {b}"));
                }
            }
            let c16 = engine.matmul(x, &g, GemmFormat::Nested16);
            let c8 = engine.matmul(x, &g, GemmFormat::Nested8);
            let k = x.cols;
            for i in 0..x.rows {
                for j in 0..w16.rows {
                    let mut werr = 0.0f64;
                    let mut mag = 0.0f64;
                    for p in 0..k {
                        let xa = x.get(i, p).abs() as f64;
                        werr += xa * (w8.get(j, p) - w16.get(j, p)).abs() as f64;
                        mag += xa * w16.get(j, p).abs() as f64;
                    }
                    let bound = werr + 1e-5 * mag + 1e-9;
                    let d = (c8.get(i, j) - c16.get(i, j)).abs() as f64;
                    if d > bound {
                        return Err(format!("({i},{j}): |Δ|={d:.3e} > bound {bound:.3e}"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn worker_counts_1_2_8_bit_identical_on_ragged_shapes() {
    // the deterministic-pool satellite: 1/2/8 workers, shapes that are
    // not multiples of any tile size, plus empty and single-row cases
    let shapes = [(37usize, 23usize, 41usize), (5, 7, 3), (1, 13, 9), (0, 8, 8), (8, 1, 8)];
    let mut rng = Pcg64::seeded(777);
    for &(m, n, k) in &shapes {
        let x = gauss(m, k, &mut rng);
        let w = gauss(n, k, &mut rng);
        for fmt in [GemmFormat::Nested16, GemmFormat::Nested8] {
            let g = GemmWeights::prepare(&w, fmt).unwrap();
            let base = GemmEngine::new(GemmConfig {
                mc: 4,
                kc: 8,
                nc: 8,
                threads: 1,
            })
            .matmul(&x, &g, fmt);
            for threads in [2, 8] {
                let c = GemmEngine::new(GemmConfig {
                    mc: 4,
                    kc: 8,
                    nc: 8,
                    threads,
                })
                .matmul(&x, &g, fmt);
                bits_equal(&c, &base).unwrap_or_else(|e| {
                    panic!("shape ({m},{n},{k}) {fmt:?} threads={threads}: {e}")
                });
            }
        }
    }
}

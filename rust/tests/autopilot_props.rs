//! Property tests on the SLO autopilot's stability guarantees:
//!
//! (a) **no thrash** — under *any* pressure/boost series, a replica's
//!     directive never oscillates faster than the dwell/cooldown
//!     discipline allows (FP16 → FP8 round trips are bounded below);
//! (b) **monotone ramps, monotone escalation** — a non-decreasing
//!     pressure ramp never makes the ladder (or any replica's rung)
//!     step back down;
//! (c) **never worse than the quality baseline** — on seeded end-to-end
//!     surge replays, the autopilot's SLO-violation seconds stay within
//!     the static-FP16 arm's (±1 s: discrete-event scheduling is not
//!     perfectly monotone in service speed).

use nestedfp::coordinator::autopilot::{Autopilot, AutopilotConfig};
use nestedfp::coordinator::backend::SimBackend;
use nestedfp::coordinator::cluster::{ClusterConfig, ClusterRouter, SurgeConfig};
use nestedfp::coordinator::engine::EngineConfig;
use nestedfp::coordinator::precision::{PrecisionDirective, PrecisionPolicy, SloConfig};
use nestedfp::coordinator::router::RoutingPolicy;
use nestedfp::gpusim::WeightFormat;
use nestedfp::kvcache::KvPressureConfig;
use nestedfp::model::zoo;
use nestedfp::trace::workload::{build_requests, poisson_arrivals, surge_rates, WorkloadConfig};
use nestedfp::util::prop;
use nestedfp::util::rng::Pcg64;

// ---------------------------------------------------------------------------
// (a) dwell discipline under adversarial inputs
// ---------------------------------------------------------------------------

#[test]
fn prop_no_oscillation_faster_than_the_dwell_time() {
    prop::check_res(
        "autopilot-no-thrash",
        40,
        |rng: &mut Pcg64| {
            // adversarial series: per-tick random per-replica pressures
            // (0..2, straddling both thresholds) and predictor boosts
            (0..160)
                .map(|_| {
                    (
                        [rng.f64() * 2.0, rng.f64() * 2.0, rng.f64() * 2.0],
                        rng.f64() * 0.8,
                    )
                })
                .collect::<Vec<_>>()
        },
        |series| {
            let cfg = AutopilotConfig::default();
            let mut ap = Autopilot::new(3, cfg);
            let hr = [0.0; 3];
            let mut t = 0.0;
            for (p, boost) in series {
                ap.control_at(t, p, *boost, &hr);
                t += cfg.control_interval_s;
            }
            let min_dwell = cfg.escalate_dwell_s.min(cfg.promote_dwell_s);
            for i in 0..3 {
                let tl = ap.directive_timeline(i);
                // any two consecutive switches respect the tighter dwell
                for w in tl.windows(2) {
                    let gap = w[1].0 - w[0].0;
                    if gap + 1e-9 < min_dwell {
                        return Err(format!(
                            "replica {i}: switches {gap:.3}s apart (< dwell {min_dwell})"
                        ));
                    }
                }
                // FP16 <-> FP8 round trips are bounded below: reaching
                // FP8 from FP16 crosses Mixed under the escalate dwell
                // (and post-promotion cooldown); coming back crosses
                // Mixed under the promote dwell twice
                let mut last_fp16: Option<f64> = None;
                let mut last_fp8: Option<f64> = None;
                for &(at, d) in tl {
                    match d {
                        PrecisionDirective::Fp8 => {
                            if let Some(t16) = last_fp16 {
                                let lb = cfg.cooldown_s.max(cfg.escalate_dwell_s)
                                    + cfg.escalate_dwell_s;
                                if at - t16 + 1e-9 < lb {
                                    return Err(format!(
                                        "replica {i}: FP16->FP8 in {:.3}s (< {lb})",
                                        at - t16
                                    ));
                                }
                            }
                            last_fp8 = Some(at);
                        }
                        PrecisionDirective::Fp16 => {
                            if let Some(t8) = last_fp8 {
                                let lb = 2.0 * cfg.promote_dwell_s;
                                if at - t8 + 1e-9 < lb {
                                    return Err(format!(
                                        "replica {i}: FP8->FP16 in {:.3}s (< {lb})",
                                        at - t8
                                    ));
                                }
                            }
                            last_fp16 = Some(at);
                        }
                        PrecisionDirective::Mixed => {}
                    }
                }
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// (b) monotone escalation
// ---------------------------------------------------------------------------

#[test]
fn prop_monotone_ramps_escalate_monotonically() {
    prop::check_res(
        "autopilot-monotone-ramp",
        50,
        |rng: &mut Pcg64| {
            // a random non-decreasing ramp from calm to overload
            let peak = 1.0 + rng.f64() * 2.0;
            let steps = 40 + rng.range_u64(0, 60) as usize;
            let mut v = 0.0;
            let mut ramp = Vec::with_capacity(steps);
            for _ in 0..steps {
                v = (v + rng.f64() * 2.5 * peak / steps as f64).min(peak);
                ramp.push(v);
            }
            ramp
        },
        |ramp| {
            let cfg = AutopilotConfig::default();
            let mut ap = Autopilot::new(2, cfg);
            let hr = [0.0; 2];
            let mut t = 0.0;
            let mut last_sev = 0usize;
            let mut last_rungs = [0usize; 2];
            for &p in ramp {
                let dirs = ap.control_at(t, &[p, p], 0.0, &hr);
                if ap.severity() < last_sev {
                    return Err(format!(
                        "ladder stepped down ({} -> {}) at pressure {p:.2}",
                        last_sev,
                        ap.severity()
                    ));
                }
                last_sev = ap.severity();
                for (i, d) in dirs.iter().enumerate() {
                    if d.rung() < last_rungs[i] {
                        return Err(format!(
                            "replica {i} demoted its rung ({} -> {}) on a monotone ramp",
                            last_rungs[i],
                            d.rung()
                        ));
                    }
                    last_rungs[i] = d.rung();
                }
                t += cfg.control_interval_s;
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// (c) end-to-end: never worse than static FP16, any seed
// ---------------------------------------------------------------------------

/// One small surge replay: 40 s at 3 req/s with a 4x plateau, two
/// sim-H100 replicas. Returns cluster SLO-violation seconds.
fn mini_surge_violations(
    policy: PrecisionPolicy,
    autopilot: Option<AutopilotConfig>,
    seed: u64,
) -> usize {
    let spec = zoo::find("llama31-8b").expect("llama31-8b in the zoo");
    let max_seq = 512;
    let backends: Vec<SimBackend> = (0..2)
        .map(|_| {
            SimBackend::new(
                spec,
                WeightFormat::Nested16,
                WeightFormat::Nested8,
                64,
                max_seq,
                64 * (max_seq / 16 + 1) * 2,
            )
        })
        .collect();
    let cfg = ClusterConfig {
        policy: RoutingPolicy::SloHeadroom,
        engine: EngineConfig {
            policy,
            slo: SloConfig::default(),
            physical_kv: false,
            max_iterations: 0,
            kv: KvPressureConfig::default(),
            devices: 1,
        },
        surge: SurgeConfig::disabled(),
        autopilot,
        ..ClusterConfig::default()
    };
    let rates = surge_rates(3.0, 4.0, 40, 12, 10);
    let arrivals = poisson_arrivals(&rates, seed);
    let wl = WorkloadConfig {
        seed: seed ^ 0x5eed,
        input_len: 0,
        output_len: 0,
        chunk_align: 64,
    };
    let mut requests = build_requests(&arrivals, &wl, max_seq);
    for r in &mut requests {
        r.max_new_tokens = r.max_new_tokens.min(64);
    }
    let n = requests.len();
    let mut cluster = ClusterRouter::new(backends, cfg);
    let report = cluster.run(requests).expect("mini surge must drain");
    assert_eq!(report.aggregate.completed, n, "workload did not drain");
    report
        .aggregate
        .slo_violation_seconds(&SloConfig::default())
}

#[test]
fn prop_autopilot_violations_at_most_static_fp16() {
    // same seed, same arrivals, same shapes — only the control differs.
    // ±1 s slack: a discrete-event schedule is not perfectly monotone in
    // service speed (a faster early iteration can regroup later batches).
    for seed in [3u64, 11, 29, 57, 101] {
        let f16 = mini_surge_violations(PrecisionPolicy::Fp16Only, None, seed);
        let ap = mini_surge_violations(
            PrecisionPolicy::Dual,
            Some(AutopilotConfig::default()),
            seed,
        );
        assert!(
            ap <= f16 + 1,
            "seed {seed}: autopilot violated {ap}s, static fp16 only {f16}s"
        );
    }
}

//! Offline-vendored minimal stand-in for the [`anyhow`] crate.
//!
//! The build environment has no crates.io access, so this path dependency
//! provides exactly the API subset the nestedfp codebase uses:
//!
//! * [`Error`] — a message-chain error type (context outermost-first),
//! * [`Result<T>`] — `Result<T, Error>` alias with a default type param,
//! * [`anyhow!`] / [`bail!`] / [`ensure!`] — format-style constructors,
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`.
//!
//! Like the real crate, `Error` intentionally does **not** implement
//! `std::error::Error`: that is what allows the blanket
//! `impl<E: std::error::Error> From<E> for Error` to coexist with the
//! standard reflexive `From<Error> for Error` impl (so `?` works on both
//! std errors and `Error` itself).
//!
//! [`anyhow`]: https://docs.rs/anyhow

use std::fmt;

/// A chain of error messages, outermost context first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            chain: vec![message.to_string()],
        }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// Iterate the message chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost (root) message of the chain.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` renders the whole chain, like the real anyhow
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(self.chain.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.chain.first().map(|s| s.as_str()).unwrap_or(""))?;
        if self.chain.len() > 1 {
            f.write_str("\n\nCaused by:")?;
            for (i, cause) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `Result` with this crate's [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to the error variant of a `Result` (or a `None`).
pub trait Context<T> {
    /// Wrap the error with an eagerly evaluated context message.
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    /// Wrap the error with a lazily evaluated context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_and_alternate() {
        let e = anyhow!("inner {}", 7).context("outer");
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: inner 7");
        assert_eq!(e.root_cause(), "inner 7");
    }

    #[test]
    fn question_mark_on_std_and_own_errors() {
        fn f() -> Result<u32> {
            let n = "not-a-number".parse::<u32>()?; // std error via `?`
            Ok(n)
        }
        fn g() -> Result<u32> {
            let n = f().with_context(|| "calling f")?; // own error via `?`
            Ok(n)
        }
        let e = g().unwrap_err();
        let rendered = format!("{e:#}");
        assert!(
            rendered.starts_with("calling f: "),
            "context lost: {rendered}"
        );
    }

    #[test]
    fn bail_and_ensure() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative: {x}");
            if x == 0 {
                bail!("zero");
            }
            Ok(x)
        }
        assert!(f(1).is_ok());
        assert_eq!(format!("{}", f(0).unwrap_err()), "zero");
        assert_eq!(format!("{}", f(-2).unwrap_err()), "negative: -2");
    }

    #[test]
    fn option_context() {
        let v: Option<i32> = None;
        let e = v.context("empty").unwrap_err();
        assert_eq!(format!("{e}"), "empty");
    }
}

//! Stub runtime used when the `pjrt` feature is disabled.
//!
//! Mirrors the public surface of the real `client` module (`ModelRuntime`,
//! `StepExecutable`, `StepOutput`, `log`) so the rest of the crate compiles
//! unchanged. Since PR 5 the stub **loads** artifacts for real — the
//! manifest and weight store are plain files, and the host-native step
//! path (`coordinator::hostforward` + the block-native attention
//! engine) serves prefill/decode from them without any compiled
//! executable. Only artifact *execution* (`step`/`run`, the
//! artifact-parity tests) still needs the `xla` PJRT bindings, which
//! link the XLA C++ runtime and are unavailable in offline builds;
//! those entry points return a descriptive error here.

use std::path::Path;

use anyhow::{bail, Result};

use super::artifacts::{ExecSpec, Manifest};
use super::tensor::HostTensor;
use super::weights::WeightStore;

/// One step function descriptor (never executable in this build).
pub struct StepExecutable {
    pub spec: ExecSpec,
}

/// Raw outputs of a step execution (never produced in this build).
#[derive(Debug)]
pub struct StepOutput {
    pub tensors: Vec<HostTensor>,
    /// Device-side execution time (compile-level; excludes input upload).
    pub exec_micros: u64,
}

/// The model runtime stub: parses artifacts (enough for the host-native
/// backend), cannot execute the compiled step functions.
pub struct ModelRuntime {
    pub manifest: Manifest,
    pub weights: WeightStore,
}

impl ModelRuntime {
    /// Parse the artifact manifest and weight store. `modes`/`kinds`
    /// are validated against the manifest the same way the real client
    /// filters compilations, so an empty match still errors loudly.
    pub fn load(dir: &Path, modes: &[&str], kinds: &[&str]) -> Result<ModelRuntime> {
        let manifest = Manifest::load(dir)?;
        let weights = WeightStore::load(&dir.join("weights.bin"))?;
        let matched = manifest
            .executables
            .iter()
            .filter(|e| modes.contains(&e.mode.as_str()) && kinds.contains(&e.kind.as_str()))
            .count();
        if matched == 0 {
            bail!("no executables matched modes {modes:?} kinds {kinds:?}");
        }
        Ok(ModelRuntime { manifest, weights })
    }

    pub fn step(&self, kind: &str, mode: &str, size: usize) -> Result<&StepExecutable> {
        bail!("executable ({kind}, {mode}, {size}): built without the `pjrt` feature")
    }

    pub fn loaded_keys(&self) -> Vec<(String, String, usize)> {
        Vec::new()
    }

    /// Execute a step (always fails in this build).
    pub fn run(&self, step: &StepExecutable, _dynamic: &[HostTensor]) -> Result<StepOutput> {
        bail!(
            "{}: built without the `pjrt` feature",
            step.spec.path.display()
        )
    }
}

/// Leveled diagnostics, delegated to the unified telemetry facade —
/// same surface as the pjrt build's `client::log`.
pub mod log {
    pub use crate::telemetry::log::{debug, info, set_verbose};
}

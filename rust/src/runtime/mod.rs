//! The PJRT execution layer (Layer 3 → Layer 2 bridge).
//!
//! Loads the AOT artifacts produced by `python/compile/aot.py`:
//! `weights.bin` (the single NestedFP weight store), `manifest.json`
//! (executable index) and `*.hlo.txt` (HLO text per step function), then
//! compiles and executes them on the PJRT CPU client via the `xla` crate.
//!
//! Key property: **weights are uploaded to the device once per mode** and
//! shared by every bucket executable of that mode; per-step calls upload
//! only the small dynamic inputs (tokens, positions, gathered KV).

pub mod tensor;
pub mod weights;
pub mod artifacts;

// The real PJRT client needs the `xla` bindings (XLA C++ runtime), which
// cannot be built offline. Without the `pjrt` feature a stub with the same
// surface loads manifests/weights (enough for the host-native backend:
// `coordinator::hostforward` serves prefill/decode from the store with
// block-native attention) but refuses to execute compiled artifacts —
// the simulation backend covers every figure and bench either way.
#[cfg(feature = "pjrt")]
pub mod client;
#[cfg(not(feature = "pjrt"))]
#[path = "client_stub.rs"]
pub mod client;

pub use artifacts::{ExecSpec, Manifest, ModelMeta};
pub use client::{ModelRuntime, StepExecutable, StepOutput};
pub use tensor::{Dtype, HostTensor};
pub use weights::WeightStore;

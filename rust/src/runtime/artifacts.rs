//! `manifest.json` — the executable index emitted by the AOT pipeline.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

use super::tensor::Dtype;

/// Model hyper-parameters (mirrors python ModelConfig).
#[derive(Clone, Debug)]
pub struct ModelMeta {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub max_seq: usize,
    pub head_dim: usize,
}

/// One weight input slot of an executable.
#[derive(Clone, Debug)]
pub struct WeightInput {
    pub name: String,
    pub dims: Vec<usize>,
    pub dtype: Dtype,
}

/// One dynamic input slot.
#[derive(Clone, Debug)]
pub struct DynInput {
    pub dims: Vec<usize>,
    pub dtype: Dtype,
}

/// Executable descriptor from the manifest.
#[derive(Clone, Debug)]
pub struct ExecSpec {
    /// "decode" | "prefill" | "gemm".
    pub kind: String,
    /// "fp16" | "nested16" | "nested8".
    pub mode: String,
    /// Batch bucket (decode) or chunk length (prefill); 0 for gemm.
    pub size: usize,
    pub path: PathBuf,
    pub weight_inputs: Vec<WeightInput>,
    pub dynamic_inputs: Vec<DynInput>,
}

/// Parsed manifest.
#[derive(Debug)]
pub struct Manifest {
    pub model: ModelMeta,
    pub decode_buckets: Vec<usize>,
    pub prefill_chunks: Vec<usize>,
    pub modes: Vec<String>,
    pub act_scales: BTreeMap<String, f64>,
    /// Linear layers that exceeded the NestedFP eligibility bound and
    /// stay FP16 in every mode (manifest `exception_layers`; names like
    /// `layers.3.w_down`). Empty for the in-repo trained model.
    pub exception_layers: Vec<String>,
    pub executables: Vec<ExecSpec>,
    pub dir: PathBuf,
    pub final_train_loss: Option<f64>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} (run `make artifacts` first)"))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("manifest parse: {e}"))?;

        let m = j.req("model").map_err(|e| anyhow!(e))?;
        let geti = |k: &str| -> Result<usize> {
            m.req(k)
                .map_err(|e| anyhow!(e))?
                .as_usize()
                .ok_or_else(|| anyhow!("model.{k} not a number"))
        };
        let model = ModelMeta {
            vocab: geti("vocab")?,
            d_model: geti("d_model")?,
            n_layers: geti("n_layers")?,
            n_heads: geti("n_heads")?,
            d_ff: geti("d_ff")?,
            max_seq: geti("max_seq")?,
            head_dim: geti("head_dim")?,
        };

        let usize_arr = |key: &str| -> Result<Vec<usize>> {
            Ok(j
                .req(key)
                .map_err(|e| anyhow!(e))?
                .as_arr()
                .ok_or_else(|| anyhow!("{key} not an array"))?
                .iter()
                .filter_map(|v| v.as_usize())
                .collect())
        };

        let mut act_scales = BTreeMap::new();
        if let Some(obj) = j.get("act_scales").and_then(|v| v.as_obj()) {
            for (k, v) in obj {
                if let Some(f) = v.as_f64() {
                    act_scales.insert(k.clone(), f);
                }
            }
        }

        let mut exception_layers = Vec::new();
        if let Some(obj) = j.get("exception_layers").and_then(|v| v.as_obj()) {
            for (k, v) in obj {
                if v.as_bool().unwrap_or(false) {
                    exception_layers.push(k.clone());
                }
            }
        }

        let mut executables = Vec::new();
        for e in j
            .req("executables")
            .map_err(|e| anyhow!(e))?
            .as_arr()
            .ok_or_else(|| anyhow!("executables not an array"))?
        {
            let kind = e
                .req("kind")
                .map_err(|e| anyhow!(e))?
                .as_str()
                .unwrap_or_default()
                .to_string();
            let mode = e
                .req("mode")
                .map_err(|e| anyhow!(e))?
                .as_str()
                .unwrap_or_default()
                .to_string();
            let size = e.get("size").and_then(|v| v.as_usize()).unwrap_or(0);
            let rel = e
                .req("path")
                .map_err(|e| anyhow!(e))?
                .as_str()
                .ok_or_else(|| anyhow!("path not a string"))?
                .to_string();
            let mut weight_inputs = Vec::new();
            if let Some(arr) = e.get("weight_inputs").and_then(|v| v.as_arr()) {
                for w in arr {
                    weight_inputs.push(WeightInput {
                        name: w
                            .req("name")
                            .map_err(|e| anyhow!(e))?
                            .as_str()
                            .unwrap_or_default()
                            .to_string(),
                        dims: w
                            .get("shape")
                            .and_then(|v| v.as_arr())
                            .map(|a| a.iter().filter_map(|x| x.as_usize()).collect())
                            .unwrap_or_default(),
                        dtype: Dtype::parse(
                            w.get("dtype").and_then(|v| v.as_str()).unwrap_or("float32"),
                        )?,
                    });
                }
            }
            let mut dynamic_inputs = Vec::new();
            if let Some(arr) = e.get("dynamic_inputs").and_then(|v| v.as_arr()) {
                for d in arr {
                    dynamic_inputs.push(DynInput {
                        dims: d
                            .get("shape")
                            .and_then(|v| v.as_arr())
                            .map(|a| a.iter().filter_map(|x| x.as_usize()).collect())
                            .unwrap_or_default(),
                        dtype: Dtype::parse(
                            d.get("dtype").and_then(|v| v.as_str()).unwrap_or("float32"),
                        )?,
                    });
                }
            }
            executables.push(ExecSpec {
                kind,
                mode,
                size,
                path: dir.join(rel),
                weight_inputs,
                dynamic_inputs,
            });
        }

        if executables.is_empty() {
            bail!("manifest has no executables");
        }

        Ok(Manifest {
            model,
            decode_buckets: usize_arr("decode_buckets")?,
            prefill_chunks: usize_arr("prefill_chunks")?,
            modes: j
                .req("modes")
                .map_err(|e| anyhow!(e))?
                .as_arr()
                .map(|a| {
                    a.iter()
                        .filter_map(|v| v.as_str().map(|s| s.to_string()))
                        .collect()
                })
                .unwrap_or_default(),
            act_scales,
            exception_layers,
            executables,
            dir: dir.to_path_buf(),
            final_train_loss: j.get("final_train_loss").and_then(|v| v.as_f64()),
        })
    }

    /// Find the step executable for (kind, mode, size).
    pub fn find(&self, kind: &str, mode: &str, size: usize) -> Result<&ExecSpec> {
        self.executables
            .iter()
            .find(|e| e.kind == kind && e.mode == mode && e.size == size)
            .ok_or_else(|| anyhow!("no executable for ({kind}, {mode}, size {size})"))
    }

    /// Smallest decode bucket >= n (falls back to the largest).
    pub fn decode_bucket_for(&self, n: usize) -> usize {
        self.decode_buckets
            .iter()
            .copied()
            .find(|&b| b >= n)
            .unwrap_or_else(|| *self.decode_buckets.last().unwrap())
    }

    /// Largest prefill chunk <= n (falls back to the smallest).
    pub fn prefill_chunk_for(&self, n: usize) -> usize {
        self.prefill_chunks
            .iter()
            .rev()
            .copied()
            .find(|&c| c <= n)
            .unwrap_or_else(|| *self.prefill_chunks.first().unwrap())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "model": {"vocab": 256, "d_model": 256, "n_layers": 4, "n_heads": 8,
                "d_ff": 704, "max_seq": 256, "head_dim": 32},
      "decode_buckets": [1, 2, 4, 8],
      "prefill_chunks": [32, 64],
      "modes": ["fp16", "nested16", "nested8"],
      "act_scales": {"layers.0.wq": 30.5},
      "exception_layers": {"layers.1.w_down": true},
      "final_train_loss": 1.98,
      "executables": [
        {"kind": "decode", "mode": "fp16", "size": 2, "path": "decode_fp16_b2.hlo.txt",
         "weight_inputs": [{"name": "embed", "shape": [256, 256], "dtype": "uint16"}],
         "dynamic_inputs": [{"shape": [2], "dtype": "int32"}]}
      ]
    }"#;

    #[test]
    fn parse_sample() {
        let dir = std::env::temp_dir().join("nestedfp_mtest");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), SAMPLE).unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.model.d_model, 256);
        assert_eq!(m.decode_buckets, vec![1, 2, 4, 8]);
        let e = m.find("decode", "fp16", 2).unwrap();
        assert_eq!(e.weight_inputs[0].dtype, Dtype::U16);
        assert_eq!(e.dynamic_inputs[0].dims, vec![2]);
        assert!(m.find("decode", "fp16", 9).is_err());
        assert!((m.act_scales["layers.0.wq"] - 30.5).abs() < 1e-12);
        assert_eq!(m.exception_layers, vec!["layers.1.w_down".to_string()]);
    }

    #[test]
    fn bucket_selection() {
        let dir = std::env::temp_dir().join("nestedfp_mtest");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), SAMPLE).unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.decode_bucket_for(1), 1);
        assert_eq!(m.decode_bucket_for(3), 4);
        assert_eq!(m.decode_bucket_for(100), 8);
        assert_eq!(m.prefill_chunk_for(100), 64);
        assert_eq!(m.prefill_chunk_for(40), 32);
        assert_eq!(m.prefill_chunk_for(10), 32);
    }
}

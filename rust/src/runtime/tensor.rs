//! Host-side raw tensors crossing the PJRT boundary.

use anyhow::{bail, Result};

/// Element dtype of an artifact tensor.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    U8,
    U16,
    F32,
    I32,
}

impl Dtype {
    pub fn size(self) -> usize {
        match self {
            Dtype::U8 => 1,
            Dtype::U16 => 2,
            Dtype::F32 | Dtype::I32 => 4,
        }
    }

    /// Parse numpy dtype names from the manifest.
    pub fn parse(s: &str) -> Result<Dtype> {
        Ok(match s {
            "uint8" => Dtype::U8,
            "uint16" | "float16" => Dtype::U16,
            "float32" => Dtype::F32,
            "int32" => Dtype::I32,
            other => bail!("unsupported dtype '{other}'"),
        })
    }

    #[cfg(feature = "pjrt")]
    pub fn to_xla(self) -> xla::ElementType {
        match self {
            Dtype::U8 => xla::ElementType::U8,
            Dtype::U16 => xla::ElementType::U16,
            Dtype::F32 => xla::ElementType::F32,
            Dtype::I32 => xla::ElementType::S32,
        }
    }
}

/// A dense host tensor as raw little-endian bytes.
#[derive(Clone, Debug)]
pub struct HostTensor {
    pub dtype: Dtype,
    pub dims: Vec<usize>,
    pub bytes: Vec<u8>,
}

impl HostTensor {
    pub fn new(dtype: Dtype, dims: Vec<usize>, bytes: Vec<u8>) -> Result<Self> {
        let n: usize = dims.iter().product();
        if n * dtype.size() != bytes.len() {
            bail!(
                "tensor bytes/shape mismatch: dims {dims:?} x {} != {} bytes",
                dtype.size(),
                bytes.len()
            );
        }
        Ok(HostTensor { dtype, dims, bytes })
    }

    pub fn from_f32(dims: Vec<usize>, data: &[f32]) -> Self {
        let mut bytes = Vec::with_capacity(data.len() * 4);
        for v in data {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        HostTensor {
            dtype: Dtype::F32,
            dims,
            bytes,
        }
    }

    pub fn from_i32(dims: Vec<usize>, data: &[i32]) -> Self {
        let mut bytes = Vec::with_capacity(data.len() * 4);
        for v in data {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        HostTensor {
            dtype: Dtype::I32,
            dims,
            bytes,
        }
    }

    pub fn from_u16(dims: Vec<usize>, data: &[u16]) -> Self {
        let mut bytes = Vec::with_capacity(data.len() * 2);
        for v in data {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        HostTensor {
            dtype: Dtype::U16,
            dims,
            bytes,
        }
    }

    pub fn from_u8(dims: Vec<usize>, data: Vec<u8>) -> Self {
        HostTensor {
            dtype: Dtype::U8,
            dims,
            bytes: data,
        }
    }

    pub fn element_count(&self) -> usize {
        self.dims.iter().product()
    }

    pub fn as_f32(&self) -> Result<Vec<f32>> {
        if self.dtype != Dtype::F32 {
            bail!("tensor is {:?}, not F32", self.dtype);
        }
        Ok(self
            .bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    pub fn as_u16(&self) -> Result<Vec<u16>> {
        if self.dtype != Dtype::U16 {
            bail!("tensor is {:?}, not U16", self.dtype);
        }
        Ok(self
            .bytes
            .chunks_exact(2)
            .map(|c| u16::from_le_bytes([c[0], c[1]]))
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_f32() {
        let t = HostTensor::from_f32(vec![2, 2], &[1.0, -2.0, 0.5, 3.25]);
        assert_eq!(t.element_count(), 4);
        assert_eq!(t.as_f32().unwrap(), vec![1.0, -2.0, 0.5, 3.25]);
    }

    #[test]
    fn shape_validation() {
        assert!(HostTensor::new(Dtype::F32, vec![3], vec![0u8; 12]).is_ok());
        assert!(HostTensor::new(Dtype::F32, vec![3], vec![0u8; 8]).is_err());
    }

    #[test]
    fn dtype_parse() {
        assert_eq!(Dtype::parse("float16").unwrap(), Dtype::U16);
        assert_eq!(Dtype::parse("uint8").unwrap(), Dtype::U8);
        assert!(Dtype::parse("complex64").is_err());
    }
}

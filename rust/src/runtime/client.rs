//! PJRT client wrapper: compile HLO-text artifacts, keep weights
//! device-resident, execute step functions from the serving hot path.

use std::collections::HashMap;
use std::path::Path;
use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use super::artifacts::{ExecSpec, Manifest};
use super::tensor::HostTensor;
use super::weights::WeightStore;

/// One compiled step function plus its device-resident weight buffers.
pub struct StepExecutable {
    pub spec: ExecSpec,
    exe: xla::PjRtLoadedExecutable,
    weight_bufs: Vec<xla::PjRtBuffer>,
}

/// Raw outputs of a step execution, already copied back to the host.
#[derive(Debug)]
pub struct StepOutput {
    pub tensors: Vec<HostTensor>,
    /// Device-side execution time (compile-level; excludes input upload).
    pub exec_micros: u64,
}

/// The model runtime: PJRT client + all compiled executables for the
/// modes requested, sharing one weight store.
pub struct ModelRuntime {
    pub manifest: Manifest,
    pub weights: WeightStore,
    client: xla::PjRtClient,
    steps: HashMap<(String, String, usize), StepExecutable>,
}

impl ModelRuntime {
    /// Load artifacts and compile the executables for `modes` (e.g.
    /// `["nested16", "nested8"]`). `kinds` filters decode/prefill/gemm.
    pub fn load(dir: &Path, modes: &[&str], kinds: &[&str]) -> Result<ModelRuntime> {
        let manifest = Manifest::load(dir)?;
        let weights = WeightStore::load(&dir.join("weights.bin"))?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;

        let mut steps = HashMap::new();
        let specs: Vec<ExecSpec> = manifest
            .executables
            .iter()
            .filter(|e| modes.contains(&e.mode.as_str()) && kinds.contains(&e.kind.as_str()))
            .cloned()
            .collect();
        for spec in specs {
            let t0 = Instant::now();
            let exe = compile_hlo(&client, &spec.path)?;
            let weight_bufs = upload_weights(&client, &spec, &weights)?;
            log::debug(&format!(
                "compiled {} ({:.2}s, {} weight buffers)",
                spec.path.display(),
                t0.elapsed().as_secs_f64(),
                weight_bufs.len()
            ));
            steps.insert(
                (spec.kind.clone(), spec.mode.clone(), spec.size),
                StepExecutable {
                    spec,
                    exe,
                    weight_bufs,
                },
            );
        }
        if steps.is_empty() {
            bail!("no executables matched modes {modes:?} kinds {kinds:?}");
        }
        Ok(ModelRuntime {
            manifest,
            weights,
            client,
            steps,
        })
    }

    pub fn step(&self, kind: &str, mode: &str, size: usize) -> Result<&StepExecutable> {
        self.steps
            .get(&(kind.to_string(), mode.to_string(), size))
            .ok_or_else(|| anyhow!("executable ({kind}, {mode}, {size}) not loaded"))
    }

    pub fn loaded_keys(&self) -> Vec<(String, String, usize)> {
        let mut v: Vec<_> = self.steps.keys().cloned().collect();
        v.sort();
        v
    }

    /// Execute a step with the given dynamic inputs (must match the
    /// spec's dynamic signature). Weight buffers are reused from device
    /// memory; only dynamic inputs cross the host boundary.
    pub fn run(&self, step: &StepExecutable, dynamic: &[HostTensor]) -> Result<StepOutput> {
        if dynamic.len() != step.spec.dynamic_inputs.len() {
            bail!(
                "{}: expected {} dynamic inputs, got {}",
                step.spec.path.display(),
                step.spec.dynamic_inputs.len(),
                dynamic.len()
            );
        }
        for (i, (t, d)) in dynamic.iter().zip(&step.spec.dynamic_inputs).enumerate() {
            if t.dims != d.dims || t.dtype != d.dtype {
                bail!(
                    "dynamic input {i}: got {:?}{:?}, want {:?}{:?}",
                    t.dtype,
                    t.dims,
                    d.dtype,
                    d.dims
                );
            }
        }

        let mut args: Vec<&xla::PjRtBuffer> = step.weight_bufs.iter().collect();
        let dyn_bufs: Vec<xla::PjRtBuffer> = dynamic
            .iter()
            .map(|t| upload_tensor(&self.client, t))
            .collect::<Result<_>>()?;
        args.extend(dyn_bufs.iter());

        let t0 = Instant::now();
        let result = step
            .exe
            .execute_b(&args)
            .map_err(|e| anyhow!("execute {}: {e:?}", step.spec.path.display()))?;
        let exec_micros = t0.elapsed().as_micros() as u64;

        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch output: {e:?}"))?;
        let parts = tuple
            .to_tuple()
            .map_err(|e| anyhow!("untuple output: {e:?}"))?;
        let tensors = parts
            .into_iter()
            .map(literal_to_host)
            .collect::<Result<Vec<_>>>()?;
        Ok(StepOutput {
            tensors,
            exec_micros,
        })
    }
}

fn compile_hlo(client: &xla::PjRtClient, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
    let proto = xla::HloModuleProto::from_text_file(
        path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
    )
    .map_err(|e| anyhow!("parsing HLO {path:?}: {e:?}"))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client
        .compile(&comp)
        .map_err(|e| anyhow!("compiling {path:?}: {e:?}"))
}

/// Upload a host tensor with the *typed* PJRT entry point.
///
/// NOTE: the crate's `buffer_from_host_raw_bytes` is buggy — it passes the
/// `ElementType` discriminant (U16=6) where the C API expects the XLA
/// `PrimitiveType` numbering (U16=7), silently creating a buffer of the
/// wrong element type. The typed `buffer_from_host_buffer::<T>` goes
/// through `T::TY.primitive_type()` and is correct.
pub fn upload_tensor(client: &xla::PjRtClient, t: &HostTensor) -> Result<xla::PjRtBuffer> {
    use super::tensor::Dtype;
    let res = match t.dtype {
        Dtype::U8 => client.buffer_from_host_buffer(&t.bytes, &t.dims, None),
        Dtype::U16 => {
            let v: Vec<u16> = t
                .bytes
                .chunks_exact(2)
                .map(|c| u16::from_le_bytes([c[0], c[1]]))
                .collect();
            client.buffer_from_host_buffer(&v, &t.dims, None)
        }
        Dtype::F32 => {
            let v: Vec<f32> = t
                .bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            client.buffer_from_host_buffer(&v, &t.dims, None)
        }
        Dtype::I32 => {
            let v: Vec<i32> = t
                .bytes
                .chunks_exact(4)
                .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            client.buffer_from_host_buffer(&v, &t.dims, None)
        }
    };
    res.map_err(|e| anyhow!("uploading {:?}{:?}: {e:?}", t.dtype, t.dims))
}

fn upload_weights(
    client: &xla::PjRtClient,
    spec: &ExecSpec,
    store: &WeightStore,
) -> Result<Vec<xla::PjRtBuffer>> {
    spec.weight_inputs
        .iter()
        .map(|w| {
            let t = store.get(&w.name)?;
            if t.dims != w.dims {
                bail!(
                    "weight {}: store dims {:?} != spec dims {:?}",
                    w.name,
                    t.dims,
                    w.dims
                );
            }
            if t.dtype != w.dtype {
                bail!(
                    "weight {}: store dtype {:?} != spec dtype {:?}",
                    w.name,
                    t.dtype,
                    w.dtype
                );
            }
            upload_tensor(client, t)
        })
        .collect()
}

fn literal_to_host(lit: xla::Literal) -> Result<HostTensor> {
    use super::tensor::Dtype;
    let shape = lit
        .array_shape()
        .map_err(|e| anyhow!("output shape: {e:?}"))?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let prim = lit
        .ty()
        .map_err(|e| anyhow!("output type: {e:?}"))?;
    if prim == xla::ElementType::F16 {
        // f16 outputs are value-converted to f32 on the way out (the
        // crate's typed copy rejects reading F16 as u16 bits)
        let conv = lit
            .convert(xla::PrimitiveType::F32)
            .map_err(|e| anyhow!("f16->f32 convert: {e:?}"))?;
        return literal_to_host(conv);
    }
    let dtype = match prim {
        xla::ElementType::F32 => Dtype::F32,
        xla::ElementType::S32 => Dtype::I32,
        xla::ElementType::U8 => Dtype::U8,
        xla::ElementType::U16 => Dtype::U16,
        other => bail!("unsupported output element type {other:?}"),
    };
    let n: usize = dims.iter().product();
    let mut bytes = vec![0u8; n * dtype.size()];
    // copy_raw_to is typed; use the matching width
    match dtype {
        Dtype::F32 => {
            let mut v = vec![0f32; n];
            lit.copy_raw_to(&mut v).map_err(|e| anyhow!("copy out: {e:?}"))?;
            for (i, x) in v.iter().enumerate() {
                bytes[i * 4..i * 4 + 4].copy_from_slice(&x.to_le_bytes());
            }
        }
        Dtype::I32 => {
            let mut v = vec![0i32; n];
            lit.copy_raw_to(&mut v).map_err(|e| anyhow!("copy out: {e:?}"))?;
            for (i, x) in v.iter().enumerate() {
                bytes[i * 4..i * 4 + 4].copy_from_slice(&x.to_le_bytes());
            }
        }
        Dtype::U16 => {
            let mut v = vec![0u16; n];
            lit.copy_raw_to(&mut v).map_err(|e| anyhow!("copy out: {e:?}"))?;
            for (i, x) in v.iter().enumerate() {
                bytes[i * 2..i * 2 + 2].copy_from_slice(&x.to_le_bytes());
            }
        }
        Dtype::U8 => {
            let mut v = vec![0u8; n];
            lit.copy_raw_to(&mut v).map_err(|e| anyhow!("copy out: {e:?}"))?;
            bytes.copy_from_slice(&v);
        }
    }
    HostTensor::new(dtype, dims, bytes)
}

/// Leveled diagnostics, delegated to the unified telemetry facade.
/// Kept as `client::log` so both feature configs expose the same
/// surface; `set_verbose(true)` raises the global level to debug.
pub mod log {
    pub use crate::telemetry::log::{debug, info, set_verbose};
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::tensor::{Dtype, HostTensor};

    #[test]
    fn typed_upload_preserves_element_type() {
        // regression for the crate's raw-bytes entry point, which maps
        // ElementType::U16 (=6) to PrimitiveType U8 (=6) — see
        // upload_tensor's doc comment.
        let client = xla::PjRtClient::cpu().unwrap();
        let t = HostTensor::from_u16(vec![4, 2], &[1, 2, 3, 4, 5, 6, 7, 8]);
        let buf = upload_tensor(&client, &t).unwrap();
        let shape = buf.on_device_shape().unwrap();
        match shape {
            xla::Shape::Array(a) => {
                assert_eq!(a.ty(), xla::ElementType::U16);
                assert_eq!(a.dims(), &[4, 2]);
            }
            other => panic!("unexpected shape {other:?}"),
        }
        assert_eq!(t.dims, vec![4, 2]);
        assert_eq!(t.dtype, Dtype::U16);
    }
}

//! `weights.bin` reader — the single NestedFP weight store.
//!
//! Format (written by `python/compile/aot.py::write_weights_bin`):
//!
//! ```text
//! magic "NFPW" | u32 version | u32 count
//! per tensor:
//!   u16 name_len | name | u8 dtype (0=u8,1=u16,2=f32,3=i32) | u8 ndim
//!   u32 dims[ndim] | u64 byte_len | raw little-endian payload
//! ```

use std::collections::BTreeMap;
use std::io::Read;
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::tensor::{Dtype, HostTensor};

/// All serving weights, keyed by tensor name (e.g. `layers.0.wq.upper`).
#[derive(Debug)]
pub struct WeightStore {
    pub tensors: BTreeMap<String, HostTensor>,
}

impl WeightStore {
    pub fn load(path: &Path) -> Result<WeightStore> {
        let mut f = std::fs::File::open(path)
            .with_context(|| format!("opening weight store {path:?}"))?;
        let mut magic = [0u8; 4];
        f.read_exact(&mut magic)?;
        if &magic != b"NFPW" {
            bail!("{path:?}: bad magic {magic:?}");
        }
        let version = read_u32(&mut f)?;
        if version != 1 {
            bail!("{path:?}: unsupported version {version}");
        }
        let count = read_u32(&mut f)? as usize;
        let mut tensors = BTreeMap::new();
        for _ in 0..count {
            let name_len = read_u16(&mut f)? as usize;
            let mut name_bytes = vec![0u8; name_len];
            f.read_exact(&mut name_bytes)?;
            let name = String::from_utf8(name_bytes).context("tensor name utf8")?;
            let mut hdr = [0u8; 2];
            f.read_exact(&mut hdr)?;
            let dtype = match hdr[0] {
                0 => Dtype::U8,
                1 => Dtype::U16,
                2 => Dtype::F32,
                3 => Dtype::I32,
                other => bail!("{name}: bad dtype code {other}"),
            };
            let ndim = hdr[1] as usize;
            let mut dims = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                dims.push(read_u32(&mut f)? as usize);
            }
            let byte_len = read_u64(&mut f)? as usize;
            let mut bytes = vec![0u8; byte_len];
            f.read_exact(&mut bytes)?;
            let t = HostTensor::new(dtype, dims, bytes)
                .with_context(|| format!("tensor {name}"))?;
            tensors.insert(name, t);
        }
        Ok(WeightStore { tensors })
    }

    pub fn get(&self, name: &str) -> Result<&HostTensor> {
        self.tensors
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("weight '{name}' missing from store"))
    }

    /// Total bytes — the paper's memory-footprint headline: the nested
    /// planes plus fp16 masters. `nested_only_bytes` counts just the
    /// deployable store (upper+lower), which equals one fp16 copy.
    pub fn total_bytes(&self) -> usize {
        self.tensors.values().map(|t| t.bytes.len()).sum()
    }

    /// Bytes of the dual-precision store alone (upper + lower planes).
    pub fn nested_plane_bytes(&self) -> usize {
        self.tensors
            .iter()
            .filter(|(k, _)| k.ends_with(".upper") || k.ends_with(".lower"))
            .map(|(_, t)| t.bytes.len())
            .sum()
    }

    /// Bytes of the fp16 linear-layer masters (what separate-storage
    /// co-deployment would duplicate).
    pub fn f16_linear_bytes(&self) -> usize {
        self.tensors
            .iter()
            .filter(|(k, _)| k.ends_with(".f16"))
            .map(|(_, t)| t.bytes.len())
            .sum()
    }
}

fn read_u16(f: &mut impl Read) -> Result<u16> {
    let mut b = [0u8; 2];
    f.read_exact(&mut b)?;
    Ok(u16::from_le_bytes(b))
}

fn read_u32(f: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    f.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64(f: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    f.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_test_store(path: &Path) {
        // one u8 tensor [2,3], one f32 tensor [2]
        let mut f = std::fs::File::create(path).unwrap();
        f.write_all(b"NFPW").unwrap();
        f.write_all(&1u32.to_le_bytes()).unwrap();
        f.write_all(&2u32.to_le_bytes()).unwrap();
        // "a.upper"
        let name = b"a.upper";
        f.write_all(&(name.len() as u16).to_le_bytes()).unwrap();
        f.write_all(name).unwrap();
        f.write_all(&[0u8, 2u8]).unwrap();
        f.write_all(&2u32.to_le_bytes()).unwrap();
        f.write_all(&3u32.to_le_bytes()).unwrap();
        f.write_all(&6u64.to_le_bytes()).unwrap();
        f.write_all(&[1, 2, 3, 4, 5, 6]).unwrap();
        // "b"
        let name = b"b";
        f.write_all(&(name.len() as u16).to_le_bytes()).unwrap();
        f.write_all(name).unwrap();
        f.write_all(&[2u8, 1u8]).unwrap();
        f.write_all(&2u32.to_le_bytes()).unwrap();
        f.write_all(&8u64.to_le_bytes()).unwrap();
        f.write_all(&1.5f32.to_le_bytes()).unwrap();
        f.write_all(&(-2.0f32).to_le_bytes()).unwrap();
    }

    #[test]
    fn parse_roundtrip() {
        let dir = std::env::temp_dir().join("nestedfp_wtest");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("weights.bin");
        write_test_store(&path);
        let ws = WeightStore::load(&path).unwrap();
        let a = ws.get("a.upper").unwrap();
        assert_eq!(a.dims, vec![2, 3]);
        assert_eq!(a.bytes, vec![1, 2, 3, 4, 5, 6]);
        let b = ws.get("b").unwrap();
        assert_eq!(b.as_f32().unwrap(), vec![1.5, -2.0]);
        assert_eq!(ws.nested_plane_bytes(), 6);
        assert!(ws.get("missing").is_err());
    }

    #[test]
    fn rejects_bad_magic() {
        let dir = std::env::temp_dir().join("nestedfp_wtest2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.bin");
        std::fs::write(&path, b"XXXX0000").unwrap();
        assert!(WeightStore::load(&path).is_err());
    }
}

//! # NestedFP
//!
//! A reproduction of *"NestedFP: High-Performance, Memory-Efficient
//! Dual-Precision Floating Point Support for LLMs"* (Lee et al., 2025) as a
//! three-layer Rust + JAX + Pallas serving stack.
//!
//! The crate provides:
//!
//! * [`format`] — the NestedFP numeric format itself: bit-exact FP16
//!   decomposition into two 8-bit tensors (the upper being a valid E4M3
//!   value at a fixed 2^8 scale), lossless reconstruction, and the
//!   per-channel absmax FP8 quantizer used as the paper's baseline.
//! * [`model`] — model configurations (the in-repo tiny transformer plus
//!   the paper's 14-model zoo with their real GEMM shapes) and the
//!   layer-applicability analyzer (Table 3).
//! * [`runtime`] — the PJRT execution layer: loads AOT-compiled HLO-text
//!   artifacts produced by `python/compile/aot.py` and executes them on the
//!   CPU PJRT client. Python never runs at serving time.
//! * [`kvcache`] — the paged dual-precision KV cache: a block allocator
//!   with per-request block tables (no slot cap), an FP8 block codec that
//!   demotes LRU-cold blocks to half the bytes under precision pressure,
//!   and a host-offload tier whose transfer latency is charged on the
//!   engine's virtual clock.
//! * [`attn`] — block-native paged attention: per-block QK^T/PV
//!   microkernels that walk the cache's block tables in place (FP8
//!   dequant fused into the block load, online softmax, deterministic
//!   fork-join threading), bit-identical to the dense-gather oracle it
//!   replaced on the decode hot path.
//! * [`coordinator`] — the vLLM-style serving engine: continuous batching
//!   with chunked prefill, paged KV management, request router,
//!   latency metrics, and the paper's headline feature — an
//!   iteration-level **dual-precision controller** switching FP16/FP8.
//!   On top of it, [`coordinator::cluster`] scales serving out: N replica
//!   engines behind pluggable routing policies
//!   ([`coordinator::router`]) on one shared virtual clock, and
//!   [`coordinator::autopilot`] closes the SLO loop — sliding-window
//!   TTFT/TPOT tracking, per-replica FP16 → Mixed → FP8 hysteresis
//!   ladders, and an arrival-slope surge predictor demote the fewest
//!   replicas needed during surges while the rest keep serving FP16.
//! * [`gemm`] — the executable compute layer: a cache-blocked,
//!   multi-threaded CPU GEMM engine that consumes NestedFP weights
//!   directly — the pack stage fuses the (upper, lower) → FP16
//!   reconstruction in FP16 mode and streams only the upper plane in FP8
//!   mode — bit-identical to the reference oracle for every format and
//!   worker count.
//! * [`gpusim`] — a tile-level analytical H100 GEMM cost model (the
//!   hardware substitute; see DESIGN.md §2) with the paper's kernel config
//!   search space, used to regenerate the performance figures.
//! * [`shard`] — the device-shard layer: per-replica [`shard::ShardPlan`]s
//!   (tensor-parallel degree over a fixed device pool with per-shard
//!   weight/KV byte accounting), the sublinear precision-dependent TP
//!   cost law extending `gpusim`, and the [`shard::Resharder`] that
//!   executes plan transitions as clock-billed
//!   drain → repartition → resume windows under the autopilot's second
//!   (parallelism) hysteresis ladder.
//! * [`telemetry`] — the unified observability layer: a virtual-clock
//!   span/event tracer with Perfetto-exportable timelines
//!   (`repro reproduce <exp> --trace FILE`), a typed counter registry
//!   with deterministic cross-replica merge, kernel phase profilers,
//!   and the `NESTEDFP_LOG` leveled diagnostics facade.
//! * [`trace`] — Azure-trace-like synthetic workload generation.
//! * [`eval`] — accuracy harness comparing FP16 / baseline FP8 / NestedFP8.
//! * [`bench`] — the reproduction harness behind `repro reproduce <exp>`.
//! * [`util`] — std-only support code (RNG, stats, JSON, CLI, property
//!   testing) since the offline environment has no tokio/serde/clap/etc.

pub mod util;
pub mod format;
pub mod kvcache;
pub mod attn;
pub mod model;
pub mod gemm;
pub mod gpusim;
pub mod shard;
pub mod telemetry;
pub mod trace;
pub mod eval;
pub mod runtime;
pub mod coordinator;
pub mod bench;

/// Crate-wide result type (thin alias over `anyhow`).
pub type Result<T> = anyhow::Result<T>;

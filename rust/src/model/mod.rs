//! Model configurations and weight-distribution analysis.
//!
//! * [`zoo`] — the 14 LLMs of the paper (Tables 1–3, Figures 7–10) with
//!   their real linear-layer GEMM shapes, plus calibrated weight-magnitude
//!   profiles for the applicability analysis.
//! * [`applicability`] — the NestedFP eligibility analyzer (Table 3 /
//!   Figure 3b): per-layer |w|max vs the 1.75 threshold.

pub mod zoo;
pub mod applicability;

pub use zoo::{GemmKind, ModelSpec, ZOO};

//! NestedFP layer-applicability analysis (Table 3, Figure 3b).
//!
//! The real analysis reads each layer's |w|max and compares against the
//! 1.75 eligibility threshold (`format::nested::is_eligible`). For the
//! in-repo tiny model we analyze the actual trained weights; for the zoo
//! (whose checkpoints we do not have) we use a **calibrated sampler**:
//! per-layer |w|max values drawn so that the published Table-3 counts are
//! reproduced — applicable layers get a max in the typical 0.3–1.6 band,
//! inapplicable layers get the model's published outlier magnitude.

use crate::format::fp16::F16;
use crate::format::nested;
use crate::util::rng::Pcg64;

use super::zoo::{GemmKind, ModelSpec};

/// Analysis result for one layer.
#[derive(Clone, Debug)]
pub struct LayerReport {
    pub kind: GemmKind,
    pub index: usize,
    pub max_abs: f32,
    pub applicable: bool,
}

/// Analysis result for a whole model.
#[derive(Clone, Debug)]
pub struct ModelReport {
    pub name: String,
    pub layers: Vec<LayerReport>,
}

impl ModelReport {
    pub fn counts(&self, kind: GemmKind) -> (usize, usize) {
        let of_kind = self.layers.iter().filter(|l| l.kind == kind);
        let total = of_kind.clone().count();
        let app = of_kind.filter(|l| l.applicable).count();
        (app, total)
    }

    pub fn total_counts(&self) -> (usize, usize) {
        let total = self.layers.len();
        let app = self.layers.iter().filter(|l| l.applicable).count();
        (app, total)
    }

    pub fn weight_range(&self) -> (f32, f32) {
        let max = self
            .layers
            .iter()
            .map(|l| l.max_abs)
            .fold(0.0f32, f32::max);
        (-max, max)
    }
}

/// Analyze a real weight tensor: |w|max and eligibility of every element.
pub fn analyze_tensor(w_f16: &[u16]) -> (f32, bool) {
    let mut max_abs = 0.0f32;
    let mut all_eligible = true;
    for &bits in w_f16 {
        let h = F16::from_bits(bits);
        let a = h.abs().to_f32();
        if a > max_abs {
            max_abs = a;
        }
        if !nested::is_eligible(h) {
            all_eligible = false;
        }
    }
    (max_abs, all_eligible)
}

/// Calibrated synthetic analysis of a zoo model: draws per-layer |w|max
/// consistent with the published Table-3 counts and the model's outlier
/// profile, then applies the *same* 1.75 rule the real analyzer uses.
pub fn analyze_zoo_model(spec: &ModelSpec, seed: u64) -> ModelReport {
    let mut rng = Pcg64::new(seed, spec.name.len() as u64);
    let mut layers = Vec::new();
    let t3 = spec
        .table3
        .expect("zoo model without published applicability");
    for (ki, kind) in GemmKind::ALL.iter().enumerate() {
        let (applicable, total) = t3.per_kind[ki];
        // choose which layer indices are the exceptions, deterministically
        let mut idx: Vec<usize> = (0..total).collect();
        rng.shuffle(&mut idx);
        let exceptional: Vec<usize> = idx[applicable..].to_vec();
        for i in 0..total {
            let is_exc = exceptional.contains(&i);
            let max_abs = if is_exc {
                // outlier layer: between just-over-threshold and the
                // model's published maximum
                let lo = 1.8f32;
                let hi = spec.max_weight.max(2.0);
                lo + (hi - lo) * rng.f32().powi(2)
            } else {
                // typical trained-LLM layer max: 0.3 .. 1.6
                0.3 + 1.3 * rng.f32()
            };
            layers.push(LayerReport {
                kind: *kind,
                index: i,
                max_abs,
                applicable: max_abs <= 1.75,
            });
        }
    }
    ModelReport {
        name: spec.name.to_string(),
        layers,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;

    #[test]
    fn analyze_tensor_detects_outliers() {
        let ok: Vec<u16> = [0.5f32, -1.2, 1.75]
            .iter()
            .map(|&v| F16::from_f32(v).to_bits())
            .collect();
        let (max, elig) = analyze_tensor(&ok);
        assert_eq!(max, 1.75);
        assert!(elig);
        let bad: Vec<u16> = [0.5f32, 2.5].iter().map(|&v| F16::from_f32(v).to_bits()).collect();
        let (max, elig) = analyze_tensor(&bad);
        assert_eq!(max, 2.5);
        assert!(!elig);
    }

    #[test]
    fn zoo_analysis_reproduces_table3_counts() {
        for spec in zoo::ZOO {
            let report = analyze_zoo_model(spec, 42);
            let t3 = spec.table3.unwrap();
            for (ki, kind) in GemmKind::ALL.iter().enumerate() {
                assert_eq!(
                    report.counts(*kind),
                    t3.per_kind[ki],
                    "{} {}",
                    spec.name,
                    kind.label()
                );
            }
            assert_eq!(report.total_counts(), t3.total(), "{}", spec.name);
        }
    }

    #[test]
    fn gemma_outliers_reach_published_magnitude() {
        let spec = zoo::find("gemma3-4b").unwrap();
        let report = analyze_zoo_model(spec, 42);
        let (_, max) = report.weight_range();
        assert!(max > 1.75 && max <= 26.25, "max {max}");
    }

    #[test]
    fn fully_applicable_models_have_no_outliers() {
        let spec = zoo::find("mistral-nemo-12b").unwrap();
        let report = analyze_zoo_model(spec, 7);
        assert!(report.layers.iter().all(|l| l.applicable));
        let (_, max) = report.weight_range();
        assert!(max <= 1.75);
    }
}

//! The paper's model zoo: architectures, GEMM shapes, and published
//! applicability counts (Table 3) used to calibrate the synthetic weight
//! sampler.
//!
//! GEMM taxonomy (Table 3): GEMM1 = Q/K/V projections (separate layers in
//! Llama/Mistral/Qwen-style models, one fused layer in Phi models),
//! GEMM2 = output projection, GEMM3 = MLP gate/up, GEMM4 = MLP down.

/// Linear-layer kind (the paper's GEMM1..GEMM4).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum GemmKind {
    Qkv,
    OutProj,
    GateUp,
    Down,
}

impl GemmKind {
    pub const ALL: [GemmKind; 4] = [
        GemmKind::Qkv,
        GemmKind::OutProj,
        GemmKind::GateUp,
        GemmKind::Down,
    ];

    pub fn label(self) -> &'static str {
        match self {
            GemmKind::Qkv => "GEMM1",
            GemmKind::OutProj => "GEMM2",
            GemmKind::GateUp => "GEMM3",
            GemmKind::Down => "GEMM4",
        }
    }
}

/// Published Table-3 applicability: (applicable, total) per GEMM kind.
#[derive(Clone, Copy, Debug)]
pub struct Applicability {
    pub per_kind: [(usize, usize); 4],
}

impl Applicability {
    pub fn total(&self) -> (usize, usize) {
        self.per_kind
            .iter()
            .fold((0, 0), |(a, t), &(x, y)| (a + x, t + y))
    }
}

/// One model of the zoo.
#[derive(Clone, Debug)]
pub struct ModelSpec {
    pub name: &'static str,
    pub params_b: f64,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub kv_heads: usize,
    pub head_dim: usize,
    pub d_ff: usize,
    pub vocab: usize,
    /// Phi-style fused QKV projection (one layer per block).
    pub fused_qkv: bool,
    /// Published Table-3 counts; None for models not in Table 3 (the four
    /// main-eval models are fully applicable per §5.1 except Phi-4).
    pub table3: Option<Applicability>,
    /// Largest per-layer |w| in the checkpoint (paper Fig 3b / §E) —
    /// drives the calibrated sampler for ineligible layers.
    pub max_weight: f32,
}

impl ModelSpec {
    pub fn kv_dim(&self) -> usize {
        self.kv_heads * self.head_dim
    }

    /// (N, K) *kernel* shapes with per-layer multiplicity for each GEMM
    /// kind. QKV runs as one fused GEMM (vLLM's qkv_proj) in every model
    /// — this is what makes the paper's count of "14 unique (N,K) shapes
    /// across the four models" come out (Table 3's GEMM1 instead counts
    /// q/k/v as separate checkpoint layers where the model stores them
    /// separately; see `fused_qkv`).
    pub fn gemm_shapes(&self, kind: GemmKind) -> Vec<(usize, usize, usize)> {
        let d = self.d_model;
        let attn_dim = self.n_heads * self.head_dim;
        match kind {
            GemmKind::Qkv => vec![(attn_dim + 2 * self.kv_dim(), d, 1)],
            GemmKind::OutProj => vec![(d, attn_dim, 1)],
            GemmKind::GateUp => vec![(self.d_ff, d, 2)],
            GemmKind::Down => vec![(d, self.d_ff, 1)],
        }
    }

    /// The distinct (N,K) shapes of this model's linear layers — the
    /// paper's "four distinct (N,K) shapes" per model (Fig 7a/9).
    pub fn unique_shapes(&self) -> Vec<(usize, usize)> {
        let mut shapes = Vec::new();
        for kind in GemmKind::ALL {
            for (n, k, _) in self.gemm_shapes(kind) {
                if !shapes.contains(&(n, k)) {
                    shapes.push((n, k));
                }
            }
        }
        shapes
    }

    /// The largest (N,K) shape (Fig 7a plots these).
    pub fn largest_shape(&self) -> (usize, usize) {
        self.unique_shapes()
            .into_iter()
            .max_by_key(|&(n, k)| n * k)
            .unwrap()
    }

    /// Total weight FLOPs per token for the linear layers (2*N*K each).
    pub fn linear_flops_per_token(&self) -> f64 {
        let mut per_layer = 0.0;
        for kind in GemmKind::ALL {
            for (n, k, mult) in self.gemm_shapes(kind) {
                per_layer += 2.0 * (n * k * mult) as f64;
            }
        }
        per_layer * self.n_layers as f64
    }
}

const fn app(
    g1: (usize, usize),
    g2: (usize, usize),
    g3: (usize, usize),
    g4: (usize, usize),
) -> Option<Applicability> {
    Some(Applicability {
        per_kind: [g1, g2, g3, g4],
    })
}

/// The four main-evaluation models come first (Tables 1–2, Figs 7–10).
pub static ZOO: &[ModelSpec] = &[
    ModelSpec {
        name: "llama31-8b",
        params_b: 8.0,
        d_model: 4096,
        n_layers: 32,
        n_heads: 32,
        kv_heads: 8,
        head_dim: 128,
        d_ff: 14336,
        vocab: 128_256,
        fused_qkv: false,
        table3: Some(Applicability {
            per_kind: [(96, 96), (32, 32), (64, 64), (32, 32)],
        }),
        max_weight: 1.4,
    },
    ModelSpec {
        name: "mistral-nemo-12b",
        params_b: 12.0,
        d_model: 5120,
        n_layers: 40,
        n_heads: 32,
        kv_heads: 8,
        head_dim: 128,
        d_ff: 14336,
        vocab: 131_072,
        fused_qkv: false,
        table3: Some(Applicability {
            per_kind: [(120, 120), (40, 40), (80, 80), (40, 40)],
        }),
        max_weight: 1.2,
    },
    ModelSpec {
        name: "phi-4-14b",
        params_b: 14.0,
        d_model: 5120,
        n_layers: 40,
        n_heads: 40,
        kv_heads: 10,
        head_dim: 128,
        d_ff: 17920,
        vocab: 100_352,
        fused_qkv: true,
        table3: Some(Applicability {
            per_kind: [(40, 40), (38, 40), (40, 40), (28, 40)],
        }),
        max_weight: 2.9,
    },
    ModelSpec {
        name: "mistral-small-24b",
        params_b: 24.0,
        d_model: 5120,
        n_layers: 40,
        n_heads: 32,
        kv_heads: 8,
        head_dim: 128,
        d_ff: 32768,
        vocab: 131_072,
        fused_qkv: false,
        table3: Some(Applicability {
            per_kind: [(120, 120), (40, 40), (80, 80), (40, 40)],
        }),
        max_weight: 1.1,
    },
    // ---- extended zoo (Table 3 / Appendix E) -----------------------------
    ModelSpec {
        name: "codellama-7b",
        params_b: 7.0,
        d_model: 4096,
        n_layers: 32,
        n_heads: 32,
        kv_heads: 32,
        head_dim: 128,
        d_ff: 11008,
        vocab: 32_016,
        fused_qkv: false,
        table3: app((96, 96), (32, 32), (64, 64), (31, 32)),
        max_weight: 2.6,
    },
    ModelSpec {
        name: "codellama-13b",
        params_b: 13.0,
        d_model: 5120,
        n_layers: 40,
        n_heads: 40,
        kv_heads: 40,
        head_dim: 128,
        d_ff: 13824,
        vocab: 32_016,
        fused_qkv: false,
        table3: app((120, 120), (40, 40), (80, 80), (37, 40)),
        max_weight: 2.8,
    },
    ModelSpec {
        name: "gemma3-4b",
        params_b: 4.0,
        d_model: 2560,
        n_layers: 34,
        n_heads: 8,
        kv_heads: 4,
        head_dim: 256,
        d_ff: 10240,
        vocab: 262_144,
        fused_qkv: false,
        table3: app((207, 264), (64, 88), (123, 176), (34, 34)),
        max_weight: 26.25,
    },
    ModelSpec {
        name: "gemma3-12b",
        params_b: 12.0,
        d_model: 3840,
        n_layers: 48,
        n_heads: 16,
        kv_heads: 8,
        head_dim: 256,
        d_ff: 15360,
        vocab: 262_144,
        fused_qkv: false,
        table3: app((249, 306), (78, 102), (151, 204), (48, 48)),
        max_weight: 26.25,
    },
    ModelSpec {
        name: "gemma3-27b",
        params_b: 27.0,
        d_model: 5376,
        n_layers: 62,
        n_heads: 32,
        kv_heads: 16,
        head_dim: 128,
        d_ff: 21504,
        vocab: 262_144,
        fused_qkv: false,
        table3: app((291, 348), (92, 116), (179, 232), (62, 62)),
        max_weight: 26.25,
    },
    ModelSpec {
        name: "llama31-70b",
        params_b: 70.0,
        d_model: 8192,
        n_layers: 80,
        n_heads: 64,
        kv_heads: 8,
        head_dim: 128,
        d_ff: 28672,
        vocab: 128_256,
        fused_qkv: false,
        table3: app((224, 240), (80, 80), (141, 160), (78, 80)),
        max_weight: 93.0,
    },
    ModelSpec {
        name: "phi-3.5-mini",
        params_b: 3.8,
        d_model: 3072,
        n_layers: 32,
        n_heads: 32,
        kv_heads: 32,
        head_dim: 96,
        d_ff: 8192,
        vocab: 32_064,
        fused_qkv: true,
        table3: app((26, 32), (31, 32), (31, 32), (24, 32)),
        max_weight: 3.2,
    },
    ModelSpec {
        name: "qwen3-8b",
        params_b: 8.0,
        d_model: 4096,
        n_layers: 36,
        n_heads: 32,
        kv_heads: 8,
        head_dim: 128,
        d_ff: 12288,
        vocab: 151_936,
        fused_qkv: false,
        table3: app((108, 108), (35, 36), (72, 72), (34, 36)),
        max_weight: 2.4,
    },
    ModelSpec {
        name: "qwen3-14b",
        params_b: 14.0,
        d_model: 5120,
        n_layers: 40,
        n_heads: 40,
        kv_heads: 8,
        head_dim: 128,
        d_ff: 17408,
        vocab: 151_936,
        fused_qkv: false,
        table3: app((120, 120), (40, 40), (80, 80), (38, 40)),
        max_weight: 2.2,
    },
    ModelSpec {
        name: "qwen3-32b",
        params_b: 32.0,
        d_model: 5120,
        n_layers: 64,
        n_heads: 64,
        kv_heads: 8,
        head_dim: 128,
        d_ff: 25600,
        vocab: 151_936,
        fused_qkv: false,
        table3: app((192, 192), (63, 64), (127, 128), (56, 64)),
        max_weight: 2.8,
    },
];

/// Look a model up by name.
pub fn find(name: &str) -> Option<&'static ModelSpec> {
    ZOO.iter().find(|m| m.name == name)
}

/// The four main-evaluation models.
pub fn main_four() -> Vec<&'static ModelSpec> {
    ZOO[..4].iter().collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fourteen_unique_shapes_across_main_four() {
        // the paper's "14 unique (N,K) shapes" (§5.2, Fig 9)
        let mut all = Vec::new();
        for m in main_four() {
            for s in m.unique_shapes() {
                if !all.contains(&s) {
                    all.push(s);
                }
            }
        }
        assert_eq!(all.len(), 14, "shapes: {all:?}");
    }

    #[test]
    fn four_unique_shapes_per_model() {
        for m in main_four() {
            assert_eq!(m.unique_shapes().len(), 4, "{}", m.name);
        }
    }

    #[test]
    fn largest_shapes_match_paper() {
        let ll = find("llama31-8b").unwrap().largest_shape();
        assert!(ll == (14336, 4096) || ll == (4096, 14336), "{ll:?}");
        let big = find("mistral-small-24b").unwrap().largest_shape();
        assert!(big == (32768, 5120) || big == (5120, 32768), "{big:?}");
        // Fig 7b's M x 5120 x 32768 is mistral-small's down projection
        let down = find("mistral-small-24b").unwrap().gemm_shapes(GemmKind::Down);
        assert_eq!(down, vec![(5120, 32768, 1)]);
    }

    #[test]
    fn table3_counts_consistent() {
        // GEMM totals must equal layers x multiplicity for non-multimodal
        // text models
        let m = find("llama31-8b").unwrap();
        let t3 = m.table3.unwrap();
        assert_eq!(t3.per_kind[0].1, 3 * m.n_layers); // separate q,k,v
        assert_eq!(t3.per_kind[1].1, m.n_layers);
        assert_eq!(t3.per_kind[2].1, 2 * m.n_layers);
        assert_eq!(t3.per_kind[3].1, m.n_layers);
        let phi = find("phi-4-14b").unwrap();
        assert_eq!(phi.table3.unwrap().per_kind[0].1, phi.n_layers); // fused
        // published totals
        assert_eq!(find("llama31-8b").unwrap().table3.unwrap().total(), (224, 224));
        assert_eq!(find("qwen3-32b").unwrap().table3.unwrap().total(), (438, 448));
        // note: the paper's own Table 3 total for Gemma 3 4B (429/563) is
        // internally inconsistent with its per-GEMM cells, which sum to
        // 428/562; we keep the per-cell values.
        assert_eq!(find("gemma3-4b").unwrap().table3.unwrap().total(), (428, 562));
    }

    #[test]
    fn flops_scale_with_size(){
        let small = find("llama31-8b").unwrap().linear_flops_per_token();
        let big = find("mistral-small-24b").unwrap().linear_flops_per_token();
        assert!(big > 2.0 * small);
    }
}

//! Operand packing — where the NestedFP fusion happens.
//!
//! The blocked kernel never reads operands from their stored layout; it
//! reads *packed panels* shaped for the microkernel:
//!
//! * A panel (activations): strips of `MR` consecutive X rows, p-major —
//!   `apack[si·kc·MR + p·MR + ii] = x[row0 + si·MR + ii][pc + p]`
//! * B panel (weights): strips of `NR` consecutive weight rows (output
//!   channels), p-major —
//!   `bpack[sj·kc·NR + p·NR + jj] = W[jc + sj·NR + jj][pc + p]`
//!
//! Ragged edges are zero-padded to full strips so the microkernel never
//! branches; padded lanes are simply not stored back.
//!
//! The B packer is the engine's analogue of the paper's in-kernel SIMT
//! stage: each stored byte is converted to f32 *once per (pc, jc) tile*,
//! on its way into the panel, and the multiply loop only ever sees f32:
//!
//! * `Fp16` — convert the f16 master bits,
//! * `Nested16` — the branch-free (upper, lower) → FP16 reconstruction
//!   of `format::nested` (Figure 6), fused into the pack,
//! * `Nested8` — a 256-entry LUT of `decode_e4m3(b)·2⁻⁸` over the upper
//!   plane only (the lower plane is never loaded: half the traffic),
//! * `Fp8` — E4M3 LUT + the per-channel scale division.
//!
//! Every packer is required (and tested) to produce bit-for-bit the
//! values of [`GemmWeights::dense_f32`] — that is what makes the whole
//! engine bit-identical to the reference oracle.

use crate::format::fp16::F16;
use crate::format::nested;
use crate::format::tensor::Tensor2;
use crate::format::e4m3;

use super::kernel::{MR, NR};
use super::weights::{GemmFormat, GemmWeights};

/// Per-matmul lookup tables (256 decodes each; built once per call).
pub(crate) struct PackContext {
    /// `upper_lut[b] = decode_e4m3(b) * 2^-8` — the Nested8 weight value.
    upper_lut: [f32; 256],
    /// `e4m3_lut[b] = decode_e4m3(b)` — the Fp8 code value (pre-scale).
    e4m3_lut: [f32; 256],
}

impl PackContext {
    pub(crate) fn new() -> PackContext {
        let mut upper_lut = [0.0f32; 256];
        let mut e4m3_lut = [0.0f32; 256];
        for b in 0..=255u8 {
            upper_lut[b as usize] = nested::upper_as_weight(b);
            e4m3_lut[b as usize] = e4m3::decode(b);
        }
        PackContext {
            upper_lut,
            e4m3_lut,
        }
    }
}

/// Pack `m_eff` rows of X (starting at absolute row `row0`) over columns
/// `[pc, pc + kc_eff)` into MR-row strips.
pub(crate) fn pack_a(
    x: &Tensor2,
    row0: usize,
    m_eff: usize,
    pc: usize,
    kc_eff: usize,
    buf: &mut Vec<f32>,
) {
    let n_strips = m_eff.div_ceil(MR);
    buf.clear();
    buf.resize(n_strips * kc_eff * MR, 0.0);
    for si in 0..n_strips {
        let base = si * kc_eff * MR;
        for ii in 0..MR {
            let r = si * MR + ii;
            if r >= m_eff {
                break; // rest of the strip stays zero-padded
            }
            let src = &x.data[(row0 + r) * x.cols + pc..(row0 + r) * x.cols + pc + kc_eff];
            for (p, &v) in src.iter().enumerate() {
                buf[base + p * MR + ii] = v;
            }
        }
    }
}

/// Pack `n_eff` weight rows (starting at `jc`) over columns
/// `[pc, pc + kc_eff)` into NR-row strips, decoding `fmt` on the way in.
#[allow(clippy::too_many_arguments)] // a tile coordinate per argument
pub(crate) fn pack_b(
    w: &GemmWeights,
    fmt: GemmFormat,
    ctx: &PackContext,
    jc: usize,
    n_eff: usize,
    pc: usize,
    kc_eff: usize,
    buf: &mut Vec<f32>,
) {
    let k = w.cols();
    let n_strips = n_eff.div_ceil(NR);
    buf.clear();
    buf.resize(n_strips * kc_eff * NR, 0.0);
    // one tight loop per (store, format) pair; the closure is the fusion
    // point and monomorphizes into the fill loop
    match (w, fmt) {
        (GemmWeights::F16 { bits, .. }, GemmFormat::Fp16) => {
            fill(buf, n_eff, kc_eff, |j, p| {
                F16::from_bits(bits[(jc + j) * k + pc + p]).to_f32()
            });
        }
        (GemmWeights::Nested(t), GemmFormat::Nested16) => {
            let (upper, lower) = (&t.upper, &t.lower);
            fill(buf, n_eff, kc_eff, |j, p| {
                let idx = (jc + j) * k + pc + p;
                nested::reconstruct(upper[idx], lower[idx]).to_f32()
            });
        }
        (GemmWeights::Nested(t), GemmFormat::Nested8) => {
            let upper = &t.upper; // lower plane untouched: half the bytes
            fill(buf, n_eff, kc_eff, |j, p| {
                ctx.upper_lut[upper[(jc + j) * k + pc + p] as usize]
            });
        }
        (GemmWeights::Fp8(q), GemmFormat::Fp8) => {
            let (codes, scales) = (&q.codes, &q.scales);
            fill(buf, n_eff, kc_eff, |j, p| {
                // decode / scale, exactly like QuantizedWeight::dequantize
                ctx.e4m3_lut[codes[(jc + j) * k + pc + p] as usize] / scales[jc + j]
            });
        }
        _ => panic!("{fmt:?} not supported by this weight store"),
    }
}

#[inline]
fn fill(buf: &mut [f32], n_eff: usize, kc_eff: usize, value: impl Fn(usize, usize) -> f32) {
    for sj in 0..n_eff.div_ceil(NR) {
        let base = sj * kc_eff * NR;
        for jj in 0..NR {
            let j = sj * NR + jj;
            if j >= n_eff {
                break;
            }
            for p in 0..kc_eff {
                buf[base + p * NR + jj] = value(j, p);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::testutil::gauss;

    #[test]
    fn pack_a_layout_and_padding() {
        let x = gauss(5, 8, 1); // 5 rows -> 2 strips of MR=4, 3 pad lanes
        let mut buf = Vec::new();
        pack_a(&x, 0, 5, 2, 6, &mut buf);
        assert_eq!(buf.len(), 2 * 6 * MR);
        // strip 0, p=3, row 2  ->  x[2][2+3]
        assert_eq!(buf[3 * MR + 2], x.get(2, 5));
        // strip 1 holds row 4 in lane 0; lanes 1..3 are zero padding
        assert_eq!(buf[6 * MR], x.get(4, 2)); // p=0, lane 0
        assert_eq!(buf[6 * MR + MR], x.get(4, 3)); // p=1, lane 0
        for p in 0..6 {
            for ii in 1..MR {
                assert_eq!(buf[6 * MR + p * MR + ii], 0.0, "pad lane p={p} ii={ii}");
            }
        }
    }

    #[test]
    fn packers_match_dense_reference_bitwise() {
        let w = gauss(NR + 3, 21, 2); // ragged in both directions
        let ctx = PackContext::new();
        for fmt in GemmFormat::ALL {
            let g = GemmWeights::prepare(&w, fmt).unwrap();
            let dense = g.dense_f32(fmt);
            let (jc, n_eff, pc, kc_eff) = (1usize, NR + 1, 4usize, 13usize);
            let mut buf = Vec::new();
            pack_b(&g, fmt, &ctx, jc, n_eff, pc, kc_eff, &mut buf);
            for j in 0..n_eff {
                for p in 0..kc_eff {
                    let got = buf[(j / NR) * kc_eff * NR + p * NR + (j % NR)];
                    let want = dense.get(jc + j, pc + p);
                    assert_eq!(
                        got.to_bits(),
                        want.to_bits(),
                        "{fmt:?} at weight row {} col {}",
                        jc + j,
                        pc + p
                    );
                }
            }
        }
    }

    #[test]
    fn luts_match_the_codecs() {
        let ctx = PackContext::new();
        for b in 0..=255u8 {
            let lut = ctx.upper_lut[b as usize];
            let direct = nested::upper_as_weight(b);
            assert!(
                lut.to_bits() == direct.to_bits() || (lut.is_nan() && direct.is_nan()),
                "upper_lut[{b:#04x}]"
            );
            let lut = ctx.e4m3_lut[b as usize];
            let direct = e4m3::decode(b);
            assert!(
                lut.to_bits() == direct.to_bits() || (lut.is_nan() && direct.is_nan()),
                "e4m3_lut[{b:#04x}]"
            );
        }
    }
}

//! The executable fused-NestedFP GEMM engine — the crate's compute layer.
//!
//! Everything below the serving stack used to *model* GEMM cost
//! ([`crate::gpusim`]) while actual multiplies fell back to the naive
//! reference loop in [`Tensor2::matmul`]. This module is the real thing:
//! a cache-blocked CPU engine that consumes NestedFP weights directly,
//! mirroring the paper's kernel design (§5) one level up the memory
//! hierarchy:
//!
//! | paper (H100 kernel)                  | this engine (CPU)                |
//! |--------------------------------------|----------------------------------|
//! | HBM → shared-memory tile staging     | stored planes → packed panels    |
//! | SIMT reconstruction stage, fused     | `Nested16` pack fuses Fig-6 math |
//! | FP8 mode streams upper plane only    | `Nested8` pack reads `upper` only|
//! | tensor-core MMA on staged tiles      | `MR×NR` register microkernel     |
//! | CTA tiling / wave scheduling         | MC/KC/NC blocking + row bands    |
//!
//! Structure: [`weights`] stores the operand formats, [`pack`] is the
//! fusion point (stored bytes → f32 panels), [`kernel`] the blocked
//! core, [`pool`] a deterministic fork-join pool over C row bands.
//!
//! Two invariants the tests pin down:
//!
//! 1. **Bit-exactness** — for every format the engine's output is
//!    bit-identical to `x.matmul(&w.dense_f32(fmt).transposed())`
//!    (the naive oracle over the format's decoded weights), for any
//!    tile sizes and worker counts. In particular the fused `Nested16`
//!    path reproduces reconstruct-then-matmul exactly, the engine-level
//!    restatement of the paper's losslessness claim.
//! 2. **Determinism** — worker count never changes a single output bit
//!    (row bands are disjoint and self-contained).

pub mod kernel;
pub mod pack;
pub mod pool;
pub mod weights;

/// Shared test-data generator for this module's unit tests: eligible
/// (|w| ≤ 1.7 < 1.75) gaussian tensors, so every format can prepare.
#[cfg(test)]
pub(crate) mod testutil {
    use crate::format::tensor::Tensor2;
    use crate::util::rng::Pcg64;

    pub(crate) fn gauss(rows: usize, cols: usize, seed: u64) -> Tensor2 {
        let mut rng = Pcg64::seeded(seed);
        Tensor2::from_vec(
            rows,
            cols,
            (0..rows * cols)
                .map(|_| (rng.normal() as f32 * 0.3).clamp(-1.7, 1.7))
                .collect(),
        )
    }
}

pub use pool::ThreadPool;
pub use weights::{GemmFormat, GemmWeights};

use crate::format::tensor::Tensor2;
use crate::telemetry::Profiler;

/// Blocking parameters. Defaults target a generic ~32 KiB L1 / ~1 MiB L2
/// core: the A block (`mc·kc` f32 = 64 KiB) lives in L2, one B strip
/// (`kc·NR` f32 = 16 KiB) in L1, the B panel (`kc·nc` f32 = 512 KiB) in
/// L2/L3.
#[derive(Clone, Copy, Debug)]
pub struct GemmConfig {
    /// Row-block height (M direction).
    pub mc: usize,
    /// Inner-dimension slice depth (K direction).
    pub kc: usize,
    /// Column-panel width (N direction).
    pub nc: usize,
    /// Worker threads (1 = fully sequential; results never depend on it).
    pub threads: usize,
}

impl Default for GemmConfig {
    fn default() -> Self {
        GemmConfig {
            mc: 64,
            kc: 256,
            nc: 512,
            threads: 1,
        }
    }
}

/// The compute engine. Cheap to construct; holds no operand state. The
/// profiler defaults to the disabled no-op handle — benches attach an
/// active one via [`GemmEngine::set_profiler`] to get per-phase
/// pack/microkernel/reduce timings. Profiling only reads the clock
/// around existing sections; it never changes the operation sequence,
/// so the bit-exactness invariant is untouched either way.
#[derive(Clone, Debug, Default)]
pub struct GemmEngine {
    cfg: GemmConfig,
    profiler: Profiler,
}

impl GemmEngine {
    pub fn new(cfg: GemmConfig) -> GemmEngine {
        assert!(cfg.mc > 0 && cfg.kc > 0 && cfg.nc > 0, "tile sizes must be positive");
        GemmEngine {
            cfg,
            profiler: Profiler::disabled(),
        }
    }

    /// Attach a profiler handle (use
    /// [`crate::telemetry::profiler::GEMM_PHASES`]). Clones of the
    /// handle share accumulators, so the caller keeps one to read.
    pub fn set_profiler(&mut self, profiler: Profiler) {
        self.profiler = profiler;
    }

    pub fn profiler(&self) -> &Profiler {
        &self.profiler
    }

    /// Default blocking with `threads` workers.
    pub fn with_threads(threads: usize) -> GemmEngine {
        GemmEngine::new(GemmConfig {
            threads,
            ..GemmConfig::default()
        })
    }

    pub fn config(&self) -> &GemmConfig {
        &self.cfg
    }

    /// How many row bands (and hence worker threads) an `[M, ·]` multiply
    /// actually uses: bands are `mc`-aligned, so small M caps parallelism
    /// at `ceil(M / mc)` no matter how many threads are configured.
    pub fn bands(&self, m: usize) -> usize {
        ThreadPool::new(self.cfg.threads)
            .workers()
            .min(m.div_ceil(self.cfg.mc))
            .max(1)
    }

    /// `X[M,K] × W[N,K]ᵀ → C[M,N]`, decoding `w` under `fmt` inside the
    /// pack stage. Panics if shapes disagree or the store cannot serve
    /// `fmt` (see [`GemmWeights::supports`]).
    pub fn matmul(&self, x: &Tensor2, w: &GemmWeights, fmt: GemmFormat) -> Tensor2 {
        assert_eq!(
            x.cols,
            w.cols(),
            "inner dims: x is [M,{}], w is [{},{}]",
            x.cols,
            w.rows(),
            w.cols()
        );
        assert!(w.supports(fmt), "weight store cannot execute as {fmt:?}");
        let (m, n) = (x.rows, w.rows());
        let mut c = Tensor2::zeros(m, n);
        if m == 0 || n == 0 || w.cols() == 0 {
            return c; // empty sum == zeros, same as the oracle
        }
        let ctx = pack::PackContext::new();
        // one contiguous, mc-aligned row band per worker; fewer bands
        // than workers when M is small (see [`Self::bands`])
        let workers = self.bands(m);
        let band_rows = m.div_ceil(workers).div_ceil(self.cfg.mc) * self.cfg.mc;
        let prof = &self.profiler;
        ThreadPool::new(workers).for_each_chunk(&mut c.data, band_rows * n, |bi, band| {
            kernel::gemm_band(x, w, fmt, &ctx, &self.cfg, prof, bi * band_rows, band);
        });
        c
    }
}

#[cfg(test)]
mod tests {
    use super::testutil::gauss;
    use super::*;

    /// The reference: naive oracle over the format's decoded weights.
    fn oracle(x: &Tensor2, w: &GemmWeights, fmt: GemmFormat) -> Tensor2 {
        x.matmul(&w.dense_f32(fmt).transposed())
    }

    fn assert_bits_eq(a: &Tensor2, b: &Tensor2, what: &str) {
        assert_eq!((a.rows, a.cols), (b.rows, b.cols), "{what}: shape");
        for (i, (x, y)) in a.data.iter().zip(&b.data).enumerate() {
            assert_eq!(
                x.to_bits(),
                y.to_bits(),
                "{what}: element {i} differs: {x} vs {y}"
            );
        }
    }

    #[test]
    fn known_product() {
        let x = Tensor2::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let w = GemmWeights::prepare(
            &Tensor2::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]),
            GemmFormat::Fp16,
        )
        .unwrap();
        let c = GemmEngine::default().matmul(&x, &w, GemmFormat::Fp16);
        assert_eq!(c.data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn all_formats_bit_identical_to_their_oracle() {
        let engine = GemmEngine::new(GemmConfig {
            mc: 8,
            kc: 16,
            nc: 32,
            threads: 1,
        });
        let x = gauss(13, 37, 10);
        let w = gauss(41, 37, 11);
        for fmt in GemmFormat::ALL {
            let g = GemmWeights::prepare(&w, fmt).unwrap();
            assert_bits_eq(
                &engine.matmul(&x, &g, fmt),
                &oracle(&x, &g, fmt),
                fmt.label(),
            );
        }
    }

    #[test]
    fn tile_sizes_never_change_bits() {
        let x = gauss(9, 23, 20);
        let w = GemmWeights::prepare(&gauss(17, 23, 21), GemmFormat::Nested16).unwrap();
        let want = oracle(&x, &w, GemmFormat::Nested16);
        for (mc, kc, nc) in [(1, 1, 1), (3, 5, 7), (4, 23, 16), (64, 256, 512)] {
            let engine = GemmEngine::new(GemmConfig {
                mc,
                kc,
                nc,
                threads: 1,
            });
            assert_bits_eq(
                &engine.matmul(&x, &w, GemmFormat::Nested16),
                &want,
                &format!("tiles ({mc},{kc},{nc})"),
            );
        }
    }

    #[test]
    fn worker_count_never_changes_bits() {
        // the pool's determinism contract, end to end, on a ragged shape
        let x = gauss(37, 29, 30);
        let w = GemmWeights::prepare(&gauss(19, 29, 31), GemmFormat::Nested16).unwrap();
        let cfg = GemmConfig {
            mc: 8,
            kc: 16,
            nc: 16,
            threads: 1,
        };
        let want = GemmEngine::new(cfg).matmul(&x, &w, GemmFormat::Nested16);
        for threads in [2, 3, 8] {
            let engine = GemmEngine::new(GemmConfig { threads, ..cfg });
            assert_bits_eq(
                &engine.matmul(&x, &w, GemmFormat::Nested16),
                &want,
                &format!("threads={threads}"),
            );
        }
    }

    #[test]
    fn ragged_and_edge_shapes() {
        // M, N, K deliberately not multiples of MR/NR/tiles; plus empty
        // and single-row cases
        let engine = GemmEngine::new(GemmConfig {
            mc: 8,
            kc: 8,
            nc: 24,
            threads: 2,
        });
        for (m, n, k) in [(1, 1, 1), (1, 19, 7), (5, 3, 9), (22, 33, 17), (7, 16, 4)] {
            let x = gauss(m, k, (m * 100 + n) as u64);
            let w = GemmWeights::prepare(&gauss(n, k, (n * 100 + k) as u64), GemmFormat::Nested16)
                .unwrap();
            assert_bits_eq(
                &engine.matmul(&x, &w, GemmFormat::Nested16),
                &oracle(&x, &w, GemmFormat::Nested16),
                &format!("shape ({m},{n},{k})"),
            );
        }
        // empty M: a [0, N] result
        let x = Tensor2::zeros(0, 5);
        let w = GemmWeights::prepare(&gauss(4, 5, 99), GemmFormat::Nested16).unwrap();
        let c = engine.matmul(&x, &w, GemmFormat::Nested16);
        assert_eq!((c.rows, c.cols), (0, 4));
        // empty K: zeros, like the oracle's empty sum
        let x = Tensor2::zeros(3, 0);
        let w = GemmWeights::prepare(&Tensor2::zeros(4, 0), GemmFormat::Fp16).unwrap();
        let c = engine.matmul(&x, &w, GemmFormat::Fp16);
        assert!(c.data.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn profiling_never_changes_bits() {
        use crate::telemetry::profiler::GEMM_PHASES;
        use crate::telemetry::Profiler;
        let x = gauss(40, 64, 50);
        let w = GemmWeights::prepare(&gauss(48, 64, 51), GemmFormat::Nested16).unwrap();
        let want = GemmEngine::default().matmul(&x, &w, GemmFormat::Nested16);
        let mut engine = GemmEngine::default();
        engine.set_profiler(Profiler::enabled(GEMM_PHASES));
        let got = engine.matmul(&x, &w, GemmFormat::Nested16);
        assert_bits_eq(&got, &want, "profiled");
        assert!(
            engine.profiler().total_seconds() > 0.0,
            "an enabled profiler must accumulate time"
        );
    }

    #[test]
    #[should_panic(expected = "cannot execute")]
    fn format_mismatch_panics() {
        let w = GemmWeights::prepare(&gauss(4, 4, 1), GemmFormat::Fp16).unwrap();
        GemmEngine::default().matmul(&gauss(2, 4, 2), &w, GemmFormat::Nested8);
    }
}

//! A dependency-free, deterministic fork-join pool for the GEMM engine.
//!
//! Work is partitioned *before* any thread starts: the output buffer is
//! split into contiguous chunks and each worker receives a fixed,
//! contiguous range of chunks. Nothing is stolen, nothing races, and the
//! function applied to a chunk may depend only on the chunk index and the
//! chunk contents — so the result is bit-identical for any worker count,
//! including 1 (which runs inline without spawning).
//!
//! This is all the engine needs: C row panels are disjoint slices of the
//! output tensor, and every panel's arithmetic is self-contained (each
//! worker packs its own operand tiles). `std::thread::scope` keeps the
//! whole thing safe-Rust with zero dependencies.

/// Fixed-size fork-join pool. `workers` is a *maximum*: a run with fewer
/// chunks than workers spawns fewer threads (or none).
#[derive(Clone, Copy, Debug)]
pub struct ThreadPool {
    workers: usize,
}

impl ThreadPool {
    /// A pool that will use at most `workers` OS threads (clamped to ≥ 1).
    pub fn new(workers: usize) -> ThreadPool {
        ThreadPool {
            workers: workers.max(1),
        }
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Split `data` into contiguous chunks of `chunk_len` elements (the
    /// last one may be shorter) and call `f(chunk_index, chunk)` exactly
    /// once per chunk. Chunk `i` covers `data[i*chunk_len ..]`. Workers
    /// receive contiguous chunk ranges; with one worker (or one chunk)
    /// everything runs inline on the calling thread.
    ///
    /// Determinism contract: `f` must write only through its `chunk` and
    /// derive everything else from `chunk_index` — then the output is
    /// identical for every worker count.
    pub fn for_each_chunk<T, F>(&self, data: &mut [T], chunk_len: usize, f: F)
    where
        T: Send,
        F: Fn(usize, &mut [T]) + Sync,
    {
        assert!(chunk_len > 0, "chunk_len must be positive");
        if data.is_empty() {
            return;
        }
        let n_chunks = data.len().div_ceil(chunk_len);
        if self.workers == 1 || n_chunks == 1 {
            for (i, chunk) in data.chunks_mut(chunk_len).enumerate() {
                f(i, chunk);
            }
            return;
        }
        // pre-assign contiguous chunk ranges: worker w gets chunks
        // [w*per, min((w+1)*per, n_chunks))
        let per = n_chunks.div_ceil(self.workers);
        let mut groups: Vec<Vec<(usize, &mut [T])>> = Vec::new();
        for (i, chunk) in data.chunks_mut(chunk_len).enumerate() {
            if i % per == 0 {
                groups.push(Vec::with_capacity(per));
            }
            groups.last_mut().unwrap().push((i, chunk));
        }
        let f = &f;
        std::thread::scope(|s| {
            for group in groups {
                s.spawn(move || {
                    for (i, chunk) in group {
                        f(i, chunk);
                    }
                });
            }
        });
    }
}

impl Default for ThreadPool {
    /// All available cores, overridable with `NESTEDFP_THREADS`.
    ///
    /// The pool's partitioning is bit-identical for any worker count
    /// (see [`ThreadPool::for_each_chunk`]'s determinism contract), so
    /// defaulting to `std::thread::available_parallelism()` changes
    /// only speed, never results — the previous default of 1 silently
    /// pinned every default-constructed GEMM to a single core. Set
    /// `NESTEDFP_THREADS=<n>` to pin an explicit count (benchmark
    /// stability, CI core caps); invalid or zero values fall back to
    /// the detected parallelism.
    fn default() -> Self {
        let detected = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        let workers = std::env::var("NESTEDFP_THREADS")
            .ok()
            .and_then(|s| s.trim().parse::<usize>().ok())
            .filter(|&n| n >= 1)
            .unwrap_or(detected);
        ThreadPool::new(workers)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    fn run_fill(workers: usize, len: usize, chunk: usize) -> Vec<usize> {
        let mut data = vec![0usize; len];
        ThreadPool::new(workers).for_each_chunk(&mut data, chunk, |idx, c| {
            for (off, v) in c.iter_mut().enumerate() {
                *v = idx * 1000 + off;
            }
        });
        data
    }

    #[test]
    fn covers_every_element_exactly_once() {
        let counter = AtomicUsize::new(0);
        let mut data = vec![0u8; 103];
        ThreadPool::new(4).for_each_chunk(&mut data, 10, |_, c| {
            counter.fetch_add(c.len(), Ordering::SeqCst);
            for v in c.iter_mut() {
                *v += 1;
            }
        });
        assert_eq!(counter.load(Ordering::SeqCst), 103);
        assert!(data.iter().all(|&v| v == 1), "every element touched once");
    }

    #[test]
    fn identical_for_any_worker_count() {
        let expect = run_fill(1, 97, 8);
        for workers in [2, 3, 8, 16] {
            assert_eq!(run_fill(workers, 97, 8), expect, "workers={workers}");
        }
    }

    #[test]
    fn chunk_indices_are_global() {
        // worker partitioning must not renumber chunks
        let data = run_fill(3, 50, 7); // 8 chunks over 3 workers
        for (i, &v) in data.iter().enumerate() {
            assert_eq!(v, (i / 7) * 1000 + i % 7);
        }
    }

    #[test]
    fn empty_and_single_chunk_run_inline() {
        let mut empty: Vec<u32> = Vec::new();
        ThreadPool::new(8).for_each_chunk(&mut empty, 4, |_, _| panic!("no chunks expected"));
        let tid = std::thread::current().id();
        let mut one = vec![0u32; 3];
        ThreadPool::new(8).for_each_chunk(&mut one, 100, |_, c| {
            assert_eq!(std::thread::current().id(), tid, "single chunk runs inline");
            c[0] = 7;
        });
        assert_eq!(one, vec![7, 0, 0]);
    }

    #[test]
    fn clamps_zero_workers() {
        assert_eq!(ThreadPool::new(0).workers(), 1);
    }

    #[test]
    fn default_pool_is_parallel_but_still_deterministic() {
        // the exact count depends on the machine (and NESTEDFP_THREADS),
        // so the pinned contract is: at least one worker, and the same
        // results as the single-threaded pool on a real workload —
        // worker count changes speed, never bits
        let pool = ThreadPool::default();
        assert!(pool.workers() >= 1);
        let mut data = vec![0usize; 131];
        pool.for_each_chunk(&mut data, 9, |idx, c| {
            for (off, v) in c.iter_mut().enumerate() {
                *v = idx * 1000 + off;
            }
        });
        assert_eq!(data, run_fill(1, 131, 9));
    }
}

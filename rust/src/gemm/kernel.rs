//! The cache-blocked compute core.
//!
//! Classic three-level (GotoBLAS-style) blocking over `C[M,N] = X[M,K] ×
//! W[N,K]ᵀ`: `NC`-wide column panels of C, `KC`-deep slices of the inner
//! dimension (B packed once per (jc, pc) tile), `MC`-tall row blocks of X
//! (A packed per block), and an `MR×NR` register microkernel at the
//! bottom that only ever touches packed, zero-padded panels.
//!
//! ## Bit-exactness invariant
//!
//! For every output element `c[i][j]`, the additions happen in ascending
//! `p` order into a single f32 accumulator (carried through C between
//! `pc` slices), with a plain mul + add per term — exactly the operation
//! sequence of the reference oracle `Tensor2::matmul`. Blocking changes
//! *when* each term is added, never *in what order* for a given element,
//! so the engine's output is bit-identical to the oracle applied to the
//! same (format-decoded) dense operands, for any tile sizes and any
//! worker count. Tests assert this; keep it when touching this file
//! (no `mul_add`, no reassociation, no per-element reordering).

use crate::format::tensor::Tensor2;
use crate::telemetry::Profiler;

use super::pack::{pack_a, pack_b, PackContext};
use super::weights::{GemmFormat, GemmWeights};
use super::GemmConfig;

// Phase indices into [`crate::telemetry::profiler::GEMM_PHASES`].
const PH_PACK: usize = 0;
const PH_MICRO: usize = 1;
const PH_REDUCE: usize = 2;

/// Microkernel row count (X rows per strip).
pub(crate) const MR: usize = 4;
/// Microkernel column count (weight rows per strip); `NR` f32 = one
/// 64-byte cache line.
pub(crate) const NR: usize = 16;

/// `acc[ir][jj] += a_strip ⋅ b_strip` over `kc` packed terms. The `jj`
/// lanes are independent accumulator chains (vectorizable); each chain
/// runs in ascending `p` order (not reassociable).
#[inline]
fn microkernel(kc: usize, a: &[f32], b: &[f32], acc: &mut [[f32; NR]; MR]) {
    debug_assert!(a.len() >= kc * MR);
    debug_assert!(b.len() >= kc * NR);
    for p in 0..kc {
        let ap = &a[p * MR..p * MR + MR];
        let bp = &b[p * NR..p * NR + NR];
        for ir in 0..MR {
            let av = ap[ir];
            let row = &mut acc[ir];
            for jj in 0..NR {
                row[jj] += av * bp[jj];
            }
        }
    }
}

/// Multiply one horizontal band of the output: rows `[row0, row0 + band)`
/// of C, where `band = c_band.len() / n`. Each band is self-contained
/// (it packs its own A and B tiles), which is what lets the thread pool
/// hand disjoint bands to workers with no shared mutable state.
///
/// `prof` times the pack / microkernel / reduce sections (a disabled
/// handle skips every clock read); it only brackets existing code and
/// must never reorder it — see the bit-exactness invariant above.
pub(crate) fn gemm_band(
    x: &Tensor2,
    w: &GemmWeights,
    fmt: GemmFormat,
    ctx: &PackContext,
    cfg: &GemmConfig,
    prof: &Profiler,
    row0: usize,
    c_band: &mut [f32],
) {
    let n = w.rows();
    let k = w.cols();
    let band = c_band.len() / n;
    debug_assert_eq!(c_band.len(), band * n);
    let mut apack: Vec<f32> = Vec::new();
    let mut bpack: Vec<f32> = Vec::new();

    let mut jc = 0;
    while jc < n {
        let nc_eff = cfg.nc.min(n - jc);
        let n_strips_j = nc_eff.div_ceil(NR);
        let mut pc = 0;
        while pc < k {
            let kc_eff = cfg.kc.min(k - pc);
            let t0 = prof.start();
            pack_b(w, fmt, ctx, jc, nc_eff, pc, kc_eff, &mut bpack);
            prof.record(PH_PACK, t0);
            let mut ic = 0;
            while ic < band {
                let mc_eff = cfg.mc.min(band - ic);
                let t0 = prof.start();
                pack_a(x, row0 + ic, mc_eff, pc, kc_eff, &mut apack);
                prof.record(PH_PACK, t0);
                let n_strips_i = mc_eff.div_ceil(MR);
                for sj in 0..n_strips_j {
                    let j0 = jc + sj * NR;
                    let cols = NR.min(jc + nc_eff - j0);
                    let bstrip = &bpack[sj * kc_eff * NR..(sj + 1) * kc_eff * NR];
                    for si in 0..n_strips_i {
                        let i0 = ic + si * MR; // band-relative C row
                        let rows = MR.min(ic + mc_eff - i0);
                        let astrip = &apack[si * kc_eff * MR..(si + 1) * kc_eff * MR];
                        // load live accumulators from C (pad lanes stay 0)
                        let t0 = prof.start();
                        let mut acc = [[0.0f32; NR]; MR];
                        for (ir, acc_row) in acc.iter_mut().enumerate().take(rows) {
                            let crow = &c_band[(i0 + ir) * n + j0..(i0 + ir) * n + j0 + cols];
                            acc_row[..cols].copy_from_slice(crow);
                        }
                        prof.record(PH_REDUCE, t0);
                        let t0 = prof.start();
                        microkernel(kc_eff, astrip, bstrip, &mut acc);
                        prof.record(PH_MICRO, t0);
                        let t0 = prof.start();
                        for (ir, acc_row) in acc.iter().enumerate().take(rows) {
                            let crow =
                                &mut c_band[(i0 + ir) * n + j0..(i0 + ir) * n + j0 + cols];
                            crow.copy_from_slice(&acc_row[..cols]);
                        }
                        prof.record(PH_REDUCE, t0);
                    }
                }
                ic += mc_eff;
            }
            pc += kc_eff;
        }
        jc += nc_eff;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn microkernel_accumulates_known_values() {
        // kc=2, A strip = identity-ish lanes, B strip = ramps
        let mut a = vec![0.0f32; 2 * MR];
        let mut b = vec![0.0f32; 2 * NR];
        for ir in 0..MR {
            a[ir] = (ir + 1) as f32; // p=0
            a[MR + ir] = 10.0; // p=1
        }
        for jj in 0..NR {
            b[jj] = jj as f32; // p=0
            b[NR + jj] = 1.0; // p=1
        }
        let mut acc = [[0.0f32; NR]; MR];
        acc[0][0] = 100.0; // carried-in partial sum survives
        microkernel(2, &a, &b, &mut acc);
        for ir in 0..MR {
            for jj in 0..NR {
                let carried = if ir == 0 && jj == 0 { 100.0 } else { 0.0 };
                let want = carried + (ir + 1) as f32 * jj as f32 + 10.0;
                assert_eq!(acc[ir][jj], want, "ir={ir} jj={jj}");
            }
        }
    }
}

//! Weight-operand storage for the compute engine.
//!
//! A `GemmWeights` is the *stored* form of a `[N, K]` weight matrix (rows
//! = output features, matching the rest of the repo); a [`GemmFormat`]
//! selects how the pack stage turns those stored bytes into f32 tile
//! values. The split mirrors the paper's central trick: one `Nested`
//! store serves both the lossless FP16 path (`Nested16`, both planes)
//! and the FP8 path (`Nested8`, upper plane only — half the bytes).

use anyhow::{bail, Result};

use crate::format::fp16::F16;
use crate::format::nested::{self, DecomposeResult, NestedTensor};
use crate::format::quant::{self, QuantizedWeight};
use crate::format::tensor::Tensor2;

/// Execution format of a GEMM — mirrors `gpusim::WeightFormat` so the
/// analytical model and the real engine speak the same language.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum GemmFormat {
    /// Plain FP16 weights (the cuBLAS-style baseline).
    Fp16,
    /// NestedFP two-plane weights, FP16-mode: the pack stage fuses the
    /// branch-free (upper, lower) → FP16 reconstruction.
    Nested16,
    /// NestedFP upper plane only, FP8-mode: E4M3 bytes at the global 2⁻⁸
    /// scale; the lower plane is never touched.
    Nested8,
    /// Native per-channel absmax E4M3 weights (the Torch-FP8 comparator).
    Fp8,
}

impl GemmFormat {
    pub const ALL: [GemmFormat; 4] = [
        GemmFormat::Fp16,
        GemmFormat::Nested16,
        GemmFormat::Nested8,
        GemmFormat::Fp8,
    ];

    pub fn label(self) -> &'static str {
        match self {
            GemmFormat::Fp16 => "fp16",
            GemmFormat::Nested16 => "nested16",
            GemmFormat::Nested8 => "nested8",
            GemmFormat::Fp8 => "fp8",
        }
    }

    /// The matching analytical-model format (for prediction cross-checks).
    pub fn to_gpusim(self) -> crate::gpusim::WeightFormat {
        match self {
            GemmFormat::Fp16 => crate::gpusim::WeightFormat::Fp16,
            GemmFormat::Nested16 => crate::gpusim::WeightFormat::Nested16,
            GemmFormat::Nested8 => crate::gpusim::WeightFormat::Nested8,
            GemmFormat::Fp8 => crate::gpusim::WeightFormat::Fp8,
        }
    }
}

/// Stored weights for the engine, row-major `[N, K]`.
#[derive(Clone, Debug)]
pub enum GemmWeights {
    /// FP16 master bit patterns.
    F16 {
        rows: usize,
        cols: usize,
        bits: Vec<u16>,
    },
    /// NestedFP (upper, lower) planes; serves `Nested16` and `Nested8`.
    Nested(NestedTensor),
    /// Per-output-channel absmax E4M3 (`format::quant`).
    Fp8(QuantizedWeight),
}

impl GemmWeights {
    /// Output features (N).
    pub fn rows(&self) -> usize {
        match self {
            GemmWeights::F16 { rows, .. } => *rows,
            GemmWeights::Nested(t) => t.rows,
            GemmWeights::Fp8(q) => q.rows,
        }
    }

    /// Input features (K).
    pub fn cols(&self) -> usize {
        match self {
            GemmWeights::F16 { cols, .. } => *cols,
            GemmWeights::Nested(t) => t.cols,
            GemmWeights::Fp8(q) => q.cols,
        }
    }

    /// Can this store run under `fmt`? (`Nested` serves both nested
    /// formats; the baselines only themselves.) An upper-plane-only
    /// nested store — legal, the FP8 path never reads `lower` — serves
    /// `Nested8` but not the reconstructing `Nested16` path, so misuse
    /// hits the engine's designed assert instead of a slice panic.
    pub fn supports(&self, fmt: GemmFormat) -> bool {
        match (self, fmt) {
            (GemmWeights::F16 { .. }, GemmFormat::Fp16) => true,
            (GemmWeights::Nested(t), GemmFormat::Nested16) => t.lower.len() == t.upper.len(),
            (GemmWeights::Nested(_), GemmFormat::Nested8) => true,
            (GemmWeights::Fp8(_), GemmFormat::Fp8) => true,
            _ => false,
        }
    }

    /// Quantize/encode an f32 weight matrix into the store `fmt` needs.
    /// The f32 values are first rounded to FP16 (the master precision);
    /// `Nested16`/`Nested8` then require every element NestedFP-eligible
    /// (|w| ≤ 1.75) and fail otherwise, mirroring the paper's exception-
    /// layer rule.
    pub fn prepare(w: &Tensor2, fmt: GemmFormat) -> Result<GemmWeights> {
        let bits: Vec<u16> = w.data.iter().map(|&v| F16::from_f32(v).to_bits()).collect();
        match fmt {
            GemmFormat::Fp16 => Ok(GemmWeights::F16 {
                rows: w.rows,
                cols: w.cols,
                bits,
            }),
            GemmFormat::Nested16 | GemmFormat::Nested8 => {
                match nested::decompose_tensor(w.rows, w.cols, &bits) {
                    DecomposeResult::Nested(t) => Ok(GemmWeights::Nested(t)),
                    DecomposeResult::Exception {
                        ineligible_count,
                        max_abs,
                    } => bail!(
                        "{ineligible_count} ineligible element(s) (max |w| = {max_abs}): \
                         exception layer, stays FP16"
                    ),
                }
            }
            GemmFormat::Fp8 => {
                // quantize from the f16-rounded masters, like the paper's
                // baseline does
                let w16 = Tensor2::from_vec(
                    w.rows,
                    w.cols,
                    bits.iter().map(|&b| F16::from_bits(b).to_f32()).collect(),
                );
                Ok(GemmWeights::Fp8(quant::quantize_weight_per_channel(&w16)))
            }
        }
    }

    /// The dense f32 `[N, K]` weight matrix `fmt` semantically multiplies
    /// by — the engine's reference oracle operand. Pack stages must
    /// produce *exactly* these values (bit-for-bit), which is what makes
    /// the engine's output bit-identical to
    /// `x.matmul(&dense.transposed())`.
    pub fn dense_f32(&self, fmt: GemmFormat) -> Tensor2 {
        assert!(self.supports(fmt), "{:?} cannot run as {fmt:?}", self.kind());
        let data = match (self, fmt) {
            (GemmWeights::F16 { bits, .. }, GemmFormat::Fp16) => {
                bits.iter().map(|&b| F16::from_bits(b).to_f32()).collect()
            }
            (GemmWeights::Nested(t), GemmFormat::Nested16) => t.reconstruct_f32(),
            (GemmWeights::Nested(t), GemmFormat::Nested8) => t.fp8_weights_f32(),
            (GemmWeights::Fp8(q), GemmFormat::Fp8) => q.dequantize().data,
            _ => unreachable!("supports() checked above"),
        };
        Tensor2::from_vec(self.rows(), self.cols(), data)
    }

    /// Weight bytes a GEMM under `fmt` streams from the store — the
    /// memory-traffic half of the paper's story: `Nested8` touches half
    /// of what `Nested16`/`Fp16` do.
    pub fn bytes_streamed(&self, fmt: GemmFormat) -> usize {
        let elems = self.rows() * self.cols();
        match fmt {
            GemmFormat::Fp16 | GemmFormat::Nested16 => 2 * elems,
            GemmFormat::Nested8 => elems,
            // codes + one f32 scale per output channel
            GemmFormat::Fp8 => elems + 4 * self.rows(),
        }
    }

    fn kind(&self) -> &'static str {
        match self {
            GemmWeights::F16 { .. } => "F16",
            GemmWeights::Nested(_) => "Nested",
            GemmWeights::Fp8(_) => "Fp8",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::testutil::gauss;

    #[test]
    fn prepare_and_support_matrix() {
        let w = gauss(8, 16, 1);
        for fmt in GemmFormat::ALL {
            let g = GemmWeights::prepare(&w, fmt).unwrap();
            assert!(g.supports(fmt));
            assert_eq!((g.rows(), g.cols()), (8, 16));
            assert_eq!(g.dense_f32(fmt).rows, 8);
        }
        // one nested store serves both nested formats
        let g = GemmWeights::prepare(&w, GemmFormat::Nested16).unwrap();
        assert!(g.supports(GemmFormat::Nested8));
        assert!(!g.supports(GemmFormat::Fp16));
    }

    #[test]
    fn upper_only_store_serves_nested8_only() {
        // an upper-plane-only tensor (no lower bytes) is how the FP8 path
        // can ship weights; it must refuse the reconstructing format
        let w = gauss(3, 5, 9);
        let GemmWeights::Nested(mut t) =
            GemmWeights::prepare(&w, GemmFormat::Nested8).unwrap()
        else {
            panic!("expected nested store");
        };
        t.lower = Vec::new();
        let g = GemmWeights::Nested(t);
        assert!(g.supports(GemmFormat::Nested8));
        assert!(!g.supports(GemmFormat::Nested16));
        assert_eq!(g.dense_f32(GemmFormat::Nested8).data.len(), 15);
    }

    #[test]
    fn ineligible_weights_rejected_for_nested() {
        let w = Tensor2::from_vec(1, 2, vec![0.5, 3.0]);
        assert!(GemmWeights::prepare(&w, GemmFormat::Nested16).is_err());
        assert!(GemmWeights::prepare(&w, GemmFormat::Fp16).is_ok());
    }

    #[test]
    fn nested16_dense_is_lossless() {
        let w = gauss(6, 10, 2);
        let w16: Vec<f32> = w
            .data
            .iter()
            .map(|&v| F16::from_f32(v).to_f32())
            .collect();
        let g = GemmWeights::prepare(&w, GemmFormat::Nested16).unwrap();
        assert_eq!(g.dense_f32(GemmFormat::Nested16).data, w16);
    }

    #[test]
    fn bytes_streamed_halves_in_fp8_mode() {
        let g = GemmWeights::prepare(&gauss(4, 32, 3), GemmFormat::Nested16).unwrap();
        assert_eq!(g.bytes_streamed(GemmFormat::Nested16), 2 * 4 * 32);
        assert_eq!(g.bytes_streamed(GemmFormat::Nested8), 4 * 32);
    }

    #[test]
    fn format_labels_roundtrip_gpusim() {
        for fmt in GemmFormat::ALL {
            assert!(fmt.to_gpusim().weight_bytes() >= 1.0);
            assert!(!fmt.label().is_empty());
        }
    }
}

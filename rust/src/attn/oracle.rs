//! The dense-gather oracle — the path the engine replaces, kept as the
//! reference.
//!
//! [`attend_dense`] (one layer) and [`attend_dense_step`] (all layers,
//! one gather per lane — exactly what the pre-PR 5 backend paid per
//! decode step) first materialize each lane's dense `[L, H, max_seq,
//! Dh]` K/V via `PagedKvCache::gather_seq`, then run the *same*
//! per-query law as the block-native engine (the shared `kernel`
//! helpers), in the same ascending-position order. The gather dequantizes FP8 blocks
//! through `kvcache::codec`, producing exactly the f32 values the
//! engine's fused dequant computes — so engine and oracle outputs are
//! bit-identical, and any timing difference between them is pure gather
//! overhead.

use crate::kvcache::PagedKvCache;

use super::engine::{AttnLane, AttnStats};
use super::kernel::{axpy_f32, dot_f32, OnlineSoftmax};

fn validate(kv: &PagedKvCache, lanes: &[AttnLane]) -> usize {
    let g = kv.geo;
    let (h, dh) = (g.n_heads, g.head_dim);
    let t = lanes.first().map(|l| l.positions.len()).unwrap_or(0);
    for lane in lanes {
        assert_eq!(lane.positions.len(), t, "lanes must share a token count");
        assert_eq!(lane.q.len(), t * h * dh, "query shape [t, H*Dh]");
        for &p in lane.positions {
            assert!(p >= 0 && (p as usize) < g.max_seq, "position {p} out of range");
        }
    }
    t
}

/// One (head, query) pass over a gathered dense plane — the identical
/// operation sequence to the engine's block walk.
#[allow(clippy::too_many_arguments)]
fn dense_query(
    gk: &[f32],
    gv: &[f32],
    s_max: usize,
    h: usize,
    dh: usize,
    layer: usize,
    head: usize,
    q: &[f32],
    pos: usize,
    acc: &mut [f32],
    dst: &mut [f32],
) {
    let inv = 1.0 / (dh as f32).sqrt();
    for a in acc.iter_mut() {
        *a = 0.0;
    }
    let mut sm = OnlineSoftmax::new();
    let row0 = (layer * h + head) * s_max * dh;
    for j in 0..=pos {
        let kr = &gk[row0 + j * dh..row0 + (j + 1) * dh];
        let p = sm.admit(dot_f32(q, kr) * inv, acc);
        axpy_f32(p, &gv[row0 + j * dh..row0 + (j + 1) * dh], acc);
    }
    sm.finish(acc, dst);
}

/// Dense-gather attention for one layer: gathers each lane's full dense
/// cache (the cost being eliminated), then applies the shared law.
/// Output layout matches [`AttnEngine::attend`](super::AttnEngine):
/// `[lane, head, t, head_dim]`.
pub fn attend_dense(
    kv: &mut PagedKvCache,
    layer: usize,
    lanes: &[AttnLane],
    out: &mut [f32],
) -> AttnStats {
    let g = kv.geo;
    let (h, dh, s_max) = (g.n_heads, g.head_dim, g.max_seq);
    assert!(layer < g.n_layers);
    let t = validate(kv, lanes);
    assert_eq!(out.len(), lanes.len() * h * t * dh, "out shape [B, H, t, Dh]");
    let mut stats = AttnStats::default();
    let (mut gk, mut gv) = (Vec::new(), Vec::new());
    let mut acc = vec![0.0f32; dh];
    for (li, lane) in lanes.iter().enumerate() {
        kv.gather_seq(lane.seq, &mut gk, &mut gv);
        // the oracle streams the dense slab it just built: per-layer
        // share, same units as the engine's counters
        stats.dense_bytes += g.layer_dense_bytes();
        stats.touched_bytes += g.layer_dense_bytes();
        for head in 0..h {
            for ti in 0..t {
                let q = &lane.q[(ti * h + head) * dh..(ti * h + head + 1) * dh];
                let dst0 = ((li * h + head) * t + ti) * dh;
                dense_query(
                    &gk,
                    &gv,
                    s_max,
                    h,
                    dh,
                    layer,
                    head,
                    q,
                    lane.positions[ti] as usize,
                    &mut acc,
                    &mut out[dst0..dst0 + dh],
                );
            }
        }
    }
    stats
}

/// Dense-gather attention for a whole step: **one** gather per lane
/// serves all `n_layers` attention layers — the exact traffic shape of
/// the pre-PR 5 `RealBackend::decode`. Output layout `[layer, lane,
/// head, t, head_dim]` (the per-layer slices match `attend`).
pub fn attend_dense_step(kv: &mut PagedKvCache, lanes: &[AttnLane], out: &mut [f32]) -> AttnStats {
    let (mut gk, mut gv) = (Vec::new(), Vec::new());
    attend_dense_step_with(kv, lanes, out, &mut gk, &mut gv)
}

/// [`attend_dense_step`] with caller-owned gather scratch. The bench's
/// timed loop uses this so the dense arm — like the pre-PR 5 backend,
/// which kept its gather buffers at high-water size — pays no per-step
/// allocation, and the measured delta is the gather itself.
pub fn attend_dense_step_with(
    kv: &mut PagedKvCache,
    lanes: &[AttnLane],
    out: &mut [f32],
    gk: &mut Vec<f32>,
    gv: &mut Vec<f32>,
) -> AttnStats {
    let g = kv.geo;
    let (l, h, dh, s_max) = (g.n_layers, g.n_heads, g.head_dim, g.max_seq);
    let t = validate(kv, lanes);
    let per_layer = lanes.len() * h * t * dh;
    assert_eq!(out.len(), l * per_layer, "out shape [L, B, H, t, Dh]");
    let mut stats = AttnStats::default();
    let mut acc = vec![0.0f32; dh];
    for (li, lane) in lanes.iter().enumerate() {
        kv.gather_seq(lane.seq, gk, gv);
        stats.dense_bytes += l * g.layer_dense_bytes();
        stats.touched_bytes += l * g.layer_dense_bytes();
        for layer in 0..l {
            for head in 0..h {
                for ti in 0..t {
                    let q = &lane.q[(ti * h + head) * dh..(ti * h + head + 1) * dh];
                    let dst0 = layer * per_layer + ((li * h + head) * t + ti) * dh;
                    dense_query(
                        gk,
                        gv,
                        s_max,
                        h,
                        dh,
                        layer,
                        head,
                        q,
                        lane.positions[ti] as usize,
                        &mut acc,
                        &mut out[dst0..dst0 + dh],
                    );
                }
            }
        }
    }
    stats
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attn::testutil::{filled_cache, rand_q, test_geo as geo};
    use crate::attn::AttnEngine;
    use crate::kvcache::KvPressureConfig;
    use crate::util::rng::Pcg64;

    fn assert_bits(a: &[f32], b: &[f32], what: &str) {
        assert_eq!(a.len(), b.len(), "{what}: length");
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{what}: element {i}: {x} vs {y}");
        }
    }

    #[test]
    fn engine_is_bit_identical_to_the_oracle_f32() {
        let g = geo();
        let (mut kv, seqs) = filled_cache(g, &[11, 24], 61, KvPressureConfig::dense_baseline());
        let (h, dh) = (g.n_heads, g.head_dim);
        let mut rng = Pcg64::seeded(62);
        // prefill-style: 3 queries per lane ending at the context tip
        let t = 3usize;
        let qs: Vec<Vec<f32>> = seqs.iter().map(|_| rand_q(&mut rng, t * h * dh)).collect();
        let pos: Vec<Vec<i32>> = [11usize, 24]
            .iter()
            .map(|&len| (len - t..len).map(|p| p as i32).collect())
            .collect();
        let lanes: Vec<AttnLane> = seqs
            .iter()
            .zip(&qs)
            .zip(&pos)
            .map(|((&seq, q), p)| AttnLane {
                seq,
                q,
                positions: p,
            })
            .collect();
        let n = lanes.len() * h * t * dh;
        for layer in 0..g.n_layers {
            let mut blk = vec![0.0f32; n];
            let mut dns = vec![0.0f32; n];
            AttnEngine::new(3).attend(&kv, layer, &lanes, &mut blk);
            attend_dense(&mut kv, layer, &lanes, &mut dns);
            assert_bits(&blk, &dns, &format!("layer {layer}"));
        }
    }

    #[test]
    fn engine_is_bit_identical_to_the_oracle_with_fp8_blocks() {
        let g = geo();
        let policy = KvPressureConfig {
            demote_watermark_fp8: 0.0,
            ..KvPressureConfig::demote_only()
        };
        let (mut kv, seqs) = filled_cache(g, &[30, 19], 71, policy);
        kv.set_precision_pressure(true);
        assert!(kv.maintain() > 0, "mixed-precision tables need demotions");
        let (h, dh) = (g.n_heads, g.head_dim);
        let mut rng = Pcg64::seeded(72);
        let qs: Vec<Vec<f32>> = seqs.iter().map(|_| rand_q(&mut rng, h * dh)).collect();
        let pos = [[29i32], [18i32]];
        let lanes: Vec<AttnLane> = seqs
            .iter()
            .zip(&qs)
            .zip(pos.iter())
            .map(|((&seq, q), p)| AttnLane {
                seq,
                q,
                positions: p,
            })
            .collect();
        let n = lanes.len() * h * dh;
        for layer in 0..g.n_layers {
            let mut blk = vec![0.0f32; n];
            let mut dns = vec![0.0f32; n];
            AttnEngine::new(2).attend(&kv, layer, &lanes, &mut blk);
            attend_dense(&mut kv, layer, &lanes, &mut dns);
            assert_bits(&blk, &dns, &format!("fp8 layer {layer}"));
        }
    }

    #[test]
    fn step_oracle_matches_per_layer_slices() {
        let g = geo();
        let (mut kv, seqs) = filled_cache(g, &[14], 81, KvPressureConfig::dense_baseline());
        let (h, dh) = (g.n_heads, g.head_dim);
        let mut rng = Pcg64::seeded(82);
        let q = rand_q(&mut rng, h * dh);
        let pos = [13i32];
        let lanes = [AttnLane {
            seq: seqs[0],
            q: &q,
            positions: &pos,
        }];
        let per = h * dh;
        let mut step = vec![0.0f32; g.n_layers * per];
        let st = attend_dense_step(&mut kv, &lanes, &mut step);
        assert_eq!(st.dense_bytes, g.n_layers * g.layer_dense_bytes());
        for layer in 0..g.n_layers {
            let mut one = vec![0.0f32; per];
            attend_dense(&mut kv, layer, &lanes, &mut one);
            assert_bits(&one, &step[layer * per..(layer + 1) * per], "slice");
        }
    }
}

//! The block-native attention engine.
//!
//! One `attend` call computes one layer's attention for a batch of
//! lanes (sequences), reading K/V straight out of the paged cache's
//! block tables — FP8 blocks dequantize inside the block load, nothing
//! is gathered, and no `max_seq`-sized intermediate exists (online
//! softmax). Work is partitioned into (lane × head) tasks, each of
//! which writes a disjoint contiguous slice of the output, so the
//! [`ThreadPool`] determinism contract applies: bit-identical output
//! for any worker count.
//!
//! The new K/V rows of the step being executed must already be
//! scattered into the cache ([`PagedKvCache::scatter_rows`]) before the
//! call — a query at position `p` attends positions `0..=p`, which by
//! then are all block-resident. Padding lanes do not exist here: a
//! batch is exactly its real lanes (the dense oracle zero-fills pads
//! instead; see `PagedKvCache::gather_batch_padded`).

use crate::gemm::ThreadPool;
use crate::kvcache::{BlockKv, PagedKvCache};
use crate::telemetry::Profiler;

use super::kernel::{axpy_f32, axpy_fp8, dot_f32, dot_fp8, e4m3_lut, OnlineSoftmax};

// Phase indices into [`crate::telemetry::profiler::ATTN_PHASES`].
const PH_LOAD: usize = 0;
const PH_DOT: usize = 1;
const PH_SOFTMAX: usize = 2;

/// One sequence's queries for an `attend` call. All lanes of a call
/// carry the same token count `t` (1 for decode, the chunk length for
/// prefill).
pub struct AttnLane<'a> {
    /// Paged-cache sequence handle.
    pub seq: usize,
    /// Queries, `[t, n_heads * head_dim]` row-major (post-RoPE).
    pub q: &'a [f32],
    /// Absolute context position of each query row; positions are the
    /// causal bound (`q[i]` attends `0..=positions[i]`).
    pub positions: &'a [i32],
}

/// Per-call traffic accounting: the structural win the engine exists
/// to deliver, in bytes.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct AttnStats {
    /// Bytes a dense gather would have copied to serve this call — one
    /// `[n_heads, max_seq, head_dim]` K+V f32 slab per lane (the old
    /// backend's per-layer share of `gather_seq`/`gather_batch`).
    pub dense_bytes: usize,
    /// KV bytes this call actually streamed: the covering blocks' bytes
    /// at their *stored* precision (FP8 blocks count roughly half),
    /// per-layer share.
    pub touched_bytes: usize,
}

impl AttnStats {
    pub fn merge(&mut self, other: AttnStats) {
        self.dense_bytes += other.dense_bytes;
        self.touched_bytes += other.touched_bytes;
    }

    /// Fraction of the dense gather's traffic the block walk avoided.
    pub fn savings(&self) -> f64 {
        if self.dense_bytes == 0 {
            return 0.0;
        }
        1.0 - self.touched_bytes as f64 / self.dense_bytes as f64
    }
}

/// The engine: the worker budget plus the E4M3 dequant table (built
/// once at construction — `attend` runs per layer per step, so the
/// 256-entry LUT must not be rebuilt on the hot path). The profiler
/// defaults to the disabled no-op handle; benches attach an active one
/// via [`AttnEngine::set_profiler`] for block_load/dot/softmax phase
/// timings. Profiling only brackets existing sections and never changes
/// a single output bit.
#[derive(Clone, Debug)]
pub struct AttnEngine {
    threads: usize,
    lut: [f32; 256],
    profiler: Profiler,
}

impl Default for AttnEngine {
    fn default() -> Self {
        AttnEngine::new(1)
    }
}

impl AttnEngine {
    /// An engine using at most `threads` workers (clamped to ≥ 1). The
    /// worker count never changes a single output bit.
    pub fn new(threads: usize) -> AttnEngine {
        AttnEngine {
            threads: threads.max(1),
            lut: e4m3_lut(),
            profiler: Profiler::disabled(),
        }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Attach a profiler handle (use
    /// [`crate::telemetry::profiler::ATTN_PHASES`]). Clones of the
    /// handle share accumulators, so the caller keeps one to read.
    pub fn set_profiler(&mut self, profiler: Profiler) {
        self.profiler = profiler;
    }

    pub fn profiler(&self) -> &Profiler {
        &self.profiler
    }

    /// Compute one layer's attention for `lanes`, writing `out` with
    /// layout `[lane, head, t, head_dim]`. Panics on shape mismatches,
    /// offloaded lanes, or positions beyond `max_seq` — the same
    /// contracts the gather path enforced.
    ///
    /// LRU note: in-place reads borrow `&KvCacheManager` and cannot bump
    /// the touch clock; callers that do not also scatter this step
    /// should call [`PagedKvCache::touch_read`] per lane first.
    pub fn attend(
        &self,
        kv: &PagedKvCache,
        layer: usize,
        lanes: &[AttnLane],
        out: &mut [f32],
    ) -> AttnStats {
        self.attend_inner(kv, layer, lanes, out, false)
    }

    /// [`Self::attend`] over lanes on **either tier**: offloaded lanes
    /// walk their host-resident blocks in place
    /// ([`PagedKvCache::seq_block_kv_any_tier`]) — the compute half of
    /// host attention piggybacking. Payloads are tier-invariant, so a
    /// device-resident lane produces bit-identical output through either
    /// entry; only where the bytes are billed differs (the backend's
    /// cost model, not this engine).
    pub fn attend_any_tier(
        &self,
        kv: &PagedKvCache,
        layer: usize,
        lanes: &[AttnLane],
        out: &mut [f32],
    ) -> AttnStats {
        self.attend_inner(kv, layer, lanes, out, true)
    }

    fn attend_inner(
        &self,
        kv: &PagedKvCache,
        layer: usize,
        lanes: &[AttnLane],
        out: &mut [f32],
        allow_host: bool,
    ) -> AttnStats {
        let g = kv.geo;
        let (h, dh) = (g.n_heads, g.head_dim);
        assert!(layer < g.n_layers, "layer {layer} of {}", g.n_layers);
        if lanes.is_empty() {
            assert!(out.is_empty(), "out must be empty for an empty batch");
            return AttnStats::default();
        }
        let t = lanes[0].positions.len();
        assert!(t > 0, "zero-token lanes");
        let mut stats = AttnStats::default();
        for lane in lanes {
            assert_eq!(lane.positions.len(), t, "lanes must share a token count");
            assert_eq!(lane.q.len(), t * h * dh, "query shape [t, H*Dh]");
            assert!(
                allow_host || !kv.is_offloaded(lane.seq),
                "attend on offloaded seq {}",
                lane.seq
            );
            let mut ctx = 0usize;
            for &p in lane.positions {
                assert!(p >= 0, "negative position");
                ctx = ctx.max(p as usize + 1);
            }
            assert!(ctx <= g.max_seq, "position beyond max_seq {}", g.max_seq);
            stats.dense_bytes += g.layer_dense_bytes();
            stats.touched_bytes += kv.seq_touched_bytes(lane.seq, ctx);
        }
        assert_eq!(out.len(), lanes.len() * h * t * dh, "out shape [B, H, t, Dh]");

        let lut = &self.lut;
        let prof = &self.profiler;
        let zeros = vec![0.0f32; dh];
        // one (lane, head) task per chunk; each task's loop over its own
        // queries and blocks is fully sequential, so worker count is
        // irrelevant to the bits
        ThreadPool::new(self.threads).for_each_chunk(out, t * dh, |c, dst| {
            let lane = &lanes[c / h];
            let head = c % h;
            let mut acc = vec![0.0f32; dh];
            for ti in 0..t {
                let q = &lane.q[(ti * h + head) * dh..(ti * h + head + 1) * dh];
                attend_query(
                    kv,
                    layer,
                    lane.seq,
                    head,
                    q,
                    lane.positions[ti] as usize,
                    allow_host,
                    lut,
                    prof,
                    &zeros,
                    &mut acc,
                    &mut dst[ti * dh..(ti + 1) * dh],
                );
            }
        });
        stats
    }
}

/// One query's block walk: online softmax over positions `0..=pos`,
/// visiting blocks in table order and tokens in ascending position —
/// the exact operation sequence of the dense oracle, minus the gather.
#[allow(clippy::too_many_arguments)]
fn attend_query(
    kv: &PagedKvCache,
    layer: usize,
    seq: usize,
    head: usize,
    q: &[f32],
    pos: usize,
    allow_host: bool,
    lut: &[f32; 256],
    prof: &Profiler,
    zeros: &[f32],
    acc: &mut [f32],
    dst: &mut [f32],
) {
    let g = kv.geo;
    let (h, dh, bs) = (g.n_heads, g.head_dim, g.block_size);
    let inv = 1.0 / (dh as f32).sqrt();
    for a in acc.iter_mut() {
        *a = 0.0;
    }
    let mut sm = OnlineSoftmax::new();
    let ctx = pos + 1;
    // (layer, head) slice offset inside a block plane `[L, H, bs, Dh]`
    let base = (layer * h + head) * bs * dh;
    let mut bi = 0usize;
    while bi * bs < ctx {
        let n_tok = bs.min(ctx - bi * bs);
        let t0 = prof.start();
        let blk = if allow_host {
            kv.seq_block_kv_any_tier(seq, bi)
        } else {
            kv.seq_block_kv(seq, bi)
        };
        prof.record(PH_LOAD, t0);
        match blk {
            BlockKv::F32 { k, v } => {
                for j in 0..n_tok {
                    let kr = &k[base + j * dh..base + (j + 1) * dh];
                    let t0 = prof.start();
                    let s = dot_f32(q, kr) * inv;
                    prof.record(PH_DOT, t0);
                    let t0 = prof.start();
                    let p = sm.admit(s, acc);
                    axpy_f32(p, &v[base + j * dh..base + (j + 1) * dh], acc);
                    prof.record(PH_SOFTMAX, t0);
                }
            }
            BlockKv::Fp8 {
                k,
                v,
                scale_k,
                scale_v,
            } => {
                for j in 0..n_tok {
                    let kr = &k[base + j * dh..base + (j + 1) * dh];
                    let t0 = prof.start();
                    let s = dot_fp8(q, kr, scale_k, lut) * inv;
                    prof.record(PH_DOT, t0);
                    let t0 = prof.start();
                    let p = sm.admit(s, acc);
                    axpy_fp8(p, &v[base + j * dh..base + (j + 1) * dh], scale_v, lut, acc);
                    prof.record(PH_SOFTMAX, t0);
                }
            }
            BlockKv::Acct => {
                // accounting-only pool: the dense gather would have
                // produced zeros — run the identical law over zeros
                for _ in 0..n_tok {
                    let t0 = prof.start();
                    let s = dot_f32(q, zeros) * inv;
                    prof.record(PH_DOT, t0);
                    let t0 = prof.start();
                    let p = sm.admit(s, acc);
                    axpy_f32(p, zeros, acc);
                    prof.record(PH_SOFTMAX, t0);
                }
            }
        }
        bi += 1;
    }
    let t0 = prof.start();
    sm.finish(acc, dst);
    prof.record(PH_SOFTMAX, t0);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attn::testutil::{filled_cache, rand_q, test_geo as geo};
    use crate::kvcache::KvPressureConfig;
    use crate::util::rng::Pcg64;

    #[test]
    fn decode_query_matches_two_pass_reference() {
        // independent numerical check: the engine vs a from-scratch f64
        // two-pass softmax over the same cache contents
        let g = geo();
        let (kv, seqs) = filled_cache(g, &[13], 7, KvPressureConfig::dense_baseline());
        let (h, dh) = (g.n_heads, g.head_dim);
        let mut rng = Pcg64::seeded(8);
        let q = rand_q(&mut rng, h * dh);
        let pos = [12i32];
        let lanes = [AttnLane {
            seq: seqs[0],
            q: &q,
            positions: &pos,
        }];
        let mut out = vec![0.0f32; h * dh];
        AttnEngine::new(1).attend(&kv, 1, &lanes, &mut out);

        // rebuild the dense values through the public block view
        let ctx = 13usize;
        for head in 0..h {
            let base = (h + head) * g.block_size * dh; // layer 1
            let mut scores = Vec::new();
            let mut vals: Vec<Vec<f32>> = Vec::new();
            for j in 0..ctx {
                let (bi, off) = (j / g.block_size, j % g.block_size);
                let BlockKv::F32 { k, v } = kv.seq_block_kv(seqs[0], bi) else {
                    panic!("expected f32 blocks");
                };
                let kr = &k[base + off * dh..base + (off + 1) * dh];
                let qh = &q[head * dh..(head + 1) * dh];
                let s: f64 = qh
                    .iter()
                    .zip(kr)
                    .map(|(&a, &b)| a as f64 * b as f64)
                    .sum::<f64>()
                    / (dh as f64).sqrt();
                scores.push(s);
                vals.push(v[base + off * dh..base + (off + 1) * dh].to_vec());
            }
            let m = scores.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            let denom: f64 = scores.iter().map(|&s| (s - m).exp()).sum();
            for d in 0..dh {
                let want: f64 = scores
                    .iter()
                    .zip(&vals)
                    .map(|(&s, v)| (s - m).exp() * v[d] as f64)
                    .sum::<f64>()
                    / denom;
                let got = out[head * dh + d] as f64;
                assert!(
                    (got - want).abs() < 1e-4,
                    "head {head} d {d}: engine {got} vs reference {want}"
                );
            }
        }
    }

    #[test]
    fn worker_count_never_changes_bits() {
        let g = geo();
        let (kv, seqs) = filled_cache(g, &[9, 17, 30], 21, KvPressureConfig::dense_baseline());
        let (h, dh) = (g.n_heads, g.head_dim);
        let mut rng = Pcg64::seeded(22);
        let qs: Vec<Vec<f32>> = seqs.iter().map(|_| rand_q(&mut rng, h * dh)).collect();
        let pos: Vec<[i32; 1]> = [8i32, 16, 29].iter().map(|&p| [p]).collect();
        let lanes: Vec<AttnLane> = seqs
            .iter()
            .zip(&qs)
            .zip(&pos)
            .map(|((&seq, q), p)| AttnLane {
                seq,
                q,
                positions: p,
            })
            .collect();
        let n = lanes.len() * h * dh;
        let mut want = vec![0.0f32; n];
        let s1 = AttnEngine::new(1).attend(&kv, 0, &lanes, &mut want);
        for threads in [2, 3, 8] {
            let mut got = vec![0.0f32; n];
            let s = AttnEngine::new(threads).attend(&kv, 0, &lanes, &mut got);
            assert_eq!(s, s1, "stats must not depend on workers");
            assert!(
                want.iter().zip(&got).all(|(a, b)| a.to_bits() == b.to_bits()),
                "threads={threads} changed bits"
            );
        }
    }

    #[test]
    fn stats_count_fp8_blocks_at_half() {
        let g = geo();
        let policy = KvPressureConfig {
            demote_watermark_fp8: 0.0,
            ..KvPressureConfig::demote_only()
        };
        let (mut kv, seqs) = filled_cache(g, &[16], 31, policy);
        let mut rng = Pcg64::seeded(32);
        let q = rand_q(&mut rng, g.n_heads * g.head_dim);
        let pos = [15i32];
        let mut out = vec![0.0f32; g.n_heads * g.head_dim];
        let lane = |s| AttnLane {
            seq: s,
            q: &q,
            positions: &pos,
        };
        let before = AttnEngine::new(1).attend(&kv, 0, &[lane(seqs[0])], &mut out);
        assert!(
            before.touched_bytes < before.dense_bytes,
            "a 16-token context must stream less than the 32-slot dense gather"
        );
        kv.set_precision_pressure(true);
        assert!(kv.maintain() > 0, "forced demotion must engage");
        let after = AttnEngine::new(1).attend(&kv, 0, &[lane(seqs[0])], &mut out);
        assert!(
            after.touched_bytes < before.touched_bytes,
            "fp8 blocks must stream fewer bytes: {} !< {}",
            after.touched_bytes,
            before.touched_bytes
        );
        assert_eq!(after.dense_bytes, before.dense_bytes);
    }

    #[test]
    fn profiling_never_changes_bits() {
        use crate::telemetry::profiler::ATTN_PHASES;
        let g = geo();
        let (kv, seqs) = filled_cache(g, &[25], 61, KvPressureConfig::dense_baseline());
        let (h, dh) = (g.n_heads, g.head_dim);
        let mut rng = Pcg64::seeded(62);
        let q = rand_q(&mut rng, h * dh);
        let pos = [24i32];
        let lanes = [AttnLane {
            seq: seqs[0],
            q: &q,
            positions: &pos,
        }];
        let mut want = vec![0.0f32; h * dh];
        AttnEngine::new(1).attend(&kv, 0, &lanes, &mut want);
        let mut engine = AttnEngine::new(1);
        engine.set_profiler(Profiler::enabled(ATTN_PHASES));
        let mut got = vec![0.0f32; h * dh];
        engine.attend(&kv, 0, &lanes, &mut got);
        assert!(
            want.iter().zip(&got).all(|(a, b)| a.to_bits() == b.to_bits()),
            "profiling changed bits"
        );
        assert!(
            engine.profiler().total_seconds() > 0.0,
            "an enabled profiler must accumulate time"
        );
    }

    #[test]
    fn empty_batch_is_a_no_op() {
        let g = geo();
        let (kv, _) = filled_cache(g, &[8], 41, KvPressureConfig::dense_baseline());
        let mut out: Vec<f32> = Vec::new();
        let stats = AttnEngine::new(4).attend(&kv, 0, &[], &mut out);
        assert_eq!(stats, AttnStats::default());
    }

    #[test]
    fn any_tier_attend_matches_device_bits_across_offload() {
        // offload moves accounting, not payloads — the host walk must
        // reproduce the device walk bit-for-bit, stats included
        let g = geo();
        let (mut kv, seqs) = filled_cache(g, &[16], 77, KvPressureConfig::default());
        let (h, dh) = (g.n_heads, g.head_dim);
        let mut rng = Pcg64::seeded(78);
        let q = rand_q(&mut rng, h * dh);
        let pos = [15i32];
        let lanes = [AttnLane {
            seq: seqs[0],
            q: &q,
            positions: &pos,
        }];
        let mut want = vec![0.0f32; h * dh];
        let s_dev = AttnEngine::new(1).attend(&kv, 0, &lanes, &mut want);
        kv.offload_sequence(seqs[0]).unwrap();
        let mut got = vec![0.0f32; h * dh];
        let s_host = AttnEngine::new(1).attend_any_tier(&kv, 0, &lanes, &mut got);
        assert_eq!(s_host, s_dev, "traffic stats are tier-invariant");
        assert!(
            want.iter().zip(&got).all(|(a, b)| a.to_bits() == b.to_bits()),
            "host-tier walk changed bits"
        );
        // and for device-resident lanes the two entries are one path
        kv.fetch_sequence(seqs[0]).unwrap();
        let mut back = vec![0.0f32; h * dh];
        AttnEngine::new(1).attend_any_tier(&kv, 0, &lanes, &mut back);
        assert!(want.iter().zip(&back).all(|(a, b)| a.to_bits() == b.to_bits()));
    }

    #[test]
    #[should_panic(expected = "offloaded")]
    fn offloaded_lane_panics() {
        let g = geo();
        let (mut kv, seqs) = filled_cache(g, &[16], 51, KvPressureConfig::default());
        kv.offload_sequence(seqs[0]).unwrap();
        let q = vec![0.0f32; g.n_heads * g.head_dim];
        let pos = [15i32];
        let mut out = vec![0.0f32; g.n_heads * g.head_dim];
        AttnEngine::new(1).attend(
            &kv,
            0,
            &[AttnLane {
                seq: seqs[0],
                q: &q,
                positions: &pos,
            }],
            &mut out,
        );
    }
}

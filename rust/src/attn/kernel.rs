//! The shared per-query attention law.
//!
//! Both the block-native engine and the dense-gather oracle are thin
//! drivers around the helpers here: one f32 dot per key (ascending
//! element order), one online-softmax admit, one weighted V accumulate
//! (ascending element order). Keys are visited in ascending position
//! order in both paths, so — with the FP8 dequant computing exactly the
//! value the gather's `codec::decode_block` would have materialized
//! (`LUT[byte] * scale`, one f32 multiply) — the two paths execute the
//! identical f32 operation sequence and their outputs match bit for
//! bit. Keep it that way: no `mul_add`, no reassociation, no
//! early-exit on zero.

use crate::format::e4m3;

/// 256-entry E4M3 decode table. `LUT[b] == e4m3::decode(b)` exactly, so
/// `LUT[b] * scale` reproduces `kvcache::codec::decode_block` bit for
/// bit — the fused dequant and the gather dequant cannot disagree.
pub(crate) fn e4m3_lut() -> [f32; 256] {
    let mut lut = [0.0f32; 256];
    for (b, slot) in lut.iter_mut().enumerate() {
        *slot = e4m3::decode(b as u8);
    }
    lut
}

/// Online-softmax running state for one (query, head) pair: the running
/// max `m` and the rescaled partition sum `l`. The V accumulator lives
/// with the caller (it is `head_dim`-sized) and is rescaled in lockstep.
pub(crate) struct OnlineSoftmax {
    m: f32,
    l: f32,
}

impl OnlineSoftmax {
    pub fn new() -> OnlineSoftmax {
        OnlineSoftmax {
            m: f32::NEG_INFINITY,
            l: 0.0,
        }
    }

    /// Admit one score: rescale the running state (and `acc`) if `s` is
    /// a new max, and return the weight `p = exp(s - m)` the caller
    /// multiplies into the V accumulate.
    #[inline]
    pub fn admit(&mut self, s: f32, acc: &mut [f32]) -> f32 {
        if s > self.m {
            // first key: l and acc are zero, so the rescale factor is
            // moot — but exp(-inf - s) would be 0.0 anyway; keep the
            // explicit branch so a NaN never leaks out of (m - s)
            let r = if self.m == f32::NEG_INFINITY {
                0.0
            } else {
                (self.m - s).exp()
            };
            self.l *= r;
            for a in acc.iter_mut() {
                *a *= r;
            }
            self.m = s;
        }
        let p = (s - self.m).exp();
        self.l += p;
        p
    }

    /// Normalize the accumulator into `dst`. With at least one admitted
    /// key, `l >= 1` (the running max contributes `exp(0)`), so the
    /// division is safe.
    #[inline]
    pub fn finish(&self, acc: &[f32], dst: &mut [f32]) {
        for (d, &a) in dst.iter_mut().zip(acc) {
            *d = a / self.l;
        }
    }
}

/// `q ⋅ k` over f32 rows, ascending element order, one mul + add per
/// term.
#[inline]
pub(crate) fn dot_f32(q: &[f32], k: &[f32]) -> f32 {
    debug_assert_eq!(q.len(), k.len());
    let mut s = 0.0f32;
    for (a, b) in q.iter().zip(k) {
        s += a * b;
    }
    s
}

/// `q ⋅ dequant(k)` with the dequant fused into the load:
/// `LUT[byte] * scale` is exactly the f32 the dense gather would have
/// stored, so the products (and their ascending-order sum) match
/// [`dot_f32`] over the gathered row bit for bit.
#[inline]
pub(crate) fn dot_fp8(q: &[f32], k: &[u8], scale: f32, lut: &[f32; 256]) -> f32 {
    debug_assert_eq!(q.len(), k.len());
    let mut s = 0.0f32;
    for (a, &b) in q.iter().zip(k) {
        s += a * (lut[b as usize] * scale);
    }
    s
}

/// `acc += p * v` over an f32 row, ascending element order.
#[inline]
pub(crate) fn axpy_f32(p: f32, v: &[f32], acc: &mut [f32]) {
    debug_assert_eq!(v.len(), acc.len());
    for (a, b) in acc.iter_mut().zip(v) {
        *a += p * b;
    }
}

/// `acc += p * dequant(v)` — the PV half of the fused-dequant
/// microkernel; same bit-match argument as [`dot_fp8`].
#[inline]
pub(crate) fn axpy_fp8(p: f32, v: &[u8], scale: f32, lut: &[f32; 256], acc: &mut [f32]) {
    debug_assert_eq!(v.len(), acc.len());
    for (a, &b) in acc.iter_mut().zip(v) {
        *a += p * (lut[b as usize] * scale);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lut_matches_the_codec_decoder() {
        let lut = e4m3_lut();
        for b in 0..=255u8 {
            let want = e4m3::decode(b);
            if want.is_nan() {
                assert!(lut[b as usize].is_nan(), "byte {b:#x}");
            } else {
                assert_eq!(lut[b as usize].to_bits(), want.to_bits(), "byte {b:#x}");
            }
        }
    }

    #[test]
    fn online_softmax_matches_two_pass_reference() {
        // scores chosen so the running max changes mid-stream
        let scores = [0.5f32, -1.0, 2.0, 1.5, 3.0, -0.5];
        let vals = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let mut sm = OnlineSoftmax::new();
        let mut acc = [0.0f32];
        for (&s, &v) in scores.iter().zip(&vals) {
            let p = sm.admit(s, &mut acc);
            axpy_f32(p, &[v], &mut acc);
        }
        let mut out = [0.0f32];
        sm.finish(&acc, &mut out);

        let m = scores.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
        let denom: f64 = scores.iter().map(|&s| ((s - m) as f64).exp()).sum();
        let want: f64 = scores
            .iter()
            .zip(&vals)
            .map(|(&s, &v)| ((s - m) as f64).exp() * v as f64)
            .sum::<f64>()
            / denom;
        assert!(
            (out[0] as f64 - want).abs() < 1e-6,
            "online {} vs two-pass {want}",
            out[0]
        );
    }

    #[test]
    fn fp8_helpers_match_f32_over_dequantized_rows() {
        let lut = e4m3_lut();
        let bytes: Vec<u8> = vec![0x00, 0x3C, 0x85, 0xC1, 0x7E, 0x12];
        let scale = 0.37f32;
        let dense: Vec<f32> = bytes.iter().map(|&b| lut[b as usize] * scale).collect();
        let q: Vec<f32> = (0..bytes.len()).map(|i| 0.1 * i as f32 - 0.2).collect();
        assert_eq!(
            dot_fp8(&q, &bytes, scale, &lut).to_bits(),
            dot_f32(&q, &dense).to_bits()
        );
        let mut a1 = vec![0.5f32; bytes.len()];
        let mut a2 = a1.clone();
        axpy_fp8(0.75, &bytes, scale, &lut, &mut a1);
        axpy_f32(0.75, &dense, &mut a2);
        assert!(a1.iter().zip(&a2).all(|(x, y)| x.to_bits() == y.to_bits()));
    }
}

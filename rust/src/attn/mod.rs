//! Block-native paged attention — the read path the paged KV cache
//! deserved.
//!
//! Until PR 5 the host compute twin dense-gathered every scheduled
//! sequence's *entire* cache — `O(n_layers × n_heads × max_seq ×
//! head_dim)` floats copied (and FP8-dequantized) per decode step —
//! before a single score was computed, erasing the bandwidth advantage
//! the paged cache (PR 2) exists to deliver. MorphServe (PAPERS.md)
//! makes the same observation for runtime KV-precision swapping: the
//! win only materializes when attention consumes quantized blocks **in
//! place**.
//!
//! This module walks [`PagedKvCache`](crate::kvcache::PagedKvCache)
//! block tables directly:
//!
//! * [`engine::AttnEngine`] — per-block QK^T / PV microkernels that fuse
//!   the FP8 dequant (per-block absmax scale, the same
//!   `kvcache::codec` law) into the block load, an online-softmax
//!   accumulator so no `max_seq`-sized intermediate ever exists, and
//!   fork-join threading over (lane × head) tasks with the
//!   [`gemm::ThreadPool`](crate::gemm::ThreadPool) determinism
//!   contract: bit-identical output for any worker count.
//! * [`oracle`] — the dense-gather reference: materialize the dense
//!   `[L, H, max_seq, Dh]` cache (exactly what the old backend did),
//!   then apply the *same* per-query accumulation law. Because both
//!   paths visit the same values in the same order with the same
//!   arithmetic, the block-native engine is **bit-identical** to the
//!   oracle for every precision mix — the gather is pure waste, which
//!   is precisely the claim `repro reproduce attention` measures.
//! * `kernel` (crate-private) — the shared law itself (dot, weighted
//!   accumulate, online-softmax state, the E4M3 dequant LUT), factored
//!   so the two paths cannot drift apart.
//!
//! Accounting: every attend reports [`AttnStats`] — the dense-equivalent
//! bytes a gather would have copied vs. the block bytes actually
//! touched (at stored precision, so FP8 blocks count half). The engine
//! mirrors these into `Metrics` per step; `repro reproduce attention`
//! and the KV bench surface the savings.

pub mod engine;
pub(crate) mod kernel;
pub mod oracle;

pub use engine::{AttnEngine, AttnLane, AttnStats};
pub use oracle::{attend_dense, attend_dense_step};

/// Shared fixtures for this module's unit tests: a small physical cache
/// with random-filled sequences.
#[cfg(test)]
pub(crate) mod testutil {
    use crate::kvcache::{KvGeometry, KvPressureConfig, PagedKvCache};
    use crate::util::rng::Pcg64;

    pub(crate) fn test_geo() -> KvGeometry {
        KvGeometry {
            n_layers: 2,
            n_heads: 2,
            max_seq: 32,
            head_dim: 4,
            block_size: 8,
            total_blocks: 24,
        }
    }

    /// Physical cache holding `lens` sequences filled with seeded
    /// gaussian K/V.
    pub(crate) fn filled_cache(
        g: KvGeometry,
        lens: &[usize],
        seed: u64,
        policy: KvPressureConfig,
    ) -> (PagedKvCache, Vec<usize>) {
        let mut kv = PagedKvCache::new(g, policy);
        let mut rng = Pcg64::seeded(seed);
        let mut seqs = Vec::new();
        for &len in lens {
            let s = kv.allocate(len).expect("test budget");
            let n = g.n_layers * len * g.n_heads * g.head_dim;
            let nk: Vec<f32> = (0..n).map(|_| rng.normal() as f32 * 0.5).collect();
            let nv: Vec<f32> = (0..n).map(|_| rng.normal() as f32 * 0.5).collect();
            kv.scatter_prefill(s, 0, len, &nk, &nv);
            kv.grow(s, len).unwrap();
            seqs.push(s);
        }
        (kv, seqs)
    }

    pub(crate) fn rand_q(rng: &mut Pcg64, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32 * 0.3).collect()
    }
}

//! OCP FP8 E4M3 codec (the "FN" variant used by H100 tensor cores).
//!
//! Layout: S EEEE MMM, exponent bias 7. The all-ones exponent is *not*
//! reserved for infinity: `S.1111.111` is the only NaN pattern and
//! `S.1111.110` = ±448 is the maximum finite value. Subnormals (E=0) reach
//! down to 2^-9.
//!
//! NestedFP's upper byte is a valid E4M3 value equal to the original FP16
//! weight times 2^8 (see `nested.rs`); the baseline FP8 quantizer
//! (`quant.rs`) also encodes through this codec.

/// Maximum finite E4M3 magnitude.
pub const E4M3_MAX: f32 = 448.0;
/// Exponent bias.
pub const BIAS: i32 = 7;
/// The canonical positive NaN pattern.
pub const NAN_PATTERN: u8 = 0x7F;

/// Decode an E4M3 byte to f32.
pub fn decode(b: u8) -> f32 {
    let s = if b & 0x80 != 0 { -1.0f32 } else { 1.0 };
    let e = ((b >> 3) & 0xF) as i32;
    let m = (b & 0x7) as i32;
    if e == 0xF && m == 0x7 {
        return f32::NAN;
    }
    if e == 0 {
        // subnormal: m/8 * 2^(1-bias)
        s * (m as f32 / 8.0) * f32::powi(2.0, 1 - BIAS)
    } else {
        s * (1.0 + m as f32 / 8.0) * f32::powi(2.0, e - BIAS)
    }
}

/// Encode f32 to E4M3 with round-to-nearest-even and saturation to ±448.
/// NaN input maps to the NaN pattern; ±inf saturates (matching common
/// hardware saturation mode for inference).
///
/// Bit-level fast path (the float-math reference survives as
/// [`encode_sat_ref`]; a differential test pins them to each other — the
/// rewrite bought ~30× on the quantizer hot loop, see EXPERIMENTS.md
/// §Perf).
pub fn encode_sat(x: f32) -> u8 {
    let bits = x.to_bits();
    let s = ((bits >> 31) as u8) << 7;
    let e = ((bits >> 23) & 0xFF) as i32;
    let m = bits & 0x7F_FFFF;
    if e == 0xFF {
        return if m == 0 { s | 0x7E } else { NAN_PATTERN }; // inf sat / nan
    }
    if e == 0 {
        return s; // f32 subnormal: far below E4M3's smallest, flush
    }
    let e_unb = e - 127;
    if e_unb >= 9 {
        return s | 0x7E; // >= 512: saturate
    }
    if e_unb >= -6 {
        // normal E4M3 target: RNE on the 7-bit integer E4‖M3 so a
        // mantissa carry propagates into the exponent
        let e_field = (e_unb + BIAS) as u32; // 1..=15
        let base = (e_field << 3) | (m >> 20);
        let rem = m & 0xF_FFFF;
        let mut v = base;
        if rem > 0x8_0000 || (rem == 0x8_0000 && base & 1 == 1) {
            v += 1;
        }
        if v >= 0x7F {
            return s | 0x7E; // rounded past 448 (or onto the NaN pattern)
        }
        return s | v as u8;
    }
    if e_unb < -10 {
        return s; // below half the smallest subnormal quantum
    }
    // subnormal target: round |x| / 2^-9 with RNE using integer mantissa
    // arithmetic: sig = 1.m (24 bits), quantum exponent -9
    let sig = m | 0x80_0000; // value = sig * 2^(e_unb - 23)
    let shift = (23 - 9 - e_unb) as u32; // bits to drop so units = 2^-9
    let kept = sig >> shift;
    let rem = sig & ((1u32 << shift) - 1);
    let half = 1u32 << (shift - 1);
    let mut k = kept;
    if rem > half || (rem == half && kept & 1 == 1) {
        k += 1;
    }
    // k <= 8: k == 8 lands exactly on the smallest normal (0x08)
    s | k as u8
}

/// The float-math reference implementation of [`encode_sat`].
pub fn encode_sat_ref(x: f32) -> u8 {
    if x.is_nan() {
        return NAN_PATTERN;
    }
    let s: u8 = if x.is_sign_negative() { 0x80 } else { 0 };
    let a = x.abs();
    if a == 0.0 {
        return s;
    }
    if a >= 464.0 {
        // 464 = midpoint between 448 (max) and the next would-be value;
        // everything >= saturates. Values in (448, 464) round to 448 too.
        return s | 0x7E;
    }

    // Work in f64 to make the rounding analysis exact.
    let a = a as f64;
    let e_unb = a.log2().floor() as i32;
    // normal range: e_unb in [-6, 8]
    if e_unb < -6 {
        // subnormal target: quantum 2^-9
        let q = a / f64::powi(2.0, -9);
        let r = rne_int(q);
        if r == 0 {
            return s;
        }
        if r <= 7 {
            return s | (r as u8);
        }
        // rounded up into the normal range
        return s | 0x08;
    }
    let e_field = (e_unb + BIAS) as u8; // 1..=15
    let frac = a / f64::powi(2.0, e_unb) - 1.0; // [0,1)
    let m = rne_int(frac * 8.0);
    if m == 8 {
        // carry into the exponent (e2 == 0xF with m == 0 is a fine finite value)
        let e2 = e_field + 1;
        if e2 > 0xF {
            return s | 0x7E; // saturate
        }
        return s | (e2 << 3);
    }
    let b = s | (e_field << 3) | (m as u8);
    if b & 0x7F == NAN_PATTERN {
        // 448 < |x| rounded to the NaN pattern -> saturate instead
        return s | 0x7E;
    }
    b
}

/// Round-to-nearest-even of a non-negative f64 to u32.
fn rne_int(x: f64) -> u32 {
    let f = x.floor();
    let r = x - f;
    let base = f as u32;
    if r > 0.5 {
        base + 1
    } else if r < 0.5 {
        base
    } else if base % 2 == 1 {
        base + 1
    } else {
        base
    }
}

/// Quantize-dequantize helper: the value E4M3 "sees".
pub fn quantize(x: f32) -> f32 {
    decode(encode_sat(x))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_values() {
        assert_eq!(decode(0x00), 0.0);
        assert_eq!(decode(0x38), 1.0); // E=7 M=0 -> 2^0
        assert_eq!(decode(0x3E), 1.75); // E=7 M=6
        assert_eq!(decode(0x7E), 448.0);
        assert!(decode(0x7F).is_nan());
        assert_eq!(decode(0x01), f32::powi(2.0, -9)); // smallest subnormal
        assert_eq!(decode(0x08), f32::powi(2.0, -6)); // smallest normal
        assert_eq!(decode(0xBE), -1.75);
    }

    #[test]
    fn exhaustive_roundtrip() {
        // every E4M3 value must encode back to itself (canonical -0 kept)
        for b in 0..=u8::MAX {
            let v = decode(b);
            if v.is_nan() {
                assert_eq!(encode_sat(v) & 0x7F, NAN_PATTERN);
                continue;
            }
            let back = encode_sat(v);
            assert_eq!(back, b, "0x{b:02x} -> {v} -> 0x{back:02x}");
        }
    }

    #[test]
    fn saturation() {
        assert_eq!(encode_sat(1e9), 0x7E);
        assert_eq!(encode_sat(-1e9), 0xFE);
        assert_eq!(encode_sat(f32::INFINITY), 0x7E);
        assert_eq!(encode_sat(460.0), 0x7E); // rounds down to 448
        assert_eq!(encode_sat(500.0), 0x7E);
    }

    #[test]
    fn rne_behaviour() {
        // midpoint between 1.0 (m=0) and 1.125 (m=1) is 1.0625 -> ties to even (m=0)
        assert_eq!(encode_sat(1.0625), 0x38);
        // midpoint between 1.125 and 1.25 is 1.1875 -> ties to even (m=2)
        assert_eq!(encode_sat(1.1875), 0x3A);
        // just above midpoint rounds up
        assert_eq!(encode_sat(1.07), 0x39);
    }

    #[test]
    fn subnormal_rounding() {
        let q = f32::powi(2.0, -9);
        assert_eq!(encode_sat(3.0 * q), 0x03);
        // halfway between 0 and q ties to even -> 0
        assert_eq!(encode_sat(0.5 * q), 0x00);
        // 7.6q rounds to 8q = smallest normal
        assert_eq!(encode_sat(7.6 * q), 0x08);
    }

    #[test]
    fn quantize_error_bound() {
        // relative error of a normal-range value is at most 2^-4 (half ulp of 3-bit mantissa)
        let mut worst: f32 = 0.0;
        let mut x = 0.016f32;
        while x < 448.0 {
            let q = quantize(x);
            worst = worst.max(((q - x) / x).abs());
            x *= 1.01;
        }
        assert!(worst <= 1.0 / 16.0 + 1e-6, "worst rel err {worst}");
    }
}

#[cfg(test)]
mod edge_tests {
    use super::*;

    #[test]
    fn nan_and_inf_handling() {
        // every NaN input maps to the canonical NaN pattern (sign dropped
        // by the fast path's inf/nan branch — only bit 0x7F matters)
        assert_eq!(encode_sat(f32::NAN) & 0x7F, NAN_PATTERN);
        assert_eq!(encode_sat(-f32::NAN) & 0x7F, NAN_PATTERN);
        let weird_nan = f32::from_bits(0x7F80_0001); // signalling payload
        assert_eq!(encode_sat(weird_nan) & 0x7F, NAN_PATTERN);
        assert!(decode(NAN_PATTERN).is_nan());
        assert!(decode(0xFF).is_nan(), "negative NaN pattern decodes NaN");
        // infinities saturate with their sign (hardware saturation mode)
        assert_eq!(encode_sat(f32::INFINITY), 0x7E);
        assert_eq!(encode_sat(f32::NEG_INFINITY), 0xFE);
        assert_eq!(decode(0x7E), E4M3_MAX);
        assert_eq!(decode(0xFE), -E4M3_MAX);
    }

    #[test]
    fn subnormal_edges() {
        let q = f32::powi(2.0, -9); // smallest E4M3 subnormal quantum
        // f32 subnormal inputs are far below q/2: flush to signed zero
        let f32_min_sub = f32::from_bits(1);
        assert_eq!(encode_sat(f32_min_sub), 0x00);
        assert_eq!(encode_sat(-f32_min_sub), 0x80);
        // every subnormal code roundtrips exactly
        for k in 1u8..=7 {
            assert_eq!(encode_sat(k as f32 * q), k);
            assert_eq!(decode(k), k as f32 * q);
        }
        // half-quantum ties go to even: 0.5q -> 0, 1.5q -> 2q
        assert_eq!(encode_sat(0.5 * q), 0x00);
        assert_eq!(encode_sat(1.5 * q), 0x02);
        // just below half the quantum flushes, just above rounds up
        assert_eq!(encode_sat(0.49 * q), 0x00);
        assert_eq!(encode_sat(0.51 * q), 0x01);
        // the subnormal/normal boundary: 7.5q ties up to the smallest
        // normal 8q = 2^-6 (even), and 8q encodes as normal 0x08
        assert_eq!(encode_sat(7.5 * q), 0x08);
        assert_eq!(encode_sat(8.0 * q), 0x08);
    }

    #[test]
    fn saturation_at_448() {
        assert_eq!(encode_sat(448.0), 0x7E);
        assert_eq!(encode_sat(-448.0), 0xFE);
        // (448, 464): nearer 448 than the would-be next step -> still 448
        assert_eq!(encode_sat(448.0001), 0x7E);
        assert_eq!(encode_sat(463.999), 0x7E);
        // the tie and beyond saturate (there is no larger finite value)
        assert_eq!(encode_sat(464.0), 0x7E);
        assert_eq!(encode_sat(-464.0), 0xFE);
        assert_eq!(encode_sat(f32::MAX), 0x7E);
        assert_eq!(encode_sat(-f32::MAX), 0xFE);
        // rounding must never land on the NaN pattern
        for x in [447.0f32, 447.9, 448.0, 455.9, 456.0, 460.0] {
            assert_ne!(encode_sat(x) & 0x7F, NAN_PATTERN, "x={x}");
        }
    }

    #[test]
    fn roundtrip_is_monotone() {
        // quantization must preserve ordering over the full finite range,
        // including the subnormal region and both signs
        let mut xs: Vec<f32> = Vec::new();
        let mut x = -500.0f32;
        while x <= 500.0 {
            xs.push(x);
            x += 0.371;
        }
        for i in -4000i32..=4000 {
            xs.push(i as f32 * 1e-3); // dense sweep around zero
            xs.push(i as f32 * f32::powi(2.0, -12)); // sub-quantum sweep
        }
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mut prev = f32::NEG_INFINITY;
        for &xi in &xs {
            let qv = quantize(xi);
            assert!(
                qv >= prev,
                "monotonicity broken at x={xi}: {qv} < {prev}"
            );
            prev = qv;
        }
        // decode over sorted positive codes is strictly increasing
        let mut last = -1.0f32;
        for b in 0x00..=0x7E {
            let v = decode(b);
            assert!(v > last, "code 0x{b:02x} not increasing: {v} <= {last}");
            last = v;
        }
    }
}

#[cfg(test)]
mod fastpath_tests {
    use super::*;
    use crate::format::fp16::F16;
    use crate::util::rng::Pcg64;

    /// Differential test: the bit-level fast path must agree with the
    /// float-math reference on every f16 value at several scales plus a
    /// large random f32 sample.
    #[test]
    fn encode_fast_matches_ref() {
        for bits in 0..=u16::MAX {
            let v = F16::from_bits(bits).to_f32();
            for scale in [1.0f32, 256.0, 1.0 / 256.0] {
                let x = v * scale;
                let fast = encode_sat(x);
                let slow = encode_sat_ref(x);
                if x.is_nan() {
                    assert_eq!(fast & 0x7F, NAN_PATTERN);
                    continue;
                }
                assert_eq!(
                    fast, slow,
                    "x={x} (f16 0x{bits:04x} * {scale}): fast 0x{fast:02x} ref 0x{slow:02x}"
                );
            }
        }
        let mut rng = Pcg64::seeded(31337);
        for _ in 0..200_000 {
            let x = f32::from_bits(rng.next_u32());
            if x.is_nan() {
                continue;
            }
            assert_eq!(encode_sat(x), encode_sat_ref(x), "x={x} ({:#x})", x.to_bits());
        }
    }
}

//! The NestedFP format (paper §4.2, Figures 4 & 6).
//!
//! An FP16 weight `S EEEEE MMMMMMMMMM` (E5M10) whose magnitude is ≤ 1.75
//! has a zero exponent MSB. NestedFP splits it into two bytes:
//!
//! * **upper** = `S E[2:5] M'[1:3]` — the sign, the low 4 exponent bits,
//!   and the 10-bit mantissa rounded to 3 bits with round-to-nearest-even
//!   (RNE applied to the 7-bit concatenation `E[2:5]‖M[1:3]` as an
//!   integer, so a mantissa carry correctly propagates into the exponent).
//!   Read as an OCP E4M3 byte this equals the original value × 2⁸ — i.e.
//!   the upper tensor *is* an E4M3 quantization with a global scale 2⁸.
//! * **lower** = `M[3:10]` — the low 8 bits of the original mantissa. Its
//!   MSB is the *pre-rounding* bit M3, which doubles as the checksum that
//!   lets the FP16 path undo the rounding.
//!
//! Reconstruction (branch-free, Figure 6): let `m3 = lower >> 7` (the
//! original M3) and `m3' = upper & 1` (the rounded M3). Rounding added
//! 0 or 1 to the 7-bit integer; in all four (m3, m3', carry) combinations
//! `upper - m3` has the same top-6 bits (E[2:5], M[1:2]) as the
//! pre-rounding value, so
//!
//! ```text
//! fp16 = S<<15 | E[2:5]<<10 | M[1:2]<<8 | lower
//! ```
//!
//! recovers the original bit pattern exactly. This module is the Rust
//! reference; the Pallas kernel (`python/compile/kernels/nested.py`)
//! performs the identical algebra on tiles, and `python/tests` +
//! `rust/tests/format_exhaustive.rs` pin them to each other.

use super::fp16::F16;

/// Eligibility threshold: |w| ≤ 1.75 (paper §4.2 / Fig 3).
///
/// In bit terms: exponent field < 15, or == 15 with mantissa ≤ 0b1100000000
/// (= 0.75), so the rounded 3-bit mantissa never produces the E4M3 NaN
/// pattern `1111.111`. NaN/Inf (E=31) are automatically ineligible.
pub fn is_eligible(h: F16) -> bool {
    let e = h.exp_field();
    let m = h.man_field();
    e < 15 || (e == 15 && m <= 0b11_0000_0000)
}

/// Decompose an eligible FP16 value into (upper, lower) NestedFP bytes.
///
/// Panics in debug builds if the value is ineligible; release callers
/// must check [`is_eligible`] first (the tensor-level API does).
#[inline]
pub fn decompose(h: F16) -> (u8, u8) {
    debug_assert!(is_eligible(h), "decompose() on ineligible value {h:?}");
    let bits = h.to_bits();
    let s = (bits >> 15) as u8;
    // 7-bit integer E[2:5] ‖ M[1:3]  (low 4 exponent bits + top 3 mantissa bits)
    let base = ((bits >> 7) & 0x7F) as u8;
    let rem = (bits & 0x7F) as u8; // dropped low 7 mantissa bits M[4:10]
    // round-to-nearest-even on the dropped 7 bits (midpoint = 64)
    let mut upper7 = base;
    if rem > 64 || (rem == 64 && base & 1 == 1) {
        upper7 += 1; // carry propagates M'->E inside the 7-bit integer
    }
    let upper = (s << 7) | upper7;
    let lower = (bits & 0xFF) as u8; // M[3:10]; MSB is the original M3
    (upper, lower)
}

/// Reconstruct the original FP16 bit pattern from (upper, lower).
///
/// Branch-free: mirrors the SIMT sequence of Figure 6 (and the Pallas
/// kernel's tile version).
#[inline]
pub fn reconstruct(upper: u8, lower: u8) -> F16 {
    let s = (upper as u16 >> 7) & 1;
    let m3 = (lower >> 7) & 1; // original M3 (checksum bit)
    // undo rounding: top-6 bits of (upper7 - m3) are the original E[2:5], M[1:2]
    let corrected = (upper & 0x7F).wrapping_sub(m3);
    let top6 = (corrected >> 1) as u16 & 0x3F; // E[2:5] (4) ‖ M[1:2] (2)
    let bits = (s << 15) | (top6 << 8) | lower as u16; // E MSB restored as 0
    F16::from_bits(bits)
}

/// The E4M3 value encoded by the upper byte equals `fp16_value * 2^8`
/// (up to the 3-bit mantissa rounding). This helper returns the *weight
/// value* the FP8 path uses: `decode_e4m3(upper) * 2^-8`.
#[inline]
pub fn upper_as_weight(upper: u8) -> f32 {
    super::e4m3::decode(upper) * f32::powi(2.0, -8)
}

/// A weight matrix stored in NestedFP form: the paper's single 16-bit
/// representation, physically laid out as two separate 8-bit tensors so
/// the FP8 path touches only `upper` (half the memory traffic).
#[derive(Clone, Debug)]
pub struct NestedTensor {
    /// Rows (output features, N).
    pub rows: usize,
    /// Cols (input features, K).
    pub cols: usize,
    /// Upper bytes (E4M3 × 2⁸), row-major, len = rows*cols.
    pub upper: Vec<u8>,
    /// Lower bytes (mantissa tail + checksum), row-major.
    pub lower: Vec<u8>,
    /// True if every element was eligible; ineligible tensors must stay
    /// in plain FP16 (the paper's "exception layers").
    pub fully_eligible: bool,
}

/// Decomposition outcome for a weight tensor.
pub enum DecomposeResult {
    /// All elements eligible: NestedFP applies.
    Nested(NestedTensor),
    /// Some element exceeded |1.75|: layer stays FP16 (exception layer).
    Exception { ineligible_count: usize, max_abs: f32 },
}

/// Decompose a row-major f16 tensor. Implements the paper's all-or-nothing
/// per-layer rule: if *any* element is ineligible the whole layer is an
/// exception layer.
pub fn decompose_tensor(rows: usize, cols: usize, w: &[u16]) -> DecomposeResult {
    assert_eq!(w.len(), rows * cols);
    let mut ineligible = 0usize;
    let mut max_abs = 0.0f32;
    for &bits in w {
        let h = F16::from_bits(bits);
        let a = h.abs().to_f32();
        if a > max_abs {
            max_abs = a;
        }
        if !is_eligible(h) {
            ineligible += 1;
        }
    }
    if ineligible > 0 {
        return DecomposeResult::Exception {
            ineligible_count: ineligible,
            max_abs,
        };
    }
    let mut upper = Vec::with_capacity(w.len());
    let mut lower = Vec::with_capacity(w.len());
    for &bits in w {
        let (u, l) = decompose(F16::from_bits(bits));
        upper.push(u);
        lower.push(l);
    }
    DecomposeResult::Nested(NestedTensor {
        rows,
        cols,
        upper,
        lower,
        fully_eligible: true,
    })
}

impl NestedTensor {
    /// Reconstruct the full FP16 tensor (bit patterns).
    pub fn reconstruct_f16(&self) -> Vec<u16> {
        self.upper
            .iter()
            .zip(&self.lower)
            .map(|(&u, &l)| reconstruct(u, l).to_bits())
            .collect()
    }

    /// Reconstruct to f32.
    pub fn reconstruct_f32(&self) -> Vec<f32> {
        self.upper
            .iter()
            .zip(&self.lower)
            .map(|(&u, &l)| reconstruct(u, l).to_f32())
            .collect()
    }

    /// The FP8-path weight values: upper bytes decoded with the 2⁻⁸ scale.
    pub fn fp8_weights_f32(&self) -> Vec<f32> {
        self.upper.iter().map(|&u| upper_as_weight(u)).collect()
    }

    /// Memory footprint in bytes (== one FP16 copy: the paper's headline).
    pub fn bytes(&self) -> usize {
        self.upper.len() + self.lower.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::format::e4m3;
    use crate::util::rng::Pcg64;

    #[test]
    fn eligibility_boundary() {
        assert!(is_eligible(F16::from_f32(1.75)));
        assert!(is_eligible(F16::from_f32(-1.75)));
        assert!(!is_eligible(F16::from_f32(1.7509766))); // next f16 above 1.75
        assert!(!is_eligible(F16::from_f32(2.0)));
        assert!(!is_eligible(F16::from_f32(f32::NAN)));
        assert!(!is_eligible(F16::INFINITY));
        assert!(is_eligible(F16::ZERO));
        assert!(is_eligible(F16::from_bits(0x8000))); // -0
        assert!(is_eligible(F16::from_bits(0x0001))); // smallest subnormal
    }

    #[test]
    fn roundtrip_simple_values() {
        for v in [
            0.0f32, 1.0, -1.0, 0.5, 1.75, -1.75, 0.1, -0.3, 1.0e-3, 6.0e-8, 1.5,
        ] {
            let h = F16::from_f32(v);
            let (u, l) = decompose(h);
            assert_eq!(reconstruct(u, l).to_bits(), h.to_bits(), "value {v}");
        }
    }

    #[test]
    fn upper_is_e4m3_times_256() {
        // for eligible values, decode(upper) must equal RNE-E4M3(value*256)
        let mut rng = Pcg64::seeded(100);
        for _ in 0..20_000 {
            let v = (rng.f32() - 0.5) * 3.5; // within ±1.75
            let h = F16::from_f32(v);
            if !is_eligible(h) {
                continue;
            }
            let (u, _l) = decompose(h);
            let direct = e4m3::encode_sat(h.to_f32() * 256.0);
            assert_eq!(
                u, direct,
                "value {v}: upper 0x{u:02x} vs direct E4M3 0x{direct:02x}"
            );
        }
    }

    #[test]
    fn rounding_carry_into_exponent() {
        // mantissa 0b1111111111 rounds up: carry into exponent field
        let h = F16::from_bits((14 << 10) | 0x3FF); // E=14, M=all ones
        let (u, l) = decompose(h);
        // upper must be E=15, M'=000
        assert_eq!(u & 0x7F, (15 << 3) | 0);
        assert_eq!(reconstruct(u, l).to_bits(), h.to_bits());
    }

    #[test]
    fn checksum_detects_rounding() {
        // value where RNE rounds up and M3 == 1 (borrow case of Fig 6)
        let h = F16::from_bits((10 << 10) | 0b01_1100_0001); // M3=1, rem7=65>64
        let (u, l) = decompose(h);
        let m3 = (l >> 7) & 1;
        let m3p = u & 1;
        assert_eq!(m3, 1);
        assert_ne!(m3, m3p, "rounding must flip the checksum bit");
        assert_eq!(reconstruct(u, l).to_bits(), h.to_bits());
    }

    #[test]
    fn tensor_roundtrip_and_exception() {
        let vals: Vec<u16> = [0.5f32, -1.2, 0.01, 1.75]
            .iter()
            .map(|&v| F16::from_f32(v).to_bits())
            .collect();
        match decompose_tensor(2, 2, &vals) {
            DecomposeResult::Nested(t) => {
                assert_eq!(t.reconstruct_f16(), vals);
                assert_eq!(t.bytes(), 8);
            }
            _ => panic!("expected nested"),
        }
        let bad: Vec<u16> = [0.5f32, 3.0].iter().map(|&v| F16::from_f32(v).to_bits()).collect();
        match decompose_tensor(1, 2, &bad) {
            DecomposeResult::Exception {
                ineligible_count,
                max_abs,
            } => {
                assert_eq!(ineligible_count, 1);
                assert_eq!(max_abs, 3.0);
            }
            _ => panic!("expected exception"),
        }
    }

    #[test]
    fn fp8_weights_close_to_original() {
        let mut rng = Pcg64::seeded(7);
        let vals: Vec<u16> = (0..1000)
            .map(|_| F16::from_f32(rng.normal() as f32 * 0.2).to_bits())
            .collect();
        if let DecomposeResult::Nested(t) = decompose_tensor(10, 100, &vals) {
            let w8 = t.fp8_weights_f32();
            let w16 = t.reconstruct_f32();
            for (a, b) in w8.iter().zip(&w16) {
                if b.abs() > 1e-3 {
                    assert!(
                        ((a - b) / b).abs() <= 1.0 / 16.0 + 1e-6,
                        "fp8 {a} vs fp16 {b}"
                    );
                }
            }
        } else {
            panic!("expected nested");
        }
    }
}

//! The NestedFP numeric format and its supporting codecs.
//!
//! This is the paper's §4.2 contribution, implemented bit-exactly:
//!
//! * [`fp16`] — software IEEE binary16 (E5M10) utilities (the environment
//!   has no `half` crate): f32↔f16 conversion with round-to-nearest-even,
//!   field extraction, classification.
//! * [`e4m3`] — the OCP FP8 E4M3 codec (bias 7, max 448, S.1111.111 = NaN)
//!   with RNE encoding and saturation, used both by the NestedFP upper
//!   tensor semantics and by the baseline FP8 quantizer.
//! * [`nested`] — decompose an FP16 weight into (upper, lower) bytes and
//!   losslessly reconstruct it, including the branch-free correction of
//!   Figure 6.
//! * [`quant`] — the Table-1/2 baseline: per-channel absmax E4M3 weight
//!   quantization and per-tensor/per-token activation quantization.
//! * [`tensor`] — minimal dense tensor containers used across the crate.

pub mod fp16;
pub mod e4m3;
pub mod nested;
pub mod quant;
pub mod tensor;

pub use fp16::F16;
pub use nested::{decompose, decompose_tensor, is_eligible, reconstruct, NestedTensor};
pub use tensor::{Tensor2, TensorU8};

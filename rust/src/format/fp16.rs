//! Software IEEE 754 binary16 (E5M10).
//!
//! `F16` is a transparent wrapper over the 16-bit pattern. Conversions use
//! round-to-nearest-even and handle subnormals, infinities and NaN — this
//! matters because NestedFP's eligibility rule and reconstruction are
//! defined directly on the bit layout.

/// IEEE binary16 value as its raw bit pattern.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Default)]
pub struct F16(pub u16);

pub const EXP_BITS: u32 = 5;
pub const MAN_BITS: u32 = 10;
pub const EXP_BIAS: i32 = 15;

impl F16 {
    pub const ZERO: F16 = F16(0);
    pub const ONE: F16 = F16(0x3C00);
    pub const INFINITY: F16 = F16(0x7C00);
    pub const NEG_INFINITY: F16 = F16(0xFC00);
    pub const NAN: F16 = F16(0x7E00);
    /// Largest finite magnitude (65504).
    pub const MAX: F16 = F16(0x7BFF);

    #[inline]
    pub fn from_bits(b: u16) -> F16 {
        F16(b)
    }

    #[inline]
    pub fn to_bits(self) -> u16 {
        self.0
    }

    /// Sign bit (0 or 1).
    #[inline]
    pub fn sign(self) -> u16 {
        self.0 >> 15
    }

    /// Raw 5-bit exponent field.
    #[inline]
    pub fn exp_field(self) -> u16 {
        (self.0 >> MAN_BITS) & 0x1F
    }

    /// Raw 10-bit mantissa field.
    #[inline]
    pub fn man_field(self) -> u16 {
        self.0 & 0x3FF
    }

    #[inline]
    pub fn is_nan(self) -> bool {
        self.exp_field() == 0x1F && self.man_field() != 0
    }

    #[inline]
    pub fn is_infinite(self) -> bool {
        self.exp_field() == 0x1F && self.man_field() == 0
    }

    #[inline]
    pub fn is_subnormal(self) -> bool {
        self.exp_field() == 0 && self.man_field() != 0
    }

    #[inline]
    pub fn is_zero(self) -> bool {
        self.0 & 0x7FFF == 0
    }

    /// Convert to f32 (exact — every f16 is representable in f32).
    pub fn to_f32(self) -> f32 {
        let s = (self.0 >> 15) as u32;
        let e = self.exp_field() as u32;
        let m = self.man_field() as u32;
        let bits = if e == 0 {
            if m == 0 {
                s << 31 // signed zero
            } else {
                // subnormal: value = m * 2^-24; normalize into f32.
                // lz = leading zeros within the 10-bit field; the implicit
                // one lands at 2^(-15 - lz).
                let lz = m.leading_zeros() - 22;
                let m_norm = (m << (lz + 1)) & 0x3FF;
                let e_f32 = 112 - lz; // 127 + (-15 - lz)
                (s << 31) | (e_f32 << 23) | (m_norm << 13)
            }
        } else if e == 0x1F {
            if m == 0 {
                (s << 31) | 0x7F80_0000
            } else {
                (s << 31) | 0x7FC0_0000 | (m << 13)
            }
        } else {
            (s << 31) | ((e + 127 - 15) << 23) | (m << 13)
        };
        f32::from_bits(bits)
    }

    /// Convert from f32 with round-to-nearest-even, overflow to ±inf.
    pub fn from_f32(x: f32) -> F16 {
        let bits = x.to_bits();
        let s = ((bits >> 31) as u16) << 15;
        let e = ((bits >> 23) & 0xFF) as i32;
        let m = bits & 0x7F_FFFF;

        if e == 0xFF {
            // inf / nan
            return if m == 0 {
                F16(s | 0x7C00)
            } else {
                F16(s | 0x7E00 | ((m >> 13) as u16 & 0x1FF))
            };
        }

        // unbiased exponent
        let e_unb = e - 127;
        let e_f16 = e_unb + EXP_BIAS;

        if e_f16 >= 0x1F {
            return F16(s | 0x7C00); // overflow -> inf
        }

        if e_f16 <= 0 {
            // subnormal or underflow-to-zero
            if e_f16 < -10 {
                return F16(s); // too small, flush to signed zero
            }
            // implicit leading one joins the mantissa
            let full = m | 0x80_0000;
            let shift = (14 - e_f16) as u32; // bits to drop from 24-bit sig to 10-bit field
            let kept = full >> shift;
            let rem = full & ((1 << shift) - 1);
            let half = 1u32 << (shift - 1);
            let mut out = kept as u16;
            if rem > half || (rem == half && out & 1 == 1) {
                out += 1; // may carry into the exponent (0 -> smallest normal): correct
            }
            return F16(s | out);
        }

        // normal: round 23-bit mantissa to 10 bits (drop 13)
        let kept = (m >> 13) as u16;
        let rem = m & 0x1FFF;
        let mut out = (s as u32) | ((e_f16 as u32) << 10) | kept as u32;
        if rem > 0x1000 || (rem == 0x1000 && out & 1 == 1) {
            out += 1; // mantissa carry may bump exponent; bit layout handles it
        }
        // may have become inf via carry: that is IEEE-correct behavior
        F16(out as u16)
    }

    /// Absolute value.
    #[inline]
    pub fn abs(self) -> F16 {
        F16(self.0 & 0x7FFF)
    }
}

impl std::fmt::Debug for F16 {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "F16(0x{:04x} = {})", self.0, self.to_f32())
    }
}

impl std::fmt::Display for F16 {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.to_f32())
    }
}

/// Convert a slice of f32 to f16 bit patterns.
pub fn f32s_to_f16(xs: &[f32]) -> Vec<u16> {
    xs.iter().map(|&x| F16::from_f32(x).to_bits()).collect()
}

/// Convert a slice of f16 bit patterns to f32.
pub fn f16s_to_f32(xs: &[u16]) -> Vec<f32> {
    xs.iter().map(|&b| F16::from_bits(b).to_f32()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_constants() {
        assert_eq!(F16::from_f32(1.0).to_bits(), 0x3C00);
        assert_eq!(F16::from_f32(-2.0).to_bits(), 0xC000);
        assert_eq!(F16::from_f32(0.5).to_bits(), 0x3800);
        assert_eq!(F16::from_f32(65504.0).to_bits(), 0x7BFF);
        assert_eq!(F16::from_f32(1.75).to_bits(), 0x3F00);
        assert!(F16::from_f32(f32::NAN).is_nan());
        assert!(F16::from_f32(1e30).is_infinite());
    }

    #[test]
    fn exhaustive_f16_to_f32_roundtrip() {
        // every finite f16 must roundtrip exactly through f32
        for bits in 0..=u16::MAX {
            let h = F16::from_bits(bits);
            if h.is_nan() {
                assert!(F16::from_f32(h.to_f32()).is_nan());
                continue;
            }
            let back = F16::from_f32(h.to_f32());
            assert_eq!(
                back.to_bits(),
                bits,
                "bits 0x{bits:04x} -> {} -> 0x{:04x}",
                h.to_f32(),
                back.to_bits()
            );
        }
    }

    #[test]
    fn rne_ties_to_even() {
        // 1.0 + 2^-11 is exactly halfway between 1.0 and 1.0+2^-10:
        // must round to even mantissa (0) -> 1.0
        let tie = 1.0f32 + f32::powi(2.0, -11);
        assert_eq!(F16::from_f32(tie).to_bits(), 0x3C00);
        // 1.0 + 3*2^-11 is halfway between m=1 and m=2 -> rounds to m=2
        let tie2 = 1.0f32 + 3.0 * f32::powi(2.0, -11);
        assert_eq!(F16::from_f32(tie2).to_bits(), 0x3C02);
    }

    #[test]
    fn subnormal_conversion() {
        // smallest positive subnormal: 2^-24
        let tiny = f32::powi(2.0, -24);
        assert_eq!(F16::from_f32(tiny).to_bits(), 0x0001);
        assert_eq!(F16::from_bits(0x0001).to_f32(), tiny);
        // largest subnormal
        let big_sub = F16::from_bits(0x03FF);
        assert!(big_sub.is_subnormal());
        assert_eq!(F16::from_f32(big_sub.to_f32()).to_bits(), 0x03FF);
    }

    #[test]
    fn signed_zero() {
        assert_eq!(F16::from_f32(-0.0).to_bits(), 0x8000);
        assert_eq!(F16::from_bits(0x8000).to_f32().to_bits(), (-0.0f32).to_bits());
    }

    #[test]
    fn field_extraction() {
        let h = F16::from_f32(1.75); // 0x3F00: S=0 E=01111 M=1100000000
        assert_eq!(h.sign(), 0);
        assert_eq!(h.exp_field(), 0b01111);
        assert_eq!(h.man_field(), 0b11_0000_0000);
    }
}

//! Minimal dense tensor containers (row-major) used across the crate.
//!
//! These are deliberately simple — the heavy math runs inside the AOT
//! executables; Rust-side tensors exist for weight storage, verification,
//! and the cost model.

/// Row-major 2-D f32 tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor2 {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Tensor2 {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Tensor2 {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Tensor2 { rows, cols, data }
    }

    #[inline]
    pub fn get(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        self.data[r * self.cols + c] = v;
    }

    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Naive matmul: self [m,k] × other [k,n] -> [m,n]. The crate's
    /// *reference oracle*: `gemm::GemmEngine` is required to match it
    /// bit-for-bit, so its semantics are part of the contract — a
    /// cache-friendly `i-k-j` loop where each output element accumulates
    /// its `k` terms in ascending order through one f32 chain, one plain
    /// mul + add per term (no skips, no FMA, no reassociation).
    pub fn matmul(&self, other: &Tensor2) -> Tensor2 {
        assert_eq!(self.cols, other.rows, "inner dims");
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let mut out = Tensor2::zeros(m, n);
        for i in 0..m {
            for p in 0..k {
                let a = self.get(i, p);
                let orow = other.row(p);
                let dst = &mut out.data[i * n..(i + 1) * n];
                for j in 0..n {
                    dst[j] += a * orow[j];
                }
            }
        }
        out
    }

    /// The [cols, rows] transpose (used to feed [N,K] weight matrices to
    /// [`Self::matmul`], which wants the right operand as [K,N]).
    pub fn transposed(&self) -> Tensor2 {
        let mut out = Tensor2::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                out.data[c * self.rows + r] = self.data[r * self.cols + c];
            }
        }
        out
    }

    /// Max |x|.
    pub fn abs_max(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &v| m.max(v.abs()))
    }

    /// Per-row max |x| (per output channel for [N,K] weights).
    pub fn row_abs_max(&self) -> Vec<f32> {
        (0..self.rows)
            .map(|r| self.row(r).iter().fold(0.0f32, |m, &v| m.max(v.abs())))
            .collect()
    }

    /// Frobenius norm of the difference.
    pub fn rel_err(&self, other: &Tensor2) -> f64 {
        assert_eq!(self.data.len(), other.data.len());
        let mut num = 0.0f64;
        let mut den = 0.0f64;
        for (a, b) in self.data.iter().zip(&other.data) {
            num += ((a - b) as f64).powi(2);
            den += (*b as f64).powi(2);
        }
        if den == 0.0 {
            num.sqrt()
        } else {
            (num / den).sqrt()
        }
    }
}

/// Row-major 2-D u8 tensor (NestedFP component planes).
#[derive(Clone, Debug, PartialEq)]
pub struct TensorU8 {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<u8>,
}

impl TensorU8 {
    pub fn from_vec(rows: usize, cols: usize, data: Vec<u8>) -> Self {
        assert_eq!(data.len(), rows * cols);
        TensorU8 { rows, cols, data }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_known() {
        let a = Tensor2::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Tensor2::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_accumulates_zeros_like_any_other_term() {
        // the oracle contract: no zero-skip — signed-zero and non-finite
        // propagation behave exactly like the blocked engine's
        let a = Tensor2::from_vec(1, 2, vec![0.0, 1.0]);
        let b = Tensor2::from_vec(2, 1, vec![f32::INFINITY, 2.0]);
        assert!(a.matmul(&b).data[0].is_nan(), "0·inf must contribute NaN");
    }

    #[test]
    fn transpose_roundtrip() {
        let t = Tensor2::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let tt = t.transposed();
        assert_eq!((tt.rows, tt.cols), (3, 2));
        assert_eq!(tt.get(2, 1), t.get(1, 2));
        assert_eq!(tt.transposed(), t);
    }

    #[test]
    fn absmax_and_rows() {
        let t = Tensor2::from_vec(2, 3, vec![1.0, -5.0, 2.0, 0.5, 0.25, -0.75]);
        assert_eq!(t.abs_max(), 5.0);
        assert_eq!(t.row_abs_max(), vec![5.0, 0.75]);
    }

    #[test]
    fn rel_err_zero_for_identical() {
        let t = Tensor2::from_vec(1, 3, vec![1.0, 2.0, 3.0]);
        assert_eq!(t.rel_err(&t), 0.0);
    }
}

//! The FP8 quantization *baseline* the paper compares against (Tables 1–2):
//! per-channel absmax E4M3 weight quantization plus per-tensor (or
//! per-token) absmax activation quantization.
//!
//! NestedFP8 instead uses a single global scale of 2⁸ baked into the bit
//! layout; this module lets `eval` reproduce the FP8(B)-vs-FP8(N)
//! comparison.

use super::e4m3;
use super::tensor::Tensor2;

/// A weight matrix quantized per output channel (row) to E4M3.
#[derive(Clone, Debug)]
pub struct QuantizedWeight {
    pub rows: usize,
    pub cols: usize,
    /// E4M3 payloads, row-major.
    pub codes: Vec<u8>,
    /// Per-row scale: real_value = decode(code) / scale.
    pub scales: Vec<f32>,
}

/// Per-channel (per output row) absmax quantization: scale_r = 448 / max|row|.
pub fn quantize_weight_per_channel(w: &Tensor2) -> QuantizedWeight {
    let maxes = w.row_abs_max();
    let scales: Vec<f32> = maxes
        .iter()
        .map(|&m| if m > 0.0 { e4m3::E4M3_MAX / m } else { 1.0 })
        .collect();
    let mut codes = Vec::with_capacity(w.data.len());
    for r in 0..w.rows {
        let s = scales[r];
        for &v in w.row(r) {
            codes.push(e4m3::encode_sat(v * s));
        }
    }
    QuantizedWeight {
        rows: w.rows,
        cols: w.cols,
        codes,
        scales,
    }
}

impl QuantizedWeight {
    /// Dequantize back to f32 (what the GEMM "sees").
    pub fn dequantize(&self) -> Tensor2 {
        let mut data = Vec::with_capacity(self.codes.len());
        for r in 0..self.rows {
            let s = self.scales[r];
            for c in 0..self.cols {
                data.push(e4m3::decode(self.codes[r * self.cols + c]) / s);
            }
        }
        Tensor2::from_vec(self.rows, self.cols, data)
    }
}

/// Per-tensor absmax activation quantization: returns the fake-quantized
/// activations (quantize→dequantize), modelling FP8 GEMM numerics.
pub fn fake_quantize_activation_per_tensor(x: &Tensor2) -> Tensor2 {
    let m = x.abs_max();
    let scale = if m > 0.0 { e4m3::E4M3_MAX / m } else { 1.0 };
    let data = x
        .data
        .iter()
        .map(|&v| e4m3::decode(e4m3::encode_sat(v * scale)) / scale)
        .collect();
    Tensor2::from_vec(x.rows, x.cols, data)
}

/// Per-token (per row of the activation matrix) absmax variant.
pub fn fake_quantize_activation_per_token(x: &Tensor2) -> Tensor2 {
    let mut data = Vec::with_capacity(x.data.len());
    for r in 0..x.rows {
        let m = x.row(r).iter().fold(0.0f32, |acc, &v| acc.max(v.abs()));
        let scale = if m > 0.0 { e4m3::E4M3_MAX / m } else { 1.0 };
        for &v in x.row(r) {
            data.push(e4m3::decode(e4m3::encode_sat(v * scale)) / scale);
        }
    }
    Tensor2::from_vec(x.rows, x.cols, data)
}

/// Weight fake-quant round trip for error studies.
pub fn fake_quantize_weight_per_channel(w: &Tensor2) -> Tensor2 {
    quantize_weight_per_channel(w).dequantize()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn random_tensor(rows: usize, cols: usize, scale: f32, seed: u64) -> Tensor2 {
        let mut rng = Pcg64::seeded(seed);
        let data = (0..rows * cols)
            .map(|_| rng.normal() as f32 * scale)
            .collect();
        Tensor2::from_vec(rows, cols, data)
    }

    #[test]
    fn per_channel_scales_use_row_max() {
        let w = Tensor2::from_vec(2, 2, vec![1.0, -2.0, 0.5, 0.25]);
        let q = quantize_weight_per_channel(&w);
        assert_eq!(q.scales[0], 448.0 / 2.0);
        assert_eq!(q.scales[1], 448.0 / 0.5);
    }

    #[test]
    fn dequant_error_bounded() {
        let w = random_tensor(16, 64, 0.05, 3);
        let dq = fake_quantize_weight_per_channel(&w);
        // E4M3 with absmax scaling: relative error per element <= 2^-4 of the
        // row max (subnormal region aside); check a loose global bound
        let err = dq.rel_err(&w);
        assert!(err < 0.05, "rel err {err}");
        // row max is exactly representable after scaling (448 hits the grid)
        for r in 0..w.rows {
            let m = w.row(r).iter().fold(0.0f32, |a, &v| a.max(v.abs()));
            let mq = dq.row(r).iter().fold(0.0f32, |a, &v| a.max(v.abs()));
            assert!(((m - mq) / m).abs() < 1e-6, "row {r}: {m} vs {mq}");
        }
    }

    #[test]
    fn activation_quant_preserves_zero_and_sign() {
        let x = Tensor2::from_vec(1, 4, vec![0.0, -1.0, 2.0, -3.0]);
        let q = fake_quantize_activation_per_tensor(&x);
        assert_eq!(q.data[0], 0.0);
        assert!(q.data[1] < 0.0 && q.data[3] < 0.0 && q.data[2] > 0.0);
    }

    #[test]
    fn per_token_tighter_than_per_tensor_on_extreme_rows() {
        // E4M3 is itself floating point, so absmax scaling only matters at
        // the range edges: make the small row so small that the per-tensor
        // scale pushes it into the subnormal region (ratio >> 2^12), where
        // per-token scaling keeps full relative precision.
        let mut data = vec![0.0f32; 2 * 64];
        let mut rng = Pcg64::seeded(5);
        for j in 0..64 {
            data[j] = rng.normal() as f32 * 1000.0;
            data[64 + j] = rng.normal() as f32 * 1e-4;
        }
        let x = Tensor2::from_vec(2, 64, data);
        let pt = fake_quantize_activation_per_tensor(&x);
        let tok = fake_quantize_activation_per_token(&x);
        let rel = |q: &Tensor2| -> f64 {
            (0..64)
                .map(|j| {
                    let v = x.get(1, j) as f64;
                    if v == 0.0 {
                        0.0
                    } else {
                        ((q.get(1, j) as f64 - v) / v).abs()
                    }
                })
                .sum::<f64>()
                / 64.0
        };
        let (err_pt, err_tok) = (rel(&pt), rel(&tok));
        assert!(
            err_tok < err_pt * 0.5,
            "per-token {err_tok} not clearly better than per-tensor {err_pt}"
        );
    }
}

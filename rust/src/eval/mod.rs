//! Accuracy harness: the substitute for the paper's LM-Eval-Harness runs
//! (Tables 1–2). Compares FP16 / baseline-FP8 / NestedFP8 on the in-repo
//! trained model using three synthetic downstream tasks plus logit-level
//! and weight-level error metrics.

pub mod tasks;
pub mod quanterr;
pub mod accuracy;

pub use tasks::{eval_prompts, gen_example, Task};

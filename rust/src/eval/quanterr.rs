//! Weight-level quantization-error metrics: the fast, model-free half of
//! the Table 1/2 comparison. Quantifies how close NestedFP8's upper plane
//! (global 2^8 scale) is to per-channel absmax E4M3 — the paper's claim
//! that the fixed-scale nested format "achieves accuracy comparable to
//! the FP8 baseline despite foregoing fine-grained quantization".

use crate::format::nested::{self, DecomposeResult};
use crate::format::quant;
use crate::format::tensor::Tensor2;
use crate::format::fp16::F16;
use crate::gemm::{GemmEngine, GemmFormat, GemmWeights};

/// Error metrics of a quantized weight tensor vs its fp16 original.
#[derive(Clone, Copy, Debug)]
pub struct QuantError {
    /// Relative Frobenius error ||q - w|| / ||w||.
    pub rel_fro: f64,
    /// Mean per-element relative error (non-zero elements).
    pub mean_rel: f64,
    /// Worst per-element relative error.
    pub max_rel: f64,
}

fn error_of(q: &[f32], w: &[f32]) -> QuantError {
    assert_eq!(q.len(), w.len());
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    let mut sum_rel = 0.0f64;
    let mut n_rel = 0usize;
    let mut max_rel = 0.0f64;
    for (a, b) in q.iter().zip(w) {
        let d = (*a as f64 - *b as f64).powi(2);
        num += d;
        den += (*b as f64).powi(2);
        if *b != 0.0 {
            let r = ((*a - *b) / *b).abs() as f64;
            sum_rel += r;
            n_rel += 1;
            if r > max_rel {
                max_rel = r;
            }
        }
    }
    QuantError {
        rel_fro: (num / den.max(1e-300)).sqrt(),
        mean_rel: sum_rel / n_rel.max(1) as f64,
        max_rel,
    }
}

/// Compare the two FP8 representations of an fp16 weight tensor
/// (elements must be NestedFP-eligible).
pub fn compare_fp8_variants(w: &Tensor2) -> (QuantError, QuantError) {
    // reference fp16 values (exactly representable)
    let w16: Vec<u16> = w
        .data
        .iter()
        .map(|&v| F16::from_f32(v).to_bits())
        .collect();
    let w_vals: Vec<f32> = w16.iter().map(|&b| F16::from_bits(b).to_f32()).collect();

    // baseline: per-channel absmax E4M3
    let w_t = Tensor2::from_vec(w.rows, w.cols, w_vals.clone());
    let baseline = quant::fake_quantize_weight_per_channel(&w_t);
    let err_base = error_of(&baseline.data, &w_vals);

    // NestedFP8: upper plane at the global 2^8 scale
    let nested = match nested::decompose_tensor(w.rows, w.cols, &w16) {
        DecomposeResult::Nested(t) => t,
        DecomposeResult::Exception { .. } => panic!("ineligible tensor in comparison"),
    };
    let w8 = nested.fp8_weights_f32();
    let err_nested = error_of(&w8, &w_vals);

    (err_base, err_nested)
}

/// Output-level (activation-weighted) FP8 error, measured through the
/// real compute engine rather than weight tables: how far the GEMM
/// *products* drift once activations multiply in. The reference is the
/// fused `Nested16` product — bit-identical to FP16, so it is the exact
/// baseline the paper's losslessness claim provides for free.
#[derive(Clone, Copy, Debug)]
pub struct GemmOutputError {
    /// Per-channel absmax FP8 baseline vs the FP16 product.
    pub baseline: QuantError,
    /// NestedFP8 (upper plane, global 2⁻⁸ scale) vs the FP16 product.
    pub nested8: QuantError,
}

/// Multiply `x` [M,K] by `w` [N,K] under all three precisions on
/// [`GemmEngine`] (replacing the old reconstruct + `Tensor2::matmul`
/// reference path) and compare the FP8 variants' outputs against the
/// exact FP16 product. Weights must be NestedFP-eligible.
pub fn gemm_output_error(w: &Tensor2, x: &Tensor2) -> GemmOutputError {
    let engine = GemmEngine::default();
    let nested = GemmWeights::prepare(w, GemmFormat::Nested16)
        .expect("ineligible tensor in comparison");
    let fp8 = GemmWeights::prepare(w, GemmFormat::Fp8).expect("fp8 prepare");
    let out16 = engine.matmul(x, &nested, GemmFormat::Nested16);
    let out8n = engine.matmul(x, &nested, GemmFormat::Nested8);
    let out8b = engine.matmul(x, &fp8, GemmFormat::Fp8);
    GemmOutputError {
        baseline: error_of(&out8b.data, &out16.data),
        nested8: error_of(&out8n.data, &out16.data),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn gauss_tensor(rows: usize, cols: usize, std: f32, seed: u64) -> Tensor2 {
        let mut rng = Pcg64::seeded(seed);
        let data = (0..rows * cols)
            .map(|_| (rng.normal() as f32 * std).clamp(-1.7, 1.7))
            .collect();
        Tensor2::from_vec(rows, cols, data)
    }

    #[test]
    fn nested_error_comparable_to_baseline() {
        // the Table-2 claim at the weight level: NestedFP8's error is the
        // same order as per-channel absmax (both are 3-bit-mantissa FP)
        let w = gauss_tensor(64, 256, 0.05, 9);
        let (base, nested) = compare_fp8_variants(&w);
        assert!(base.rel_fro > 0.0 && nested.rel_fro > 0.0);
        let ratio = nested.rel_fro / base.rel_fro;
        assert!(
            ratio < 2.0,
            "nested {:.4} vs baseline {:.4} (ratio {ratio:.2})",
            nested.rel_fro,
            base.rel_fro
        );
    }

    #[test]
    fn both_errors_bounded_by_e4m3_ulp() {
        let w = gauss_tensor(32, 128, 0.1, 11);
        let (base, nested) = compare_fp8_variants(&w);
        // 3-bit mantissa -> <= 2^-4 relative, up to subnormal effects
        assert!(base.mean_rel < 0.04, "{base:?}");
        assert!(nested.mean_rel < 0.04, "{nested:?}");
    }

    #[test]
    fn output_error_comparable_through_the_engine() {
        // the Table-2 claim at the *product* level: with real activations
        // multiplied in, NestedFP8's output error stays the same order as
        // the per-channel absmax baseline's
        let w = gauss_tensor(48, 96, 0.05, 21);
        let mut rng = Pcg64::seeded(22);
        let x = Tensor2::from_vec(
            12,
            96,
            (0..12 * 96).map(|_| rng.normal() as f32 * 0.5).collect(),
        );
        let e = gemm_output_error(&w, &x);
        assert!(e.baseline.rel_fro > 0.0 && e.nested8.rel_fro > 0.0);
        assert!(e.baseline.rel_fro < 0.1, "{:?}", e.baseline);
        assert!(e.nested8.rel_fro < 0.1, "{:?}", e.nested8);
        let ratio = e.nested8.rel_fro / e.baseline.rel_fro;
        assert!(ratio < 2.5, "output-level ratio {ratio:.2}");
    }

    #[test]
    fn nested_loses_no_range_within_eligibility() {
        // large (but eligible) weights: nested handles them with zero
        // saturation because 1.75*2^8 == 448 == E4M3 max
        let w = Tensor2::from_vec(1, 4, vec![1.75, -1.75, 1.0, -0.001]);
        let (_, nested) = compare_fp8_variants(&w);
        assert!(nested.max_rel < 0.07, "{nested:?}");
    }
}

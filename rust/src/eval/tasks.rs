//! The synthetic downstream tasks — a bit-exact Rust mirror of
//! `python/compile/corpus.py` (same PCG64 stream, same grammar), so the
//! eval prompts here match the training distribution exactly and the two
//! languages can cross-check each other.

use crate::util::rng::Pcg64;

const LETTERS: &[u8] = b"abcdefghijklmnopqrstuvwxyz";

/// The three tasks (stand-ins for Minerva Math / MMLU-Pro / BBH).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Task {
    Copy,
    Sort,
    Add,
}

impl Task {
    pub const ALL: [Task; 3] = [Task::Copy, Task::Sort, Task::Add];

    pub fn name(self) -> &'static str {
        match self {
            Task::Copy => "copy",
            Task::Sort => "sort",
            Task::Add => "add",
        }
    }

    fn index(self) -> u64 {
        match self {
            Task::Copy => 0,
            Task::Sort => 1,
            Task::Add => 2,
        }
    }
}

/// Generate one (prompt, answer) pair — mirrors corpus.gen_example.
pub fn gen_example(rng: &mut Pcg64, task: Task) -> (String, String) {
    match task {
        Task::Copy => {
            let n = rng.range_u64(3, 7) as usize;
            let s: String = (0..n)
                .map(|_| LETTERS[rng.range_u64(0, 26) as usize] as char)
                .collect();
            (format!("C:{s}="), format!("{s};"))
        }
        Task::Sort => {
            let n = rng.range_u64(3, 7) as usize;
            let s: String = (0..n)
                .map(|_| LETTERS[rng.range_u64(0, 26) as usize] as char)
                .collect();
            let mut sorted: Vec<u8> = s.bytes().collect();
            sorted.sort_unstable();
            (
                format!("S:{s}="),
                format!("{};", String::from_utf8(sorted).unwrap()),
            )
        }
        Task::Add => {
            let a = rng.range_u64(0, 100);
            let b = rng.range_u64(0, 100);
            (format!("A:{a}+{b}="), format!("{};", a + b))
        }
    }
}

/// Held-out eval set — mirrors corpus.eval_prompts (seed + 1000 + task
/// index, default PCG stream).
pub fn eval_prompts(seed: u64, task: Task, n: usize) -> Vec<(String, String)> {
    let mut rng = Pcg64::seeded(seed + 1000 + task.index());
    (0..n).map(|_| gen_example(&mut rng, task)).collect()
}

/// Pad a prompt to a chunk-aligned length by prepending full task lines
/// (benign, in-distribution context). Returns byte tokens.
pub fn chunk_aligned_prompt(prompt: &str, align: usize, filler_seed: u64) -> Vec<i32> {
    if prompt.len() % align == 0 {
        return prompt.bytes().map(|b| b as i32).collect();
    }
    let mut rng = Pcg64::seeded(filler_seed);
    let mut prefix = String::new();
    // grow the prefix with whole task lines past the next multiple, then
    // trim the prefix head to land exactly on a multiple of `align`
    let target0 = prompt.len().div_ceil(align) * align;
    while prefix.len() + prompt.len() < target0 {
        let t = Task::ALL[rng.range_u64(0, 3) as usize];
        let (p, a) = gen_example(&mut rng, t);
        prefix.push_str(&p);
        prefix.push_str(&a);
    }
    let total = prefix.len() + prompt.len();
    let trim = total % align; // always <= prefix.len(); see tests
    prefix.drain(..trim);
    let full = format!("{prefix}{prompt}");
    debug_assert_eq!(full.len() % align, 0, "alignment failed: {}", full.len());
    full.bytes().map(|b| b as i32).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grammar_shapes() {
        let mut rng = Pcg64::seeded(1);
        for _ in 0..50 {
            let (p, a) = gen_example(&mut rng, Task::Copy);
            assert!(p.starts_with("C:") && p.ends_with('='));
            assert!(a.ends_with(';'));
            assert_eq!(&p[2..p.len() - 1], &a[..a.len() - 1]);

            let (p, a) = gen_example(&mut rng, Task::Sort);
            let src = &p[2..p.len() - 1];
            let mut sorted: Vec<u8> = src.bytes().collect();
            sorted.sort_unstable();
            assert_eq!(a.as_bytes()[..a.len() - 1], sorted[..]);

            let (p, a) = gen_example(&mut rng, Task::Add);
            let body = &p[2..p.len() - 1];
            let (x, y) = body.split_once('+').unwrap();
            let sum: u64 = x.parse::<u64>().unwrap() + y.parse::<u64>().unwrap();
            assert_eq!(a, format!("{sum};"));
        }
    }

    #[test]
    fn eval_sets_deterministic_and_distinct() {
        let a = eval_prompts(100, Task::Copy, 10);
        let b = eval_prompts(100, Task::Copy, 10);
        assert_eq!(a, b);
        let c = eval_prompts(100, Task::Sort, 10);
        assert_ne!(a[0].0, c[0].0);
    }

    #[test]
    fn chunk_alignment() {
        for align in [8usize, 16, 32] {
            for prompt in ["C:abc=", "A:12+34=", "S:zyxwvu="] {
                let toks = chunk_aligned_prompt(prompt, align, 5);
                assert_eq!(toks.len() % align, 0, "{prompt} align {align}");
                // the prompt itself must be the suffix
                let tail: String = toks[toks.len() - prompt.len()..]
                    .iter()
                    .map(|&t| t as u8 as char)
                    .collect();
                assert_eq!(tail, prompt);
            }
        }
    }
}

//! Downstream-task accuracy on the real (PJRT) backend: greedy decoding
//! of held-out task prompts under each precision mode — the Tables 1–2
//! analog (DESIGN.md §2 explains the task substitution).

use anyhow::Result;

use crate::coordinator::backend::{ModeMap, RealBackend};
use crate::coordinator::engine::{Engine, EngineConfig};
use crate::coordinator::precision::PrecisionPolicy;
use crate::coordinator::request::Request;
use crate::runtime::ModelRuntime;

use super::tasks::{self, Task};

/// Accuracy of one task under one mode.
#[derive(Clone, Debug)]
pub struct TaskAccuracy {
    pub task: Task,
    pub n: usize,
    pub correct: usize,
    pub exact_prefix: usize,
}

impl TaskAccuracy {
    pub fn accuracy(&self) -> f64 {
        self.correct as f64 / self.n.max(1) as f64
    }
}

/// Run the eval set for every task under artifact mode `mode`
/// ("fp16" | "nested16" | "nested8").
///
/// `rt` must be loaded with decode+prefill kinds for that mode. Requests
/// are all submitted at t=0, so this also exercises continuous batching.
pub fn evaluate_mode(
    rt: ModelRuntime,
    mode: &'static str,
    n_per_task: usize,
    seed: u64,
) -> Result<Vec<TaskAccuracy>> {
    let chunk_align = rt
        .manifest
        .prefill_chunks
        .iter()
        .copied()
        .min()
        .unwrap_or(32);
    let max_batch = rt.manifest.decode_buckets.iter().copied().max().unwrap_or(4);
    let max_seq = rt.manifest.model.max_seq;
    let backend = RealBackend::new(
        rt,
        ModeMap {
            fp16_mode: mode,
            fp8_mode: mode,
        },
        // generous block budget: eval contexts are short
        max_batch * max_seq / 16 + 64,
    );
    let mut engine = Engine::new(
        backend,
        EngineConfig {
            policy: PrecisionPolicy::Fp16Only, // fixed mode via ModeMap
            physical_kv: true,
            ..Default::default()
        },
    );

    // build all requests
    let mut requests = Vec::new();
    let mut keys = Vec::new(); // (task, answer)
    let mut id = 0u64;
    for task in Task::ALL {
        for (i, (prompt, answer)) in tasks::eval_prompts(seed, task, n_per_task)
            .into_iter()
            .enumerate()
        {
            let toks = tasks::chunk_aligned_prompt(&prompt, chunk_align, seed + i as u64);
            let max_new = answer.len() + 4;
            requests.push(
                Request::new(id, toks, max_new, 0.0).with_stop(b';' as i32),
            );
            keys.push((task, answer));
            id += 1;
        }
    }

    let report = engine.run(requests)?;
    let mut out: Vec<TaskAccuracy> = Task::ALL
        .iter()
        .map(|&t| TaskAccuracy {
            task: t,
            n: 0,
            correct: 0,
            exact_prefix: 0,
        })
        .collect();
    for c in &report.completions {
        let (task, answer) = &keys[c.id as usize];
        let slot = out
            .iter_mut()
            .find(|a| a.task == *task)
            .unwrap();
        slot.n += 1;
        let text: String = c.tokens.iter().map(|&t| (t as u8) as char).collect();
        if text == *answer {
            slot.correct += 1;
        }
        if answer.starts_with(text.trim_end_matches(';'))
            || text.starts_with(&answer[..answer.len().min(2)])
        {
            slot.exact_prefix += 1;
        }
    }
    Ok(out)
}

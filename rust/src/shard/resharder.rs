//! The resharder: plan transitions as an explicit, clock-billed
//! drain → repartition → resume window.
//!
//! Switching a replica's tensor-parallel degree is not a register write.
//! The replica first **drains** — the router stops sending it work, the
//! engine freezes admission, and in-flight requests run to completion at
//! the old degree (nothing is dropped, nothing is double-counted; the
//! reshard-invariant property suite pins this). Once nothing is admitted,
//! the **repartition** window opens: weight shards move over the
//! interconnect, billed on the virtual clock by the cost law below. At
//! the window's end the replica **resumes** at the new degree and its
//! frozen queue is admitted again.
//!
//! This module owns the per-replica state machine, the window cost law,
//! and the counters; `coordinator::cluster` drives it from a dedicated
//! event-core component (parked whenever no reshard is pending, so runs
//! that never reshard cost zero extra events and stay bit-identical).

use crate::gpusim::h100;
use crate::model::zoo::ModelSpec;

use super::plan::ShardPlan;

/// Where one replica is in its reshard lifecycle.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ReshardState {
    /// Serving normally at the current plan.
    Serving,
    /// Admission frozen; in-flight requests finishing at the old degree.
    Draining { target_tp: usize },
    /// Weights moving; the window closes at `until` (virtual seconds).
    Repartitioning { target_tp: usize, until: f64 },
}

/// The repartition window cost law: moving the new plan's weight shards
/// over the pool interconnect, plus a fixed reconfiguration latency
/// (process-group teardown/rebuild, allocator reset).
#[derive(Clone, Copy, Debug)]
pub struct ReshardCost {
    /// Interconnect bandwidth for the weight move, bytes/s.
    pub interconnect_bw: f64,
    /// Fixed window overhead, seconds.
    pub base_latency_s: f64,
}

impl Default for ReshardCost {
    fn default() -> Self {
        ReshardCost {
            interconnect_bw: h100::NVLINK_BW,
            base_latency_s: 25e-3,
        }
    }
}

impl ReshardCost {
    /// Window length for re-laying `spec`'s weights from `from.tp` to
    /// `to.tp` shards. Every device ends up loading its new shard, and
    /// shard loads proceed in parallel across the pool — so the billed
    /// time is one per-shard payload (at the *finer* of the two plans,
    /// which bounds the slice every device must fetch) over the
    /// interconnect, plus the fixed latency.
    pub fn window_s(&self, spec: &ModelSpec, from: ShardPlan, to: ShardPlan) -> f64 {
        let tp = from.tp.max(to.tp).max(1);
        let bytes = ShardPlan::weight_bytes_total(spec).div_ceil(tp);
        self.base_latency_s + bytes as f64 / self.interconnect_bw
    }
}

/// Per-replica reshard bookkeeping for one cluster run.
#[derive(Clone, Debug)]
pub struct Resharder {
    states: Vec<ReshardState>,
    cost: ReshardCost,
    /// `(virtual time, replica, new tp)` — one entry per *completed*
    /// reshard, appended at resume time.
    pub timeline: Vec<(f64, usize, usize)>,
    /// Completed reshards.
    pub reshards: usize,
    /// Virtual seconds spent inside repartition windows (drain time is
    /// workload-dependent and accounted by the engine clock, not here).
    pub repartition_s: f64,
}

impl Resharder {
    pub fn new(n_replicas: usize, cost: ReshardCost) -> Resharder {
        Resharder {
            states: vec![ReshardState::Serving; n_replicas],
            cost,
            timeline: Vec::new(),
            reshards: 0,
            repartition_s: 0.0,
        }
    }

    pub fn cost(&self) -> ReshardCost {
        self.cost
    }

    pub fn state(&self, i: usize) -> ReshardState {
        self.states[i]
    }

    /// Is replica `i` anywhere in a reshard window (draining or
    /// repartitioning)? Routers must not send it new work.
    pub fn resharding(&self, i: usize) -> bool {
        self.states[i] != ReshardState::Serving
    }

    /// Any replica mid-reshard?
    pub fn any_pending(&self) -> bool {
        self.states.iter().any(|s| *s != ReshardState::Serving)
    }

    /// Begin a reshard on a serving replica. Returns `false` (and does
    /// nothing) if the replica is already mid-reshard — the autopilot's
    /// dwell discipline should prevent this, but the state machine stays
    /// safe regardless.
    pub fn begin(&mut self, i: usize, target_tp: usize) -> bool {
        if self.states[i] != ReshardState::Serving {
            return false;
        }
        self.states[i] = ReshardState::Draining { target_tp };
        true
    }

    /// The draining replica `i` has no admitted work left: open its
    /// repartition window at `now` and return the window's end time.
    ///
    /// `spec` drives the weight-move term of the window; callers whose
    /// backend has no model (accounting-only test backends) pass `None`
    /// and are billed the fixed latency floor alone.
    pub fn drained(
        &mut self,
        i: usize,
        now: f64,
        spec: Option<&ModelSpec>,
        from: ShardPlan,
    ) -> f64 {
        let ReshardState::Draining { target_tp } = self.states[i] else {
            panic!("replica {i} reported drained while not draining");
        };
        let to = ShardPlan {
            devices: from.devices,
            tp: target_tp,
        };
        let window = match spec {
            Some(s) => self.cost.window_s(s, from, to),
            None => self.cost.base_latency_s,
        };
        let until = now + window;
        self.repartition_s += window;
        self.states[i] = ReshardState::Repartitioning { target_tp, until };
        until
    }

    /// The earliest repartition-window deadline, if any — the resharder
    /// component's `next_tick`.
    pub fn next_deadline(&self) -> Option<f64> {
        self.states
            .iter()
            .filter_map(|s| match s {
                ReshardState::Repartitioning { until, .. } => Some(*until),
                _ => None,
            })
            .min_by(|a, b| a.total_cmp(b))
    }

    /// Declare the reshard counters in a telemetry registry under
    /// `prefix` (both summed across replicas/runs).
    pub fn register_into(&self, r: &mut crate::telemetry::Registry, prefix: &str) {
        use crate::telemetry::registry::MergeRule::Sum;
        r.set_int(&format!("{prefix}.reshards"), Sum, self.reshards as u64);
        r.set_float(&format!("{prefix}.repartition_s"), Sum, self.repartition_s);
    }

    /// Close every window due at `now` (deadline `<= now`), returning
    /// `(replica, new_tp)` for each in replica order. Records the
    /// timeline entries and counters.
    pub fn complete_due(&mut self, now: f64) -> Vec<(usize, usize)> {
        let mut done = Vec::new();
        for (i, s) in self.states.iter_mut().enumerate() {
            if let ReshardState::Repartitioning { target_tp, until } = *s {
                if until <= now + 1e-12 {
                    *s = ReshardState::Serving;
                    self.timeline.push((now, i, target_tp));
                    self.reshards += 1;
                    done.push((i, target_tp));
                }
            }
        }
        done
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;

    #[test]
    fn lifecycle_walks_drain_repartition_resume() {
        let spec = zoo::find("llama31-8b").unwrap();
        let mut rs = Resharder::new(2, ReshardCost::default());
        assert!(!rs.any_pending());
        assert!(rs.begin(0, 2));
        assert!(!rs.begin(0, 4), "double-begin must be refused");
        assert!(rs.resharding(0) && !rs.resharding(1));
        assert_eq!(rs.next_deadline(), None, "draining has no deadline yet");

        let until = rs.drained(0, 10.0, Some(spec), ShardPlan::single(4));
        assert!(until > 10.0);
        assert_eq!(rs.next_deadline(), Some(until));
        assert!(rs.complete_due(10.0).is_empty(), "window still open");
        let done = rs.complete_due(until);
        assert_eq!(done, vec![(0, 2)]);
        assert_eq!(rs.state(0), ReshardState::Serving);
        assert_eq!(rs.reshards, 1);
        assert_eq!(rs.timeline, vec![(until, 0, 2)]);
        assert!(rs.repartition_s > 0.0);
    }

    #[test]
    fn window_cost_scales_with_model_and_latency_floor() {
        let llama = zoo::find("llama31-8b").unwrap();
        let small = zoo::find("mistral-small-24b").unwrap();
        let c = ReshardCost::default();
        let p1 = ShardPlan::single(4);
        let p2 = ShardPlan::with_tp(4, 2).unwrap();
        let w_llama = c.window_s(llama, p1, p2);
        let w_small = c.window_s(small, p1, p2);
        assert!(w_llama >= c.base_latency_s);
        assert!(w_small > w_llama, "bigger model, longer window");
        // finer target shards mean less bytes per device: tp 1->4
        // is cheaper per device than 1->2
        let p4 = ShardPlan::with_tp(4, 4).unwrap();
        assert!(c.window_s(llama, p1, p4) < w_llama);
    }

    #[test]
    #[should_panic(expected = "not draining")]
    fn drained_without_begin_panics() {
        let spec = zoo::find("llama31-8b").unwrap();
        let mut rs = Resharder::new(1, ReshardCost::default());
        rs.drained(0, 0.0, Some(spec), ShardPlan::single(2));
    }

    #[test]
    fn specless_backends_pay_the_latency_floor_only() {
        let mut rs = Resharder::new(1, ReshardCost::default());
        assert!(rs.begin(0, 2));
        let until = rs.drained(0, 5.0, None, ShardPlan::single(4));
        assert!((until - 5.0 - rs.cost().base_latency_s).abs() < 1e-15);
    }
}

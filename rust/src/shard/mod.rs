//! The device-shard layer: tensor parallelism as a *runtime knob*.
//!
//! NestedFP makes precision a runtime control input for SLO management;
//! FLYING SERVING (PAPERS.md, arxiv 2602.22593) shows parallelism degree
//! is a second, independent knob worth switching on the fly. This module
//! gives each replica a fixed pool of devices and a [`ShardPlan`] — the
//! tensor-parallel degree currently active over that pool — plus the
//! machinery to *change* plans while serving:
//!
//! * [`ShardPlan`] — the plan itself, with per-shard weight and KV byte
//!   accounting derived from [`ModelSpec`](crate::model::zoo::ModelSpec)
//!   GEMM shapes, [`GemmWeights`](crate::gemm::GemmWeights) stores, and
//!   the paged cache's [`KvGeometry`](crate::kvcache::KvGeometry).
//! * The shard-aware cost model lives in `gpusim`
//!   ([`step_latency_tp`](crate::gpusim::step_latency_tp)): per-shard
//!   GEMM/attention kernel time plus a latency+bandwidth all-reduce
//!   term, so TP speedup is sublinear and precision-dependent (FP8
//!   gains less — the collective does not shrink with the GEMMs).
//! * [`Resharder`] — the bookkeeper for plan transitions. A reshard is
//!   never free: the replica **drains** (admits nothing, finishes
//!   in-flight work), **repartitions** (a clock-billed window moving
//!   weight shards over the interconnect), then **resumes** at the new
//!   degree. The cluster's event core drives this as a real component
//!   (`coordinator::cluster`); this module owns the states, the cost
//!   law, and the counters.
//!
//! The autopilot arbitrates this ladder against the precision ladder
//! (`coordinator::autopilot`): precision switches are instant, reshards
//! cost a downtime window, so the controller always prefers the cheaper
//! knob first and never moves both on one control tick.

pub mod plan;
pub mod resharder;

pub use plan::ShardPlan;
pub use resharder::{ReshardCost, ReshardState, Resharder};

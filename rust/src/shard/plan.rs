//! Shard plans: a tensor-parallel degree over a fixed per-replica device
//! pool, with the byte accounting that makes plan transitions costable.

use anyhow::{ensure, Result};

use crate::gemm::{GemmFormat, GemmWeights};
use crate::kvcache::KvGeometry;
use crate::model::zoo::{GemmKind, ModelSpec};

/// One replica's parallelism plan: `tp` tensor-parallel shards over a
/// pool of `devices` accelerators. `tp == 1` is the degenerate plan —
/// the whole model on one device, which is exactly the pre-shard-layer
/// world (and costs exactly the same, see
/// [`step_latency_tp`](crate::gpusim::step_latency_tp)).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardPlan {
    /// Fixed device pool owned by the replica (never changes at runtime).
    pub devices: usize,
    /// Active tensor-parallel degree (a power of two `<= devices`).
    pub tp: usize,
}

impl ShardPlan {
    /// The degenerate single-device plan.
    pub fn single(devices: usize) -> ShardPlan {
        ShardPlan {
            devices: devices.max(1),
            tp: 1,
        }
    }

    /// A plan at an explicit degree; rejects degrees the pool cannot
    /// hold and non-power-of-two degrees (the GEMM/KV head splits only
    /// tile evenly at powers of two — the same rule real TP launchers
    /// enforce).
    pub fn with_tp(devices: usize, tp: usize) -> Result<ShardPlan> {
        ensure!(tp >= 1, "tensor-parallel degree must be >= 1");
        ensure!(tp.is_power_of_two(), "tp {tp} is not a power of two");
        ensure!(
            tp <= devices.max(1),
            "tp {tp} exceeds the device pool ({devices})"
        );
        Ok(ShardPlan {
            devices: devices.max(1),
            tp,
        })
    }

    /// The parallelism ladder over a pool: every power of two up to the
    /// pool size, ascending — the autopilot's rungs.
    pub fn rungs(devices: usize) -> Vec<usize> {
        let mut r = Vec::new();
        let mut tp = 1usize;
        while tp <= devices.max(1) {
            r.push(tp);
            tp *= 2;
        }
        r
    }

    /// Total quantizable linear-layer weight bytes for `spec` at FP16
    /// master precision (2 bytes/elem), plus the (never-quantized)
    /// lm head. This is the payload a repartition has to move.
    pub fn weight_bytes_total(spec: &ModelSpec) -> usize {
        let mut elems = 0usize;
        for kind in GemmKind::ALL {
            for (n, k, mult) in spec.gemm_shapes(kind) {
                elems += n * k * mult * spec.n_layers;
            }
        }
        elems += spec.vocab * spec.d_model; // lm head
        2 * elems
    }

    /// Weight bytes resident on **one** shard under this plan: each
    /// device holds `1/tp` of every linear layer (column- or row-split)
    /// and `1/tp` of the vocab-split lm head.
    pub fn weight_bytes_per_shard(&self, spec: &ModelSpec) -> usize {
        Self::weight_bytes_total(spec).div_ceil(self.tp)
    }

    /// Bytes one shard streams for a prepared [`GemmWeights`] store
    /// under `fmt` — the per-shard share of
    /// [`GemmWeights::bytes_streamed`] (output channels split `tp`
    /// ways, so Nested8's half-byte-traffic story composes with
    /// sharding).
    pub fn gemm_bytes_per_shard(&self, w: &GemmWeights, fmt: GemmFormat) -> usize {
        w.bytes_streamed(fmt).div_ceil(self.tp)
    }

    /// Device KV-cache bytes resident on one shard: the paged cache's
    /// full f32-resident budget (K + V) split across shards, since TP
    /// shards the KV heads.
    pub fn kv_bytes_per_shard(&self, geo: &KvGeometry) -> usize {
        let total = geo.total_blocks * geo.block_elems() * 2 * 4; // K+V, f32 budget
        total.div_ceil(self.tp)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;

    #[test]
    fn plan_validation() {
        assert_eq!(ShardPlan::single(4).tp, 1);
        assert!(ShardPlan::with_tp(4, 2).is_ok());
        assert!(ShardPlan::with_tp(4, 4).is_ok());
        assert!(ShardPlan::with_tp(4, 8).is_err(), "pool too small");
        assert!(ShardPlan::with_tp(4, 3).is_err(), "non-power-of-two");
        assert!(ShardPlan::with_tp(4, 0).is_err());
    }

    #[test]
    fn rungs_are_powers_of_two_within_the_pool() {
        assert_eq!(ShardPlan::rungs(1), vec![1]);
        assert_eq!(ShardPlan::rungs(4), vec![1, 2, 4]);
        assert_eq!(ShardPlan::rungs(6), vec![1, 2, 4]);
        assert_eq!(ShardPlan::rungs(8), vec![1, 2, 4, 8]);
        assert_eq!(ShardPlan::rungs(0), vec![1], "empty pool still serves");
    }

    #[test]
    fn weight_accounting_splits_evenly() {
        let spec = zoo::find("llama31-8b").unwrap();
        let total = ShardPlan::weight_bytes_total(spec);
        // an ~8B model at 2 bytes/elem lands in the 10-20 GB band
        assert!(
            total > 8_000_000_000 && total < 25_000_000_000,
            "implausible weight bytes: {total}"
        );
        let p1 = ShardPlan::single(4);
        let p4 = ShardPlan::with_tp(4, 4).unwrap();
        assert_eq!(p1.weight_bytes_per_shard(spec), total);
        let per4 = p4.weight_bytes_per_shard(spec);
        assert!(per4 >= total / 4 && per4 <= total / 4 + 1);
    }

    #[test]
    fn kv_accounting_shards_the_budget() {
        let geo = KvGeometry {
            n_layers: 4,
            n_heads: 2,
            max_seq: 128,
            head_dim: 8,
            block_size: 16,
            total_blocks: 64,
        };
        let p1 = ShardPlan::single(2);
        let p2 = ShardPlan::with_tp(2, 2).unwrap();
        let full = p1.kv_bytes_per_shard(&geo);
        assert_eq!(full, 64 * geo.block_elems() * 8);
        assert_eq!(p2.kv_bytes_per_shard(&geo), full / 2);
    }

    #[test]
    fn gemm_store_bytes_shard() {
        use crate::format::tensor::Tensor2;
        let w = Tensor2::from_vec(8, 16, vec![0.5f32; 128]);
        let g = GemmWeights::prepare(&w, GemmFormat::Nested16).unwrap();
        let p2 = ShardPlan::with_tp(4, 2).unwrap();
        assert_eq!(
            p2.gemm_bytes_per_shard(&g, GemmFormat::Nested16),
            g.bytes_streamed(GemmFormat::Nested16) / 2
        );
        // Nested8 half-traffic composes with sharding
        assert_eq!(
            p2.gemm_bytes_per_shard(&g, GemmFormat::Nested8),
            g.bytes_streamed(GemmFormat::Nested16) / 4
        );
    }
}

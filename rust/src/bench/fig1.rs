//! Figure 1: (a) the trace's request-rate variability; (b) p90 TPOT under
//! FP16 / FP8 / dual-precision on a bursty trace slice.

use anyhow::Result;

use crate::bench::report::Report;
use crate::coordinator::backend::SimBackend;
use crate::coordinator::engine::{Engine, EngineConfig};
use crate::coordinator::precision::{PrecisionPolicy, SloConfig};
use crate::gpusim::WeightFormat;
use crate::model::zoo;
use crate::trace::azure::{self, AzureTraceConfig};
use crate::trace::workload::{build_requests, poisson_arrivals, WorkloadConfig};

/// Figure 1a: generate the day-long rate series and report its
/// variability statistics (the paper's numbers: range 0-100 req/s, 5.8x
/// worst hour, 3.2x worst minute).
pub fn fig1a() -> Report {
    let cfg = AzureTraceConfig::default();
    let rates = azure::generate_rate_series(&cfg);
    let st = azure::stats(&rates);
    let mut rep = Report::new(
        "Fig 1a — synthetic Azure-like trace, per-second request rates",
        &["metric", "value", "paper"],
    );
    rep.row(vec!["seconds".into(), rates.len().to_string(), "86400".into()]);
    rep.row(vec![
        "rate range (req/s)".into(),
        format!("{:.0} - {:.0}", st.min_rate, st.max_rate),
        "0 - 100".into(),
    ]);
    rep.row(vec![
        "worst 1-hour max/min".into(),
        format!("{:.1}x", st.worst_hour_ratio),
        "5.8x".into(),
    ]);
    rep.row(vec![
        "worst 1-minute max/min".into(),
        format!("{:.1}x", st.worst_minute_ratio),
        "3.2x".into(),
    ]);
    // hourly profile sample
    let hourly: Vec<String> = (0..24)
        .step_by(4)
        .map(|h| {
            let win = &rates[h * 3600..(h + 1) * 3600];
            format!("{:02}h:{:.0}", h, win.iter().sum::<f64>() / 3600.0)
        })
        .collect();
    rep.note(format!("mean rate by hour: {}", hourly.join(" ")));
    rep
}

/// One Fig-1b serving run: the busy-hour slice, downscaled 20%, on the
/// simulated H100 with llama-3.1-8b.
fn fig1b_run(policy: PrecisionPolicy, seconds: usize) -> Result<(f64, usize, f64)> {
    let spec = zoo::find("llama31-8b").unwrap();
    let cfg = AzureTraceConfig::default();
    let rates = azure::generate_rate_series(&cfg);
    // the paper replays a bursty 60s window at 20% scale (1-11 req/s);
    // take the busiest minute region
    let start = cfg.busy_minute_start - seconds / 2;
    let slice = azure::downscale(&rates[start..start + seconds], 0.16);
    let arrivals = poisson_arrivals(&slice, 33);
    let wl = WorkloadConfig {
        seed: 5,
        input_len: 0,  // sampled
        output_len: 0, // sampled
        chunk_align: 64,
    };
    let max_seq = 2048;
    let mut requests = build_requests(&arrivals, &wl, max_seq);
    // cap output lengths for run-time sanity
    for r in &mut requests {
        r.max_new_tokens = r.max_new_tokens.min(256);
    }

    // NestedFP serving: fp16 mode = Nested16, fp8 mode = Nested8.
    let backend = SimBackend::new(
        spec,
        WeightFormat::Nested16,
        WeightFormat::Nested8,
        64,
        max_seq,
        64 * (max_seq / 16 + 1) * 2,
    );
    let mut engine = Engine::new(
        backend,
        EngineConfig {
            policy,
            slo: SloConfig::default(),
            physical_kv: false,
            ..Default::default()
        },
    );
    let mut report = engine.run(requests)?;
    let p90 = report.metrics.tpot.percentile(90.0);
    let viol = report
        .metrics
        .slo_violation_seconds(&SloConfig::default());
    let fp16_frac = report.controller.fp16_fraction();
    Ok((p90, viol, fp16_frac))
}

/// Figure 1b: p90 TPOT + SLO violation seconds for the three policies.
pub fn fig1b() -> Result<Report> {
    let mut rep = Report::new(
        "Fig 1b — p90 TPOT on the bursty trace slice (llama31-8b, sim-H100)",
        &["policy", "p90_tpot_ms", "slo_violation_s", "fp16_time_frac"],
    );
    rep.note("SLO: TPOT <= 33.3 ms; paper: fp16 19s viol, fp8 8s, dual == fp8 with >=68% fp16 time");
    let secs = 180;
    for (name, policy) in [
        ("fp16-only", PrecisionPolicy::Fp16Only),
        ("fp8-only", PrecisionPolicy::Fp8Only),
        ("dual (NestedFP)", PrecisionPolicy::Dual),
    ] {
        let (p90, viol, frac) = fig1b_run(policy, secs)?;
        rep.row(vec![
            name.into(),
            format!("{:.1}", p90 * 1e3),
            viol.to_string(),
            format!("{:.0}%", frac * 100.0),
        ]);
    }
    Ok(rep)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig1b_shape_holds() {
        // the paper's qualitative result: fp8 violates less than fp16;
        // dual is close to fp8 while keeping substantial fp16 time
        let (_, viol16, _) = fig1b_run(PrecisionPolicy::Fp16Only, 60).unwrap();
        let (_, viol8, _) = fig1b_run(PrecisionPolicy::Fp8Only, 60).unwrap();
        let (_, viol_dual, frac) = fig1b_run(PrecisionPolicy::Dual, 60).unwrap();
        assert!(viol8 <= viol16, "fp8 {viol8} !<= fp16 {viol16}");
        assert!(
            viol_dual <= viol16,
            "dual {viol_dual} !<= fp16 {viol16}"
        );
        assert!(frac > 0.1, "dual never used fp16 ({frac})");
    }
}

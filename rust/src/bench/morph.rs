//! The per-layer morph study (`repro reproduce morph`): the same
//! Azure-busy-minute surge the autopilot bench replays, but with a
//! per-layer precision schedule installed and the autopilot's ladder run
//! at increasing granularity —
//!
//! * **coarse-3rung** — the legacy whole-replica ladder
//!   (FP16 → Mixed → FP8), schedule pinned to its endpoints,
//! * **fine-4rung** / **fine-8rung** — `morph_rungs` interior rungs, each
//!   demoting a prefix of the sensitivity ranking (MorphServe-style
//!   elastic morphing, arxiv 2506.02006).
//!
//! Every arm reports both axes of the frontier: goodput under the SLO
//! and a quality proxy — the per-iteration demotion error integrated by
//! the controller ([`LayerSchedule::demotion_error`]: 0 = all-FP16,
//! 1 = the all-FP8 error). The acceptance claim, asserted here and in
//! the test suite: the fine ladder **weakly dominates** the coarse arm —
//! goodput no worse, quality-proxy error no higher.
//!
//! The sensitivity ranking is computed once at startup from
//! [`quanterr::gemm_output_error`] on seeded per-layer weight/activation
//! draws (no trained checkpoint in the loop — the ranking mechanism is
//! what the bench exercises, not a particular model's profile).

use anyhow::{ensure, Result};

use crate::bench::autopilot::{surge_workload, SurgeScenario};
use crate::bench::report::Report;
use crate::coordinator::autopilot::AutopilotConfig;
use crate::coordinator::backend::SimBackend;
use crate::coordinator::cluster::{ClusterConfig, ClusterReport, ClusterRouter, SurgeConfig};
use crate::coordinator::engine::EngineConfig;
use crate::coordinator::precision::{LayerSchedule, PrecisionPolicy, SloConfig};
use crate::coordinator::router::RoutingPolicy;
use crate::eval::quanterr;
use crate::format::tensor::Tensor2;
use crate::gpusim::WeightFormat;
use crate::kvcache::KvPressureConfig;
use crate::model::zoo;
use crate::util::rng::Pcg64;

fn gauss(rows: usize, cols: usize, std: f32, seed: u64) -> Tensor2 {
    let mut rng = Pcg64::seeded(seed);
    let data = (0..rows * cols)
        .map(|_| (rng.normal() as f32 * std).clamp(-1.7, 1.7))
        .collect();
    Tensor2::from_vec(rows, cols, data)
}

/// Per-layer quantization sensitivity, computed once at startup: the
/// output-level NestedFP8 error of a seeded per-layer weight draw
/// through the real GEMM engine. Layer weight scales vary deterministically
/// so the ranking is non-trivial (a flat profile would make every
/// demotion order equivalent and the bench vacuous).
pub fn layer_sensitivity(n_layers: usize) -> Vec<f64> {
    (0..n_layers as u64)
        .map(|i| {
            // spread the per-layer weight scale over ~4x so the FP8
            // error profile has real structure to rank
            let std = 0.010 + 0.004 * ((i * 5) % 11) as f32;
            let w = gauss(48, 64, std, 0x6d0 + i);
            let x = gauss(8, 64, 0.5, 0x1a0 + i);
            quanterr::gemm_output_error(&w, &x).nested8.rel_fro
        })
        .collect()
}

/// One frontier arm: the autopilot at `morph_rungs` granularity
/// (0 = the legacy coarse three-rung ladder).
fn morph_cluster(sc: &SurgeScenario, morph_rungs: usize) -> ClusterRouter<SimBackend> {
    let spec = zoo::find("llama31-8b").expect("llama31-8b in the zoo");
    let max_seq = 1024;
    let backends: Vec<SimBackend> = (0..sc.replicas)
        .map(|_| {
            SimBackend::new(
                spec,
                WeightFormat::Nested16,
                WeightFormat::Nested8,
                64,
                max_seq,
                64 * (max_seq / 16 + 1) * 2,
            )
        })
        .collect();
    let cfg = ClusterConfig {
        policy: RoutingPolicy::SloHeadroom,
        engine: EngineConfig {
            policy: PrecisionPolicy::Dual,
            slo: SloConfig::default(),
            physical_kv: false,
            max_iterations: 0,
            kv: KvPressureConfig::default(),
            devices: 1,
        },
        surge: SurgeConfig::disabled(),
        autopilot: Some(AutopilotConfig {
            morph_rungs,
            ..AutopilotConfig::default()
        }),
        ..ClusterConfig::default()
    };
    ClusterRouter::new(backends, cfg)
}

/// Run one arm with the schedule installed on every replica.
pub fn run_morph_arm(
    sc: &SurgeScenario,
    morph_rungs: usize,
    schedule: &LayerSchedule,
) -> Result<ClusterReport> {
    let mut cluster = morph_cluster(sc, morph_rungs);
    cluster.set_layer_schedule(Some(schedule));
    cluster.run(surge_workload(sc))
}

/// Mean per-iteration demotion error of a finished arm, in `[0, 1]`
/// (0 = every iteration all-FP16, 1 = every iteration at the all-FP8
/// error) — the quality axis of the frontier.
pub fn quality_err(report: &ClusterReport) -> f64 {
    let (mut err, mut iters) = (0.0f64, 0usize);
    for r in &report.replicas {
        err += r.controller.sched_err_iters;
        iters += r.controller.sched_iters;
    }
    if iters == 0 {
        0.0
    } else {
        err / iters as f64
    }
}

/// The `repro reproduce morph` entry point: the sensitivity ranking and
/// the quality-vs-goodput frontier, with the weak-domination claim
/// asserted (fine-8rung vs coarse).
pub fn morph_frontier(quick: bool) -> Result<Vec<Report>> {
    let sc = if quick {
        SurgeScenario::quick()
    } else {
        SurgeScenario::full()
    };
    let slo = SloConfig::default();
    let n_requests = surge_workload(&sc).len();

    let spec = zoo::find("llama31-8b").expect("llama31-8b in the zoo");
    let sens = layer_sensitivity(spec.n_layers);
    let schedule = LayerSchedule::from_sensitivity(&sens);

    let mut ranking = Report::new(
        "Morph — per-layer sensitivity ranking (seeded draws through \
         quanterr::gemm_output_error; demotion takes prefixes of this order)",
        &["demotion_rank", "layer", "nested8_rel_fro", "cum_err_frac"],
    );
    for (pos, &layer) in schedule.order().iter().enumerate() {
        ranking.row(vec![
            pos.to_string(),
            layer.to_string(),
            format!("{:.5}", sens[layer]),
            format!("{:.3}", schedule.demotion_error(pos + 1)),
        ]);
    }

    let mut frontier = Report::new(
        "Morph — quality-vs-goodput frontier under the Azure busy-minute \
         surge (llama31-8b, sim-H100, 2 replicas; quality proxy: mean \
         per-iteration demotion error, 1.0 = all-FP8)",
        &[
            "arm",
            "goodput_req_s",
            "slo_violation_s",
            "ttft_p99_ms",
            "tpot_p99_ms",
            "fp16_time_frac",
            "quality_err",
            "mode_switches",
        ],
    );
    frontier.note(format!(
        "{n_requests} requests over {}s (lead {}s, spike minute, drain); \
         SLO: TTFT <= 200 ms, TPOT <= 33.3 ms",
        sc.len_s, sc.lead_s
    ));
    frontier.note(
        "claim: the fine ladder weakly dominates the coarse arm — goodput \
         no worse, quality-proxy error no higher",
    );

    let mut coarse = None;
    let mut fine8 = None;
    for (name, rungs) in [("coarse-3rung", 0usize), ("fine-4rung", 4), ("fine-8rung", 8)] {
        let mut report = run_morph_arm(&sc, rungs, &schedule)?;
        let s = crate::bench::autopilot::summarize(&mut report, &slo);
        let err = quality_err(&report);
        ensure!(
            s.completed == n_requests,
            "{name} drained {} of {n_requests} requests",
            s.completed
        );
        frontier.row(vec![
            name.into(),
            format!("{:.3}", s.goodput_req_s),
            s.slo_violation_s.to_string(),
            format!("{:.1}", s.ttft_p99_s * 1e3),
            format!("{:.1}", s.tpot_p99_s * 1e3),
            format!("{:.0}%", s.fp16_time_frac * 100.0),
            format!("{err:.4}"),
            s.mode_switches.to_string(),
        ]);
        match rungs {
            0 => coarse = Some((s, err)),
            8 => fine8 = Some((s, err)),
            _ => {}
        }
    }
    let (cs, cerr) = coarse.expect("coarse arm ran");
    let (fs, ferr) = fine8.expect("fine arm ran");
    // weak domination, with small scheduling-noise slack on the goodput
    // axis (the report above carries the exact values)
    ensure!(
        fs.goodput_req_s >= cs.goodput_req_s * 0.98,
        "fine ladder lost goodput: {} < coarse {}",
        fs.goodput_req_s,
        cs.goodput_req_s
    );
    ensure!(
        ferr <= cerr * 1.02 + 1e-9,
        "fine ladder lost quality: err {ferr} > coarse {cerr}"
    );
    frontier.note(format!(
        "weak domination holds: fine-8rung goodput {:.3} >= coarse {:.3} (2% slack), \
         quality err {:.4} <= coarse {:.4}",
        fs.goodput_req_s, cs.goodput_req_s, ferr, cerr
    ));
    Ok(vec![ranking, frontier])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sensitivity_ranking_is_deterministic_and_structured() {
        let a = layer_sensitivity(8);
        let b = layer_sensitivity(8);
        assert_eq!(a.len(), 8);
        assert!(a.iter().zip(&b).all(|(x, y)| x.to_bits() == y.to_bits()));
        assert!(a.iter().all(|s| s.is_finite() && *s >= 0.0));
        // the profile must have real structure (not flat), or every
        // demotion order would be equivalent and the bench vacuous
        let min = a.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = a.iter().cloned().fold(0.0f64, f64::max);
        assert!(max > min * 1.05, "flat sensitivity profile: {a:?}");
    }

    /// The acceptance property on the quick scenario: the fine ladder
    /// weakly dominates the coarse three-rung arm on both frontier axes.
    #[test]
    fn fine_ladder_weakly_dominates_the_coarse_arm() {
        let sc = SurgeScenario::quick();
        let slo = SloConfig::default();
        let spec = zoo::find("llama31-8b").unwrap();
        let schedule = LayerSchedule::from_sensitivity(&layer_sensitivity(spec.n_layers));
        let mut coarse = run_morph_arm(&sc, 0, &schedule).unwrap();
        let mut fine = run_morph_arm(&sc, 8, &schedule).unwrap();
        let cerr = quality_err(&coarse);
        let ferr = quality_err(&fine);
        let cs = crate::bench::autopilot::summarize(&mut coarse, &slo);
        let fs = crate::bench::autopilot::summarize(&mut fine, &slo);
        assert_eq!(cs.completed, fs.completed, "both arms drain the workload");
        assert!(
            fs.goodput_req_s >= cs.goodput_req_s * 0.98,
            "goodput: fine {} < coarse {}",
            fs.goodput_req_s,
            cs.goodput_req_s
        );
        assert!(
            ferr <= cerr * 1.02 + 1e-9,
            "quality: fine err {ferr} > coarse {cerr}"
        );
        // the surge must actually demote something in both arms, or the
        // domination claim is vacuous
        assert!(cerr > 0.0, "coarse arm never demoted");
    }
}

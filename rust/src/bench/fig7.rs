//! Figures 7a, 7b, 9 and 13: kernel-level performance on the simulated
//! H100 with the paper's config search.

use crate::bench::report::{ms, pct, Report};
use crate::gpusim::gemm::{gemm_latency, GemmQuery, WeightFormat};
use crate::gpusim::kernel::{KernelConfig, OptLevel, Scheduler};
use crate::gpusim::search;
use crate::model::zoo;

/// The M sweep: the paper steps M by 32 from 32 to 2048 and (Appendix A)
/// pads activations to multiples of the tile dimension Tm "as it provides
/// more robust performance" — so the effective measured grid is
/// tile-aligned. We sweep the tile-aligned grid directly.
fn sweep_m() -> Vec<usize> {
    let mut v = vec![32, 64];
    v.extend((1..=16).map(|i| i * 128));
    v
}

/// Figure 7a: CUTLASS FP16 baseline vs NestedFP16 on each model's largest
/// (N,K), M swept 32..=2048 (paper sweeps by 32; we print every 256 and
/// compute the average over the full 32-step sweep).
pub fn fig7a() -> Vec<Report> {
    let mut out = Vec::new();
    for spec in zoo::main_four() {
        let (n, k) = spec.largest_shape();
        let mut rep = Report::new(
            &format!("Fig 7a — {} largest GEMM (N={n}, K={k})", spec.name),
            &["M", "fp16_ms", "nested16_ms", "overhead"],
        );
        let mut ratios = Vec::new();
        for m in sweep_m() {
            let t16 = search::best_latency(&GemmQuery {
                m,
                n,
                k,
                format: WeightFormat::Fp16,
                opt: OptLevel::Level3,
            });
            let tn = search::best_latency(&GemmQuery {
                m,
                n,
                k,
                format: WeightFormat::Nested16,
                opt: OptLevel::Level3,
            });
            ratios.push(tn / t16);
            if m % 256 == 0 || m == 32 {
                rep.row(vec![m.to_string(), ms(t16), ms(tn), pct(tn / t16)]);
            }
        }
        let avg = ratios.iter().sum::<f64>() / ratios.len() as f64;
        rep.note(format!(
            "average overhead over the full M sweep: {} (paper: 5.69-6.83%)",
            pct(avg)
        ));
        out.push(rep);
    }
    out
}

/// Figure 7b: optimization-level ablation on M x 5120 x 32768.
pub fn fig7b() -> Report {
    let mut rep = Report::new(
        "Fig 7b — NestedFP16 kernel optimization levels (M x 5120 x 32768)",
        &["level", "latency_ms", "vs_prev", "vs_level1"],
    );
    rep.note("paper: level2 -38.3% vs level1; level3 -11.0% vs level2");
    let cfg = KernelConfig {
        tm: 128,
        tn: 128,
        tk: 64,
        cooperative: false,
        scheduler: Scheduler::DataParallel,
    };
    let m = 1024;
    let lat = |opt| {
        gemm_latency(
            &GemmQuery {
                m,
                n: 5120,
                k: 32768,
                format: WeightFormat::Nested16,
                opt,
            },
            &cfg,
        )
        .unwrap()
    };
    let l1 = lat(OptLevel::Level1);
    let l2 = lat(OptLevel::Level2);
    let l3 = lat(OptLevel::Level3);
    rep.row(vec!["1 (3-stage pipeline)".into(), ms(l1), "-".into(), "-".into()]);
    rep.row(vec![
        "2 (+fused 32-bit SIMT)".into(),
        ms(l2),
        format!("{:+.1}%", (l2 / l1 - 1.0) * 100.0),
        format!("{:+.1}%", (l2 / l1 - 1.0) * 100.0),
    ]);
    rep.row(vec![
        "3 (+scheduling/fence)".into(),
        ms(l3),
        format!("{:+.1}%", (l3 / l2 - 1.0) * 100.0),
        format!("{:+.1}%", (l3 / l1 - 1.0) * 100.0),
    ]);
    rep
}

/// Figure 9 (Appendix B): overhead across all 14 unique (N,K) shapes.
pub fn fig9() -> Report {
    let mut rep = Report::new(
        "Fig 9 — NestedFP16 vs CUTLASS baseline across all 14 (N,K) shapes",
        &["N", "K", "avg_overhead", "min", "max"],
    );
    rep.note("paper: per-shape average overheads range 4.3%-7.2%, global avg 6.1%");
    let mut shapes = Vec::new();
    for spec in zoo::main_four() {
        for s in spec.unique_shapes() {
            if !shapes.contains(&s) {
                shapes.push(s);
            }
        }
    }
    let mut all = Vec::new();
    for (n, k) in shapes {
        let mut ratios = Vec::new();
        for m in sweep_m() {
            let t16 = search::best_latency(&GemmQuery {
                m,
                n,
                k,
                format: WeightFormat::Fp16,
                opt: OptLevel::Level3,
            });
            let tn = search::best_latency(&GemmQuery {
                m,
                n,
                k,
                format: WeightFormat::Nested16,
                opt: OptLevel::Level3,
            });
            ratios.push(tn / t16);
        }
        let avg = ratios.iter().sum::<f64>() / ratios.len() as f64;
        let mn = ratios.iter().cloned().fold(f64::INFINITY, f64::min);
        let mx = ratios.iter().cloned().fold(0.0f64, f64::max);
        all.push(avg);
        rep.row(vec![
            n.to_string(),
            k.to_string(),
            pct(avg),
            pct(mn),
            pct(mx),
        ]);
    }
    let global = all.iter().sum::<f64>() / all.len() as f64;
    rep.note(format!("global average overhead: {}", pct(global)));
    rep
}

/// A cuBLAS-like heuristic config pick (no exhaustive search): reproduce
/// the Appendix-D comparison where tuned CUTLASS ~matches cuBLAS.
fn cublas_pick(q: &GemmQuery) -> f64 {
    // heuristic: pick tile by rounding M to the nearest library kernel
    let tm = if q.m <= 64 {
        64
    } else if q.m <= 128 {
        128
    } else {
        256
    };
    let candidates = [
        KernelConfig {
            tm,
            tn: 128,
            tk: 64,
            cooperative: tm >= 128,
            scheduler: Scheduler::DataParallel,
        },
        KernelConfig {
            tm,
            tn: 256,
            tk: 64,
            cooperative: true,
            scheduler: Scheduler::StreamK,
        },
    ];
    let lib_overhead = 0.985; // cuBLAS's slightly better epilogue/launch
    candidates
        .iter()
        .filter_map(|c| gemm_latency(q, c))
        .fold(f64::INFINITY, f64::min)
        * lib_overhead
}

/// Figure 13 (Appendix D.2): tuned CUTLASS baseline vs cuBLAS.
pub fn fig13() -> Report {
    let mut rep = Report::new(
        "Fig 13 — CUTLASS (tuned) baseline vs cuBLAS model, 14 shapes",
        &["N", "K", "cutlass_avg_ms", "cublas_avg_ms", "delta"],
    );
    rep.note("paper: avg difference 1.8%; cuBLAS slightly ahead on the 3 smallest shapes");
    let mut shapes = Vec::new();
    for spec in zoo::main_four() {
        for s in spec.unique_shapes() {
            if !shapes.contains(&s) {
                shapes.push(s);
            }
        }
    }
    shapes.sort_by_key(|&(n, k)| n * k);
    let mut deltas = Vec::new();
    for (n, k) in shapes {
        let mut t_cut = 0.0;
        let mut t_cub = 0.0;
        let mut cnt = 0.0;
        for m in sweep_m() {
            let q = GemmQuery {
                m,
                n,
                k,
                format: WeightFormat::Fp16,
                opt: OptLevel::Level3,
            };
            t_cut += search::best_latency(&q);
            t_cub += cublas_pick(&q);
            cnt += 1.0;
        }
        t_cut /= cnt;
        t_cub /= cnt;
        deltas.push((t_cut / t_cub - 1.0).abs());
        rep.row(vec![
            n.to_string(),
            k.to_string(),
            ms(t_cut),
            ms(t_cub),
            pct(t_cut / t_cub),
        ]);
    }
    let avg = deltas.iter().sum::<f64>() / deltas.len() as f64;
    rep.note(format!("average |difference|: {:.1}%", avg * 100.0));
    rep
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig7b_deltas_in_band() {
        let rep = fig7b();
        assert_eq!(rep.rows.len(), 3);
        // level-2 row, vs_prev column ~ -38%
        let d21: f64 = rep.rows[1][2]
            .trim_end_matches('%')
            .parse()
            .unwrap();
        assert!(d21 < -30.0 && d21 > -46.0, "{d21}");
    }

    #[test]
    fn fig9_overheads_positive_and_bounded() {
        let rep = fig9();
        assert_eq!(rep.rows.len(), 14);
        for row in &rep.rows {
            let avg: f64 = row[2].trim_end_matches('%').parse().unwrap();
            assert!(avg >= 0.0 && avg < 15.0, "{row:?}");
        }
    }
}

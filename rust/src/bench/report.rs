//! Experiment output: aligned-table printing + machine-readable JSON.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::util::json::Json;

/// A tabular experiment report.
#[derive(Clone)]
pub struct Report {
    pub title: String,
    pub notes: Vec<String>,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Report {
    pub fn new(title: &str, header: &[&str]) -> Report {
        Report {
            title: title.to_string(),
            notes: Vec::new(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn note(&mut self, s: impl Into<String>) {
        self.notes.push(s.into());
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "column count mismatch");
        self.rows.push(cells);
    }

    /// Render as an aligned text table.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "== {} ==", self.title);
        for n in &self.notes {
            let _ = writeln!(out, "   {n}");
        }
        let line = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:>w$}", w = w))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let _ = writeln!(out, "{}", line(&self.header, &widths));
        let total: usize = widths.iter().sum::<usize>() + 2 * (widths.len() - 1);
        let _ = writeln!(out, "{}", "-".repeat(total));
        for row in &self.rows {
            let _ = writeln!(out, "{}", line(row, &widths));
        }
        out
    }

    /// Machine-readable JSON for EXPERIMENTS.md tooling.
    pub fn to_json(&self) -> Json {
        let mut obj = BTreeMap::new();
        obj.insert("title".into(), Json::Str(self.title.clone()));
        obj.insert(
            "header".into(),
            Json::Arr(self.header.iter().map(|h| Json::Str(h.clone())).collect()),
        );
        obj.insert(
            "rows".into(),
            Json::Arr(
                self.rows
                    .iter()
                    .map(|r| Json::Arr(r.iter().map(|c| Json::Str(c.clone())).collect()))
                    .collect(),
            ),
        );
        Json::Obj(obj)
    }
}

/// Format seconds as milliseconds with 3 significant decimals.
pub fn ms(t: f64) -> String {
    format!("{:.3}", t * 1e3)
}

/// Format a ratio as a percentage delta ("+6.2%").
pub fn pct(ratio: f64) -> String {
    format!("{:+.2}%", (ratio - 1.0) * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut r = Report::new("t", &["a", "long_header"]);
        r.row(vec!["1".into(), "2".into()]);
        let s = r.render();
        assert!(s.contains("long_header"));
        assert!(s.contains("== t =="));
    }

    #[test]
    #[should_panic(expected = "column count")]
    fn row_width_checked() {
        let mut r = Report::new("t", &["a"]);
        r.row(vec!["1".into(), "2".into()]);
    }

    #[test]
    fn helpers() {
        assert_eq!(ms(0.00125), "1.250");
        assert_eq!(pct(1.062), "+6.20%");
    }
}

//! Experiment output: aligned-table printing + machine-readable JSON,
//! plus the shared `--trace` hooks every `repro reproduce` bench runs
//! through ([`traced`] / [`export_trace`]).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::telemetry::trace::{self, Kind, BENCH_TRACK};
use crate::util::json::Json;

/// A tabular experiment report.
#[derive(Clone)]
pub struct Report {
    pub title: String,
    pub notes: Vec<String>,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Report {
    pub fn new(title: &str, header: &[&str]) -> Report {
        Report {
            title: title.to_string(),
            notes: Vec::new(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn note(&mut self, s: impl Into<String>) {
        self.notes.push(s.into());
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "column count mismatch");
        self.rows.push(cells);
    }

    /// Render as an aligned text table.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "== {} ==", self.title);
        for n in &self.notes {
            let _ = writeln!(out, "   {n}");
        }
        let line = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:>w$}", w = w))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let _ = writeln!(out, "{}", line(&self.header, &widths));
        let total: usize = widths.iter().sum::<usize>() + 2 * (widths.len() - 1);
        let _ = writeln!(out, "{}", "-".repeat(total));
        for row in &self.rows {
            let _ = writeln!(out, "{}", line(row, &widths));
        }
        out
    }

    /// Machine-readable JSON for EXPERIMENTS.md tooling.
    pub fn to_json(&self) -> Json {
        let mut obj = BTreeMap::new();
        obj.insert("title".into(), Json::Str(self.title.clone()));
        obj.insert(
            "header".into(),
            Json::Arr(self.header.iter().map(|h| Json::Str(h.clone())).collect()),
        );
        obj.insert(
            "rows".into(),
            Json::Arr(
                self.rows
                    .iter()
                    .map(|r| Json::Arr(r.iter().map(|c| Json::Str(c.clone())).collect()))
                    .collect(),
            ),
        );
        Json::Obj(obj)
    }
}

/// Run one experiment inside its own trace run, bracketed by a
/// wall-clock `bench` span on the reserved bench track — the shared
/// hook `repro reproduce` wraps every experiment in, so a `--trace`
/// export attributes each arm's events to a named Perfetto process.
/// When tracing is disabled this is exactly `f()`.
pub fn traced<T>(label: &str, f: impl FnOnce() -> T) -> T {
    if !trace::enabled() {
        return f();
    }
    trace::begin_run(label);
    let t0 = std::time::Instant::now();
    trace::begin(BENCH_TRACK, Kind::Bench, 0.0, 0, 0);
    let out = f();
    trace::end(BENCH_TRACK, Kind::Bench, t0.elapsed().as_secs_f64(), 0, 0);
    out
}

/// Uninstall the thread-local tracer and write its recording to `path`
/// as Chrome-trace/Perfetto JSON. Returns `Ok(None)` when no tracer was
/// installed, else the recorded event count.
pub fn export_trace(path: &str) -> anyhow::Result<Option<usize>> {
    match trace::take() {
        Some(tr) => Ok(Some(crate::telemetry::export::write_trace(path, &tr)?)),
        None => Ok(None),
    }
}

/// Format seconds as milliseconds with 3 significant decimals.
pub fn ms(t: f64) -> String {
    format!("{:.3}", t * 1e3)
}

/// Format a ratio as a percentage delta ("+6.2%").
pub fn pct(ratio: f64) -> String {
    format!("{:+.2}%", (ratio - 1.0) * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut r = Report::new("t", &["a", "long_header"]);
        r.row(vec!["1".into(), "2".into()]);
        let s = r.render();
        assert!(s.contains("long_header"));
        assert!(s.contains("== t =="));
    }

    #[test]
    #[should_panic(expected = "column count")]
    fn row_width_checked() {
        let mut r = Report::new("t", &["a"]);
        r.row(vec!["1".into(), "2".into()]);
    }

    #[test]
    fn helpers() {
        assert_eq!(ms(0.00125), "1.250");
        assert_eq!(pct(1.062), "+6.20%");
    }

    #[test]
    fn traced_and_export_trace_round_trip() {
        trace::install(256);
        assert_eq!(traced("arm", || 42), 42);
        let file = format!("nestedfp_trace_{}.json", std::process::id());
        let path = std::env::temp_dir().join(file);
        let path = path.to_str().unwrap().to_string();
        let n = export_trace(&path).unwrap().expect("tracer installed");
        assert_eq!(n, 2, "one bench begin + one end");
        let chk =
            crate::telemetry::export::check_trace(&std::fs::read_to_string(&path).unwrap())
                .unwrap();
        assert_eq!(chk.spans, 1);
        let _ = std::fs::remove_file(&path);
        // with no tracer installed both hooks are inert
        assert_eq!(traced("arm", || 7), 7);
        assert!(export_trace(&path).unwrap().is_none());
    }
}

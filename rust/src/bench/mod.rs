//! The reproduction harness: one module per paper table/figure, invoked
//! via `repro reproduce <exp>`. Each prints the same rows/series the
//! paper reports (shape-level reproduction; see DESIGN.md §5).

pub mod report;
pub mod attention;
pub mod autopilot;
pub mod gemm;
pub mod table1;
pub mod table3;
pub mod fig1;
pub mod fig3;
pub mod fig7;
pub mod fig8;
pub mod cluster;
pub mod kvcache;
pub mod morph;
pub mod parallelism;

pub use report::Report;

//! `repro reproduce gemm` — measure the real compute engine.
//!
//! Sweeps paper-derived GEMM shapes × the four weight formats through
//! `gemm::GemmEngine`, reporting wall-clock GFLOP/s, and cross-checks the
//! *measured* Nested8 : Nested16 ratio against the `gpusim` analytical
//! prediction (the calibration table). The (N, K) shapes are the
//! llama31-8b linear layers scaled by ¼ so a CPU sweep finishes in
//! seconds; 512³ is the acceptance shape, where the blocked engine must
//! beat the naive oracle ≥ 3× single-threaded (asserted loosely here —
//! with slack, release builds only — and reported exactly in the JSON).
//!
//! A committed trajectory file (`GEMM_BENCH.json`) carries per-
//! (shape, format) GFLOP/s floors; when present, measured numbers are
//! checked against it and misses are called out in the report notes.
//! `--update-trajectory` rewrites the file from the current run (full
//! sweeps only — a `--quick` subset would drop floors; floors sit at 70%
//! of measured, absorbing machine-to-machine noise).

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::time::Duration;

use anyhow::{ensure, Result};

use crate::bench::report::Report;
use crate::format::tensor::Tensor2;
use crate::gemm::{GemmEngine, GemmFormat, GemmWeights};
use crate::gpusim::{self, GemmQuery, OptLevel};
use crate::telemetry::profiler::GEMM_PHASES;
use crate::telemetry::{registry, Profiler, Registry};
use crate::util::json::Json;
use crate::util::rng::Pcg64;
use crate::util::timer;

/// The committed perf-trajectory file (repo root).
pub const TRAJECTORY_FILE: &str = "GEMM_BENCH.json";
/// Trajectory schema tag.
pub const TRAJECTORY_SCHEMA: &str = "nestedfp/gemm-trajectory@1";

/// Where the trajectory file lives: the working directory when it is (or
/// can become) the repo root's copy, falling back to the crate root for
/// dev runs started elsewhere (e.g. `cargo run` from a subdirectory).
fn trajectory_path() -> PathBuf {
    let cwd = PathBuf::from(TRAJECTORY_FILE);
    if cwd.exists() {
        return cwd;
    }
    let crate_root = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join(TRAJECTORY_FILE);
    if crate_root.exists() {
        crate_root
    } else {
        cwd
    }
}

/// Options threaded in from the CLI.
#[derive(Clone, Copy, Debug, Default)]
pub struct BenchOpts {
    /// Smaller shape set and fewer timing iterations (CI smoke).
    pub quick: bool,
    /// Rewrite `GEMM_BENCH.json` from this run's measurements.
    pub update_trajectory: bool,
    /// cluster only: the 100+-replica discrete-event scale arm instead
    /// of the 1/2/4-replica surge table.
    pub scale: bool,
}

/// The swept shapes: (M, N, K, tag). 512³ is the acceptance shape.
pub fn shapes(quick: bool) -> Vec<(usize, usize, usize, &'static str)> {
    if quick {
        vec![
            (64, 512, 1024, "decode-ish"),
            (512, 512, 512, "acceptance"),
        ]
    } else {
        vec![
            (16, 1024, 1024, "decode qkv (llama-8b / 4)"),
            (512, 512, 512, "acceptance"),
            (256, 1024, 3584, "prefill down (llama-8b / 4)"),
            (512, 3584, 1024, "prefill gate (llama-8b / 4)"),
        ]
    }
}

/// One measured (shape, format) cell.
#[derive(Clone, Debug)]
struct Measured {
    m: usize,
    n: usize,
    k: usize,
    tag: &'static str,
    fmt: GemmFormat,
    /// Best single-threaded wall time, seconds.
    secs_1t: f64,
    gflops_1t: f64,
    /// Multi-threaded GFLOP/s; `None` when the shape runs single-banded
    /// anyway (M ≤ mc caps the row-band parallelism at 1).
    gflops_mt: Option<f64>,
    mt_threads: usize,
    /// Kernel phase shares of a profiled single-thread pass, in
    /// [`GEMM_PHASES`] order (pack, microkernel, reduce); sums to ~1.
    phase_share: [f64; 3],
}

fn gflops(m: usize, n: usize, k: usize, secs: f64) -> f64 {
    2.0 * (m as f64) * (n as f64) * (k as f64) / secs / 1e9
}

/// Best-of-N wall time of `f`, in seconds. The iteration count is the
/// only effective cap: `timer::bench`'s time budget engages from the 5th
/// iteration and we never run that many (the big shapes would blow any
/// sub-second budget anyway).
fn best_secs(quick: bool, f: impl FnMut()) -> f64 {
    let (warmup, iters) = if quick { (0, 2) } else { (1, 3) };
    timer::bench(warmup, iters, Duration::from_secs(60), f).min_ns * 1e-9
}

fn synth_operands(m: usize, n: usize, k: usize) -> (Tensor2, Tensor2) {
    let mut rng = Pcg64::seeded((m * 31 + n * 7 + k) as u64);
    let x = Tensor2::from_vec(
        m,
        k,
        (0..m * k).map(|_| rng.normal() as f32 * 0.5).collect(),
    );
    let w = Tensor2::from_vec(
        n,
        k,
        (0..n * k)
            .map(|_| (rng.normal() as f32 * 0.3).clamp(-1.7, 1.7))
            .collect(),
    );
    (x, w)
}

/// Run the sweep. Returns the measured cells plus the naive-oracle best
/// time at the acceptance shape (single thread), if it was in the sweep.
fn run_sweep(opts: &BenchOpts) -> Result<(Vec<Measured>, Option<f64>)> {
    let mt_threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(8);
    let engine_1t = GemmEngine::with_threads(1);
    let engine_mt = GemmEngine::with_threads(mt_threads);
    let mut rows = Vec::new();
    let mut naive_acceptance = None;
    for (m, n, k, tag) in shapes(opts.quick) {
        let (x, w) = synth_operands(m, n, k);
        for fmt in GemmFormat::ALL {
            let g = GemmWeights::prepare(&w, fmt)?;
            let secs_1t = best_secs(opts.quick, || {
                std::hint::black_box(engine_1t.matmul(&x, &g, fmt));
            });
            // only measure (and report) the threaded path when the shape
            // actually fans out into more than one row band
            let gflops_mt = if engine_mt.bands(m) > 1 {
                let secs_mt = best_secs(opts.quick, || {
                    std::hint::black_box(engine_mt.matmul(&x, &g, fmt));
                });
                Some(gflops(m, n, k, secs_mt))
            } else {
                None
            };
            // one profiled pass (separate from the timed ones, so the
            // per-strip clock reads never skew the reported GFLOP/s);
            // totals also fold into the global registry for --json
            let mut prof_engine = GemmEngine::with_threads(1);
            prof_engine.set_profiler(Profiler::enabled(GEMM_PHASES));
            std::hint::black_box(prof_engine.matmul(&x, &g, fmt));
            let p = prof_engine.profiler();
            let total = p.total_seconds();
            let share = |i: usize| {
                if total > 0.0 {
                    p.seconds(i) / total
                } else {
                    0.0
                }
            };
            let phase_share = [share(0), share(1), share(2)];
            registry::with_global(|r| {
                let mut tmp = Registry::new();
                p.register_into(&mut tmp, "gemm.profile");
                r.merge(&tmp);
            });
            rows.push(Measured {
                m,
                n,
                k,
                tag,
                fmt,
                secs_1t,
                gflops_1t: gflops(m, n, k, secs_1t),
                gflops_mt,
                mt_threads,
                phase_share,
            });
        }
        if tag == "acceptance" {
            // the naive reference oracle over the same fp16 weights, with
            // the same warmup/iteration policy as the blocked side so the
            // acceptance ratio compares like with like
            let g = GemmWeights::prepare(&w, GemmFormat::Fp16)?;
            let wt = g.dense_f32(GemmFormat::Fp16).transposed();
            let secs = best_secs(opts.quick, || {
                std::hint::black_box(x.matmul(&wt));
            });
            naive_acceptance = Some(secs);
        }
    }
    Ok((rows, naive_acceptance))
}

fn find<'a>(
    rows: &'a [Measured],
    m: usize,
    n: usize,
    k: usize,
    fmt: GemmFormat,
) -> Option<&'a Measured> {
    rows.iter()
        .find(|r| r.m == m && r.n == n && r.k == k && r.fmt == fmt)
}

/// Main perf report: GFLOP/s per shape × format plus the naive-oracle
/// acceptance ratio.
fn perf_report(rows: &[Measured], naive_secs: Option<f64>) -> Result<Report> {
    let threads = rows.first().map(|r| r.mt_threads).unwrap_or(1);
    let mut rep = Report::new(
        "GEMM engine — measured GFLOP/s (packed-tile blocked kernel, fused NestedFP packs)",
        &[
            "m", "n", "k", "tag", "format", "ms_1t", "gflops_1t", "gflops_mt", "vs_fp16",
            "pack%", "micro%", "reduce%",
        ],
    );
    rep.note("single-threaded times are best-of-N wall clock; vs_fp16 = speedup over the Fp16 path of the same shape");
    rep.note(format!(
        "gflops_mt uses {threads} worker thread(s); '-' = M <= mc, the row-band pool runs a single band anyway"
    ));
    rep.note(
        "pack/micro/reduce = kernel phase shares from a separate profiled pass \
         (pack = fused NestedFP decode into panels; reduce = C tile load/writeback)",
    );
    for r in rows {
        let base = find(rows, r.m, r.n, r.k, GemmFormat::Fp16).map(|b| b.secs_1t);
        let vs = base.map(|b| b / r.secs_1t).unwrap_or(1.0);
        rep.row(vec![
            r.m.to_string(),
            r.n.to_string(),
            r.k.to_string(),
            r.tag.into(),
            r.fmt.label().into(),
            format!("{:.3}", r.secs_1t * 1e3),
            format!("{:.2}", r.gflops_1t),
            r.gflops_mt
                .map(|g| format!("{g:.2}"))
                .unwrap_or_else(|| "-".into()),
            format!("{vs:.2}x"),
            format!("{:.0}%", r.phase_share[0] * 100.0),
            format!("{:.0}%", r.phase_share[1] * 100.0),
            format!("{:.0}%", r.phase_share[2] * 100.0),
        ]);
    }
    if let Some(naive) = naive_secs {
        if let Some(blocked) = find(rows, 512, 512, 512, GemmFormat::Fp16) {
            let speedup = naive / blocked.secs_1t;
            rep.note(format!(
                "acceptance 512x512x512 (1 thread): naive oracle {:.1} ms vs blocked {:.1} ms -> {:.2}x \
                 (target >= 3x{})",
                naive * 1e3,
                blocked.secs_1t * 1e3,
                speedup,
                if speedup >= 3.0 { ", met" } else { " — WARNING: below target" }
            ));
            // loose assertion (release only): the blocked engine must
            // clearly beat the naive oracle; exact value lives in JSON
            if !cfg!(debug_assertions) {
                ensure!(
                    speedup >= 2.0,
                    "blocked engine only {speedup:.2}x over the naive oracle at 512^3 (loose floor 2x, target 3x)"
                );
            }
        }
    }
    Ok(rep)
}

/// Calibration report: measured CPU ratios vs gpusim's H100 predictions.
fn calibration_report(rows: &[Measured]) -> Report {
    let mut rep = Report::new(
        "GEMM engine <-> gpusim calibration (format ratios, measured vs predicted)",
        &[
            "m", "n", "k",
            "n8/n16_pred", "n8/n16_meas", "delta",
            "n16_ovh_pred", "n16_ovh_meas",
        ],
    );
    rep.note("predictions are H100 HBM-roofline latencies (gpusim best config, opt level 3);");
    rep.note("measurements are CPU cache-hierarchy wall clock — expect the same ordering, not equality");
    let mut seen: Vec<(usize, usize, usize)> = Vec::new();
    for r in rows {
        if seen.contains(&(r.m, r.n, r.k)) {
            continue;
        }
        seen.push((r.m, r.n, r.k));
        let q = |format| GemmQuery {
            m: r.m,
            n: r.n,
            k: r.k,
            format,
            opt: OptLevel::Level3,
        };
        let pred_n16 = gpusim::best_latency(&q(GemmFormat::Nested16.to_gpusim()));
        let pred_n8 = gpusim::best_latency(&q(GemmFormat::Nested8.to_gpusim()));
        let pred_f16 = gpusim::best_latency(&q(GemmFormat::Fp16.to_gpusim()));
        let (Some(m16), Some(m8), Some(mf)) = (
            find(rows, r.m, r.n, r.k, GemmFormat::Nested16),
            find(rows, r.m, r.n, r.k, GemmFormat::Nested8),
            find(rows, r.m, r.n, r.k, GemmFormat::Fp16),
        ) else {
            continue;
        };
        let pred_ratio = pred_n16 / pred_n8;
        let meas_ratio = m16.secs_1t / m8.secs_1t;
        rep.row(vec![
            r.m.to_string(),
            r.n.to_string(),
            r.k.to_string(),
            format!("{pred_ratio:.2}x"),
            format!("{meas_ratio:.2}x"),
            format!("{:+.0}%", (meas_ratio / pred_ratio - 1.0) * 100.0),
            format!("{:+.1}%", (pred_n16 / pred_f16 - 1.0) * 100.0),
            format!("{:+.1}%", (m16.secs_1t / mf.secs_1t - 1.0) * 100.0),
        ]);
    }
    rep
}

/// Output-level FP8 quality companion table: the same engine the perf
/// sweep measures, used by `eval::quanterr::gemm_output_error` to compare
/// the FP8 variants' *products* against the exact FP16 product.
fn output_error_report() -> Report {
    let mut rep = Report::new(
        "GEMM engine — output-level FP8 error (eval::quanterr through the engine)",
        &["m", "n", "k", "rel_fro_fp8_baseline", "rel_fro_nested8", "ratio"],
    );
    rep.note("reference = fused Nested16 product (bit-identical to FP16); relative Frobenius over the output");
    for (m, n, k) in [(32usize, 256usize, 512usize), (8, 512, 1024)] {
        let (x, w) = synth_operands(m, n, k);
        let e = crate::eval::quanterr::gemm_output_error(&w, &x);
        rep.row(vec![
            m.to_string(),
            n.to_string(),
            k.to_string(),
            format!("{:.4}", e.baseline.rel_fro),
            format!("{:.4}", e.nested8.rel_fro),
            format!("{:.2}", e.nested8.rel_fro / e.baseline.rel_fro),
        ]);
    }
    rep
}

// ---------------------------------------------------------------------------
// Trajectory file
// ---------------------------------------------------------------------------

/// Floors from `GEMM_BENCH.json` that the given measurements violate.
/// Entries with a `null` floor (the provisional seed) never miss.
fn trajectory_misses(traj: &Json, rows: &[Measured]) -> Result<(usize, Vec<String>), String> {
    if traj.get("schema").and_then(|s| s.as_str()) != Some(TRAJECTORY_SCHEMA) {
        return Err(format!("unexpected schema (want {TRAJECTORY_SCHEMA})"));
    }
    let entries = traj
        .get("entries")
        .and_then(|e| e.as_arr())
        .ok_or("missing 'entries' array")?;
    let mut checked = 0usize;
    let mut misses = Vec::new();
    for e in entries {
        let (Some(m), Some(n), Some(k), Some(fmt)) = (
            e.get("m").and_then(|v| v.as_usize()),
            e.get("n").and_then(|v| v.as_usize()),
            e.get("k").and_then(|v| v.as_usize()),
            e.get("format").and_then(|v| v.as_str()),
        ) else {
            return Err("entry missing m/n/k/format".into());
        };
        let Some(floor) = e.get("floor_gflops").and_then(|v| v.as_f64()) else {
            continue; // provisional entry: nothing to enforce yet
        };
        let Some(meas) = rows
            .iter()
            .find(|r| r.m == m && r.n == n && r.k == k && r.fmt.label() == fmt)
        else {
            continue; // shape not in this sweep (e.g. --quick)
        };
        checked += 1;
        if meas.gflops_1t < floor {
            misses.push(format!(
                "{m}x{n}x{k} {fmt}: {:.2} GFLOP/s < floor {floor:.2}",
                meas.gflops_1t
            ));
        }
    }
    Ok((checked, misses))
}

fn trajectory_json(rows: &[Measured]) -> Json {
    let entries: Vec<Json> = rows
        .iter()
        .map(|r| {
            let mut e = BTreeMap::new();
            e.insert("m".into(), Json::Num(r.m as f64));
            e.insert("n".into(), Json::Num(r.n as f64));
            e.insert("k".into(), Json::Num(r.k as f64));
            e.insert("format".into(), Json::Str(r.fmt.label().into()));
            e.insert("gflops".into(), Json::Num((r.gflops_1t * 100.0).round() / 100.0));
            e.insert(
                "floor_gflops".into(),
                Json::Num((r.gflops_1t * 0.7 * 100.0).round() / 100.0),
            );
            Json::Obj(e)
        })
        .collect();
    let mut root = BTreeMap::new();
    root.insert("schema".into(), Json::Str(TRAJECTORY_SCHEMA.into()));
    root.insert(
        "generated_by".into(),
        Json::Str(
            "repro reproduce gemm --update-trajectory (threads=1, floors = 70% of measured)"
                .to_string(),
        ),
    );
    root.insert("provisional".into(), Json::Bool(false));
    root.insert("entries".into(), Json::Arr(entries));
    Json::Obj(root)
}

/// The `gemm` experiment: perf sweep + calibration table.
pub fn gemm_bench(opts: &BenchOpts) -> Result<Vec<Report>> {
    let (rows, naive) = run_sweep(opts)?;
    let mut perf = perf_report(&rows, naive)?;
    let traj_path = trajectory_path();
    match std::fs::read_to_string(&traj_path) {
        Ok(text) => match Json::parse(&text).and_then(|t| trajectory_misses(&t, &rows)) {
            Ok((0, _)) => perf.note(format!(
                "trajectory {TRAJECTORY_FILE}: no enforceable floors yet (provisional seed) — \
                 run with --update-trajectory on a pinned machine to set them"
            )),
            Ok((checked, misses)) if misses.is_empty() => {
                perf.note(format!("trajectory {TRAJECTORY_FILE}: {checked} floors checked, all met"))
            }
            Ok((checked, misses)) => perf.note(format!(
                "trajectory {TRAJECTORY_FILE}: {}/{checked} floors MISSED — {}",
                misses.len(),
                misses.join("; ")
            )),
            Err(e) => perf.note(format!("trajectory {TRAJECTORY_FILE}: unreadable ({e})")),
        },
        Err(_) => perf.note(format!("trajectory {TRAJECTORY_FILE}: not found (skipped)")),
    }
    if opts.update_trajectory {
        if opts.quick {
            // a quick sweep covers a subset of the shapes: rewriting would
            // silently drop the full-sweep floors
            perf.note(format!(
                "trajectory {TRAJECTORY_FILE}: NOT rewritten — --quick covers a shape subset; \
                 rerun --update-trajectory without --quick"
            ));
        } else {
            std::fs::write(&traj_path, trajectory_json(&rows).to_string() + "\n")?;
            perf.note(format!("trajectory {}: rewritten from this run", traj_path.display()));
        }
    }
    Ok(vec![perf, calibration_report(&rows), output_error_report()])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_sets() {
        let q = shapes(true);
        let f = shapes(false);
        assert!(q.len() < f.len());
        for set in [&q, &f] {
            assert!(
                set.iter().any(|&(m, n, k, tag)| (m, n, k) == (512, 512, 512) && tag == "acceptance"),
                "acceptance shape must always be swept"
            );
        }
    }

    #[test]
    fn committed_trajectory_parses() {
        // the repo-root seed file must match the schema this module reads
        let text = std::fs::read_to_string(trajectory_path())
            .expect("GEMM_BENCH.json missing from repo root");
        let traj = Json::parse(&text).expect("GEMM_BENCH.json is not valid JSON");
        assert_eq!(
            traj.get("schema").and_then(|s| s.as_str()),
            Some(TRAJECTORY_SCHEMA)
        );
        // provisional seed: structure must be checkable even with no rows
        let (checked, misses) = trajectory_misses(&traj, &[]).expect("schema walk");
        assert_eq!(checked, 0, "no measurements given, nothing checkable");
        assert!(misses.is_empty());
        // every full-sweep (shape, format) cell is present
        let entries = traj.get("entries").and_then(|e| e.as_arr()).unwrap();
        assert_eq!(entries.len(), shapes(false).len() * GemmFormat::ALL.len());
    }

    #[test]
    fn misses_flagged_against_floors() {
        let mut e = BTreeMap::new();
        e.insert("m".into(), Json::Num(8.0));
        e.insert("n".into(), Json::Num(8.0));
        e.insert("k".into(), Json::Num(8.0));
        e.insert("format".into(), Json::Str("fp16".into()));
        e.insert("floor_gflops".into(), Json::Num(5.0));
        let mut root = BTreeMap::new();
        root.insert("schema".into(), Json::Str(TRAJECTORY_SCHEMA.into()));
        root.insert("entries".into(), Json::Arr(vec![Json::Obj(e)]));
        let traj = Json::Obj(root);
        let row = Measured {
            m: 8,
            n: 8,
            k: 8,
            tag: "t",
            fmt: GemmFormat::Fp16,
            secs_1t: 1.0,
            gflops_1t: 2.0, // below the 5.0 floor
            gflops_mt: None,
            mt_threads: 1,
            phase_share: [0.0; 3],
        };
        let (checked, misses) = trajectory_misses(&traj, &[row.clone()]).unwrap();
        assert_eq!((checked, misses.len()), (1, 1));
        let fast = Measured {
            gflops_1t: 9.0,
            ..row
        };
        let (_, misses) = trajectory_misses(&traj, &[fast]).unwrap();
        assert!(misses.is_empty());
    }

    #[test]
    fn trajectory_json_roundtrips() {
        let row = Measured {
            m: 4,
            n: 4,
            k: 4,
            tag: "t",
            fmt: GemmFormat::Nested8,
            secs_1t: 0.5,
            gflops_1t: 3.17,
            gflops_mt: Some(6.0),
            mt_threads: 2,
            phase_share: [0.0; 3],
        };
        let j = trajectory_json(&[row]);
        let back = Json::parse(&j.to_string()).unwrap();
        let (checked, misses) = trajectory_misses(&back, &[]).unwrap();
        assert_eq!(checked, 0);
        assert!(misses.is_empty());
    }
}

//! The autopilot surge study (`repro reproduce autopilot`): one
//! Azure-shaped traffic surge replayed against three arms —
//!
//! * **static-fp16** — the quality baseline; no precision control at all,
//! * **static-fp8**  — the throughput baseline; quality paid up front,
//! * **autopilot**   — the closed-loop controller of
//!   [`coordinator::autopilot`](crate::coordinator::autopilot),
//!
//! plus **local-dual** (each engine's reactive per-iteration controller,
//! no cluster coordination — the PR-1 state of the world) as a reference
//! row showing what the closed loop adds.
//!
//! The trace is the window around the day trace's busiest minute
//! (`trace::azure`, 18:12, the 31 → 98 req/s spike) downscaled to a
//! two-replica sim-H100 budget: a calm lead-in the predictor can learn,
//! a ramp it must catch, and a drain it must hand back.
//!
//! The acceptance claim (asserted loosely in tests, reported exactly
//! here and via `--json`): the autopilot arm's goodput is at least
//! static-FP16's, and its SLO-violation seconds are at most both static
//! arms'.

use anyhow::Result;

use crate::bench::report::Report;
use crate::coordinator::autopilot::AutopilotConfig;
use crate::coordinator::backend::SimBackend;
use crate::coordinator::cluster::{ClusterConfig, ClusterReport, ClusterRouter, SurgeConfig};
use crate::coordinator::engine::EngineConfig;
use crate::coordinator::precision::{PrecisionPolicy, SloConfig};
use crate::coordinator::request::Request;
use crate::coordinator::router::RoutingPolicy;
use crate::gpusim::WeightFormat;
use crate::kvcache::KvPressureConfig;
use crate::model::zoo;
use crate::trace::azure::{self, AzureTraceConfig};
use crate::trace::workload::{build_requests, poisson_arrivals, WorkloadConfig};

/// The four bench arms.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Arm {
    StaticFp16,
    StaticFp8,
    LocalDual,
    Autopilot,
}

impl Arm {
    pub fn name(self) -> &'static str {
        match self {
            Arm::StaticFp16 => "static-fp16",
            Arm::StaticFp8 => "static-fp8",
            Arm::LocalDual => "local-dual",
            Arm::Autopilot => "autopilot",
        }
    }
}

/// One replayable surge scenario (everything seeded — same scenario,
/// same report, bit for bit).
#[derive(Clone, Copy, Debug)]
pub struct SurgeScenario {
    /// Seconds of calm lead-in before the busiest minute.
    pub lead_s: usize,
    /// Total window length, seconds.
    pub len_s: usize,
    /// Downscale factor applied to the day trace's rates.
    pub scale: f64,
    /// Engine replicas.
    pub replicas: usize,
    /// Poisson arrival seed.
    pub arrival_seed: u64,
    /// Request length-sampling seed.
    pub shape_seed: u64,
}

impl SurgeScenario {
    /// The default surge: 60 s lead-in, the spike minute, 60 s drain.
    /// Scale 0.32 over two replicas puts each replica at the same load
    /// band fig1b replays on one (its 20%-scale precedent, 0.16): a calm
    /// ~3 req/s per replica rising to ~16 at the spike's crest.
    pub fn full() -> SurgeScenario {
        SurgeScenario {
            lead_s: 60,
            len_s: 180,
            scale: 0.32,
            replicas: 2,
            arrival_seed: 21,
            shape_seed: 9,
        }
    }

    /// CI-budget variant: short lead-in, the full spike minute, and a
    /// short calm tail (the promote-back assertions need one).
    pub fn quick() -> SurgeScenario {
        SurgeScenario {
            lead_s: 15,
            len_s: 90,
            scale: 0.22,
            ..SurgeScenario::full()
        }
    }

    /// Tiny seeded scenario for the golden-trace regression suite: small
    /// enough to replay in a unit-test budget, busy enough to move the
    /// ladder. (Keep in lockstep with `rust/tests/golden_trace.rs` — any
    /// parameter change invalidates the committed snapshot, loudly.)
    pub fn golden() -> SurgeScenario {
        SurgeScenario {
            lead_s: 15,
            len_s: 50,
            scale: 0.16,
            replicas: 2,
            arrival_seed: 21,
            shape_seed: 9,
        }
    }
}

/// The scenario's request list (Poisson arrivals over the downscaled
/// azure window, sampled prompt/output shapes, outputs capped for
/// run-time sanity).
pub fn surge_workload(sc: &SurgeScenario) -> Vec<Request> {
    let cfg = AzureTraceConfig::default();
    let slice = azure::surge_slice(&cfg, cfg.busy_minute_start, sc.lead_s, sc.len_s);
    let rates = azure::downscale(&slice, sc.scale);
    let arrivals = poisson_arrivals(&rates, sc.arrival_seed);
    let wl = WorkloadConfig {
        seed: sc.shape_seed,
        input_len: 0,  // sampled
        output_len: 0, // sampled
        chunk_align: 64,
    };
    let max_seq = 1024;
    let mut requests = build_requests(&arrivals, &wl, max_seq);
    for r in &mut requests {
        r.max_new_tokens = r.max_new_tokens.min(128);
    }
    requests
}

/// Build one arm's cluster (simulated H100s, llama-3.1-8b) without
/// running it — the equivalence suite drives the same construction
/// through both the event-core driver and the lockstep oracle.
pub fn arm_cluster(arm: Arm, sc: &SurgeScenario) -> ClusterRouter<SimBackend> {
    let spec = zoo::find("llama31-8b").expect("llama31-8b in the zoo");
    let max_seq = 1024;
    let backends: Vec<SimBackend> = (0..sc.replicas)
        .map(|_| {
            SimBackend::new(
                spec,
                WeightFormat::Nested16,
                WeightFormat::Nested8,
                64,
                max_seq,
                64 * (max_seq / 16 + 1) * 2,
            )
        })
        .collect();
    let policy = match arm {
        Arm::StaticFp16 => PrecisionPolicy::Fp16Only,
        Arm::StaticFp8 => PrecisionPolicy::Fp8Only,
        Arm::LocalDual | Arm::Autopilot => PrecisionPolicy::Dual,
    };
    let cfg = ClusterConfig {
        policy: RoutingPolicy::SloHeadroom,
        engine: EngineConfig {
            policy,
            slo: SloConfig::default(),
            physical_kv: false,
            max_iterations: 0,
            kv: KvPressureConfig::default(),
            devices: 1,
        },
        // static arms must stay static: no reactive stage demotions
        surge: SurgeConfig::disabled(),
        autopilot: match arm {
            Arm::Autopilot => Some(AutopilotConfig::default()),
            _ => None,
        },
        ..ClusterConfig::default()
    };
    ClusterRouter::new(backends, cfg)
}

/// Run one arm of the study on simulated H100s (llama-3.1-8b).
pub fn run_arm(arm: Arm, sc: &SurgeScenario) -> Result<ClusterReport> {
    arm_cluster(arm, sc).run(surge_workload(sc))
}

/// Headline numbers of one arm (exactly what the report rows print; the
/// acceptance tests and the golden suite read these fields).
#[derive(Clone, Copy, Debug)]
pub struct ArmSummary {
    pub completed: usize,
    pub goodput_req_s: f64,
    pub slo_violation_s: usize,
    pub ttft_p99_s: f64,
    pub tpot_p99_s: f64,
    pub fp16_time_frac: f64,
    pub mode_switches: usize,
    pub dwell_s: [f64; 3],
    pub pre_escalations: usize,
}

pub fn summarize(report: &mut ClusterReport, slo: &SloConfig) -> ArmSummary {
    ArmSummary {
        completed: report.aggregate.completed,
        goodput_req_s: report.aggregate.goodput_req_s(slo),
        slo_violation_s: report.aggregate.slo_violation_seconds(slo),
        ttft_p99_s: report.aggregate.ttft.percentile(99.0),
        tpot_p99_s: report.aggregate.tpot.percentile(99.0),
        fp16_time_frac: report.fp16_fraction(),
        mode_switches: report.aggregate.mode_switches,
        dwell_s: report.aggregate.mode_dwell_s,
        pre_escalations: report.pre_escalations,
    }
}

/// The `repro reproduce autopilot` entry point: the arm table plus the
/// autopilot's control timeline.
pub fn autopilot_surge(quick: bool) -> Result<Vec<Report>> {
    let sc = if quick {
        SurgeScenario::quick()
    } else {
        SurgeScenario::full()
    };
    let slo = SloConfig::default();
    let n_requests = surge_workload(&sc).len();

    let mut arms = Report::new(
        "Autopilot — SLO-aware precision under an Azure-shaped surge \
         (llama31-8b, sim-H100, 2 replicas, SLO-headroom routing)",
        &[
            "arm",
            "goodput_req_s",
            "slo_violation_s",
            "ttft_p99_ms",
            "tpot_p99_ms",
            "fp16_time_frac",
            "mode_switches",
            "dwell_s_fp16/mix/fp8",
            "pre_esc",
        ],
    );
    arms.note(format!(
        "{n_requests} requests over {}s (lead {}s, spike minute, drain); \
         SLO: TTFT <= 200 ms, TPOT <= 33.3 ms",
        sc.len_s, sc.lead_s
    ));
    arms.note(
        "claim: autopilot goodput >= static-fp16, violations <= both static arms, \
         while most calm time stays FP16-locked",
    );

    let mut ladder = Report::new(
        "Autopilot — cluster ladder timeline (severity 0..2N; N rungs \
         pre-armable by the predictor, FP8 pins need measured pressure)",
        &["t_s", "severity", "fp8_pins"],
    );

    for arm in [Arm::StaticFp16, Arm::StaticFp8, Arm::LocalDual, Arm::Autopilot] {
        let mut report = run_arm(arm, &sc)?;
        let s = summarize(&mut report, &slo);
        arms.row(vec![
            arm.name().into(),
            format!("{:.3}", s.goodput_req_s),
            s.slo_violation_s.to_string(),
            format!("{:.1}", s.ttft_p99_s * 1e3),
            format!("{:.1}", s.tpot_p99_s * 1e3),
            format!("{:.0}%", s.fp16_time_frac * 100.0),
            s.mode_switches.to_string(),
            format!(
                "{:.0}/{:.0}/{:.0}",
                s.dwell_s[0], s.dwell_s[1], s.dwell_s[2]
            ),
            s.pre_escalations.to_string(),
        ]);
        if arm == Arm::Autopilot {
            anyhow::ensure!(
                s.completed == n_requests,
                "autopilot arm drained {} of {n_requests} requests",
                s.completed
            );
            let mut fp8_pins = report.demotion_timeline.iter().peekable();
            // the fp8-pin count carries forward between ladder change
            // points (a row without a new pin event keeps the last count)
            let mut pins = 0;
            for &(t, sev) in &report.ladder_timeline {
                while let Some(&&(pt, k)) = fp8_pins.peek() {
                    if pt <= t + 1e-9 {
                        pins = k;
                        fp8_pins.next();
                    } else {
                        break;
                    }
                }
                ladder.row(vec![
                    format!("{t:.2}"),
                    sev.to_string(),
                    pins.to_string(),
                ]);
            }
            ladder.note(format!(
                "{} pre-escalations (predictor-driven, ahead of measured pressure)",
                s.pre_escalations
            ));
        }
    }
    Ok(vec![arms, ladder])
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The acceptance property, on the quick scenario (loose bounds; the
    /// full run reports exact values). Static arms bracket the autopilot:
    /// goodput at least FP16's, violations at most FP16's and within a
    /// whisker of FP8's.
    #[test]
    fn autopilot_beats_fp16_and_matches_fp8_violations() {
        let sc = SurgeScenario::quick();
        let slo = SloConfig::default();
        let n = surge_workload(&sc).len();
        let mut f16 = run_arm(Arm::StaticFp16, &sc).unwrap();
        let mut f8 = run_arm(Arm::StaticFp8, &sc).unwrap();
        let mut ap = run_arm(Arm::Autopilot, &sc).unwrap();
        let s16 = summarize(&mut f16, &slo);
        let s8 = summarize(&mut f8, &slo);
        let sap = summarize(&mut ap, &slo);
        // every arm drains the same workload
        assert_eq!(s16.completed, n);
        assert_eq!(s8.completed, n);
        assert_eq!(sap.completed, n);
        // the surge must actually hurt the FP16 baseline, or the scenario
        // tests nothing
        assert!(
            s16.slo_violation_s >= 3,
            "surge too gentle: fp16 violated only {}s",
            s16.slo_violation_s
        );
        // acceptance: goodput >= static-fp16 (2% slack for scheduling
        // noise; the headline report carries the exact values)
        assert!(
            sap.goodput_req_s >= s16.goodput_req_s * 0.98,
            "autopilot goodput {} < fp16 {}",
            sap.goodput_req_s,
            s16.goodput_req_s
        );
        // acceptance: violations <= static-fp16, and <= static-fp8 plus
        // a small switching allowance (loose bound)
        assert!(
            sap.slo_violation_s <= s16.slo_violation_s,
            "autopilot violated {}s vs fp16 {}s",
            sap.slo_violation_s,
            s16.slo_violation_s
        );
        let fp8_slack = 2 + s8.slo_violation_s / 5;
        assert!(
            sap.slo_violation_s <= s8.slo_violation_s + fp8_slack,
            "autopilot violated {}s vs fp8 {}s (+{fp8_slack} slack)",
            sap.slo_violation_s,
            s8.slo_violation_s
        );
        // and it must not have bought that by abandoning quality: a
        // meaningful share of replica-time stays FP16-locked or Mixed
        let dwell_total: f64 = sap.dwell_s.iter().sum();
        assert!(
            sap.dwell_s[0] + sap.dwell_s[1] > 0.25 * dwell_total,
            "fleet spent almost all time pinned FP8: {:?}",
            sap.dwell_s
        );
    }

    #[test]
    fn autopilot_preescalates_and_promotes_back() {
        let sc = SurgeScenario::quick();
        let report = run_arm(Arm::Autopilot, &sc).unwrap();
        assert!(
            !report.ladder_timeline.is_empty(),
            "the surge never moved the ladder"
        );
        let peak = report.ladder_timeline.iter().map(|&(_, s)| s).max().unwrap();
        assert!(peak >= 2, "ladder peaked at {peak}");
        // the ladder must come back down as the surge drains
        let last = report.ladder_timeline.last().unwrap().1;
        assert!(
            last < peak,
            "ladder never promoted back (peak {peak}, final {last})"
        );
        // mode switches happened and are bounded by the dwell discipline:
        // each replica can switch at most once per escalate_dwell
        let cfg = AutopilotConfig::default();
        let span = report.aggregate.t_end - report.aggregate.t_start;
        let max_switches =
            (span / cfg.escalate_dwell_s.min(cfg.promote_dwell_s)).ceil() as usize + 1;
        for r in &report.replicas {
            assert!(r.mode_stats.switches > 0, "a replica never moved");
            assert!(
                r.mode_stats.switches <= max_switches,
                "replica thrashed: {} switches in {span:.0}s",
                r.mode_stats.switches
            );
        }
    }

    #[test]
    fn workload_is_deterministic() {
        let a = surge_workload(&SurgeScenario::quick());
        let b = surge_workload(&SurgeScenario::quick());
        assert_eq!(a.len(), b.len());
        assert!(!a.is_empty());
        assert!(a.iter().zip(&b).all(|(x, y)| {
            x.arrival == y.arrival
                && x.prompt.len() == y.prompt.len()
                && x.max_new_tokens == y.max_new_tokens
        }));
    }
}

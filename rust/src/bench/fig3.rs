//! Figure 3: (a) weight distributions of linear layers; (b) per-model
//! weight range and NestedFP-eligible layer counts.
//!
//! The in-repo trained model is analyzed from its real checkpoint
//! (weights.bin); the zoo models go through the calibrated sampler.

use std::path::Path;

use anyhow::Result;

use crate::bench::report::Report;
use crate::format::fp16::F16;
use crate::model::applicability::{self, analyze_tensor};
use crate::model::zoo;
use crate::runtime::WeightStore;

/// Figure 3a analog: magnitude histogram of the trained model's linear
/// weights (log-ish buckets), plus eligibility share.
pub fn fig3a(artifacts: &Path) -> Result<Report> {
    let ws = WeightStore::load(&artifacts.join("weights.bin"))?;
    let mut rep = Report::new(
        "Fig 3a — |w| distribution of the in-repo model's linear layers",
        &["bucket", "count", "share"],
    );
    let buckets = [0.0f32, 0.01, 0.05, 0.1, 0.25, 0.5, 1.0, 1.75, f32::INFINITY];
    let mut counts = vec![0usize; buckets.len() - 1];
    let mut total = 0usize;
    let mut max_abs = 0.0f32;
    for (name, t) in &ws.tensors {
        if !name.ends_with(".f16") {
            continue;
        }
        for bits in t.as_u16()? {
            let a = F16::from_bits(bits).abs().to_f32();
            max_abs = max_abs.max(a);
            total += 1;
            for i in 0..buckets.len() - 1 {
                if a >= buckets[i] && a < buckets[i + 1] {
                    counts[i] += 1;
                    break;
                }
            }
        }
    }
    for i in 0..counts.len() {
        let hi = if buckets[i + 1].is_infinite() {
            ">1.75".to_string()
        } else {
            format!("{:.2}-{:.2}", buckets[i], buckets[i + 1])
        };
        rep.row(vec![
            hi,
            counts[i].to_string(),
            format!("{:.2}%", counts[i] as f64 / total as f64 * 100.0),
        ]);
    }
    rep.note(format!(
        "max |w| = {max_abs:.3}; eligible share = {:.3}% (paper: vast majority <= 1.75)",
        counts[..counts.len() - 1].iter().sum::<usize>() as f64 / total as f64 * 100.0
    ));
    Ok(rep)
}

/// Figure 3b analog: per-model weight range + eligible layer counts
/// (trained model measured; zoo models calibrated).
pub fn fig3b(artifacts: &Path) -> Result<Report> {
    let mut rep = Report::new(
        "Fig 3b — per-model weight range and NestedFP-eligible layers",
        &["model", "weight_range", "eligible_layers", "share"],
    );

    // the in-repo model, measured from the checkpoint
    if let Ok(ws) = WeightStore::load(&artifacts.join("weights.bin")) {
        let mut app = 0usize;
        let mut tot = 0usize;
        let mut max_abs = 0.0f32;
        for (name, t) in &ws.tensors {
            if !name.ends_with(".f16") || name == "embed" || name == "lm_head" {
                continue;
            }
            let (mx, elig) = analyze_tensor(&t.as_u16()?);
            max_abs = max_abs.max(mx);
            tot += 1;
            if elig {
                app += 1;
            }
        }
        rep.row(vec![
            "tiny-repo (measured)".into(),
            format!("[-{max_abs:.2}, {max_abs:.2}]"),
            format!("{app}/{tot}"),
            format!("{:.1}%", app as f64 / tot as f64 * 100.0),
        ]);
    }

    for spec in zoo::main_four() {
        let report = applicability::analyze_zoo_model(spec, 42);
        let (app, tot) = report.total_counts();
        let (lo, hi) = report.weight_range();
        rep.row(vec![
            spec.name.to_string(),
            format!("[{lo:.2}, {hi:.2}]"),
            format!("{app}/{tot}"),
            format!("{:.1}%", app as f64 / tot as f64 * 100.0),
        ]);
    }
    rep.note("paper: 3 of 4 models fully eligible; Phi-4 has 8.75% exception layers");
    Ok(rep)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig3b_zoo_rows_match_table3() {
        // phi-4 must show its exception layers
        let spec = zoo::find("phi-4-14b").unwrap();
        let report = applicability::analyze_zoo_model(spec, 42);
        let (app, tot) = report.total_counts();
        assert_eq!((app, tot), (146, 160));
        // 14/160 = 8.75% — exactly the paper's number
        assert!(((tot - app) as f64 / tot as f64 - 0.0875).abs() < 1e-9);
    }
}

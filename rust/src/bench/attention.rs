//! Decode-attention microbench: dense-gather oracle vs block-native
//! walk (`repro reproduce attention`).
//!
//! One "step" is what a decode iteration pays for attention: the dense
//! arm gathers each lane's full `[L, H, max_seq, Dh]` cache once and
//! attends every layer from the copy (the pre-PR 5 backend); the
//! block-native arm walks the block tables per layer with fused FP8
//! dequant and never materializes anything. Both arms execute the
//! identical per-query law, so outputs are asserted bit-identical and
//! every measured delta is gather overhead.
//!
//! The sweep crosses context length × batch × precision arm. The
//! acceptance criterion (asserted in this module's tests and annotated
//! in the report): block-native decode is strictly faster whenever
//! `max_seq ≥ 4 ×` the mean context, with bit-identical logits.
//!
//! A committed trajectory file (`ATTN_BENCH.json`) carries per-
//! (arm, batch, mean_ctx) effective-bandwidth floors for the block-native
//! walk (touched bytes / step time); when present, measured numbers are
//! checked against it and misses are called out in the report notes.
//! `--update-trajectory` rewrites the file from the current run (full
//! sweeps only — a `--quick` subset would drop floors; floors sit at 70%
//! of measured, the same discipline as `GEMM_BENCH.json`).

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::time::Instant;

use anyhow::Result;

use crate::attn::oracle::attend_dense_step_with;
use crate::attn::{AttnEngine, AttnLane, AttnStats};
use crate::bench::gemm::BenchOpts;
use crate::bench::report::Report;
use crate::kvcache::{KvGeometry, KvPressureConfig, PagedKvCache};
use crate::telemetry::profiler::ATTN_PHASES;
use crate::telemetry::{registry, Profiler, Registry};
use crate::util::json::Json;
use crate::util::rng::Pcg64;

/// The committed perf-trajectory file (repo root).
pub const TRAJECTORY_FILE: &str = "ATTN_BENCH.json";
/// Trajectory schema tag.
pub const TRAJECTORY_SCHEMA: &str = "nestedfp/attn-trajectory@1";

/// Where the trajectory file lives: the working directory when it is (or
/// can become) the repo root's copy, falling back to the crate root for
/// dev runs started elsewhere.
fn trajectory_path() -> PathBuf {
    let cwd = PathBuf::from(TRAJECTORY_FILE);
    if cwd.exists() {
        return cwd;
    }
    let crate_root = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join(TRAJECTORY_FILE);
    if crate_root.exists() {
        crate_root
    } else {
        cwd
    }
}

/// One sweep point.
#[derive(Clone, Copy, Debug)]
pub struct AttnCase {
    /// Precision arm: "fp16" (all-f32 blocks), "mixed" (half demoted),
    /// "fp8" (everything demotable demoted).
    pub arm: &'static str,
    pub batch: usize,
    /// Mean live context length, tokens (lanes are ragged around it).
    pub mean_len: usize,
    pub max_seq: usize,
    /// Timed repetitions.
    pub reps: usize,
}

/// Measured outcome of one case.
#[derive(Clone, Copy, Debug)]
pub struct AttnMeasure {
    /// Seconds per step, dense-gather arm.
    pub dense_s: f64,
    /// Seconds per step, block-native arm.
    pub block_s: f64,
    pub stats: AttnStats,
    pub bit_identical: bool,
    /// Engine phase shares of a profiled pass, in [`ATTN_PHASES`] order
    /// (block_load, dot, softmax); sums to ~1.
    pub phase_share: [f64; 3],
}

impl AttnMeasure {
    pub fn speedup(&self) -> f64 {
        self.dense_s / self.block_s
    }

    /// Effective KV bandwidth of the block-native walk, GB/s: bytes the
    /// walk actually touched over the measured step time. This is the
    /// trajectory metric — it is monotone in walk speed and independent
    /// of the dense arm.
    pub fn eff_gbps(&self) -> f64 {
        if self.block_s > 0.0 {
            self.stats.touched_bytes as f64 / self.block_s / 1e9
        } else {
            0.0
        }
    }
}

fn bench_geo(max_seq: usize, batch: usize) -> KvGeometry {
    KvGeometry {
        n_layers: 4,
        n_heads: 8,
        max_seq,
        head_dim: 32,
        block_size: 16,
        total_blocks: batch * (max_seq / 16 + 2) + 4,
    }
}

/// Build a physical cache with `batch` ragged sequences around
/// `mean_len`, demoted per `arm`. Returns the cache, the handles, and
/// each live length.
fn build_cache(case: &AttnCase, seed: u64) -> (PagedKvCache, Vec<usize>, Vec<usize>) {
    let g = bench_geo(case.max_seq, case.batch);
    let policy = match case.arm {
        "fp16" => KvPressureConfig::dense_baseline(),
        // mixed tables: demote everything cold but keep the recent half
        // of the mean context f32 via a wide hot tail
        "mixed" => KvPressureConfig {
            demote_watermark_fp8: 0.0,
            hot_tail_blocks: (case.mean_len / 32).max(1),
            ..KvPressureConfig::demote_only()
        },
        _ => KvPressureConfig {
            demote_watermark_fp8: 0.0,
            ..KvPressureConfig::demote_only()
        },
    };
    let mut kv = PagedKvCache::new(g, policy);
    let mut rng = Pcg64::seeded(seed);
    let mut seqs = Vec::new();
    let mut lens = Vec::new();
    for i in 0..case.batch {
        // ragged: 0.5x .. 1.5x the mean, deterministic per lane
        let jitter = (case.mean_len / 2).max(1);
        let wobble = (rng.next_u64() % (2 * jitter as u64)) as usize + i % 2;
        let len = (case.mean_len - jitter + wobble).clamp(1, g.max_seq);
        let s = kv.allocate(len).expect("bench block budget");
        let n = g.n_layers * len * g.n_heads * g.head_dim;
        let nk: Vec<f32> = (0..n).map(|_| rng.normal() as f32 * 0.4).collect();
        let nv: Vec<f32> = (0..n).map(|_| rng.normal() as f32 * 0.4).collect();
        kv.scatter_prefill(s, 0, len, &nk, &nv);
        kv.grow(s, len).unwrap();
        seqs.push(s);
        lens.push(len);
    }
    if case.arm != "fp16" {
        kv.set_precision_pressure(true);
        kv.maintain();
    }
    (kv, seqs, lens)
}

/// Run one case: time `reps` dense steps and `reps` block-native steps
/// over identical state, and verify bit-identity of the outputs.
pub fn measure(case: &AttnCase, seed: u64) -> AttnMeasure {
    let (mut kv, seqs, lens) = build_cache(case, seed);
    let g = kv.geo;
    let (l, h, dh) = (g.n_layers, g.n_heads, g.head_dim);
    let mut rng = Pcg64::seeded(seed ^ 0x5eed);
    let qs: Vec<Vec<f32>> = seqs
        .iter()
        .map(|_| (0..h * dh).map(|_| rng.normal() as f32 * 0.3).collect())
        .collect();
    let positions: Vec<[i32; 1]> = lens.iter().map(|&len| [len as i32 - 1]).collect();
    let lanes: Vec<AttnLane> = seqs
        .iter()
        .zip(&qs)
        .zip(&positions)
        .map(|((&seq, q), p)| AttnLane {
            seq,
            q,
            positions: p,
        })
        .collect();
    let per_layer = lanes.len() * h * dh;
    let engine = AttnEngine::new(1); // single-threaded: measure the walk, not parallelism
    let mut out_block = vec![0.0f32; l * per_layer];
    let mut out_dense = vec![0.0f32; l * per_layer];

    // gather scratch is hoisted like the pre-PR 5 backend's high-water
    // buffers, so the dense arm pays no per-step allocation
    let (mut gk, mut gv) = (Vec::new(), Vec::new());

    // warmup once each (page in payloads, size scratch)
    let mut stats = AttnStats::default();
    for layer in 0..l {
        stats.merge(engine.attend(
            &kv,
            layer,
            &lanes,
            &mut out_block[layer * per_layer..(layer + 1) * per_layer],
        ));
    }
    attend_dense_step_with(&mut kv, &lanes, &mut out_dense, &mut gk, &mut gv);
    let bit_identical = out_block
        .iter()
        .zip(&out_dense)
        .all(|(a, b)| a.to_bits() == b.to_bits());

    let t0 = Instant::now();
    for _ in 0..case.reps {
        for layer in 0..l {
            engine.attend(
                &kv,
                layer,
                &lanes,
                &mut out_block[layer * per_layer..(layer + 1) * per_layer],
            );
        }
    }
    let block_s = t0.elapsed().as_secs_f64() / case.reps as f64;

    let t0 = Instant::now();
    for _ in 0..case.reps {
        attend_dense_step_with(&mut kv, &lanes, &mut out_dense, &mut gk, &mut gv);
    }
    let dense_s = t0.elapsed().as_secs_f64() / case.reps as f64;

    // one profiled step, separate from the timed reps so the per-token
    // clock reads never skew the measured speedups; phase totals also
    // fold into the global registry for --json
    let mut prof_engine = AttnEngine::new(1);
    prof_engine.set_profiler(Profiler::enabled(ATTN_PHASES));
    for layer in 0..l {
        prof_engine.attend(
            &kv,
            layer,
            &lanes,
            &mut out_block[layer * per_layer..(layer + 1) * per_layer],
        );
    }
    let p = prof_engine.profiler();
    let total = p.total_seconds();
    let share = |i: usize| {
        if total > 0.0 {
            p.seconds(i) / total
        } else {
            0.0
        }
    };
    let phase_share = [share(0), share(1), share(2)];
    registry::with_global(|r| {
        let mut tmp = Registry::new();
        p.register_into(&mut tmp, "attn.profile");
        r.merge(&tmp);
    });

    AttnMeasure {
        dense_s,
        block_s,
        stats,
        bit_identical,
        phase_share,
    }
}

fn mb(bytes: usize) -> String {
    format!("{:.2}", bytes as f64 / (1 << 20) as f64)
}

/// The sweep's case grid: (arms, batches, mean lens, max_seq, reps).
pub fn sweep_grid(
    quick: bool,
) -> (&'static [&'static str], &'static [usize], &'static [usize], usize, usize) {
    if quick {
        (&["fp16", "fp8"], &[4], &[64], 256, 6)
    } else {
        (&["fp16", "mixed", "fp8"], &[1, 4, 8], &[32, 64, 128], 512, 24)
    }
}

/// The `repro reproduce attention` sweep.
pub fn attention_sweep(opts: &BenchOpts) -> Result<Vec<Report>> {
    let quick = opts.quick;
    let (arms, batches, lens, max_seq, reps) = sweep_grid(quick);
    let mut rep = Report::new(
        "Attention — dense-gather oracle vs block-native paged walk (decode step, per-step times)",
        &[
            "arm",
            "batch",
            "mean_ctx",
            "max_seq",
            "dense_us",
            "block_us",
            "speedup",
            "gathered_MB",
            "touched_MB",
            "load%",
            "dot%",
            "smax%",
            "bits",
        ],
    );
    rep.note(
        "one step = all layers' decode attention for the batch; dense arm gathers \
         [L,H,max_seq,Dh] per lane first, block arm walks block tables with fused FP8 dequant",
    );
    rep.note(
        "acceptance: speedup > 1 whenever max_seq >= 4x mean_ctx, outputs bit-identical \
         (asserted in bench tests)",
    );
    rep.note(
        "load/dot/smax = block-native engine phase shares from a separate profiled step \
         (load = block fetch incl. fused FP8 dequant; smax = online softmax + PV accumulate)",
    );
    let mut all_bits = true;
    let mut cells: Vec<(AttnCase, AttnMeasure)> = Vec::new();
    for &arm in arms {
        for &batch in batches {
            for &mean_len in lens {
                let case = AttnCase {
                    arm,
                    batch,
                    mean_len,
                    max_seq,
                    reps,
                };
                let m = measure(&case, 97);
                all_bits &= m.bit_identical;
                cells.push((case, m));
                rep.row(vec![
                    arm.into(),
                    batch.to_string(),
                    mean_len.to_string(),
                    max_seq.to_string(),
                    format!("{:.1}", m.dense_s * 1e6),
                    format!("{:.1}", m.block_s * 1e6),
                    format!("{:.2}x", m.speedup()),
                    mb(m.stats.dense_bytes),
                    mb(m.stats.touched_bytes),
                    format!("{:.0}%", m.phase_share[0] * 100.0),
                    format!("{:.0}%", m.phase_share[1] * 100.0),
                    format!("{:.0}%", m.phase_share[2] * 100.0),
                    if m.bit_identical { "ok" } else { "DIFF" }.into(),
                ]);
            }
        }
    }
    anyhow::ensure!(
        all_bits,
        "block-native attention diverged from the dense oracle"
    );
    let traj_path = trajectory_path();
    match std::fs::read_to_string(&traj_path) {
        Ok(text) => match Json::parse(&text).and_then(|t| trajectory_misses(&t, &cells)) {
            Ok((0, _)) => rep.note(format!(
                "trajectory {TRAJECTORY_FILE}: no enforceable floors yet (provisional seed) — \
                 run with --update-trajectory on a pinned machine to set them"
            )),
            Ok((checked, misses)) if misses.is_empty() => {
                rep.note(format!("trajectory {TRAJECTORY_FILE}: {checked} floors checked, all met"))
            }
            Ok((checked, misses)) => rep.note(format!(
                "trajectory {TRAJECTORY_FILE}: {}/{checked} floors MISSED — {}",
                misses.len(),
                misses.join("; ")
            )),
            Err(e) => rep.note(format!("trajectory {TRAJECTORY_FILE}: unreadable ({e})")),
        },
        Err(_) => rep.note(format!("trajectory {TRAJECTORY_FILE}: not found (skipped)")),
    }
    if opts.update_trajectory {
        if quick {
            // a quick sweep covers a case subset: rewriting would silently
            // drop the full-sweep floors
            rep.note(format!(
                "trajectory {TRAJECTORY_FILE}: NOT rewritten — --quick covers a case subset; \
                 rerun --update-trajectory without --quick"
            ));
        } else {
            std::fs::write(&traj_path, trajectory_json(&cells).to_string() + "\n")?;
            rep.note(format!("trajectory {}: rewritten from this run", traj_path.display()));
        }
    }
    Ok(vec![rep])
}

// ---------------------------------------------------------------------------
// Trajectory file
// ---------------------------------------------------------------------------

/// Floors from `ATTN_BENCH.json` that the given measurements violate.
/// Entries with a `null` floor (the provisional seed) never miss.
fn trajectory_misses(
    traj: &Json,
    cells: &[(AttnCase, AttnMeasure)],
) -> Result<(usize, Vec<String>), String> {
    if traj.get("schema").and_then(|s| s.as_str()) != Some(TRAJECTORY_SCHEMA) {
        return Err(format!("unexpected schema (want {TRAJECTORY_SCHEMA})"));
    }
    let entries = traj
        .get("entries")
        .and_then(|e| e.as_arr())
        .ok_or("missing 'entries' array")?;
    let mut checked = 0usize;
    let mut misses = Vec::new();
    for e in entries {
        let (Some(arm), Some(batch), Some(mean_ctx)) = (
            e.get("arm").and_then(|v| v.as_str()),
            e.get("batch").and_then(|v| v.as_usize()),
            e.get("mean_ctx").and_then(|v| v.as_usize()),
        ) else {
            return Err("entry missing arm/batch/mean_ctx".into());
        };
        let Some(floor) = e.get("floor_eff_gbps").and_then(|v| v.as_f64()) else {
            continue; // provisional entry: nothing to enforce yet
        };
        let Some((_, m)) = cells
            .iter()
            .find(|(c, _)| c.arm == arm && c.batch == batch && c.mean_len == mean_ctx)
        else {
            continue; // case not in this sweep (e.g. --quick)
        };
        checked += 1;
        if m.eff_gbps() < floor {
            misses.push(format!(
                "{arm} b{batch} ctx{mean_ctx}: {:.2} GB/s < floor {floor:.2}",
                m.eff_gbps()
            ));
        }
    }
    Ok((checked, misses))
}

fn trajectory_json(cells: &[(AttnCase, AttnMeasure)]) -> Json {
    let entries: Vec<Json> = cells
        .iter()
        .map(|(c, m)| {
            let mut e = BTreeMap::new();
            e.insert("arm".into(), Json::Str(c.arm.into()));
            e.insert("batch".into(), Json::Num(c.batch as f64));
            e.insert("mean_ctx".into(), Json::Num(c.mean_len as f64));
            e.insert(
                "eff_gbps".into(),
                Json::Num((m.eff_gbps() * 100.0).round() / 100.0),
            );
            e.insert(
                "floor_eff_gbps".into(),
                Json::Num((m.eff_gbps() * 0.7 * 100.0).round() / 100.0),
            );
            Json::Obj(e)
        })
        .collect();
    let mut root = BTreeMap::new();
    root.insert("schema".into(), Json::Str(TRAJECTORY_SCHEMA.into()));
    root.insert(
        "generated_by".into(),
        Json::Str(
            "repro reproduce attention --update-trajectory (threads=1, floors = 70% of measured)"
                .to_string(),
        ),
    );
    root.insert("provisional".into(), Json::Bool(false));
    root.insert("entries".into(), Json::Arr(entries));
    Json::Obj(root)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The PR's acceptance criterion: at `max_seq >= 4x` the mean
    /// context, the block-native walk is strictly faster than the
    /// dense-gather path, with bit-identical outputs.
    #[test]
    fn block_native_strictly_beats_dense_gather_at_4x_headroom() {
        let case = AttnCase {
            arm: "fp16",
            batch: 4,
            mean_len: 64, // max_seq = 8x mean: comfortably past the 4x bound
            max_seq: 512,
            reps: 12,
        };
        let m = measure(&case, 11);
        assert!(m.bit_identical, "outputs must match the oracle bit for bit");
        assert!(
            m.speedup() > 1.0,
            "block-native must be strictly faster: dense {:.1}us vs block {:.1}us",
            m.dense_s * 1e6,
            m.block_s * 1e6
        );
        assert!(
            m.stats.touched_bytes < m.stats.dense_bytes,
            "block walk must also touch fewer bytes"
        );
    }

    #[test]
    fn fp8_arm_touches_fewer_bytes_and_stays_bit_identical() {
        let mk = |arm| AttnCase {
            arm,
            batch: 2,
            mean_len: 64,
            max_seq: 256,
            reps: 2,
        };
        let f32_m = measure(&mk("fp16"), 13);
        let fp8_m = measure(&mk("fp8"), 13);
        assert!(f32_m.bit_identical && fp8_m.bit_identical);
        assert!(
            fp8_m.stats.touched_bytes < f32_m.stats.touched_bytes,
            "demoted blocks must stream fewer bytes: {} !< {}",
            fp8_m.stats.touched_bytes,
            f32_m.stats.touched_bytes
        );
        assert_eq!(fp8_m.stats.dense_bytes, f32_m.stats.dense_bytes);
    }

    #[test]
    fn quick_sweep_runs_and_asserts_bits() {
        let opts = BenchOpts {
            quick: true,
            ..Default::default()
        };
        let reports = attention_sweep(&opts).unwrap();
        assert_eq!(reports.len(), 1);
        assert!(!reports[0].rows.is_empty());
        assert!(reports[0].rows.iter().all(|r| r[12] == "ok"));
    }

    #[test]
    fn committed_trajectory_parses() {
        // the repo-root seed file must match the schema this module reads
        let text = std::fs::read_to_string(trajectory_path())
            .expect("ATTN_BENCH.json missing from repo root");
        let traj = Json::parse(&text).expect("ATTN_BENCH.json is not valid JSON");
        assert_eq!(
            traj.get("schema").and_then(|s| s.as_str()),
            Some(TRAJECTORY_SCHEMA)
        );
        // provisional seed: structure must be checkable even with no rows
        let (checked, misses) = trajectory_misses(&traj, &[]).expect("schema walk");
        assert_eq!(checked, 0, "no measurements given, nothing checkable");
        assert!(misses.is_empty());
        // every full-sweep (arm, batch, mean_ctx) cell is present
        let (arms, batches, lens, _, _) = sweep_grid(false);
        let entries = traj.get("entries").and_then(|e| e.as_arr()).unwrap();
        assert_eq!(entries.len(), arms.len() * batches.len() * lens.len());
    }

    #[test]
    fn misses_flagged_against_floors() {
        let mut e = BTreeMap::new();
        e.insert("arm".into(), Json::Str("fp16".into()));
        e.insert("batch".into(), Json::Num(2.0));
        e.insert("mean_ctx".into(), Json::Num(64.0));
        e.insert("floor_eff_gbps".into(), Json::Num(5.0));
        let mut root = BTreeMap::new();
        root.insert("schema".into(), Json::Str(TRAJECTORY_SCHEMA.into()));
        root.insert("entries".into(), Json::Arr(vec![Json::Obj(e)]));
        let traj = Json::Obj(root);
        let case = AttnCase {
            arm: "fp16",
            batch: 2,
            mean_len: 64,
            max_seq: 256,
            reps: 1,
        };
        let slow = AttnMeasure {
            dense_s: 1.0,
            block_s: 1.0,
            stats: AttnStats {
                touched_bytes: 2_000_000_000, // 2 GB/s < 5 floor
                ..Default::default()
            },
            bit_identical: true,
            phase_share: [0.0; 3],
        };
        let (checked, misses) = trajectory_misses(&traj, &[(case, slow)]).unwrap();
        assert_eq!((checked, misses.len()), (1, 1));
        let fast = AttnMeasure {
            stats: AttnStats {
                touched_bytes: 9_000_000_000,
                ..Default::default()
            },
            ..slow
        };
        let (_, misses) = trajectory_misses(&traj, &[(case, fast)]).unwrap();
        assert!(misses.is_empty());

        // a quick-sweep trajectory write must be refused
        let opts = BenchOpts {
            quick: true,
            update_trajectory: true,
            ..Default::default()
        };
        let reports = attention_sweep(&opts).unwrap();
        assert!(
            reports[0].notes.iter().any(|n| n.contains("NOT rewritten")),
            "--quick --update-trajectory must refuse to rewrite"
        );
    }

    #[test]
    fn trajectory_json_roundtrips() {
        let case = AttnCase {
            arm: "fp8",
            batch: 4,
            mean_len: 32,
            max_seq: 128,
            reps: 1,
        };
        let m = AttnMeasure {
            dense_s: 2.0,
            block_s: 0.5,
            stats: AttnStats::default(),
            bit_identical: true,
            phase_share: [0.0; 3],
        };
        let j = trajectory_json(&[(case, m)]);
        let back = Json::parse(&j.to_string()).unwrap();
        let (checked, misses) = trajectory_misses(&back, &[]).unwrap();
        assert_eq!(checked, 0);
        assert!(misses.is_empty());
    }
}

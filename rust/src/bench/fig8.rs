//! Figures 8 and 10: end-to-end serving throughput on the simulated H100
//! through the real coordinator (continuous batching + chunked prefill),
//! for the four main models under FP16 / NestedFP16 / FP8 / NestedFP8.

use anyhow::Result;

use crate::bench::report::Report;
use crate::coordinator::backend::SimBackend;
use crate::coordinator::engine::{Engine, EngineConfig};
use crate::coordinator::precision::PrecisionPolicy;
use crate::coordinator::request::Request;
use crate::gpusim::WeightFormat;
use crate::model::zoo::{self, ModelSpec};

/// Closed-loop throughput of one (model, format, batch, in/out) config:
/// 3x`batch` identical requests all arriving at t=0; engine runs them to
/// completion at max decode batch = `batch`.
pub fn throughput(
    spec: &'static ModelSpec,
    format: WeightFormat,
    batch: usize,
    input_len: usize,
    output_len: usize,
) -> Result<f64> {
    let max_seq = (input_len + output_len + 64).next_multiple_of(64);
    // KV budget sized to hold ~1.5x the target batch at full context
    let blocks_per_seq = (max_seq).div_ceil(16) + 1;
    let total_blocks = blocks_per_seq * batch * 3 / 2;
    let backend = SimBackend::new(spec, format, format, batch, max_seq, total_blocks);
    let mut engine = Engine::new(
        backend,
        EngineConfig {
            policy: PrecisionPolicy::Fp16Only, // fixed format via SimBackend
            physical_kv: false,
            ..Default::default()
        },
    );
    let n_req = batch * 3;
    let requests: Vec<Request> = (0..n_req)
        .map(|i| Request::new(i as u64, vec![65; input_len], output_len, 0.0))
        .collect();
    let report = engine.run(requests)?;
    Ok(report.metrics.throughput_tok_s())
}

/// Figure 8: 256-in/512-out, batch swept 32..512.
pub fn fig8() -> Result<Vec<Report>> {
    let mut out = Vec::new();
    for spec in zoo::main_four() {
        let mut rep = Report::new(
            &format!("Fig 8 — e2e throughput, {} (256 in / 512 out)", spec.name),
            &["batch", "fp16_tok_s", "nested16_tok_s", "nested8_tok_s", "n16_ovh", "n8_speedup"],
        );
        let mut speedups = Vec::new();
        let mut ovhs = Vec::new();
        for batch in [32usize, 64, 128, 256, 512] {
            let t16 = throughput(spec, WeightFormat::Fp16, batch, 256, 512)?;
            let n16 = throughput(spec, WeightFormat::Nested16, batch, 256, 512)?;
            let n8 = throughput(spec, WeightFormat::Nested8, batch, 256, 512)?;
            ovhs.push(1.0 - n16 / t16);
            speedups.push(n8 / n16);
            rep.row(vec![
                batch.to_string(),
                format!("{t16:.0}"),
                format!("{n16:.0}"),
                format!("{n8:.0}"),
                format!("{:.2}%", (1.0 - n16 / t16) * 100.0),
                format!("{:.2}x", n8 / n16),
            ]);
        }
        let avg_ovh = ovhs.iter().sum::<f64>() / ovhs.len() as f64 * 100.0;
        let avg_sp = speedups.iter().sum::<f64>() / speedups.len() as f64;
        rep.note(format!(
            "avg NestedFP16 overhead {avg_ovh:.2}% (paper: 2.69-4.51%), avg NestedFP8 speedup {avg_sp:.2}x (paper: 1.24-1.53x)"
        ));
        out.push(rep);
    }
    Ok(out)
}

/// Figure 10 (Appendix C): four in/out configs, including Torch FP8.
pub fn fig10() -> Result<Vec<Report>> {
    let configs = [(32usize, 512usize), (1024, 512), (32, 32), (1024, 32)];
    let mut out = Vec::new();
    for (ilen, olen) in configs {
        let mut rep = Report::new(
            &format!("Fig 10 — e2e throughput ({ilen} in / {olen} out)"),
            &["model", "fp16", "nested16", "fp8", "nested8", "n8/fp8"],
        );
        rep.note("paper: NestedFP8 at 96.8-98.8% of Torch FP8 throughput");
        let batch = 128;
        for spec in zoo::main_four() {
            let t16 = throughput(spec, WeightFormat::Fp16, batch, ilen, olen)?;
            let n16 = throughput(spec, WeightFormat::Nested16, batch, ilen, olen)?;
            let t8 = throughput(spec, WeightFormat::Fp8, batch, ilen, olen)?;
            let n8 = throughput(spec, WeightFormat::Nested8, batch, ilen, olen)?;
            rep.row(vec![
                spec.name.to_string(),
                format!("{t16:.0}"),
                format!("{n16:.0}"),
                format!("{t8:.0}"),
                format!("{n8:.0}"),
                format!("{:.1}%", n8 / t8 * 100.0),
            ]);
        }
        out.push(rep);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn throughput_ordering_holds() {
        let spec = zoo::find("llama31-8b").unwrap();
        let t16 = throughput(spec, WeightFormat::Fp16, 32, 64, 64).unwrap();
        let n16 = throughput(spec, WeightFormat::Nested16, 32, 64, 64).unwrap();
        let n8 = throughput(spec, WeightFormat::Nested8, 32, 64, 64).unwrap();
        assert!(t16 > 0.0);
        assert!(n16 <= t16 * 1.001, "nested16 {n16} should not beat fp16 {t16}");
        assert!(n8 > n16, "fp8 should beat fp16-mode");
    }
}

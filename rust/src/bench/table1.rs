//! Tables 1 and 2: downstream-task accuracy under FP16 / FP8(baseline) /
//! NestedFP8, on the in-repo trained model via real PJRT execution, plus
//! the weight-level quantization-error comparison.

use std::path::Path;

use anyhow::Result;

use crate::bench::report::Report;
use crate::eval::accuracy::{evaluate_mode, TaskAccuracy};
use crate::eval::quanterr;
use crate::eval::tasks::Task;
use crate::format::tensor::Tensor2;
use crate::runtime::{ModelRuntime, WeightStore};

fn acc_of(rows: &[TaskAccuracy], t: Task) -> f64 {
    rows.iter()
        .find(|a| a.task == t)
        .map(|a| a.accuracy() * 100.0)
        .unwrap_or(f64::NAN)
}

/// Tables 1+2 (model level): accuracy per task per mode.
///
/// `n` eval examples per task (paper uses full LM-eval tasks; we default
/// to a few dozen — the engine decodes them with real batching).
pub fn table12(artifacts: &Path, n: usize) -> Result<Report> {
    let mut rep = Report::new(
        "Tables 1-2 — task accuracy (%), in-repo model, real PJRT execution",
        &["task", "FP16", "FP8(B)", "FP8(N)", "d_B", "d_N"],
    );
    rep.note("FP8(B): per-channel absmax baseline; FP8(N): NestedFP8 (global 2^8 scale)");
    rep.note("paper's claim: FP8(N) ~ FP8(B), both slightly below FP16");

    let mut per_mode = Vec::new();
    for mode in ["fp16", "fp8base", "nested8"] {
        let rt = ModelRuntime::load(artifacts, &[mode], &["decode", "prefill"])?;
        per_mode.push(evaluate_mode(rt, Box::leak(mode.to_string().into_boxed_str()), n, 20250710)?);
    }
    for task in Task::ALL {
        let f16 = acc_of(&per_mode[0], task);
        let b = acc_of(&per_mode[1], task);
        let nst = acc_of(&per_mode[2], task);
        rep.row(vec![
            task.name().into(),
            format!("{f16:.1}"),
            format!("{b:.1}"),
            format!("{nst:.1}"),
            format!("{:+.1}", b - f16),
            format!("{:+.1}", nst - f16),
        ]);
    }
    Ok(rep)
}

/// Table 2 (weight level): FP8(B) vs FP8(N) quantization error on every
/// linear layer of the trained checkpoint.
pub fn table2_weights(artifacts: &Path) -> Result<Report> {
    let ws = WeightStore::load(&artifacts.join("weights.bin"))?;
    let mut rep = Report::new(
        "Table 2 (weight level) — relative Frobenius quantization error",
        &["layer", "FP8(B)", "FP8(N)", "N/B"],
    );
    let mut ratios = Vec::new();
    for (name, t) in &ws.tensors {
        if !name.ends_with(".f16") || name == "embed" || name == "lm_head" {
            continue;
        }
        let vals: Vec<f32> = t
            .as_u16()?
            .into_iter()
            .map(|b| crate::format::fp16::F16::from_bits(b).to_f32())
            .collect();
        let w = Tensor2::from_vec(t.dims[0], t.dims[1], vals);
        let (base, nested) = quanterr::compare_fp8_variants(&w);
        ratios.push(nested.rel_fro / base.rel_fro);
        // print one row per layer kind of layer 0 only, plus the summary
        if name.starts_with("layers.0.") {
            rep.row(vec![
                name.trim_end_matches(".f16").into(),
                format!("{:.4}", base.rel_fro),
                format!("{:.4}", nested.rel_fro),
                format!("{:.2}", nested.rel_fro / base.rel_fro),
            ]);
        }
    }
    let avg = ratios.iter().sum::<f64>() / ratios.len() as f64;
    rep.note(format!(
        "mean error ratio FP8(N)/FP8(B) over all {} linear layers: {avg:.2} (1.0 = parity)",
        ratios.len()
    ));
    Ok(rep)
}

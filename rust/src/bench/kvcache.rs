//! KV-pressure scenario: the same surge and the same device block budget
//! replayed under four KV policies (`repro reproduce kvcache`).
//!
//! * `dense-f32` — the seed behavior: conservative full-context
//!   reservation, all blocks f32, stall when the budget is gone.
//! * `fp8-demote` — LRU-cold blocks demote to FP8 (half the units) as
//!   utilization rises and the precision controller escalates.
//! * `paged+offload` — true paged admission plus the host tier:
//!   preempt-by-offload instead of stalling, transfer latency charged on
//!   the virtual clock.
//! * `host-piggyback` — same tier, but evicted sequences keep decoding
//!   over their host-resident blocks (attention piggybacked on the host
//!   cost law) instead of parking until a resume transfer fits.
//!
//! The headline column is `admitted_peak`: under the same budget, FP8
//! demotion must fit measurably more concurrent requests than all-f32
//! (asserted in this module's tests), with the codec's documented error
//! bound as the quality price. The piggyback arm's claim is goodput:
//! host-served lanes keep earning SLO-attaining completions while the
//! resume-only policy lets them stall (also asserted in tests).

use anyhow::Result;

use crate::bench::report::Report;
use crate::coordinator::backend::SimBackend;
use crate::coordinator::engine::{Engine, EngineConfig, RunReport};
use crate::coordinator::precision::{PrecisionPolicy, SloConfig};
use crate::gpusim::WeightFormat;
use crate::kvcache::{codec, KvCacheStats, KvPressureConfig};
use crate::model::zoo;
use crate::trace::workload::{build_requests, poisson_arrivals, surge_rates, WorkloadConfig};
use crate::util::rng::Pcg64;

/// The scenario's fixed shape: `seconds` of Poisson traffic at `base`
/// req/s with a 6x plateau through the middle third — sized to slam a
/// deliberately tight KV budget.
pub fn pressure_workload(seconds: usize, base: f64) -> Vec<crate::coordinator::request::Request> {
    let rates = surge_rates(base, 6.0, seconds, seconds / 3, seconds / 3);
    let arrivals = poisson_arrivals(&rates, 23);
    let wl = WorkloadConfig {
        seed: 9,
        input_len: 0,  // sampled
        output_len: 0, // sampled
        chunk_align: 64,
    };
    let mut requests = build_requests(&arrivals, &wl, 1024);
    for r in &mut requests {
        r.max_new_tokens = r.max_new_tokens.clamp(32, 192);
    }
    requests
}

/// Run the pressure scenario on one simulated H100 (llama31-8b) with a
/// `total_blocks` device budget under the given KV policy.
pub fn run_pressure(
    kv: KvPressureConfig,
    seconds: usize,
    base: f64,
    total_blocks: usize,
) -> Result<(RunReport, KvCacheStats)> {
    let spec = zoo::find("llama31-8b").expect("llama31-8b in the zoo");
    let backend = SimBackend::new(
        spec,
        WeightFormat::Nested16,
        WeightFormat::Nested8,
        48,
        1024,
        total_blocks,
    );
    let mut engine = Engine::new(
        backend,
        EngineConfig {
            policy: PrecisionPolicy::Dual,
            slo: SloConfig::default(),
            physical_kv: false,
            max_iterations: 0,
            kv,
            devices: 1,
        },
    );
    let report = engine.run(pressure_workload(seconds, base))?;
    let stats = engine.kv.stats();
    Ok((report, stats))
}

/// The four policy variants the scenario compares.
pub fn variants() -> Vec<(&'static str, KvPressureConfig)> {
    vec![
        ("dense-f32", KvPressureConfig::dense_baseline()),
        ("fp8-demote", KvPressureConfig::demote_only()),
        ("paged+offload", KvPressureConfig::default()),
        ("host-piggyback", KvPressureConfig::piggyback()),
    ]
}

/// The KV-pressure table (the `kvcache` experiment's main report).
/// `quick` shortens the surge for CI smokes; the policy arms (including
/// the piggyback one) are identical in both shapes.
pub fn kvcache_pressure(quick: bool) -> Result<Report> {
    let slo = SloConfig::default();
    let (seconds, base, blocks) = if quick { (16, 2.0, 384) } else { (48, 2.0, 384) };
    let mut rep = Report::new(
        "KV cache — paged dual-precision under surge (llama31-8b, sim-H100, same 384-block budget)",
        &[
            "policy",
            "admitted_peak",
            "completed",
            "p90_ttft_ms",
            "p90_tpot_ms",
            "slo_violation_s",
            "goodput_req_s",
            "demoted_blocks",
            "offloads",
            "transfer_ms",
            "kv_read_savings",
            "piggy_steps",
            "host_attn_ms",
            "avoided_ms",
        ],
    );
    rep.note(format!(
        "{seconds}s at {base} req/s with a 6x surge; admitted_peak = peak concurrently resident requests"
    ));
    rep.note(
        "kv_read_savings = attention KV traffic avoided by the block-native walk vs the \
         dense gather (PR 5): 1 - touched/gathered bytes",
    );
    rep.note(
        "piggy_steps = decode iterations carrying host-piggybacked lanes; host_attn_ms = \
         host-tier attention seconds billed on the virtual clock; avoided_ms = resume \
         transfers never paid because sequences finished on the host",
    );
    for (name, cfg) in variants() {
        let (mut r, st) = run_pressure(cfg, seconds, base, blocks)?;
        let ttft = r.metrics.ttft_summary();
        let tpot = r.metrics.tpot_summary();
        rep.row(vec![
            name.into(),
            st.peak_live_seqs.to_string(),
            r.metrics.completed.to_string(),
            format!("{:.1}", ttft.p90 * 1e3),
            format!("{:.1}", tpot.p90 * 1e3),
            r.metrics.slo_violation_seconds(&slo).to_string(),
            format!("{:.2}", r.metrics.goodput_req_s(&slo)),
            st.demoted_blocks.to_string(),
            st.offload_events.to_string(),
            format!("{:.2}", st.transfer_seconds * 1e3),
            format!("{:.1}%", r.metrics.attn_gather_savings() * 100.0),
            r.metrics.host_piggybacked_steps.to_string(),
            format!("{:.2}", r.metrics.host_attn_seconds * 1e3),
            format!("{:.2}", r.metrics.host_transfer_seconds_avoided * 1e3),
        ]);
    }
    Ok(rep)
}

/// Codec-quality companion table: measured roundtrip error of the FP8
/// block codec on KV-like data vs. the documented bound.
pub fn codec_error() -> Report {
    let mut rep = Report::new(
        "KV cache — FP8 block codec roundtrip error (per-block absmax scale)",
        &["distribution", "absmax", "max_rel_err", "rel_bound", "max_abs_err", "abs_floor"],
    );
    rep.note("documented bound: |err| <= max(|x|/16, absmax * 2^-10 / 448)");
    let mut rng = Pcg64::seeded(77);
    for (name, scale) in [("normal(0,1)", 1.0f64), ("normal(0,1e-2)", 1e-2), ("normal(0,40)", 40.0)] {
        let x: Vec<f32> = (0..4096).map(|_| (rng.normal() * scale) as f32).collect();
        let absmax = x.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        let (bytes, s) = codec::encode_block(&x);
        let mut out = vec![0.0f32; x.len()];
        codec::decode_block(&bytes, s, &mut out);
        let mut max_rel = 0.0f64;
        let mut max_abs = 0.0f64;
        for (&xi, &oi) in x.iter().zip(&out) {
            let err = (oi as f64 - xi as f64).abs();
            max_abs = max_abs.max(err);
            if xi != 0.0 {
                max_rel = max_rel.max(err / (xi as f64).abs());
            }
        }
        let abs_floor = absmax as f64 / 448.0 * f64::powi(2.0, -10);
        rep.row(vec![
            name.into(),
            format!("{absmax:.4}"),
            format!("{max_rel:.4}"),
            "0.0625".into(),
            format!("{max_abs:.3e}"),
            format!("{abs_floor:.3e}"),
        ]);
    }
    rep
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn demotion_admits_more_under_the_same_budget() {
        // the acceptance criterion, end to end: same workload, same block
        // budget — FP8 demotion must reach a higher peak of concurrently
        // admitted requests than the all-f32 baseline, and everything
        // still completes
        let (seconds, base, blocks) = (24, 2.0, 384);
        let (base_rep, base_st) =
            run_pressure(KvPressureConfig::dense_baseline(), seconds, base, blocks).unwrap();
        let (dem_rep, dem_st) =
            run_pressure(KvPressureConfig::demote_only(), seconds, base, blocks).unwrap();
        assert_eq!(
            base_rep.metrics.completed, dem_rep.metrics.completed,
            "same workload must drain under both policies"
        );
        assert!(
            dem_st.peak_live_seqs > base_st.peak_live_seqs,
            "fp8 demotion must admit more concurrent requests: {} !> {}",
            dem_st.peak_live_seqs,
            base_st.peak_live_seqs
        );
        assert!(dem_st.demoted_blocks > 0, "demotion never engaged");
    }

    #[test]
    fn offload_tier_attacks_queueing_delay() {
        // with the host tier, admission stalls convert into transfers: the
        // full paged policy must admit at least as many concurrently as
        // demote-only and must actually use the tier under this budget
        let (seconds, base, blocks) = (24, 2.0, 384);
        let (_, dem) =
            run_pressure(KvPressureConfig::demote_only(), seconds, base, blocks).unwrap();
        let (rep, full) =
            run_pressure(KvPressureConfig::default(), seconds, base, blocks).unwrap();
        assert!(full.peak_live_seqs >= dem.peak_live_seqs);
        assert!(
            full.offload_events > 0,
            "tight budget must exercise the host tier"
        );
        assert!(full.transfer_seconds > 0.0);
        assert_eq!(
            rep.metrics.kv_offload_events, full.offload_events,
            "metrics must mirror the cache stats"
        );
    }

    #[test]
    fn piggyback_beats_resume_only_goodput_under_pressure() {
        // the PR's acceptance property: same surge, same budget — serving
        // evicted sequences on the host tier must earn strictly more
        // goodput than parking them for a resume transfer
        let slo = SloConfig::default();
        let (seconds, base, blocks) = (24, 2.0, 384);
        let (resume_rep, _) =
            run_pressure(KvPressureConfig::default(), seconds, base, blocks).unwrap();
        let (piggy_rep, piggy_st) =
            run_pressure(KvPressureConfig::piggyback(), seconds, base, blocks).unwrap();
        assert_eq!(
            resume_rep.metrics.completed, piggy_rep.metrics.completed,
            "same workload must drain under both policies"
        );
        assert!(
            piggy_st.offload_events > 0,
            "tight budget must exercise the host tier"
        );
        assert!(
            piggy_rep.metrics.host_piggybacked_steps > 0,
            "piggyback arm never served a host lane"
        );
        assert!(
            piggy_rep.metrics.host_attn_seconds > 0.0,
            "host attention must bill the virtual clock"
        );
        let g_resume = resume_rep.metrics.goodput_req_s(&slo);
        let g_piggy = piggy_rep.metrics.goodput_req_s(&slo);
        assert!(
            g_piggy > g_resume,
            "piggyback goodput must beat resume-only: {g_piggy} !> {g_resume}"
        );
    }

    #[test]
    fn workload_is_deterministic() {
        let a = pressure_workload(24, 2.0);
        let b = pressure_workload(24, 2.0);
        assert_eq!(a.len(), b.len());
        assert!(!a.is_empty());
        assert!(a.iter().zip(&b).all(|(x, y)| {
            x.arrival == y.arrival
                && x.prompt.len() == y.prompt.len()
                && x.max_new_tokens == y.max_new_tokens
        }));
    }
}

//! Table 3 (Appendix E): layer-wise NestedFP applicability across the
//! full 14-model zoo, via the calibrated weight sampler + the real
//! eligibility analyzer.

use crate::bench::report::Report;
use crate::model::applicability::analyze_zoo_model;
use crate::model::zoo::{GemmKind, ZOO};

pub fn table3() -> Report {
    let mut rep = Report::new(
        "Table 3 — layer-wise NestedFP applicability (X/Y = applicable/total)",
        &["model", "GEMM1", "GEMM2", "GEMM3", "GEMM4", "total", "share"],
    );
    rep.note("calibrated sampler + real 1.75-threshold analyzer; totals match the published table");
    for spec in ZOO {
        let report = analyze_zoo_model(spec, 42);
        let fmt = |k: GemmKind| {
            let (a, t) = report.counts(k);
            format!("{a}/{t}")
        };
        let (a, t) = report.total_counts();
        rep.row(vec![
            spec.name.to_string(),
            fmt(GemmKind::Qkv),
            fmt(GemmKind::OutProj),
            fmt(GemmKind::GateUp),
            fmt(GemmKind::Down),
            format!("{a}/{t}"),
            format!("{:.1}%", a as f64 / t as f64 * 100.0),
        ]);
    }
    rep
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_has_all_models() {
        let rep = table3();
        assert_eq!(rep.rows.len(), 14);
        // llama 3.1 8B fully applicable
        let llama = rep.rows.iter().find(|r| r[0] == "llama31-8b").unwrap();
        assert_eq!(llama[5], "224/224");
        assert_eq!(llama[6], "100.0%");
        // gemma3-4b share ~76%
        let gemma = rep.rows.iter().find(|r| r[0] == "gemma3-4b").unwrap();
        let share: f64 = gemma[6].trim_end_matches('%').parse().unwrap();
        assert!((share - 76.2).abs() < 1.0, "{share}");
    }
}

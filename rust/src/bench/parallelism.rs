//! The parallelism study (`repro reproduce parallelism`): the same
//! Azure busy-minute surge as the autopilot bench, replayed against a
//! fleet whose replicas each own a 4-device pool — so the controller
//! has **two** knobs, arbitrated by the two-ladder autopilot:
//!
//! * **static-fp16**     — no control at all (the quality baseline),
//! * **precision-only**  — the PR-4 autopilot: FP16 → Mixed → FP8,
//!   tensor parallelism pinned at 1,
//! * **parallel-only**   — precision pinned at FP16
//!   (`max_precision_rung: 0`), the parallelism ladder walks tp 1 → 2
//!   → 4 through clock-billed reshard windows,
//! * **combined**        — both ladders live; precision moves first
//!   (cheap, instant), parallelism only once precision is saturated.
//!
//! The surge is deliberately heavier than the autopilot bench's
//! (scale 0.45 full / 0.30 quick vs 0.32 / 0.22): the point of the
//! second knob is the regime where FP8 alone no longer holds the SLO,
//! so the scenario must push the precision ladder past saturation.
//!
//! The acceptance claim (asserted loosely in tests, reported exactly
//! here and via `--json`): the combined arm's goodput is at least both
//! single-knob arms', and its SLO-violation seconds are at most the
//! precision-only arm's — two knobs beat either alone, and the reshard
//! windows pay for themselves.

use anyhow::Result;

use crate::bench::autopilot::{summarize, surge_workload, SurgeScenario};
use crate::bench::report::Report;
use crate::coordinator::autopilot::AutopilotConfig;
use crate::coordinator::backend::SimBackend;
use crate::coordinator::cluster::{ClusterConfig, ClusterReport, ClusterRouter, SurgeConfig};
use crate::coordinator::engine::EngineConfig;
use crate::coordinator::precision::{PrecisionPolicy, SloConfig};
use crate::coordinator::router::RoutingPolicy;
use crate::gpusim::WeightFormat;
use crate::kvcache::KvPressureConfig;
use crate::model::zoo;

/// Fixed per-replica device pool for every arm — the arms differ only
/// in which knobs the controller may turn, never in hardware.
pub const DEVICES_PER_REPLICA: usize = 4;

/// The four bench arms.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Arm {
    StaticFp16,
    PrecisionOnly,
    ParallelOnly,
    Combined,
}

impl Arm {
    pub fn name(self) -> &'static str {
        match self {
            Arm::StaticFp16 => "static-fp16",
            Arm::PrecisionOnly => "precision-only",
            Arm::ParallelOnly => "parallel-only",
            Arm::Combined => "combined",
        }
    }

    pub fn all() -> [Arm; 4] {
        [
            Arm::StaticFp16,
            Arm::PrecisionOnly,
            Arm::ParallelOnly,
            Arm::Combined,
        ]
    }
}

/// The study's surge: the autopilot scenario's trace window at a scale
/// heavy enough that the precision ladder saturates and the parallelism
/// ladder has room to matter.
pub fn scenario(quick: bool) -> SurgeScenario {
    if quick {
        SurgeScenario {
            scale: 0.30,
            ..SurgeScenario::quick()
        }
    } else {
        SurgeScenario {
            scale: 0.45,
            ..SurgeScenario::full()
        }
    }
}

/// Tiny seeded scenario for the bit-identity and property suites: small
/// enough for a unit-test budget, busy enough to force at least one
/// reshard window.
pub fn mini_scenario() -> SurgeScenario {
    SurgeScenario {
        scale: 0.30,
        ..SurgeScenario::golden()
    }
}

/// Build one arm's cluster (simulated H100s, llama-3.1-8b, 4 devices
/// per replica) without running it — the equivalence and property
/// suites drive the same construction through both the event-core
/// driver and the lockstep oracle.
pub fn arm_cluster(arm: Arm, sc: &SurgeScenario) -> ClusterRouter<SimBackend> {
    let spec = zoo::find("llama31-8b").expect("llama31-8b in the zoo");
    let max_seq = 1024;
    let backends: Vec<SimBackend> = (0..sc.replicas)
        .map(|_| {
            SimBackend::new(
                spec,
                WeightFormat::Nested16,
                WeightFormat::Nested8,
                64,
                max_seq,
                64 * (max_seq / 16 + 1) * 2,
            )
        })
        .collect();
    let policy = match arm {
        // precision pinned at FP16: the engine itself must not demote
        Arm::StaticFp16 | Arm::ParallelOnly => PrecisionPolicy::Fp16Only,
        Arm::PrecisionOnly | Arm::Combined => PrecisionPolicy::Dual,
    };
    let autopilot = match arm {
        Arm::StaticFp16 => None,
        Arm::PrecisionOnly => Some(AutopilotConfig::default()),
        Arm::ParallelOnly => Some(AutopilotConfig {
            max_precision_rung: 0,
            max_tp: DEVICES_PER_REPLICA,
            ..AutopilotConfig::default()
        }),
        Arm::Combined => Some(AutopilotConfig {
            max_tp: DEVICES_PER_REPLICA,
            ..AutopilotConfig::default()
        }),
    };
    let cfg = ClusterConfig {
        policy: RoutingPolicy::SloHeadroom,
        engine: EngineConfig {
            policy,
            slo: SloConfig::default(),
            physical_kv: false,
            max_iterations: 0,
            kv: KvPressureConfig::default(),
            devices: DEVICES_PER_REPLICA,
        },
        // static arms must stay static: no reactive stage demotions
        surge: SurgeConfig::disabled(),
        autopilot,
        ..ClusterConfig::default()
    };
    ClusterRouter::new(backends, cfg)
}

/// Run one arm of the study.
pub fn run_arm(arm: Arm, sc: &SurgeScenario) -> Result<ClusterReport> {
    arm_cluster(arm, sc).run(surge_workload(sc))
}

/// The `repro reproduce parallelism` entry point: the arm table plus
/// the combined arm's reshard timeline.
pub fn parallelism_surge(quick: bool) -> Result<Vec<Report>> {
    let sc = scenario(quick);
    let slo = SloConfig::default();
    let n_requests = surge_workload(&sc).len();

    let mut arms = Report::new(
        "Parallelism — two-knob SLO control (precision ladder + TP ladder) \
         under an Azure-shaped surge (llama31-8b, sim-H100, 2 replicas x 4 \
         devices, SLO-headroom routing)",
        &[
            "arm",
            "goodput_req_s",
            "slo_violation_s",
            "ttft_p99_ms",
            "tpot_p99_ms",
            "fp16_time_frac",
            "reshards",
            "repart_ms",
            "final_tp",
        ],
    );
    arms.note(format!(
        "{n_requests} requests over {}s (lead {}s, spike minute, drain); \
         SLO: TTFT <= 200 ms, TPOT <= 33.3 ms; reshard window = drain + \
         25 ms + weight-shard move over NVLink",
        sc.len_s, sc.lead_s
    ));
    arms.note(
        "claim: combined goodput >= both single-knob arms, violations <= \
         precision-only — the controller turns the cheap knob (precision) \
         first and reshards only once FP8 is saturated",
    );

    let mut windows = Report::new(
        "Parallelism — combined-arm reshard timeline (completed windows; \
         one replica reshards at a time, admission frozen inside a window)",
        &["t_s", "replica", "new_tp"],
    );

    for arm in Arm::all() {
        let mut report = run_arm(arm, &sc)?;
        let s = summarize(&mut report, &slo);
        let tps: Vec<String> = report
            .replicas
            .iter()
            .map(|r| r.final_tp_degree.to_string())
            .collect();
        arms.row(vec![
            arm.name().into(),
            format!("{:.3}", s.goodput_req_s),
            s.slo_violation_s.to_string(),
            format!("{:.1}", s.ttft_p99_s * 1e3),
            format!("{:.1}", s.tpot_p99_s * 1e3),
            format!("{:.0}%", s.fp16_time_frac * 100.0),
            report.aggregate.reshards.to_string(),
            format!("{:.1}", report.aggregate.reshard_repartition_s * 1e3),
            tps.join("/"),
        ]);
        if arm == Arm::Combined {
            anyhow::ensure!(
                s.completed == n_requests,
                "combined arm drained {} of {n_requests} requests",
                s.completed
            );
            for &(t, i, tp) in &report.reshard_timeline {
                windows.row(vec![format!("{t:.2}"), i.to_string(), tp.to_string()]);
            }
            windows.note(format!(
                "{} completed windows, {:.1} ms total repartition time \
                 (drain time is workload-dependent and excluded)",
                report.aggregate.reshards,
                report.aggregate.reshard_repartition_s * 1e3
            ));
        }
    }
    Ok(vec![arms, windows])
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The tentpole acceptance property, on the quick scenario (loose
    /// bounds; the full run reports exact values): two knobs beat either
    /// alone.
    #[test]
    fn combined_control_beats_both_single_knob_arms() {
        let sc = scenario(true);
        let slo = SloConfig::default();
        let n = surge_workload(&sc).len();
        let mut f16 = run_arm(Arm::StaticFp16, &sc).unwrap();
        let mut prec = run_arm(Arm::PrecisionOnly, &sc).unwrap();
        let mut par = run_arm(Arm::ParallelOnly, &sc).unwrap();
        let mut comb = run_arm(Arm::Combined, &sc).unwrap();
        let s16 = summarize(&mut f16, &slo);
        let sp = summarize(&mut prec, &slo);
        let sl = summarize(&mut par, &slo);
        let sc2 = summarize(&mut comb, &slo);
        // every arm drains the same workload
        assert_eq!(s16.completed, n);
        assert_eq!(sp.completed, n);
        assert_eq!(sl.completed, n);
        assert_eq!(sc2.completed, n);
        // the surge must actually hurt the uncontrolled baseline, or the
        // scenario tests nothing
        assert!(
            s16.slo_violation_s >= 3,
            "surge too gentle: static fp16 violated only {}s",
            s16.slo_violation_s
        );
        // the combined arm must actually have used both knobs
        assert!(
            comb.aggregate.reshards >= 1,
            "combined arm never resharded — the surge never saturated FP8"
        );
        assert!(
            comb.replicas.iter().any(|r| r.controller.iters_fp8 > 0),
            "combined arm never demoted precision"
        );
        // acceptance: goodput >= both single-knob arms (2% slack for
        // scheduling noise; the headline report carries exact values)
        assert!(
            sc2.goodput_req_s >= sp.goodput_req_s * 0.98,
            "combined goodput {} < precision-only {}",
            sc2.goodput_req_s,
            sp.goodput_req_s
        );
        assert!(
            sc2.goodput_req_s >= sl.goodput_req_s * 0.98,
            "combined goodput {} < parallel-only {}",
            sc2.goodput_req_s,
            sl.goodput_req_s
        );
        // acceptance: violations <= the precision-only arm
        assert!(
            sc2.slo_violation_s <= sp.slo_violation_s,
            "combined violated {}s vs precision-only {}s",
            sc2.slo_violation_s,
            sp.slo_violation_s
        );
    }

    /// Each single-knob arm must turn only its own knob — otherwise the
    /// three-way comparison measures nothing.
    #[test]
    fn single_knob_arms_use_only_their_knob() {
        let sc = scenario(true);
        let prec = run_arm(Arm::PrecisionOnly, &sc).unwrap();
        assert_eq!(
            prec.aggregate.reshards, 0,
            "precision-only arm resharded"
        );
        assert!(prec.reshard_timeline.is_empty());
        assert!(prec.replicas.iter().all(|r| r.final_tp_degree == 1));

        let par = run_arm(Arm::ParallelOnly, &sc).unwrap();
        assert!(
            par.aggregate.reshards >= 1,
            "parallel-only arm never resharded under the surge"
        );
        assert!(
            par.replicas.iter().all(|r| r.controller.iters_fp8 == 0),
            "parallel-only arm demoted precision"
        );

        let f16 = run_arm(Arm::StaticFp16, &sc).unwrap();
        assert_eq!(f16.aggregate.reshards, 0);
        assert!(f16.replicas.iter().all(|r| r.controller.iters_fp8 == 0));
    }

    /// The bit-identity harness extended over reshard events on the real
    /// sim backend: heap driver vs lockstep oracle on the combined arm's
    /// mini scenario (the cheap-backend version lives in
    /// `coordinator::cluster`'s tests).
    #[test]
    fn combined_arm_matches_lockstep_with_reshards() {
        let sc = mini_scenario();
        let wl = surge_workload(&sc);
        let a = arm_cluster(Arm::Combined, &sc).run(wl.clone()).unwrap();
        let b = arm_cluster(Arm::Combined, &sc).run_lockstep(wl).unwrap();
        assert!(
            a.aggregate.reshards >= 1,
            "mini scenario must actually reshard to pin anything"
        );
        let ids = |r: &ClusterReport| -> Vec<u64> {
            r.completions.iter().map(|c| c.id).collect()
        };
        assert_eq!(ids(&a), ids(&b));
        let timeline_bits = |r: &ClusterReport| -> Vec<(u64, usize, usize)> {
            r.reshard_timeline
                .iter()
                .map(|&(t, i, tp)| (t.to_bits(), i, tp))
                .collect()
        };
        assert_eq!(timeline_bits(&a), timeline_bits(&b));
        // dispatch counters agree (heap lazy deletions excepted)
        assert_eq!(a.events.arrival_events, b.events.arrival_events);
        assert_eq!(a.events.control_events, b.events.control_events);
        assert_eq!(a.events.replica_step_events, b.events.replica_step_events);
        assert_eq!(a.events.reshard_events, b.events.reshard_events);
        assert_eq!(a.ladder_timeline, b.ladder_timeline);
        for (x, y) in a.replicas.iter().zip(&b.replicas) {
            assert_eq!(x.iterations, y.iterations);
            assert_eq!(x.final_tp_degree, y.final_tp_degree);
            assert_eq!(x.directive_timeline, y.directive_timeline);
        }
    }
}

//! Cluster scaling scenario: one traffic surge replayed against 1, 2, and
//! 4 engine replicas (`repro reproduce cluster`), plus the discrete-event
//! scale arm (`repro reproduce cluster --scale`): 100+ replicas replaying
//! a multi-hour Azure day slice — ≥1M simulated requests — with
//! per-event accounting proving idle replicas cost zero events.
//!
//! The single-replica experiments (Fig 1b) show dual precision absorbing
//! a surge *in time* (switch to FP8 for the bad seconds). This scenario
//! shows the cluster absorbing the same surge *in space*: with enough
//! replicas the SLO-headroom router spreads the load and nobody demotes;
//! undersized clusters demote their tail replicas (staged escalation)
//! and still contain the violation window.

use std::time::Instant;

use anyhow::{ensure, Result};

use crate::bench::report::Report;
use crate::coordinator::autopilot::AutopilotConfig;
use crate::coordinator::backend::SimBackend;
use crate::coordinator::cluster::{ClusterConfig, ClusterReport, ClusterRouter, SurgeConfig};
use crate::coordinator::engine::EngineConfig;
use crate::coordinator::precision::{PrecisionPolicy, SloConfig};
use crate::coordinator::router::RoutingPolicy;
use crate::gpusim::WeightFormat;
use crate::kvcache::KvPressureConfig;
use crate::model::zoo;
use crate::trace::azure::{day_slice, downscale, AzureTraceConfig};
use crate::trace::workload::{build_requests, poisson_arrivals, surge_rates, WorkloadConfig};

/// The scenario's fixed shape: 60 s at `base` req/s with a 5x surge for
/// 15 s starting at t=20 (per-second Poisson arrivals, sampled lengths).
pub fn surge_workload(seconds: usize, base: f64) -> Vec<crate::coordinator::request::Request> {
    let rates = surge_rates(base, 5.0, seconds, seconds / 3, seconds / 4);
    let arrivals = poisson_arrivals(&rates, 17);
    let wl = WorkloadConfig {
        seed: 5,
        input_len: 0,  // sampled
        output_len: 0, // sampled
        chunk_align: 64,
    };
    let max_seq = 1024;
    let mut requests = build_requests(&arrivals, &wl, max_seq);
    for r in &mut requests {
        r.max_new_tokens = r.max_new_tokens.min(128);
    }
    requests
}

/// Run the surge against `n_replicas` simulated H100s (llama-3.1-8b).
pub fn run_cluster(
    n_replicas: usize,
    policy: RoutingPolicy,
    seconds: usize,
    base: f64,
) -> Result<ClusterReport> {
    let spec = zoo::find("llama31-8b").expect("llama31-8b in the zoo");
    let max_seq = 1024;
    let backends: Vec<SimBackend> = (0..n_replicas)
        .map(|_| {
            SimBackend::new(
                spec,
                WeightFormat::Nested16,
                WeightFormat::Nested8,
                64,
                max_seq,
                64 * (max_seq / 16 + 1) * 2,
            )
        })
        .collect();
    let cfg = ClusterConfig {
        policy,
        engine: EngineConfig {
            policy: PrecisionPolicy::Dual,
            slo: SloConfig::default(),
            physical_kv: false,
            max_iterations: 0,
            kv: KvPressureConfig::default(),
            devices: 1,
        },
        surge: SurgeConfig::default(),
        autopilot: None,
        ..ClusterConfig::default()
    };
    let mut cluster = ClusterRouter::new(backends, cfg);
    cluster.run(surge_workload(seconds, base))
}

/// The cluster scaling table: same surge, 1 / 2 / 4 replicas.
pub fn cluster_scaling() -> Result<Report> {
    let slo = SloConfig::default();
    let mut rep = Report::new(
        "Cluster — surge absorption vs replica count (llama31-8b, sim-H100, SLO-headroom routing)",
        &[
            "replicas",
            "p90_ttft_ms",
            "p90_tpot_ms",
            "slo_violation_s",
            "goodput_req_s",
            "fp16_time_frac",
            "peak_fp8_replicas",
        ],
    );
    rep.note("60s at 3 req/s with a 5x surge for 15s; staged escalation demotes tail replicas first");
    for n in [1usize, 2, 4] {
        let mut r = run_cluster(n, RoutingPolicy::SloHeadroom, 60, 3.0)?;
        let peak = r
            .demotion_timeline
            .iter()
            .map(|&(_, k)| k)
            .max()
            .unwrap_or(0);
        let ttft = r.aggregate.ttft_summary();
        let tpot = r.aggregate.tpot_summary();
        rep.row(vec![
            n.to_string(),
            format!("{:.1}", ttft.p90 * 1e3),
            format!("{:.1}", tpot.p90 * 1e3),
            r.aggregate.slo_violation_seconds(&slo).to_string(),
            format!("{:.2}", r.aggregate.goodput_req_s(&slo)),
            format!("{:.0}%", r.fp16_fraction() * 100.0),
            peak.to_string(),
        ]);
    }
    Ok(rep)
}

/// The `--scale` scenario: a fleet of replicas replaying a multi-hour
/// slice of the synthetic Azure day trace (paper Fig 1a) under the
/// autopilot. Request shapes are tiny and fixed — the arm measures the
/// *driver* (event dispatch over hundreds of components and millions of
/// events), not per-request realism.
#[derive(Clone, Copy, Debug)]
pub struct ScaleScenario {
    pub replicas: usize,
    /// Slice start within the trace day, seconds (0 = midnight).
    pub start_s: usize,
    /// Slice length, seconds.
    pub len_s: usize,
    /// Rate downscale factor applied to the day trace.
    pub scale: f64,
    pub arrival_seed: u64,
    pub shape_seed: u64,
}

impl ScaleScenario {
    /// The headline arm: 120 replicas over the 00:00–06:00 slice (the
    /// diurnal curve runs 76–88 req/s there, peaking ~03:00), scaled to
    /// 0.75 — about 1.3M requests over six simulated hours.
    pub fn full() -> ScaleScenario {
        ScaleScenario {
            replicas: 120,
            start_s: 0,
            len_s: 21_600,
            scale: 0.75,
            arrival_seed: 31,
            shape_seed: 12,
        }
    }

    /// CI smoke: still ≥100 replicas, but 15 simulated minutes (~50k
    /// requests) so the arm finishes in seconds.
    pub fn quick() -> ScaleScenario {
        ScaleScenario {
            len_s: 900,
            replicas: 100,
            ..ScaleScenario::full()
        }
    }
}

/// Build the scale workload: Azure day slice → downscale → Poisson
/// arrivals → fixed 16-in/8-out requests (context 64, so each request
/// costs a handful of KV blocks and the fleet stays decode-bound).
pub fn scale_workload(sc: &ScaleScenario) -> Vec<crate::coordinator::request::Request> {
    let rates = day_slice(&AzureTraceConfig::default(), sc.start_s, sc.len_s);
    let rates = downscale(&rates, sc.scale);
    let arrivals = poisson_arrivals(&rates, sc.arrival_seed);
    let wl = WorkloadConfig {
        seed: sc.shape_seed,
        input_len: 16,
        output_len: 8,
        chunk_align: 16,
    };
    build_requests(&arrivals, &wl, 64)
}

/// Run one scale scenario to completion. Returns the cluster report and
/// the request count; the acceptance floors live in
/// [`cluster_scale`], so tests can drive small scenarios through the
/// exact same construction path.
pub fn run_scale(sc: &ScaleScenario) -> Result<(ClusterReport, usize)> {
    let workload = scale_workload(sc);
    let n_requests = workload.len();
    let spec = zoo::find("llama31-8b").expect("llama31-8b in the zoo");
    let max_seq = 64;
    let backends: Vec<SimBackend> = (0..sc.replicas)
        .map(|_| {
            SimBackend::new(
                spec,
                WeightFormat::Nested16,
                WeightFormat::Nested8,
                32,
                max_seq,
                320,
            )
        })
        .collect();
    let cfg = ClusterConfig {
        policy: RoutingPolicy::SloHeadroom,
        engine: EngineConfig {
            policy: PrecisionPolicy::Dual,
            slo: SloConfig::default(),
            physical_kv: false,
            max_iterations: 0,
            kv: KvPressureConfig::default(),
            devices: 1,
        },
        surge: SurgeConfig::disabled(),
        autopilot: Some(AutopilotConfig::default()),
        // the scale arm holds millions of control ticks: keep only the
        // count and the bounded head/tail window (regression suites that
        // diff tick times set this true on their small scenarios)
        record_control_ticks: false,
        ..ClusterConfig::default()
    };
    let mut cluster = ClusterRouter::new(backends, cfg);
    let report = cluster.run(workload)?;
    Ok((report, n_requests))
}

/// `repro reproduce cluster --scale [--quick]`: the event-core scale
/// demonstration, with the tentpole floors enforced (`--quick` keeps the
/// replica floor but shortens the trace) and per-event accounting in the
/// report/JSON.
pub fn cluster_scale(quick: bool) -> Result<Report> {
    let sc = if quick {
        ScaleScenario::quick()
    } else {
        ScaleScenario::full()
    };
    let slo = SloConfig::default();
    let t0 = Instant::now();
    let (mut r, n_requests) = run_scale(&sc)?;
    let wall_s = t0.elapsed().as_secs_f64();

    ensure!(
        sc.replicas >= 100,
        "scale arm must drive >= 100 replicas (got {})",
        sc.replicas
    );
    let request_floor = if quick { 10_000 } else { 1_000_000 };
    ensure!(
        n_requests >= request_floor,
        "scale arm generated only {n_requests} requests (floor {request_floor})"
    );
    ensure!(
        r.aggregate.completed == n_requests,
        "scale workload did not drain: {} of {n_requests} completed",
        r.aggregate.completed
    );
    ensure!(
        r.events.idle_replica_events == 0,
        "{} events were dispatched to idle replicas (must be 0)",
        r.events.idle_replica_events
    );
    for (i, rep) in r.replicas.iter().enumerate() {
        ensure!(
            rep.final_free_kv_blocks == rep.total_kv_blocks && rep.final_host_kv_blocks == 0,
            "replica {i} leaked KV at scale: free {}/{} host {}",
            rep.final_free_kv_blocks,
            rep.total_kv_blocks,
            rep.final_host_kv_blocks
        );
    }

    let ev = r.events;
    let ttft = r.aggregate.ttft_summary();
    let mut rep = Report::new(
        &format!(
            "Cluster — discrete-event scale arm ({} replicas, Azure day slice {}–{} s x{:.2})",
            sc.replicas,
            sc.start_s,
            sc.start_s + sc.len_s,
            sc.scale
        ),
        &["metric", "value"],
    );
    rep.note(
        "event-core driver: min-heap over arrival/control/predictor/replica components; \
         idle replicas are parked (idle_replica_events must be 0)",
    );
    let mut kv = |k: &str, v: String| rep.row(vec![k.to_string(), v]);
    kv("replicas", sc.replicas.to_string());
    kv("requests", n_requests.to_string());
    kv("sim_hours", format!("{:.2}", sc.len_s as f64 / 3600.0));
    kv("wall_s", format!("{wall_s:.1}"));
    kv("events_popped", ev.queue.popped.to_string());
    kv("events_scheduled", ev.queue.scheduled.to_string());
    kv("arrival_events", ev.arrival_events.to_string());
    kv("control_events", ev.control_events.to_string());
    kv("predictor_events", ev.predictor_events.to_string());
    kv("replica_step_events", ev.replica_step_events.to_string());
    kv("replica_blocked_wakes", ev.replica_blocked_wakes.to_string());
    kv("idle_replica_events", ev.idle_replica_events.to_string());
    kv("reshard_events", ev.reshard_events.to_string());
    // full per-tick times are not recorded at scale (they'd hold every
    // 0.25 s tick over the whole day slice); the count plus a head/tail
    // window is what the report keeps
    kv("control_ticks", r.control_tick_count.to_string());
    kv(
        "control_ticks_head",
        format!("{:?}", r.control_ticks_head.iter().map(|t| (t * 100.0).round() / 100.0).collect::<Vec<f64>>()),
    );
    kv(
        "control_ticks_tail",
        format!("{:?}", r.control_ticks_tail.iter().map(|t| (t * 100.0).round() / 100.0).collect::<Vec<f64>>()),
    );
    kv(
        "events_per_request",
        format!("{:.2}", ev.queue.popped as f64 / n_requests as f64),
    );
    kv(
        "events_per_wall_s",
        format!("{:.0}", ev.queue.popped as f64 / wall_s.max(1e-9)),
    );
    kv("p99_ttft_ms", format!("{:.1}", ttft.p99 * 1e3));
    kv(
        "goodput_req_s",
        format!("{:.2}", r.aggregate.goodput_req_s(&slo)),
    );
    kv("fp16_time_frac", format!("{:.0}%", r.fp16_fraction() * 100.0));
    Ok(rep)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scaling_shape_holds() {
        // the qualitative claim: adding replicas absorbs the surge —
        // violations and worst-case TTFT shrink, goodput does not drop
        let slo = SloConfig::default();
        let mut one = run_cluster(1, RoutingPolicy::SloHeadroom, 30, 2.0).unwrap();
        let mut four = run_cluster(4, RoutingPolicy::SloHeadroom, 30, 2.0).unwrap();
        assert_eq!(
            one.aggregate.completed, four.aggregate.completed,
            "same workload must fully drain in both configurations"
        );
        let v1 = one.aggregate.slo_violation_seconds(&slo);
        let v4 = four.aggregate.slo_violation_seconds(&slo);
        assert!(v4 <= v1, "4 replicas violated more than 1 ({v4} > {v1})");
        let t1 = one.aggregate.ttft_summary();
        let t4 = four.aggregate.ttft_summary();
        assert!(
            t4.p90 <= t1.p90 + 1e-9,
            "p90 TTFT got worse with more replicas: {} > {}",
            t4.p90,
            t1.p90
        );
        assert!(four.aggregate.goodput_req_s(&slo) >= one.aggregate.goodput_req_s(&slo) - 1e-9);
    }

    #[test]
    fn scale_path_drains_with_zero_idle_events() {
        // a shrunken ScaleScenario through the exact --scale construction
        // path: same backends, autopilot, and workload pipeline
        let sc = ScaleScenario {
            replicas: 6,
            start_s: 0,
            len_s: 30,
            scale: 0.05,
            ..ScaleScenario::full()
        };
        let (r, n) = run_scale(&sc).unwrap();
        assert!(n > 20, "degenerate workload: {n} requests");
        assert_eq!(r.aggregate.completed, n);
        assert_eq!(r.events.arrival_events, n);
        assert_eq!(r.events.idle_replica_events, 0);
        assert!(r.events.control_events > 0, "autopilot control never ticked");
        assert!(r.events.predictor_events > 0, "predictor clock never ticked");
        // every pop is accounted to exactly one component class
        let dispatched = r.events.arrival_events
            + r.events.control_events
            + r.events.predictor_events
            + r.events.replica_step_events
            + r.events.idle_replica_events
            + r.events.reshard_events;
        assert_eq!(r.events.queue.popped as usize, dispatched);
        // scale runs keep only bounded control-tick state
        assert!(r.control_ticks.is_empty());
        assert_eq!(r.events.control_events, r.control_tick_count);
        assert!(r.control_ticks_head.len() <= 16 && r.control_ticks_tail.len() <= 16);
        for rep in &r.replicas {
            assert_eq!(rep.final_free_kv_blocks, rep.total_kv_blocks);
            assert_eq!(rep.final_host_kv_blocks, 0);
        }
    }

    #[test]
    fn scale_workload_is_deterministic() {
        let sc = ScaleScenario {
            replicas: 4,
            len_s: 60,
            scale: 0.1,
            ..ScaleScenario::full()
        };
        let a = scale_workload(&sc);
        let b = scale_workload(&sc);
        assert_eq!(a.len(), b.len());
        assert!(!a.is_empty());
        assert!(a.iter().zip(&b).all(|(x, y)| x.arrival == y.arrival));
        // fixed tiny shapes: 16-in (aligned), 8-out
        assert!(a.iter().all(|r| r.prompt.len() == 16 && r.max_new_tokens == 8));
    }

    #[test]
    fn workload_is_deterministic() {
        let a = surge_workload(30, 2.0);
        let b = surge_workload(30, 2.0);
        assert_eq!(a.len(), b.len());
        assert!(a.iter().zip(&b).all(|(x, y)| {
            x.arrival == y.arrival
                && x.prompt.len() == y.prompt.len()
                && x.max_new_tokens == y.max_new_tokens
        }));
        assert!(!a.is_empty());
    }
}

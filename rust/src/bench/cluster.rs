//! Cluster scaling scenario: one traffic surge replayed against 1, 2, and
//! 4 engine replicas (`repro reproduce cluster`).
//!
//! The single-replica experiments (Fig 1b) show dual precision absorbing
//! a surge *in time* (switch to FP8 for the bad seconds). This scenario
//! shows the cluster absorbing the same surge *in space*: with enough
//! replicas the SLO-headroom router spreads the load and nobody demotes;
//! undersized clusters demote their tail replicas (staged escalation)
//! and still contain the violation window.

use anyhow::Result;

use crate::bench::report::Report;
use crate::coordinator::backend::SimBackend;
use crate::coordinator::cluster::{ClusterConfig, ClusterReport, ClusterRouter, SurgeConfig};
use crate::coordinator::engine::EngineConfig;
use crate::coordinator::precision::{PrecisionPolicy, SloConfig};
use crate::coordinator::router::RoutingPolicy;
use crate::gpusim::WeightFormat;
use crate::kvcache::KvPressureConfig;
use crate::model::zoo;
use crate::trace::workload::{build_requests, poisson_arrivals, surge_rates, WorkloadConfig};

/// The scenario's fixed shape: 60 s at `base` req/s with a 5x surge for
/// 15 s starting at t=20 (per-second Poisson arrivals, sampled lengths).
pub fn surge_workload(seconds: usize, base: f64) -> Vec<crate::coordinator::request::Request> {
    let rates = surge_rates(base, 5.0, seconds, seconds / 3, seconds / 4);
    let arrivals = poisson_arrivals(&rates, 17);
    let wl = WorkloadConfig {
        seed: 5,
        input_len: 0,  // sampled
        output_len: 0, // sampled
        chunk_align: 64,
    };
    let max_seq = 1024;
    let mut requests = build_requests(&arrivals, &wl, max_seq);
    for r in &mut requests {
        r.max_new_tokens = r.max_new_tokens.min(128);
    }
    requests
}

/// Run the surge against `n_replicas` simulated H100s (llama-3.1-8b).
pub fn run_cluster(
    n_replicas: usize,
    policy: RoutingPolicy,
    seconds: usize,
    base: f64,
) -> Result<ClusterReport> {
    let spec = zoo::find("llama31-8b").expect("llama31-8b in the zoo");
    let max_seq = 1024;
    let backends: Vec<SimBackend> = (0..n_replicas)
        .map(|_| {
            SimBackend::new(
                spec,
                WeightFormat::Nested16,
                WeightFormat::Nested8,
                64,
                max_seq,
                64 * (max_seq / 16 + 1) * 2,
            )
        })
        .collect();
    let cfg = ClusterConfig {
        policy,
        engine: EngineConfig {
            policy: PrecisionPolicy::Dual,
            slo: SloConfig::default(),
            physical_kv: false,
            max_iterations: 0,
            kv: KvPressureConfig::default(),
        },
        surge: SurgeConfig::default(),
        autopilot: None,
    };
    let mut cluster = ClusterRouter::new(backends, cfg);
    cluster.run(surge_workload(seconds, base))
}

/// The cluster scaling table: same surge, 1 / 2 / 4 replicas.
pub fn cluster_scaling() -> Result<Report> {
    let slo = SloConfig::default();
    let mut rep = Report::new(
        "Cluster — surge absorption vs replica count (llama31-8b, sim-H100, SLO-headroom routing)",
        &[
            "replicas",
            "p90_ttft_ms",
            "p90_tpot_ms",
            "slo_violation_s",
            "goodput_req_s",
            "fp16_time_frac",
            "peak_fp8_replicas",
        ],
    );
    rep.note("60s at 3 req/s with a 5x surge for 15s; staged escalation demotes tail replicas first");
    for n in [1usize, 2, 4] {
        let mut r = run_cluster(n, RoutingPolicy::SloHeadroom, 60, 3.0)?;
        let peak = r
            .demotion_timeline
            .iter()
            .map(|&(_, k)| k)
            .max()
            .unwrap_or(0);
        let ttft = r.aggregate.ttft_summary();
        let tpot = r.aggregate.tpot_summary();
        rep.row(vec![
            n.to_string(),
            format!("{:.1}", ttft.p90 * 1e3),
            format!("{:.1}", tpot.p90 * 1e3),
            r.aggregate.slo_violation_seconds(&slo).to_string(),
            format!("{:.2}", r.aggregate.goodput_req_s(&slo)),
            format!("{:.0}%", r.fp16_fraction() * 100.0),
            peak.to_string(),
        ]);
    }
    Ok(rep)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scaling_shape_holds() {
        // the qualitative claim: adding replicas absorbs the surge —
        // violations and worst-case TTFT shrink, goodput does not drop
        let slo = SloConfig::default();
        let mut one = run_cluster(1, RoutingPolicy::SloHeadroom, 30, 2.0).unwrap();
        let mut four = run_cluster(4, RoutingPolicy::SloHeadroom, 30, 2.0).unwrap();
        assert_eq!(
            one.aggregate.completed, four.aggregate.completed,
            "same workload must fully drain in both configurations"
        );
        let v1 = one.aggregate.slo_violation_seconds(&slo);
        let v4 = four.aggregate.slo_violation_seconds(&slo);
        assert!(v4 <= v1, "4 replicas violated more than 1 ({v4} > {v1})");
        let t1 = one.aggregate.ttft_summary();
        let t4 = four.aggregate.ttft_summary();
        assert!(
            t4.p90 <= t1.p90 + 1e-9,
            "p90 TTFT got worse with more replicas: {} > {}",
            t4.p90,
            t1.p90
        );
        assert!(four.aggregate.goodput_req_s(&slo) >= one.aggregate.goodput_req_s(&slo) - 1e-9);
    }

    #[test]
    fn workload_is_deterministic() {
        let a = surge_workload(30, 2.0);
        let b = surge_workload(30, 2.0);
        assert_eq!(a.len(), b.len());
        assert!(a.iter().zip(&b).all(|(x, y)| {
            x.arrival == y.arrival
                && x.prompt.len() == y.prompt.len()
                && x.max_new_tokens == y.max_new_tokens
        }));
        assert!(!a.is_empty());
    }
}

//! End-to-end iteration latency model for zoo models on the simulated
//! H100 — the cost source for the serving engine's `SimClock` (Figures
//! 1b, 8, 10).
//!
//! One serving iteration = sum over layers of the four GEMM kinds (each
//! autotuned via `search::best_config`) + attention KV streaming +
//! elementwise/norm traffic + lm-head GEMM + a fixed framework overhead
//! per iteration (scheduler, launch amortization — vLLM-like).

use std::collections::HashMap;
use std::sync::Mutex;

use crate::model::zoo::{GemmKind, ModelSpec};

use super::gemm::{GemmQuery, WeightFormat};
use super::h100;
use super::kernel::OptLevel;
use super::search;

/// Framework overhead per serving iteration (scheduling, sampling, python
/// glue in vLLM; our engine is cheaper but the figures model the paper's
/// setup).
pub const ITER_OVERHEAD_S: f64 = 250e-6;

/// Effective host (CPU DRAM) bandwidth available to a piggybacked
/// attention walk, bytes/s — DDR5-class, an order of magnitude under
/// H100 HBM. Host attention is bandwidth-bound just like the device
/// law; only the roofline moves.
pub const HOST_MEM_BW: f64 = 120e9;
/// Achievable fraction of [`HOST_MEM_BW`] for the strided block walk
/// (the host analogue of `h100::HBM_EFF`).
pub const HOST_MEM_EFF: f64 = 0.6;
/// Per-layer dispatch overhead of the host attention walk, seconds —
/// thread wakeup + block-table chase, far below a CUDA kernel launch.
pub const HOST_ATTN_LAUNCH_S: f64 = 2e-6;

/// Latency of serving one decode iteration's attention for the
/// host-resident lanes: stream `kv_bytes` (the touched bytes of every
/// host lane summed over all layers, at stored precision) at the host
/// roofline, plus one dispatch per layer. Zero host lanes cost zero —
/// the piggyback-disabled path adds exactly nothing.
pub fn host_attention_seconds(n_layers: usize, kv_bytes: usize) -> f64 {
    if kv_bytes == 0 {
        return 0.0;
    }
    n_layers as f64 * HOST_ATTN_LAUNCH_S + kv_bytes as f64 / (HOST_MEM_BW * HOST_MEM_EFF)
}

/// The decode-attention term of [`step_latency_split`] in isolation:
/// what `seqs` device lanes at mean context `ctx` pay for KV streaming
/// and attention kernel launches. Mixed-tier batches subtract the
/// all-lanes term and add back the device-lane term, so a batch with no
/// host lanes reproduces the monolithic law bit for bit. Zero lanes
/// launch nothing and cost zero.
pub fn device_attention_seconds(spec: &ModelSpec, seqs: usize, ctx: usize) -> f64 {
    if seqs == 0 {
        return 0.0;
    }
    let kv_bytes_per_layer = (seqs * ctx * 2 * spec.kv_dim() * 2) as f64;
    spec.n_layers as f64 * kv_bytes_per_layer / (h100::HBM_BW * h100::HBM_EFF)
        + spec.n_layers as f64 * h100::KERNEL_OVERHEAD_S
}

/// What kind of serving step to cost.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum StepKind {
    /// Decode: one token per sequence, `batch` sequences, average context
    /// length `ctx`.
    Decode,
    /// Prefill: `batch` = number of prompt tokens in the chunk.
    Prefill,
}

/// A step-latency query.
#[derive(Clone, Copy, Debug)]
pub struct StepQuery {
    pub kind: StepKind,
    /// Token rows entering the linear layers (batch for decode, chunk
    /// length for prefill).
    pub m: usize,
    /// Average context length (KV entries read per sequence).
    pub ctx: usize,
    /// Number of sequences attending (== m for decode, 1 for prefill).
    pub seqs: usize,
    pub format: WeightFormat,
    pub opt: OptLevel,
}

fn gemm_key(m: usize, n: usize, k: usize, f: WeightFormat, o: OptLevel) -> (usize, usize, usize, u8, u8) {
    let fi = match f {
        WeightFormat::Fp16 => 0,
        WeightFormat::Nested16 => 1,
        WeightFormat::Nested8 => 2,
        WeightFormat::Fp8 => 3,
    };
    let oi = match o {
        OptLevel::Level1 => 0,
        OptLevel::Level2 => 1,
        OptLevel::Level3 => 2,
    };
    (m, n, k, fi, oi)
}

/// Autotuned GEMM latency with memoization (the config search is run once
/// per distinct shape, like a real autotuner cache).
pub fn tuned_gemm_latency(m: usize, n: usize, k: usize, format: WeightFormat, opt: OptLevel) -> f64 {
    static CACHE: Mutex<Option<HashMap<(usize, usize, usize, u8, u8), f64>>> = Mutex::new(None);
    let key = gemm_key(m, n, k, format, opt);
    let mut guard = CACHE.lock().unwrap();
    let cache = guard.get_or_insert_with(HashMap::new);
    if let Some(&t) = cache.get(&key) {
        return t;
    }
    let q = GemmQuery {
        m,
        n,
        k,
        format,
        opt,
    };
    let t = search::best_latency(&q);
    cache.insert(key, t);
    t
}

/// Latency of one serving iteration for `spec` under `q`.
pub fn step_latency(spec: &ModelSpec, q: &StepQuery) -> f64 {
    step_latency_split(spec, q, q.format, 0)
}

/// Latency of one serving iteration when `cold_layers` of the model's
/// layers run at `cold_format` and the rest at `q.format` — the cost
/// model for a partial [`LayerSchedule`] under per-layer morphing.
/// `cold_layers == 0` is *exactly* [`step_latency`] (same expressions,
/// same bits — the uniform model is the degenerate split), and
/// `cold_layers == n_layers` prices every layer at `cold_format`.
/// Attention KV streaming, elementwise traffic, and the lm head are
/// format-independent in this model, so only the linear-layer GEMM term
/// splits.
///
/// [`LayerSchedule`]: crate::coordinator::precision::LayerSchedule
pub fn step_latency_split(
    spec: &ModelSpec,
    q: &StepQuery,
    cold_format: WeightFormat,
    cold_layers: usize,
) -> f64 {
    assert!(q.m > 0, "empty step");
    assert!(
        cold_layers <= spec.n_layers,
        "cold_layers {} > model layers {}",
        cold_layers,
        spec.n_layers
    );
    let hot = spec.n_layers - cold_layers;
    let mut t = 0.0;

    // linear layers (quantizable; lm_head and embeddings stay fp16),
    // each priced at its own layer's format — the adds are gated so the
    // all-hot path stays bit-identical to the pre-split model
    for kind in GemmKind::ALL {
        for (n, k, mult) in spec.gemm_shapes(kind) {
            if hot > 0 {
                t += mult as f64
                    * hot as f64
                    * tuned_gemm_latency(q.m, n, k, q.format, q.opt);
            }
            if cold_layers > 0 {
                t += mult as f64
                    * cold_layers as f64
                    * tuned_gemm_latency(q.m, n, k, cold_format, q.opt);
            }
        }
    }

    // attention: stream each sequence's KV cache (fp16) once per layer
    let kv_bytes_per_layer = match q.kind {
        StepKind::Decode => {
            (q.seqs * q.ctx * 2 * spec.kv_dim() * 2) as f64
        }
        StepKind::Prefill => {
            // FlashAttention streams past + new K/V roughly once per
            // query block: (ctx + m) entries per layer
            ((q.ctx + q.m) * 2 * spec.kv_dim() * 2) as f64
        }
    };
    t += spec.n_layers as f64 * kv_bytes_per_layer / (h100::HBM_BW * h100::HBM_EFF);
    // attention kernel launches
    t += spec.n_layers as f64 * h100::KERNEL_OVERHEAD_S;

    // elementwise traffic: norms, rope, residuals (~10 activation sweeps
    // per layer at d_model width, fp16)
    let elem_bytes = (q.m * spec.d_model * 2) as f64 * 10.0;
    t += spec.n_layers as f64 * elem_bytes / (h100::HBM_BW * h100::HBM_EFF);

    // lm head (always fp16: embeddings are not quantized, §2.2)
    t += tuned_gemm_latency(q.m.min(q.seqs.max(1)), spec.vocab, spec.d_model, WeightFormat::Fp16, q.opt);

    t + ITER_OVERHEAD_S
}

/// Time of one tensor-parallel all-reduce over `m` activation rows of
/// width `d_model` (fp16), ring-style across `tp` ranks: a fixed
/// per-phase latency times `ceil(log2 tp)` phases plus the classic
/// `2(tp-1)/tp` bytes-on-the-wire term over NVLink.
pub fn allreduce_latency(m: usize, d_model: usize, tp: usize) -> f64 {
    if tp <= 1 {
        return 0.0;
    }
    let phases = (usize::BITS - (tp - 1).leading_zeros()) as f64; // ceil(log2 tp)
    let bytes = (m * d_model * 2) as f64;
    h100::ALLREDUCE_BASE_LATENCY_S * phases + 2.0 * (tp - 1) as f64 / tp as f64 * bytes / h100::NVLINK_BW
}

/// Latency of one serving iteration for `spec` under `q` when the
/// replica runs tensor-parallel over `tp` devices.
///
/// `tp == 1` is *exactly* [`step_latency`] (same call, same bits — the
/// single-device cost model is the degenerate shard plan). For `tp > 1`
/// the sharded dimensions shrink — GEMM output columns, KV heads, and
/// the lm-head vocab split `tp` ways — while the per-layer kernel
/// launches, elementwise sweeps, and framework overhead do **not**, and
/// two all-reduces per layer (attention output + MLP down, the Megatron
/// pattern) plus one lm-head gather are added on top. Speedup is
/// therefore sublinear, and *precision-dependent*: FP8 GEMMs are
/// already fast, so the constant collective cost eats a larger fraction
/// of the win — exactly why the autopilot treats parallelism as the
/// more expensive knob.
pub fn step_latency_tp(spec: &ModelSpec, q: &StepQuery, tp: usize) -> f64 {
    step_latency_split_tp(spec, q, q.format, 0, tp)
}

/// Tensor-parallel variant of [`step_latency_split`]: `cold_layers`
/// priced at `cold_format`, the rest at `q.format`, sharded `tp` ways.
/// `cold_layers == 0` is exactly [`step_latency_tp`], and `tp == 1` is
/// exactly [`step_latency_split`] — both degenerate cases preserve the
/// existing bit-identity guarantees.
pub fn step_latency_split_tp(
    spec: &ModelSpec,
    q: &StepQuery,
    cold_format: WeightFormat,
    cold_layers: usize,
    tp: usize,
) -> f64 {
    assert!(tp >= 1, "tensor-parallel degree must be >= 1");
    if tp == 1 {
        return step_latency_split(spec, q, cold_format, cold_layers);
    }
    assert!(q.m > 0, "empty step");
    assert!(
        cold_layers <= spec.n_layers,
        "cold_layers {} > model layers {}",
        cold_layers,
        spec.n_layers
    );
    let hot = spec.n_layers - cold_layers;
    let mut t = 0.0;

    // linear layers, output dimension sharded tp ways per device
    for kind in GemmKind::ALL {
        for (n, k, mult) in spec.gemm_shapes(kind) {
            if hot > 0 {
                t += mult as f64
                    * hot as f64
                    * tuned_gemm_latency(q.m, n.div_ceil(tp), k, q.format, q.opt);
            }
            if cold_layers > 0 {
                t += mult as f64
                    * cold_layers as f64
                    * tuned_gemm_latency(q.m, n.div_ceil(tp), k, cold_format, q.opt);
            }
        }
    }

    // attention: KV heads are sharded, so each device streams 1/tp of
    // the cache bytes
    let kv_bytes_per_layer = match q.kind {
        StepKind::Decode => (q.seqs * q.ctx * 2 * spec.kv_dim() * 2) as f64 / tp as f64,
        StepKind::Prefill => ((q.ctx + q.m) * 2 * spec.kv_dim() * 2) as f64 / tp as f64,
    };
    t += spec.n_layers as f64 * kv_bytes_per_layer / (h100::HBM_BW * h100::HBM_EFF);
    // attention kernel launches do not shrink with tp
    t += spec.n_layers as f64 * h100::KERNEL_OVERHEAD_S;

    // elementwise traffic is replicated on every device (norms, rope,
    // residuals run on full activations)
    let elem_bytes = (q.m * spec.d_model * 2) as f64 * 10.0;
    t += spec.n_layers as f64 * elem_bytes / (h100::HBM_BW * h100::HBM_EFF);

    // lm head, vocab sharded
    t += tuned_gemm_latency(
        q.m.min(q.seqs.max(1)),
        spec.vocab.div_ceil(tp),
        spec.d_model,
        WeightFormat::Fp16,
        q.opt,
    );

    // two all-reduces per layer (attn out-proj + MLP down) plus the
    // lm-head logits gather
    t += (2 * spec.n_layers + 1) as f64 * allreduce_latency(q.m, spec.d_model, tp);

    t + ITER_OVERHEAD_S
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;

    fn dq(spec_m: usize, fmt: WeightFormat) -> StepQuery {
        StepQuery {
            kind: StepKind::Decode,
            m: spec_m,
            ctx: 512,
            seqs: spec_m,
            format: fmt,
            opt: OptLevel::Level3,
        }
    }

    #[test]
    fn fp8_speeds_up_decode() {
        let spec = zoo::find("llama31-8b").unwrap();
        for b in [8, 64, 256] {
            let t16 = step_latency(spec, &dq(b, WeightFormat::Nested16));
            let t8 = step_latency(spec, &dq(b, WeightFormat::Nested8));
            assert!(t8 < t16, "b={b}");
        }
    }

    #[test]
    fn e2e_speedup_band_matches_paper() {
        // paper Fig 8: NestedFP8 over NestedFP16 = 1.24x (llama) ..
        // 1.53x (mistral-small) at batch 32..512; larger models gain more
        let llama = zoo::find("llama31-8b").unwrap();
        let small = zoo::find("mistral-small-24b").unwrap();
        let speedup = |spec: &zoo::ModelSpec| {
            let mut rs = Vec::new();
            for b in [32, 128, 256, 512] {
                let t16 = step_latency(spec, &dq(b, WeightFormat::Nested16));
                let t8 = step_latency(spec, &dq(b, WeightFormat::Nested8));
                rs.push(t16 / t8);
            }
            rs.iter().sum::<f64>() / rs.len() as f64
        };
        let s_llama = speedup(llama);
        let s_small = speedup(small);
        assert!(s_small > s_llama, "larger model should gain more: {s_llama} vs {s_small}");
        assert!(s_llama > 1.1 && s_llama < 1.6, "llama speedup {s_llama}");
        assert!(s_small > 1.25 && s_small < 1.9, "mistral-small speedup {s_small}");
    }

    #[test]
    fn nested16_e2e_overhead_below_kernel_overhead() {
        // paper: e2e overhead (2.7-4.5%) < kernel overhead (5.7-6.8%)
        // because non-GEMM components amortize it
        let spec = zoo::find("llama31-8b").unwrap();
        let mut worst: f64 = 0.0;
        for b in [32, 128, 512] {
            let t16 = step_latency(spec, &dq(b, WeightFormat::Fp16));
            let tn = step_latency(spec, &dq(b, WeightFormat::Nested16));
            worst = worst.max(tn / t16 - 1.0);
        }
        assert!(worst < 0.09, "e2e overhead {worst}");
    }

    #[test]
    fn prefill_scales_with_chunk() {
        let spec = zoo::find("llama31-8b").unwrap();
        let q1 = StepQuery {
            kind: StepKind::Prefill,
            m: 128,
            ctx: 0,
            seqs: 1,
            format: WeightFormat::Fp16,
            opt: OptLevel::Level3,
        };
        let q2 = StepQuery { m: 1024, ..q1 };
        let t1 = step_latency(spec, &q1);
        let t2 = step_latency(spec, &q2);
        assert!(t2 > 2.0 * t1, "{t1} vs {t2}");
    }

    #[test]
    fn tp1_is_bit_identical_to_the_dense_model() {
        let spec = zoo::find("llama31-8b").unwrap();
        for b in [1, 8, 64, 256] {
            for fmt in [WeightFormat::Nested16, WeightFormat::Nested8] {
                let a = step_latency(spec, &dq(b, fmt));
                let t = step_latency_tp(spec, &dq(b, fmt), 1);
                assert_eq!(a.to_bits(), t.to_bits(), "b={b} fmt={fmt:?}");
            }
        }
    }

    #[test]
    fn split_endpoints_are_bit_identical_to_the_uniform_model() {
        let spec = zoo::find("llama31-8b").unwrap();
        for b in [1, 8, 64] {
            let q = dq(b, WeightFormat::Nested16);
            let uniform16 = step_latency(spec, &q);
            let all_hot = step_latency_split(spec, &q, WeightFormat::Nested8, 0);
            assert_eq!(uniform16.to_bits(), all_hot.to_bits(), "b={b} all-hot");
            let q8 = dq(b, WeightFormat::Nested8);
            let uniform8 = step_latency(spec, &q8);
            let all_cold =
                step_latency_split(spec, &q, WeightFormat::Nested8, spec.n_layers);
            assert_eq!(uniform8.to_bits(), all_cold.to_bits(), "b={b} all-cold");
            for tp in [1, 2, 4] {
                let u = step_latency_tp(spec, &q, tp);
                let s = step_latency_split_tp(spec, &q, WeightFormat::Nested8, 0, tp);
                assert_eq!(u.to_bits(), s.to_bits(), "b={b} tp={tp}");
            }
        }
    }

    #[test]
    fn split_interpolates_monotonically_between_the_formats() {
        let spec = zoo::find("llama31-8b").unwrap();
        let q = dq(64, WeightFormat::Nested16);
        let mut prev = f64::INFINITY;
        for cold in (0..=spec.n_layers).step_by(4) {
            let t = step_latency_split(spec, &q, WeightFormat::Nested8, cold);
            assert!(
                t <= prev + 1e-15,
                "more FP8 layers must never cost more: cold={cold}"
            );
            prev = t;
        }
        let t16 = step_latency_split(spec, &q, WeightFormat::Nested8, 0);
        let t8 = step_latency_split(spec, &q, WeightFormat::Nested8, spec.n_layers);
        let half = step_latency_split(spec, &q, WeightFormat::Nested8, spec.n_layers / 2);
        assert!(t8 < half && half < t16, "interior strictly between endpoints");
    }

    #[test]
    fn tp_speedup_is_sublinear() {
        let spec = zoo::find("llama31-8b").unwrap();
        let q = dq(64, WeightFormat::Nested16);
        let t1 = step_latency_tp(spec, &q, 1);
        let t2 = step_latency_tp(spec, &q, 2);
        let t4 = step_latency_tp(spec, &q, 4);
        assert!(t2 < t1, "tp=2 must beat tp=1 at batch 64: {t2} vs {t1}");
        assert!(t4 < t2, "tp=4 must beat tp=2 at batch 64: {t4} vs {t2}");
        // sublinear: 2 devices buy less than 2x, 4 less than 4x
        assert!(t1 / t2 < 2.0, "tp=2 speedup {} superlinear", t1 / t2);
        assert!(t1 / t4 < 4.0, "tp=4 speedup {} superlinear", t1 / t4);
        // and the second doubling buys less than the first
        assert!(t1 / t4 < 2.0 * (t1 / t2), "no collective cost visible");
    }

    #[test]
    fn tp_speedup_is_precision_dependent() {
        // FP8 GEMMs are already fast, so the (precision-independent)
        // all-reduce bill eats a larger fraction of the TP win
        let spec = zoo::find("llama31-8b").unwrap();
        let s = |fmt: WeightFormat| {
            let t1 = step_latency_tp(spec, &dq(128, fmt), 1);
            let t4 = step_latency_tp(spec, &dq(128, fmt), 4);
            t1 / t4
        };
        let s16 = s(WeightFormat::Nested16);
        let s8 = s(WeightFormat::Nested8);
        assert!(
            s16 > s8,
            "FP16 must gain more from TP than FP8: {s16} vs {s8}"
        );
    }

    #[test]
    fn allreduce_law_shape() {
        assert_eq!(allreduce_latency(64, 4096, 1), 0.0);
        let t2 = allreduce_latency(64, 4096, 2);
        let t4 = allreduce_latency(64, 4096, 4);
        assert!(t2 > 0.0 && t4 > t2, "more ranks cost more: {t2} vs {t4}");
        // bytes term grows with m
        assert!(allreduce_latency(512, 4096, 2) > t2);
    }

    #[test]
    fn host_attention_law_shape() {
        // zero host lanes add exactly nothing (the piggyback-disabled
        // bit-identity hinges on this)
        assert_eq!(host_attention_seconds(32, 0), 0.0);
        // monotone in bytes, launches charged per layer
        let a = host_attention_seconds(4, 1 << 20);
        let b = host_attention_seconds(4, 1 << 22);
        assert!(a > 0.0 && b > a);
        assert!(host_attention_seconds(8, 1 << 20) > a, "more layers cost more");
        // calibration: per byte, the host walk is much slower than the
        // device stream (HBM vs DDR roofline)
        let spec = zoo::find("llama31-8b").unwrap();
        let bytes_per_layer = 8 * 512 * 2 * spec.kv_dim() * 2;
        let host = host_attention_seconds(spec.n_layers, spec.n_layers * bytes_per_layer);
        let dev = device_attention_seconds(spec, 8, 512);
        assert!(host > dev, "host attention must be the slower tier: {host} vs {dev}");
    }

    #[test]
    fn device_attention_term_matches_the_step_law() {
        // the isolated term must track the attention slice of the
        // monolithic decode law: its bytes component equals the law's
        // KV-streaming expression exactly, so subtract-and-add-back in
        // the mixed-tier backend preserves the no-host-lane cost
        let spec = zoo::find("llama31-8b").unwrap();
        for (seqs, ctx) in [(1usize, 64usize), (8, 512), (64, 1024)] {
            let kv_term = (seqs * ctx * 2 * spec.kv_dim() * 2) as f64 * spec.n_layers as f64
                / (h100::HBM_BW * h100::HBM_EFF);
            let isolated = device_attention_seconds(spec, seqs, ctx)
                - spec.n_layers as f64 * h100::KERNEL_OVERHEAD_S;
            assert!(
                (isolated - kv_term).abs() <= kv_term * 1e-12,
                "seqs={seqs} ctx={ctx}: {isolated} vs {kv_term}"
            );
        }
        assert_eq!(device_attention_seconds(spec, 0, 4096), 0.0);
    }

    #[test]
    fn decode_latency_sane_absolute_range() {
        // ~8B model, batch 64 decode on H100: low single-digit ms
        let spec = zoo::find("llama31-8b").unwrap();
        let t = step_latency(spec, &dq(64, WeightFormat::Fp16));
        assert!(t > 0.5e-3 && t < 30e-3, "t={t}");
    }
}

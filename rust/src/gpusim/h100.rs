//! H100 SXM hardware constants (public spec values).

/// Number of streaming multiprocessors.
pub const SM_COUNT: usize = 132;
/// Dense FP16 tensor-core peak, FLOP/s.
pub const FP16_FLOPS: f64 = 989e12;
/// Dense FP8 tensor-core peak, FLOP/s.
pub const FP8_FLOPS: f64 = 1979e12;
/// HBM3 bandwidth, bytes/s.
pub const HBM_BW: f64 = 3.35e12;
/// Sustained fraction of peak HBM bandwidth achievable by GEMM streams.
pub const HBM_EFF: f64 = 0.82;
/// L2 capacity, bytes (50 MB).
pub const L2_BYTES: usize = 50 * 1024 * 1024;
/// Shared memory per SM, bytes (228 KB usable).
pub const SMEM_BYTES: usize = 228 * 1024;
/// Boost clock, Hz.
pub const CLOCK_HZ: f64 = 1.59e9;
/// Fixed kernel launch + epilogue overhead, seconds.
pub const KERNEL_OVERHEAD_S: f64 = 4.0e-6;
/// Per-element SIMT reconstruction cost (naive byte-wise ops), seconds
/// per weight element per SM. Calibrated so the naive three-stage
/// pipeline of Fig. 7b exposes SIMT time ≈ 1.04× the (wave-quantized)
/// tensor-core time at (1024,5120,32768) with Tm=Tn=128 — which
/// reproduces the published −38.3% (level 2) and −11.0% (level 3) deltas.
pub const SIMT_NAIVE_S_PER_ELEM: f64 = 4.44e-11;
/// Fusing four 8-bit ops into one 32-bit op (level 2) divides SIMT work.
pub const SIMT_FUSE_FACTOR: f64 = 4.0;
/// Fraction of (fused) SIMT time hidden by level-3 scheduling in the
/// non-cooperative kernel (bulk copy advance + preloaded operands).
pub const SIMT_OVERLAP_NONCOOP: f64 = 0.60;
/// Cooperative kernels contend for the SIMT pipe; the NVVM fence recovers
/// most but not all of the overlap.
pub const SIMT_OVERLAP_COOP: f64 = 0.52;
/// NVLink 4 per-direction bandwidth per GPU, bytes/s (900 GB/s
/// bidirectional on H100 SXM → 450 GB/s each way; ring all-reduce is
/// unidirectional per step).
pub const NVLINK_BW: f64 = 450e9;
/// Fixed per-hop latency of one collective phase (launch + NVLink
/// round-trip + NCCL protocol overhead). Billed per `ceil(log2 tp)`
/// stages, so it grows with the tensor-parallel degree but not with
/// message size.
pub const ALLREDUCE_BASE_LATENCY_S: f64 = 8.0e-6;
/// Stream-K fix-up (partial reduction) cost factor.
pub const STREAMK_FIXUP: f64 = 0.03;
/// cuBLAS-vs-tuned-CUTLASS gap modelled for Fig. 13: cuBLAS uses a
/// heuristic config pick; we model it as a small efficiency haircut that
/// sometimes wins on small shapes (fixed overhead amortization).
pub const CUBLAS_SMALL_SHAPE_BONUS: f64 = 0.7;

//! Exhaustive kernel-config search (the paper's per-shape autotune).
//!
//! Grid (paper §5.2): non-cooperative kernels sweep
//! Tm ∈ {16,32,64,128,256}, Tn ∈ {64,128,256}, Tk ∈ {64,128,256} with the
//! data-parallel scheduler; cooperative kernels use Tn ∈ {128,256} and
//! both data-parallel and Stream-K. Infeasible configs (smem overflow)
//! are skipped, mirroring "configurations that fail to compile are
//! excluded".

use super::gemm::{gemm_latency, GemmQuery};
use super::kernel::{KernelConfig, Scheduler};

/// The full search space.
pub fn config_space() -> Vec<KernelConfig> {
    let mut out = Vec::new();
    for &tm in &[16usize, 32, 64, 128, 256] {
        for &tn in &[64usize, 128, 256] {
            for &tk in &[64usize, 128, 256] {
                out.push(KernelConfig {
                    tm,
                    tn,
                    tk,
                    cooperative: false,
                    scheduler: Scheduler::DataParallel,
                });
                if tn >= 128 {
                    for sched in [Scheduler::DataParallel, Scheduler::StreamK] {
                        out.push(KernelConfig {
                            tm,
                            tn,
                            tk,
                            cooperative: true,
                            scheduler: sched,
                        });
                    }
                }
            }
        }
    }
    out
}

/// Best (config, latency) for a query, or None if nothing is feasible.
pub fn best_config(q: &GemmQuery) -> Option<(KernelConfig, f64)> {
    config_space()
        .into_iter()
        .filter_map(|cfg| gemm_latency(q, &cfg).map(|t| (cfg, t)))
        .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
}

/// Best latency only.
pub fn best_latency(q: &GemmQuery) -> f64 {
    best_config(q).map(|(_, t)| t).expect("no feasible kernel config")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::gemm::WeightFormat;
    use crate::gpusim::kernel::OptLevel;

    #[test]
    fn space_size_reasonable() {
        let space = config_space();
        // 5*3*3 non-coop + 5*2*3*2 coop = 45 + 60 = 105
        assert_eq!(space.len(), 105);
    }

    #[test]
    fn search_beats_fixed_config_somewhere() {
        // small M: a small-Tm config must win over Tm=256
        let q = GemmQuery {
            m: 32,
            n: 4096,
            k: 4096,
            format: WeightFormat::Fp16,
            opt: OptLevel::Level3,
        };
        let (best, t_best) = best_config(&q).unwrap();
        assert!(best.tm <= 64, "picked {best:?}");
        let big = KernelConfig {
            tm: 256,
            tn: 128,
            tk: 64,
            cooperative: false,
            scheduler: Scheduler::DataParallel,
        };
        let t_big = gemm_latency(&q, &big).unwrap();
        assert!(t_best <= t_big);
    }

    #[test]
    fn tuned_nested16_overhead_in_paper_band() {
        // sweep the paper's real GEMM shapes (largest per model) and check
        // the *average* overhead lands in the published 4-9% band
        let shapes = [
            (4096usize, 14336usize), // llama-8b mlp
            (5120, 14336),           // nemo
            (5120, 17920),           // phi-4
            (5120, 32768),           // mistral-small
        ];
        let mut ratios = Vec::new();
        for &(n, k) in &shapes {
            let mut m = 32;
            while m <= 2048 {
                let q16 = GemmQuery {
                    m,
                    n,
                    k,
                    format: WeightFormat::Fp16,
                    opt: OptLevel::Level3,
                };
                let qn = GemmQuery {
                    format: WeightFormat::Nested16,
                    ..q16
                };
                ratios.push(best_latency(&qn) / best_latency(&q16));
                m += 160;
            }
        }
        let avg = ratios.iter().sum::<f64>() / ratios.len() as f64;
        assert!(
            avg > 1.01 && avg < 1.12,
            "avg tuned overhead {avg} outside the plausible band"
        );
    }

    #[test]
    fn nested8_within_a_few_percent_of_native_fp8() {
        let q8n = GemmQuery {
            m: 512,
            n: 4096,
            k: 4096,
            format: WeightFormat::Nested8,
            opt: OptLevel::Level3,
        };
        let q8 = GemmQuery {
            format: WeightFormat::Fp8,
            ..q8n
        };
        let r = best_latency(&q8n) / best_latency(&q8);
        assert!(r >= 1.0 - 1e-9 && r < 1.06, "nested8/fp8 ratio {r}");
    }
}

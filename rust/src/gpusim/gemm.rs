//! The GEMM latency model.
//!
//! latency = max(compute-path, memory-path) + fixed overhead, where the
//! compute path includes the wave-quantized tensor-core time plus any
//! *exposed* SIMT reconstruction time (NestedFP16 only), and the memory
//! path is the HBM roofline over the bytes actually touched.

use super::h100;
use super::kernel::{KernelConfig, OptLevel, Scheduler};

/// Weight storage format of the GEMM operand.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum WeightFormat {
    /// Plain FP16 weights (the CUTLASS / cuBLAS baseline).
    Fp16,
    /// NestedFP two-plane weights, FP16-mode execution (reconstruction).
    Nested16,
    /// NestedFP upper plane only, FP8-mode execution.
    Nested8,
    /// Native FP8 weights (the Torch FP8 comparator of Appendix C).
    Fp8,
}

impl WeightFormat {
    /// Bytes of weight traffic per element.
    pub fn weight_bytes(self) -> f64 {
        match self {
            WeightFormat::Fp16 | WeightFormat::Nested16 => 2.0,
            WeightFormat::Nested8 | WeightFormat::Fp8 => 1.0,
        }
    }

    /// Tensor-core peak for the multiply.
    pub fn flops(self) -> f64 {
        match self {
            WeightFormat::Fp16 | WeightFormat::Nested16 => h100::FP16_FLOPS,
            WeightFormat::Nested8 | WeightFormat::Fp8 => h100::FP8_FLOPS,
        }
    }

    /// Does this format run the SIMT reconstruction stage?
    pub fn reconstructs(self) -> bool {
        matches!(self, WeightFormat::Nested16)
    }
}

/// One GEMM instance: activations [M,K] × weights [N,K] -> [M,N].
#[derive(Clone, Copy, Debug)]
pub struct GemmQuery {
    pub m: usize,
    pub n: usize,
    pub k: usize,
    pub format: WeightFormat,
    pub opt: OptLevel,
}

/// Latency in seconds of `q` under kernel config `cfg`.
///
/// Returns `None` if the config is infeasible (shared-memory overflow —
/// the paper's "configurations that fail to compile are excluded").
pub fn gemm_latency(q: &GemmQuery, cfg: &KernelConfig) -> Option<f64> {
    let (m, n, k) = (q.m as f64, q.n as f64, q.k as f64);
    if q.m == 0 {
        return Some(0.0);
    }

    // feasibility: operand staging must fit shared memory
    let w_bytes = q.format.weight_bytes();
    if cfg.smem_bytes(w_bytes) > h100::SMEM_BYTES as f64 {
        return None;
    }
    // cooperative kernels need the larger N tiles (paper: Tn in {128,256})
    if cfg.cooperative && cfg.tn < 128 {
        return None;
    }

    let tiles_m = (m / cfg.tm as f64).ceil();
    let tiles_n = (n / cfg.tn as f64).ceil();
    let tiles = tiles_m * tiles_n;

    // ---- compute path ----------------------------------------------------
    // padded FLOPs (partial tiles still occupy full MMA issue slots)
    let eff_m = tiles_m * cfg.tm as f64;
    let eff_n = tiles_n * cfg.tn as f64;
    let eff_k = (k / cfg.tk as f64).ceil() * cfg.tk as f64;
    let flops = 2.0 * eff_m * eff_n * eff_k;
    let mut t_tc = flops / (q.format.flops() * cfg.mma_efficiency());

    // wave quantization (data-parallel only): the tail wave occupies SMs
    // for a full tile time even when mostly idle
    let concurrency = if cfg.cooperative {
        h100::SM_COUNT as f64 // one block (2 warp groups) per SM
    } else {
        h100::SM_COUNT as f64
    };
    match cfg.scheduler {
        Scheduler::DataParallel => {
            let waves = (tiles / concurrency).ceil();
            let wave_eff = tiles / (waves * concurrency);
            t_tc /= wave_eff.max(1e-6);
        }
        Scheduler::StreamK => {
            // K-splitting balances the tail away; pay the fix-up merge
            t_tc *= 1.0 + h100::STREAMK_FIXUP;
        }
    }

    // ---- SIMT reconstruction (NestedFP16 only) ---------------------------
    let t_simt_exposed = if q.format.reconstructs() {
        // every row-tile re-reconstructs its weight tile: total elements
        // = N*K per column-sweep × number of row tiles, spread over SMs
        let elems = eff_n * eff_k * tiles_m;
        let naive = elems * h100::SIMT_NAIVE_S_PER_ELEM / h100::SM_COUNT as f64;
        let fused = match q.opt {
            OptLevel::Level1 => naive,
            OptLevel::Level2 | OptLevel::Level3 => naive / h100::SIMT_FUSE_FACTOR,
        };
        match q.opt {
            OptLevel::Level3 => {
                let overlap = if cfg.cooperative {
                    h100::SIMT_OVERLAP_COOP
                } else {
                    h100::SIMT_OVERLAP_NONCOOP
                };
                fused * (1.0 - overlap)
            }
            _ => fused,
        }
    } else {
        0.0
    };

    // ---- memory path ------------------------------------------------------
    // weights stream once (L2 reuse across row tiles at serving M sizes),
    // activations once, output written once
    let bytes = n * k * w_bytes + m * k * 2.0 + m * n * 4.0;
    let t_mem = bytes / (h100::HBM_BW * h100::HBM_EFF);

    // compute pipeline = tensor core + exposed SIMT (synchronous issue)
    let mut t_compute = t_tc + t_simt_exposed;
    // NestedFP8 carries the fixed 2^-8 global-scale epilogue and a less
    // mature config space than native FP8 (paper §C: 96.8–98.8% of Torch
    // FP8 throughput)
    if q.format == WeightFormat::Nested8 {
        t_compute *= 1.025;
    }
    Some(t_compute.max(t_mem) + h100::KERNEL_OVERHEAD_S)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> KernelConfig {
        KernelConfig {
            tm: 128,
            tn: 128,
            tk: 64,
            cooperative: false,
            scheduler: Scheduler::DataParallel,
        }
    }

    fn q(m: usize, fmt: WeightFormat) -> GemmQuery {
        GemmQuery {
            m,
            n: 4096,
            k: 4096,
            format: fmt,
            opt: OptLevel::Level3,
        }
    }

    #[test]
    fn fp8_faster_than_fp16() {
        for m in [32, 256, 2048] {
            let t16 = gemm_latency(&q(m, WeightFormat::Fp16), &cfg()).unwrap();
            let t8 = gemm_latency(&q(m, WeightFormat::Fp8), &cfg()).unwrap();
            assert!(t8 < t16, "m={m}: fp8 {t8} !< fp16 {t16}");
        }
    }

    #[test]
    fn fp8_speedup_near_2x_when_memory_bound() {
        // decode regime: M small => weight-streaming bound => ~2x from
        // halved weight bytes
        let t16 = gemm_latency(&q(32, WeightFormat::Fp16), &cfg()).unwrap();
        let t8 = gemm_latency(&q(32, WeightFormat::Fp8), &cfg()).unwrap();
        let speedup = t16 / t8;
        assert!(speedup > 1.6 && speedup < 2.1, "speedup {speedup}");
    }

    #[test]
    fn nested16_overhead_small_but_positive() {
        for m in [64, 512, 2048] {
            let t16 = gemm_latency(&q(m, WeightFormat::Fp16), &cfg()).unwrap();
            let tn = gemm_latency(&q(m, WeightFormat::Nested16), &cfg()).unwrap();
            let ovh = tn / t16 - 1.0;
            assert!(ovh >= 0.0, "m={m}: negative overhead");
            assert!(ovh < 0.30, "m={m}: overhead {ovh} too large");
        }
    }

    #[test]
    fn opt_levels_monotone() {
        let mk = |opt| GemmQuery {
            m: 1024,
            n: 5120,
            k: 32768,
            format: WeightFormat::Nested16,
            opt,
        };
        let l1 = gemm_latency(&mk(OptLevel::Level1), &cfg()).unwrap();
        let l2 = gemm_latency(&mk(OptLevel::Level2), &cfg()).unwrap();
        let l3 = gemm_latency(&mk(OptLevel::Level3), &cfg()).unwrap();
        assert!(l1 > l2 && l2 > l3, "{l1} {l2} {l3}");
    }

    #[test]
    fn fig7b_deltas_reproduced() {
        // the calibration anchor: M×5120×32768 with Tm=128
        let mk = |opt| GemmQuery {
            m: 1024,
            n: 5120,
            k: 32768,
            format: WeightFormat::Nested16,
            opt,
        };
        let l1 = gemm_latency(&mk(OptLevel::Level1), &cfg()).unwrap();
        let l2 = gemm_latency(&mk(OptLevel::Level2), &cfg()).unwrap();
        let l3 = gemm_latency(&mk(OptLevel::Level3), &cfg()).unwrap();
        let d21 = 1.0 - l2 / l1; // paper: 38.3%
        let d32 = 1.0 - l3 / l2; // paper: 11.0%
        assert!((d21 - 0.383).abs() < 0.06, "level2 delta {d21}");
        assert!((d32 - 0.110).abs() < 0.05, "level3 delta {d32}");
    }

    #[test]
    fn smem_overflow_rejected() {
        let fat = KernelConfig {
            tm: 256,
            tn: 256,
            tk: 256,
            cooperative: false,
            scheduler: Scheduler::DataParallel,
        };
        assert!(gemm_latency(&q(128, WeightFormat::Fp16), &fat).is_none());
    }

    #[test]
    fn streamk_beats_dp_on_tail_heavy_shapes() {
        // 133 tiles over 132 SMs: DP pays a 2x wave penalty, Stream-K only
        // the fix-up
        let cfg_dp = cfg();
        let cfg_sk = KernelConfig {
            scheduler: Scheduler::StreamK,
            ..cfg_dp
        };
        let query = GemmQuery {
            m: 128 * 7,
            n: 128 * 19,
            k: 8192,
            format: WeightFormat::Fp16,
            opt: OptLevel::Level3,
        }; // 7*19 = 133 tiles
        let t_dp = gemm_latency(&query, &cfg_dp).unwrap();
        let t_sk = gemm_latency(&query, &cfg_sk).unwrap();
        assert!(t_sk < t_dp, "stream-k {t_sk} !< dp {t_dp}");
    }

    #[test]
    fn latency_monotone_in_m_within_same_wave_structure() {
        let t1 = gemm_latency(&q(512, WeightFormat::Fp16), &cfg()).unwrap();
        let t2 = gemm_latency(&q(2048, WeightFormat::Fp16), &cfg()).unwrap();
        assert!(t2 > t1);
    }
}

//! Tile-level analytical H100 GEMM cost model — the hardware substitute.
//!
//! The paper's performance evaluation runs CUTLASS SM90 kernels on an
//! H100 SXM; this environment has no GPU, so (per the substitution rule in
//! DESIGN.md §2) we model the mechanisms the paper's §4.3 and Appendix D
//! describe and regenerate the performance *shape* of every figure:
//!
//! * data-parallel vs Stream-K tile scheduling (wave quantization),
//! * cooperative (2 consumer warp groups) vs non-cooperative kernels,
//! * the roofline: 989 TFLOP/s dense FP16 / 1979 FP8, 3.35 TB/s HBM3,
//! * the NestedFP16 **synchronous SIMT reconstruction stage** and the
//!   three optimization levels of Figure 7b (naive 3-stage pipeline,
//!   fused 32-bit bit ops, scheduling/fence overlap),
//! * the paper's exhaustive per-shape kernel config search.
//!
//! Constants are calibrated against the paper's own measurements
//! (Fig. 7b: level-2 −38.3%, level-3 −11.0%; §5.2: 5.7–6.8% average
//! FP16-mode overhead; §C: NestedFP8 at 97–99% of native FP8).

pub mod h100;
pub mod kernel;
pub mod gemm;
pub mod search;
pub mod e2e;

pub use gemm::{gemm_latency, GemmQuery, WeightFormat};
pub use kernel::{KernelConfig, OptLevel, Scheduler};
pub use search::{best_config, best_latency, config_space};
pub use e2e::{
    allreduce_latency, device_attention_seconds, host_attention_seconds, step_latency,
    step_latency_split, step_latency_split_tp, step_latency_tp, StepKind, StepQuery,
    HOST_ATTN_LAUNCH_S, HOST_MEM_BW, HOST_MEM_EFF,
};

//! Kernel configuration space (paper §5.2 / Appendix D).

/// Tile scheduling policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Scheduler {
    /// One thread block owns a full output tile (full K sweep).
    DataParallel,
    /// Multiple blocks split the K dimension of one output tile and merge
    /// partials (Stream-K) — kills wave quantization, adds fix-up cost.
    StreamK,
}

/// NestedFP16 kernel optimization levels (Figure 7b).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum OptLevel {
    /// Three-stage pipeline, naive byte-wise SIMT reconstruction.
    Level1,
    /// + four 8-bit ops fused into one 32-bit op.
    Level2,
    /// + scheduling: bulk smem→reg copies (non-coop) / NVVM fence (coop).
    Level3,
}

/// One CUTLASS-style kernel configuration.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct KernelConfig {
    /// Output tile M dimension.
    pub tm: usize,
    /// Output tile N dimension.
    pub tn: usize,
    /// Mainloop K step.
    pub tk: usize,
    /// Two consumer warp groups (cooperative) vs one.
    pub cooperative: bool,
    /// Thread-block scheduling.
    pub scheduler: Scheduler,
}

impl KernelConfig {
    /// Shared-memory working set for one pipeline stage set (operand
    /// staging; 3 stages assumed). `w_bytes` is bytes per weight element
    /// (2 for fp16, 2 for nested16 upper+lower, 1 for fp8).
    pub fn smem_bytes(&self, w_bytes_per_elem: f64) -> f64 {
        let stages = 3.0;
        let act = (self.tm * self.tk) as f64 * 2.0;
        let wt = (self.tn * self.tk) as f64 * w_bytes_per_elem;
        stages * (act + wt)
    }

    /// MMA efficiency of the tile shape: warp-group MMA wants M>=64 and
    /// large N; small tiles leave tensor-core lanes idle.
    pub fn mma_efficiency(&self) -> f64 {
        let m_eff = (self.tm as f64 / 64.0).min(1.0);
        let n_eff = (self.tn as f64 / 128.0).min(1.0);
        // diminishing penalty: sqrt keeps small tiles usable (matches the
        // gentle degradation CUTLASS shows down to 64-wide tiles)
        (m_eff * n_eff).sqrt().max(0.25)
    }

    pub fn name(&self) -> String {
        format!(
            "{}x{}x{}_{}{}",
            self.tm,
            self.tn,
            self.tk,
            if self.cooperative { "coop" } else { "nc" },
            match self.scheduler {
                Scheduler::DataParallel => "_dp",
                Scheduler::StreamK => "_sk",
            }
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smem_grows_with_tiles() {
        let small = KernelConfig {
            tm: 64,
            tn: 64,
            tk: 64,
            cooperative: false,
            scheduler: Scheduler::DataParallel,
        };
        let big = KernelConfig { tm: 128, tn: 256, ..small };
        assert!(big.smem_bytes(2.0) > small.smem_bytes(2.0));
    }

    #[test]
    fn mma_efficiency_bounds() {
        let cfg = KernelConfig {
            tm: 128,
            tn: 256,
            tk: 64,
            cooperative: true,
            scheduler: Scheduler::DataParallel,
        };
        assert!((cfg.mma_efficiency() - 1.0).abs() < 1e-9);
        let tiny = KernelConfig { tm: 16, tn: 64, ..cfg };
        assert!(tiny.mma_efficiency() < 0.6);
        assert!(tiny.mma_efficiency() >= 0.25);
    }
}

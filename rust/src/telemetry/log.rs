//! The leveled diagnostics facade.
//!
//! One process-wide level, read lazily from `NESTEDFP_LOG`
//! (`off | warn | info | debug`; unset and unknown values mean `info`,
//! which preserves the historical always-print behavior of the serve
//! path). The [`log_warn!`](crate::log_warn),
//! [`log_info!`](crate::log_info) and [`log_debug!`](crate::log_debug)
//! macros check the level *before* touching their format arguments, so
//! a filtered-out message allocates and formats nothing.
//!
//! This replaces both copied `debug/info` helper blocks that
//! `runtime/client.rs` and `runtime/client_stub.rs` used to carry
//! (their `set_verbose(true)` switch maps to [`set_verbose`], i.e.
//! raising the level to `debug`).

use std::sync::atomic::{AtomicU8, Ordering};

/// Diagnostic severity, ordered: a configured level admits itself and
/// everything below it.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Off = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

const UNSET: u8 = 0xff;
static LEVEL: AtomicU8 = AtomicU8::new(UNSET);

fn init_level() -> u8 {
    let from_env = match std::env::var("NESTEDFP_LOG").ok().as_deref().map(str::trim) {
        Some(s) if s.eq_ignore_ascii_case("off") => Level::Off,
        Some(s) if s.eq_ignore_ascii_case("warn") => Level::Warn,
        Some(s) if s.eq_ignore_ascii_case("debug") => Level::Debug,
        // "info", unknown values, and an unset variable all mean info
        _ => Level::Info,
    } as u8;
    // don't clobber an explicit set_level() that ran before first use
    let _ = LEVEL.compare_exchange(UNSET, from_env, Ordering::Relaxed, Ordering::Relaxed);
    LEVEL.load(Ordering::Relaxed)
}

/// Is a message at `level` currently admitted? This is the (cheap)
/// check the macros perform before formatting anything.
#[inline]
pub fn enabled(level: Level) -> bool {
    let cur = LEVEL.load(Ordering::Relaxed);
    let cur = if cur == UNSET { init_level() } else { cur };
    level as u8 <= cur
}

/// Override the level programmatically (wins over `NESTEDFP_LOG`).
pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Legacy verbose switch of the runtime client: `true` raises the
/// level to `debug`; `false` leaves the configured level alone.
pub fn set_verbose(v: bool) {
    if v {
        set_level(Level::Debug);
    }
}

/// Sink for an already-filtered message. Prefer the macros; call this
/// directly only when the arguments are already formatted.
pub fn emit(args: std::fmt::Arguments<'_>) {
    eprintln!("{args}");
}

/// String-convenience forms for callers holding a finished message.
pub fn warn(msg: &str) {
    if enabled(Level::Warn) {
        emit(format_args!("{msg}"));
    }
}

pub fn info(msg: &str) {
    if enabled(Level::Info) {
        emit(format_args!("{msg}"));
    }
}

pub fn debug(msg: &str) {
    if enabled(Level::Debug) {
        emit(format_args!("[debug] {msg}"));
    }
}

/// Log at warn level. Arguments are not evaluated when filtered out.
#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        if $crate::telemetry::log::enabled($crate::telemetry::log::Level::Warn) {
            $crate::telemetry::log::emit(format_args!($($arg)*));
        }
    };
}

/// Log at info level. Arguments are not evaluated when filtered out.
#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        if $crate::telemetry::log::enabled($crate::telemetry::log::Level::Info) {
            $crate::telemetry::log::emit(format_args!($($arg)*));
        }
    };
}

/// Log at debug level. Arguments are not evaluated when filtered out.
#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        if $crate::telemetry::log::enabled($crate::telemetry::log::Level::Debug) {
            $crate::telemetry::log::emit(format_args!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    // Tests share one process-wide level, so a single test walks the
    // whole contract instead of racing siblings.
    #[test]
    fn level_ordering_and_overrides() {
        set_level(Level::Info);
        assert!(enabled(Level::Warn));
        assert!(enabled(Level::Info));
        assert!(!enabled(Level::Debug));

        set_level(Level::Off);
        assert!(!enabled(Level::Warn));

        set_verbose(false); // must not raise the level
        assert!(!enabled(Level::Warn));
        set_verbose(true);
        assert!(enabled(Level::Debug));

        // the macros compile against all three levels
        set_level(Level::Off);
        crate::log_warn!("never printed {}", 1);
        crate::log_info!("never printed");
        crate::log_debug!("never printed {:?}", (1, 2));
        set_level(Level::Info);
    }
}

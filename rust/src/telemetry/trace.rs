//! The span/event tracer on the virtual clock.
//!
//! Design constraints, in order:
//!
//! 1. **Zero interference.** Recording must never perturb the
//!    simulation: hooks read the virtual clock and integer ids that the
//!    caller already has, and touch no `f64` state of their own. The
//!    heap-vs-lockstep bit-identity suites therefore hold with tracing
//!    on or off (pinned by `rust/tests/telemetry_props.rs`).
//! 2. **Zero cost when disabled.** Every hook starts with one
//!    thread-local flag check ([`enabled`]) and returns immediately when
//!    tracing is not installed — no allocation, no formatting, no clock
//!    reads.
//! 3. **Bounded memory.** Events land in a buffer with a hard cap;
//!    once full, further events are counted as dropped instead of
//!    recorded, and the exporter surfaces the dropped count so a
//!    truncated trace is never mistaken for a complete one.
//!
//! The tracer is thread-local: cluster simulations are single-threaded
//! by construction (the event core is a sequential scheduler), and
//! thread-locality keeps concurrently running tests from contaminating
//! each other's traces.
//!
//! Identity model (mirrors the Perfetto export):
//! * **run** (`pid`) — one simulation/bench arm; [`begin_run`] opens one.
//! * **track** (`tid`) — a replica index, or the reserved
//!   [`CONTROL_TRACK`] / [`BENCH_TRACK`].
//! * **kind + id** — the event taxonomy plus a correlator (request id,
//!   replica id, …). Spans are `Begin`/`End` pairs keyed by
//!   `(run, track, kind, id)`; [`finish_run`] force-closes any span
//!   still open at the end of a run so exports are always balanced.

use std::cell::{Cell, RefCell};

/// Reserved track id for control-plane events (autopilot, resharder).
pub const CONTROL_TRACK: u32 = 1_000_000;
/// Reserved track id for wall-clock bench measurement spans.
pub const BENCH_TRACK: u32 = 1_000_001;

/// The event taxonomy. Spans: [`Kind::Queue`], [`Kind::Prefill`],
/// [`Kind::Decode`], [`Kind::Offload`], [`Kind::Step`],
/// [`Kind::Reshard`], [`Kind::Bench`]. The rest are instants.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Kind {
    /// Request waiting for admission (arrival → KV slot allocated).
    Queue,
    /// Admission → first token.
    Prefill,
    /// First token → completion.
    Decode,
    /// Request preempted to the host tier (offload → resume).
    Offload,
    /// One engine iteration on a replica (`arg` = 1 when FP8).
    Step,
    /// A reshard window (begin → resume; `id` = replica, `arg` = new tp).
    Reshard,
    /// Wall-clock measurement around one bench experiment.
    Bench,
    /// Request arrival (routing decision made; `id` = request).
    Arrival,
    /// Request completion (`id` = request).
    Completion,
    /// Precision rung change (`arg` = mode index).
    Rung,
    /// Autopilot staged pre-escalation (`arg` = severity rung).
    PreEscalate,
    /// KV blocks demoted to FP8 this iteration (`arg` = block count).
    KvDemote,
    /// Decode iteration carried host-piggybacked attention lanes
    /// (`arg` = lane count).
    HostStep,
}

impl Kind {
    /// Slice/instant name in the exported trace.
    pub fn name(self) -> &'static str {
        match self {
            Kind::Queue => "queue",
            Kind::Prefill => "prefill",
            Kind::Decode => "decode",
            Kind::Offload => "offload",
            Kind::Step => "step",
            Kind::Reshard => "reshard",
            Kind::Bench => "bench",
            Kind::Arrival => "arrival",
            Kind::Completion => "complete",
            Kind::Rung => "rung",
            Kind::PreEscalate => "pre_escalate",
            Kind::KvDemote => "kv_demote",
            Kind::HostStep => "host_step",
        }
    }
}

/// Span phase of one record.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    Begin,
    End,
    Instant,
}

/// One recorded event. `t` is virtual seconds for simulation tracks and
/// wall seconds for [`BENCH_TRACK`]; both export as microseconds.
#[derive(Clone, Copy, Debug)]
pub struct Event {
    pub run: u32,
    pub track: u32,
    pub kind: Kind,
    pub phase: Phase,
    pub t: f64,
    pub id: u64,
    pub arg: i64,
}

/// A finished recording, as returned by [`take`].
#[derive(Clone, Debug, Default)]
pub struct Trace {
    pub events: Vec<Event>,
    /// Run labels, indexed by run id (`events[i].run`).
    pub runs: Vec<String>,
    /// Events discarded after the buffer cap was hit.
    pub dropped: usize,
}

struct Tracer {
    events: Vec<Event>,
    runs: Vec<String>,
    cap: usize,
    dropped: usize,
    /// Open spans, `(run, track, kind, id)`; closed LIFO by `finish_run`.
    open: Vec<(u32, u32, Kind, u64)>,
}

thread_local! {
    static ENABLED: Cell<bool> = const { Cell::new(false) };
    static TRACER: RefCell<Option<Tracer>> = const { RefCell::new(None) };
}

/// Default buffer cap: ~1M events (≈50 MB), plenty for a busy-minute
/// run and a hard bound for everything larger.
pub const DEFAULT_CAP: usize = 1 << 20;

/// Install a fresh tracer on this thread with the given event cap.
/// Replaces any previous recording.
pub fn install(cap: usize) {
    TRACER.with(|t| {
        *t.borrow_mut() = Some(Tracer {
            events: Vec::new(),
            runs: vec!["main".to_string()],
            cap: cap.max(16),
            dropped: 0,
            open: Vec::new(),
        });
    });
    ENABLED.with(|e| e.set(true));
}

/// Is a tracer installed on this thread? This is the check every hook
/// performs first; when `false` the hook does nothing else.
#[inline]
pub fn enabled() -> bool {
    ENABLED.with(|e| e.get())
}

/// Uninstall the tracer and return everything it recorded (`None` when
/// no tracer was installed).
pub fn take() -> Option<Trace> {
    ENABLED.with(|e| e.set(false));
    TRACER.with(|t| {
        t.borrow_mut().take().map(|tr| Trace {
            events: tr.events,
            runs: tr.runs,
            dropped: tr.dropped,
        })
    })
}

/// Open a new run (one simulation or bench arm; one Perfetto process).
/// Subsequent events attribute to it. Returns the run id; a no-op 0
/// when tracing is disabled.
pub fn begin_run(label: &str) -> u32 {
    if !enabled() {
        return 0;
    }
    TRACER.with(|t| {
        let mut b = t.borrow_mut();
        let tr = b.as_mut().expect("enabled implies installed");
        tr.runs.push(label.to_string());
        (tr.runs.len() - 1) as u32
    })
}

/// Close every span still open, LIFO, stamped at `t` — called at the
/// end of a run so exports are balanced even when requests are still
/// in flight at the horizon.
pub fn finish_run(t: f64) {
    if !enabled() {
        return;
    }
    TRACER.with(|tr| {
        let mut b = tr.borrow_mut();
        let tr = b.as_mut().expect("enabled implies installed");
        // entries in `open` correspond to *recorded* Begins, so their
        // closing Ends are recorded unconditionally (cap-exempt)
        while let Some((run, track, kind, id)) = tr.open.pop() {
            tr.events.push(Event {
                run,
                track,
                kind,
                phase: Phase::End,
                t,
                id,
                arg: 0,
            });
        }
    });
}

impl Tracer {
    fn push(&mut self, ev: Event) {
        if self.events.len() >= self.cap {
            self.dropped += 1;
        } else {
            self.events.push(ev);
        }
    }

    fn current_run(&self) -> u32 {
        (self.runs.len() - 1) as u32
    }
}

fn record(track: u32, kind: Kind, phase: Phase, t: f64, id: u64, arg: i64) {
    TRACER.with(|tr| {
        let mut b = tr.borrow_mut();
        let tr = b.as_mut().expect("enabled implies installed");
        let run = tr.current_run();
        match phase {
            Phase::Begin => {
                // once the buffer is full a Begin is dropped whole, so
                // it must not leave an orphan open-span entry behind
                if tr.events.len() < tr.cap {
                    tr.open.push((run, track, kind, id));
                }
            }
            Phase::End => {
                if let Some(i) = tr
                    .open
                    .iter()
                    .rposition(|&(r, tk, k, d)| (r, tk, k, d) == (run, track, kind, id))
                {
                    tr.open.remove(i);
                    // the matching Begin was recorded, so this End must
                    // be too — even one slot past the cap — or the
                    // exported trace would be unbalanced
                    tr.events.push(Event {
                        run,
                        track,
                        kind,
                        phase,
                        t,
                        id,
                        arg,
                    });
                } else {
                    // no matching Begin (it was dropped at cap, or the
                    // caller never opened one): skip so traces stay
                    // balanced by construction
                    tr.dropped += 1;
                }
                return;
            }
            Phase::Instant => {}
        }
        tr.push(Event {
            run,
            track,
            kind,
            phase,
            t,
            id,
            arg,
        });
    });
}

/// Open a span. No-op when tracing is disabled.
#[inline]
pub fn begin(track: u32, kind: Kind, t: f64, id: u64, arg: i64) {
    if enabled() {
        record(track, kind, Phase::Begin, t, id, arg);
    }
}

/// Close the innermost open span with this `(track, kind, id)`.
#[inline]
pub fn end(track: u32, kind: Kind, t: f64, id: u64, arg: i64) {
    if enabled() {
        record(track, kind, Phase::End, t, id, arg);
    }
}

/// Record an instant. No-op when tracing is disabled.
#[inline]
pub fn instant(track: u32, kind: Kind, t: f64, id: u64, arg: i64) {
    if enabled() {
        record(track, kind, Phase::Instant, t, id, arg);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_records_nothing() {
        assert!(take().is_none());
        begin(0, Kind::Decode, 1.0, 7, 0);
        instant(0, Kind::Arrival, 1.0, 7, 0);
        end(0, Kind::Decode, 2.0, 7, 0);
        assert!(!enabled());
        assert!(take().is_none());
    }

    #[test]
    fn spans_and_instants_round_trip() {
        install(1024);
        let run = begin_run("arm-a");
        assert_eq!(run, 1, "run 0 is the implicit main run");
        instant(0, Kind::Arrival, 0.5, 42, 0);
        begin(0, Kind::Decode, 1.0, 42, 0);
        end(0, Kind::Decode, 2.0, 42, 0);
        let tr = take().expect("installed");
        assert_eq!(tr.events.len(), 3);
        assert_eq!(tr.runs, vec!["main", "arm-a"]);
        assert_eq!(tr.dropped, 0);
        assert_eq!(tr.events[1].phase, Phase::Begin);
        assert_eq!(tr.events[2].phase, Phase::End);
        assert!(tr.events.iter().all(|e| e.run == 1));
    }

    #[test]
    fn finish_run_closes_open_spans_lifo() {
        install(1024);
        begin(0, Kind::Prefill, 1.0, 1, 0);
        begin(0, Kind::Decode, 2.0, 1, 0);
        finish_run(9.0);
        let tr = take().unwrap();
        assert_eq!(tr.events.len(), 4);
        assert_eq!(tr.events[2].kind, Kind::Decode, "LIFO close order");
        assert_eq!(tr.events[3].kind, Kind::Prefill);
        assert!(tr.events[2..].iter().all(|e| e.phase == Phase::End && e.t == 9.0));
    }

    #[test]
    fn cap_drops_and_counts_without_unbalancing() {
        install(16);
        for i in 0..40u64 {
            begin(0, Kind::Step, i as f64, i, 0);
            end(0, Kind::Step, i as f64 + 0.5, i, 0);
        }
        let tr = take().unwrap();
        assert_eq!(tr.events.len(), 16);
        assert!(tr.dropped > 0);
        // every recorded Begin has its matching End recorded
        let begins = tr.events.iter().filter(|e| e.phase == Phase::Begin).count();
        let ends = tr.events.iter().filter(|e| e.phase == Phase::End).count();
        assert_eq!(begins, ends);
    }

    #[test]
    fn unmatched_end_is_not_recorded() {
        install(64);
        end(0, Kind::Decode, 1.0, 5, 0);
        let tr = take().unwrap();
        assert!(tr.events.is_empty());
        assert_eq!(tr.dropped, 1);
    }
}

//! The unified observability layer: one pipeline from kernel
//! microseconds to cluster timelines.
//!
//! Every subsystem below the coordinator used to invent its own
//! introspection story — `Metrics` merged ~15 scalar counters by hand,
//! reshard/precision timelines were bespoke vectors, kernel timing did
//! not exist, and diagnostics went through scattered `eprintln!`. This
//! module is the single layer they all plug into:
//!
//! * [`trace`] — a span/event tracer on the **virtual clock**. Request
//!   lifecycles (queue → prefill → decode → completion, with
//!   offload/resume windows) and control-plane moments (precision rung
//!   changes, reshard windows, autopilot pre-escalations, KV demotions)
//!   are recorded as cheap integer-id events into a bounded buffer.
//!   Recording is pure observation: it never touches simulation
//!   arithmetic, so heap/lockstep bit-identity and the golden traces
//!   hold with tracing enabled or disabled — and when disabled every
//!   hook is a single thread-local flag check.
//! * [`registry`] — a typed counter/gauge registry with deterministic
//!   merge rules (sum / max / min). `Metrics`, `KvCacheStats`,
//!   `EventStats`, the `Resharder`, and the kernel profilers register
//!   into it, so cross-replica aggregation is one merge law instead of
//!   a hand-written field-by-field function.
//! * [`export`] — exporters: Chrome-trace/Perfetto JSON
//!   (`repro reproduce <bench> --trace FILE`; tracks = replicas + the
//!   control plane, one slice per span) plus the flat counter dump
//!   folded into the `nestedfp/bench-reports@1` JSON, and the
//!   well-formedness checker behind `repro analyze trace <FILE>`.
//! * [`log`] — the leveled diagnostics facade (`NESTEDFP_LOG`
//!   env filter; `log_warn!`/`log_info!`/`log_debug!` allocate nothing
//!   when filtered out) replacing ad-hoc `eprintln!`.
//! * [`profiler`] — per-phase wall-time accumulators for the GEMM and
//!   attention kernels (pack/microkernel/reduce; block-load/dot/softmax)
//!   behind a cloneable [`profiler::Profiler`] handle that is free when
//!   disabled.

pub mod export;
pub mod log;
pub mod profiler;
pub mod registry;
pub mod trace;

pub use profiler::Profiler;
pub use registry::Registry;

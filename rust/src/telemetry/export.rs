//! Exporters: Chrome-trace/Perfetto JSON and the trace checker.
//!
//! The export follows the Chrome Trace Event format (the JSON flavor
//! Perfetto ingests directly): one `traceEvents` array of `B`/`E` slice
//! pairs and `i` instants, timestamps in microseconds, with
//! `pid` = run (one simulation or bench arm) and `tid` = track
//! (replica index, control plane, or wall-clock bench track).
//! `M`etadata events name every process and thread, so opening the file
//! in `ui.perfetto.dev` shows per-replica decode timelines overlapped
//! with precision-rung and reshard-window markers without any manual
//! mapping.
//!
//! Everything is emitted through [`crate::util::json::Json`] (BTreeMap
//! keys, deterministic number formatting), so the same recording always
//! serializes to the same bytes — the property the trace-determinism
//! test in `rust/tests/telemetry_props.rs` pins.

use std::collections::{BTreeMap, BTreeSet, HashMap};

use anyhow::{anyhow, bail, Result};

use crate::util::json::Json;

use super::trace::{Phase, Trace, BENCH_TRACK, CONTROL_TRACK};

/// Human name for a track id.
fn track_name(track: u32) -> String {
    match track {
        CONTROL_TRACK => "control".to_string(),
        BENCH_TRACK => "bench".to_string(),
        r => format!("replica {r}"),
    }
}

fn obj(fields: Vec<(&str, Json)>) -> Json {
    Json::Obj(
        fields
            .into_iter()
            .map(|(k, v)| (k.to_string(), v))
            .collect::<BTreeMap<_, _>>(),
    )
}

/// Render a recording as a Chrome-trace JSON value.
pub fn trace_to_json(trace: &Trace) -> Json {
    let mut events: Vec<Json> = Vec::with_capacity(trace.events.len() + 16);

    // metadata first: name every (run, track) pair that appears
    let mut runs_seen: BTreeSet<u32> = BTreeSet::new();
    let mut tracks_seen: BTreeSet<(u32, u32)> = BTreeSet::new();
    for e in &trace.events {
        runs_seen.insert(e.run);
        tracks_seen.insert((e.run, e.track));
    }
    for &run in &runs_seen {
        let label = trace
            .runs
            .get(run as usize)
            .cloned()
            .unwrap_or_else(|| format!("run {run}"));
        events.push(obj(vec![
            ("ph", Json::Str("M".to_string())),
            ("name", Json::Str("process_name".to_string())),
            ("pid", Json::Num(run as f64)),
            ("tid", Json::Num(0.0)),
            (
                "args",
                obj(vec![("name", Json::Str(label))]),
            ),
        ]));
    }
    for &(run, track) in &tracks_seen {
        events.push(obj(vec![
            ("ph", Json::Str("M".to_string())),
            ("name", Json::Str("thread_name".to_string())),
            ("pid", Json::Num(run as f64)),
            ("tid", Json::Num(track as f64)),
            (
                "args",
                obj(vec![("name", Json::Str(track_name(track)))]),
            ),
        ]));
    }

    for e in &trace.events {
        let ph = match e.phase {
            Phase::Begin => "B",
            Phase::End => "E",
            Phase::Instant => "i",
        };
        let mut fields = vec![
            ("name", Json::Str(e.kind.name().to_string())),
            ("cat", Json::Str("sim".to_string())),
            ("ph", Json::Str(ph.to_string())),
            ("ts", Json::Num(e.t * 1e6)),
            ("pid", Json::Num(e.run as f64)),
            ("tid", Json::Num(e.track as f64)),
            (
                "args",
                obj(vec![
                    ("id", Json::Num(e.id as f64)),
                    ("arg", Json::Num(e.arg as f64)),
                ]),
            ),
        ];
        if e.phase == Phase::Instant {
            fields.push(("s", Json::Str("t".to_string())));
        }
        events.push(obj(fields));
    }

    obj(vec![
        ("displayTimeUnit", Json::Str("ms".to_string())),
        (
            "otherData",
            obj(vec![
                ("schema", Json::Str("nestedfp/trace@1".to_string())),
                ("dropped", Json::Num(trace.dropped as f64)),
                ("events", Json::Num(trace.events.len() as f64)),
            ]),
        ),
        ("traceEvents", Json::Arr(events)),
    ])
}

/// Serialize and write a recording; returns the recorded event count.
pub fn write_trace(path: &str, trace: &Trace) -> Result<usize> {
    std::fs::write(path, trace_to_json(trace).to_string())
        .map_err(|e| anyhow!("writing trace to {path}: {e}"))?;
    Ok(trace.events.len())
}

/// What [`check_trace`] found in a well-formed trace file.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct TraceCheck {
    /// Non-metadata events.
    pub events: usize,
    /// Completed spans (matched `B`/`E` pairs).
    pub spans: usize,
    pub instants: usize,
    /// Dropped-event count the exporter recorded.
    pub dropped: u64,
}

/// Validate an exported trace: well-formed JSON in our schema, every
/// `B` matched by an `E` on the same `(pid, tid, name, id)` with
/// non-decreasing timestamps, nothing negative-depth. Backs
/// `repro analyze trace <FILE>` and the CI smoke.
pub fn check_trace(text: &str) -> Result<TraceCheck> {
    let root = Json::parse(text).map_err(|e| anyhow!("trace is not valid JSON: {e}"))?;
    let events = root
        .get("traceEvents")
        .and_then(|v| v.as_arr())
        .ok_or_else(|| anyhow!("trace has no traceEvents array"))?;
    let dropped = root
        .get("otherData")
        .and_then(|o| o.get("dropped"))
        .and_then(|d| d.as_f64())
        .unwrap_or(0.0) as u64;

    let mut out = TraceCheck {
        dropped,
        ..TraceCheck::default()
    };
    // open-span stack depth + last begin ts per (pid, tid, name, id)
    let mut open: HashMap<(i64, i64, String, i64), Vec<f64>> = HashMap::new();
    for (i, e) in events.iter().enumerate() {
        let ph = e
            .get("ph")
            .and_then(|p| p.as_str())
            .ok_or_else(|| anyhow!("event {i} has no ph"))?;
        if ph == "M" {
            continue;
        }
        let name = e
            .get("name")
            .and_then(|n| n.as_str())
            .ok_or_else(|| anyhow!("event {i} has no name"))?
            .to_string();
        let ts = e
            .get("ts")
            .and_then(|t| t.as_f64())
            .ok_or_else(|| anyhow!("event {i} ({name}) has no ts"))?;
        let pid = e.get("pid").and_then(|p| p.as_i64()).unwrap_or(0);
        let tid = e.get("tid").and_then(|t| t.as_i64()).unwrap_or(0);
        let id = e
            .get("args")
            .and_then(|a| a.get("id"))
            .and_then(|v| v.as_i64())
            .unwrap_or(0);
        out.events += 1;
        match ph {
            "B" => open.entry((pid, tid, name, id)).or_default().push(ts),
            "E" => {
                let key = (pid, tid, name, id);
                let stack = open.get_mut(&key);
                let Some(begin_ts) = stack.and_then(|s| s.pop()) else {
                    bail!(
                        "event {i}: E without matching B \
                         (pid {pid}, tid {tid}, {} id {id})",
                        key.2
                    );
                };
                if ts + 1e-9 < begin_ts {
                    bail!(
                        "event {i}: span {} ends at {ts} before it began at {begin_ts}",
                        key.2
                    );
                }
                out.spans += 1;
            }
            "i" => out.instants += 1,
            other => bail!("event {i}: unsupported phase {other:?}"),
        }
    }
    let unclosed: usize = open.values().map(|s| s.len()).sum();
    if unclosed > 0 {
        bail!("{unclosed} span(s) never closed");
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::trace::{self, Kind};

    fn sample_trace() -> Trace {
        trace::install(1024);
        trace::begin_run("arm");
        trace::instant(0, Kind::Arrival, 0.25, 7, 0);
        trace::begin(0, Kind::Decode, 0.5, 7, 0);
        trace::instant(trace::CONTROL_TRACK, Kind::Rung, 0.75, 0, 2);
        trace::end(0, Kind::Decode, 1.0, 7, 0);
        trace::take().unwrap()
    }

    #[test]
    fn export_is_deterministic_and_checks_clean() {
        let a = trace_to_json(&sample_trace()).to_string();
        let b = trace_to_json(&sample_trace()).to_string();
        assert_eq!(a, b, "same recording, same bytes");
        let chk = check_trace(&a).unwrap();
        assert_eq!(chk.events, 4);
        assert_eq!(chk.spans, 1);
        assert_eq!(chk.instants, 2);
        assert_eq!(chk.dropped, 0);
        // metadata names both tracks
        assert!(a.contains("replica 0"));
        assert!(a.contains("\"control\""));
        // timestamps are microseconds
        assert!(a.contains("250000"));
    }

    #[test]
    fn checker_rejects_unbalanced_and_reversed_spans() {
        let unbalanced = r#"{"traceEvents":[
            {"ph":"B","name":"decode","ts":1,"pid":0,"tid":0,"args":{"id":1}}
        ]}"#;
        assert!(check_trace(unbalanced).unwrap_err().to_string().contains("never closed"));
        let orphan = r#"{"traceEvents":[
            {"ph":"E","name":"decode","ts":1,"pid":0,"tid":0,"args":{"id":1}}
        ]}"#;
        assert!(check_trace(orphan).unwrap_err().to_string().contains("without matching B"));
        let reversed = r#"{"traceEvents":[
            {"ph":"B","name":"decode","ts":5,"pid":0,"tid":0,"args":{"id":1}},
            {"ph":"E","name":"decode","ts":2,"pid":0,"tid":0,"args":{"id":1}}
        ]}"#;
        assert!(check_trace(reversed).unwrap_err().to_string().contains("before it began"));
        assert!(check_trace("not json").is_err());
        assert!(check_trace("{}").is_err());
    }
}

//! Per-phase wall-time accumulators for the compute kernels.
//!
//! The GEMM and attention engines are multi-threaded (deterministic
//! fork-join pools), so a profiler handle must be shareable across
//! workers and must never perturb results: phases accumulate into
//! relaxed `AtomicU64` nanosecond counters, and a **disabled** handle
//! (the default) skips the clock reads entirely — [`Profiler::start`]
//! returns `None` and [`Profiler::record`] is a no-op, so the hot loops
//! pay one branch.
//!
//! Accumulated time is *CPU seconds summed across workers* (a 4-thread
//! phase running 1 wall second reports ≈4 s); the benches report shares
//! of total, where the distinction cancels out.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

use super::registry::{MergeRule, Registry};

/// Phase names for the GEMM kernel: operand packing (fused NestedFP
/// decode), the MR×NR register microkernel, and the edge-tile reduce /
/// writeback path.
pub const GEMM_PHASES: &[&str] = &["pack", "microkernel", "reduce"];

/// Phase names for the attention engine: block load (fused FP8
/// dequant), QK^T dot products, and online softmax + PV accumulation.
pub const ATTN_PHASES: &[&str] = &["block_load", "dot", "softmax"];

#[derive(Debug)]
struct Inner {
    names: &'static [&'static str],
    nanos: Vec<AtomicU64>,
}

/// A cloneable per-phase timer. Clones share the same accumulators, so
/// handing a clone to each pool worker aggregates into one place.
#[derive(Clone, Debug, Default)]
pub struct Profiler {
    inner: Option<Arc<Inner>>,
}

impl Profiler {
    /// The no-op handle (also `Default`): timing disabled, zero cost.
    pub fn disabled() -> Profiler {
        Profiler::default()
    }

    /// An active profiler over a fixed phase-name set (use
    /// [`GEMM_PHASES`] / [`ATTN_PHASES`]).
    pub fn enabled(names: &'static [&'static str]) -> Profiler {
        Profiler {
            inner: Some(Arc::new(Inner {
                names,
                nanos: names.iter().map(|_| AtomicU64::new(0)).collect(),
            })),
        }
    }

    pub fn is_enabled(&self) -> bool {
        self.inner.is_some()
    }

    /// Begin timing a phase section: `None` (no clock read) when
    /// disabled.
    #[inline]
    pub fn start(&self) -> Option<Instant> {
        if self.inner.is_some() {
            Some(Instant::now())
        } else {
            None
        }
    }

    /// Charge the elapsed time since `start` to `phase`. A `None`
    /// token (disabled profiler) is a no-op.
    #[inline]
    pub fn record(&self, phase: usize, t0: Option<Instant>) {
        if let (Some(inner), Some(t0)) = (self.inner.as_ref(), t0) {
            inner.nanos[phase].fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        }
    }

    /// Accumulated seconds for one phase (0.0 when disabled).
    pub fn seconds(&self, phase: usize) -> f64 {
        self.inner
            .as_ref()
            .map(|i| i.nanos[phase].load(Ordering::Relaxed) as f64 * 1e-9)
            .unwrap_or(0.0)
    }

    /// Sum over all phases.
    pub fn total_seconds(&self) -> f64 {
        (0..self.phase_count()).map(|p| self.seconds(p)).sum()
    }

    pub fn phase_count(&self) -> usize {
        self.inner.as_ref().map(|i| i.names.len()).unwrap_or(0)
    }

    pub fn phase_name(&self, phase: usize) -> &'static str {
        self.inner.as_ref().map_or("", |i| i.names[phase])
    }

    /// Zero all accumulators (between bench arms).
    pub fn reset(&self) {
        if let Some(i) = self.inner.as_ref() {
            for n in &i.nanos {
                n.store(0, Ordering::Relaxed);
            }
        }
    }

    /// Fold the phase totals into a registry as summed float seconds
    /// (`<prefix>.<phase>_s`).
    pub fn register_into(&self, r: &mut Registry, prefix: &str) {
        if let Some(i) = self.inner.as_ref() {
            for (p, name) in i.names.iter().enumerate() {
                r.set_float(&format!("{prefix}.{name}_s"), MergeRule::Sum, self.seconds(p));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_is_free_and_reports_zero() {
        let p = Profiler::disabled();
        assert!(!p.is_enabled());
        assert!(p.start().is_none());
        p.record(0, None);
        assert_eq!(p.phase_count(), 0);
        assert_eq!(p.total_seconds(), 0.0);
        let mut r = Registry::new();
        p.register_into(&mut r, "gemm");
        assert!(r.is_empty());
    }

    #[test]
    fn clones_share_accumulators_across_threads() {
        let p = Profiler::enabled(GEMM_PHASES);
        let q = p.clone();
        std::thread::scope(|s| {
            s.spawn(|| {
                let t0 = q.start();
                std::thread::sleep(std::time::Duration::from_millis(2));
                q.record(1, t0);
            });
            let t0 = p.start();
            std::thread::sleep(std::time::Duration::from_millis(2));
            p.record(1, t0);
        });
        assert!(p.seconds(1) >= 0.004 - 1e-3);
        assert_eq!(p.seconds(0), 0.0);
        let mut r = Registry::new();
        p.register_into(&mut r, "gemm");
        assert!(r.float("gemm.microkernel_s") > 0.0);
        assert_eq!(r.len(), GEMM_PHASES.len());
        p.reset();
        assert_eq!(p.total_seconds(), 0.0);
    }
}

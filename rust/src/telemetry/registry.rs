//! The typed counter/gauge registry with deterministic merge.
//!
//! Before this module, cross-replica aggregation was a hand-written
//! field-by-field `Metrics::merge`: every new counter meant touching the
//! struct, the merge function, and the JSON dump, and nothing checked
//! that the three agreed. Here a metric is one named [`Entry`] carrying
//! its own [`MergeRule`], so the merge law and the export are derived
//! from a single registration point:
//!
//! * **Sum** — event counts, accumulated seconds, byte totals.
//! * **Max** — peaks (utilization, live sequences) and end timestamps.
//! * **Min** — start timestamps.
//!
//! Entries live in a `BTreeMap`, so iteration, merge, and the JSON dump
//! are deterministic regardless of registration order. Merging is
//! commutative and associative for `Max`/`Min` and integer `Sum`;
//! float `Sum` is summed in name order, which is fixed, so merging the
//! same set of registries always produces bit-identical results.
//!
//! Subsystems expose a `register_into(&self, r, prefix)` method (see
//! `KvCacheStats`, `EventStats`, `Resharder`, [`super::Profiler`]);
//! benches fold those into the thread-local [`with_global`] registry,
//! which `repro reproduce --json` dumps as a flat `counters` object in
//! the `nestedfp/bench-reports@1` report.

use std::cell::RefCell;
use std::collections::BTreeMap;

use crate::util::json::Json;

/// How two values of the same metric combine across replicas/runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MergeRule {
    Sum,
    Max,
    Min,
}

/// A metric value: integer counters stay exact; gauges/seconds are f64.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Value {
    Int(u64),
    Float(f64),
}

impl Value {
    pub fn as_f64(self) -> f64 {
        match self {
            Value::Int(v) => v as f64,
            Value::Float(v) => v,
        }
    }

    fn combine(self, other: Value, rule: MergeRule) -> Value {
        match (self, other) {
            (Value::Int(a), Value::Int(b)) => Value::Int(match rule {
                MergeRule::Sum => a + b,
                MergeRule::Max => a.max(b),
                MergeRule::Min => a.min(b),
            }),
            (a, b) => {
                let (a, b) = (a.as_f64(), b.as_f64());
                Value::Float(match rule {
                    MergeRule::Sum => a + b,
                    MergeRule::Max => a.max(b),
                    MergeRule::Min => a.min(b),
                })
            }
        }
    }
}

/// One registered metric: its merge rule and current value.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Entry {
    pub rule: MergeRule,
    pub value: Value,
}

/// The registry itself — a deterministic name → entry map.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Registry {
    entries: BTreeMap<String, Entry>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// Register (or overwrite) an integer metric.
    pub fn set_int(&mut self, name: &str, rule: MergeRule, v: u64) {
        self.entries.insert(
            name.to_string(),
            Entry {
                rule,
                value: Value::Int(v),
            },
        );
    }

    /// Register (or overwrite) a float metric.
    pub fn set_float(&mut self, name: &str, rule: MergeRule, v: f64) {
        self.entries.insert(
            name.to_string(),
            Entry {
                rule,
                value: Value::Float(v),
            },
        );
    }

    /// Fold `v` into an existing metric under its own rule, registering
    /// it as a `Sum` counter if absent.
    pub fn add_int(&mut self, name: &str, v: u64) {
        match self.entries.get_mut(name) {
            Some(e) => e.value = e.value.combine(Value::Int(v), e.rule),
            None => self.set_int(name, MergeRule::Sum, v),
        }
    }

    /// Current integer value (0 when absent; floats truncate).
    pub fn int(&self, name: &str) -> u64 {
        match self.entries.get(name).map(|e| e.value) {
            Some(Value::Int(v)) => v,
            Some(Value::Float(v)) => v as u64,
            None => 0,
        }
    }

    /// Current value as f64 (0.0 when absent).
    pub fn float(&self, name: &str) -> f64 {
        self.entries.get(name).map(|e| e.value.as_f64()).unwrap_or(0.0)
    }

    pub fn get(&self, name: &str) -> Option<Entry> {
        self.entries.get(name).copied()
    }

    /// Deterministic (name-ordered) iteration.
    pub fn iter(&self) -> impl Iterator<Item = (&str, Entry)> {
        self.entries.iter().map(|(k, v)| (k.as_str(), *v))
    }

    /// Merge `other` into `self`, entry by entry, under each entry's
    /// rule. Names only one side knows are carried over unchanged. The
    /// same name must be registered with the same rule everywhere —
    /// a mismatch is a registration bug (debug-asserted).
    pub fn merge(&mut self, other: &Registry) {
        for (name, e) in &other.entries {
            match self.entries.get_mut(name) {
                Some(mine) => {
                    debug_assert_eq!(
                        mine.rule, e.rule,
                        "metric {name} registered with conflicting merge rules"
                    );
                    mine.value = mine.value.combine(e.value, mine.rule);
                }
                None => {
                    self.entries.insert(name.clone(), *e);
                }
            }
        }
    }

    /// Flat JSON object (name → number), deterministic order.
    /// Non-finite floats (e.g. an unmerged `Min`-rule start time still
    /// at +inf) serialize as `null` — JSON has no infinity literal.
    pub fn to_json(&self) -> Json {
        Json::Obj(
            self.entries
                .iter()
                .map(|(k, e)| {
                    let v = match e.value {
                        Value::Int(v) => Json::Num(v as f64),
                        Value::Float(v) if v.is_finite() => Json::Num(v),
                        Value::Float(_) => Json::Null,
                    };
                    (k.clone(), v)
                })
                .collect(),
        )
    }
}

thread_local! {
    static GLOBAL: RefCell<Registry> = RefCell::new(Registry::new());
}

/// Run `f` against this thread's global registry — the one bench runs
/// fold their counters into and `--json` dumps.
pub fn with_global<R>(f: impl FnOnce(&mut Registry) -> R) -> R {
    GLOBAL.with(|g| f(&mut g.borrow_mut()))
}

/// Snapshot the global registry.
pub fn global_snapshot() -> Registry {
    GLOBAL.with(|g| g.borrow().clone())
}

/// Clear the global registry (start of a `repro reproduce` invocation).
pub fn reset_global() {
    GLOBAL.with(|g| *g.borrow_mut() = Registry::new());
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rules_merge_as_documented() {
        let mut a = Registry::new();
        a.set_int("events", MergeRule::Sum, 3);
        a.set_float("peak", MergeRule::Max, 0.5);
        a.set_float("t_start", MergeRule::Min, 10.0);
        let mut b = Registry::new();
        b.set_int("events", MergeRule::Sum, 4);
        b.set_float("peak", MergeRule::Max, 0.25);
        b.set_float("t_start", MergeRule::Min, 7.0);
        b.set_int("only_b", MergeRule::Sum, 9);
        a.merge(&b);
        assert_eq!(a.int("events"), 7);
        assert_eq!(a.float("peak"), 0.5);
        assert_eq!(a.float("t_start"), 7.0);
        assert_eq!(a.int("only_b"), 9, "one-sided names carry over");
    }

    #[test]
    fn merge_is_deterministic_and_order_independent_for_ints() {
        let regs: Vec<Registry> = (0..10)
            .map(|i| {
                let mut r = Registry::new();
                r.set_int("n", MergeRule::Sum, i);
                r.set_int("hi", MergeRule::Max, 100 - i);
                r
            })
            .collect();
        let fold = |order: Vec<usize>| {
            let mut acc = Registry::new();
            for i in order {
                acc.merge(&regs[i]);
            }
            acc
        };
        let fwd = fold((0..10).collect());
        let rev = fold((0..10).rev().collect());
        assert_eq!(fwd, rev);
        assert_eq!(fwd.int("n"), 45);
        assert_eq!(fwd.int("hi"), 100);
    }

    #[test]
    fn add_int_registers_then_accumulates() {
        let mut r = Registry::new();
        r.add_int("c", 2);
        r.add_int("c", 3);
        assert_eq!(r.int("c"), 5);
        assert_eq!(r.get("c").unwrap().rule, MergeRule::Sum);
    }

    #[test]
    fn json_dump_is_name_ordered() {
        let mut r = Registry::new();
        r.set_int("zz", MergeRule::Sum, 1);
        r.set_int("aa", MergeRule::Sum, 2);
        let s = r.to_json().to_string();
        assert!(s.find("aa").unwrap() < s.find("zz").unwrap());
    }

    #[test]
    fn global_registry_folds_and_resets() {
        reset_global();
        with_global(|r| r.add_int("g", 1));
        with_global(|r| r.add_int("g", 1));
        assert_eq!(global_snapshot().int("g"), 2);
        reset_global();
        assert!(global_snapshot().is_empty());
    }
}

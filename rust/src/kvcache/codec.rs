//! FP8 block codec: one E4M3 byte per element with a per-block absmax
//! scale, per plane (K and V are scaled independently).
//!
//! A demoted block stores at half the f32-block bytes. Dequantized reads
//! carry the documented error bound below; the bench
//! (`repro reproduce kvcache`) and the tests here pin it.
//!
//! # Error bound
//!
//! With `s = absmax / 448` and `y = x / s`, every finite `y` lands in
//! E4M3's representable range, so per element:
//!
//! * normal targets (`|y| >= 2^-6`): relative error `<= 2^-4` (half ulp of
//!   the 3-bit mantissa — the same bound `format::e4m3` tests), and
//! * subnormal targets: absolute error `<= s * 2^-10` (half the subnormal
//!   quantum `2^-9`, times the scale).
//!
//! Combined: `|decode(encode(x)) - x| <= max(|x| / 16, absmax * 2^-10 / 448)`.

use crate::format::e4m3;

/// Encode a block plane to E4M3 bytes; returns `(bytes, scale)` with
/// `scale = absmax / 448` (1.0 for an all-zero block so decode is exact).
pub fn encode_block(x: &[f32]) -> (Vec<u8>, f32) {
    let absmax = x.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
    let scale = if absmax > 0.0 && absmax.is_finite() {
        absmax / e4m3::E4M3_MAX
    } else {
        1.0
    };
    let inv = 1.0 / scale;
    (x.iter().map(|&v| e4m3::encode_sat(v * inv)).collect(), scale)
}

/// Decode E4M3 bytes back to f32 into `out` (lengths must match).
pub fn decode_block(bytes: &[u8], scale: f32, out: &mut [f32]) {
    debug_assert_eq!(bytes.len(), out.len(), "codec plane length");
    for (o, &b) in out.iter_mut().zip(bytes) {
        *o = e4m3::decode(b) * scale;
    }
}

/// The documented per-element roundtrip error bound (see module docs).
pub fn error_bound(x: f32, absmax: f32) -> f32 {
    let rel = x.abs() / 16.0;
    let abs_floor = absmax / e4m3::E4M3_MAX * f32::powi(2.0, -10);
    rel.max(abs_floor)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn roundtrip(x: &[f32]) -> Vec<f32> {
        let (bytes, scale) = encode_block(x);
        assert_eq!(bytes.len(), x.len(), "one byte per element");
        let mut out = vec![0.0f32; x.len()];
        decode_block(&bytes, scale, &mut out);
        out
    }

    #[test]
    fn zero_block_roundtrips_exactly() {
        let x = vec![0.0f32; 64];
        assert_eq!(roundtrip(&x), x);
    }

    #[test]
    fn absmax_element_survives_nearly_exactly() {
        // the absmax element maps to exactly ±448, so it decodes back to
        // absmax up to one f32 multiply rounding
        for absmax in [1e-3f32, 0.7, 3.0, 1e4] {
            let x = vec![0.1 * absmax, -absmax, 0.5 * absmax];
            let out = roundtrip(&x);
            let rel = ((out[1] + absmax) / absmax).abs();
            assert!(rel < 1e-6, "absmax {absmax}: got {} rel {rel}", out[1]);
        }
    }

    #[test]
    fn roundtrip_error_within_documented_bound() {
        let mut rng = Pcg64::seeded(4242);
        for scale in [1e-3f64, 1.0, 300.0] {
            for _ in 0..50 {
                let x: Vec<f32> =
                    (0..256).map(|_| (rng.normal() * scale) as f32).collect();
                let absmax = x.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
                let out = roundtrip(&x);
                for (&xi, &oi) in x.iter().zip(&out) {
                    let err = (oi - xi).abs();
                    // small slop for the scale multiply's own rounding
                    let bound = error_bound(xi, absmax) * (1.0 + 1e-5) + 1e-30;
                    assert!(
                        err <= bound,
                        "x={xi} decoded {oi}: err {err} > bound {bound} (absmax {absmax})"
                    );
                }
            }
        }
    }

    #[test]
    fn mixed_sign_block_keeps_signs() {
        let x = vec![-2.0f32, 2.0, -0.5, 0.5];
        let out = roundtrip(&x);
        for (&xi, &oi) in x.iter().zip(&out) {
            assert_eq!(xi.signum(), oi.signum(), "{xi} -> {oi}");
        }
    }
}

//! [`PagedKvCache`] — the engine-facing paged dual-precision KV manager.
//!
//! Replaces the seed's dense slot store (`coordinator::kv`): sequences own
//! block tables over a shared [`BlockPool`](super::block), admission is
//! gated only by the device block budget (no slot cap), cold blocks demote
//! to FP8 under precision pressure, and whole sequences can be preempted
//! to the host tier with transfer latency charged on the virtual clock.
//!
//! Write-path invariant: the scheduler only scatters into the tail of a
//! live sequence, and demotion never touches the last
//! `hot_tail_blocks` blocks of a sequence's written frontier — so scatters
//! always land in f32-resident blocks. Gathers dequantize FP8 blocks on
//! the fly (the approximation cost of demotion); offloaded sequences are
//! never scheduled, so gathers never see host blocks.

use anyhow::{bail, Result};

use super::block::{BlockId, BlockPool, BlockPrecision, UNITS_F32};
use super::codec;
use super::offload::HostTier;
use super::policy::{AdmissionMode, KvPressureConfig};
use super::KvGeometry;

/// Cumulative cache statistics (engine metrics mirror these).
#[derive(Clone, Copy, Debug, Default)]
pub struct KvCacheStats {
    /// Blocks demoted to FP8 over the run.
    pub demoted_blocks: usize,
    /// Sequence offload events (device → host).
    pub offload_events: usize,
    /// Blocks moved to the host tier over the run.
    pub offloaded_blocks: usize,
    /// Sequence fetch events (host → device).
    pub fetch_events: usize,
    /// Virtual-clock seconds charged for host transfers.
    pub transfer_seconds: f64,
    /// Peak concurrently live sequences — the admission-capacity signal
    /// the `kvcache` bench compares across policies.
    pub peak_live_seqs: usize,
    /// Peak device-unit utilization in [0, 1].
    pub peak_utilization: f64,
}

impl KvCacheStats {
    /// Declare these counters in a telemetry registry under `prefix`
    /// (sums for the cumulative counters, max for the peaks — the same
    /// rules `coordinator::Metrics` merges them under).
    pub fn register_into(&self, r: &mut crate::telemetry::Registry, prefix: &str) {
        use crate::telemetry::registry::MergeRule::{Max, Sum};
        r.set_int(&format!("{prefix}.demoted_blocks"), Sum, self.demoted_blocks as u64);
        r.set_int(&format!("{prefix}.offload_events"), Sum, self.offload_events as u64);
        r.set_int(&format!("{prefix}.offloaded_blocks"), Sum, self.offloaded_blocks as u64);
        r.set_int(&format!("{prefix}.fetch_events"), Sum, self.fetch_events as u64);
        r.set_float(&format!("{prefix}.transfer_s"), Sum, self.transfer_seconds);
        r.set_int(&format!("{prefix}.peak_live_seqs"), Max, self.peak_live_seqs as u64);
        r.set_float(&format!("{prefix}.peak_utilization"), Max, self.peak_utilization);
    }
}

/// Borrowed view of one block's stored K/V payload — what the
/// block-native attention engine ([`crate::attn`]) reads in place,
/// fusing FP8 dequantization into the block load instead of gathering.
pub enum BlockKv<'a> {
    /// Accounting-only pool (or released block): no payload; readers
    /// treat the contents as zeros, exactly like the dense gather does.
    Acct,
    /// Full-precision payload, plane layout `[L, H, block_size, Dh]`.
    F32 { k: &'a [f32], v: &'a [f32] },
    /// FP8-demoted payload: one E4M3 byte per element plus the
    /// per-block absmax scale per plane (`value = decode(byte) * scale`,
    /// the [`codec`] law).
    Fp8 {
        k: &'a [u8],
        v: &'a [u8],
        scale_k: f32,
        scale_v: f32,
    },
}

struct Seq {
    table: Vec<BlockId>,
    /// Valid context length, tokens.
    len: usize,
    /// LRU stamp (monotone logical clock; bumped on scatter/gather/grow).
    last_touch: u64,
    /// All blocks on the host tier (sequence preempted).
    offloaded: bool,
}

/// The paged KV cache.
pub struct PagedKvCache {
    pub geo: KvGeometry,
    policy: KvPressureConfig,
    physical: bool,
    pool: BlockPool,
    seqs: Vec<Option<Seq>>,
    host: HostTier,
    clock: u64,
    /// Fraction of the model's layers currently demoted to FP8 (0.0 =
    /// all-FP16, 1.0 = all-FP8) — drives the elastic demotion watermark.
    demoted_frac: f64,
    stats: KvCacheStats,
    live: usize,
}

impl PagedKvCache {
    /// Physical cache: blocks carry real K/V payloads (the PJRT backend).
    pub fn new(geo: KvGeometry, policy: KvPressureConfig) -> PagedKvCache {
        Self::build(geo, policy, true)
    }

    /// Accounting-only cache for the simulation backend: block tables and
    /// budget math without payloads (demotion/offload still account).
    pub fn accounting_only(geo: KvGeometry, policy: KvPressureConfig) -> PagedKvCache {
        Self::build(geo, policy, false)
    }

    fn build(geo: KvGeometry, policy: KvPressureConfig, physical: bool) -> PagedKvCache {
        policy.validate();
        PagedKvCache {
            pool: BlockPool::new(geo.total_blocks, geo.block_elems(), physical),
            host: HostTier::new(policy.host_bw_gbps, policy.transfer_base_s),
            geo,
            policy,
            physical,
            seqs: Vec::new(),
            clock: 0,
            demoted_frac: 0.0,
            stats: KvCacheStats::default(),
            live: 0,
        }
    }

    // ---- introspection ----------------------------------------------

    pub fn policy(&self) -> &KvPressureConfig {
        &self.policy
    }

    pub fn stats(&self) -> KvCacheStats {
        self.stats
    }

    /// Free budget expressed in f32-equivalent blocks (router signal).
    pub fn free_blocks(&self) -> usize {
        self.pool.free_units() / UNITS_F32
    }

    /// Free budget in raw half-block units (admission math).
    pub fn free_units(&self) -> usize {
        self.pool.free_units()
    }

    /// Units an admission reserving `len` tokens must find free.
    pub fn admit_units(&self, len: usize) -> usize {
        (self.geo.blocks_for(len) + 1) * UNITS_F32
    }

    /// Device units one (non-offloaded) sequence currently occupies —
    /// what preempting it to the host tier would free.
    pub fn seq_device_units(&self, seq: usize) -> usize {
        self.seq(seq)
            .table
            .iter()
            .map(|&id| self.pool.blocks[id as usize].units())
            .sum()
    }

    /// Device-unit utilization in [0,1] — the precision-pressure signal.
    pub fn block_utilization(&self) -> f64 {
        self.pool.utilization()
    }

    /// Currently live (allocated, device- or host-resident) sequences.
    pub fn live_seqs(&self) -> usize {
        self.live
    }

    /// Device blocks currently stored demoted to FP8.
    pub fn fp8_blocks(&self) -> usize {
        self.pool.fp8_device_blocks()
    }

    /// Blocks currently on the host tier.
    pub fn host_blocks(&self) -> usize {
        self.pool.host_blocks()
    }

    /// Blocks held by one sequence.
    pub fn seq_blocks(&self, seq: usize) -> usize {
        self.seq(seq).table.len()
    }

    /// Valid context length of one sequence.
    pub fn seq_len(&self, seq: usize) -> usize {
        self.seq(seq).len
    }

    /// FP8-demoted device blocks held by one sequence.
    pub fn seq_fp8_blocks(&self, seq: usize) -> usize {
        self.seq(seq)
            .table
            .iter()
            .filter(|&&id| {
                let b = &self.pool.blocks[id as usize];
                !b.on_host && b.precision == BlockPrecision::Fp8
            })
            .count()
    }

    pub fn is_offloaded(&self, seq: usize) -> bool {
        self.seq(seq).offloaded
    }

    /// Borrow block `bi` of `seq`'s table for an in-place read. Panics
    /// on host-resident blocks — device-scheduled sequences never read
    /// the host plane, the same contract [`Self::gather_seq`] asserts.
    /// Host attention piggybacking reads through
    /// [`Self::seq_block_kv_any_tier`] instead.
    pub fn seq_block_kv(&self, seq: usize, bi: usize) -> BlockKv<'_> {
        let id = self.seq(seq).table[bi];
        let b = &self.pool.blocks[id as usize];
        assert!(
            !b.on_host,
            "block-native read of host block (seq {seq}, block {bi})"
        );
        self.block_kv_of(b)
    }

    /// Borrow block `bi` of `seq`'s table for an in-place read on
    /// **either tier**. Host-resident payloads stay byte-identical to
    /// their device form (offload moves accounting, not contents), so a
    /// host-side attention walk reads the same values a resumed device
    /// walk would — the piggybacking correctness contract.
    pub fn seq_block_kv_any_tier(&self, seq: usize, bi: usize) -> BlockKv<'_> {
        let id = self.seq(seq).table[bi];
        self.block_kv_of(&self.pool.blocks[id as usize])
    }

    fn block_kv_of<'a>(&self, b: &'a super::block::Block) -> BlockKv<'a> {
        match &b.payload {
            super::block::BlockPayload::Acct => BlockKv::Acct,
            super::block::BlockPayload::F32 { k, v } => BlockKv::F32 { k, v },
            super::block::BlockPayload::Fp8 {
                k,
                v,
                scale_k,
                scale_v,
            } => BlockKv::Fp8 {
                k,
                v,
                scale_k: *scale_k,
                scale_v: *scale_v,
            },
        }
    }

    /// Bump `seq`'s LRU stamp for a block-native read. Gathers and
    /// scatters touch implicitly; in-place readers borrow `&self` (they
    /// run under the fork-join pool) and call this beforehand instead.
    pub fn touch_read(&mut self, seq: usize) {
        self.touch(seq);
    }

    /// KV bytes one attention layer's block walk streams for the first
    /// `tokens` positions of `seq`: the per-layer share of the covering
    /// blocks' K+V bytes at their **stored** precision (an FP8 block
    /// counts roughly half an f32 block). A full step over all layers
    /// touches `n_layers ×` this; compare
    /// [`KvGeometry::layer_dense_bytes`](super::KvGeometry::layer_dense_bytes),
    /// the dense gather's per-layer cost, which scales with `max_seq`
    /// instead of the live context.
    pub fn seq_touched_bytes(&self, seq: usize, tokens: usize) -> usize {
        let g = self.geo;
        let per_layer = g.block_size * g.n_heads * g.head_dim;
        let s = self.seq(seq);
        let n = g.blocks_for(tokens).min(s.table.len());
        let mut bytes = 0usize;
        for &id in &s.table[..n] {
            bytes += match self.pool.blocks[id as usize].precision {
                BlockPrecision::F32 => per_layer * 4 * 2,
                // two u8 planes + the two f32 scales
                BlockPrecision::Fp8 => per_layer * 2 + 8,
            };
        }
        bytes
    }

    fn seq(&self, i: usize) -> &Seq {
        self.seqs[i].as_ref().expect("dead kv sequence handle")
    }

    fn seq_mut(&mut self, i: usize) -> &mut Seq {
        self.seqs[i].as_mut().expect("dead kv sequence handle")
    }

    fn touch(&mut self, seq: usize) {
        self.clock += 1;
        let t = self.clock;
        self.seq_mut(seq).last_touch = t;
    }

    fn note_utilization(&mut self) {
        let u = self.pool.utilization();
        if u > self.stats.peak_utilization {
            self.stats.peak_utilization = u;
        }
    }

    /// Bytes one block occupies at `precision` (K + V planes).
    fn block_bytes(&self, precision: BlockPrecision) -> usize {
        match precision {
            BlockPrecision::F32 => self.geo.block_elems() * 4 * 2,
            // two u8 planes + two f32 scales
            BlockPrecision::Fp8 => self.geo.block_elems() * 2 + 8,
        }
    }

    // ---- admission / lifecycle --------------------------------------

    /// The reservation length admission uses for a request, per the
    /// configured [`AdmissionMode`].
    pub fn admit_len(&self, prompt_len: usize, max_new_tokens: usize) -> usize {
        match self.policy.admission {
            AdmissionMode::Reserve => (prompt_len + max_new_tokens).min(self.geo.max_seq),
            AdmissionMode::Paged => prompt_len.min(self.geo.max_seq),
        }
    }

    /// Can a sequence reserving `len` tokens (+1 headroom block) be
    /// admitted right now, from real free-block counts alone?
    pub fn can_admit(&self, len: usize) -> bool {
        self.pool.free_units() >= self.admit_units(len)
    }

    /// Allocate a sequence reserving `reserve_len` tokens of f32 blocks
    /// plus one headroom block; returns the sequence handle.
    pub fn allocate(&mut self, reserve_len: usize) -> Result<usize> {
        if !self.can_admit(reserve_len) {
            bail!(
                "kv exhausted: {} free blocks, {} needed",
                self.free_blocks(),
                self.geo.blocks_for(reserve_len) + 1
            );
        }
        let n = self.geo.blocks_for(reserve_len) + 1;
        let mut table = Vec::with_capacity(n);
        for _ in 0..n {
            table.push(self.pool.alloc().expect("can_admit checked the budget"));
        }
        self.clock += 1;
        let entry = Seq {
            table,
            len: 0,
            last_touch: self.clock,
            offloaded: false,
        };
        let idx = match self.seqs.iter().position(|s| s.is_none()) {
            Some(i) => {
                self.seqs[i] = Some(entry);
                i
            }
            None => {
                self.seqs.push(Some(entry));
                self.seqs.len() - 1
            }
        };
        self.live += 1;
        if self.live > self.stats.peak_live_seqs {
            self.stats.peak_live_seqs = self.live;
        }
        self.note_utilization();
        Ok(idx)
    }

    /// Grow a sequence's block table to cover `new_len` tokens. Under
    /// pressure this demotes cold blocks first; it fails only when even
    /// demotion cannot free enough budget (the engine then preempts a
    /// sequence to the host tier).
    pub fn grow(&mut self, seq: usize, new_len: usize) -> Result<()> {
        if new_len > self.geo.max_seq {
            bail!(
                "sequence length {new_len} exceeds max_seq {}",
                self.geo.max_seq
            );
        }
        if self.seq(seq).offloaded {
            bail!("grow on offloaded seq {seq}");
        }
        let need = self.geo.blocks_for(new_len);
        let have = self.seq(seq).table.len();
        if need > have {
            let extra = need - have;
            if self.pool.free_units() < extra * UNITS_F32 {
                self.demote_until_units(extra * UNITS_F32);
            }
            if self.pool.free_units() < extra * UNITS_F32 {
                bail!("kv block budget exhausted growing seq {seq}");
            }
            for _ in 0..extra {
                let id = self.pool.alloc().expect("checked above");
                self.seq_mut(seq).table.push(id);
            }
        }
        self.seq_mut(seq).len = new_len;
        self.touch(seq);
        self.note_utilization();
        Ok(())
    }

    /// Release a sequence and all its blocks (device and host).
    pub fn release(&mut self, seq: usize) {
        let s = self.seqs[seq].take().expect("releasing free seq");
        self.live -= 1;
        let mut host_blocks = 0usize;
        let mut host_bytes = 0usize;
        for id in s.table {
            let (on_host, prec) = {
                let b = &self.pool.blocks[id as usize];
                (b.on_host, b.precision)
            };
            if on_host {
                host_blocks += 1;
                host_bytes += self.block_bytes(prec);
            }
            self.pool.release(id);
        }
        if host_blocks > 0 {
            self.host.discard(host_blocks, host_bytes);
        }
    }

    // ---- demotion (precision pressure) ------------------------------

    /// Couple the cache to the engine's precision controller: FP8
    /// iterations tighten the demotion watermark. The legacy binary
    /// view — a shim over [`Self::set_demoted_layer_fraction`]'s
    /// endpoints.
    pub fn set_precision_pressure(&mut self, fp8: bool) {
        self.set_demoted_layer_fraction(if fp8 { 1.0 } else { 0.0 });
    }

    /// Couple the cache to a per-layer precision schedule: the demotion
    /// watermark tightens with the fraction of the model's layers
    /// currently demoted to FP8 (elastic KV resizing per MorphServe).
    /// `0.0` and `1.0` reproduce the legacy binary pressure flag.
    pub fn set_demoted_layer_fraction(&mut self, frac: f64) {
        self.demoted_frac = frac.clamp(0.0, 1.0);
    }

    /// Eligible demotion targets, coldest first: LRU by sequence touch,
    /// then lowest block index (oldest context first). The write frontier
    /// (`hot_tail_blocks`, minimum 1) is never eligible, so scatters stay
    /// f32-safe; reserved-but-unwritten blocks sit beyond the frontier
    /// and are likewise excluded.
    fn demote_candidates(&self) -> Vec<(u64, usize, usize)> {
        let hot_tail = self.policy.hot_tail_blocks.max(1);
        let mut out = Vec::new();
        for (si, s) in self.seqs.iter().enumerate() {
            let Some(s) = s else { continue };
            if s.offloaded {
                continue;
            }
            let frontier = self.geo.blocks_for(s.len);
            for (bi, &id) in s.table.iter().enumerate() {
                if bi + hot_tail >= frontier {
                    break;
                }
                let b = &self.pool.blocks[id as usize];
                if b.on_host || b.precision != BlockPrecision::F32 {
                    continue;
                }
                out.push((s.last_touch, si, bi));
            }
        }
        out.sort_unstable();
        out
    }

    fn demote_until_units(&mut self, want_free: usize) {
        if !self.policy.demote_enabled || self.pool.free_units() >= want_free {
            return;
        }
        for (_, si, bi) in self.demote_candidates() {
            if self.pool.free_units() >= want_free {
                break;
            }
            let id = self.seq(si).table[bi];
            self.pool.demote(id);
            self.stats.demoted_blocks += 1;
        }
    }

    /// Watermark maintenance, called once per engine iteration: demote
    /// LRU-cold blocks until utilization falls to the active watermark.
    /// Returns the number of blocks demoted.
    pub fn maintain(&mut self) -> usize {
        if !self.policy.demote_enabled {
            return 0;
        }
        let w = self.policy.watermark_at(self.demoted_frac);
        if self.pool.utilization() <= w {
            return 0;
        }
        let target_used = (w * self.pool.total_units() as f64).floor() as usize;
        let before = self.stats.demoted_blocks;
        for (_, si, bi) in self.demote_candidates() {
            if self.pool.used_units() <= target_used {
                break;
            }
            let id = self.seq(si).table[bi];
            self.pool.demote(id);
            self.stats.demoted_blocks += 1;
        }
        self.stats.demoted_blocks - before
    }

    /// Admission relief: demote cold blocks (watermark-independent) until
    /// a reservation of `len` tokens fits. Returns whether it now fits.
    pub fn relieve_for_admit(&mut self, len: usize) -> bool {
        if self.can_admit(len) {
            return true;
        }
        let needed = self.admit_units(len);
        self.demote_until_units(needed);
        self.can_admit(len)
    }

    // ---- host tier --------------------------------------------------

    /// Would a fetch of this offloaded sequence fit right now? Includes
    /// one f32 block of headroom so the first post-resume grow cannot
    /// immediately strand it (waived when the sequence alone fills the
    /// budget), plus the policy's resume margin
    /// (`resume_headroom_mult ×` the stored units) so a resume under
    /// sustained pressure does not ping-pong straight back to the host
    /// — the anti-thrash rule. The margin is likewise waived when it
    /// could never be met (it would otherwise strand big sequences on
    /// the host forever).
    pub fn can_fetch(&self, seq: usize) -> bool {
        let s = self.seq(seq);
        if !s.offloaded {
            return true;
        }
        let units = self.stored_units(seq);
        let headroom = if units + UNITS_F32 <= self.pool.total_units() {
            UNITS_F32
        } else {
            0
        };
        let margin = (units as f64 * self.policy.resume_headroom_mult).ceil() as usize;
        let want = if units + headroom + margin <= self.pool.total_units() {
            units + headroom + margin
        } else {
            units + headroom
        };
        self.pool.free_units() >= want
    }

    /// The transfer bill a resume of this offloaded sequence would pay
    /// right now (the cost host-piggybacked decode *avoids* when the
    /// sequence finishes without ever fetching back).
    pub fn resume_transfer_estimate(&self, seq: usize) -> f64 {
        let s = self.seq(seq);
        if !s.offloaded {
            return 0.0;
        }
        let bytes: usize = s
            .table
            .iter()
            .map(|&id| self.block_bytes(self.pool.blocks[id as usize].precision))
            .sum();
        self.host.transfer_seconds(bytes)
    }

    /// Device units this sequence's blocks occupy at their stored
    /// precision (what a fetch must charge).
    fn stored_units(&self, seq: usize) -> usize {
        self.seq(seq)
            .table
            .iter()
            .map(|&id| match self.pool.blocks[id as usize].precision {
                BlockPrecision::F32 => UNITS_F32,
                BlockPrecision::Fp8 => 1,
            })
            .sum()
    }

    /// Preempt a whole sequence to the host tier. Frees its device units
    /// and returns the transfer seconds to charge on the virtual clock.
    pub fn offload_sequence(&mut self, seq: usize) -> Result<f64> {
        if !self.policy.offload_enabled {
            bail!("host offload tier disabled");
        }
        if self.seq(seq).offloaded {
            bail!("seq {seq} already offloaded");
        }
        self.seq_mut(seq).offloaded = true;
        let table = self.seq(seq).table.clone();
        let mut bytes = 0usize;
        for &id in &table {
            bytes += self.block_bytes(self.pool.blocks[id as usize].precision);
            self.pool.set_host(id, true);
        }
        let dt = self.host.deposit(table.len(), bytes);
        self.stats.offload_events += 1;
        self.stats.offloaded_blocks += table.len();
        self.stats.transfer_seconds += dt;
        Ok(dt)
    }

    /// Bring an offloaded sequence back to the device (demoting cold
    /// blocks if that is what it takes). Returns the transfer seconds.
    pub fn fetch_sequence(&mut self, seq: usize) -> Result<f64> {
        if !self.seq(seq).offloaded {
            bail!("seq {seq} is not offloaded");
        }
        let needed = self.stored_units(seq);
        if self.pool.free_units() < needed {
            self.demote_until_units(needed);
        }
        if self.pool.free_units() < needed {
            bail!("no device room to fetch seq {seq} back from the host tier");
        }
        let table = self.seq(seq).table.clone();
        let mut bytes = 0usize;
        for &id in &table {
            bytes += self.block_bytes(self.pool.blocks[id as usize].precision);
            self.pool.set_host(id, false);
        }
        self.seq_mut(seq).offloaded = false;
        self.touch(seq);
        let dt = self.host.withdraw(table.len(), bytes);
        self.stats.fetch_events += 1;
        self.stats.transfer_seconds += dt;
        self.note_utilization();
        Ok(dt)
    }

    /// Grow an **offloaded** sequence's context on the host plane —
    /// host-piggybacked decode appending tokens past its held blocks.
    /// New blocks allocate directly on the host tier (no device budget,
    /// so growth never preempts anyone), and each one bills the
    /// write-through transfer of its K/V bytes on the virtual clock.
    /// Returns the seconds to charge.
    pub fn grow_on_host(&mut self, seq: usize, new_len: usize) -> Result<f64> {
        if new_len > self.geo.max_seq {
            bail!(
                "sequence length {new_len} exceeds max_seq {}",
                self.geo.max_seq
            );
        }
        if !self.seq(seq).offloaded {
            bail!("grow_on_host on device-resident seq {seq}");
        }
        let need = self.geo.blocks_for(new_len);
        let have = self.seq(seq).table.len();
        let mut dt = 0.0;
        if need > have {
            let extra = need - have;
            for _ in 0..extra {
                let id = self.pool.alloc_on_host();
                self.seq_mut(seq).table.push(id);
            }
            let bytes = extra * self.block_bytes(BlockPrecision::F32);
            dt = self.host.deposit(extra, bytes);
            self.stats.transfer_seconds += dt;
        }
        self.seq_mut(seq).len = new_len;
        self.touch(seq);
        Ok(dt)
    }

    // ---- write path -------------------------------------------------

    fn locate(&self, seq: usize, pos: usize) -> (BlockId, usize) {
        let s = self.seq(seq);
        let bi = pos / self.geo.block_size;
        assert!(
            bi < s.table.len(),
            "position {pos} beyond held blocks of seq {seq}"
        );
        (s.table[bi], pos % self.geo.block_size)
    }

    /// Scatter one **layer**'s new K/V rows for `count` tokens starting
    /// at `start_pos`. `new_k`/`new_v` layout: `[T, H, Dh]` flattened —
    /// the natural shape of one layer's projection output, which is
    /// what lets the host-native forward pass write each layer into the
    /// cache *before* its block-native attention reads it (no dense
    /// staging buffer anywhere). The whole-token wrappers
    /// [`Self::scatter_prefill`] / [`Self::scatter_decode`] delegate
    /// here per layer.
    pub fn scatter_rows(
        &mut self,
        seq: usize,
        layer: usize,
        start_pos: usize,
        count: usize,
        new_k: &[f32],
        new_v: &[f32],
    ) {
        let g = self.geo;
        let (h, dh, bs) = (g.n_heads, g.head_dim, g.block_size);
        debug_assert!(layer < g.n_layers, "layer {layer} of {}", g.n_layers);
        debug_assert_eq!(new_k.len(), count * h * dh, "new_k length");
        debug_assert_eq!(new_v.len(), count * h * dh, "new_v length");
        self.touch(seq);
        if !self.physical {
            return;
        }
        for t in 0..count {
            let pos = start_pos + t;
            let (id, off) = self.locate(seq, pos);
            let block = &mut self.pool.blocks[id as usize];
            let super::block::BlockPayload::F32 { k, v } = &mut block.payload else {
                panic!("scatter into demoted/offloaded block (seq {seq}, pos {pos})");
            };
            for hi in 0..h {
                let src = (t * h + hi) * dh;
                let dst = ((layer * h + hi) * bs + off) * dh;
                k[dst..dst + dh].copy_from_slice(&new_k[src..src + dh]);
                v[dst..dst + dh].copy_from_slice(&new_v[src..src + dh]);
            }
        }
    }

    /// Scatter new K/V rows for `count` tokens starting at `start_pos`.
    /// `new_k`/`new_v` layout: `[L, T, H, Dh]` (prefill) flattened.
    pub fn scatter_prefill(
        &mut self,
        seq: usize,
        start_pos: usize,
        count: usize,
        new_k: &[f32],
        new_v: &[f32],
    ) {
        let g = self.geo;
        let (l, h, dh) = (g.n_layers, g.n_heads, g.head_dim);
        debug_assert_eq!(new_k.len(), l * count * h * dh, "new_k length");
        debug_assert_eq!(new_v.len(), l * count * h * dh, "new_v length");
        let per = count * h * dh;
        for li in 0..l {
            self.scatter_rows(
                seq,
                li,
                start_pos,
                count,
                &new_k[li * per..(li + 1) * per],
                &new_v[li * per..(li + 1) * per],
            );
        }
    }

    /// Scatter one decode token's K/V. `new_k`/`new_v` layout: `[L, H, Dh]`
    /// for this sequence (already sliced out of the batch output).
    pub fn scatter_decode(&mut self, seq: usize, pos: usize, new_k: &[f32], new_v: &[f32]) {
        let g = self.geo;
        let (l, h, dh) = (g.n_layers, g.n_heads, g.head_dim);
        debug_assert_eq!(new_k.len(), l * h * dh, "new_k length");
        debug_assert_eq!(new_v.len(), l * h * dh, "new_v length");
        let per = h * dh;
        for li in 0..l {
            self.scatter_rows(
                seq,
                li,
                pos,
                1,
                &new_k[li * per..(li + 1) * per],
                &new_v[li * per..(li + 1) * per],
            );
        }
    }

    // ---- read path --------------------------------------------------

    /// Gather one sequence into the dense `[L, H, max_seq, Dh]` shape the
    /// fixed-shape executables consume; FP8 blocks dequantize on the fly.
    pub fn gather_seq(&mut self, seq: usize, out_k: &mut Vec<f32>, out_v: &mut Vec<f32>) {
        let per = self.geo.slot_elems();
        out_k.clear();
        out_k.resize(per, 0.0);
        out_v.clear();
        out_v.resize(per, 0.0);
        self.touch(seq);
        if self.physical {
            self.gather_into(seq, out_k, out_v);
        }
    }

    /// Gather the full padded batch cache for a decode call:
    /// output layout `[B, L, H, max_seq, Dh]` with `B = seqs.len()`.
    pub fn gather_batch(&mut self, seqs: &[usize], out_k: &mut Vec<f32>, out_v: &mut Vec<f32>) {
        let per = self.geo.slot_elems();
        out_k.clear();
        out_k.resize(per * seqs.len(), 0.0);
        out_v.clear();
        out_v.resize(per * seqs.len(), 0.0);
        for (i, &sq) in seqs.iter().enumerate() {
            self.touch(sq);
            if self.physical {
                let (ks, vs) = (
                    &mut out_k[i * per..(i + 1) * per],
                    &mut out_v[i * per..(i + 1) * per],
                );
                self.gather_into(sq, ks, vs);
            }
        }
    }

    /// Gather a decode batch padded to `bucket` lanes: real lanes are
    /// dense-gathered, padding lanes are **zero-filled**. (The pre-PR 5
    /// backend re-gathered slot 0's entire cache for every padding lane
    /// — pure waste, and a data dependency the padding never needed.
    /// The block-native path has no padding lanes at all; this is the
    /// dense oracle's equivalent.)
    pub fn gather_batch_padded(
        &mut self,
        seqs: &[usize],
        bucket: usize,
        out_k: &mut Vec<f32>,
        out_v: &mut Vec<f32>,
    ) {
        assert!(seqs.len() <= bucket, "batch {} exceeds bucket {bucket}", seqs.len());
        let per = self.geo.slot_elems();
        out_k.clear();
        out_k.resize(per * bucket, 0.0);
        out_v.clear();
        out_v.resize(per * bucket, 0.0);
        for (i, &sq) in seqs.iter().enumerate() {
            self.touch(sq);
            if self.physical {
                let (ks, vs) = (
                    &mut out_k[i * per..(i + 1) * per],
                    &mut out_v[i * per..(i + 1) * per],
                );
                self.gather_into(sq, ks, vs);
            }
        }
    }

    fn gather_into(&self, seq: usize, out_k: &mut [f32], out_v: &mut [f32]) {
        let g = self.geo;
        let (l, h, s_max, dh, bs) = (g.n_layers, g.n_heads, g.max_seq, g.head_dim, g.block_size);
        let sq = self.seq(seq);
        assert!(!sq.offloaded, "gather of offloaded seq {seq}");
        // dequant scratch, allocated only if the sequence holds FP8 blocks
        let mut scratch: Vec<f32> = Vec::new();
        for (bi, &id) in sq.table.iter().enumerate() {
            let start = bi * bs;
            if start >= s_max {
                break; // the headroom block can sit past max_seq
            }
            let n_tok = bs.min(s_max - start);
            match &self.pool.blocks[id as usize].payload {
                super::block::BlockPayload::Acct => {}
                super::block::BlockPayload::F32 { k, v } => {
                    copy_block_rows(k, out_k, l, h, bs, dh, s_max, start, n_tok);
                    copy_block_rows(v, out_v, l, h, bs, dh, s_max, start, n_tok);
                }
                super::block::BlockPayload::Fp8 {
                    k,
                    v,
                    scale_k,
                    scale_v,
                } => {
                    if scratch.is_empty() {
                        scratch = vec![0.0; g.block_elems()];
                    }
                    codec::decode_block(k, *scale_k, &mut scratch);
                    copy_block_rows(&scratch, out_k, l, h, bs, dh, s_max, start, n_tok);
                    codec::decode_block(v, *scale_v, &mut scratch);
                    copy_block_rows(&scratch, out_v, l, h, bs, dh, s_max, start, n_tok);
                }
            }
        }
    }
}

/// Copy one block plane (`[L, H, bs, Dh]`) into a dense plane
/// (`[L, H, s_max, Dh]`) at token offset `start`, `n_tok` tokens.
#[allow(clippy::too_many_arguments)]
fn copy_block_rows(
    src: &[f32],
    dst: &mut [f32],
    l: usize,
    h: usize,
    bs: usize,
    dh: usize,
    s_max: usize,
    start: usize,
    n_tok: usize,
) {
    for li in 0..l {
        for hi in 0..h {
            let so = ((li * h + hi) * bs) * dh;
            let d = ((li * h + hi) * s_max + start) * dh;
            dst[d..d + n_tok * dh].copy_from_slice(&src[so..so + n_tok * dh]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn geo() -> KvGeometry {
        KvGeometry {
            n_layers: 2,
            n_heads: 2,
            max_seq: 32,
            head_dim: 4,
            block_size: 8,
            total_blocks: 16,
        }
    }

    fn acct(policy: KvPressureConfig) -> PagedKvCache {
        PagedKvCache::accounting_only(geo(), policy)
    }

    #[test]
    fn allocate_grow_release_accounting() {
        let mut kv = acct(KvPressureConfig::dense_baseline());
        assert_eq!(kv.free_blocks(), 16);
        let s0 = kv.allocate(10).unwrap(); // 2 blocks prompt + 1 headroom
        assert_eq!(kv.free_blocks(), 13);
        kv.grow(s0, 10).unwrap(); // within held
        assert_eq!(kv.free_blocks(), 13);
        kv.grow(s0, 25).unwrap(); // 4 blocks needed, held 3 -> +1
        assert_eq!(kv.free_blocks(), 12);
        kv.release(s0);
        assert_eq!(kv.free_blocks(), 16);
        assert_eq!(kv.live_seqs(), 0);
    }

    #[test]
    fn admission_limits_come_from_blocks_alone() {
        let mut kv = acct(KvPressureConfig::dense_baseline());
        let _a = kv.allocate(32).unwrap(); // 4+1 = 5 blocks
        let _b = kv.allocate(32).unwrap(); // 5 blocks (10 total)
        let _c = kv.allocate(32).unwrap(); // 5 blocks (15 total)
        assert_eq!(kv.live_seqs(), 3);
        assert!(!kv.can_admit(32), "only 1 block free");
        assert!(!kv.can_admit(1), "needs 2 blocks (1 + headroom)");
        assert!(kv.allocate(1).is_err());
    }

    #[test]
    fn grow_respects_max_seq_and_budget() {
        let mut kv = acct(KvPressureConfig::dense_baseline());
        let s = kv.allocate(8).unwrap();
        assert!(kv.grow(s, 33).is_err()); // > max_seq
        let _other = kv.allocate(32).unwrap();
        let _other2 = kv.allocate(32).unwrap();
        // 16 - 2 - 5 - 5 = 4 free; growing s to 32 needs 4 held vs 2 -> +2
        kv.grow(s, 32).unwrap();
        assert_eq!(kv.free_blocks(), 2);
    }

    #[test]
    fn allocator_reuses_released_blocks_and_seq_ids() {
        let mut kv = acct(KvPressureConfig::dense_baseline());
        let a = kv.allocate(16).unwrap();
        assert_eq!(a, 0);
        let held = kv.seq_blocks(a);
        kv.release(a);
        let b = kv.allocate(16).unwrap();
        assert_eq!(b, 0, "sequence handle reused");
        assert_eq!(kv.seq_blocks(b), held);
        assert_eq!(kv.free_blocks(), 16 - held, "no budget leaked by reuse");
    }

    #[test]
    fn utilization_signal() {
        let mut kv = acct(KvPressureConfig::dense_baseline());
        assert_eq!(kv.block_utilization(), 0.0);
        let _s = kv.allocate(32).unwrap();
        assert!((kv.block_utilization() - 5.0 / 16.0).abs() < 1e-12);
    }

    #[test]
    fn demotion_follows_lru_order() {
        // two sequences with demotable prefixes; a tight target demotes
        // exactly one block — it must come from the LRU (older-touched) seq
        let mut kv = acct(KvPressureConfig {
            demote_watermark_fp8: 0.48, // floor(0.48 * 32) = 15 of 16 used
            ..KvPressureConfig::demote_only()
        });
        let a = kv.allocate(24).unwrap(); // 4 blocks
        kv.grow(a, 24).unwrap(); // frontier 3 -> blocks 0,1 eligible
        let b = kv.allocate(24).unwrap();
        kv.grow(b, 24).unwrap(); // touched after a
        assert_eq!(kv.block_utilization(), 0.5);
        assert_eq!(kv.maintain(), 0, "below the fp16 watermark");
        kv.set_precision_pressure(true);
        assert_eq!(kv.maintain(), 1, "one demotion reaches the target");
        assert_eq!(kv.seq_fp8_blocks(a), 1, "LRU sequence demoted first");
        assert_eq!(kv.seq_fp8_blocks(b), 0);
        // touching a (a gather) makes b the LRU victim for the next one
        let (mut k, mut v) = (Vec::new(), Vec::new());
        kv.gather_seq(a, &mut k, &mut v);
        let mut tight = kv;
        tight.policy.demote_watermark_fp8 = 0.40; // floor -> 12; used 15
        assert!(tight.maintain() >= 1);
        assert!(tight.seq_fp8_blocks(b) >= 1, "b demoted after a was touched");
    }

    #[test]
    fn demotion_expands_admission_capacity() {
        // the acceptance property at cache level: same block budget, FP8
        // demotion admits more concurrent sequences than all-f32
        let run = |policy: KvPressureConfig| -> usize {
            let mut kv = acct(policy);
            let mut admitted = 0;
            for _ in 0..8 {
                if !kv.relieve_for_admit(16) {
                    break;
                }
                let s = kv.allocate(16).unwrap();
                kv.grow(s, 16).unwrap(); // write the blocks so they can cool
                admitted += 1;
            }
            admitted
        };
        let base = run(KvPressureConfig::dense_baseline());
        let demote = run(KvPressureConfig::demote_only());
        assert!(
            demote > base,
            "fp8 demotion must admit more: {demote} !> {base}"
        );
    }

    #[test]
    fn offload_charges_the_documented_transfer_latency() {
        let policy = KvPressureConfig::default();
        let mut kv = acct(policy);
        let s = kv.allocate(32).unwrap(); // 5 blocks
        kv.grow(s, 32).unwrap();
        let free_before = kv.free_blocks();
        let dt = kv.offload_sequence(s).unwrap();
        let bytes = 5 * (geo().block_elems() * 4 * 2);
        let expect = policy.transfer_base_s + bytes as f64 / (policy.host_bw_gbps * 1e9);
        assert!((dt - expect).abs() < 1e-15, "charged {dt}, expected {expect}");
        assert!(kv.is_offloaded(s));
        assert_eq!(kv.free_blocks(), 16, "host blocks stop counting");
        assert_eq!(kv.host_blocks(), 5);
        assert!(kv.grow(s, 32).is_err(), "offloaded seqs cannot grow");

        assert!(kv.can_fetch(s));
        let dt2 = kv.fetch_sequence(s).unwrap();
        assert!((dt2 - expect).abs() < 1e-15, "fetch charges the same bill");
        assert_eq!(kv.free_blocks(), free_before);
        assert_eq!(kv.host_blocks(), 0);
        let st = kv.stats();
        assert_eq!(st.offload_events, 1);
        assert_eq!(st.fetch_events, 1);
        assert_eq!(st.offloaded_blocks, 5);
        assert!((st.transfer_seconds - dt - dt2).abs() < 1e-15);
    }

    #[test]
    fn release_while_offloaded_clears_the_host_tier() {
        let mut kv = acct(KvPressureConfig::default());
        let s = kv.allocate(16).unwrap();
        kv.grow(s, 16).unwrap();
        kv.offload_sequence(s).unwrap();
        kv.release(s);
        assert_eq!(kv.host_blocks(), 0);
        assert_eq!(kv.free_blocks(), 16);
        assert_eq!(kv.live_seqs(), 0);
    }

    #[test]
    fn can_fetch_requires_device_room() {
        // margin 0 pins the legacy resume-the-moment-it-fits rule; the
        // anti-thrash margin has its own test below
        let mut kv = acct(KvPressureConfig {
            demote_enabled: false,
            resume_headroom_mult: 0.0,
            ..KvPressureConfig::default()
        });
        let a = kv.allocate(32).unwrap(); // 5 blocks
        kv.grow(a, 32).unwrap();
        kv.offload_sequence(a).unwrap();
        // fill the device: 3 x 5 blocks = 15 of 16
        let mut held = Vec::new();
        for _ in 0..3 {
            held.push(kv.allocate(32).unwrap());
        }
        assert!(!kv.can_fetch(a), "1 free block cannot host 5");
        kv.release(held.pop().unwrap());
        assert!(kv.can_fetch(a), "6 free blocks fit 5 + headroom");
        kv.fetch_sequence(a).unwrap();
    }

    #[test]
    fn resume_margin_delays_fetch_until_growth_room_exists() {
        let mut kv = acct(KvPressureConfig {
            demote_enabled: false,
            resume_headroom_mult: 0.5,
            ..KvPressureConfig::default()
        });
        let a = kv.allocate(32).unwrap(); // 5 blocks = 10 units stored
        kv.grow(a, 32).unwrap();
        kv.offload_sequence(a).unwrap();
        let mut held = Vec::new();
        for _ in 0..3 {
            held.push(kv.allocate(32).unwrap());
        }
        // exactly-fits (10 + 2 headroom = 12 free) is no longer enough:
        // the margin wants ceil(10 * 0.5) = 5 more units
        kv.release(held.pop().unwrap());
        assert_eq!(kv.free_units(), 12);
        assert!(!kv.can_fetch(a), "margin withholds an exact-fit resume");
        kv.release(held.pop().unwrap());
        assert!(kv.can_fetch(a), "margin satisfied with growth room free");
        kv.fetch_sequence(a).unwrap();
    }

    #[test]
    fn resume_margin_is_waived_when_it_could_never_be_met() {
        // a sequence whose stored units + margin exceed the whole budget
        // must still be fetchable on an empty device (liveness)
        let mut kv = acct(KvPressureConfig {
            demote_enabled: false,
            resume_headroom_mult: 4.0,
            ..KvPressureConfig::default()
        });
        let a = kv.allocate(32).unwrap(); // 5 blocks; margin would want 40 units
        kv.grow(a, 32).unwrap();
        kv.offload_sequence(a).unwrap();
        assert!(kv.can_fetch(a), "unmeetable margin is waived");
        kv.fetch_sequence(a).unwrap();
    }

    #[test]
    fn host_grow_extends_context_without_device_budget() {
        let mut kv = acct(KvPressureConfig::piggyback());
        let a = kv.allocate(16).unwrap(); // 3 blocks
        kv.grow(a, 16).unwrap();
        // exhaust the device so a device grow could not possibly fit
        let mut held = Vec::new();
        while kv.can_admit(32) {
            held.push(kv.allocate(32).unwrap());
        }
        kv.offload_sequence(a).unwrap();
        let free_before = kv.free_units();
        let host_before = kv.host_blocks();
        // 16 -> 32 tokens: held 3 blocks, need 4 -> one host block
        let dt = kv.grow_on_host(a, 32).unwrap();
        assert!(dt > 0.0, "appended block bills its write-through transfer");
        assert_eq!(kv.host_blocks(), host_before + 1);
        assert_eq!(kv.free_units(), free_before, "no device units consumed");
        assert_eq!(kv.seq_len(a), 32);
        // growth within held blocks is free
        let dt2 = kv.grow_on_host(a, 32).unwrap();
        assert_eq!(dt2, 0.0);
        assert!(kv.grow_on_host(a, 33).is_err(), "max_seq still enforced");
        // release drops the host copy: ledger and pool both drain
        kv.release(a);
        assert_eq!(kv.host_blocks(), 0);
    }

    #[test]
    fn any_tier_view_reads_host_blocks_in_place() {
        let mut kv = PagedKvCache::new(geo(), KvPressureConfig::piggyback());
        let g = geo();
        let (l, h, dh) = (g.n_layers, g.n_heads, g.head_dim);
        let s = kv.allocate(8).unwrap();
        let n = l * 8 * h * dh;
        let nk: Vec<f32> = (0..n).map(|i| i as f32 * 0.1).collect();
        let nv: Vec<f32> = nk.iter().map(|x| -x).collect();
        kv.scatter_prefill(s, 0, 8, &nk, &nv);
        kv.grow(s, 8).unwrap();
        kv.offload_sequence(s).unwrap();
        // the device-only accessor still refuses host blocks ...
        let panicked = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            kv.seq_block_kv(s, 0);
        }))
        .is_err();
        assert!(panicked, "seq_block_kv must keep its device-only contract");
        // ... while the any-tier view reads the payload in place
        let BlockKv::F32 { k, .. } = kv.seq_block_kv_any_tier(s, 0) else {
            panic!("host payload stays f32 in place");
        };
        assert_eq!(k[0], nk[0]);
        // and a genuinely host-allocated block (past the held table of
        // 1 prompt + 1 headroom block) is writable through scatter
        let tok: Vec<f32> = (0..l * h * dh).map(|i| 5.0 + i as f32).collect();
        let dt = kv.grow_on_host(s, 17).unwrap(); // blocks_for(17) = 3 > 2 held
        assert!(dt > 0.0);
        kv.scatter_decode(s, 16, &tok, &tok);
        let BlockKv::F32 { k, .. } = kv.seq_block_kv_any_tier(s, 2) else {
            panic!("host-grown block is f32");
        };
        assert_eq!(k[0], tok[0]);
        let est = kv.resume_transfer_estimate(s);
        assert!(est > 0.0, "a resume would pay a real transfer bill");
    }

    // ---- physical-store tests ---------------------------------------

    fn physical() -> PagedKvCache {
        PagedKvCache::new(geo(), KvPressureConfig::dense_baseline())
    }

    #[test]
    fn scatter_gather_roundtrip() {
        let mut kv = physical();
        let s = kv.allocate(4).unwrap();
        let g = geo();
        let (l, h, dh) = (g.n_layers, g.n_heads, g.head_dim);
        let count = 3;
        let mut nk = vec![0.0f32; l * count * h * dh];
        for (i, v) in nk.iter_mut().enumerate() {
            *v = i as f32;
        }
        let nv: Vec<f32> = nk.iter().map(|x| -x).collect();
        kv.scatter_prefill(s, 0, count, &nk, &nv);
        kv.grow(s, count).unwrap();

        // token at layer 1, t=2, head 1 in the dense gather
        let (mut dk, mut dv) = (Vec::new(), Vec::new());
        kv.gather_seq(s, &mut dk, &mut dv);
        let src = ((1 * count + 2) * h + 1) * dh;
        let dst = ((1 * h + 1) * g.max_seq + 2) * dh;
        assert_eq!(dk[dst..dst + dh], nk[src..src + dh]);
        assert_eq!(dv[dst], -nk[src]);

        // decode token at pos 3
        let tk: Vec<f32> = (0..l * h * dh).map(|i| 100.0 + i as f32).collect();
        let tv: Vec<f32> = tk.iter().map(|x| x + 0.5).collect();
        kv.scatter_decode(s, 3, &tk, &tv);
        kv.grow(s, 4).unwrap();
        let (mut bk, mut bv) = (Vec::new(), Vec::new());
        kv.gather_batch(&[s], &mut bk, &mut bv);
        assert_eq!(bk.len(), kv.geo.slot_elems());
        let d = ((0 * h + 0) * g.max_seq + 3) * dh;
        assert_eq!(bk[d], 100.0);
        assert_eq!(bk[dst], nk[src], "prefill data still intact");
    }

    #[test]
    fn demoted_blocks_gather_within_codec_bounds() {
        let mut kv = PagedKvCache::new(geo(), KvPressureConfig::demote_only());
        let s = kv.allocate(24).unwrap(); // 4 blocks
        let g = geo();
        let (l, h, dh) = (g.n_layers, g.n_heads, g.head_dim);
        let count = 24;
        let nk: Vec<f32> = (0..l * count * h * dh)
            .map(|i| ((i % 17) as f32 - 8.0) * 0.25)
            .collect();
        let nv: Vec<f32> = nk.iter().map(|x| x * -0.5).collect();
        kv.scatter_prefill(s, 0, count, &nk, &nv);
        kv.grow(s, count).unwrap();

        let (mut exact_k, mut exact_v) = (Vec::new(), Vec::new());
        kv.gather_seq(s, &mut exact_k, &mut exact_v);

        // force-demote everything eligible (frontier 3, hot tail 1 -> 2)
        kv.set_precision_pressure(true);
        kv.policy.demote_watermark_fp8 = 0.0;
        let demoted = kv.maintain();
        assert_eq!(demoted, 2);
        assert_eq!(kv.seq_fp8_blocks(s), 2);

        let (mut qk, mut qv) = (Vec::new(), Vec::new());
        kv.gather_seq(s, &mut qk, &mut qv);
        let absmax = nk.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
        for (i, (&e, &q)) in exact_k.iter().zip(&qk).enumerate() {
            let bound = super::codec::error_bound(e, absmax) * (1.0 + 1e-5) + 1e-30;
            assert!(
                (e - q).abs() <= bound,
                "elem {i}: exact {e} quantized {q}"
            );
        }
        // the hot tail stayed f32: tokens 16.. of the dense K are exact
        let tail = ((0 * h + 0) * g.max_seq + 17) * dh;
        assert_eq!(qk[tail..tail + dh], exact_k[tail..tail + dh]);
    }

    #[test]
    #[should_panic(expected = "new_v length")]
    fn scatter_prefill_validates_new_v() {
        let mut kv = physical();
        let s = kv.allocate(8).unwrap();
        let g = geo();
        let n = g.n_layers * 2 * g.n_heads * g.head_dim;
        let nk = vec![0.0f32; n];
        let nv = vec![0.0f32; n - 1]; // wrong
        kv.scatter_prefill(s, 0, 2, &nk, &nv);
    }

    #[test]
    #[should_panic(expected = "new_v length")]
    fn scatter_decode_validates_new_v() {
        let mut kv = physical();
        let s = kv.allocate(8).unwrap();
        let g = geo();
        let n = g.n_layers * g.n_heads * g.head_dim;
        kv.scatter_decode(s, 0, &vec![0.0f32; n], &vec![0.0f32; n + 1]);
    }

    #[test]
    fn block_view_sees_what_scatter_wrote() {
        let mut kv = physical();
        let s = kv.allocate(12).unwrap();
        let g = geo();
        let (l, h, dh, bs) = (g.n_layers, g.n_heads, g.head_dim, g.block_size);
        let count = 10;
        let nk: Vec<f32> = (0..l * count * h * dh).map(|i| i as f32).collect();
        let nv: Vec<f32> = nk.iter().map(|x| -x).collect();
        kv.scatter_prefill(s, 0, count, &nk, &nv);
        kv.grow(s, count).unwrap();
        // in-place readers bump the LRU stamp explicitly (gathers do it
        // implicitly): after touch_read, s is the freshest sequence
        kv.touch_read(s);
        // token 9 (block 1, offset 1), layer 1, head 1, elem 2
        let BlockKv::F32 { k, v } = kv.seq_block_kv(s, 1) else {
            panic!("fresh blocks are f32");
        };
        let (li, t, hi, e) = (1usize, 9usize, 1usize, 2usize);
        let src = ((li * count + t) * h + hi) * dh + e;
        let idx = ((li * h + hi) * bs + (t % bs)) * dh + e;
        assert_eq!(k[idx], nk[src]);
        assert_eq!(v[idx], nv[src]);
    }

    #[test]
    fn per_layer_scatter_rows_compose_to_scatter_decode() {
        let g = geo();
        let (l, h, dh) = (g.n_layers, g.n_heads, g.head_dim);
        let token: Vec<f32> = (0..l * h * dh).map(|i| 3.0 + i as f32).collect();
        let tv: Vec<f32> = token.iter().map(|x| x * 0.5).collect();
        // one cache written whole-token, one written layer by layer
        let mut whole = physical();
        let a = whole.allocate(4).unwrap();
        whole.scatter_decode(a, 2, &token, &tv);
        let mut by_layer = physical();
        let b = by_layer.allocate(4).unwrap();
        for li in 0..l {
            by_layer.scatter_rows(b, li, 2, 1, &token[li * h * dh..(li + 1) * h * dh], &tv[li * h * dh..(li + 1) * h * dh]);
        }
        for (s, kv) in [(a, &mut whole), (b, &mut by_layer)] {
            kv.grow(s, 3).unwrap();
        }
        let (mut k1, mut v1) = (Vec::new(), Vec::new());
        whole.gather_seq(a, &mut k1, &mut v1);
        let (mut k2, mut v2) = (Vec::new(), Vec::new());
        by_layer.gather_seq(b, &mut k2, &mut v2);
        assert_eq!(k1, k2);
        assert_eq!(v1, v2);
    }

    #[test]
    fn padded_gather_zero_fills_padding_lanes() {
        let mut kv = physical();
        let s = kv.allocate(4).unwrap();
        let g = geo();
        let n = g.n_layers * g.n_heads * g.head_dim;
        let nk: Vec<f32> = (0..n).map(|i| 1.0 + i as f32).collect();
        kv.scatter_decode(s, 0, &nk, &nk);
        kv.grow(s, 1).unwrap();
        let per = g.slot_elems();
        let (mut bk, mut bv) = (Vec::new(), Vec::new());
        kv.gather_batch_padded(&[s], 3, &mut bk, &mut bv);
        assert_eq!(bk.len(), 3 * per);
        // real lane matches the per-sequence gather ...
        let (mut sk, mut sv) = (Vec::new(), Vec::new());
        kv.gather_seq(s, &mut sk, &mut sv);
        assert_eq!(&bk[..per], &sk[..]);
        assert_eq!(&bv[..per], &sv[..]);
        // ... and padding lanes are zeros, not slot-0 copies
        assert!(bk[per..].iter().all(|&x| x == 0.0));
        assert!(bv[per..].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn touched_bytes_track_stored_precision() {
        let mut kv = PagedKvCache::new(
            geo(),
            KvPressureConfig {
                demote_watermark_fp8: 0.0,
                ..KvPressureConfig::demote_only()
            },
        );
        let g = geo();
        let s = kv.allocate(24).unwrap();
        let n = g.n_layers * 24 * g.n_heads * g.head_dim;
        let nk: Vec<f32> = (0..n).map(|i| (i % 13) as f32 * 0.1).collect();
        kv.scatter_prefill(s, 0, 24, &nk, &nk);
        kv.grow(s, 24).unwrap();
        let per_layer = g.block_size * g.n_heads * g.head_dim;
        // 24 tokens = 3 blocks, all f32
        assert_eq!(kv.seq_touched_bytes(s, 24), 3 * per_layer * 8);
        assert_eq!(kv.seq_touched_bytes(s, 8), per_layer * 8);
        assert_eq!(kv.seq_touched_bytes(s, 0), 0);
        // demote (frontier 3, hot tail 1 -> blocks 0 and 1 demote)
        kv.set_precision_pressure(true);
        assert_eq!(kv.maintain(), 2);
        assert_eq!(
            kv.seq_touched_bytes(s, 24),
            2 * (per_layer * 2 + 8) + per_layer * 8,
            "fp8 blocks stream at half (plus scales)"
        );
        // walking less context touches fewer blocks
        assert!(kv.seq_touched_bytes(s, 9) < kv.seq_touched_bytes(s, 24));
    }
}

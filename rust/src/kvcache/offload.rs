//! The host-offload tier: capacity beyond the device, paid for in
//! transfer latency on the engine's virtual clock.
//!
//! On CPU the "device" and the "host" are the same RAM, so the tier is an
//! accounting and latency model: payloads stay where they are, but
//! offloaded blocks stop counting against the device budget and every
//! move charges `base + bytes / bandwidth` seconds — the shape of a PCIe
//! DMA. That is exactly what the SLO-offloading literature needs from a
//! simulator: admission past device capacity with an honest latency bill.

/// Host tier accounting + transfer model.
#[derive(Clone, Debug)]
pub struct HostTier {
    bw_gbps: f64,
    base_s: f64,
    /// Blocks currently resident on the host.
    resident_blocks: usize,
    /// Bytes currently resident on the host.
    resident_bytes: usize,
}

impl HostTier {
    pub fn new(bw_gbps: f64, base_s: f64) -> HostTier {
        assert!(bw_gbps > 0.0, "host bandwidth must be positive");
        HostTier {
            bw_gbps,
            base_s,
            resident_blocks: 0,
            resident_bytes: 0,
        }
    }

    pub fn resident_blocks(&self) -> usize {
        self.resident_blocks
    }

    pub fn resident_bytes(&self) -> usize {
        self.resident_bytes
    }

    /// Seconds one transfer of `bytes` costs over the simulated link.
    pub fn transfer_seconds(&self, bytes: usize) -> f64 {
        self.base_s + bytes as f64 / (self.bw_gbps * 1e9)
    }

    /// Move `blocks`/`bytes` device→host; returns the clock charge.
    pub fn deposit(&mut self, blocks: usize, bytes: usize) -> f64 {
        self.resident_blocks += blocks;
        self.resident_bytes += bytes;
        self.transfer_seconds(bytes)
    }

    /// Move `blocks`/`bytes` host→device; returns the clock charge.
    pub fn withdraw(&mut self, blocks: usize, bytes: usize) -> f64 {
        debug_assert!(blocks <= self.resident_blocks && bytes <= self.resident_bytes);
        self.resident_blocks -= blocks;
        self.resident_bytes -= bytes;
        self.transfer_seconds(bytes)
    }

    /// Drop a finished sequence's host copy (no transfer, no charge).
    pub fn discard(&mut self, blocks: usize, bytes: usize) {
        debug_assert!(blocks <= self.resident_blocks && bytes <= self.resident_bytes);
        self.resident_blocks -= blocks;
        self.resident_bytes -= bytes;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_latency_model() {
        let t = HostTier::new(24.0, 50e-6);
        // 24 MB over 24 GB/s = 1 ms, plus the 50 us base
        let s = t.transfer_seconds(24_000_000);
        assert!((s - 0.00105).abs() < 1e-12, "{s}");
        // base charge dominates tiny transfers
        assert!((t.transfer_seconds(0) - 50e-6).abs() < 1e-18);
    }

    #[test]
    fn deposit_withdraw_discard_conserve() {
        let mut t = HostTier::new(10.0, 0.0);
        let d = t.deposit(3, 1_000_000);
        assert!((d - 1e-4).abs() < 1e-15);
        assert_eq!(t.resident_blocks(), 3);
        assert_eq!(t.resident_bytes(), 1_000_000);
        let w = t.withdraw(1, 400_000);
        assert!((w - 4e-5).abs() < 1e-15);
        t.discard(2, 600_000);
        assert_eq!(t.resident_blocks(), 0);
        assert_eq!(t.resident_bytes(), 0);
    }
}

//! The physical block pool: storage, half-block-unit accounting, id reuse.
//!
//! The device budget is `total_blocks` f32-resident blocks, accounted in
//! **units** of half a block so FP8 demotion has first-class capacity
//! meaning: an f32 block costs [`UNITS_F32`] = 2, a demoted FP8 block
//! [`UNITS_FP8`] = 1, and a host-offloaded block 0 (its bytes left the
//! device). Released block ids go to a free list and are reused.

use super::codec;

/// Index into the pool's block table.
pub type BlockId = u32;

/// Storage precision of a block's payload.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BlockPrecision {
    F32,
    Fp8,
}

/// Device-budget units for an f32-resident block.
pub const UNITS_F32: usize = 2;
/// Device-budget units for an FP8-demoted block.
pub const UNITS_FP8: usize = 1;

/// Block payload. `Acct` both for accounting-only pools (the simulation
/// backend) and for released blocks awaiting reuse.
pub(crate) enum BlockPayload {
    Acct,
    F32 {
        k: Vec<f32>,
        v: Vec<f32>,
    },
    Fp8 {
        k: Vec<u8>,
        v: Vec<u8>,
        scale_k: f32,
        scale_v: f32,
    },
}

pub(crate) struct Block {
    pub payload: BlockPayload,
    pub precision: BlockPrecision,
    /// Offloaded to the host tier (bytes no longer on device).
    pub on_host: bool,
}

impl Block {
    /// Device units this block currently consumes.
    pub fn units(&self) -> usize {
        if self.on_host {
            0
        } else {
            match self.precision {
                BlockPrecision::F32 => UNITS_F32,
                BlockPrecision::Fp8 => UNITS_FP8,
            }
        }
    }
}

pub(crate) struct BlockPool {
    physical: bool,
    /// Floats per plane (K or V) of one block.
    block_elems: usize,
    pub blocks: Vec<Block>,
    free: Vec<BlockId>,
    total_units: usize,
    used_units: usize,
    /// Live FP8 blocks on device (router load signal).
    n_fp8_device: usize,
    /// Live blocks on the host tier.
    n_host: usize,
}

impl BlockPool {
    pub fn new(total_blocks: usize, block_elems: usize, physical: bool) -> BlockPool {
        BlockPool {
            physical,
            block_elems,
            blocks: Vec::new(),
            free: Vec::new(),
            total_units: total_blocks * UNITS_F32,
            used_units: 0,
            n_fp8_device: 0,
            n_host: 0,
        }
    }

    pub fn fp8_device_blocks(&self) -> usize {
        self.n_fp8_device
    }

    pub fn host_blocks(&self) -> usize {
        self.n_host
    }

    pub fn total_units(&self) -> usize {
        self.total_units
    }

    pub fn used_units(&self) -> usize {
        self.used_units
    }

    pub fn free_units(&self) -> usize {
        self.total_units - self.used_units
    }

    pub fn utilization(&self) -> f64 {
        if self.total_units == 0 {
            return 1.0;
        }
        self.used_units as f64 / self.total_units as f64
    }

    /// Allocate one f32 block (zero-filled in physical pools); `None` when
    /// fewer than [`UNITS_F32`] units remain.
    pub fn alloc(&mut self) -> Option<BlockId> {
        if self.free_units() < UNITS_F32 {
            return None;
        }
        self.used_units += UNITS_F32;
        let payload = if self.physical {
            BlockPayload::F32 {
                k: vec![0.0; self.block_elems],
                v: vec![0.0; self.block_elems],
            }
        } else {
            BlockPayload::Acct
        };
        match self.free.pop() {
            Some(id) => {
                let b = &mut self.blocks[id as usize];
                b.payload = payload;
                b.precision = BlockPrecision::F32;
                b.on_host = false;
                Some(id)
            }
            None => {
                self.blocks.push(Block {
                    payload,
                    precision: BlockPrecision::F32,
                    on_host: false,
                });
                Some((self.blocks.len() - 1) as BlockId)
            }
        }
    }

    /// Allocate one f32 block **directly on the host tier**: it consumes
    /// no device units (host capacity is not budgeted here — the
    /// [`HostTier`](super::offload::HostTier) ledger tracks residency),
    /// so this never fails on device pressure. Host-piggybacked decode
    /// grows its context through this path.
    pub fn alloc_on_host(&mut self) -> BlockId {
        let payload = if self.physical {
            BlockPayload::F32 {
                k: vec![0.0; self.block_elems],
                v: vec![0.0; self.block_elems],
            }
        } else {
            BlockPayload::Acct
        };
        self.n_host += 1;
        match self.free.pop() {
            Some(id) => {
                let b = &mut self.blocks[id as usize];
                b.payload = payload;
                b.precision = BlockPrecision::F32;
                b.on_host = true;
                id
            }
            None => {
                self.blocks.push(Block {
                    payload,
                    precision: BlockPrecision::F32,
                    on_host: true,
                });
                (self.blocks.len() - 1) as BlockId
            }
        }
    }

    /// Return a block to the free list, refunding its current units.
    pub fn release(&mut self, id: BlockId) {
        let b = &mut self.blocks[id as usize];
        self.used_units -= b.units();
        if b.on_host {
            self.n_host -= 1;
        } else if b.precision == BlockPrecision::Fp8 {
            self.n_fp8_device -= 1;
        }
        b.payload = BlockPayload::Acct;
        b.precision = BlockPrecision::F32;
        b.on_host = false;
        self.free.push(id);
    }

    /// Demote an on-device f32 block to FP8, freeing one unit. Physical
    /// payloads re-encode through the block codec.
    pub fn demote(&mut self, id: BlockId) {
        let b = &mut self.blocks[id as usize];
        debug_assert!(!b.on_host, "demoting a host block");
        debug_assert_eq!(b.precision, BlockPrecision::F32, "double demotion");
        if let BlockPayload::F32 { k, v } = std::mem::replace(&mut b.payload, BlockPayload::Acct) {
            let (k8, scale_k) = codec::encode_block(&k);
            let (v8, scale_v) = codec::encode_block(&v);
            b.payload = BlockPayload::Fp8 {
                k: k8,
                v: v8,
                scale_k,
                scale_v,
            };
        }
        b.precision = BlockPrecision::Fp8;
        self.used_units -= UNITS_F32 - UNITS_FP8;
        self.n_fp8_device += 1;
    }

    /// Move a block to/from the host tier, adjusting unit accounting. The
    /// caller checks budget before fetching (`on_host = false`).
    pub fn set_host(&mut self, id: BlockId, on_host: bool) {
        let b = &mut self.blocks[id as usize];
        if b.on_host == on_host {
            return;
        }
        if on_host {
            self.used_units -= b.units();
            b.on_host = true;
            self.n_host += 1;
            if b.precision == BlockPrecision::Fp8 {
                self.n_fp8_device -= 1;
            }
        } else {
            b.on_host = false;
            self.used_units += b.units();
            self.n_host -= 1;
            if b.precision == BlockPrecision::Fp8 {
                self.n_fp8_device += 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unit_accounting_across_lifecycle() {
        let mut p = BlockPool::new(4, 8, false);
        assert_eq!(p.total_units(), 8);
        let a = p.alloc().unwrap();
        let b = p.alloc().unwrap();
        assert_eq!(p.used_units(), 4);
        p.demote(a);
        assert_eq!(p.used_units(), 3);
        p.set_host(b, true);
        assert_eq!(p.used_units(), 1);
        p.set_host(b, false);
        assert_eq!(p.used_units(), 3);
        p.release(a);
        p.release(b);
        assert_eq!(p.used_units(), 0);
        assert_eq!(p.free_units(), 8);
    }

    #[test]
    fn budget_exhaustion_and_fp8_headroom() {
        let mut p = BlockPool::new(2, 8, false);
        let a = p.alloc().unwrap();
        let _b = p.alloc().unwrap();
        assert!(p.alloc().is_none(), "budget is 2 f32 blocks");
        // demoting one frees half a block — still not enough for f32
        p.demote(a);
        assert_eq!(p.free_units(), 1);
        assert!(p.alloc().is_none());
    }

    #[test]
    fn host_alloc_charges_no_device_units() {
        let mut p = BlockPool::new(1, 8, false);
        let _dev = p.alloc().unwrap();
        assert!(p.alloc().is_none(), "device budget exhausted");
        let h = p.alloc_on_host();
        assert_eq!(p.host_blocks(), 1);
        assert_eq!(p.used_units(), 2, "host block billed no device units");
        // fetching it to the device later goes through set_host like any
        // other host block — but only when the budget allows
        p.release(h);
        assert_eq!(p.host_blocks(), 0);
        assert_eq!(p.used_units(), 2);
    }

    #[test]
    fn released_ids_are_reused() {
        let mut p = BlockPool::new(8, 8, false);
        let ids: Vec<BlockId> = (0..3).map(|_| p.alloc().unwrap()).collect();
        assert_eq!(ids, vec![0, 1, 2]);
        for &id in &ids {
            p.release(id);
        }
        let again: Vec<BlockId> = (0..3).map(|_| p.alloc().unwrap()).collect();
        let mut sorted = again.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, ids, "no fresh ids while the free list has some");
        assert_eq!(p.blocks.len(), 3, "pool did not grow");
    }

    #[test]
    fn physical_demote_reencodes_payload() {
        let mut p = BlockPool::new(2, 4, true);
        let id = p.alloc().unwrap();
        if let BlockPayload::F32 { k, v } = &mut p.blocks[id as usize].payload {
            k.copy_from_slice(&[1.0, -2.0, 0.5, 4.0]);
            v.copy_from_slice(&[0.0, 8.0, -8.0, 1.0]);
        } else {
            panic!("fresh physical block must be f32");
        }
        p.demote(id);
        match &p.blocks[id as usize].payload {
            BlockPayload::Fp8 { k, v, scale_k, scale_v } => {
                assert_eq!(k.len(), 4);
                assert_eq!(v.len(), 4);
                assert!(*scale_k > 0.0 && *scale_v > 0.0);
                let mut out = [0.0f32; 4];
                super::codec::decode_block(k, *scale_k, &mut out);
                assert!((out[3] - 4.0).abs() / 4.0 < 1e-6, "absmax elem exact-ish");
            }
            _ => panic!("demotion must leave an fp8 payload"),
        }
    }
}

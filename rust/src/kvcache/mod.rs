//! The paged dual-precision KV cache — NestedFP's capacity story.
//!
//! Weights were the paper's memory target; at serving time the KV cache is
//! the *actual* capacity bottleneck that drives the precision-pressure
//! signal. This subsystem applies the same one-footprint idea to KV state:
//!
//! * [`block`] — a physical block pool with PagedAttention-style block
//!   tables: no per-sequence dense `[L, H, S_max, Dh]` buffers and no hard
//!   slot cap; a sequence holds exactly the blocks its context needs.
//!   Device budget is accounted in **half-block units** so an FP8 block
//!   costs half of an f32 block.
//! * [`codec`] — the FP8 block codec: cold blocks re-encode through
//!   [`format::e4m3`](crate::format::e4m3) with one absmax scale per block
//!   per plane (K and V), storing at half the bytes. Attention then reads
//!   the dequantized approximation — the runtime analogue of MorphServe's
//!   KV quantization.
//! * [`policy`] — when to demote: an LRU watermark policy whose threshold
//!   tightens when the engine's `PrecisionController` escalates to FP8
//!   (precision pressure couples weights and KV), plus the admission mode
//!   (conservative full-context reservation vs. true paging).
//! * [`offload`] — the host tier: whole sequences can be preempted to host
//!   memory instead of stalling the queue, with the PCIe-style transfer
//!   latency charged on the engine's virtual clock.
//! * [`paged`] — [`PagedKvCache`] ties it together and exposes the
//!   engine-facing API (admit/allocate/grow/release, scatter/gather through
//!   block tables, demotion maintenance, offload/fetch, stats).
//!
//! Lifecycle of a block:
//!
//! ```text
//!   alloc ──► Device·F32 ──demote (LRU, watermark)──► Device·FP8
//!                │                                        │
//!                └──────── offload (whole sequence) ──────┴──► Host
//!                                                              │
//!   release ◄── Device·{F32,FP8} ◄──────── fetch (resume) ─────┘
//! ```

pub mod block;
pub mod codec;
pub mod offload;
pub mod paged;
pub mod policy;

pub use block::{BlockId, BlockPrecision};
pub use codec::{decode_block, encode_block};
pub use offload::HostTier;
pub use paged::{BlockKv, KvCacheStats, PagedKvCache};
pub use policy::{AdmissionMode, KvPressureConfig};

/// Geometry of the cache (formerly `coordinator::kv::KvGeometry`; the
/// dense-store `n_slots` cap is gone — concurrency is bounded only by the
/// block budget).
#[derive(Clone, Copy, Debug)]
pub struct KvGeometry {
    pub n_layers: usize,
    pub n_heads: usize,
    /// Per-sequence context bound (the AOT executables are fixed-shape, so
    /// dense gathers still materialize `[L, H, max_seq, Dh]`).
    pub max_seq: usize,
    pub head_dim: usize,
    /// Tokens per block.
    pub block_size: usize,
    /// Device budget, expressed in f32-resident blocks. An FP8 block
    /// consumes half a budget block; a host-offloaded block consumes none.
    pub total_blocks: usize,
}

impl KvGeometry {
    /// Floats per token for one of K/V across all layers and heads.
    pub fn token_elems(&self) -> usize {
        self.n_layers * self.n_heads * self.head_dim
    }

    /// Floats per block for one of K/V (layout `[L, H, block_size, Dh]`).
    pub fn block_elems(&self) -> usize {
        self.block_size * self.token_elems()
    }

    /// Floats per dense-gathered sequence for one of K/V — the fixed
    /// `[L, H, max_seq, Dh]` shape the AOT executables consume.
    pub fn slot_elems(&self) -> usize {
        self.n_layers * self.n_heads * self.max_seq * self.head_dim
    }

    /// Blocks needed to cover `tokens` context positions.
    pub fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_size)
    }

    /// Bytes the dense gather materializes **per layer** per sequence
    /// (K + V, f32): the per-layer share of [`Self::slot_elems`]. This
    /// is what one layer of gather-based attention pays regardless of
    /// the live context — the quantity the block-native engine's
    /// `touched_bytes` is measured against.
    pub fn layer_dense_bytes(&self) -> usize {
        self.n_heads * self.max_seq * self.head_dim * 4 * 2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geometry_math() {
        let g = KvGeometry {
            n_layers: 2,
            n_heads: 4,
            max_seq: 64,
            head_dim: 8,
            block_size: 16,
            total_blocks: 32,
        };
        assert_eq!(g.token_elems(), 64);
        assert_eq!(g.block_elems(), 1024);
        assert_eq!(g.slot_elems(), 4096);
        assert_eq!(g.blocks_for(0), 0);
        assert_eq!(g.blocks_for(1), 1);
        assert_eq!(g.blocks_for(16), 1);
        assert_eq!(g.blocks_for(17), 2);
        assert_eq!(g.layer_dense_bytes() * g.n_layers, g.slot_elems() * 4 * 2);
    }
}

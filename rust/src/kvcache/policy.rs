//! Precision-pressure and admission policy for the paged KV cache.
//!
//! Demotion is the KV half of the paper's dual-precision story: as block
//! utilization rises past a watermark, the cache re-encodes LRU-cold
//! blocks to FP8 (half the units). When the engine's `PrecisionController`
//! escalates to FP8 the watermark tightens — the same pressure signal that
//! switches weight kernels also compresses cold KV state.

/// How admission reserves capacity for a request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AdmissionMode {
    /// Reserve the full expected context (prompt + output budget) at
    /// admission — the seed engine's conservative rule. Decode growth can
    /// never strand a running request, so this mode is safe without the
    /// host tier.
    Reserve,
    /// Reserve only the prompt (plus one headroom block) and grow on
    /// demand — true paging. Decode growth can hit a full device; the
    /// engine then preempts a sequence to the host tier instead of
    /// failing, so this mode expects `offload_enabled`.
    Paged,
}

/// Paged-cache policy knobs (engine-level: one per replica).
#[derive(Clone, Copy, Debug)]
pub struct KvPressureConfig {
    pub admission: AdmissionMode,
    /// Enable FP8 demotion of cold blocks.
    pub demote_enabled: bool,
    /// Demote above this utilization while the engine serves FP16.
    pub demote_watermark: f64,
    /// Tighter watermark while the engine serves FP8 (controller
    /// escalation demotes KV harder).
    pub demote_watermark_fp8: f64,
    /// Per-sequence write frontier that is never demoted, in blocks
    /// (minimum 1: the frontier block still receives scatters).
    pub hot_tail_blocks: usize,
    /// Enable the host-offload tier (sequence preemption).
    pub offload_enabled: bool,
    /// Simulated host link bandwidth, GB/s (PCIe-gen4-ish effective rate).
    pub host_bw_gbps: f64,
    /// Fixed per-transfer setup latency, seconds.
    pub transfer_base_s: f64,
    /// Serve attention for offloaded sequences directly over their
    /// host-resident blocks (CPU-GPU attention piggybacking) instead of
    /// parking them until a resume transfer fits. Off by default: the
    /// engine's behavior with this disabled is bit-identical to the
    /// pre-piggyback pipeline.
    pub host_piggyback: bool,
    /// Resume headroom: a fetch is only attempted once the device has
    /// `(1 + resume_headroom_mult) ×` the sequence's stored units free,
    /// so a resumed sequence has growth room and does not ping-pong
    /// straight back to the host (resume thrash). `0.0` reproduces the
    /// legacy resume-the-moment-it-fits rule.
    pub resume_headroom_mult: f64,
}

impl Default for KvPressureConfig {
    fn default() -> Self {
        KvPressureConfig {
            admission: AdmissionMode::Paged,
            demote_enabled: true,
            demote_watermark: 0.85,
            demote_watermark_fp8: 0.60,
            hot_tail_blocks: 1,
            offload_enabled: true,
            host_bw_gbps: 24.0,
            transfer_base_s: 50e-6,
            host_piggyback: false,
            resume_headroom_mult: 0.5,
        }
    }
}

impl KvPressureConfig {
    /// The seed repo's behavior: dense-style conservative reservation,
    /// all-f32 blocks, no host tier. The bench baseline.
    pub fn dense_baseline() -> Self {
        KvPressureConfig {
            admission: AdmissionMode::Reserve,
            demote_enabled: false,
            offload_enabled: false,
            ..Default::default()
        }
    }

    /// FP8 demotion only: conservative reservation (no stranding without
    /// a host tier) plus LRU block demotion under pressure.
    pub fn demote_only() -> Self {
        KvPressureConfig {
            admission: AdmissionMode::Reserve,
            demote_enabled: true,
            offload_enabled: false,
            ..Default::default()
        }
    }

    /// The full paged + offload stack with host attention piggybacking
    /// on: offloaded sequences keep decoding over their host-resident
    /// blocks instead of stalling for a resume window. The kvcache
    /// bench's piggyback arm.
    pub fn piggyback() -> Self {
        KvPressureConfig {
            host_piggyback: true,
            ..Default::default()
        }
    }

    /// Reject an inverted watermark pair at construction time instead of
    /// silently min-clamping it at every query: a
    /// `demote_watermark_fp8 > demote_watermark` config is a bug (the
    /// "pressure" watermark would *loosen* demotion), so debug builds
    /// panic and release builds log one warning through the telemetry
    /// log facade and proceed with the clamped value.
    pub fn validate(&self) {
        if self.demote_watermark_fp8 > self.demote_watermark {
            if cfg!(debug_assertions) {
                panic!(
                    "inverted KV watermarks: demote_watermark_fp8 {} > demote_watermark {}",
                    self.demote_watermark_fp8, self.demote_watermark
                );
            }
            use std::sync::atomic::{AtomicBool, Ordering};
            static WARNED: AtomicBool = AtomicBool::new(false);
            if !WARNED.swap(true, Ordering::Relaxed) {
                crate::log_warn!(
                    "inverted KV watermarks: demote_watermark_fp8 {} > demote_watermark {} (clamping)",
                    self.demote_watermark_fp8,
                    self.demote_watermark
                );
            }
        }
    }

    /// Active demotion watermark given the fraction of the model's
    /// layers currently demoted to FP8 (`0.0` = all-FP16, `1.0` =
    /// all-FP8). The endpoints reproduce the legacy binary watermarks
    /// bit for bit; interior fractions blend linearly — elastic KV
    /// resizing per MorphServe: the more layers run demoted, the harder
    /// cold KV state is compressed.
    pub fn watermark_at(&self, demoted_frac: f64) -> f64 {
        let tight = self.demote_watermark_fp8.min(self.demote_watermark);
        if demoted_frac <= 0.0 {
            self.demote_watermark
        } else if demoted_frac >= 1.0 {
            tight
        } else {
            self.demote_watermark + demoted_frac * (tight - self.demote_watermark)
        }
    }

    /// Active demotion watermark given the engine's current precision —
    /// the legacy binary view, now a shim over [`Self::watermark_at`]'s
    /// endpoints.
    pub fn watermark(&self, fp8_pressure: bool) -> f64 {
        self.watermark_at(if fp8_pressure { 1.0 } else { 0.0 })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_are_consistent() {
        let base = KvPressureConfig::dense_baseline();
        assert_eq!(base.admission, AdmissionMode::Reserve);
        assert!(!base.demote_enabled && !base.offload_enabled);

        let demote = KvPressureConfig::demote_only();
        assert_eq!(demote.admission, AdmissionMode::Reserve);
        assert!(demote.demote_enabled && !demote.offload_enabled);

        let full = KvPressureConfig::default();
        assert_eq!(full.admission, AdmissionMode::Paged);
        assert!(full.demote_enabled && full.offload_enabled);
        assert!(full.hot_tail_blocks >= 1);
        assert!(!full.host_piggyback, "piggybacking is opt-in");
        assert!(full.resume_headroom_mult > 0.0, "anti-thrash margin on by default");

        let piggy = KvPressureConfig::piggyback();
        assert_eq!(piggy.admission, AdmissionMode::Paged);
        assert!(piggy.offload_enabled && piggy.host_piggyback);
    }

    #[test]
    fn fp8_pressure_tightens_the_watermark() {
        let p = KvPressureConfig::default();
        p.validate();
        assert!(p.watermark(true) < p.watermark(false));
    }

    #[test]
    #[cfg_attr(debug_assertions, should_panic(expected = "demote_watermark_fp8"))]
    fn inverted_watermarks_are_rejected_not_clamped() {
        // an inverted pair is a config bug: debug builds panic at
        // validation (release builds warn once through the log facade
        // and clamp — the old silent min-clamp is no longer blessed)
        let odd = KvPressureConfig {
            demote_watermark: 0.5,
            demote_watermark_fp8: 0.9,
            ..Default::default()
        };
        odd.validate();
        // release-path behavior: queries still never loosen under pressure
        assert!(odd.watermark(true) <= odd.watermark(false));
    }

    #[test]
    fn watermark_at_is_monotone_and_endpoint_exact() {
        let p = KvPressureConfig::default();
        // endpoints reproduce the legacy binary watermarks bit for bit
        assert_eq!(p.watermark_at(0.0).to_bits(), p.demote_watermark.to_bits());
        assert_eq!(
            p.watermark_at(1.0).to_bits(),
            p.demote_watermark_fp8.min(p.demote_watermark).to_bits()
        );
        assert_eq!(p.watermark(false).to_bits(), p.watermark_at(0.0).to_bits());
        assert_eq!(p.watermark(true).to_bits(), p.watermark_at(1.0).to_bits());
        // monotone non-increasing in the demoted fraction
        let mut prev = f64::INFINITY;
        for k in 0..=16 {
            let w = p.watermark_at(k as f64 / 16.0);
            assert!(w <= prev + 1e-15, "watermark rose at frac {}", k as f64 / 16.0);
            prev = w;
        }
        // out-of-range fractions clamp to the endpoints
        assert_eq!(p.watermark_at(-0.5).to_bits(), p.watermark_at(0.0).to_bits());
        assert_eq!(p.watermark_at(1.5).to_bits(), p.watermark_at(1.0).to_bits());
    }
}

//! The event core: a deterministic discrete-event scheduler for the
//! cluster layer.
//!
//! The cluster used to advance every replica engine in lockstep on a
//! shared virtual clock — each driver iteration scanned the whole fleet
//! to find the lagging replica, so idle replicas burned driver work and
//! scenarios topped out at a handful of replicas. This module replaces
//! that loop with the classic discrete-event design (the embedded_emul
//! execution engine is the exemplar): everything that can act — replica
//! engines, the control loop, the surge predictor's bucket clock, trace
//! arrival injection — is a [`Component`] with a `next_tick()` /
//! `tick(now)` surface, drained from one binary-heap [`EventQueue`].
//!
//! Determinism is a contract, not an accident:
//!
//! * **Ordering law** — events pop in ascending `(time, component id)`
//!   order. Ties at the same virtual instant always resolve to the
//!   lowest component id, regardless of insertion order, so a run is
//!   bit-reproducible (`f64::total_cmp` on time; no NaNs admitted).
//! * **Clock monotonicity** — scheduling an event before the last
//!   popped time is a bug and panics ("no time travel").
//! * **Idle costs zero** — a component with nothing scheduled is simply
//!   absent from the heap. It receives no ticks, burns no scans, and is
//!   woken only by an explicit [`Waker::wake_at`] from another
//!   component's tick (e.g. an arrival routed to a parked replica).
//!
//! Two drivers share the exact same component/waker semantics:
//! [`drive`] (the binary heap, production) and [`drive_lockstep`] (a
//! naive O(n) scan per event, the test oracle). The equivalence suite
//! (`rust/tests/event_core_props.rs`) pins them bit-for-bit against
//! each other on full cluster scenarios.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use anyhow::Result;

/// Stable identity of a component: its index in the driver's component
/// slice. The heap's tie-break law makes this id the *priority* at equal
/// timestamps, so component order is part of the scheduler's semantics.
pub type ComponentId = usize;

/// One schedulable actor in the discrete-event simulation.
///
/// `S` is the shared system state (for the cluster,
/// `ClusterRouter<B>` itself) — components stay thin: identity plus the
/// two scheduling hooks, with the actual mutation logic living on `S`.
pub trait Component<S> {
    /// The component's first event time on an empty queue, or `None` to
    /// start parked (idle components cost nothing until woken).
    fn next_tick(&self, sys: &S) -> Option<f64>;

    /// Handle this component's event at virtual time `now`. Return the
    /// component's own next event time (`None` parks it); request
    /// cross-component wake-ups through `wake` — never by returning
    /// another component's time.
    fn tick(&mut self, now: f64, sys: &mut S, wake: &mut Waker) -> Result<Option<f64>>;
}

/// Cross-component wake requests gathered during one tick and applied
/// by the driver after it. `wake_at(c, t)` means "ensure component `c`
/// has an event no later than `t`": a parked component is scheduled at
/// `t`, an earlier existing event wins, a later one is pulled forward.
#[derive(Debug, Default)]
pub struct Waker {
    requests: Vec<(ComponentId, f64)>,
}

impl Waker {
    pub fn wake_at(&mut self, c: ComponentId, at: f64) {
        self.requests.push((c, at));
    }

    fn drain(&mut self) -> std::vec::Drain<'_, (ComponentId, f64)> {
        self.requests.drain(..)
    }
}

/// Driver-level event accounting (surfaced in the cluster's bench JSON).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct QueueStats {
    /// Events actually (re)scheduled (no-op `wake_at`s don't count).
    pub scheduled: u64,
    /// Events dispatched to a component tick.
    pub popped: u64,
    /// Lazily-deleted heap entries skipped on pop (an earlier `wake_at`
    /// superseded them). The naive-scan oracle never produces these.
    pub stale: u64,
}

/// One heap entry. Ordering is *inverted* (earliest time, then lowest
/// id, compares greatest) so Rust's max-heap pops the minimum; `gen`
/// implements lazy deletion and takes no part in the ordering.
#[derive(Clone, Copy, Debug)]
struct Entry {
    at: f64,
    id: ComponentId,
    gen: u64,
}

impl PartialEq for Entry {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}
impl Eq for Entry {}
impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Entry {
    fn cmp(&self, other: &Self) -> Ordering {
        // reversed: smaller (at, id) is "greater" for the max-heap
        other
            .at
            .total_cmp(&self.at)
            .then_with(|| other.id.cmp(&self.id))
    }
}

/// The deterministic min-heap event queue: at most one live event per
/// component (a `sched` mirror holds its time + generation; superseded
/// heap entries are skipped lazily on pop).
pub struct EventQueue {
    heap: BinaryHeap<Entry>,
    /// Per-component live event: `(time, generation)`.
    sched: Vec<Option<(f64, u64)>>,
    next_gen: u64,
    last_popped: f64,
    pub stats: QueueStats,
}

impl EventQueue {
    pub fn new(n_components: usize) -> EventQueue {
        EventQueue {
            heap: BinaryHeap::new(),
            sched: vec![None; n_components],
            next_gen: 0,
            last_popped: f64::NEG_INFINITY,
            stats: QueueStats::default(),
        }
    }

    /// Ensure component `id` has an event no later than `at`. Panics on
    /// NaN and on time travel (scheduling before the last popped time).
    pub fn schedule(&mut self, id: ComponentId, at: f64) {
        assert!(!at.is_nan(), "component {id}: NaN event time");
        assert!(
            at >= self.last_popped,
            "time travel: component {id} scheduled at {at} after the \
             clock reached {}",
            self.last_popped
        );
        if let Some((existing, _)) = self.sched[id] {
            if existing <= at {
                return; // an earlier (or equal) event already covers this
            }
        }
        self.next_gen += 1;
        self.sched[id] = Some((at, self.next_gen));
        self.heap.push(Entry {
            at,
            id,
            gen: self.next_gen,
        });
        self.stats.scheduled += 1;
    }

    /// Pop the next live event in `(time, id)` order; `None` drains.
    pub fn pop_next(&mut self) -> Option<(f64, ComponentId)> {
        while let Some(e) = self.heap.pop() {
            match self.sched[e.id] {
                Some((at, gen)) if gen == e.gen => {
                    debug_assert_eq!(at.to_bits(), e.at.to_bits());
                    self.sched[e.id] = None;
                    debug_assert!(e.at >= self.last_popped, "heap order violated");
                    self.last_popped = e.at;
                    self.stats.popped += 1;
                    return Some((e.at, e.id));
                }
                _ => self.stats.stale += 1, // superseded by a later schedule
            }
        }
        None
    }

    /// The component's currently scheduled event time, if any.
    pub fn scheduled_at(&self, id: ComponentId) -> Option<f64> {
        self.sched[id].map(|(at, _)| at)
    }

    /// The time of the most recently popped event.
    pub fn clock(&self) -> f64 {
        self.last_popped
    }
}

/// Drain the system to quiescence through the binary-heap queue: seed
/// each component's `next_tick`, then pop-and-tick in `(time, id)` order
/// until no component has an event scheduled.
pub fn drive<S>(components: &mut [Box<dyn Component<S> + '_>], sys: &mut S) -> Result<QueueStats> {
    let mut q = EventQueue::new(components.len());
    for (id, c) in components.iter().enumerate() {
        if let Some(at) = c.next_tick(sys) {
            q.schedule(id, at);
        }
    }
    let mut wake = Waker::default();
    while let Some((now, id)) = q.pop_next() {
        let next = components[id].tick(now, sys, &mut wake)?;
        if let Some(at) = next {
            q.schedule(id, at);
        }
        for (c, at) in wake.drain() {
            q.schedule(c, at);
        }
    }
    Ok(q.stats)
}

/// The retired lockstep driver, kept as the equivalence oracle: a naive
/// O(n) scan over every component's scheduled time per event, applying
/// the identical `(time, lowest id)` dispatch law and the identical
/// tick/waker semantics. Slow by design — its value is that it is
/// obviously correct, so `drive` can be pinned against it bit-for-bit
/// (the PR-5 dense-gather-oracle pattern).
pub fn drive_lockstep<S>(
    components: &mut [Box<dyn Component<S> + '_>],
    sys: &mut S,
) -> Result<QueueStats> {
    let mut sched: Vec<Option<f64>> = components.iter().map(|c| c.next_tick(sys)).collect();
    let mut stats = QueueStats::default();
    let mut last_popped = f64::NEG_INFINITY;
    stats.scheduled = sched.iter().flatten().count() as u64;
    let mut wake = Waker::default();
    loop {
        // earliest time wins; the first minimal index is the lowest id
        let mut pick: Option<(f64, ComponentId)> = None;
        for (id, s) in sched.iter().enumerate() {
            if let Some(at) = *s {
                if pick.map(|(best, _)| at < best).unwrap_or(true) {
                    pick = Some((at, id));
                }
            }
        }
        let Some((now, id)) = pick else {
            return Ok(stats);
        };
        assert!(now >= last_popped, "time travel in the lockstep oracle");
        last_popped = now;
        sched[id] = None;
        stats.popped += 1;
        let next = components[id].tick(now, sys, &mut wake)?;
        if let Some(at) = next {
            assert!(!at.is_nan() && at >= now, "component {id} scheduled the past");
            sched[id] = Some(at);
            stats.scheduled += 1;
        }
        for (c, at) in wake.drain() {
            assert!(!at.is_nan() && at >= now, "wake_at({c}) into the past");
            if sched[c].map(|existing| at < existing).unwrap_or(true) {
                sched[c] = Some(at);
                stats.scheduled += 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_then_id_order_regardless_of_insertion() {
        let mut q = EventQueue::new(5);
        // insertion order deliberately scrambled; ids 1/3/0 tie at t=2.0
        for (id, at) in [(4usize, 9.0f64), (1, 2.0), (2, 5.0), (3, 2.0), (0, 2.0)] {
            q.schedule(id, at);
        }
        let mut popped = Vec::new();
        while let Some(e) = q.pop_next() {
            popped.push(e);
        }
        assert_eq!(
            popped,
            vec![(2.0, 0), (2.0, 1), (2.0, 3), (5.0, 2), (9.0, 4)]
        );
        assert_eq!(q.stats.popped, 5);
        assert_eq!(q.stats.stale, 0);
    }

    #[test]
    fn wake_semantics_pull_forward_never_push_back() {
        let mut q = EventQueue::new(1);
        q.schedule(0, 5.0);
        q.schedule(0, 7.0); // later: no-op
        assert_eq!(q.scheduled_at(0), Some(5.0));
        q.schedule(0, 3.0); // earlier: supersedes
        assert_eq!(q.scheduled_at(0), Some(3.0));
        assert_eq!(q.pop_next(), Some((3.0, 0)));
        assert_eq!(q.pop_next(), None, "superseded entry must be skipped");
        assert_eq!(q.stats.stale, 1);
    }

    #[test]
    #[should_panic(expected = "time travel")]
    fn scheduling_the_past_panics() {
        let mut q = EventQueue::new(2);
        q.schedule(0, 10.0);
        q.pop_next();
        q.schedule(1, 9.0);
    }

    /// Toy component for driver-parity checks: fires at fixed offsets,
    /// appending `(time, id)` to the shared log.
    struct Beeper {
        id: ComponentId,
        times: Vec<f64>,
        next: usize,
    }

    impl Component<Vec<(f64, ComponentId)>> for Beeper {
        fn next_tick(&self, _sys: &Vec<(f64, ComponentId)>) -> Option<f64> {
            self.times.first().copied()
        }
        fn tick(
            &mut self,
            now: f64,
            sys: &mut Vec<(f64, ComponentId)>,
            _wake: &mut Waker,
        ) -> Result<Option<f64>> {
            sys.push((now, self.id));
            self.next += 1;
            Ok(self.times.get(self.next).copied())
        }
    }

    fn beepers(spec: &[&[f64]]) -> Vec<Box<dyn Component<Vec<(f64, ComponentId)>>>> {
        spec.iter()
            .enumerate()
            .map(|(id, times)| {
                Box::new(Beeper {
                    id,
                    times: times.to_vec(),
                    next: 0,
                }) as Box<dyn Component<Vec<(f64, ComponentId)>>>
            })
            .collect()
    }

    #[test]
    fn heap_and_lockstep_drivers_agree_on_interleaved_components() {
        let spec: &[&[f64]] = &[
            &[0.0, 1.0, 1.0, 4.0],
            &[0.0, 2.5],
            &[],           // starts parked, never woken: zero ticks
            &[1.0, 1.0, 3.0],
        ];
        let mut log_heap = Vec::new();
        drive(&mut beepers(spec), &mut log_heap).unwrap();
        let mut log_scan = Vec::new();
        drive_lockstep(&mut beepers(spec), &mut log_scan).unwrap();
        assert_eq!(log_heap, log_scan);
        // ties at t=0.0 and t=1.0 resolve to the lowest id in both
        assert_eq!(log_heap[0], (0.0, 0));
        assert_eq!(log_heap[1], (0.0, 1));
        assert!(!log_heap.iter().any(|&(_, id)| id == 2), "parked = no ticks");
    }
}

//! KV-cache management — since PR 2 a thin compatibility surface over the
//! top-level [`kvcache`](crate::kvcache) subsystem.
//!
//! The seed's dense `[L, H, S_max, Dh]` slot store (hard `n_slots` cap,
//! per-slot max_seq-sized buffers) is gone. The engine now talks to
//! [`PagedKvCache`]: a block allocator with per-request block tables, FP8
//! demotion of LRU-cold blocks under precision pressure, and a host
//! offload tier. This module re-exports the types under their historical
//! names so `coordinator::kv::{KvCacheManager, KvGeometry}` keeps working.

pub use crate::kvcache::{KvCacheStats, KvGeometry, KvPressureConfig, PagedKvCache};

/// The engine's KV manager — an alias for [`PagedKvCache`].
pub type KvCacheManager = PagedKvCache;

//! KV-cache management: slots + block accounting.
//!
//! The AOT executables use fixed-shape dense per-slot caches
//! (`[L, H, S_max, Dh]` f32), so physical storage here is slot-granular;
//! on top of it we keep PagedAttention-style **block accounting** (the
//! admission control signal): a request only holds as many blocks as its
//! current context needs, and the scheduler admits new work only when
//! blocks are available — exactly the mechanism that determines batch
//! size (and thus the paper's precision-pressure signal) in vLLM.

use anyhow::{bail, Result};

/// Geometry of the cache.
#[derive(Clone, Copy, Debug)]
pub struct KvGeometry {
    pub n_layers: usize,
    pub n_heads: usize,
    pub max_seq: usize,
    pub head_dim: usize,
    /// Tokens per accounting block.
    pub block_size: usize,
    /// Total blocks in the (simulated) device memory budget.
    pub total_blocks: usize,
    /// Physical slots (concurrent sequences).
    pub n_slots: usize,
}

impl KvGeometry {
    /// Floats per slot for one of K/V.
    pub fn slot_elems(&self) -> usize {
        self.n_layers * self.n_heads * self.max_seq * self.head_dim
    }

    pub fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_size)
    }
}

/// One physical slot's storage (host-side, fed to the executables).
pub struct Slot {
    /// K cache, layout [L, H, S, Dh] row-major.
    pub k: Vec<f32>,
    /// V cache, same layout.
    pub v: Vec<f32>,
    /// Valid context length.
    pub len: usize,
    pub in_use: bool,
}

/// The manager: slots + block budget.
pub struct KvCacheManager {
    pub geo: KvGeometry,
    slots: Vec<Slot>,
    free_blocks: usize,
    /// Blocks held per slot.
    held: Vec<usize>,
}

impl KvCacheManager {
    pub fn new(geo: KvGeometry) -> KvCacheManager {
        let slots = (0..geo.n_slots)
            .map(|_| Slot {
                k: vec![0.0; geo.slot_elems()],
                v: vec![0.0; geo.slot_elems()],
                len: 0,
                in_use: false,
            })
            .collect();
        KvCacheManager {
            free_blocks: geo.total_blocks,
            held: vec![0; geo.n_slots],
            slots,
            geo,
        }
    }

    /// Lightweight variant for the simulation backend: block accounting
    /// only, no physical storage.
    pub fn accounting_only(geo: KvGeometry) -> KvCacheManager {
        let slots = (0..geo.n_slots)
            .map(|_| Slot {
                k: Vec::new(),
                v: Vec::new(),
                len: 0,
                in_use: false,
            })
            .collect();
        KvCacheManager {
            free_blocks: geo.total_blocks,
            held: vec![0; geo.n_slots],
            slots,
            geo,
        }
    }

    pub fn free_blocks(&self) -> usize {
        self.free_blocks
    }

    pub fn free_slots(&self) -> usize {
        self.slots.iter().filter(|s| !s.in_use).count()
    }

    /// Can a request of `prompt_len` (+ headroom for one block of output)
    /// be admitted now?
    pub fn can_admit(&self, prompt_len: usize) -> bool {
        self.free_slots() > 0
            && self.free_blocks >= self.geo.blocks_for(prompt_len) + 1
    }

    /// Allocate a slot for a request; reserves blocks for the prompt.
    pub fn allocate(&mut self, prompt_len: usize) -> Result<usize> {
        if !self.can_admit(prompt_len) {
            bail!(
                "kv exhausted: {} free slots, {} free blocks",
                self.free_slots(),
                self.free_blocks
            );
        }
        let idx = self
            .slots
            .iter()
            .position(|s| !s.in_use)
            .expect("checked above");
        let blocks = self.geo.blocks_for(prompt_len) + 1;
        self.free_blocks -= blocks;
        self.held[idx] = blocks;
        let slot = &mut self.slots[idx];
        slot.in_use = true;
        slot.len = 0;
        Ok(idx)
    }

    /// Grow a slot's held blocks to cover `new_len` tokens; fails if the
    /// budget is exhausted (the engine must then preempt or stall).
    pub fn grow(&mut self, slot: usize, new_len: usize) -> Result<()> {
        if new_len > self.geo.max_seq {
            bail!("sequence length {new_len} exceeds max_seq {}", self.geo.max_seq);
        }
        let need = self.geo.blocks_for(new_len);
        if need > self.held[slot] {
            let extra = need - self.held[slot];
            if extra > self.free_blocks {
                bail!("kv block budget exhausted growing slot {slot}");
            }
            self.free_blocks -= extra;
            self.held[slot] = need;
        }
        self.slots[slot].len = new_len;
        Ok(())
    }

    /// Release a slot and all its blocks.
    pub fn release(&mut self, slot: usize) {
        assert!(self.slots[slot].in_use, "releasing free slot {slot}");
        self.free_blocks += self.held[slot];
        self.held[slot] = 0;
        let s = &mut self.slots[slot];
        s.in_use = false;
        s.len = 0;
        // storage intentionally not zeroed: the length mask guards reads,
        // and new prefills overwrite (mirrors real paged caches)
    }

    pub fn slot(&self, idx: usize) -> &Slot {
        &self.slots[idx]
    }

    pub fn slot_mut(&mut self, idx: usize) -> &mut Slot {
        &mut self.slots[idx]
    }

    /// Scatter new K/V rows for `count` tokens starting at `start_pos`.
    /// `new_k`/`new_v` layout: [L, T, H, Dh] (prefill) flattened.
    pub fn scatter_prefill(
        &mut self,
        slot: usize,
        start_pos: usize,
        count: usize,
        new_k: &[f32],
        new_v: &[f32],
    ) {
        let g = self.geo;
        let (l, h, s, dh) = (g.n_layers, g.n_heads, g.max_seq, g.head_dim);
        debug_assert_eq!(new_k.len(), l * count * h * dh);
        let dst = &mut self.slots[slot];
        for li in 0..l {
            for t in 0..count {
                for hi in 0..h {
                    let src = ((li * count + t) * h + hi) * dh;
                    let pos = start_pos + t;
                    let d = ((li * h + hi) * s + pos) * dh;
                    dst.k[d..d + dh].copy_from_slice(&new_k[src..src + dh]);
                    dst.v[d..d + dh].copy_from_slice(&new_v[src..src + dh]);
                }
            }
        }
    }

    /// Scatter one decode token's K/V. `new_k` layout: [L, H, Dh] for this
    /// sequence (already sliced out of the batch output).
    pub fn scatter_decode(&mut self, slot: usize, pos: usize, new_k: &[f32], new_v: &[f32]) {
        let g = self.geo;
        let (l, h, s, dh) = (g.n_layers, g.n_heads, g.max_seq, g.head_dim);
        debug_assert_eq!(new_k.len(), l * h * dh);
        let dst = &mut self.slots[slot];
        for li in 0..l {
            for hi in 0..h {
                let src = (li * h + hi) * dh;
                let d = ((li * h + hi) * s + pos) * dh;
                dst.k[d..d + dh].copy_from_slice(&new_k[src..src + dh]);
                dst.v[d..d + dh].copy_from_slice(&new_v[src..src + dh]);
            }
        }
    }

    /// Gather the full padded batch cache for a decode call:
    /// output layout [B, L, H, S, Dh] with B = `slots.len()`.
    pub fn gather_batch(&self, slots: &[usize], out_k: &mut Vec<f32>, out_v: &mut Vec<f32>) {
        let per = self.geo.slot_elems();
        out_k.clear();
        out_v.clear();
        out_k.reserve(per * slots.len());
        out_v.reserve(per * slots.len());
        for &idx in slots {
            out_k.extend_from_slice(&self.slots[idx].k);
            out_v.extend_from_slice(&self.slots[idx].v);
        }
    }

    /// Memory utilization in [0,1] — a precision-pressure signal.
    pub fn block_utilization(&self) -> f64 {
        1.0 - self.free_blocks as f64 / self.geo.total_blocks as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn geo() -> KvGeometry {
        KvGeometry {
            n_layers: 2,
            n_heads: 2,
            max_seq: 32,
            head_dim: 4,
            block_size: 8,
            total_blocks: 16,
            n_slots: 3,
        }
    }

    #[test]
    fn allocate_grow_release_accounting() {
        let mut kv = KvCacheManager::accounting_only(geo());
        assert_eq!(kv.free_blocks(), 16);
        let s0 = kv.allocate(10).unwrap(); // 2 blocks prompt + 1 headroom
        assert_eq!(kv.free_blocks(), 13);
        kv.grow(s0, 10).unwrap(); // within held
        assert_eq!(kv.free_blocks(), 13);
        kv.grow(s0, 25).unwrap(); // 4 blocks needed, held 3 -> +1
        assert_eq!(kv.free_blocks(), 12);
        kv.release(s0);
        assert_eq!(kv.free_blocks(), 16);
        assert_eq!(kv.free_slots(), 3);
    }

    #[test]
    fn admission_limits() {
        let mut kv = KvCacheManager::accounting_only(geo());
        let _a = kv.allocate(32).unwrap(); // 4+1 = 5 blocks
        let _b = kv.allocate(32).unwrap(); // 5 blocks (10 total)
        let _c = kv.allocate(32).unwrap(); // 5 blocks (15) — slots full now
        assert_eq!(kv.free_slots(), 0);
        assert!(!kv.can_admit(1));
        assert!(kv.allocate(1).is_err());
    }

    #[test]
    fn grow_respects_max_seq_and_budget() {
        let mut kv = KvCacheManager::accounting_only(geo());
        let s = kv.allocate(8).unwrap();
        assert!(kv.grow(s, 33).is_err()); // > max_seq
        // exhaust budget with another request
        let _other = kv.allocate(32).unwrap();
        let _other2 = kv.allocate(32).unwrap();
        // 16 - 2 - 5 - 5 = 4 free; growing s to 32 needs 4 blocks held vs 2
        // held -> +2, fine; then release checks
        kv.grow(s, 32).unwrap();
        assert_eq!(kv.free_blocks(), 2);
    }

    #[test]
    fn scatter_gather_roundtrip() {
        let mut kv = KvCacheManager::new(geo());
        let s = kv.allocate(4).unwrap();
        let g = geo();
        let (l, h, dh) = (g.n_layers, g.n_heads, g.head_dim);
        // prefill 3 tokens with recognizable values
        let count = 3;
        let mut nk = vec![0.0f32; l * count * h * dh];
        for (i, v) in nk.iter_mut().enumerate() {
            *v = i as f32;
        }
        let nv: Vec<f32> = nk.iter().map(|x| -x).collect();
        kv.scatter_prefill(s, 0, count, &nk, &nv);
        kv.grow(s, count).unwrap();
        // token at layer 1, t=2, head 1 should be at k[(1*2+1)*32+2]*4
        let slot = kv.slot(s);
        let src = ((1 * count + 2) * h + 1) * dh;
        let dst = ((1 * h + 1) * g.max_seq + 2) * dh;
        assert_eq!(slot.k[dst..dst + dh], nk[src..src + dh]);
        assert_eq!(slot.v[dst], -nk[src]);

        // decode token at pos 3
        let dk: Vec<f32> = (0..l * h * dh).map(|i| 100.0 + i as f32).collect();
        let dv: Vec<f32> = dk.iter().map(|x| x + 0.5).collect();
        kv.scatter_decode(s, 3, &dk, &dv);
        let slot = kv.slot(s);
        let d = ((0 * h + 0) * g.max_seq + 3) * dh;
        assert_eq!(slot.k[d], 100.0);

        // gather one-slot batch
        let mut bk = Vec::new();
        let mut bv = Vec::new();
        kv.gather_batch(&[s], &mut bk, &mut bv);
        assert_eq!(bk.len(), kv.geo.slot_elems());
        assert_eq!(bk[dst], nk[src]);
    }

    #[test]
    fn utilization_signal() {
        let mut kv = KvCacheManager::accounting_only(geo());
        assert_eq!(kv.block_utilization(), 0.0);
        let _s = kv.allocate(32).unwrap();
        assert!((kv.block_utilization() - 5.0 / 16.0).abs() < 1e-12);
    }
}

//! Request routing across engine replicas.
//!
//! The cluster front door sees only cheap per-replica load signals (a
//! [`ReplicaSnapshot`]) and must pick a replica for each arriving request
//! before its prompt touches any engine. Policies are deliberately
//! stateless apart from a cursor/RNG, so dispatch is deterministic and
//! replayable — the same property the engines get from the virtual clock.

use crate::util::rng::Pcg64;

/// How the cluster picks a replica for each arriving request.
///
/// # Examples
///
/// ```
/// use nestedfp::coordinator::router::{ReplicaSnapshot, Router, RoutingPolicy};
///
/// let mut router = Router::new(RoutingPolicy::RoundRobin);
/// let replicas = vec![ReplicaSnapshot::default(); 3];
/// assert_eq!(router.pick(&replicas), 0);
/// assert_eq!(router.pick(&replicas), 1);
/// assert_eq!(router.pick(&replicas), 2);
/// assert_eq!(router.pick(&replicas), 0);
/// ```
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RoutingPolicy {
    /// Cycle through replicas in index order.
    RoundRobin,
    /// Uniform-random replica from a fixed seed (a deterministic
    /// baseline: same seed, same dispatch sequence).
    Random { seed: u64 },
    /// The replica with the most free KV blocks — memory headroom is
    /// what actually bounds batch growth in a vLLM-style engine.
    LeastLoadedKv,
    /// The replica with the most SLO headroom: TPOT EWMA vs target,
    /// discounted by queue depth, KV pressure, and FP8 demotion. This is
    /// the policy that lets the cluster steer new work *away* from
    /// replicas the surge controller has already demoted.
    SloHeadroom,
}

/// What the router sees of one replica at dispatch time.
///
/// `PartialEq` because the cluster's event-core driver keeps a snapshot
/// cache and cross-checks it against freshly built snapshots in debug
/// builds.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ReplicaSnapshot {
    /// Free device budget in f32-equivalent blocks (FP8 demotion shows up
    /// here: a replica storing cold KV at half the bytes has more free).
    pub free_kv_blocks: usize,
    pub total_kv_blocks: usize,
    /// Unfinished requests owned by the replica.
    pub active_requests: usize,
    /// Requests waiting for admission, mid-prefill, or host-preempted.
    pub queued_requests: usize,
    /// EWMA of observed TPOT, seconds (0 until the first observation).
    pub ewma_tpot: f64,
    /// TPOT SLO target, seconds.
    pub tpot_target: f64,
    /// Replica currently demoted to FP8 by the cluster controller.
    pub forced_fp8: bool,
    /// Device blocks currently stored demoted to FP8 (quality debt).
    pub fp8_kv_blocks: usize,
    /// Blocks preempted to the replica's host tier (latency debt: each
    /// one implies a pending fetch before its sequence decodes again).
    pub host_kv_blocks: usize,
    /// Sequences currently decoding over host-resident KV (attention
    /// piggybacked). Progress, but slow-lane progress: each one drags
    /// the batch's TPOT toward the host attention law, so the router
    /// discounts replicas serving many of them.
    pub host_serving_lanes: usize,
    /// Active tensor-parallel degree (1 = unsharded).
    pub tp_degree: usize,
    /// Replica inside a reshard window (draining or repartitioning) —
    /// it admits nothing, so the router must not send it work.
    pub resharding: bool,
}

/// SLO-headroom score: higher is a better dispatch target. Ties are
/// broken by the caller in favour of the lowest index.
///
/// Public because the cluster [`autopilot`](super::autopilot) orders its
/// escalation ladder by the same signal: the replica with the *least*
/// headroom is demoted to FP8 first, and the one with the most is
/// promoted back to FP16 first — router and controller agree on what
/// "pressured" means.
pub fn slo_headroom(s: &ReplicaSnapshot) -> f64 {
    let target = if s.tpot_target > 0.0 { s.tpot_target } else { 1.0 };
    let headroom = ((target - s.ewma_tpot) / target).clamp(-1.0, 1.0);
    let blocks = s.total_kv_blocks.max(1) as f64;
    let kv_frac = if s.total_kv_blocks > 0 {
        s.free_kv_blocks as f64 / blocks
    } else {
        0.0
    };
    let queue = (s.active_requests + s.queued_requests) as f64;
    // paged-cache debts: host-resident blocks owe a fetch (hard latency),
    // FP8-demoted blocks owe quality — steer new work away from both
    let host_debt = s.host_kv_blocks as f64 / blocks;
    let fp8_debt = s.fp8_kv_blocks as f64 / blocks;
    headroom + 0.5 * kv_frac - 0.25 * queue
        - if s.forced_fp8 { 0.25 } else { 0.0 }
        - 0.3 * host_debt
        - 0.1 * fp8_debt
        // host-piggybacked lanes are served, not queued, so they weigh
        // less than a queued request — but more than nothing: they hold
        // the decode batch on the slower host attention law
        - 0.15 * s.host_serving_lanes as f64
        // a resharding replica admits nothing until its window closes;
        // the penalty dwarfs every other term so both the router and the
        // autopilot's ladder ordering treat it as the worst target
        - if s.resharding { 4.0 } else { 0.0 }
}

/// A routing-policy instance (cursor / RNG state included).
pub struct Router {
    pub policy: RoutingPolicy,
    rr: usize,
    rng: Pcg64,
}

impl Router {
    pub fn new(policy: RoutingPolicy) -> Router {
        let seed = match policy {
            RoutingPolicy::Random { seed } => seed,
            _ => 0,
        };
        Router {
            policy,
            rr: 0,
            rng: Pcg64::new(seed, 0x7071),
        }
    }

    /// Pick a replica index for the next request.
    ///
    /// Replicas mid-reshard admit nothing, so every policy routes around
    /// them; if the whole fleet is resharding the router falls back to
    /// considering everyone (the request queues at its replica until the
    /// window closes — nothing is dropped).
    ///
    /// Deterministic for every policy (the `Random` policy draws from a
    /// fixed-seed PCG64, so replays are bit-identical). Panics if
    /// `replicas` is empty.
    pub fn pick(&mut self, replicas: &[ReplicaSnapshot]) -> usize {
        assert!(!replicas.is_empty(), "router has no replicas");
        let mut eligible: Vec<usize> = (0..replicas.len())
            .filter(|&i| !replicas[i].resharding)
            .collect();
        if eligible.is_empty() {
            eligible = (0..replicas.len()).collect();
        }
        match self.policy {
            RoutingPolicy::RoundRobin => {
                // with no reshard in flight `eligible` is the identity
                // mapping and this is the classic `rr % n` cursor
                let i = eligible[self.rr % eligible.len()];
                self.rr += 1;
                i
            }
            RoutingPolicy::Random { .. } => eligible[self.rng.index(eligible.len())],
            RoutingPolicy::LeastLoadedKv => {
                let mut best = eligible[0];
                for &i in &eligible[1..] {
                    if replicas[i].free_kv_blocks > replicas[best].free_kv_blocks {
                        best = i;
                    }
                }
                best
            }
            RoutingPolicy::SloHeadroom => {
                let mut best = eligible[0];
                let mut best_score = slo_headroom(&replicas[best]);
                for &i in &eligible[1..] {
                    let score = slo_headroom(&replicas[i]);
                    if score > best_score {
                        best = i;
                        best_score = score;
                    }
                }
                best
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn snap(free: usize, total: usize, active: usize, ewma: f64) -> ReplicaSnapshot {
        ReplicaSnapshot {
            free_kv_blocks: free,
            total_kv_blocks: total,
            active_requests: active,
            queued_requests: 0,
            ewma_tpot: ewma,
            tpot_target: 0.0333,
            forced_fp8: false,
            fp8_kv_blocks: 0,
            host_kv_blocks: 0,
            host_serving_lanes: 0,
            tp_degree: 1,
            resharding: false,
        }
    }

    #[test]
    fn round_robin_cycles_deterministically() {
        let mut r = Router::new(RoutingPolicy::RoundRobin);
        let snaps = vec![ReplicaSnapshot::default(); 3];
        let picks: Vec<usize> = (0..7).map(|_| r.pick(&snaps)).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2, 0]);
    }

    #[test]
    fn random_policy_is_deterministic_per_seed() {
        let snaps = vec![ReplicaSnapshot::default(); 4];
        let seq = |seed: u64| -> Vec<usize> {
            let mut r = Router::new(RoutingPolicy::Random { seed });
            (0..64).map(|_| r.pick(&snaps)).collect()
        };
        assert_eq!(seq(9), seq(9), "same seed must replay identically");
        assert_ne!(seq(9), seq(10), "different seeds should diverge");
        // all replicas get traffic
        let hit: std::collections::HashSet<usize> = seq(9).into_iter().collect();
        assert_eq!(hit.len(), 4);
    }

    #[test]
    fn least_loaded_picks_most_free_kv_blocks() {
        let mut r = Router::new(RoutingPolicy::LeastLoadedKv);
        let snaps = vec![snap(10, 64, 3, 0.0), snap(40, 64, 1, 0.0), snap(25, 64, 2, 0.0)];
        assert_eq!(r.pick(&snaps), 1);
        // ties break toward the lowest index
        let tied = vec![snap(30, 64, 0, 0.0), snap(30, 64, 0, 0.0)];
        assert_eq!(r.pick(&tied), 0);
    }

    #[test]
    fn slo_headroom_avoids_pressured_and_demoted_replicas() {
        let mut r = Router::new(RoutingPolicy::SloHeadroom);
        // replica 0 is near its TPOT target, replica 1 is comfortable
        let snaps = vec![snap(32, 64, 2, 0.032), snap(32, 64, 2, 0.010)];
        assert_eq!(r.pick(&snaps), 1);
        // all else equal, a demoted (forced-FP8) replica loses the tie
        let mut a = snap(32, 64, 2, 0.010);
        a.forced_fp8 = true;
        let b = snap(32, 64, 2, 0.010);
        assert_eq!(r.pick(&[a, b]), 1);
        // but a big queue on the healthy replica outweighs the demotion
        let mut busy = b;
        busy.queued_requests = 6;
        assert_eq!(r.pick(&[a, busy]), 0);
    }

    #[test]
    fn every_policy_routes_around_a_resharding_replica() {
        let mut draining = snap(64, 64, 0, 0.0);
        draining.resharding = true;
        let healthy = snap(10, 64, 5, 0.030);
        for policy in [
            RoutingPolicy::RoundRobin,
            RoutingPolicy::Random { seed: 7 },
            RoutingPolicy::LeastLoadedKv,
            RoutingPolicy::SloHeadroom,
        ] {
            let mut r = Router::new(policy);
            for _ in 0..8 {
                assert_eq!(
                    r.pick(&[draining, healthy]),
                    1,
                    "{policy:?} routed into a reshard window"
                );
            }
        }
        // whole fleet resharding: fall back to considering everyone
        let mut r = Router::new(RoutingPolicy::RoundRobin);
        assert_eq!(r.pick(&[draining, draining]), 0);
        assert_eq!(r.pick(&[draining, draining]), 1);
    }

    #[test]
    fn slo_headroom_penalizes_paged_debts() {
        let mut r = Router::new(RoutingPolicy::SloHeadroom);
        // all else equal, pending host fetches lose the tie
        let clean = snap(32, 64, 2, 0.010);
        let mut hosty = clean;
        hosty.host_kv_blocks = 16;
        assert_eq!(r.pick(&[hosty, clean]), 1);
        // FP8-demoted KV is a milder debt but still breaks ties
        let mut demoted = clean;
        demoted.fp8_kv_blocks = 32;
        assert_eq!(r.pick(&[demoted, clean]), 1);
        // host debt weighs more than the same fraction of fp8 debt
        let mut fp8_only = clean;
        fp8_only.fp8_kv_blocks = 16;
        assert_eq!(r.pick(&[hosty, fp8_only]), 1);
    }

    #[test]
    fn slo_headroom_discounts_host_serving_lanes() {
        let mut r = Router::new(RoutingPolicy::SloHeadroom);
        // all else equal, a replica piggybacking lanes on its host tier
        // loses the tie — but a lane weighs less than a queued request
        let clean = snap(32, 64, 2, 0.010);
        let mut piggy = clean;
        piggy.host_serving_lanes = 2;
        assert_eq!(r.pick(&[piggy, clean]), 1);
        let mut queuey = clean;
        queuey.queued_requests = 2;
        assert!(
            slo_headroom(&piggy) > slo_headroom(&queuey),
            "a served host lane must score above a queued request"
        );
    }
}

//! The serving engine: event loop over (admission → precision decision →
//! scheduling → execution → postprocessing), generic over the backend and
//! the clock.
//!
//! Two driving modes:
//! * [`Engine::run`] owns the whole workload (arrival simulation included)
//!   and loops to completion — the single-replica experiments.
//! * [`Engine::submit`] + [`Engine::step`] expose one iteration at a time
//!   so an external driver (the [`cluster`](super::cluster) router) can
//!   interleave many replicas on a shared virtual clock.

use anyhow::{anyhow, Result};

use crate::kvcache::KvPressureConfig;
use crate::telemetry::trace::{self, Kind};

use super::backend::{Backend, StepRun};
use super::kv::KvCacheManager;
use super::metrics::Metrics;
use super::precision::{
    LayerSchedule, Precision, PrecisionController, PrecisionPolicy, SloConfig,
};
use super::request::{FinishReason, Request, RequestId, RequestState};
use super::scheduler::{IterationPlan, Scheduler};

/// Engine construction parameters.
#[derive(Clone, Debug)]
pub struct EngineConfig {
    pub policy: PrecisionPolicy,
    pub slo: SloConfig,
    /// Use physical KV storage (real backend) or accounting only (sim).
    pub physical_kv: bool,
    /// Stop after this many iterations (safety valve; 0 = unlimited).
    pub max_iterations: usize,
    /// Paged-KV policy: admission mode, FP8 demotion, host-offload tier.
    pub kv: KvPressureConfig,
    /// Devices in the replica's shard pool (the parallelism ladder's
    /// ceiling; see [`crate::shard::ShardPlan`]). 1 = the pre-shard-layer
    /// world: no reshards possible, every run bit-identical to before.
    pub devices: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            policy: PrecisionPolicy::Dual,
            slo: SloConfig::default(),
            physical_kv: true,
            max_iterations: 0,
            kv: KvPressureConfig::default(),
            devices: 1,
        }
    }
}

/// A finished request's user-visible output.
#[derive(Clone, Debug)]
pub struct CompletedRequest {
    pub id: u64,
    pub tokens: Vec<i32>,
    pub ttft_s: f64,
    pub mean_tpot_s: f64,
}

/// Outcome of one externally driven iteration (see [`Engine::step`]).
pub struct EngineStep {
    /// Whether any work executed. `false` means nothing was runnable:
    /// queued requests exist but cannot be admitted and no decode is in
    /// flight — the driver must advance time (next arrival) or give up.
    pub ran: bool,
    /// Precision decided for the iteration (recorded even when idle, so
    /// external drivers can keep mode timelines identical to `run`'s).
    pub fp8: bool,
    /// Clock advance this iteration, seconds (0 when idle).
    pub latency: f64,
    /// Worst per-sequence inter-token gap of this iteration's decode
    /// batch, seconds (`None` for prefill/idle iterations). External
    /// control loops (the cluster autopilot's sliding-window SLO tracker)
    /// sample this as their online TPOT signal.
    pub tpot_worst: Option<f64>,
    /// Requests that finished during the iteration.
    pub completions: Vec<CompletedRequest>,
}

/// Outcome of a full run.
pub struct RunReport {
    pub metrics: Metrics,
    pub controller: PrecisionController,
    pub iterations: usize,
    /// (engine time, precision was fp8) switch timeline.
    pub mode_timeline: Vec<(f64, bool)>,
    /// Per-request outputs (generation + latency).
    pub completions: Vec<CompletedRequest>,
}

/// The engine.
pub struct Engine<B: Backend> {
    pub backend: B,
    pub kv: KvCacheManager,
    pub scheduler: Scheduler,
    pub controller: PrecisionController,
    cfg: EngineConfig,
    requests: Vec<Request>,
    now: f64,
    /// Reshard drain mode: no new admissions (queued requests wait),
    /// in-flight requests keep running to completion.
    admission_frozen: bool,
    /// Telemetry track id for this engine's trace events (the replica
    /// index in a cluster; 0 standalone). Pure observation — never read
    /// by any scheduling decision.
    trace_track: u32,
    /// Iteration counter used only as the trace-span correlator for
    /// [`Kind::Step`]; advances only while tracing is enabled.
    steps: u64,
}

impl<B: Backend> Engine<B> {
    pub fn new(backend: B, cfg: EngineConfig) -> Engine<B> {
        let geo = backend.geometry();
        let kv = if cfg.physical_kv {
            KvCacheManager::new(geo, cfg.kv)
        } else {
            KvCacheManager::accounting_only(geo, cfg.kv)
        };
        let scheduler = Scheduler::new(backend.prefill_chunks(), backend.max_decode_batch());
        let controller = PrecisionController::new(cfg.policy, cfg.slo);
        Engine {
            backend,
            kv,
            scheduler,
            controller,
            cfg,
            requests: Vec::new(),
            now: 0.0,
            admission_frozen: false,
            trace_track: 0,
            steps: 0,
        }
    }

    /// Set the telemetry track this engine's trace events attribute to
    /// (the cluster assigns each replica its index).
    pub fn set_trace_track(&mut self, track: u32) {
        self.trace_track = track;
    }

    pub fn now(&self) -> f64 {
        self.now
    }

    /// Hand a request to the engine. The engine does not simulate the
    /// arrival time of submitted requests — external drivers must call
    /// this only once their clock has reached `r.arrival` (after
    /// [`Engine::set_clock`] when the replica was idle).
    pub fn submit(&mut self, r: Request) {
        if trace::enabled() {
            trace::instant(self.trace_track, Kind::Arrival, r.arrival, r.id, 0);
            trace::begin(self.trace_track, Kind::Queue, r.arrival, r.id, 0);
        }
        self.requests.push(r);
    }

    /// Unfinished requests currently owned by the engine.
    pub fn active_requests(&self) -> usize {
        self.requests.iter().filter(|r| !r.is_finished()).count()
    }

    /// No unfinished work at all — the event core parks idle replicas
    /// (no scheduled event) until an arrival wakes them, and counts any
    /// event delivered to an idle replica as a contract violation.
    pub fn is_idle(&self) -> bool {
        self.active_requests() == 0
    }

    /// Requests waiting for KV capacity: queued for admission,
    /// mid-prefill, or preempted to the host tier — the controller's
    /// queue-pressure signal, and the router's load signal.
    /// Host-piggybacked sequences ([`RequestState::HostDecoding`]) are
    /// *not* waiting — they generate every iteration — so they count as
    /// served, not queued.
    pub fn queued_requests(&self) -> usize {
        self.requests
            .iter()
            .filter(|r| {
                r.state == RequestState::Queued
                    || r.state == RequestState::Offloaded
                    || (r.state == RequestState::Prefilling && r.remaining_prompt() > 0)
            })
            .count()
    }

    /// Requests currently decoding over host-resident KV (piggybacked
    /// attention). The router folds this into its replica snapshot:
    /// host-served lanes are progress, but slower progress — a headroom
    /// signal, not a queue signal.
    pub fn host_serving_requests(&self) -> usize {
        self.requests
            .iter()
            .filter(|r| r.state == RequestState::HostDecoding)
            .count()
    }

    /// Admitted (in-flight) unfinished requests: everything past the
    /// queue — prefilling, decoding, or host-offloaded. The reshard
    /// drain completes when this reaches zero (queued requests survive
    /// the window; they are admitted again at resume).
    pub fn admitted_requests(&self) -> usize {
        self.requests
            .iter()
            .filter(|r| !r.is_finished() && r.state != RequestState::Queued)
            .count()
    }

    /// Freeze (or thaw) admission for a reshard drain window. While
    /// frozen the scheduler never admits queued requests and the
    /// admission-assist/offload machinery stands down, but in-flight
    /// work keeps stepping normally.
    pub fn set_admission_frozen(&mut self, frozen: bool) {
        self.admission_frozen = frozen;
    }

    pub fn admission_frozen(&self) -> bool {
        self.admission_frozen
    }

    /// Fast-forward the engine clock (never moves backwards).
    pub fn set_clock(&mut self, t: f64) {
        if t > self.now {
            self.now = t;
        }
    }

    /// The engine's construction parameters.
    pub fn config(&self) -> &EngineConfig {
        &self.cfg
    }

    /// Install (or clear) a per-layer precision schedule on both the
    /// controller (which walks its demotion count) and the backend
    /// (which serves/costs each layer at its scheduled format). `None`
    /// — the default — keeps every legacy path bit-identical.
    pub fn set_layer_schedule(&mut self, s: Option<LayerSchedule>) {
        self.backend.set_layer_schedule(s.as_ref());
        self.controller.set_schedule(s);
    }

    /// Execute one iteration: precision decision → plan → execute →
    /// harvest. `imminent_arrivals` is the driver's count of requests due
    /// within the next ~20 ms (part of the controller's load signal;
    /// [`Engine::run`] derives it from its own pending queue).
    ///
    /// Returns `ran == false` when nothing was runnable; the engine clock
    /// does not advance in that case and the driver must move time
    /// forward itself (typically to the next arrival).
    pub fn step(&mut self, imminent_arrivals: usize, metrics: &mut Metrics) -> Result<EngineStep> {
        let t0 = self.now;

        // ---- host tier: resume offloaded sequences that now fit ----
        // (runs even during a reshard drain: offloaded sequences are
        // in-flight work the drain must keep alive, not new admissions)
        self.try_resume()?;
        // ---- paged admission assist: demote cold blocks (and at the
        // limit preempt a sequence to the host tier) so the oldest
        // queued request can be admitted instead of stalling ---------
        if !self.admission_frozen {
            self.admission_assist()?;
        }

        // ---- precision decision -----------------------------------
        // load signal: queued + still-prefilling requests (each one
        // means imminent prefill iterations that stretch running
        // sequences' inter-token gaps), plus imminent arrivals
        let mut queue_depth = self.queued_requests() + imminent_arrivals;
        // prefill-token backlog is the leading indicator of decode
        // gap growth: every 192 backlog tokens counts as extra load
        let backlog_tokens: usize = self
            .requests
            .iter()
            .filter(|r| !r.is_finished())
            .map(|r| r.remaining_prompt())
            .sum();
        let decoding_now = self.requests.iter().any(|r| {
            matches!(
                r.state,
                RequestState::Decoding | RequestState::HostDecoding
            )
        });
        if decoding_now {
            queue_depth += backlog_tokens / 192;
        }
        let precision = self
            .controller
            .decide(queue_depth, self.kv.block_utilization());
        let is_fp8 = precision == Precision::Fp8;
        // precision pressure couples the controller to the KV cache.
        // Under a per-layer schedule the demotion watermark tightens
        // with the *fraction* of demoted layers (elastic KV resizing,
        // MorphServe-style); without one the legacy binary FP8 flag
        // drives the same knob, bit-identically to before.
        match self.controller.demoted_fraction() {
            Some(frac) => {
                self.kv.set_demoted_layer_fraction(frac);
                self.backend.set_layer_schedule(self.controller.schedule());
            }
            None => self.kv.set_precision_pressure(is_fp8),
        }
        self.kv.maintain();

        // ---- plan & execute ---------------------------------------
        let mut tpot_worst = None;
        let plan = if self.admission_frozen {
            self.scheduler.plan_frozen(&self.requests, &self.kv)
        } else {
            self.scheduler.plan(&self.requests, &self.kv)
        };
        match plan {
            IterationPlan::Idle => {
                // blocked on KV space with decodes all finished — the
                // driver must advance time (next arrival) to make progress
                return Ok(EngineStep {
                    ran: false,
                    fp8: is_fp8,
                    latency: self.now - t0,
                    tpot_worst: None,
                    completions: Vec::new(),
                });
            }
            IterationPlan::Prefill { id, chunk } => {
                self.run_prefill(id, chunk, precision, metrics)?;
            }
            IterationPlan::Decode { ids } => {
                tpot_worst = Some(self.run_decode(&ids, precision, metrics)?);
            }
        }

        // ---- harvest finished requests ----------------------------
        let mut completions: Vec<CompletedRequest> = Vec::new();
        for r in &mut self.requests {
            if r.state == RequestState::Finished && r.slot.is_some() {
                let slot = r.slot.take().unwrap();
                self.kv.release(slot);
                metrics.record_request(r);
                let ttft = r.first_token_at.map(|t| t - r.arrival).unwrap_or(0.0);
                let mean_tpot = match (r.first_token_at, r.finished_at) {
                    (Some(f), Some(d)) if r.generated.len() > 1 => {
                        (d - f) / (r.generated.len() - 1) as f64
                    }
                    _ => 0.0,
                };
                completions.push(CompletedRequest {
                    id: r.id,
                    tokens: r.generated.clone(),
                    ttft_s: ttft,
                    mean_tpot_s: mean_tpot,
                });
            }
        }
        // drop finished request bodies to keep the table small
        self.requests.retain(|r| !r.is_finished());
        let kv_stats = self.kv.stats();
        if trace::enabled() {
            // metrics still holds last iteration's cumulative counter,
            // so the difference is exactly this iteration's demotions
            let demoted = kv_stats.demoted_blocks.saturating_sub(metrics.kv_demoted_blocks);
            if demoted > 0 {
                trace::instant(self.trace_track, Kind::KvDemote, self.now, 0, demoted as i64);
            }
            self.steps += 1;
            trace::begin(self.trace_track, Kind::Step, t0, self.steps, is_fp8 as i64);
            trace::end(self.trace_track, Kind::Step, self.now, self.steps, is_fp8 as i64);
        }
        metrics.observe_kv(&kv_stats);

        Ok(EngineStep {
            ran: true,
            fp8: is_fp8,
            latency: self.now - t0,
            tpot_worst,
            completions,
        })
    }

    /// Fetch host-resident sequences back to the device (oldest arrival
    /// first — FCFS, younger sequences never jump the fetch queue),
    /// charging transfer latency to the engine clock. Both host states
    /// resume here: parked `Offloaded` sequences and piggybacked
    /// `HostDecoding` ones — placement is reversible, and the device is
    /// always the better home once `can_fetch` says there is room (the
    /// resume-headroom margin keeps this from ping-ponging with the
    /// preemption path).
    fn try_resume(&mut self) -> Result<()> {
        loop {
            let next = self
                .requests
                .iter()
                .filter(|r| {
                    matches!(
                        r.state,
                        RequestState::Offloaded | RequestState::HostDecoding
                    )
                })
                .min_by(|a, b| a.arrival.partial_cmp(&b.arrival).unwrap())
                .map(|r| {
                    (
                        r.id,
                        r.slot.expect("offloaded request without kv seq"),
                        r.context_len(),
                    )
                });
            let Some((id, seq, ctx)) = next else {
                return Ok(());
            };
            if !self.kv.can_fetch(seq) {
                return Ok(());
            }
            let dt = self.kv.fetch_sequence(seq)?;
            self.now += dt;
            // cover the next scatter position (the preemption may have
            // skipped this sequence's growth turn)
            self.kv.grow(seq, ctx.min(self.kv.geo.max_seq))?;
            self.request_mut(id).state = RequestState::Decoding;
            trace::end(self.trace_track, Kind::Offload, self.now, id, 0);
        }
    }

    /// If the oldest queued request does not fit, demote cold blocks; at
    /// the limit, preempt one decoding sequence to the host tier
    /// (SLO-offload style: admit past device capacity, pay in transfer
    /// latency rather than queueing delay).
    fn admission_assist(&mut self) -> Result<()> {
        let oldest = self
            .requests
            .iter()
            .filter(|r| r.state == RequestState::Queued)
            .min_by(|a, b| a.arrival.partial_cmp(&b.arrival).unwrap())
            .map(|r| (r.prompt.len(), r.max_new_tokens));
        let Some((plen, max_new)) = oldest else {
            return Ok(());
        };
        let len = self.kv.admit_len(plen, max_new);
        if self.kv.relieve_for_admit(len) {
            return Ok(());
        }
        if !self.kv.policy().offload_enabled {
            return Ok(());
        }
        // bound preemption churn: one admission preemption wave in flight
        // at a time (host-piggybacked sequences count — they are a wave
        // still on the host tier), and never down to a single running
        // sequence
        if self.requests.iter().any(|r| {
            matches!(
                r.state,
                RequestState::Offloaded | RequestState::HostDecoding
            )
        }) {
            return Ok(());
        }
        // preempt only when the freed blocks can actually complete the
        // admission this very step (keeping the smallest holder running);
        // otherwise stall like the seed did — offloading without admitting
        // would bill transfer latency for nothing and then ping-pong with
        // the resume path
        let mut holders: Vec<usize> = self
            .requests
            .iter()
            .filter(|r| r.state == RequestState::Decoding && r.slot.is_some())
            .map(|r| self.kv.seq_device_units(r.slot.unwrap()))
            .collect();
        if holders.len() < 2 {
            return Ok(());
        }
        holders.sort_unstable();
        let freeable: usize = holders[1..].iter().sum();
        if self.kv.free_units() + freeable < self.kv.admit_units(len) {
            return Ok(());
        }
        loop {
            let decoding = self
                .requests
                .iter()
                .filter(|r| r.state == RequestState::Decoding)
                .count();
            if decoding < 2 {
                break;
            }
            let Some(victim) = self.pick_victim(None) else {
                break;
            };
            self.offload_request(victim)?;
            if self.kv.relieve_for_admit(len) {
                break;
            }
        }
        Ok(())
    }

    /// The preemption victim: the decoding sequence holding the most KV
    /// blocks (frees the most device memory per transfer), ties broken
    /// toward the latest arrival (preempt the youngest work).
    fn pick_victim(&self, exclude: Option<RequestId>) -> Option<RequestId> {
        let kv = &self.kv;
        self.requests
            .iter()
            .filter(|r| {
                r.state == RequestState::Decoding && Some(r.id) != exclude && r.slot.is_some()
            })
            .max_by(|a, b| {
                let ka = (kv.seq_blocks(a.slot.unwrap()), a.arrival);
                let kb = (kv.seq_blocks(b.slot.unwrap()), b.arrival);
                ka.partial_cmp(&kb).unwrap()
            })
            .map(|r| r.id)
    }

    fn offload_request(&mut self, id: RequestId) -> Result<()> {
        let seq = self
            .requests
            .iter()
            .find(|r| r.id == id)
            .and_then(|r| r.slot)
            .expect("offload victim without kv seq");
        // the span covers host residency including both transfers:
        // preemption start → post-fetch resume (closed in `try_resume`,
        // or at finish for sequences that complete on the host)
        trace::begin(self.trace_track, Kind::Offload, self.now, id, 0);
        let dt = self.kv.offload_sequence(seq)?;
        self.now += dt;
        // the placement decision: with piggybacking on, an evicted
        // sequence keeps decoding over its host-resident blocks instead
        // of parking until a resume transfer fits
        self.request_mut(id).state = if self.kv.policy().host_piggyback {
            RequestState::HostDecoding
        } else {
            RequestState::Offloaded
        };
        Ok(())
    }

    /// Grow a decoding sequence's KV to `new_len`; on a full device,
    /// preempt other sequences to the host tier until it fits
    /// (preempt-by-offload instead of failing the step).
    fn grow_or_preempt(&mut self, id: RequestId, seq: usize, new_len: usize) -> Result<()> {
        loop {
            match self.kv.grow(seq, new_len) {
                Ok(()) => return Ok(()),
                Err(e) => {
                    if !self.kv.policy().offload_enabled {
                        return Err(e);
                    }
                    let Some(victim) = self.pick_victim(Some(id)) else {
                        return Err(e);
                    };
                    self.offload_request(victim)?;
                }
            }
        }
    }

    /// Run a whole workload (requests with arrival timestamps) to
    /// completion, simulating arrival times on the engine clock.
    ///
    /// The clock advances by each step's latency; when the engine is idle
    /// it fast-forwards to the next arrival. (For the real backend the
    /// step latency *is* wall time, so the clock tracks reality; we still
    /// fast-forward idle gaps — the honest equivalent of sleeping.)
    pub fn run(&mut self, mut workload: Vec<Request>) -> Result<RunReport> {
        workload.sort_by(|a, b| a.arrival.partial_cmp(&b.arrival).unwrap());
        let mut pending = std::collections::VecDeque::from(workload);
        let mut metrics = Metrics::new();
        let mut iterations = 0usize;
        let mut mode_timeline: Vec<(f64, bool)> = Vec::new();
        let mut completions: Vec<CompletedRequest> = Vec::new();

        loop {
            // ---- admission of arrivals --------------------------------
            while pending
                .front()
                .map(|r| r.arrival <= self.now)
                .unwrap_or(false)
            {
                let r = pending.pop_front().unwrap();
                self.submit(r);
            }

            let active = self.active_requests();
            if active == 0 {
                match pending.front() {
                    Some(next) => {
                        // idle: fast-forward to the next arrival
                        self.now = next.arrival;
                        continue;
                    }
                    None => break, // done
                }
            }

            let imminent = pending
                .iter()
                .take_while(|r| r.arrival <= self.now + 0.02)
                .count();
            let t0 = self.now;
            let step = self.step(imminent, &mut metrics)?;
            if mode_timeline
                .last()
                .map(|&(_, last)| last != step.fp8)
                .unwrap_or(true)
            {
                mode_timeline.push((t0, step.fp8));
            }
            if !step.ran {
                // blocked on KV space with decodes all finished —
                // wait for arrivals (time must advance to avoid spin)
                match pending.front() {
                    Some(next) => self.now = next.arrival.max(self.now + 1e-4),
                    None => {
                        return Err(anyhow!(
                            "deadlock: {} active requests but nothing runnable",
                            active
                        ))
                    }
                }
                continue;
            }
            completions.extend(step.completions);

            iterations += 1;
            if self.cfg.max_iterations > 0 && iterations >= self.cfg.max_iterations {
                break;
            }
        }

        // close any span still open (requests cut off by max_iterations)
        // so exported traces stay balanced
        trace::finish_run(self.now);
        // single-engine benches fold into the same global counter
        // registry cluster runs use (dumped by `repro reproduce --json`)
        crate::telemetry::registry::with_global(|g| g.merge(&metrics.scalar_registry()));
        Ok(RunReport {
            metrics,
            controller: self.controller.clone(),
            iterations,
            mode_timeline,
            completions,
        })
    }

    fn request_mut(&mut self, id: u64) -> &mut Request {
        self.requests
            .iter_mut()
            .find(|r| r.id == id)
            .expect("scheduler produced unknown request id")
    }

    fn run_prefill(
        &mut self,
        id: u64,
        chunk: usize,
        precision: Precision,
        metrics: &mut Metrics,
    ) -> Result<()> {
        // admit if needed
        let (slot, start_pos, tokens) = {
            let reserve_len = {
                let r = self.requests.iter().find(|r| r.id == id).unwrap();
                // per the admission mode: full expected context (Reserve)
                // or just the prompt + headroom (Paged)
                self.kv.admit_len(r.prompt.len(), r.max_new_tokens)
            };
            let need_alloc = {
                let r = self.requests.iter().find(|r| r.id == id).unwrap();
                r.slot.is_none()
            };
            if need_alloc {
                let slot = self.kv.allocate(reserve_len)?;
                let r = self.request_mut(id);
                r.slot = Some(slot);
                r.state = RequestState::Prefilling;
                trace::end(self.trace_track, Kind::Queue, self.now, id, 0);
                trace::begin(self.trace_track, Kind::Prefill, self.now, id, 0);
            }
            let r = self.requests.iter().find(|r| r.id == id).unwrap();
            let start = r.prefilled;
            let take = chunk.min(r.remaining_prompt());
            let mut toks: Vec<i32> = r.prompt[start..start + take].to_vec();
            // pad the tail chunk with the final prompt byte (prompt
            // lengths are chunk-aligned by the workload generators; this
            // is a safety net)
            while toks.len() < chunk {
                toks.push(*toks.last().unwrap());
            }
            (r.slot.unwrap(), start, toks)
        };

        let StepRun {
            logits,
            latency,
            attn_dense_bytes,
            attn_touched_bytes,
            ..
        } = self
            .backend
            .prefill(&mut self.kv, slot, start_pos, &tokens, precision)?;
        self.now += latency;
        metrics.observe_attn(attn_dense_bytes, attn_touched_bytes);

        let r_done;
        {
            let r = self.request_mut(id);
            r.prefilled = (start_pos + tokens.len()).min(r.prompt.len());
            r_done = r.remaining_prompt() == 0;
        }
        let new_len = {
            let r = self.requests.iter().find(|r| r.id == id).unwrap();
            r.prefilled
        };
        self.kv.grow(slot, new_len)?;

        if r_done {
            // sample the first output token from the last chunk's logits
            let first_tok = logits.as_ref().map(|lg| argmax(lg)).unwrap_or(0);
            let now = self.now;
            trace::end(self.trace_track, Kind::Prefill, now, id, 0);
            trace::begin(self.trace_track, Kind::Decode, now, id, 0);
            let r = self.request_mut(id);
            r.state = RequestState::Decoding;
            r.generated.push(first_tok);
            r.first_token_at = Some(now);
            r.last_token_at = Some(now);
            if r.stop_token == Some(first_tok) || r.generated.len() >= r.max_new_tokens {
                r.state = RequestState::Finished;
                r.finish_reason = Some(if r.stop_token == Some(first_tok) {
                    FinishReason::Stop
                } else {
                    FinishReason::Length
                });
                r.finished_at = Some(now);
                trace::end(self.trace_track, Kind::Decode, now, id, 0);
                trace::instant(self.trace_track, Kind::Completion, now, id, 0);
            }
        }
        Ok(())
    }

    /// Execute one decode iteration; returns the batch's worst
    /// per-sequence inter-token gap (the iteration's TPOT sample).
    ///
    /// The batch is tier-agnostic: device-resident and host-piggybacked
    /// lanes form one merged batch for the non-attention stages, ordered
    /// device-first so the backend's [`Backend::decode_mixed`] contract
    /// (host lanes are the batch tail) holds. With piggybacking off the
    /// partition is the identity and `n_host == 0` — `decode_mixed`
    /// then *is* `decode`, bit for bit.
    fn run_decode(
        &mut self,
        ids: &[u64],
        precision: Precision,
        metrics: &mut Metrics,
    ) -> Result<f64> {
        // tier partition (stable within each tier)
        let mut order: Vec<u64> = Vec::with_capacity(ids.len());
        let mut host_tail: Vec<u64> = Vec::new();
        for &id in ids {
            let r = self.requests.iter().find(|r| r.id == id).unwrap();
            if r.state == RequestState::HostDecoding {
                host_tail.push(id);
            } else {
                order.push(id);
            }
        }
        let n_host = host_tail.len();
        order.extend(host_tail);
        let ids: &[u64] = &order;

        let mut slots = Vec::with_capacity(ids.len());
        let mut tokens = Vec::with_capacity(ids.len());
        let mut positions = Vec::with_capacity(ids.len());
        for &id in ids {
            let r = self.requests.iter().find(|r| r.id == id).unwrap();
            slots.push(r.slot.expect("decoding request without slot"));
            tokens.push(*r.generated.last().expect("decoding without a token"));
            positions.push(r.context_len() as i32 - 1);
        }

        let StepRun {
            logits,
            latency,
            attn_dense_bytes,
            attn_touched_bytes,
            host_attn_seconds,
            host_lanes,
        } = self.backend.decode_mixed(
            &mut self.kv,
            &slots,
            &tokens,
            &positions,
            precision,
            n_host,
        )?;
        self.now += latency;
        metrics.observe_attn(attn_dense_bytes, attn_touched_bytes);
        if host_lanes > 0 {
            metrics.observe_host_decode(host_lanes, host_attn_seconds);
            if trace::enabled() {
                trace::instant(
                    self.trace_track,
                    Kind::HostStep,
                    self.now,
                    0,
                    host_lanes as i64,
                );
            }
        }
        // true per-sequence TPOT: gap since that sequence's previous token
        // (includes time spent waiting on other iterations)
        let gaps: Vec<f64> = ids
            .iter()
            .map(|&id| {
                let r = self.requests.iter().find(|r| r.id == id).unwrap();
                self.now - r.last_token_at.unwrap_or(self.now - latency)
            })
            .collect();
        let worst = gaps.iter().cloned().fold(0.0f64, f64::max);
        self.controller.observe_tpot(worst);
        metrics.record_decode_iteration(self.now, &gaps);

        let vocab = logits
            .as_ref()
            .map(|lg| lg.len() / ids.len())
            .unwrap_or(0);
        let now = self.now;
        for (i, &id) in ids.iter().enumerate() {
            let tok = match &logits {
                Some(lg) => argmax(&lg[i * vocab..(i + 1) * vocab]),
                None => 0,
            };
            let max_seq = self.kv.geo.max_seq;
            // a lane finishing on the host tier never pays its resume
            // transfer: its blocks are discarded in place at release.
            // Credit the avoided PCIe time before the state flips (the
            // estimate needs the still-offloaded block table).
            let was_host = self
                .requests
                .iter()
                .find(|r| r.id == id)
                .map(|r| r.state == RequestState::HostDecoding)
                .unwrap_or(false);
            let avoided = if was_host {
                self.kv.resume_transfer_estimate(slots[i])
            } else {
                0.0
            };
            let r = self.request_mut(id);
            r.generated.push(tok);
            r.last_token_at = Some(now);
            let hit_stop = r.stop_token == Some(tok);
            let hit_len = r.generated.len() >= r.max_new_tokens;
            let hit_ctx = r.context_len() >= max_seq - 1;
            if hit_stop || hit_len || hit_ctx {
                r.state = RequestState::Finished;
                r.finish_reason = Some(if hit_stop {
                    FinishReason::Stop
                } else {
                    FinishReason::Length
                });
                r.finished_at = Some(now);
                if was_host {
                    metrics.credit_avoided_transfer(avoided);
                    trace::end(self.trace_track, Kind::Offload, now, id, 0);
                }
                trace::end(self.trace_track, Kind::Decode, now, id, 0);
                trace::instant(self.trace_track, Kind::Completion, now, id, 0);
            }
        }
        // grow each still-running sequence's KV to cover its next token;
        // preemption mid-loop may flip later entries off the device
        // (their growth then happens at resume time — or right here, on
        // the host tier, when they piggyback), so re-read states
        for &id in ids {
            let (state, slot, ctx) = {
                let r = self.requests.iter().find(|r| r.id == id).unwrap();
                (r.state, r.slot, r.context_len())
            };
            let new_len = ctx.min(self.kv.geo.max_seq);
            match state {
                RequestState::Decoding => {
                    self.grow_or_preempt(
                        id,
                        slot.expect("decoding request without slot"),
                        new_len,
                    )?;
                }
                RequestState::HostDecoding => {
                    // host growth: no device budget involved, billed as
                    // write-through transfer on the virtual clock
                    let dt = self
                        .kv
                        .grow_on_host(slot.expect("decoding request without slot"), new_len)?;
                    self.now += dt;
                }
                _ => {}
            }
        }
        Ok(worst)
    }
}

fn argmax(xs: &[f32]) -> i32 {
    let mut best = 0usize;
    let mut bv = f32::NEG_INFINITY;
    for (i, &v) in xs.iter().enumerate() {
        if v > bv {
            bv = v;
            best = i;
        }
    }
    best as i32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::StepRun;
    use crate::coordinator::kv::KvGeometry;

    /// Scripted backend for engine unit tests: fixed latency, logits that
    /// always predict token 42.
    struct FakeBackend {
        geo: KvGeometry,
        latency: f64,
        vocab: usize,
        pub prefills: usize,
        pub decodes: usize,
        /// Iterations that carried at least one host-piggybacked lane.
        pub host_decodes: usize,
    }

    impl FakeBackend {
        fn new(latency: f64) -> FakeBackend {
            Self::with_blocks(latency, 64)
        }

        fn with_blocks(latency: f64, total_blocks: usize) -> FakeBackend {
            FakeBackend {
                geo: KvGeometry {
                    n_layers: 1,
                    n_heads: 1,
                    max_seq: 64,
                    head_dim: 1,
                    block_size: 8,
                    total_blocks,
                },
                latency,
                vocab: 64,
                prefills: 0,
                decodes: 0,
                host_decodes: 0,
            }
        }

        fn logits_for(&self, n: usize) -> Vec<f32> {
            let mut lg = vec![0.0f32; n * self.vocab];
            for i in 0..n {
                lg[i * self.vocab + 42] = 10.0;
            }
            lg
        }
    }

    impl Backend for FakeBackend {
        fn geometry(&self) -> KvGeometry {
            self.geo
        }
        fn prefill_chunks(&self) -> Vec<usize> {
            vec![8, 16]
        }
        fn max_decode_batch(&self) -> usize {
            4
        }
        fn prefill(
            &mut self,
            _kv: &mut KvCacheManager,
            _slot: usize,
            _start: usize,
            _tokens: &[i32],
            _p: Precision,
        ) -> Result<StepRun> {
            self.prefills += 1;
            Ok(StepRun {
                logits: Some(self.logits_for(1)),
                latency: self.latency,
                ..StepRun::default()
            })
        }
        fn decode(
            &mut self,
            _kv: &mut KvCacheManager,
            slots: &[usize],
            _tokens: &[i32],
            _pos: &[i32],
            _p: Precision,
        ) -> Result<StepRun> {
            self.decodes += 1;
            Ok(StepRun {
                logits: Some(self.logits_for(slots.len())),
                latency: self.latency,
                ..StepRun::default()
            })
        }
        fn decode_mixed(
            &mut self,
            kv: &mut KvCacheManager,
            slots: &[usize],
            tokens: &[i32],
            positions: &[i32],
            p: Precision,
            n_host: usize,
        ) -> Result<StepRun> {
            let mut run = self.decode(kv, slots, tokens, positions, p)?;
            if n_host > 0 {
                self.host_decodes += 1;
                run.host_lanes = n_host;
                run.host_attn_seconds = n_host as f64 * 1e-4;
            }
            Ok(run)
        }
    }

    fn engine(latency: f64, policy: PrecisionPolicy) -> Engine<FakeBackend> {
        Engine::new(
            FakeBackend::new(latency),
            EngineConfig {
                policy,
                physical_kv: false,
                ..Default::default()
            },
        )
    }

    #[test]
    fn single_request_runs_to_length() {
        let mut e = engine(0.001, PrecisionPolicy::Fp16Only);
        let reqs = vec![Request::new(1, vec![1; 16], 5, 0.0)];
        let report = e.run(reqs).unwrap();
        assert_eq!(report.metrics.completed, 1);
        assert_eq!(report.metrics.total_output_tokens, 5);
        // 1 prefill (16 = one chunk) + 4 decodes (first token from prefill)
        assert_eq!(e.backend.prefills, 1);
        assert_eq!(e.backend.decodes, 4);
    }

    #[test]
    fn stop_token_halts_generation() {
        let mut e = engine(0.001, PrecisionPolicy::Fp16Only);
        let reqs = vec![Request::new(1, vec![1; 8], 100, 0.0).with_stop(42)];
        let report = e.run(reqs).unwrap();
        assert_eq!(report.metrics.completed, 1);
        // first sampled token is already 42 -> stops immediately
        assert_eq!(report.metrics.total_output_tokens, 1);
    }

    #[test]
    fn chunked_prefill_long_prompt() {
        let mut e = engine(0.001, PrecisionPolicy::Fp16Only);
        let reqs = vec![Request::new(1, vec![1; 48], 2, 0.0)];
        let report = e.run(reqs).unwrap();
        assert_eq!(report.metrics.completed, 1);
        // 48 = 16+16+16 -> 3 prefill chunks
        assert_eq!(e.backend.prefills, 3);
    }

    #[test]
    fn batching_multiple_requests() {
        let mut e = engine(0.001, PrecisionPolicy::Fp16Only);
        let reqs: Vec<Request> = (0..3)
            .map(|i| Request::new(i, vec![1; 8], 10, 0.0))
            .collect();
        let report = e.run(reqs).unwrap();
        assert_eq!(report.metrics.completed, 3);
        assert_eq!(report.metrics.total_output_tokens, 30);
        // batching means decodes << 3 * 9
        assert!(
            e.backend.decodes < 20,
            "expected batched decodes, got {}",
            e.backend.decodes
        );
    }

    #[test]
    fn arrivals_respect_clock() {
        let mut e = engine(0.010, PrecisionPolicy::Fp16Only);
        let mut r2 = Request::new(2, vec![1; 8], 2, 5.0);
        r2.arrival = 5.0;
        let reqs = vec![Request::new(1, vec![1; 8], 2, 0.0), r2];
        let mut report = e.run(reqs).unwrap();
        assert_eq!(report.metrics.completed, 2);
        // engine must have fast-forwarded: total time >= 5.0
        assert!(e.now() >= 5.0);
        let s = report.metrics.ttft.summary();
        // both requests should have small TTFT (no cross-talk)
        assert!(s.max < 0.2, "{s}");
    }

    #[test]
    fn dual_policy_switches_under_slow_backend() {
        // backend latency far above the SLO forces fp8 escalation
        let mut e = engine(0.050, PrecisionPolicy::Dual);
        let reqs: Vec<Request> = (0..4)
            .map(|i| Request::new(i, vec![1; 8], 30, 0.0))
            .collect();
        let report = e.run(reqs).unwrap();
        assert!(report.controller.switches >= 1, "never switched to fp8");
        assert!(report.controller.iters_fp8 > 0);
    }

    #[test]
    fn external_stepping_matches_run() {
        // driving via submit/step must reproduce run()'s outcome
        let mut reference = engine(0.001, PrecisionPolicy::Fp16Only);
        let reqs: Vec<Request> = (0..3)
            .map(|i| Request::new(i, vec![1; 16], 6, 0.0))
            .collect();
        let ref_report = reference.run(reqs.clone()).unwrap();

        let mut e = engine(0.001, PrecisionPolicy::Fp16Only);
        let mut metrics = Metrics::new();
        let mut completions = Vec::new();
        for r in reqs {
            e.submit(r);
        }
        while e.active_requests() > 0 {
            let step = e.step(0, &mut metrics).unwrap();
            assert!(step.ran, "nothing runnable with all requests submitted");
            completions.extend(step.completions);
        }
        assert_eq!(metrics.completed, ref_report.metrics.completed);
        assert_eq!(
            metrics.total_output_tokens,
            ref_report.metrics.total_output_tokens
        );
        assert_eq!(completions.len(), ref_report.completions.len());
        assert_eq!(e.backend.decodes, reference.backend.decodes);
    }

    #[test]
    fn metrics_timeline_populated() {
        let mut e = engine(0.002, PrecisionPolicy::Fp16Only);
        let reqs = vec![Request::new(1, vec![1; 8], 20, 0.0)];
        let report = e.run(reqs).unwrap();
        assert!(!report.metrics.tpot_by_second.is_empty());
        assert!(report.iterations >= 20);
    }

    #[test]
    fn preempts_by_offload_instead_of_stalling() {
        // 4-block budget, two requests whose contexts outgrow it even
        // after full FP8 demotion: the engine must offload one sequence
        // to the host tier, keep decoding, resume it, and finish both.
        let mut e = Engine::new(
            FakeBackend::with_blocks(0.001, 4),
            EngineConfig {
                policy: PrecisionPolicy::Fp16Only,
                physical_kv: false,
                ..Default::default()
            },
        );
        let reqs: Vec<Request> = (0..2)
            .map(|i| Request::new(i, vec![1; 8], 20, 0.0))
            .collect();
        let report = e.run(reqs).unwrap();
        assert_eq!(report.metrics.completed, 2);
        assert_eq!(report.metrics.total_output_tokens, 40);
        let st = e.kv.stats();
        assert!(st.demoted_blocks >= 1, "demotion never engaged");
        assert!(st.offload_events >= 1, "never preempted by offload");
        assert!(st.fetch_events >= 1, "offloaded sequence never resumed");
        assert!(st.transfer_seconds > 0.0, "transfers must charge the clock");
        assert_eq!(e.kv.free_blocks(), 4, "all blocks released");
        assert_eq!(e.kv.host_blocks(), 0, "host tier drained");
    }

    #[test]
    fn paged_admission_beats_reserve_under_same_budget() {
        // same 12-block budget: conservative full-context reservation can
        // hold one request at a time; FP8 demotion fits a second
        // concurrently (the acceptance property, engine level)
        let run = |kv_cfg: crate::kvcache::KvPressureConfig| {
            let mut e = Engine::new(
                FakeBackend::with_blocks(0.001, 12),
                EngineConfig {
                    policy: PrecisionPolicy::Fp16Only,
                    physical_kv: false,
                    kv: kv_cfg,
                    ..Default::default()
                },
            );
            let reqs: Vec<Request> = (0..2)
                .map(|i| Request::new(i, vec![1; 8], 40, 0.0))
                .collect();
            let report = e.run(reqs).unwrap();
            assert_eq!(report.metrics.completed, 2);
            e.kv.stats().peak_live_seqs
        };
        let base = run(crate::kvcache::KvPressureConfig::dense_baseline());
        let demote = run(crate::kvcache::KvPressureConfig::demote_only());
        assert_eq!(base, 1, "reserve mode serializes on this budget");
        assert!(
            demote > base,
            "fp8 demotion must admit more concurrently: {demote} !> {base}"
        );
    }

    #[test]
    fn piggybacked_sequences_keep_decoding_on_host() {
        // same pressure shape as `preempts_by_offload_instead_of_stalling`
        // but with piggybacking on: the evicted sequence must keep
        // generating over host blocks instead of parking for a resume
        let mut e = Engine::new(
            FakeBackend::with_blocks(0.001, 4),
            EngineConfig {
                policy: PrecisionPolicy::Fp16Only,
                physical_kv: false,
                kv: KvPressureConfig::piggyback(),
                ..Default::default()
            },
        );
        let reqs: Vec<Request> = (0..2)
            .map(|i| Request::new(i, vec![1; 8], 20, 0.0))
            .collect();
        let report = e.run(reqs).unwrap();
        assert_eq!(report.metrics.completed, 2);
        assert_eq!(report.metrics.total_output_tokens, 40);
        assert!(
            e.backend.host_decodes > 0,
            "no iteration ever carried a host lane"
        );
        assert!(report.metrics.host_piggybacked_steps > 0);
        assert!(report.metrics.host_attn_seconds > 0.0);
        assert_eq!(e.kv.free_blocks(), 4, "all device blocks released");
        assert_eq!(e.kv.host_blocks(), 0, "host tier drained at completion");
    }

    #[test]
    fn piggyback_disabled_is_bit_identical_to_the_seed_path() {
        // the refactored pipeline with piggybacking off must reproduce
        // the legacy run exactly: same decode count, same clock, same
        // tokens — decode_mixed(n_host=0) is decode
        let run = || {
            let mut e = Engine::new(
                FakeBackend::with_blocks(0.001, 4),
                EngineConfig {
                    policy: PrecisionPolicy::Fp16Only,
                    physical_kv: false,
                    ..Default::default()
                },
            );
            let reqs: Vec<Request> = (0..2)
                .map(|i| Request::new(i, vec![1; 8], 20, 0.0))
                .collect();
            let report = e.run(reqs).unwrap();
            (
                report.iterations,
                e.backend.decodes,
                e.backend.host_decodes,
                e.now().to_bits(),
                report.metrics.total_output_tokens,
            )
        };
        let a = run();
        let b = run();
        assert_eq!(a, b);
        assert_eq!(a.2, 0, "no host lanes with piggybacking off");
    }

    #[test]
    fn offload_latency_lands_on_the_virtual_clock() {
        // drive via step() so we can see per-iteration latency: any
        // iteration containing a transfer reports latency above the
        // backend's fixed cost
        let mut e = Engine::new(
            FakeBackend::with_blocks(0.001, 4),
            EngineConfig {
                policy: PrecisionPolicy::Fp16Only,
                physical_kv: false,
                ..Default::default()
            },
        );
        for i in 0..2 {
            e.submit(Request::new(i, vec![1; 8], 20, 0.0));
        }
        let mut metrics = Metrics::new();
        let mut clocked = 0.0f64;
        while e.active_requests() > 0 {
            let step = e.step(0, &mut metrics).unwrap();
            assert!(step.ran);
            clocked += step.latency;
        }
        let st = e.kv.stats();
        assert!(st.transfer_seconds > 0.0);
        assert!(
            (clocked - e.now()).abs() < 1e-9,
            "step latencies must sum to the clock: {clocked} vs {}",
            e.now()
        );
        assert!(
            clocked > st.transfer_seconds,
            "clock must include the transfer charges"
        );
        assert_eq!(metrics.kv_offload_events, st.offload_events);
    }
}

//! Layer 3: the vLLM-style serving coordinator.
//!
//! The engine implements continuous batching (ORCA-style iteration-level
//! scheduling) with chunked prefill (Sarathi-style), the paged
//! dual-precision KV cache ([`crate::kvcache`]: block tables, FP8
//! demotion under pressure, host-offload preemption), latency metrics,
//! and the paper's contribution: an **iteration-level dual-precision
//! controller** that picks FP16 or FP8 execution per scheduling step from
//! the same NestedFP weight store.
//!
//! The engine is generic over a [`backend::Backend`]:
//! * [`backend::RealBackend`] — executes real model steps host-natively
//!   ([`hostforward`]): fused NestedFP GEMMs over the artifact weight
//!   store plus block-native paged attention ([`crate::attn`]) — real
//!   logits, greedy decoding, no dense KV gather, no PJRT required.
//! * [`backend::SimBackend`] — costs each iteration with the `gpusim`
//!   H100 model and advances a virtual clock (the performance figures).
//!
//! Above the single engine sits the **cluster layer**: [`cluster`] drives
//! N replica engines as components of a deterministic discrete-event
//! scheduler ([`event_core`]: min-heap event queue, ties broken by
//! component id, idle replicas parked at zero cost), [`router`] picks a
//! replica per arriving request (round-robin / least-loaded-KV /
//! SLO-headroom / seeded-random), and the closed-loop [`autopilot`]
//! (sliding-window SLO tracking, per-replica FP16 → Mixed → FP8
//! hysteresis ladders, an EWMA-slope surge predictor) demotes the fewest
//! replicas needed during surges and promotes them back as the surge
//! drains — the paper's SLO-management story at multi-GPU scale.
//! [`server`] exposes both a single engine and a replica fleet over TCP.

pub mod request;
pub mod kv;
pub mod scheduler;
pub mod precision;
pub mod metrics;
pub mod hostforward;
pub mod backend;
pub mod engine;
pub mod router;
pub mod autopilot;
pub mod event_core;
pub mod cluster;
pub mod server;

pub use autopilot::{Autopilot, AutopilotConfig, ModeStats, SloTracker, SurgePredictor};
pub use cluster::{ClusterConfig, ClusterReport, ClusterRouter, EventStats, SurgeConfig};
pub use event_core::{Component, ComponentId, EventQueue, QueueStats, Waker};
pub use engine::{Engine, EngineConfig, EngineStep};
pub use kv::{KvCacheManager, KvGeometry, KvPressureConfig};
pub use precision::{PrecisionDirective, PrecisionPolicy, SloConfig};
pub use request::{Request, RequestId, RequestState};
pub use router::{ReplicaSnapshot, Router, RoutingPolicy};

//! Layer 3: the vLLM-style serving coordinator.
//!
//! The engine implements continuous batching (ORCA-style iteration-level
//! scheduling) with chunked prefill (Sarathi-style), a slot/block KV-cache
//! manager, latency metrics, and the paper's contribution: an
//! **iteration-level dual-precision controller** that picks FP16 or FP8
//! execution per scheduling step from the same NestedFP weight store.
//!
//! The engine is generic over a [`backend::Backend`]:
//! * [`backend::RealBackend`] — executes the AOT artifacts on the PJRT
//!   CPU client (real logits, greedy decoding; the e2e example).
//! * [`backend::SimBackend`] — costs each iteration with the `gpusim`
//!   H100 model and advances a virtual clock (the performance figures).

pub mod request;
pub mod kv;
pub mod scheduler;
pub mod precision;
pub mod metrics;
pub mod backend;
pub mod engine;
pub mod server;

pub use engine::{Engine, EngineConfig};
pub use precision::{PrecisionPolicy, SloConfig};
pub use request::{Request, RequestId, RequestState};

//! Host-native execution of the model step functions — the CPU twin of
//! the AOT prefill/decode artifacts, built on the fused GEMM engine and
//! **block-native paged attention**.
//!
//! PR 3 gave the host a real compute path for single GEMMs
//! (`RealBackend::native_gemm`); this module completes the twin: the
//! whole decoder step (embed → per-layer RMSNorm / QKV / RoPE /
//! attention / SwiGLU → final norm → LM head) runs on the host, with
//! every linear layer served straight from the NestedFP weight store by
//! [`GemmEngine`] and every attention layer consuming the paged KV
//! cache **in place** via [`AttnEngine`]. Nothing dense-gathers: each
//! layer's fresh K/V rows are scattered into their blocks
//! (`PagedKvCache::scatter_rows`) and the block walk reads them
//! back together with the (possibly FP8-demoted) past.
//!
//! Numerics mirror `python/compile/model.py` step functions: f32
//! accumulation with activations rounded to FP16 at the same points the
//! JAX model casts (`attn_in`, `ctx`, `mlp_in`, `act`, the LM-head
//! input), RoPE/RMSNorm in f32, and — in `nested8` mode — the paper's
//! static per-tensor activation fake-quant with the manifest's
//! calibrated scales. Exception layers (manifest `exception_layers`)
//! fall back to their FP16 plane in every mode, per §4.2. The host twin
//! does not promise bit-equality with the XLA-compiled artifacts (op
//! fusion differs); it promises the same *model* — and, unlike the
//! artifacts, it runs in every build, `pjrt` or not.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use crate::attn::{AttnEngine, AttnLane, AttnStats};
use crate::format::e4m3;
use crate::format::fp16::F16;
use crate::format::nested::NestedTensor;
use crate::format::tensor::Tensor2;
use crate::gemm::{GemmEngine, GemmFormat, GemmWeights};
use crate::runtime::ModelRuntime;

use super::kv::KvCacheManager;

/// RMSNorm epsilon — fixed by `python/compile/model.py::ModelConfig`
/// (the manifest does not carry it).
const NORM_EPS: f32 = 1e-5;
/// RoPE base, likewise fixed by the trainer's `ModelConfig`.
const ROPE_THETA: f32 = 10000.0;

/// One sequence's slice of a step: `tokens[i]` sits at absolute context
/// position `positions[i]` (contiguous, ascending). All lanes of one
/// forward call carry the same token count — 1 for decode, the chunk
/// length for prefill.
pub struct StepLane<'a> {
    /// Paged-cache sequence handle.
    pub seq: usize,
    pub tokens: &'a [i32],
    pub positions: &'a [i32],
}

/// Result of one host-native step.
pub struct ForwardOut {
    /// Logits of each lane's **last** token, `[n_lanes, vocab]`
    /// flattened (matching the artifacts: prefill returns the chunk's
    /// final-position logits, decode one row per lane).
    pub logits: Vec<f32>,
    /// Attention traffic accounting, summed over layers.
    pub attn: AttnStats,
}

struct Linear {
    w: GemmWeights,
    fmt: GemmFormat,
    /// `Some(s)` on the FP8 path: activations are fake-quantized as
    /// `dequant(quant(x * s)) / s` with the calibrated static scale.
    act_scale: Option<f32>,
}

struct ModeLayer {
    wq: Linear,
    wk: Linear,
    wv: Linear,
    wo: Linear,
    w_gate: Linear,
    w_up: Linear,
    w_down: Linear,
}

/// The host step executor. Construction decodes the mode-independent
/// tensors (embeddings, norms, LM head) once; per-mode linear stores
/// are prepared on first use ([`Self::prepare`]) and cached for the
/// executor's lifetime. Each prepared mode holds its own copy of the
/// linear-layer planes (mirroring `RealBackend::store_weights`) — at
/// this model scale that is kilobytes; borrowed store views are the
/// upgrade path if a full-size checkpoint ever runs through here.
pub struct HostForward {
    vocab: usize,
    d_model: usize,
    n_layers: usize,
    n_heads: usize,
    head_dim: usize,
    embed: Vec<f32>,
    final_norm: Vec<f32>,
    norms: Vec<(Vec<f32>, Vec<f32>)>,
    lm_head: GemmWeights,
    modes: BTreeMap<String, Vec<ModeLayer>>,
    gemm: GemmEngine,
    attn: AttnEngine,
    /// When set, attention walks lanes on **either tier**
    /// ([`AttnEngine::attend_any_tier`]) so host-piggybacked lanes
    /// decode over their host-resident blocks in place. Off by default:
    /// the device-only entry keeps its offloaded-lane panic as an
    /// invariant check for ordinary steps.
    any_tier: bool,
}

impl HostForward {
    /// Build with default (single-threaded) compute engines.
    pub fn new(rt: &ModelRuntime) -> Result<HostForward> {
        Self::with_engines(rt, GemmEngine::default(), AttnEngine::default())
    }

    /// Build with explicit compute engines — how the backend plumbs its
    /// public `gemm` configuration (and a matching attention worker
    /// budget) into the serving path.
    pub fn with_engines(
        rt: &ModelRuntime,
        gemm: GemmEngine,
        attn: AttnEngine,
    ) -> Result<HostForward> {
        let m = &rt.manifest.model;
        if m.d_model != m.n_heads * m.head_dim {
            bail!(
                "manifest model: d_model {} != n_heads {} * head_dim {}",
                m.d_model,
                m.n_heads,
                m.head_dim
            );
        }
        let embed_t = rt.weights.get("embed")?;
        if embed_t.dims != vec![m.vocab, m.d_model] {
            bail!("embed: dims {:?}, expected [{}, {}]", embed_t.dims, m.vocab, m.d_model);
        }
        let embed = f16_bits_to_f32(&embed_t.as_u16()?);
        let final_norm = rt.weights.get("final_norm")?.as_f32()?;
        let lm_t = rt.weights.get("lm_head")?;
        if lm_t.dims != vec![m.vocab, m.d_model] {
            bail!("lm_head: dims {:?}, expected [{}, {}]", lm_t.dims, m.vocab, m.d_model);
        }
        let lm_head = GemmWeights::F16 {
            rows: m.vocab,
            cols: m.d_model,
            bits: lm_t.as_u16()?,
        };
        let mut norms = Vec::with_capacity(m.n_layers);
        for i in 0..m.n_layers {
            norms.push((
                rt.weights.get(&format!("layers.{i}.attn_norm"))?.as_f32()?,
                rt.weights.get(&format!("layers.{i}.mlp_norm"))?.as_f32()?,
            ));
        }
        Ok(HostForward {
            vocab: m.vocab,
            d_model: m.d_model,
            n_layers: m.n_layers,
            n_heads: m.n_heads,
            head_dim: m.head_dim,
            embed,
            final_norm,
            norms,
            lm_head,
            modes: BTreeMap::new(),
            gemm,
            attn,
            any_tier: false,
        })
    }

    /// Toggle the any-tier attention walk for subsequent forwards. The
    /// backend flips this on only for mixed-tier decode batches; lane
    /// payloads are tier-invariant, so device-resident lanes produce
    /// bit-identical output either way.
    pub fn set_any_tier(&mut self, any_tier: bool) {
        self.any_tier = any_tier;
    }

    /// Prepare (and cache) one mode's linear operands. `forward` calls
    /// this itself; backends call it *before* starting their step timer
    /// so a precision-mode switch never bills weight decoding as step
    /// latency.
    pub fn prepare(&mut self, rt: &ModelRuntime, mode: &str) -> Result<()> {
        self.prepare_mode(rt, mode)
    }

    /// Assemble one linear layer's stored operand for `mode`, honoring
    /// the manifest's exception list (those layers stay FP16 in every
    /// mode, §4.2). The caller resolves `exception` against the set
    /// precomputed in [`Self::prepare_mode`] — this function no longer
    /// rescans `manifest.exception_layers` per linear.
    fn load_linear(
        &self,
        rt: &ModelRuntime,
        mode: &str,
        i: usize,
        name: &str,
        exception: bool,
    ) -> Result<Linear> {
        let key = format!("layers.{i}.{name}");
        let use_mode = if exception { "fp16" } else { mode };
        let (w, fmt) = match use_mode {
            "fp16" => {
                let t = rt.weights.get(&format!("{key}.f16"))?;
                if t.dims.len() != 2 {
                    bail!("{key}.f16: expected [N,K], got {:?}", t.dims);
                }
                (
                    GemmWeights::F16 {
                        rows: t.dims[0],
                        cols: t.dims[1],
                        bits: t.as_u16()?,
                    },
                    GemmFormat::Fp16,
                )
            }
            // the paper's FP8 *baseline*: per-channel absmax weight
            // fake-quant baked offline into the fq16 plane (plain f16
            // GEMM numerics) + the same static activation quant
            "fp8base" => {
                let t = rt.weights.get(&format!("{key}.fq16"))?;
                if t.dims.len() != 2 {
                    bail!("{key}.fq16: expected [N,K], got {:?}", t.dims);
                }
                (
                    GemmWeights::F16 {
                        rows: t.dims[0],
                        cols: t.dims[1],
                        bits: t.as_u16()?,
                    },
                    GemmFormat::Fp16,
                )
            }
            "nested16" | "nested8" => {
                let upper = rt.weights.get(&format!("{key}.upper"))?;
                if upper.dims.len() != 2 {
                    bail!("{key}.upper: expected [N,K], got {:?}", upper.dims);
                }
                // the memory story holds here too: the lower plane is
                // only fetched in nested16 mode
                let lower = if use_mode == "nested16" {
                    rt.weights.get(&format!("{key}.lower"))?.bytes.clone()
                } else {
                    Vec::new()
                };
                let t = NestedTensor {
                    rows: upper.dims[0],
                    cols: upper.dims[1],
                    upper: upper.bytes.clone(),
                    lower,
                    fully_eligible: true,
                };
                let fmt = if use_mode == "nested16" {
                    GemmFormat::Nested16
                } else {
                    GemmFormat::Nested8
                };
                (GemmWeights::Nested(t), fmt)
            }
            other => bail!("host forward: unknown mode '{other}'"),
        };
        // both FP8 paths quantize activations with the calibrated
        // static per-tensor scale; exception layers (use_mode "fp16")
        // skip it like the python model does. A key missing from
        // act_scales falls back to 1.0 — the same default model.py's
        // `scale_of` uses (`act_scales.get(name, 1.0)`), so partial
        // calibrations degrade identically on both sides.
        let act_scale = if fmt == GemmFormat::Nested8 || use_mode == "fp8base" {
            Some(*rt.manifest.act_scales.get(&key).unwrap_or(&1.0) as f32)
        } else {
            None
        };
        Ok(Linear { w, fmt, act_scale })
    }

    fn prepare_mode(&mut self, rt: &ModelRuntime, mode: &str) -> Result<()> {
        if self.modes.contains_key(mode) {
            return Ok(());
        }
        // Precompute the manifest's exception set once per mode prepare:
        // the old code linear-scanned `exception_layers` with a string
        // compare for every linear of every layer; a set lookup keeps
        // prepare O(L·log E) and is the same mechanism the per-layer
        // morph schedule uses to pick a plane per layer.
        let exceptions: std::collections::BTreeSet<&str> =
            rt.manifest.exception_layers.iter().map(|s| s.as_str()).collect();
        let is_exception =
            |i: usize, name: &str| exceptions.contains(format!("layers.{i}.{name}").as_str());
        let mut layers = Vec::with_capacity(self.n_layers);
        for i in 0..self.n_layers {
            layers.push(ModeLayer {
                wq: self.load_linear(rt, mode, i, "wq", is_exception(i, "wq"))?,
                wk: self.load_linear(rt, mode, i, "wk", is_exception(i, "wk"))?,
                wv: self.load_linear(rt, mode, i, "wv", is_exception(i, "wv"))?,
                wo: self.load_linear(rt, mode, i, "wo", is_exception(i, "wo"))?,
                w_gate: self.load_linear(rt, mode, i, "w_gate", is_exception(i, "w_gate"))?,
                w_up: self.load_linear(rt, mode, i, "w_up", is_exception(i, "w_up"))?,
                w_down: self.load_linear(rt, mode, i, "w_down", is_exception(i, "w_down"))?,
            });
        }
        self.modes.insert(mode.to_string(), layers);
        Ok(())
    }

    /// Execute one step over `lanes` under artifact mode `mode`
    /// ("fp16" | "nested16" | "nested8" | "fp8base"). Scatters each
    /// layer's fresh
    /// K/V into the paged cache, attends block-natively, and returns
    /// the last-token logits per lane. An empty batch is a no-op.
    pub fn forward(
        &mut self,
        rt: &ModelRuntime,
        kv: &mut KvCacheManager,
        mode: &str,
        lanes: &[StepLane],
    ) -> Result<ForwardOut> {
        self.prepare_mode(rt, mode)?;
        self.forward_prepared(kv, mode, lanes)
    }

    /// Execute one step with a **per-layer** precision split: layer `i`
    /// runs under `cold_mode` when `cold_layers[i]` is true and under
    /// `hot_mode` otherwise. An all-false (or all-true) mask is
    /// bit-identical to [`Self::forward`] with the corresponding single
    /// mode — the morph schedule's endpoints cost nothing in fidelity.
    pub fn forward_morph(
        &mut self,
        rt: &ModelRuntime,
        kv: &mut KvCacheManager,
        hot_mode: &str,
        cold_mode: &str,
        cold_layers: &[bool],
        lanes: &[StepLane],
    ) -> Result<ForwardOut> {
        if cold_layers.len() != self.n_layers {
            bail!(
                "host forward: cold mask covers {} layers, model has {}",
                cold_layers.len(),
                self.n_layers
            );
        }
        self.prepare_mode(rt, hot_mode)?;
        self.prepare_mode(rt, cold_mode)?;
        let hot = self.modes.get(hot_mode).expect("mode prepared");
        let cold = self.modes.get(cold_mode).expect("mode prepared");
        let layers: Vec<&ModeLayer> = cold_layers
            .iter()
            .enumerate()
            .map(|(i, &c)| if c { &cold[i] } else { &hot[i] })
            .collect();
        self.forward_layers(kv, &layers, lanes)
    }

    fn forward_prepared(
        &self,
        kv: &mut KvCacheManager,
        mode: &str,
        lanes: &[StepLane],
    ) -> Result<ForwardOut> {
        let layers = self.modes.get(mode).expect("mode prepared");
        let refs: Vec<&ModeLayer> = layers.iter().collect();
        self.forward_layers(kv, &refs, lanes)
    }

    fn forward_layers(
        &self,
        kv: &mut KvCacheManager,
        layers: &[&ModeLayer],
        lanes: &[StepLane],
    ) -> Result<ForwardOut> {
        let (h, dh, d) = (self.n_heads, self.head_dim, self.d_model);
        if lanes.is_empty() {
            return Ok(ForwardOut {
                logits: Vec::new(),
                attn: AttnStats::default(),
            });
        }
        let t = lanes[0].tokens.len();
        if t == 0 {
            bail!("host forward: zero-token lanes");
        }
        for lane in lanes {
            if lane.tokens.len() != t || lane.positions.len() != t {
                bail!("host forward: lanes must share one token count");
            }
            for w in lane.positions.windows(2) {
                if w[1] != w[0] + 1 {
                    bail!("host forward: lane positions must be contiguous");
                }
            }
        }
        let n = lanes.len();
        let mtot = n * t;

        // ---- embeddings ------------------------------------------------
        let mut x = Tensor2::zeros(mtot, d);
        for (li, lane) in lanes.iter().enumerate() {
            for (ti, &tok) in lane.tokens.iter().enumerate() {
                if tok < 0 || tok as usize >= self.vocab {
                    bail!("token {tok} outside vocab {}", self.vocab);
                }
                let src = tok as usize * d;
                let dst = (li * t + ti) * d;
                x.data[dst..dst + d].copy_from_slice(&self.embed[src..src + d]);
            }
        }

        let mut stats = AttnStats::default();
        let mut ctx_hm = vec![0.0f32; n * h * t * dh];
        for (i, layer) in layers.iter().enumerate() {
            let (attn_norm, mlp_norm) = &self.norms[i];

            // -- attention sublayer --
            let mut attn_in = x.clone();
            rms_norm_rows(&mut attn_in, attn_norm);
            round_f16(&mut attn_in.data);
            let mut q = self.run_linear(&attn_in, &layer.wq);
            let mut k = self.run_linear(&attn_in, &layer.wk);
            let v = self.run_linear(&attn_in, &layer.wv);
            for (li, lane) in lanes.iter().enumerate() {
                for (ti, &pos) in lane.positions.iter().enumerate() {
                    let row = (li * t + ti) * d;
                    rope_row(&mut q.data[row..row + d], h, dh, pos as f32);
                    rope_row(&mut k.data[row..row + d], h, dh, pos as f32);
                }
            }
            // write this layer's fresh K/V into their blocks, then walk
            // the block table — queries at position p read 0..=p with
            // the step's own tokens already resident; no dense staging
            for (li, lane) in lanes.iter().enumerate() {
                let row0 = li * t * d;
                kv.scatter_rows(
                    lane.seq,
                    i,
                    lane.positions[0] as usize,
                    t,
                    &k.data[row0..row0 + t * d],
                    &v.data[row0..row0 + t * d],
                );
            }
            let attn_lanes: Vec<AttnLane> = lanes
                .iter()
                .enumerate()
                .map(|(li, lane)| AttnLane {
                    seq: lane.seq,
                    q: &q.data[li * t * d..(li + 1) * t * d],
                    positions: lane.positions,
                })
                .collect();
            stats.merge(if self.any_tier {
                self.attn.attend_any_tier(kv, i, &attn_lanes, &mut ctx_hm)
            } else {
                self.attn.attend(kv, i, &attn_lanes, &mut ctx_hm)
            });
            // [lane, H, T, Dh] -> token rows [M, D]
            let mut ctx = Tensor2::zeros(mtot, d);
            for li in 0..n {
                for head in 0..h {
                    for ti in 0..t {
                        let src = ((li * h + head) * t + ti) * dh;
                        let dst = (li * t + ti) * d + head * dh;
                        ctx.data[dst..dst + dh].copy_from_slice(&ctx_hm[src..src + dh]);
                    }
                }
            }
            round_f16(&mut ctx.data);
            let attn_out = self.run_linear(&ctx, &layer.wo);
            add_assign(&mut x.data, &attn_out.data);

            // -- MLP sublayer (SwiGLU) --
            let mut mlp_in = x.clone();
            rms_norm_rows(&mut mlp_in, mlp_norm);
            round_f16(&mut mlp_in.data);
            let gate = self.run_linear(&mlp_in, &layer.w_gate);
            let up = self.run_linear(&mlp_in, &layer.w_up);
            let mut act = gate;
            for (a, &u) in act.data.iter_mut().zip(&up.data) {
                let g = *a;
                *a = g / (1.0 + (-g).exp()) * u; // silu(g) * u
            }
            round_f16(&mut act.data);
            let down = self.run_linear(&act, &layer.w_down);
            add_assign(&mut x.data, &down.data);
        }

        // ---- final norm + LM head on each lane's last token ------------
        let mut last = Tensor2::zeros(n, d);
        for li in 0..n {
            let row = (li * t + t - 1) * d;
            last.data[li * d..(li + 1) * d].copy_from_slice(&x.data[row..row + d]);
        }
        rms_norm_rows(&mut last, &self.final_norm);
        round_f16(&mut last.data);
        let logits = self.gemm.matmul(&last, &self.lm_head, GemmFormat::Fp16);
        Ok(ForwardOut {
            logits: logits.data,
            attn: stats,
        })
    }

    fn run_linear(&self, x: &Tensor2, lin: &Linear) -> Tensor2 {
        match lin.act_scale {
            Some(s) => {
                // FP8 path: static per-tensor activation fake-quant at
                // the calibrated scale (model.py `linear`, nested8 arm)
                let mut xq = x.clone();
                for v in xq.data.iter_mut() {
                    *v = e4m3::decode(e4m3::encode_sat(*v * s)) / s;
                }
                self.gemm.matmul(&xq, &lin.w, lin.fmt)
            }
            None => self.gemm.matmul(x, &lin.w, lin.fmt),
        }
    }
}

fn f16_bits_to_f32(bits: &[u16]) -> Vec<f32> {
    bits.iter().map(|&b| F16::from_bits(b).to_f32()).collect()
}

fn round_f16(xs: &mut [f32]) {
    for v in xs.iter_mut() {
        *v = F16::from_f32(*v).to_f32();
    }
}

fn add_assign(a: &mut [f32], b: &[f32]) {
    debug_assert_eq!(a.len(), b.len());
    for (x, y) in a.iter_mut().zip(b) {
        *x += y;
    }
}

/// Row-wise RMSNorm with a learned scale (model.py `rms_norm`).
fn rms_norm_rows(x: &mut Tensor2, scale: &[f32]) {
    let d = x.cols;
    debug_assert_eq!(scale.len(), d);
    for r in 0..x.rows {
        let row = &mut x.data[r * d..(r + 1) * d];
        let mut ss = 0.0f32;
        for &v in row.iter() {
            ss += v * v;
        }
        let inv = 1.0 / (ss / d as f32 + NORM_EPS).sqrt();
        for (v, &g) in row.iter_mut().zip(scale) {
            *v = *v * inv * g;
        }
    }
}

/// Rotary embedding of one `[H * Dh]` row at absolute position `pos`
/// (model.py `rope`: split-half rotation, `freq_j = theta^(-j/half)`).
fn rope_row(row: &mut [f32], h: usize, dh: usize, pos: f32) {
    let half = dh / 2;
    let log_theta = ROPE_THETA.ln();
    // the rotation angles depend only on j — compute them once per row
    // (mirroring model.py, which builds `freqs` once per rope() call),
    // not once per head
    let mut rot = vec![0.0f32; 2 * half]; // (sin, cos) pairs
    for j in 0..half {
        let freq = (-(j as f32) * (log_theta / half as f32)).exp();
        let (sin, cos) = (pos * freq).sin_cos();
        rot[2 * j] = sin;
        rot[2 * j + 1] = cos;
    }
    for hi in 0..h {
        let base = hi * dh;
        for j in 0..half {
            let (sin, cos) = (rot[2 * j], rot[2 * j + 1]);
            let a = row[base + j];
            let b = row[base + half + j];
            row[base + j] = a * cos - b * sin;
            row[base + half + j] = a * sin + b * cos;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rope_position_zero_is_identity() {
        let mut row = vec![0.5f32, -1.0, 2.0, 0.25];
        let want = row.clone();
        rope_row(&mut row, 1, 4, 0.0);
        for (a, b) in row.iter().zip(&want) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn rope_preserves_norm_and_depends_on_position() {
        let mut a = vec![0.3f32, 0.7, -0.2, 1.1, 0.9, -0.4, 0.0, 0.6];
        let norm0: f32 = a.iter().map(|x| x * x).sum();
        let b0 = a.clone();
        rope_row(&mut a, 2, 4, 7.0);
        let norm1: f32 = a.iter().map(|x| x * x).sum();
        assert!((norm0 - norm1).abs() < 1e-4, "rotation preserves norm");
        assert!(a.iter().zip(&b0).any(|(x, y)| (x - y).abs() > 1e-6));
    }

    #[test]
    fn rms_norm_unit_rows() {
        // a row of equal values normalizes to ~the scale vector
        let mut x = Tensor2::from_vec(1, 4, vec![3.0; 4]);
        rms_norm_rows(&mut x, &[1.0, 2.0, 0.5, 1.0]);
        let want = [1.0f32, 2.0, 0.5, 1.0];
        for (a, b) in x.data.iter().zip(&want) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
    }
}

//! Execution backends: real PJRT artifacts or the gpusim cost model.

use anyhow::{anyhow, bail, Result};

use crate::format::nested::NestedTensor;
use crate::format::tensor::Tensor2;
use crate::gemm::{GemmEngine, GemmFormat, GemmWeights};
use crate::gpusim::{self, StepKind, StepQuery, WeightFormat};
use crate::model::zoo::ModelSpec;
use crate::runtime::{HostTensor, ModelRuntime};

use super::kv::{KvCacheManager, KvGeometry};
use super::precision::Precision;

/// Result of one backend step.
pub struct StepRun {
    /// Flattened logits (`[V]` for prefill, `[B, V]` for decode); None
    /// for the simulation backend.
    pub logits: Option<Vec<f32>>,
    /// Latency this step contributed, seconds (wall for real, modelled
    /// for sim).
    pub latency: f64,
}

/// A model-execution backend for the engine.
pub trait Backend {
    fn geometry(&self) -> KvGeometry;
    fn prefill_chunks(&self) -> Vec<usize>;
    fn max_decode_batch(&self) -> usize;

    /// Prefill `tokens` for `slot` starting at `start_pos`; scatter the
    /// new KV into the slot.
    fn prefill(
        &mut self,
        kv: &mut KvCacheManager,
        slot: usize,
        start_pos: usize,
        tokens: &[i32],
        precision: Precision,
    ) -> Result<StepRun>;

    /// One decode iteration over `slots`/`tokens`/`positions` (parallel
    /// arrays); scatters each sequence's new KV.
    fn decode(
        &mut self,
        kv: &mut KvCacheManager,
        slots: &[usize],
        tokens: &[i32],
        positions: &[i32],
        precision: Precision,
    ) -> Result<StepRun>;
}

// ---------------------------------------------------------------------------
// Real backend: PJRT CPU execution of the AOT artifacts
// ---------------------------------------------------------------------------

/// Maps the controller's precision to artifact modes.
#[derive(Clone, Copy, Debug)]
pub struct ModeMap {
    /// Artifact mode used when the controller says FP16.
    pub fp16_mode: &'static str,
    /// Artifact mode used when the controller says FP8.
    pub fp8_mode: &'static str,
}

impl Default for ModeMap {
    fn default() -> Self {
        // NestedFP serving: both modes come from the single nested store
        ModeMap {
            fp16_mode: "nested16",
            fp8_mode: "nested8",
        }
    }
}

/// Executes the compiled step functions; used by the e2e examples and the
/// integration tests.
pub struct RealBackend {
    pub rt: ModelRuntime,
    pub modes: ModeMap,
    /// Host compute engine over the same weight store the artifacts use.
    /// `prefill`/`decode` run their linear layers inside the compiled
    /// artifacts; [`RealBackend::native_gemm`] is the host twin of the
    /// "gemm"-kind artifacts, and is what the examples and integration
    /// tests pin the artifacts against (replacing the old reconstruct +
    /// `Tensor2::matmul` reference path).
    pub gemm: GemmEngine,
    geo: KvGeometry,
    /// Reused dense-gather scratch (the AOT inputs are fixed-shape, so
    /// these stay at their high-water size instead of reallocating per
    /// step).
    gather_k: Vec<f32>,
    gather_v: Vec<f32>,
}

impl RealBackend {
    pub fn new(rt: ModelRuntime, modes: ModeMap, total_blocks: usize) -> RealBackend {
        let m = &rt.manifest.model;
        let geo = KvGeometry {
            n_layers: m.n_layers,
            n_heads: m.n_heads,
            max_seq: m.max_seq,
            head_dim: m.head_dim,
            block_size: 16,
            total_blocks,
        };
        RealBackend {
            rt,
            modes,
            gemm: GemmEngine::default(),
            geo,
            gather_k: Vec::new(),
            gather_v: Vec::new(),
        }
    }

    fn mode_str(&self, p: Precision) -> &'static str {
        match p {
            Precision::Fp16 => self.modes.fp16_mode,
            Precision::Fp8 => self.modes.fp8_mode,
        }
    }

    /// Assemble the engine operand for one weight-store layer under an
    /// artifact mode ("fp16" | "nested16" | "nested8").
    fn store_weights(&self, mode: &str, layer: &str) -> Result<(GemmWeights, GemmFormat)> {
        match mode {
            "fp16" => {
                let t = self.rt.weights.get(&format!("{layer}.f16"))?;
                if t.dims.len() != 2 {
                    bail!("{layer}.f16: expected a [N,K] matrix, got dims {:?}", t.dims);
                }
                let (rows, cols) = (t.dims[0], t.dims[1]);
                Ok((
                    GemmWeights::F16 {
                        rows,
                        cols,
                        bits: t.as_u16()?,
                    },
                    GemmFormat::Fp16,
                ))
            }
            "nested16" | "nested8" => {
                let upper = self.rt.weights.get(&format!("{layer}.upper"))?;
                if upper.dims.len() != 2 {
                    bail!("{layer}.upper: expected a [N,K] matrix, got dims {:?}", upper.dims);
                }
                let (rows, cols) = (upper.dims[0], upper.dims[1]);
                // the FP8 path's memory story holds at this layer too: the
                // lower plane is only fetched (and copied) in nested16
                // mode. The nested8 tensor carries an empty lower — valid
                // only for the Nested8 format it is returned with.
                let lower = if mode == "nested16" {
                    let lower = self.rt.weights.get(&format!("{layer}.lower"))?;
                    if lower.dims != upper.dims {
                        bail!(
                            "{layer}: plane dims mismatch {:?} vs {:?}",
                            upper.dims,
                            lower.dims
                        );
                    }
                    lower.bytes.clone()
                } else {
                    Vec::new()
                };
                let t = NestedTensor {
                    rows,
                    cols,
                    upper: upper.bytes.clone(),
                    lower,
                    fully_eligible: true,
                };
                let fmt = if mode == "nested16" {
                    GemmFormat::Nested16
                } else {
                    GemmFormat::Nested8
                };
                Ok((GemmWeights::Nested(t), fmt))
            }
            other => bail!("native_gemm: unknown mode '{other}'"),
        }
    }

    /// Execute one layer's GEMM (`x` [M,K] × layer weights [N,K]ᵀ)
    /// natively on the host compute engine, straight from the weight
    /// store's planes — the CPU twin of the AOT "gemm" artifacts. In
    /// `nested16` mode the pack stage reconstructs exact FP16 bits from
    /// both planes; in `nested8` mode it streams only the upper plane.
    ///
    /// This is a verification path, not the serving hot loop: each call
    /// copies the layer's plane(s) out of the store to build the engine
    /// operand. Cache the result (or the `GemmWeights`) if calling
    /// per-step.
    pub fn native_gemm(&self, mode: &str, layer: &str, x: &Tensor2) -> Result<Tensor2> {
        let (w, fmt) = self.store_weights(mode, layer)?;
        if x.cols != w.cols() {
            bail!(
                "native_gemm {layer}: x is [{},{}] but weights are [{},{}]",
                x.rows,
                x.cols,
                w.rows(),
                w.cols()
            );
        }
        Ok(self.gemm.matmul(x, &w, fmt))
    }
}

impl Backend for RealBackend {
    fn geometry(&self) -> KvGeometry {
        self.geo
    }

    fn prefill_chunks(&self) -> Vec<usize> {
        self.rt.manifest.prefill_chunks.clone()
    }

    fn max_decode_batch(&self) -> usize {
        self.rt.manifest.decode_buckets.iter().copied().max().unwrap_or(1)
    }

    fn prefill(
        &mut self,
        kv: &mut KvCacheManager,
        slot: usize,
        start_pos: usize,
        tokens: &[i32],
        precision: Precision,
    ) -> Result<StepRun> {
        let mode = self.mode_str(precision);
        let chunk = tokens.len();
        let step = self.rt.step("prefill", mode, chunk)?;
        let g = self.geo;
        // dense-gather the sequence through its block table (FP8 blocks
        // dequantize on the fly) into the fixed AOT shape
        kv.gather_seq(slot, &mut self.gather_k, &mut self.gather_v);
        let dims = vec![g.n_layers, g.n_heads, g.max_seq, g.head_dim];
        let ck = HostTensor::from_f32(dims.clone(), &self.gather_k);
        let cv = HostTensor::from_f32(dims, &self.gather_v);
        let t0 = std::time::Instant::now();
        let out = self.rt.run(
            step,
            &[
                HostTensor::from_i32(vec![chunk], tokens),
                HostTensor::from_i32(vec![], &[start_pos as i32]),
                ck,
                cv,
            ],
        )?;
        let latency = t0.elapsed().as_secs_f64();
        let logits = out.tensors[0].as_f32()?;
        let nk = out.tensors[1].as_f32()?;
        let nv = out.tensors[2].as_f32()?;
        kv.scatter_prefill(slot, start_pos, chunk, &nk, &nv);
        Ok(StepRun {
            logits: Some(logits),
            latency,
        })
    }

    fn decode(
        &mut self,
        kv: &mut KvCacheManager,
        slots: &[usize],
        tokens: &[i32],
        positions: &[i32],
        precision: Precision,
    ) -> Result<StepRun> {
        let mode = self.mode_str(precision);
        let n = slots.len();
        let bucket = self.rt.manifest.decode_bucket_for(n);
        if n > bucket {
            return Err(anyhow!("decode batch {n} exceeds largest bucket {bucket}"));
        }
        // pad the batch to the bucket: padding lanes reuse slot 0's cache
        // geometry with token 0 / pos 0; their outputs are discarded
        let mut pad_slots: Vec<usize> = slots.to_vec();
        let mut pad_tokens: Vec<i32> = tokens.to_vec();
        let mut pad_pos: Vec<i32> = positions.to_vec();
        while pad_slots.len() < bucket {
            pad_slots.push(slots[0]);
            pad_tokens.push(0);
            pad_pos.push(0);
        }

        let g = self.geo;
        kv.gather_batch(&pad_slots, &mut self.gather_k, &mut self.gather_v);
        let dims = vec![bucket, g.n_layers, g.n_heads, g.max_seq, g.head_dim];
        let step = self.rt.step("decode", mode, bucket)?;
        let t0 = std::time::Instant::now();
        let out = self.rt.run(
            step,
            &[
                HostTensor::from_i32(vec![bucket], &pad_tokens),
                HostTensor::from_i32(vec![bucket], &pad_pos),
                HostTensor::from_f32(dims.clone(), &self.gather_k),
                HostTensor::from_f32(dims, &self.gather_v),
            ],
        )?;
        let latency = t0.elapsed().as_secs_f64();
        let logits_all = out.tensors[0].as_f32()?;
        let nk = out.tensors[1].as_f32()?; // [B, L, H, Dh]
        let nv = out.tensors[2].as_f32()?;
        let vocab = logits_all.len() / bucket;
        let per = g.n_layers * g.n_heads * g.head_dim;
        for (i, &slot) in slots.iter().enumerate() {
            kv.scatter_decode(
                slot,
                positions[i] as usize,
                &nk[i * per..(i + 1) * per],
                &nv[i * per..(i + 1) * per],
            );
        }
        Ok(StepRun {
            logits: Some(logits_all[..n * vocab].to_vec()),
            latency,
        })
    }
}

// ---------------------------------------------------------------------------
// Simulation backend: gpusim-costed H100 serving (the paper's figures)
// ---------------------------------------------------------------------------

/// Costs iterations with the analytical H100 model; produces no logits
/// (simulated requests run to their fixed output budget).
pub struct SimBackend {
    pub spec: &'static ModelSpec,
    /// Format used when the controller says FP16 / FP8.
    pub fp16_format: WeightFormat,
    pub fp8_format: WeightFormat,
    pub max_batch: usize,
    pub chunks: Vec<usize>,
    geo: KvGeometry,
}

impl SimBackend {
    pub fn new(
        spec: &'static ModelSpec,
        fp16_format: WeightFormat,
        fp8_format: WeightFormat,
        max_batch: usize,
        max_seq: usize,
        total_blocks: usize,
    ) -> SimBackend {
        let geo = KvGeometry {
            n_layers: spec.n_layers,
            n_heads: spec.n_heads,
            max_seq,
            head_dim: spec.head_dim,
            block_size: 16,
            total_blocks,
        };
        SimBackend {
            spec,
            fp16_format,
            fp8_format,
            max_batch,
            chunks: vec![64, 128, 256, 512],
            geo,
        }
    }

    fn fmt(&self, p: Precision) -> WeightFormat {
        match p {
            Precision::Fp16 => self.fp16_format,
            Precision::Fp8 => self.fp8_format,
        }
    }
}

impl Backend for SimBackend {
    fn geometry(&self) -> KvGeometry {
        self.geo
    }

    fn prefill_chunks(&self) -> Vec<usize> {
        self.chunks.clone()
    }

    fn max_decode_batch(&self) -> usize {
        self.max_batch
    }

    fn prefill(
        &mut self,
        kv: &mut KvCacheManager,
        slot: usize,
        start_pos: usize,
        tokens: &[i32],
        precision: Precision,
    ) -> Result<StepRun> {
        let _ = (kv.free_blocks(), slot); // accounting only
        let q = StepQuery {
            kind: StepKind::Prefill,
            m: tokens.len(),
            ctx: start_pos,
            seqs: 1,
            format: self.fmt(precision),
            opt: gpusim::OptLevel::Level3,
        };
        Ok(StepRun {
            logits: None,
            latency: gpusim::step_latency(self.spec, &q),
        })
    }

    fn decode(
        &mut self,
        kv: &mut KvCacheManager,
        slots: &[usize],
        _tokens: &[i32],
        positions: &[i32],
        precision: Precision,
    ) -> Result<StepRun> {
        let _ = kv.free_blocks();
        let avg_ctx = (positions.iter().map(|&p| p as usize).sum::<usize>()
            / positions.len().max(1))
        .max(1);
        let q = StepQuery {
            kind: StepKind::Decode,
            m: slots.len(),
            ctx: avg_ctx,
            seqs: slots.len(),
            format: self.fmt(precision),
            opt: gpusim::OptLevel::Level3,
        };
        Ok(StepRun {
            logits: None,
            latency: gpusim::step_latency(self.spec, &q),
        })
    }
}

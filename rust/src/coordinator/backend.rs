//! Execution backends: host-native NestedFP compute or the gpusim cost
//! model.

use anyhow::{anyhow, bail, Result};

use crate::attn::AttnEngine;
use crate::format::nested::NestedTensor;
use crate::format::tensor::Tensor2;
use crate::gemm::{GemmEngine, GemmFormat, GemmWeights};
use crate::gpusim::{self, StepKind, StepQuery, WeightFormat};
use crate::model::zoo::ModelSpec;
use crate::runtime::ModelRuntime;

use super::hostforward::{ForwardOut, HostForward, StepLane};
use super::kv::{KvCacheManager, KvGeometry};
use super::precision::{LayerSchedule, Precision};

/// Result of one backend step.
#[derive(Default)]
pub struct StepRun {
    /// Flattened logits (`[V]` for prefill, `[B, V]` for decode); None
    /// for the simulation backend.
    pub logits: Option<Vec<f32>>,
    /// Latency this step contributed, seconds (wall for real, modelled
    /// for sim).
    pub latency: f64,
    /// Bytes a dense-gather attention path would have copied this step
    /// (the pre-PR 5 `gather_seq`/`gather_batch` traffic).
    pub attn_dense_bytes: usize,
    /// KV bytes the block-native attention actually touched, at stored
    /// precision. The engine mirrors both counters into `Metrics`.
    pub attn_touched_bytes: usize,
    /// Host-side attention seconds this step spent serving piggybacked
    /// lanes (the sim backend's host cost law; 0 when no lane ran on
    /// the host tier, and 0 on the real backend whose latency is wall
    /// time and cannot be split per tier).
    pub host_attn_seconds: f64,
    /// Lanes of this step that attended over host-resident blocks.
    pub host_lanes: usize,
}

/// A model-execution backend for the engine.
pub trait Backend {
    fn geometry(&self) -> KvGeometry;
    fn prefill_chunks(&self) -> Vec<usize>;
    fn max_decode_batch(&self) -> usize;

    /// The active tensor-parallel degree. Backends without a shard
    /// dimension report 1 (the degenerate [`crate::shard::ShardPlan`]).
    fn tp_degree(&self) -> usize {
        1
    }

    /// Re-shard to `tp` devices. Only the resharder should call this —
    /// it owns the drain → repartition → resume discipline that makes
    /// the switch safe; the default (single-device backends) ignores it.
    fn set_tp_degree(&mut self, _tp: usize) {}

    /// The model served, when the backend knows it. The resharder uses
    /// this to bill the weight-move term of a repartition window;
    /// backends without a spec (accounting-only test backends) keep the
    /// `None` default and are billed the fixed latency floor alone.
    fn model_spec(&self) -> Option<&'static ModelSpec> {
        None
    }

    /// Install (or clear) the engine's per-layer precision schedule.
    /// The engine re-pushes the schedule whenever its demoted-layer
    /// count moves, so backends may cache it. Backends without a
    /// per-layer path (accounting-only test backends) ignore it — the
    /// `precision` argument of `prefill`/`decode` still carries the
    /// majority-rounded directive for them.
    fn set_layer_schedule(&mut self, _schedule: Option<&LayerSchedule>) {}

    /// Prefill `tokens` for `slot` starting at `start_pos`; scatter the
    /// new KV into the slot.
    fn prefill(
        &mut self,
        kv: &mut KvCacheManager,
        slot: usize,
        start_pos: usize,
        tokens: &[i32],
        precision: Precision,
    ) -> Result<StepRun>;

    /// One decode iteration over `slots`/`tokens`/`positions` (parallel
    /// arrays); scatters each sequence's new KV.
    fn decode(
        &mut self,
        kv: &mut KvCacheManager,
        slots: &[usize],
        tokens: &[i32],
        positions: &[i32],
        precision: Precision,
    ) -> Result<StepRun>;

    /// One **mixed-tier** decode iteration: the last `n_host` lanes
    /// attend over host-resident blocks (piggybacked), the rest over
    /// device blocks; the non-attention stages (QKV / FFN / LM head)
    /// run as one merged batch either way. With `n_host == 0` this is
    /// `decode` exactly — same code path, same bits — which is what the
    /// engine's tier-agnostic pipeline calls when piggybacking is off.
    /// Backends without a host lane path keep the default and assert.
    fn decode_mixed(
        &mut self,
        kv: &mut KvCacheManager,
        slots: &[usize],
        tokens: &[i32],
        positions: &[i32],
        precision: Precision,
        n_host: usize,
    ) -> Result<StepRun> {
        assert_eq!(n_host, 0, "backend cannot serve host-attention lanes");
        self.decode(kv, slots, tokens, positions, precision)
    }
}

// ---------------------------------------------------------------------------
// Real backend: host-native NestedFP execution over the artifact store
// ---------------------------------------------------------------------------

/// Maps the controller's precision to artifact modes.
#[derive(Clone, Copy, Debug)]
pub struct ModeMap {
    /// Artifact mode used when the controller says FP16.
    pub fp16_mode: &'static str,
    /// Artifact mode used when the controller says FP8.
    pub fp8_mode: &'static str,
}

impl Default for ModeMap {
    fn default() -> Self {
        // NestedFP serving: both modes come from the single nested store
        ModeMap {
            fp16_mode: "nested16",
            fp8_mode: "nested8",
        }
    }
}

/// Executes real model steps on the host — the e2e examples, the
/// integration tests, and `repro serve`.
///
/// Since PR 5 the step functions run **host-natively**
/// ([`HostForward`]): linear layers go through the fused NestedFP GEMM
/// engine straight from the weight store, and attention walks the paged
/// KV cache's block tables in place ([`crate::attn`]) — the dense
/// `gather_seq`/`gather_batch` staging the AOT artifacts required is
/// gone from the hot path (it survives as the test oracle,
/// `attn::oracle`). The PJRT artifacts remain loadable for the
/// artifact-parity integration tests (`rt.step` / `rt.run` under the
/// `pjrt` feature), but serving no longer needs them, so this backend
/// now works in every build where the artifact *files* exist.
pub struct RealBackend {
    pub rt: ModelRuntime,
    pub modes: ModeMap,
    /// Host compute engine over the same weight store the artifacts
    /// use; [`RealBackend::native_gemm`] exposes single layers for the
    /// kernel tour and the artifact-parity tests.
    pub gemm: GemmEngine,
    geo: KvGeometry,
    /// Lazily built host step executor (prepares per-mode weight
    /// operands once, then serves every step).
    host: Option<HostForward>,
    /// Per-layer precision schedule pushed by the engine. `None` (and
    /// the schedule's endpoints) take the uniform single-mode path;
    /// interior rungs dispatch [`HostForward::forward_morph`].
    schedule: Option<LayerSchedule>,
}

impl RealBackend {
    pub fn new(rt: ModelRuntime, modes: ModeMap, total_blocks: usize) -> RealBackend {
        let m = &rt.manifest.model;
        let geo = KvGeometry {
            n_layers: m.n_layers,
            n_heads: m.n_heads,
            max_seq: m.max_seq,
            head_dim: m.head_dim,
            block_size: 16,
            total_blocks,
        };
        RealBackend {
            rt,
            modes,
            gemm: GemmEngine::default(),
            geo,
            host: None,
            schedule: None,
        }
    }

    /// Initialize the host step executor on first use. Split from the
    /// call sites (which re-borrow `self.host` and `self.rt` as
    /// disjoint fields) so the engine wiring lives in exactly one
    /// place.
    fn ensure_host(&mut self) -> Result<()> {
        if self.host.is_none() {
            self.host = Some(HostForward::with_engines(
                &self.rt,
                self.gemm.clone(),
                AttnEngine::new(self.gemm.config().threads),
            )?);
        }
        Ok(())
    }

    fn mode_str(&self, p: Precision) -> &'static str {
        match p {
            Precision::Fp16 => self.modes.fp16_mode,
            Precision::Fp8 => self.modes.fp8_mode,
        }
    }

    /// Run one host step over `lanes`: the uniform `mode` path when no
    /// interior schedule is active, the per-layer hot/cold split
    /// otherwise. Weight-operand preparation happens here, before the
    /// timer starts — a precision or schedule switch must not bill
    /// store decoding as step latency (it would spike TPOT into the
    /// SLO control loop). Returns the forward output and the timed
    /// step latency.
    fn host_step(
        &mut self,
        kv: &mut KvCacheManager,
        mode: &'static str,
        lanes: &[StepLane],
    ) -> Result<(ForwardOut, f64)> {
        self.ensure_host()?;
        let cold_mask = match &self.schedule {
            Some(s) if s.demoted_layers() > 0 && s.demoted_layers() < s.n_layers() => {
                Some(s.cold_mask())
            }
            _ => None,
        };
        let host = self.host.as_mut().expect("ensured above");
        match &cold_mask {
            Some(_) => {
                host.prepare(&self.rt, self.modes.fp16_mode)?;
                host.prepare(&self.rt, self.modes.fp8_mode)?;
            }
            None => host.prepare(&self.rt, mode)?,
        }
        let t0 = std::time::Instant::now();
        let out = match &cold_mask {
            Some(mask) => host.forward_morph(
                &self.rt,
                kv,
                self.modes.fp16_mode,
                self.modes.fp8_mode,
                mask,
                lanes,
            )?,
            None => host.forward(&self.rt, kv, mode, lanes)?,
        };
        Ok((out, t0.elapsed().as_secs_f64()))
    }

    /// Assemble the engine operand for one weight-store layer under an
    /// artifact mode ("fp16" | "nested16" | "nested8").
    fn store_weights(&self, mode: &str, layer: &str) -> Result<(GemmWeights, GemmFormat)> {
        match mode {
            "fp16" => {
                let t = self.rt.weights.get(&format!("{layer}.f16"))?;
                if t.dims.len() != 2 {
                    bail!("{layer}.f16: expected a [N,K] matrix, got dims {:?}", t.dims);
                }
                let (rows, cols) = (t.dims[0], t.dims[1]);
                Ok((
                    GemmWeights::F16 {
                        rows,
                        cols,
                        bits: t.as_u16()?,
                    },
                    GemmFormat::Fp16,
                ))
            }
            "nested16" | "nested8" => {
                let upper = self.rt.weights.get(&format!("{layer}.upper"))?;
                if upper.dims.len() != 2 {
                    bail!("{layer}.upper: expected a [N,K] matrix, got dims {:?}", upper.dims);
                }
                let (rows, cols) = (upper.dims[0], upper.dims[1]);
                // the FP8 path's memory story holds at this layer too: the
                // lower plane is only fetched (and copied) in nested16
                // mode. The nested8 tensor carries an empty lower — valid
                // only for the Nested8 format it is returned with.
                let lower = if mode == "nested16" {
                    let lower = self.rt.weights.get(&format!("{layer}.lower"))?;
                    if lower.dims != upper.dims {
                        bail!(
                            "{layer}: plane dims mismatch {:?} vs {:?}",
                            upper.dims,
                            lower.dims
                        );
                    }
                    lower.bytes.clone()
                } else {
                    Vec::new()
                };
                let t = NestedTensor {
                    rows,
                    cols,
                    upper: upper.bytes.clone(),
                    lower,
                    fully_eligible: true,
                };
                let fmt = if mode == "nested16" {
                    GemmFormat::Nested16
                } else {
                    GemmFormat::Nested8
                };
                Ok((GemmWeights::Nested(t), fmt))
            }
            other => bail!("native_gemm: unknown mode '{other}'"),
        }
    }

    /// Execute one layer's GEMM (`x` [M,K] × layer weights [N,K]ᵀ)
    /// natively on the host compute engine, straight from the weight
    /// store's planes — the CPU twin of the AOT "gemm" artifacts. In
    /// `nested16` mode the pack stage reconstructs exact FP16 bits from
    /// both planes; in `nested8` mode it streams only the upper plane.
    ///
    /// This is a verification path, not the serving hot loop: each call
    /// copies the layer's plane(s) out of the store to build the engine
    /// operand. Cache the result (or the `GemmWeights`) if calling
    /// per-step.
    pub fn native_gemm(&self, mode: &str, layer: &str, x: &Tensor2) -> Result<Tensor2> {
        let (w, fmt) = self.store_weights(mode, layer)?;
        if x.cols != w.cols() {
            bail!(
                "native_gemm {layer}: x is [{},{}] but weights are [{},{}]",
                x.rows,
                x.cols,
                w.rows(),
                w.cols()
            );
        }
        Ok(self.gemm.matmul(x, &w, fmt))
    }
}

impl Backend for RealBackend {
    fn geometry(&self) -> KvGeometry {
        self.geo
    }

    fn prefill_chunks(&self) -> Vec<usize> {
        self.rt.manifest.prefill_chunks.clone()
    }

    fn max_decode_batch(&self) -> usize {
        self.rt.manifest.decode_buckets.iter().copied().max().unwrap_or(1)
    }

    fn set_layer_schedule(&mut self, schedule: Option<&LayerSchedule>) {
        // clone only on change: the engine re-pushes every decide()
        match (schedule, &self.schedule) {
            (None, None) => {}
            (Some(s), Some(cur)) if s == cur => {}
            _ => self.schedule = schedule.cloned(),
        }
    }

    /// One prompt chunk, host-native: the forward pass scatters each
    /// layer's fresh K/V into the slot's blocks and attends over the
    /// block table directly — the dense `[L, H, max_seq, Dh]` staging
    /// the AOT path needed never materializes.
    fn prefill(
        &mut self,
        kv: &mut KvCacheManager,
        slot: usize,
        start_pos: usize,
        tokens: &[i32],
        precision: Precision,
    ) -> Result<StepRun> {
        let mode = self.mode_str(precision);
        let positions: Vec<i32> = (0..tokens.len()).map(|i| (start_pos + i) as i32).collect();
        let lanes = [StepLane {
            seq: slot,
            tokens,
            positions: &positions,
        }];
        let (out, latency) = self.host_step(kv, mode, &lanes)?;
        Ok(StepRun {
            logits: Some(out.logits),
            latency,
            attn_dense_bytes: out.attn.dense_bytes,
            attn_touched_bytes: out.attn.touched_bytes,
            ..StepRun::default()
        })
    }

    /// One decode iteration, host-native and block-native. The batch is
    /// exactly its real lanes: padding lanes are zero-length here (the
    /// pre-PR 5 path padded to the artifact bucket and re-gathered slot
    /// 0's entire cache per pad lane; a dense path that still needs
    /// bucket shapes zero-fills instead, via
    /// `PagedKvCache::gather_batch_padded`). An empty batch returns an
    /// empty `StepRun` instead of panicking on `slots[0]`.
    fn decode(
        &mut self,
        kv: &mut KvCacheManager,
        slots: &[usize],
        tokens: &[i32],
        positions: &[i32],
        precision: Precision,
    ) -> Result<StepRun> {
        let n = slots.len();
        if n == 0 {
            return Ok(StepRun {
                logits: Some(Vec::new()),
                ..StepRun::default()
            });
        }
        let max_batch = self.max_decode_batch();
        if n > max_batch {
            return Err(anyhow!("decode batch {n} exceeds max batch {max_batch}"));
        }
        let mode = self.mode_str(precision);
        let lanes: Vec<StepLane> = (0..n)
            .map(|i| StepLane {
                seq: slots[i],
                tokens: &tokens[i..i + 1],
                positions: &positions[i..i + 1],
            })
            .collect();
        let (out, latency) = self.host_step(kv, mode, &lanes)?;
        Ok(StepRun {
            logits: Some(out.logits),
            latency,
            attn_dense_bytes: out.attn.dense_bytes,
            attn_touched_bytes: out.attn.touched_bytes,
            ..StepRun::default()
        })
    }

    /// Mixed-tier decode: same merged batch as [`Self::decode`], with
    /// the attention walk switched to the any-tier entry so the trailing
    /// `n_host` lanes read their host-resident blocks in place. Latency
    /// stays wall time — on the host twin both tiers are the same DRAM,
    /// so there is no per-tier split to report.
    fn decode_mixed(
        &mut self,
        kv: &mut KvCacheManager,
        slots: &[usize],
        tokens: &[i32],
        positions: &[i32],
        precision: Precision,
        n_host: usize,
    ) -> Result<StepRun> {
        if n_host == 0 {
            return self.decode(kv, slots, tokens, positions, precision);
        }
        assert!(n_host <= slots.len(), "host lanes exceed batch");
        self.ensure_host()?;
        self.host.as_mut().expect("ensured above").set_any_tier(true);
        let res = self.decode(kv, slots, tokens, positions, precision);
        self.host.as_mut().expect("ensured above").set_any_tier(false);
        let mut run = res?;
        run.host_lanes = n_host;
        Ok(run)
    }
}

// ---------------------------------------------------------------------------
// Simulation backend: gpusim-costed H100 serving (the paper's figures)
// ---------------------------------------------------------------------------

/// Costs iterations with the analytical H100 model; produces no logits
/// (simulated requests run to their fixed output budget).
pub struct SimBackend {
    pub spec: &'static ModelSpec,
    /// Format used when the controller says FP16 / FP8.
    pub fp16_format: WeightFormat,
    pub fp8_format: WeightFormat,
    pub max_batch: usize,
    pub chunks: Vec<usize>,
    geo: KvGeometry,
    /// Active tensor-parallel degree (1 = the whole model on one sim
    /// device; see `gpusim::step_latency_tp` for the shard cost law).
    tp: usize,
    /// Layers currently demoted to the FP8 format by the engine's
    /// per-layer schedule; 0 (no schedule / FP16 endpoint) and
    /// `n_layers` (FP8 endpoint) take the legacy uniform cost path.
    demoted: usize,
}

impl SimBackend {
    pub fn new(
        spec: &'static ModelSpec,
        fp16_format: WeightFormat,
        fp8_format: WeightFormat,
        max_batch: usize,
        max_seq: usize,
        total_blocks: usize,
    ) -> SimBackend {
        let geo = KvGeometry {
            n_layers: spec.n_layers,
            n_heads: spec.n_heads,
            max_seq,
            head_dim: spec.head_dim,
            block_size: 16,
            total_blocks,
        };
        SimBackend {
            spec,
            fp16_format,
            fp8_format,
            max_batch,
            chunks: vec![64, 128, 256, 512],
            geo,
            tp: 1,
            demoted: 0,
        }
    }

    fn fmt(&self, p: Precision) -> WeightFormat {
        match p {
            Precision::Fp16 => self.fp16_format,
            Precision::Fp8 => self.fp8_format,
        }
    }

    /// Cost one step: the uniform model when the schedule sits at an
    /// endpoint (bit-identical to the pre-morph path), the hot/cold
    /// split otherwise. At the FP8 endpoint the majority-rounded
    /// `q.format` is already the FP8 format, so the uniform call prices
    /// every layer cold — no separate branch needed.
    fn step_cost(&self, q: &StepQuery) -> f64 {
        if self.demoted > 0 && self.demoted < self.spec.n_layers {
            let q16 = StepQuery {
                format: self.fp16_format,
                ..*q
            };
            gpusim::step_latency_split_tp(self.spec, &q16, self.fp8_format, self.demoted, self.tp)
        } else {
            gpusim::step_latency_tp(self.spec, q, self.tp)
        }
    }
}

impl Backend for SimBackend {
    fn geometry(&self) -> KvGeometry {
        self.geo
    }

    fn prefill_chunks(&self) -> Vec<usize> {
        self.chunks.clone()
    }

    fn max_decode_batch(&self) -> usize {
        self.max_batch
    }

    fn tp_degree(&self) -> usize {
        self.tp
    }

    fn set_tp_degree(&mut self, tp: usize) {
        assert!(tp >= 1 && tp.is_power_of_two(), "bad tp degree {tp}");
        self.tp = tp;
    }

    fn model_spec(&self) -> Option<&'static ModelSpec> {
        Some(self.spec)
    }

    fn set_layer_schedule(&mut self, schedule: Option<&LayerSchedule>) {
        self.demoted = schedule.map_or(0, |s| s.demoted_layers().min(self.spec.n_layers));
    }

    fn prefill(
        &mut self,
        kv: &mut KvCacheManager,
        slot: usize,
        start_pos: usize,
        tokens: &[i32],
        precision: Precision,
    ) -> Result<StepRun> {
        let q = StepQuery {
            kind: StepKind::Prefill,
            m: tokens.len(),
            ctx: start_pos,
            seqs: 1,
            format: self.fmt(precision),
            opt: gpusim::OptLevel::Level3,
        };
        // attention-traffic accounting (the block tables are real even
        // in the accounting-only cache): dense = one full gather, block
        // = the covering blocks at stored precision, per layer
        let g = self.geo;
        let ctx = (start_pos + tokens.len()).min(g.max_seq);
        Ok(StepRun {
            logits: None,
            latency: self.step_cost(&q),
            attn_dense_bytes: g.n_layers * g.layer_dense_bytes(),
            attn_touched_bytes: g.n_layers * kv.seq_touched_bytes(slot, ctx),
            ..StepRun::default()
        })
    }

    fn decode(
        &mut self,
        kv: &mut KvCacheManager,
        slots: &[usize],
        _tokens: &[i32],
        positions: &[i32],
        precision: Precision,
    ) -> Result<StepRun> {
        let avg_ctx = (positions.iter().map(|&p| p as usize).sum::<usize>()
            / positions.len().max(1))
        .max(1);
        let q = StepQuery {
            kind: StepKind::Decode,
            m: slots.len(),
            ctx: avg_ctx,
            seqs: slots.len(),
            format: self.fmt(precision),
            opt: gpusim::OptLevel::Level3,
        };
        let g = self.geo;
        let mut touched = 0usize;
        for (&slot, &pos) in slots.iter().zip(positions) {
            let ctx = (pos as usize + 1).min(g.max_seq);
            touched += g.n_layers * kv.seq_touched_bytes(slot, ctx);
        }
        Ok(StepRun {
            logits: None,
            latency: self.step_cost(&q),
            attn_dense_bytes: slots.len() * g.n_layers * g.layer_dense_bytes(),
            attn_touched_bytes: touched,
            ..StepRun::default()
        })
    }

    /// Mixed-tier decode under the cost model: one merged batch for the
    /// non-attention stages, the attention term split per tier. The
    /// device keeps its step law minus the attention walk of the
    /// trailing `n_host` lanes ([`gpusim::device_attention_seconds`] is
    /// calibrated to isolate exactly that term); those lanes' KV bytes
    /// are billed on the host law instead
    /// ([`gpusim::host_attention_seconds`]), and the two tiers overlap:
    /// iteration latency is the max, not the sum. Under `tp > 1` the
    /// attention swap still uses the single-device law — slightly
    /// conservative for the piggyback win, never optimistic.
    fn decode_mixed(
        &mut self,
        kv: &mut KvCacheManager,
        slots: &[usize],
        tokens: &[i32],
        positions: &[i32],
        precision: Precision,
        n_host: usize,
    ) -> Result<StepRun> {
        if n_host == 0 {
            // bit-identical to the unsplit path when nothing piggybacks
            return self.decode(kv, slots, tokens, positions, precision);
        }
        let n = slots.len();
        assert!(n_host <= n, "host lanes exceed batch");
        let n_dev = n - n_host;
        let avg_ctx = |ps: &[i32]| {
            (ps.iter().map(|&p| p as usize).sum::<usize>() / ps.len().max(1)).max(1)
        };
        let q = StepQuery {
            kind: StepKind::Decode,
            m: n,
            ctx: avg_ctx(positions),
            seqs: n,
            format: self.fmt(precision),
            opt: gpusim::OptLevel::Level3,
        };
        let t_all = self.step_cost(&q);
        let attn_all = gpusim::device_attention_seconds(self.spec, n, avg_ctx(positions));
        let attn_dev =
            gpusim::device_attention_seconds(self.spec, n_dev, avg_ctx(&positions[..n_dev]));
        let g = self.geo;
        let mut touched = 0usize;
        let mut host_bytes = 0usize;
        for (i, (&slot, &pos)) in slots.iter().zip(positions).enumerate() {
            let ctx = (pos as usize + 1).min(g.max_seq);
            let b = g.n_layers * kv.seq_touched_bytes(slot, ctx);
            touched += b;
            if i >= n_dev {
                host_bytes += b;
            }
        }
        let t_host = gpusim::host_attention_seconds(g.n_layers, host_bytes);
        let t_gpu = (t_all - attn_all + attn_dev).max(0.0);
        Ok(StepRun {
            logits: None,
            latency: t_gpu.max(t_host),
            attn_dense_bytes: n * g.n_layers * g.layer_dense_bytes(),
            attn_touched_bytes: touched,
            host_attn_seconds: t_host,
            host_lanes: n_host,
        })
    }
}

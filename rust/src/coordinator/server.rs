//! Minimal TCP front-end for interactive serving (std-net, thread-based —
//! tokio is unavailable offline).
//!
//! Line protocol (UTF-8, one request per line):
//!
//! ```text
//! -> GEN <max_new_tokens> <prompt text...>
//! <- OK <ttft_ms> <tpot_ms> <completion text...>
//! <- ERR <message>
//! ```
//!
//! The server owns a single engine worker thread; client threads submit
//! requests through a channel and wait on a per-request response channel.
//! This mirrors a serving deployment's (router → engine) split at a small
//! scale; the batching still happens inside the engine across concurrent
//! client connections.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};

use anyhow::Result;

use super::backend::Backend;
use super::engine::Engine;
use super::request::Request;

/// A submitted job: prompt plus the channel to answer on.
pub struct Job {
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    pub stop_token: Option<i32>,
    pub respond: mpsc::Sender<JobResult>,
}

/// Completed job.
#[derive(Clone, Debug)]
pub struct JobResult {
    pub tokens: Vec<i32>,
    pub ttft_s: f64,
    pub mean_tpot_s: f64,
}

/// Serve jobs forever on the engine thread: collect whatever is queued,
/// run it as one workload batch, answer, repeat. Returns when the job
/// channel closes.
pub fn engine_worker<B: Backend>(
    mut engine: Engine<B>,
    jobs: mpsc::Receiver<Job>,
) -> Result<()> {
    let mut next_id = 0u64;
    loop {
        // block for the first job, then drain whatever arrived meanwhile
        let first = match jobs.recv() {
            Ok(j) => j,
            Err(_) => return Ok(()), // channel closed
        };
        let mut batch = vec![first];
        while let Ok(j) = jobs.try_recv() {
            batch.push(j);
        }

        let mut requests = Vec::new();
        for job in &batch {
            let mut r = Request::new(next_id, job.prompt.clone(), job.max_new_tokens, 0.0);
            if let Some(s) = job.stop_token {
                r = r.with_stop(s);
            }
            requests.push(r);
            next_id += 1;
        }
        let id_base = next_id - batch.len() as u64;

        // run this batch; harvest per-request outputs from a completion
        // callback shim: the engine drops finished bodies, so we record
        // generations by re-running with collection enabled
        let outputs = run_collecting(&mut engine, requests)?;
        for (i, job) in batch.into_iter().enumerate() {
            let id = id_base + i as u64;
            let out = outputs
                .iter()
                .find(|(rid, _)| *rid == id)
                .map(|(_, o)| o.clone())
                .unwrap_or(JobResult {
                    tokens: vec![],
                    ttft_s: 0.0,
                    mean_tpot_s: 0.0,
                });
            let _ = job.respond.send(out);
        }
    }
}

/// Run a workload and collect per-request outputs (id → result).
pub fn run_collecting<B: Backend>(
    engine: &mut Engine<B>,
    requests: Vec<Request>,
) -> Result<Vec<(u64, JobResult)>> {
    let report = engine.run(requests)?;
    Ok(report
        .completions
        .into_iter()
        .map(|c| {
            (
                c.id,
                JobResult {
                    tokens: c.tokens,
                    ttft_s: c.ttft_s,
                    mean_tpot_s: c.mean_tpot_s,
                },
            )
        })
        .collect())
}

/// Accept loop: spawns one thread per connection.
pub fn serve(listener: TcpListener, jobs: mpsc::Sender<Job>, stop_token: Option<i32>) -> Result<()> {
    let jobs = Arc::new(Mutex::new(jobs));
    for stream in listener.incoming() {
        let stream = stream?;
        let jobs = Arc::clone(&jobs);
        std::thread::spawn(move || {
            let _ = handle_client(stream, jobs, stop_token);
        });
    }
    Ok(())
}

fn handle_client(
    stream: TcpStream,
    jobs: Arc<Mutex<mpsc::Sender<Job>>>,
    stop_token: Option<i32>,
) -> Result<()> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut out = stream;
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            return Ok(()); // disconnected
        }
        let trimmed = line.trim_end();
        let reply = match parse_gen(trimmed) {
            Some((max_new, prompt)) => {
                let (tx, rx) = mpsc::channel();
                let job = Job {
                    prompt,
                    max_new_tokens: max_new,
                    stop_token,
                    respond: tx,
                };
                jobs.lock().unwrap().send(job).ok();
                match rx.recv() {
                    Ok(res) => {
                        let text: String = res
                            .tokens
                            .iter()
                            .map(|&t| (t as u8) as char)
                            .collect();
                        format!(
                            "OK {:.1} {:.2} {}\n",
                            res.ttft_s * 1e3,
                            res.mean_tpot_s * 1e3,
                            text
                        )
                    }
                    Err(_) => "ERR engine gone\n".to_string(),
                }
            }
            None => "ERR usage: GEN <max_new> <prompt>\n".to_string(),
        };
        out.write_all(reply.as_bytes())?;
    }
}

/// Parse "GEN <n> <prompt...>"; prompts are byte-level tokens.
pub fn parse_gen(line: &str) -> Option<(usize, Vec<i32>)> {
    let rest = line.strip_prefix("GEN ")?;
    let (n, prompt) = rest.split_once(' ')?;
    let max_new: usize = n.parse().ok()?;
    if prompt.is_empty() || max_new == 0 {
        return None;
    }
    Some((max_new, prompt.bytes().map(|b| b as i32).collect()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_gen_lines() {
        assert_eq!(
            parse_gen("GEN 8 C:ab="),
            Some((8, vec![67, 58, 97, 98, 61]))
        );
        assert!(parse_gen("GEN x yz").is_none());
        assert!(parse_gen("GEN 8 ").is_none());
        assert!(parse_gen("NOPE 8 x").is_none());
        assert!(parse_gen("GEN 0 x").is_none());
    }
}
